# Tier-1 verification (ROADMAP.md): CPU-only, wall-clock bounded so the
# eager-loop regression class (host-synced peel rounds) is caught
# mechanically — a hung or quadratically-slow suite fails, not stalls.
VERIFY_BUDGET ?= 2400

.PHONY: verify bench quick-bench

verify:
	JAX_PLATFORMS=cpu PYTHONPATH=src timeout $(VERIFY_BUDGET) \
		python -m pytest -x -q

bench:
	JAX_PLATFORMS=cpu PYTHONPATH=src python -m benchmarks.run

quick-bench:
	JAX_PLATFORMS=cpu PYTHONPATH=src python -m benchmarks.run --quick
