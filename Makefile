# Tier-1 verification (ROADMAP.md): CPU-only, wall-clock bounded so the
# eager-loop regression class (host-synced peel rounds) is caught
# mechanically — a hung or quadratically-slow suite fails, not stalls.
VERIFY_BUDGET ?= 2400
FAST_BUDGET ?= 1800

.PHONY: verify verify-fast bench quick-bench regen-golden

verify:
	JAX_PLATFORMS=cpu PYTHONPATH=src timeout $(VERIFY_BUDGET) \
		python -m pytest -x -q

# the push lane: everything not marked slow (no subprocess meshes, no
# hypothesis fuzzing) — CI runs this on every push, the full suite in a
# second job
verify-fast:
	JAX_PLATFORMS=cpu PYTHONPATH=src timeout $(FAST_BUDGET) \
		python -m pytest -x -q -m "not slow"

bench:
	JAX_PLATFORMS=cpu PYTHONPATH=src python -m benchmarks.run

quick-bench:
	JAX_PLATFORMS=cpu PYTHONPATH=src python -m benchmarks.run --quick

# rewrite tests/golden/*.json from the oracle-pinned gather+replay path;
# the JSON diff is the review artifact for any intentional semantic change
regen-golden:
	JAX_PLATFORMS=cpu PYTHONPATH=src python tools/regen_golden.py
