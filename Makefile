# Tier-1 verification (ROADMAP.md): CPU-only, wall-clock bounded so the
# eager-loop regression class (host-synced peel rounds) is caught
# mechanically — a hung or quadratically-slow suite fails, not stalls.
# Budgets re-baselined for PR 3: the facade parity matrix adds ~2 engine
# compiles per fixture cell to the full suite (fast lane carries only the
# (2,3) column) plus the sharded-combo matrix in the slow lane.
VERIFY_BUDGET ?= 3300
FAST_BUDGET ?= 2100

.PHONY: verify verify-core verify-facade verify-fast bench quick-bench \
	regen-golden smoke bench-build calibrate kernel-tests lint-nucleus

verify:
	JAX_PLATFORMS=cpu PYTHONPATH=src timeout $(VERIFY_BUDGET) \
		python -m pytest -x -q

# the full suite split in two so the facade/golden chunk's long-standing
# interpreter-teardown segfault (exit 139 AFTER all tests pass — a CPython
# finalization flake, not a test failure) cannot mask the rest of tier-1:
# verify-core is everything else and must be green; verify-facade is just
# the two facade-parity files, isolated so a rerun/triage targets them.
verify-core:
	JAX_PLATFORMS=cpu PYTHONPATH=src timeout $(VERIFY_BUDGET) \
		python -m pytest -x -q \
		--ignore=tests/test_facade.py --ignore=tests/test_golden.py

verify-facade:
	JAX_PLATFORMS=cpu PYTHONPATH=src timeout $(VERIFY_BUDGET) \
		python -m pytest -x -q tests/test_facade.py tests/test_golden.py

# the push lane: everything not marked slow (no subprocess meshes, no
# hypothesis fuzzing) — CI runs this on every push, the full suite in a
# second job
verify-fast:
	JAX_PLATFORMS=cpu PYTHONPATH=src timeout $(FAST_BUDGET) \
		python -m pytest -x -q -m "not slow"

# nucleuslint (DESIGN.md §12): the jit/trace/concurrency static-analysis
# gate — fails on any finding not in the committed baseline.  Pure stdlib
# (no jax import), so it needs no accelerator deps and runs in seconds.
# LINT_FLAGS="--json findings.json --dead --dead-json dead.json" in CI.
LINT_FLAGS ?=
lint-nucleus:
	PYTHONPATH=src timeout 300 python -m repro.analysis src/repro \
		$(LINT_FLAGS)

bench:
	JAX_PLATFORMS=cpu PYTHONPATH=src python -m benchmarks.run

quick-bench:
	JAX_PLATFORMS=cpu PYTHONPATH=src python -m benchmarks.run --quick

# the Pallas kernel suite alone (interpret mode on CPU): the CI fast lane
# runs this as an explicit first step so a kernel-vs-oracle divergence is
# named in the job log before the full matrix runs
kernel-tests:
	JAX_PLATFORMS=cpu PYTHONPATH=src timeout 900 \
		python -m pytest -x -q -m "not slow" \
		tests/test_kernels.py tests/test_peel_round.py

# measure this device's planner crossovers (tiny_nr, the use_pallas=None
# verdict) and write the profile resolve_plan loads; the committed
# src/repro/core/planner_profile.json is this target's output on the
# reference CPU container.  CALIBRATE_FLAGS="--quick" for the CI smoke.
CALIBRATE_OUT ?= src/repro/core/planner_profile.json
CALIBRATE_FLAGS ?=
calibrate:
	JAX_PLATFORMS=cpu PYTHONPATH=src timeout 1800 \
		python tools/calibrate_planner.py --out $(CALIBRATE_OUT) \
		$(CALIBRATE_FLAGS)

# rewrite tests/golden/*.json from the oracle-pinned gather+replay path;
# the JSON diff is the review artifact for any intentional semantic change
regen-golden:
	JAX_PLATFORMS=cpu PYTHONPATH=src python tools/regen_golden.py

# chunked-incidence-builder CI gate: ba4k with a deliberately tiny memory
# budget — fails on any deviation from the golden build fingerprint or a
# >20% budget overshoot (tools/check_build_budget.py; DESIGN.md §7)
bench-build:
	JAX_PLATFORMS=cpu PYTHONPATH=src timeout 900 \
		python tools/check_build_budget.py

# examples + nucleus-serving smoke: drives the decompose() facade end-to-end
# with the repo's legacy-surface DeprecationWarnings escalated to errors, so
# any in-repo code that regresses onto the deprecated per-function surface
# fails here (DESIGN.md §6).  The filter is message-scoped to the wrappers'
# "repro.core.<name> is deprecated" prefix — dependency churn emitting its
# own DeprecationWarnings must not redden this lane.
SMOKE_W = PYTHONWARNINGS="error:repro.core:DeprecationWarning"
smoke:
	JAX_PLATFORMS=cpu PYTHONPATH=src $(SMOKE_W) timeout 600 \
		python examples/quickstart.py --n 200
	JAX_PLATFORMS=cpu PYTHONPATH=src $(SMOKE_W) timeout 900 \
		python examples/graph_pipeline.py
	JAX_PLATFORMS=cpu PYTHONPATH=src $(SMOKE_W) timeout 300 \
		python -m repro.launch.serve --arch nucleus --queries 64
	JAX_PLATFORMS=cpu PYTHONPATH=src $(SMOKE_W) timeout 600 \
		python -m repro.launch.serve --arch nucleus --warm-pool \
		--pool-graphs 4 --queries 32 --r 2,2 --s 3,4
	JAX_PLATFORMS=cpu PYTHONPATH=src $(SMOKE_W) timeout 900 \
		python -m repro.launch.serve --arch nucleus --server --selftest \
		--cache-dir /tmp/nucleus-smoke-cache
