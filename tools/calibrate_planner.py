"""Measure the planner's decision crossovers and write planner_profile.json.

`python tools/calibrate_planner.py [--quick] [--out PATH]` times the real
engines on this device and records, per device (keyed by BOTH the device
kind, e.g. "TPU v4", and the platform, e.g. "cpu"):

  * ``tiny_nr`` — the compile-vs-eager crossover behind ``resolve_plan``
    rule 6.  For each size on a BA-graph ladder we time a COLD dense
    decomposition (``jax.clear_caches()`` first, so the XLA compile is
    inside the measurement — exactly the one-shot ``decompose()`` cost the
    planner is predicting) against the eager gather loop, and record the
    first ladder size where cold-dense wins.  Above the ladder top we keep
    the static fallback's spirit: dense always wins there, so the crossover
    is the ladder top + 1 only if gather won everywhere (dense never paid
    off at bench scale — pathological, but representable).
  * ``pallas_default`` — the ``use_pallas=None`` verdict: the Pallas round
    megakernel raced against the XLA round chain, steady-state (warmed,
    compile excluded), on a mid-size (2, 3) problem.  True iff the
    megakernel wins.  On CPU the kernel runs in interpret mode, so this
    honestly records False there — which is why the committed CPU profile
    keeps XLA as the default.
  * ``shard_min_incidence`` — NOT measured on a single-device host (there
    is nothing to race); the key is simply omitted so ``thresholds()``'s
    per-key fallback keeps the static constant, and the provenance string
    still says which entry fired.

The profile schema is ``planner_profile.FORMAT`` v1; the committed
``src/repro/core/planner_profile.json`` is the output of this tool on the
reference CPU container (regenerate with ``make calibrate``).  Timings are
min-of-repeats; the crossover is snapped to the ladder grid, which is
deliberate — the planner needs the right order of magnitude, not a
microbenchmark-perfect boundary.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro.core import planner_profile  # noqa: E402
from repro.core.incidence import build_problem  # noqa: E402
from repro.core.peel import exact_coreness  # noqa: E402
from repro.graph import generators  # noqa: E402

# BA ladder for the tiny_nr crossover: n_r = n vertices at (1, 2)... but
# the planner's tiny_nr guards *any* (r, s); we ladder on (2, 3) so n_r =
# edge count and the dense engine pays a representative incidence plan.
LADDER = (16, 32, 64, 128, 256, 512)
LADDER_QUICK = (16, 64, 256)


def _timed(fn, repeats):
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _ladder_problem(n_vertices: int):
    g = generators.barabasi_albert(n_vertices, 4, seed=11)
    return build_problem(g, 2, 3)


def measure_tiny_nr(quick: bool, log) -> int:
    """First ladder n_r where a cold dense decompose beats eager gather."""
    ladder = LADDER_QUICK if quick else LADDER
    repeats = 2 if quick else 3
    crossover = None
    top_nr = 0
    for n in ladder:
        p = _ladder_problem(n)
        top_nr = max(top_nr, p.n_r)

        def cold_dense():
            jax.clear_caches()
            exact_coreness(p, backend="dense", fast_lane=False)

        t_dense = _timed(cold_dense, repeats)
        t_gather = _timed(
            lambda: exact_coreness(p, backend="gather"), repeats)
        log(f"  n={n} (n_r={p.n_r}): cold dense {t_dense * 1e3:.1f} ms, "
            f"gather {t_gather * 1e3:.1f} ms")
        if t_dense <= t_gather and crossover is None:
            crossover = p.n_r
    if crossover is None:
        crossover = top_nr + 1          # dense never won at bench scale
    return int(crossover)


def measure_pallas_default(quick: bool, log) -> bool:
    """Steady-state race: Pallas round megakernel vs the XLA round chain."""
    n = 300 if quick else 1_000
    repeats = 3 if quick else 5
    g = generators.barabasi_albert(n, 6, seed=12)
    p = build_problem(g, 2, 3)

    def run(use_pallas):
        return exact_coreness(p, backend="dense", use_pallas=use_pallas,
                              fast_lane=False)

    run(True), run(False)               # warm both executables
    t_pallas = _timed(lambda: run(True), repeats)
    t_xla = _timed(lambda: run(False), repeats)
    log(f"  n_r={p.n_r}: megakernel {t_pallas * 1e3:.1f} ms, "
        f"XLA rounds {t_xla * 1e3:.1f} ms")
    return bool(t_pallas < t_xla)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="short ladder / fewer repeats (CI smoke)")
    ap.add_argument("--out", default=planner_profile.PROFILE_PATH,
                    help="profile path (default: the committed location)")
    args = ap.parse_args(argv)
    log = lambda msg: print(msg, flush=True)  # noqa: E731

    platform = jax.default_backend()
    device_kind = jax.devices()[0].device_kind
    n_devices = jax.device_count()
    log(f"calibrating planner on platform={platform!r} "
        f"device_kind={device_kind!r} n_devices={n_devices}")

    log("tiny_nr (cold dense vs eager gather):")
    tiny_nr = measure_tiny_nr(args.quick, log)
    log(f"  -> tiny_nr = {tiny_nr}")

    log("pallas_default (megakernel vs XLA round chain, steady-state):")
    pallas = measure_pallas_default(args.quick, log)
    log(f"  -> pallas_default = {pallas}")

    entry = {
        "tiny_nr": tiny_nr,
        "pallas_default": pallas,
        # shard_min_incidence deliberately absent unless we could race a
        # real multi-device shard; thresholds() falls back per-key.
        "measured": {
            "platform": platform,
            "device_kind": device_kind,
            "n_devices": n_devices,
            "quick": bool(args.quick),
        },
    }
    blob = {"format": planner_profile.FORMAT,
            "version": planner_profile.VERSION,
            "profiles": {}}
    if os.path.exists(args.out):
        try:
            with open(args.out) as f:
                old = json.load(f)
            if old.get("format") == planner_profile.FORMAT:
                blob["profiles"].update(old.get("profiles", {}))
        except (ValueError, OSError):
            pass                        # overwrite a malformed file
    # key by both names so lookup hits whichever the runtime reports first
    blob["profiles"][device_kind] = entry
    blob["profiles"][platform] = entry
    with open(args.out, "w") as f:
        json.dump(blob, f, indent=1, sort_keys=True)
        f.write("\n")
    log(f"wrote {args.out} (profiles: {sorted(blob['profiles'])})")


if __name__ == "__main__":
    main()
