"""CI gate for the memory-bounded chunked incidence builder (`make bench-build`).

Runs the chunked builder on ba4k/(2,3) with a deliberately tiny memory
budget in a fresh subprocess (benchmarks.build_child) and FAILS if:

  1. the output digest deviates from the committed golden fingerprint
     (tests/golden/build/ba4k_build_r2s3.json) — the bit-identity contract;
  2. the output digest deviates from an eager build run in the same job
     (catches the case where both builders drift together *and* apart);
  3. peak memory exceeds the budget by >20%:
       - hard on the builder's accounted intermediate peak (deterministic),
       - on the measured peak-RSS delta with an allocator slack
         (RSS_SLACK_KB) on top, since the Python/XLA allocator keeps pools
         the builder cannot see.  The slack is a constant, not a ratio, so
         a real regression still trips it.

`--regen` rewrites the golden fingerprint file (the diff is the review
artifact, same contract as `make regen-golden`).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

GRAPH, R, S = "ba4k", 2, 3
BUDGET = 8 << 20          # deliberately tiny: forces chunking on ba4k
TOLERANCE = 1.2           # the ">20%" gate
RSS_SLACK_KB = 64 << 10   # allocator pools + numpy scratch, not builder state
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
# lives under tests/golden/build/ (not tests/golden/*.json directly: the
# decomposition-fixture suite globs that directory and pins its count)
GOLDEN = os.path.join(ROOT, "tests", "golden", "build",
                      f"{GRAPH}_build_r{R}s{S}.json")


def child(build: str, budget: int | None = None) -> dict:
    sys.path.insert(0, ROOT)
    from benchmarks.build_child import run_build_child
    return run_build_child(ROOT, GRAPH, R, S, build, budget)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--regen", action="store_true",
                    help="rewrite the golden fingerprint file")
    args = ap.parse_args()

    eager = child("eager")
    chunked = child("chunked", BUDGET)
    print(f"eager:   wall={eager['wall_s']:.2f}s "
          f"accounted={eager['accounted_bytes']} digest={eager['digest'][:16]}")
    print(f"chunked: wall={chunked['wall_s']:.2f}s budget={BUDGET} "
          f"chunks={chunked['stats']['n_chunks']} "
          f"accounted={chunked['accounted_bytes']} "
          f"peak_rss_kb={chunked['peak_delta_kb']} "
          f"digest={chunked['digest'][:16]}")

    if args.regen:
        fp = {"graph": GRAPH, "r": R, "s": S, "budget": BUDGET,
              "n_r": eager["n_r"], "n_s": eager["n_s"],
              "orientation": eager["orientation"],
              "digest": eager["digest"]}
        with open(GOLDEN, "w") as f:
            json.dump(fp, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {os.path.relpath(GOLDEN, ROOT)}")

    failures = []
    with open(GOLDEN) as f:
        golden = json.load(f)
    if chunked["digest"] != golden["digest"]:
        failures.append(
            f"chunked digest {chunked['digest']} != golden "
            f"{golden['digest']} ({os.path.relpath(GOLDEN, ROOT)})")
    if chunked["digest"] != eager["digest"]:
        failures.append(
            f"chunked digest {chunked['digest']} != eager {eager['digest']}")
    limit = BUDGET * TOLERANCE
    if chunked["accounted_bytes"] > limit and \
            chunked["stats"]["chunk_size"] > 1:
        failures.append(
            f"accounted intermediate peak {chunked['accounted_bytes']}B "
            f"exceeds budget {BUDGET}B by >20%")
    rss_kb = chunked["peak_delta_kb"]
    if rss_kb > 0 and rss_kb * 1024 > limit + RSS_SLACK_KB * 1024:
        failures.append(
            f"peak-RSS delta {rss_kb}kB exceeds budget {BUDGET}B "
            f"(+20% +{RSS_SLACK_KB}kB slack)")

    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)
    if not failures:
        print("OK: chunked build is bit-identical and within the "
              "memory budget")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
