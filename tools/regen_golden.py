"""Regenerate the golden regression fixtures under tests/golden/.

Usage: PYTHONPATH=src python tools/regen_golden.py  (or `make regen-golden`)

For every (graph, r, s) cell the fixture stores the exact core numbers and,
for each distinct positive core value c, the canonicalized c-(r,s) nucleus
partition (cut of the ANH-EL hierarchy).  Values are produced by the eager
work-efficient gather backend + host trace replay — the most directly
oracle-pinned path (tests pin it against the sequential NH baseline and the
brute-force definition) — and every other backend is checked against them
by tests/test_golden.py.

Regenerate ONLY when the canonical semantics intentionally change; the diff
of the JSON files is the review artifact.
"""
from __future__ import annotations

import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.graph.generators import golden_suite, GOLDEN_RS  # noqa: E402
from repro.core import (build_problem, canonicalize_labels, decompose,
                        NucleusConfig)  # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "tests", "golden")

GRAPHS = golden_suite()
RS = GOLDEN_RS


def fixture(gname: str, r: int, s: int) -> dict:
    g = GRAPHS[gname]()
    problem = build_problem(g, r, s)
    fx = {"graph": gname, "r": r, "s": s, "n_r": problem.n_r,
          "n_s": problem.n_s, "core": [], "partitions": {}}
    if problem.n_r == 0:
        return fx
    # the oracle-pinned path through the front door: eager gather peel +
    # host trace replay (facade parity with every other backend is what
    # tests/test_golden.py + tests/test_facade.py check)
    dec = decompose(problem, NucleusConfig(r=r, s=s, method="exact",
                                           backend="gather",
                                           hierarchy="replay"))
    core = dec.core
    fx["core"] = [int(x) for x in core]
    for c in sorted(set(int(x) for x in core if x > 0)):
        labels = canonicalize_labels(dec.cut(c))
        fx["partitions"][str(c)] = [int(x) for x in labels]
    return fx


def main() -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    for gname in GRAPHS:
        for (r, s) in RS:
            fx = fixture(gname, r, s)
            path = os.path.join(OUT_DIR, f"{gname}_r{r}s{s}.json")
            with open(path, "w") as f:
                json.dump(fx, f, indent=1, sort_keys=True)
                f.write("\n")
            print(f"wrote {os.path.relpath(path)} "
                  f"(n_r={fx['n_r']}, levels={len(fx['partitions'])})")


if __name__ == "__main__":
    main()
