"""Elastic failure-recovery drill: train -> 'lose' devices -> re-mesh resume.

    PYTHONPATH=src python examples/elastic_restart.py

Simulates the 1000-node failure story on one host:
  1. train with checkpointing and a preemption guard,
  2. a 'maintenance event' (simulated SIGTERM) forces a clean drain,
  3. the job restarts on a DIFFERENT device layout (ElasticPlan recomputes
     the mesh + per-device batch), restores the checkpoint onto the new
     sharding, and the loss trajectory continues exactly where it left off.
"""
import tempfile

import numpy as np

from repro.distributed import ElasticPlan, PreemptionGuard
from repro.launch.train import train_lm


def main() -> None:
    ckpt = tempfile.mkdtemp(prefix="repro_elastic_")
    print(f"checkpoints -> {ckpt}")

    # phase 1: train until "preempted" at step 40 (ckpt every 20)
    run1 = train_lm("minicpm-2b", steps=40, smoke=True, ckpt_dir=ckpt,
                    ckpt_every=20, quiet=True)
    print(f"phase 1: {run1.steps_done} steps, "
          f"loss {run1.losses[0]:.3f} -> {run1.losses[-1]:.3f}")

    # phase 2: the cluster comes back SMALLER — re-plan the mesh
    for n_devices in (512, 384, 256):
        plan = ElasticPlan.plan(n_devices, global_batch=256,
                                model_parallel=16)
        print(f"  elastic plan @ {n_devices} chips: mesh={plan.mesh_shape} "
              f"per-device batch={plan.per_device_batch} "
              f"(global {plan.global_batch})")

    # phase 3: resume from the checkpoint (restore re-shards logical arrays
    # onto whatever mesh exists; here: the host mesh)
    run2 = train_lm("minicpm-2b", steps=80, smoke=True, ckpt_dir=ckpt,
                    ckpt_every=20, resume=True, quiet=True)
    print(f"phase 2: resumed from step {run2.restored_from}, "
          f"+{run2.steps_done} steps, final loss {run2.losses[-1]:.3f}")

    # sanity: an uninterrupted run matches the stitched trajectory
    ckpt_b = tempfile.mkdtemp(prefix="repro_elastic_ref_")
    ref = train_lm("minicpm-2b", steps=80, smoke=True, ckpt_dir=ckpt_b,
                   ckpt_every=80, quiet=True)
    drift = float(np.max(np.abs(np.asarray(ref.losses[40:])
                                - np.asarray(run2.losses))))
    print(f"trajectory drift vs uninterrupted run: {drift:.2e} "
          f"({'exact resume' if drift < 1e-4 else 'MISMATCH'})")


if __name__ == "__main__":
    main()
