"""The paper's §8.3 trade-off, interactively: approximate vs exact
decomposition — rounds (span), wall time, and coreness error vs delta.

    PYTHONPATH=src python examples/approx_vs_exact.py
"""
import time

import numpy as np

from repro.graph import generators
from repro.core import build_problem, decompose, NucleusConfig


def main() -> None:
    g = generators.barabasi_albert(3_000, 8, seed=5)
    problem = build_problem(g, 2, 3)
    print(f"graph n={g.n} m={g.m}; (2,3) decomposition, "
          f"n_r={problem.n_r}, n_s={problem.n_s}")

    cfg = NucleusConfig(r=2, s=3, backend="gather", hierarchy="none")
    t0 = time.perf_counter()
    exact = decompose(problem, cfg)
    t_exact = time.perf_counter() - t0
    e = exact.core.astype(float)
    print(f"\nexact : {exact.rounds:5d} peel rounds  {t_exact:6.2f}s  "
          f"kmax={int(e.max())}")

    for delta in (0.1, 0.5, 1.0):
        t0 = time.perf_counter()
        approx = decompose(problem, cfg, method="approx", delta=delta)
        t_a = time.perf_counter() - t0
        a = approx.core.astype(float)
        sel = e > 0
        ratio = a[sel] / e[sel]
        print(f"delta={delta:3.1f}: {approx.rounds:5d} peel rounds  "
              f"{t_a:6.2f}s  speedup={t_exact / t_a:4.1f}x  "
              f"err mean={ratio.mean():.2f} median={np.median(ratio):.2f} "
              f"max={ratio.max():.2f}")
    print("\n(rounds == the span term: on a real pod each round is one "
          "all-reduce — see repro.core.distributed)")


if __name__ == "__main__":
    main()
