"""Batched LM serving with a KV cache (continuous-wave batching).

    PYTHONPATH=src python examples/serve_lm.py --arch deepseek-v2-lite-16b

Uses the reduced config on CPU; the decode step is the same function the
decode_32k dry-run lowers for the production meshes (MLA archs decode from
the compressed-latent cache).
"""
import argparse

from repro.launch.serve import serve_lm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-v2-lite-16b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args()
    outs = serve_lm(args.arch, n_requests=args.requests, batch_slots=4,
                    prompt_len=8, gen_len=args.gen_len, smoke=True)
    print(f"first request tokens: {outs[0][:10].tolist()}")


if __name__ == "__main__":
    main()
