"""Quickstart: (r, s) nucleus decomposition + hierarchy in five minutes.

    PYTHONPATH=src python examples/quickstart.py [--r 2 --s 3]

Builds a graph with planted dense structure, computes exact coreness values,
constructs the hierarchy (interleaved single-pass ANH-EL), and walks the tree
to extract nuclei at every resolution — the paper's Figure 1 workflow.
"""
import argparse

import numpy as np

from repro.graph import generators
from repro.core import decompose, NucleusConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--r", type=int, default=2)
    ap.add_argument("--s", type=int, default=3)
    ap.add_argument("--n", type=int, default=300)
    ap.add_argument("--backend", default="auto",
                    help="a registered backend name, or 'auto' to let the "
                         "planner pick (default)")
    ap.add_argument("--hierarchy", default="auto")
    args = ap.parse_args()

    g = generators.planted_cliques(args.n, [16, 12, 9, 7], 0.02, seed=1)
    print(f"graph: n={g.n} m={g.m};  ({args.r},{args.s}) nucleus decomposition")

    # ONE call: incidence structure + peel + hierarchy; with backend="auto"
    # the registry planner picks the backend/hierarchy from the device kind
    # and problem size, and the decision rides on the artifact
    dec = decompose(g, NucleusConfig(r=args.r, s=args.s,
                                     backend=args.backend,
                                     hierarchy=args.hierarchy))
    print(dec.plan_report())
    print(f"r-cliques: {dec.n_r}, s-cliques: {dec.problem.n_s}")

    core = dec.core
    print(f"coreness: max={core.max()}  "
          f"mean={core.mean():.2f}  peel rounds={dec.rounds}")

    tree = dec.tree  # lazy: materialized from the fused forest on demand
    print(f"hierarchy: {tree.n_leaves} leaves, {tree.n_internal} internal "
          f"nodes")
    for c in sorted(set([1, int(core.max() // 2), int(core.max())])):
        nuclei = dec.nuclei(c)
        dens = sorted((nc.density, len(nc.vertices))
                      for nc in nuclei.values())[::-1][:3]
        print(f"  c={c:3d}: {len(nuclei):4d} nuclei; densest: "
              + ", ".join(f"density={d:.2f} |V|={k}" for d, k in dens))


if __name__ == "__main__":
    main()
