"""Train an LM arch end-to-end with the full production loop:
checkpointing, restore-on-restart, straggler monitoring, WSD schedule.

    PYTHONPATH=src python examples/train_lm.py --arch minicpm-2b --steps 300

On this CPU container the reduced (smoke) config runs; on a pod, drop
--smoke to train the published config (the step function and shardings are
identical — that's what the dry-run proves).
"""
import argparse
import tempfile

from repro.launch.train import train_lm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--schedule", default="wsd",
                    choices=["constant", "cosine", "wsd"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--microbatches", type=int, default=2)
    args = ap.parse_args()
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    print(f"checkpoints -> {ckpt}")
    run = train_lm(args.arch, steps=args.steps, smoke=True, ckpt_dir=ckpt,
                   ckpt_every=100, schedule=args.schedule,
                   microbatches=args.microbatches)
    print(f"trained {run.steps_done} steps: "
          f"loss {run.losses[0]:.3f} -> {run.losses[-1]:.3f}")


if __name__ == "__main__":
    main()
