"""End-to-end driver: nucleus decomposition CURATES the training graph for a
GNN — the paper's technique composed with an assigned architecture.

    PYTHONPATH=src python examples/graph_pipeline.py

Pipeline:
  1. build a noisy social-like graph with planted communities,
  2. run (2,3) nucleus decomposition + hierarchy (the paper),
  3. cut the hierarchy to keep only dense nuclei -> curated subgraph,
  4. train GIN on both raw and curated graphs on a community-recovery task,
  5. report the accuracy gain from nucleus curation.
"""
import numpy as np
import jax
import jax.numpy as jnp
from functools import partial

from repro.graph import make_graph, generators
from repro.core import decompose, NucleusConfig
from repro.models import gin
from repro.models.gnn_common import make_batch_from_arrays
from repro.optim import adamw
from repro.launch import steps as S


def make_task(seed=0, n=240, k=4):
    """k planted communities + heavy inter-community noise edges."""
    rng = np.random.default_rng(seed)
    per = n // k
    edges = []
    labels = np.zeros(n, np.int64)
    for c in range(k):
        mem = np.arange(c * per, (c + 1) * per)
        labels[mem] = c
        for _ in range(per * 6):
            u, v = rng.choice(mem, 2, replace=False)
            edges.append((u, v))
    for _ in range(n * 4):                     # noise
        u, v = rng.integers(0, n, 2)
        edges.append((u, v))
    return make_graph(n, np.asarray(edges)), labels


def train_gin(g, labels, seed=0, steps=150):
    n = g.n
    rng = np.random.default_rng(seed)
    feats = rng.standard_normal((n, 16)).astype(np.float32)
    e = np.asarray(g.edges)
    src = np.concatenate([e[:, 0], e[:, 1]]).astype(np.int32)
    dst = np.concatenate([e[:, 1], e[:, 0]]).astype(np.int32)
    cfg = gin.GINConfig(d_in=16, n_layers=3, d_hidden=32,
                        n_classes=int(labels.max()) + 1, graph_level=False)
    params = gin.init_params(jax.random.PRNGKey(seed), cfg)
    opt = adamw.init_state(params)
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=steps,
                                weight_decay=0.0)
    train_mask = (rng.random(n) < 0.3).astype(np.float32)
    batch = {"nodes": jnp.asarray(feats), "edge_src": jnp.asarray(src),
             "edge_dst": jnp.asarray(dst),
             "node_mask": jnp.ones((n,), bool),
             "edge_mask": jnp.ones_like(jnp.asarray(src), bool),
             "graph_id": jnp.arange(n, dtype=jnp.int32),
             "labels": jnp.asarray(labels, jnp.int32),
             "label_mask": jnp.asarray(train_mask)}
    step = jax.jit(partial(S.gnn_train_step, cfg=cfg, arch="gin-tu",
                           n_graphs=n, node_level=True, opt_cfg=opt_cfg))
    for _ in range(steps):
        params, opt, metrics = step(params, opt, batch)
    # eval on held-out nodes
    cfg_eval = gin.GINConfig(**{**cfg.__dict__, "graph_level": False})
    gb = make_batch_from_arrays(feats, src, dst,
                                graph_id=np.arange(n), n_graphs=n)
    logits = gin.forward(params, gb, cfg_eval)
    pred = np.asarray(jnp.argmax(logits, -1))
    test = train_mask == 0
    return float((pred[test] == labels[test]).mean())


def main() -> None:
    g, labels = make_task()
    print(f"raw graph: n={g.n} m={g.m}")

    # --- the paper: decompose, cut, curate ---------------------------------
    dec = decompose(g, NucleusConfig(r=2, s=3, backend="dense",
                                     hierarchy="two_phase"))
    kmax = int(dec.core.max())
    cut_level = max(2, kmax // 3)
    nuclei = dec.nuclei(cut_level)
    keep = np.zeros(g.n, bool)
    for nc in nuclei.values():
        keep[nc.vertices] = True
    e = np.asarray(g.edges)
    sel = keep[e[:, 0]] & keep[e[:, 1]]
    g_cur = make_graph(g.n, e[sel])
    print(f"curated:  kept {keep.sum()} / {g.n} vertices inside "
          f"{len(nuclei)} nuclei at c={cut_level}; m={g_cur.m}")

    acc_raw = train_gin(g, labels, seed=1)
    acc_cur = train_gin(g_cur, labels, seed=1)
    print(f"GIN community recovery:  raw graph acc={acc_raw:.3f}   "
          f"nucleus-curated acc={acc_cur:.3f}")


if __name__ == "__main__":
    main()
