"""The front door vs the legacy surface (DESIGN.md §6).

Three contracts:
  * PARITY — for every golden fixture and every legal NucleusConfig
    (method, backend, hierarchy) combination, ``decompose()`` produces the
    same arrays (core, rounds, trace, tree parent/level) as the legacy
    per-function composition it replaced.
  * SERIALIZATION — ``to_json()``/``from_json()`` round-trips bit-exact on
    every golden fixture, and a loaded Decomposition (no NucleusProblem)
    answers cut/nuclei queries identically.
  * DEPRECATION — every legacy package-level name still works, warns
    exactly once, and delegates unchanged.
"""
import itertools
import warnings

import numpy as np
import pytest

import repro.core as core_pkg
from repro.graph.generators import golden_suite, GOLDEN_RS
from repro.core import (build_problem, decompose, NucleusConfig,
                        Decomposition, ConfigError, make_schedule)
from repro.core.api import METHODS, BACKENDS, HIERARCHIES
from repro.core.peel import exact_coreness, approx_coreness
from repro.core.hierarchy import (build_hierarchy_levels,
                                  build_hierarchy_basic)
from repro.core.interleaved import (build_hierarchy_interleaved,
                                    construct_tree_efficient,
                                    link_state_from_forest)
from repro.core.nh_baseline import nh_coreness
from repro.core.nuclei import nucleus_vertex_sets, _nucleus_vertex_sets_loop
from repro.core.distributed import sharded_decomposition
from repro.launch.mesh import make_host_mesh

pytestmark = pytest.mark.fast

GRAPHS = golden_suite()
CELLS = [(gname, r, s) for gname in GRAPHS for (r, s) in GOLDEN_RS]
_PROBLEMS = {}


def _problem(gname, r, s):
    key = (gname, r, s)
    if key not in _PROBLEMS:
        _PROBLEMS[key] = build_problem(GRAPHS[gname](), r, s)
    return _PROBLEMS[key]


def cells():
    for (gname, r, s) in CELLS:
        yield pytest.param(gname, r, s, id=f"{gname}_r{r}s{s}")


def parity_cells():
    """Full-matrix parity runs on every cell, but only the (2, 3) column
    rides the fast push lane: each remaining cell costs two fresh engine
    compiles (approx × {plain, fused}) that the seed lane never paid, and
    the (r, s) axis is already exercised per backend by the golden tests."""
    for (gname, r, s) in CELLS:
        marks = [] if (r, s) == (2, 3) else [pytest.mark.slow]
        yield pytest.param(gname, r, s, id=f"{gname}_r{r}s{s}", marks=marks)


# ---------------------------------------------------------------------------
# Config legality
# ---------------------------------------------------------------------------

def test_legality_matrix_is_total():
    """Every (method, backend, hierarchy) triple is either legal or raises
    ConfigError — and the split matches DESIGN.md §6."""
    legal = set(NucleusConfig.legal_combinations())
    for combo in itertools.product(METHODS, BACKENDS, HIERARCHIES):
        method, backend, hierarchy = combo
        cfg = NucleusConfig(method=method, backend=backend,
                            hierarchy=hierarchy)
        if combo in legal:
            cfg.validate()
        else:
            with pytest.raises(ConfigError):
                cfg.validate()
    # the documented matrix: fused needs a compiled loop, replay needs a
    # trace, nh is exact-only
    assert ("exact", "gather", "fused") not in legal
    assert ("exact", "sharded", "replay") not in legal
    assert ("approx", "nh", "none") not in legal
    assert ("exact", "nh", "fused") not in legal
    assert len(legal) == 29


def test_config_validation_errors_are_actionable():
    with pytest.raises(ConfigError, match="1 <= r < s"):
        NucleusConfig(r=3, s=2).validate()
    with pytest.raises(ConfigError, match="no compiled loop to fuse"):
        NucleusConfig(backend="gather", hierarchy="fused").validate()
    with pytest.raises(ConfigError, match="peel trace"):
        NucleusConfig(backend="sharded", hierarchy="replay").validate()
    with pytest.raises(ConfigError, match="sequential exact baseline"):
        NucleusConfig(backend="nh", method="approx",
                      hierarchy="none").validate()
    with pytest.raises(ConfigError, match="Pallas"):
        NucleusConfig(backend="gather", hierarchy="none",
                      use_pallas=True).validate()
    with pytest.raises(ConfigError, match="delta > 0"):
        NucleusConfig(method="approx", delta=0.0).validate()
    with pytest.raises(ConfigError, match="compress"):
        NucleusConfig(compress=True).validate()
    with pytest.raises(ConfigError, match="mesh"):
        NucleusConfig(mesh=object(), backend="dense").validate()


# ---------------------------------------------------------------------------
# Parity: decompose() vs the legacy composition, full legal matrix
# ---------------------------------------------------------------------------

def _legacy_core(problem, method, backend):
    """The pre-facade way to get (core, rounds, result-or-None)."""
    if backend in ("dense", "gather"):
        peel = exact_coreness if method == "exact" else approx_coreness
        res = peel(problem, backend=backend)
        return np.asarray(res.core), int(res.rounds), res
    if backend == "sharded":
        core, rounds = sharded_decomposition(problem, make_host_mesh(),
                                             kind=method)
        return np.asarray(core), int(rounds), None
    core, rho = nh_coreness(problem)
    return np.asarray(core), int(rho), None


def _legacy_tree(problem, method, backend, hierarchy, core):
    """The pre-facade way to build each hierarchy variant."""
    if hierarchy == "two_phase":
        return build_hierarchy_levels(problem, core)
    if hierarchy == "basic":
        return build_hierarchy_basic(problem, core)
    if backend == "sharded":  # fused
        _c, _r, parent, L, raw = sharded_decomposition(
            problem, make_host_mesh(), kind=method, hierarchy=True)
        return construct_tree_efficient(
            problem, link_state_from_forest(raw, parent, L))
    return build_hierarchy_interleaved(problem, mode=method,
                                       backend=backend, link=hierarchy).tree


def _assert_same_tree(got, want, label):
    assert got.n_leaves == want.n_leaves, label
    np.testing.assert_array_equal(np.asarray(got.parent),
                                  np.asarray(want.parent),
                                  err_msg=f"{label}: tree parent")
    np.testing.assert_array_equal(np.asarray(got.level),
                                  np.asarray(want.level),
                                  err_msg=f"{label}: tree level")


def _check_combo(problem, r, s, method, backend, hierarchy):
    label = f"{method}/{backend}/{hierarchy}"
    cfg = NucleusConfig(r=r, s=s, method=method, backend=backend,
                        hierarchy=hierarchy)
    dec = decompose(problem, cfg)
    core, rounds, res = _legacy_core(problem, method, backend)
    np.testing.assert_array_equal(dec.core, core, err_msg=f"{label}: core")
    assert dec.rounds == rounds, f"{label}: rounds"
    if res is not None:
        np.testing.assert_array_equal(dec.order_round,
                                      np.asarray(res.order_round),
                                      err_msg=f"{label}: order_round")
        np.testing.assert_array_equal(dec.peel_value,
                                      np.asarray(res.peel_value),
                                      err_msg=f"{label}: peel_value")
    if hierarchy == "none":
        assert not dec.has_hierarchy
        with pytest.raises(ValueError, match="hierarchy='none'"):
            dec.tree
        return
    assert dec.has_hierarchy
    _assert_same_tree(dec.tree, _legacy_tree(problem, method, backend,
                                             hierarchy, core), label)


@pytest.mark.parametrize("gname,r,s", parity_cells())
def test_facade_parity_local_backends(gname, r, s):
    """decompose() == legacy composition for every legal dense/gather/nh
    combo, on every golden fixture (array-for-array)."""
    problem = _problem(gname, r, s)
    if problem.n_r == 0:
        pytest.skip("no r-cliques")
    for (method, backend, hierarchy) in NucleusConfig.legal_combinations():
        if backend == "sharded":
            continue  # shard_map recompiles per call: slow lane below
        _check_combo(problem, r, s, method, backend, hierarchy)


@pytest.mark.slow
@pytest.mark.parametrize(
    "gname,r,s",
    [pytest.param(g, r, s, id=f"{g}_r{r}s{s}")
     for (g, r, s) in CELLS if (r, s) == (2, 3)])
def test_facade_parity_sharded(gname, r, s):
    """Same parity statement for every legal sharded combo.  Slow lane, and
    scoped to the (2, 3) cells: every shard_map call recompiles (~seconds),
    and sharded==dense coreness/forest equality is already pinned on every
    fixture by test_golden_sharded_backend + test_distributed_core — this
    test adds the facade-vs-legacy-composition statement per combo."""
    problem = _problem(gname, r, s)
    if problem.n_r == 0:
        pytest.skip("no r-cliques")
    for (method, backend, hierarchy) in NucleusConfig.legal_combinations():
        if backend != "sharded":
            continue
        _check_combo(problem, r, s, method, backend, hierarchy)


# ---------------------------------------------------------------------------
# Serialization round-trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gname,r,s", cells())
def test_json_roundtrip_bit_exact(gname, r, s):
    problem = _problem(gname, r, s)
    if problem.n_r == 0:
        pytest.skip("no r-cliques")
    dec = decompose(problem, NucleusConfig(r=r, s=s, backend="dense",
                                           hierarchy="fused"))
    blob = dec.to_json()
    loaded = Decomposition.from_json(blob)
    assert loaded.to_json() == blob, "round-trip must be bit-exact"
    # trace fields + has_hierarchy survive (PeelResult migration contract)
    assert loaded.has_hierarchy == dec.has_hierarchy
    assert loaded.rounds == dec.rounds
    np.testing.assert_array_equal(loaded.core, dec.core)
    np.testing.assert_array_equal(loaded.order_round, dec.order_round)
    np.testing.assert_array_equal(loaded.peel_value, dec.peel_value)
    # a loaded decomposition serves queries without the problem object
    assert loaded.problem is None
    for c in sorted(set(int(x) for x in dec.core if x > 0)):
        np.testing.assert_array_equal(loaded.cut(c), dec.cut(c),
                                      err_msg=f"cut({c}) after reload")
        got = loaded.nuclei(c)
        want = dec.nuclei(c)
        assert set(got) == set(want)
        for lab in want:
            np.testing.assert_array_equal(got[lab].vertices,
                                          want[lab].vertices)
            assert got[lab].density == pytest.approx(want[lab].density,
                                                     nan_ok=True)


def test_json_rejects_foreign_blobs():
    with pytest.raises(ValueError, match="format"):
        Decomposition.from_json('{"format": "something-else"}')


def test_json_rejects_unknown_version_actionably():
    """Stale/future serving artifacts fail loudly, with the fix in the
    message (regenerate or upgrade) — never a KeyError mid-query."""
    import json as _json
    from repro.core.api import JSON_FORMAT
    dec = decompose(_problem("two_triangles", 2, 3),
                    NucleusConfig(r=2, s=3, backend="dense",
                                  hierarchy="fused"))
    d = _json.loads(dec.to_json())
    for bad in (99, "2", None):
        d["version"] = bad
        with pytest.raises(ValueError,
                           match="unsupported Decomposition version") as ei:
            Decomposition.from_json(_json.dumps(d))
        assert "regenerate" in str(ei.value)
    # a missing format key is a foreign blob, not a version problem
    with pytest.raises(ValueError, match=JSON_FORMAT):
        Decomposition.from_json("{}")


def test_json_accepts_version1_artifacts():
    """Pre-plan (version 1) artifacts still load and serve; the plan is
    simply absent."""
    import json as _json
    dec = decompose(_problem("two_triangles", 2, 3),
                    NucleusConfig(r=2, s=3, backend="dense",
                                  hierarchy="fused"))
    d = _json.loads(dec.to_json())
    d["version"] = 1
    d.pop("plan")
    loaded = Decomposition.from_json(_json.dumps(d))
    assert loaded.plan is None
    assert "not recorded" in loaded.plan_report()
    np.testing.assert_array_equal(loaded.core, dec.core)
    for c in sorted(set(int(x) for x in dec.core if x > 0)):
        np.testing.assert_array_equal(loaded.cut(c), dec.cut(c))


# ---------------------------------------------------------------------------
# Vectorized nucleus_vertex_sets parity (satellite of this refactor)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gname,r,s", cells())
def test_nucleus_vertex_sets_vectorized_parity(gname, r, s):
    problem = _problem(gname, r, s)
    if problem.n_r == 0:
        pytest.skip("no r-cliques")
    dec = decompose(problem, NucleusConfig(r=r, s=s, backend="dense",
                                           hierarchy="fused"))
    for c in sorted(set(int(x) for x in dec.core if x > 0)) or [1]:
        labels = dec.cut(c)
        got = nucleus_vertex_sets(problem, labels)
        want = _nucleus_vertex_sets_loop(problem, labels)
        assert set(got) == set(want), f"c={c}: label sets differ"
        for lab in want:
            np.testing.assert_array_equal(got[lab], want[lab],
                                          err_msg=f"c={c} label={lab}")


# ---------------------------------------------------------------------------
# Deprecated wrappers: work, warn exactly once, delegate unchanged
# ---------------------------------------------------------------------------

def test_deprecated_wrappers_warn_exactly_once():
    problem = _problem("k4", 1, 2)
    core = exact_coreness(problem).core
    tree = build_hierarchy_levels(problem, core)
    calls = {
        "exact_coreness": lambda: core_pkg.exact_coreness(problem),
        "approx_coreness": lambda: core_pkg.approx_coreness(problem),
        "dense_coreness": lambda: core_pkg.dense_coreness(
            problem, make_schedule(problem, "exact")),
        "sharded_decomposition": lambda: core_pkg.sharded_decomposition(
            problem, make_host_mesh()),
        "build_hierarchy_levels": lambda: core_pkg.build_hierarchy_levels(
            problem, core),
        "build_hierarchy_basic": lambda: core_pkg.build_hierarchy_basic(
            problem, core),
        "build_hierarchy_interleaved":
            lambda: core_pkg.build_hierarchy_interleaved(problem),
        "nh_coreness": lambda: core_pkg.nh_coreness(problem),
        "nh_hierarchy": lambda: core_pkg.nh_hierarchy(problem,
                                                      np.asarray(core)),
        "nh_full": lambda: core_pkg.nh_full(problem),
        "cut_hierarchy": lambda: core_pkg.cut_hierarchy(tree, 1),
        "nuclei_without_hierarchy":
            lambda: core_pkg.nuclei_without_hierarchy(problem, core, 1),
    }
    assert set(calls) == set(core_pkg.DEPRECATED_NAMES), \
        "every deprecated name must be exercised here"
    core_pkg._reset_deprecation_warnings()
    for name, fn in calls.items():
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            fn()   # first call: warns
            fn()   # second call: silent
        hits = [w for w in rec if issubclass(w.category, DeprecationWarning)
                and f"repro.core.{name} is deprecated" in str(w.message)]
        assert len(hits) == 1, f"{name}: expected exactly one warning, " \
                               f"got {len(hits)}"
        assert "decompose" in str(hits[0].message) or \
            "Decomposition" in str(hits[0].message), \
            f"{name}: hint must point at the facade"


def test_deprecated_wrappers_delegate_unchanged():
    problem = _problem("two_triangles", 2, 3)
    core_pkg._reset_deprecation_warnings()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = core_pkg.exact_coreness(problem)
    np.testing.assert_array_equal(np.asarray(legacy.core),
                                  np.asarray(exact_coreness(problem).core))
