"""Round-megakernel boundary suite: Pallas (interpret) vs the jnp oracle.

Mirrors the tricount boundary tests: awkward edge counts around the chunk
boundary (E = chunk_e ± 1), degenerate rounds (empty bucket, everything
dies at once), multi-block r-clique state, and the Session's padded-plan
overrides.  The kernel and ``ref.peel_round_ref`` are both pure functions
of (plan, state, level, rnd), so parity needs no graph semantics — any
consistent random state exercises them — but the full-peel test drives a
real multi-round trajectory to completion anyway (levels from the live
minimum, the way the engine's schedule does).
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.peel_round import (chunk_windows, fused_peel_round,
                                      peel_round_plan)


def _random_plan(rng, n_r, E, C, block_n, chunk_e, **overrides):
    rids = np.sort(rng.integers(0, n_r, E)).astype(np.int32)
    members = rng.integers(0, n_r, (E, C)).astype(np.int32)
    ids_p, mem_p, n_r_pad, max_chunks = peel_round_plan(
        rids, members, n_r, block_n=block_n, chunk_e=chunk_e, **overrides)
    return jnp.asarray(ids_p), jnp.asarray(mem_p), n_r_pad, max_chunks


def _random_state(rng, n_r, n_r_pad, max_deg=12):
    """Padded (deg, peeled, core, order); pad rows peeled (inert)."""
    deg = np.zeros(n_r_pad, np.int32)
    deg[:n_r] = rng.integers(0, max_deg, n_r)
    peeled = np.ones(n_r_pad, np.int32)
    peeled[:n_r] = rng.integers(0, 2, n_r)
    core = np.full(n_r_pad, -1, np.int32)
    order = np.full(n_r_pad, -1, np.int32)
    return tuple(jnp.asarray(x) for x in (deg, peeled, core, order))


def _run_both(plan, state, level, rnd, block_n, chunk_e):
    ids, mem, n_r_pad, max_chunks = plan
    c0, nch = chunk_windows(ids, n_r_pad, block_n, chunk_e, max_chunks)
    got = fused_peel_round(ids, mem, *state, jnp.int32(level),
                           jnp.int32(rnd), c0, nch, block_n=block_n,
                           chunk_e=chunk_e, max_chunks=max_chunks,
                           interpret=True)
    want = ref.peel_round_ref(ids, mem, *state, jnp.int32(level),
                              jnp.int32(rnd))
    return got, want


def _assert_rounds_equal(got, want):
    for g, w, name in zip(got, want, ("deg", "peeled", "core", "order")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=name)


@pytest.mark.parametrize("E", [63, 64, 65, 127, 128, 129, 1])
def test_peel_round_chunk_boundaries(E):
    """E = chunk_e ± 1 (and a single edge): the pad edges must stay inert."""
    rng = np.random.default_rng(E)
    block_n, chunk_e = 32, 64
    plan = _random_plan(rng, 50, E, 3, block_n, chunk_e)
    state = _random_state(rng, 50, plan[2])
    for level in (0, 3, 7):
        got, want = _run_both(plan, state, level, 2, block_n, chunk_e)
        _assert_rounds_equal(got, want)


@pytest.mark.parametrize("n_r", [31, 32, 33, 65, 96])
def test_peel_round_block_boundaries(n_r):
    """n_r = block_n ± 1 and multi-block state."""
    rng = np.random.default_rng(n_r + 100)
    block_n, chunk_e = 32, 64
    plan = _random_plan(rng, n_r, 200, 3, block_n, chunk_e)
    state = _random_state(rng, n_r, plan[2])
    got, want = _run_both(plan, state, 4, 1, block_n, chunk_e)
    _assert_rounds_equal(got, want)


def test_peel_round_empty_round_is_identity():
    """level below every live degree: nothing peels, nothing decrements."""
    rng = np.random.default_rng(7)
    block_n, chunk_e = 32, 64
    plan = _random_plan(rng, 40, 150, 3, block_n, chunk_e)
    deg, peeled, core, order = _random_state(rng, 40, plan[2])
    deg = jnp.maximum(deg, 1)            # live degrees all >= 1
    state = (deg, peeled, core, order)
    got, want = _run_both(plan, state, 0, 5, block_n, chunk_e)
    _assert_rounds_equal(got, want)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(deg))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(peeled))


def test_peel_round_all_dead_round():
    """level above every degree: the whole graph peels in one round and
    every still-alive s-clique dies."""
    rng = np.random.default_rng(8)
    block_n, chunk_e = 32, 64
    plan = _random_plan(rng, 40, 150, 4, block_n, chunk_e)
    state = _random_state(rng, 40, plan[2])
    got, want = _run_both(plan, state, 10_000, 3, block_n, chunk_e)
    _assert_rounds_equal(got, want)
    assert bool(jnp.all(got[1] == 1))    # everyone peeled
    # every row either peeled this round (core assigned) or came in peeled
    n_r = 40
    assert bool(jnp.all((got[2][:n_r] >= 0) | (state[1][:n_r] == 1)))


def test_peel_round_session_pad_overrides():
    """The Session's bucket shapes (larger e_pad / n_r_pad / max_chunks)
    must not change the answer on the real prefix."""
    rng = np.random.default_rng(9)
    block_n, chunk_e = 32, 64
    n_r, E = 45, 130
    tight = _random_plan(rng, n_r, E, 3, block_n, chunk_e)
    rng2 = np.random.default_rng(9)
    loose = _random_plan(rng2, n_r, E, 3, block_n, chunk_e,
                         e_pad=512, n_r_pad=128, max_chunks=8)
    st_t = _random_state(np.random.default_rng(10), n_r, tight[2])
    st_l = tuple(
        jnp.concatenate([x[:n_r],
                         jnp.asarray(pad_val
                                     * np.ones(loose[2] - n_r, np.int32))])
        for x, pad_val in zip(st_t, (0, 1, -1, -1)))
    got_t, want_t = _run_both(tight, st_t, 5, 2, block_n, chunk_e)
    got_l, want_l = _run_both(loose, st_l, 5, 2, block_n, chunk_e)
    _assert_rounds_equal(got_t, want_t)
    _assert_rounds_equal(got_l, want_l)
    for a, b in zip(got_t, got_l):
        np.testing.assert_array_equal(np.asarray(a)[:n_r],
                                      np.asarray(b)[:n_r])


def test_peel_round_full_trajectory():
    """Drive a full peel to completion (level = live min each round),
    checking kernel-vs-oracle parity at EVERY round — the compounding
    test: a wrong deg in round k would diverge every later round."""
    rng = np.random.default_rng(11)
    block_n, chunk_e = 32, 64
    n_r = 60
    plan = _random_plan(rng, n_r, 257, 3, block_n, chunk_e)
    ids, mem, n_r_pad, max_chunks = plan
    # consistent initial state: deg = #incident edges, nobody peeled
    deg0 = np.zeros(n_r_pad, np.int32)
    np.add.at(deg0, np.asarray(ids)[np.asarray(ids) < n_r_pad - 1], 1)
    deg = jnp.asarray(deg0)
    peeled = jnp.asarray(
        np.concatenate([np.zeros(n_r, np.int32),
                        np.ones(n_r_pad - n_r, np.int32)]))
    core = jnp.full((n_r_pad,), -1, jnp.int32)
    order = jnp.full((n_r_pad,), -1, jnp.int32)
    state = (deg, peeled, core, order)
    for rnd in range(n_r + 2):
        if bool(jnp.all(state[1] == 1)):
            break
        live = jnp.where(state[1] == 1, np.iinfo(np.int32).max, state[0])
        level = int(jnp.min(live))
        got, want = _run_both(plan, state, level, rnd, block_n, chunk_e)
        _assert_rounds_equal(got, want)
        state = got
    assert bool(jnp.all(state[1] == 1)), "peel did not terminate"
