"""The multi-tenant serve stack (DESIGN.md §11).

Contracts:
  * ROUTING — canonical configs key the pools (dead axes pinned: same
    pool for exact tenants that differ only in ``delta``); the report
    carries the embedded Plan, hit rates derived from ``Session.stats``,
    and shape buckets.
  * PARITY — artifacts produced through the concurrent Frontend (N
    submitter threads, mixed configs/buckets) are array-for-array
    identical to serial ``decompose()`` on the golden fixtures, and the
    stats counters sum exactly (no lost updates).
  * ADMISSION — over-budget graphs are rejected up front with a typed
    ``AdmissionError`` carrying the computed padded plan bytes; a full
    queue is a typed ``QueueFullError``; both are counted.
  * RESTART — a Session manifest round-trips through JSON and
    ``prewarm`` makes the first post-restart same-bucket decompose a
    warm hit (warm==1, cold==0).
  * STATUS — ``status_report`` validates against the pinned schema and
    mirrors the Session counters; drift fails naming the field.
"""
import json
import threading

import numpy as np
import pytest

from repro.core import GraphDelta, NucleusConfig, build_problem, decompose
from repro.graph import generators
from repro.graph.generators import golden_suite
from repro.launch.platform import (GPU_XLA_FLAGS, _merge_xla_flags,
                                   setup_platform)
from repro.serve import (AdmissionError, Frontend, NucleusHTTPServer,
                         QueueFullError, Request, Router, canonical_config,
                         load_manifest, padded_plan_bytes, pool_key,
                         prewarm_router, router_manifest, save_manifest,
                         status_report, validate_status)

pytestmark = pytest.mark.fast

GRAPHS = golden_suite()


def _assert_same(dec_a, dec_b, label):
    np.testing.assert_array_equal(dec_a.core, dec_b.core,
                                  err_msg=f"{label}: core")
    assert dec_a.rounds == dec_b.rounds, label
    np.testing.assert_array_equal(dec_a.peel_value, dec_b.peel_value,
                                  err_msg=f"{label}: peel_value")
    np.testing.assert_array_equal(dec_a.order_round, dec_b.order_round,
                                  err_msg=f"{label}: order_round")
    if dec_b.has_hierarchy:
        np.testing.assert_array_equal(np.asarray(dec_a.tree.parent),
                                      np.asarray(dec_b.tree.parent),
                                      err_msg=f"{label}: tree parent")
        np.testing.assert_array_equal(np.asarray(dec_a.tree.level),
                                      np.asarray(dec_b.tree.level),
                                      err_msg=f"{label}: tree level")


# ---------------------------------------------------------------------------
# Pool keying
# ---------------------------------------------------------------------------

def test_canonical_config_pins_dead_axes():
    a = NucleusConfig(r=2, s=3, method="exact", delta=0.1)
    b = NucleusConfig(r=2, s=3, method="exact", delta=0.7)
    assert pool_key(a) == pool_key(b)  # delta is dead under exact
    # ... but live under approx
    c = NucleusConfig(r=2, s=3, method="approx", delta=0.1)
    d = NucleusConfig(r=2, s=3, method="approx", delta=0.7)
    assert pool_key(c) != pool_key(d)
    assert canonical_config(b).delta == NucleusConfig().delta


def test_router_pools_by_canonical_config():
    router = Router()
    g = GRAPHS["er20"]()
    router.route(Request(graph=g, r=2, s=3, delta=0.1))
    router.route(Request(graph=g, r=2, s=3, delta=0.9))  # same pool
    router.route(Request(graph=g, r=1, s=2))             # new pool
    report = router.report()
    assert len(report["pools"]) == 2
    # the exact pool saw both requests; the second one-shape repeat is a
    # warm hit, so the hit rate reflects Session.stats exactly
    exact = next(p for p in report["pools"] if p["config"]["s"] == 3)
    assert exact["stats"]["decompositions"] == 2
    assert exact["stats"]["warm"] == 1
    assert exact["hit_rate"] == pytest.approx(0.5)
    assert exact["plan"] is not None and "backend" in exact["plan"]
    assert any("n_r_pad" in b for b in exact["buckets"])


# ---------------------------------------------------------------------------
# Concurrent parity + exact stats
# ---------------------------------------------------------------------------

def test_concurrent_frontend_parity_and_stats():
    cases = [("triangle", 1, 2), ("k4", 2, 3), ("two_triangles", 2, 3),
             ("er20", 2, 3), ("er20", 1, 2), ("planted40", 2, 3)]
    front = Frontend(Router()).start()
    try:
        results: dict = {}
        errors: list = []

        def client(idx, name, r, s):
            try:
                g = GRAPHS[name]()
                fut = front.submit(Request(graph=g, r=r, s=s,
                                           artifact=f"a{idx}"))
                results[idx] = fut.result(timeout=300)
            except Exception as e:  # pragma: no cover - surfaced below
                errors.append((idx, e))

        threads = [threading.Thread(target=client, args=(i, *case))
                   for i, case in enumerate(cases)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(300)
        assert not errors, errors
        assert len(results) == len(cases)
        for i, (name, r, s) in enumerate(cases):
            cfg = canonical_config(NucleusConfig(r=r, s=s, backend="dense",
                                                 hierarchy="fused"))
            _assert_same(results[i], decompose(GRAPHS[name](), cfg),
                         f"{name} r={r} s={s}")
        # counters sum exactly: nothing lost across threads
        stats = front.stats
        assert stats["submitted"] == len(cases)
        assert stats["served"] == len(cases)
        assert stats["failed"] == 0
        pools = front.router.report()["pools"]
        per_pool = [p["stats"] for p in pools]
        assert sum(s["decompositions"] for s in per_pool) == len(cases)
        for s in per_pool:
            assert s["warm"] + s["cold"] + s["fallback"] == \
                s["decompositions"]
    finally:
        front.stop()


# ---------------------------------------------------------------------------
# Admission control + backpressure
# ---------------------------------------------------------------------------

def test_admission_error_carries_computed_bytes():
    front = Frontend(Router(), admission_budget_bytes=16).start()
    try:
        g = GRAPHS["er20"]()
        problem = build_problem(g, 2, 3)
        with pytest.raises(AdmissionError) as ei:
            front.submit(Request(graph=g, r=2, s=3))
        assert ei.value.plan_bytes == padded_plan_bytes(problem)
        assert ei.value.budget_bytes == 16
        assert "offline" in str(ei.value)  # actionable guidance
        assert front.stats["rejected_admission"] == 1
        assert front.stats["submitted"] == 0
    finally:
        front.stop()


def test_queue_full_is_typed_backpressure():
    front = Frontend(Router(), max_queue=1)
    # no live worker draining: submissions stay queued, so the bound is
    # deterministic (submit() only checks that the frontend was started)
    front._worker = threading.current_thread()
    g = GRAPHS["triangle"]()
    front.submit(Request(graph=g, r=1, s=2))
    with pytest.raises(QueueFullError):
        front.submit(Request(graph=g, r=1, s=2))
    assert front.stats["rejected_queue"] == 1
    assert front.stats["submitted"] == 1


def test_submit_requires_started_worker():
    with pytest.raises(RuntimeError, match="start"):
        Frontend(Router()).submit(Request(graph=GRAPHS["triangle"](),
                                          r=1, s=2))


# ---------------------------------------------------------------------------
# Manifest round-trip + restart prewarm
# ---------------------------------------------------------------------------

def test_manifest_prewarm_restart(tmp_path):
    router = Router()
    g = generators.planted_cliques(40, [8, 6, 5], 0.05, seed=3)
    router.route(Request(graph=g, r=2, s=3))
    save_manifest(router, str(tmp_path))
    manifest = load_manifest(str(tmp_path))
    assert manifest is not None

    # "restart": a fresh router prewarmed from the serialized manifest;
    # the first same-bucket decompose must be a warm hit
    restarted = Router()
    assert prewarm_router(restarted, manifest) == 1
    g2 = generators.planted_cliques(42, [8, 6, 5], 0.05, seed=4)
    dec = restarted.route(Request(graph=g2, r=2, s=3))
    stats = restarted.report()["pools"][0]["stats"]
    assert stats["warm"] == 1
    assert stats["cold"] == 0
    assert stats["prewarmed"] == 1
    # and the prewarmed executable computes the same arrays as serial
    _assert_same(dec, decompose(g2, NucleusConfig(
        r=2, s=3, backend="dense", hierarchy="fused")), "restart parity")


def test_manifest_rejects_wrong_format(tmp_path):
    p = tmp_path / "session_manifest.json"
    p.write_text(json.dumps({"format": "something-else", "pools": []}))
    with pytest.raises(ValueError, match="format"):
        load_manifest(str(tmp_path))
    assert load_manifest(str(tmp_path / "missing")) is None


def test_router_manifest_shape():
    router = Router()
    router.route(Request(graph=GRAPHS["er20"](), r=2, s=3))
    m = router_manifest(router)
    assert m["pools"] and m["pools"][0]["buckets"]
    entry = m["pools"][0]["buckets"][0]
    for key in ("method", "r", "s", "fused", "n_r_pad", "n_s_pad",
                "schedule"):
        assert key in entry, key
    # JSON-serializable end to end (what save_manifest writes)
    json.dumps(m)


# ---------------------------------------------------------------------------
# Named live artifacts
# ---------------------------------------------------------------------------

def test_named_artifact_update_versioning():
    router = Router()
    g = GRAPHS["two_triangles"]()
    dec = router.route(Request(graph=g, r=2, s=3, artifact="live"))
    assert dec.name == "live" and dec.version == 0
    new = router.update("live", GraphDelta(insert=np.array([[0, 4]])))
    assert new.name == "live" and new.version == 1
    assert router.artifact("live") is new
    # versions survive the JSON round-trip
    from repro.core.api import Decomposition
    back = Decomposition.from_json(new.to_json())
    assert back.name == "live" and back.version == 1
    with pytest.raises(KeyError, match="no live artifact"):
        router.artifact("ghost")


# ---------------------------------------------------------------------------
# Status schema
# ---------------------------------------------------------------------------

def test_status_report_matches_schema_and_stats():
    front = Frontend(Router()).start()
    try:
        front.submit_wait(Request(graph=GRAPHS["er20"](), r=2, s=3,
                                  artifact="a"))
        front.submit_wait(Request(graph=GRAPHS["er20"](), r=2, s=3))
        status = validate_status(status_report(front))
        assert status["frontend"]["served"] == 2
        pool = status["pools"][0]
        assert pool["stats"]["decompositions"] == 2
        assert pool["hit_rate"] == pytest.approx(0.5)
        # builder telemetry rides the pool row (eager builds carry it too)
        assert pool["build"] is not None
        assert pool["build"]["build"] == "eager"
        assert status["artifacts"]["a"]["version"] == 0
        assert status["queue_depth"] == 0
    finally:
        front.stop()


def test_status_report_sharded_build_telemetry():
    """A sharded-build request surfaces the distbuild chunk/skew/exchange
    stats in its pool row and the schema accepts them."""
    front = Frontend(Router()).start()
    try:
        front.submit_wait(Request(graph=GRAPHS["er20"](), r=2, s=3,
                                  build="sharded", build_shards=4))
        status = validate_status(status_report(front))
        build = status["pools"][0]["build"]
        assert build["build"] == "sharded"
        assert build["n_shards"] == 4
        assert len(build["chunks_per_shard"]) == 4
        assert build["skew"] >= 1.0
        assert build["exchange_bytes"] >= 0
        json.dumps(status)  # the whole report must stay JSON-serializable
    finally:
        front.stop()


def test_validate_status_names_the_drifted_field():
    front = Frontend(Router()).start()
    try:
        status = status_report(front)
        del status["frontend"]["served"]
        with pytest.raises(ValueError, match="frontend.served"):
            validate_status(status)
        status = status_report(front)
        status["format"] = "nope"
        with pytest.raises(ValueError, match="format"):
            validate_status(status)
    finally:
        front.stop()


# ---------------------------------------------------------------------------
# HTTP surface
# ---------------------------------------------------------------------------

def _post(host, port, route, payload, timeout=300):
    import urllib.request
    req = urllib.request.Request(
        f"http://{host}:{port}{route}", data=json.dumps(payload).encode(),
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req, timeout=timeout) as resp:
        return json.loads(resp.read())


def test_httpd_end_to_end():
    import urllib.error
    import urllib.request

    server = NucleusHTTPServer(Frontend(Router()))
    host, port = server.start()
    try:
        g = GRAPHS["two_triangles"]()
        art = _post(host, port, "/decompose",
                    {"n": g.n, "edges": np.asarray(g.edges).tolist(),
                     "r": 2, "s": 3, "artifact": "web"})
        assert art["artifact"] == "web" and art["version"] == 0
        assert art["plan"] and "backend" in art["plan"]
        cut = _post(host, port, "/query",
                    {"artifact": "web", "kind": "cut", "c": 1})
        assert len(cut["cut"]) == art["n_r"]
        upd = _post(host, port, "/update",
                    {"artifact": "web", "insert": [[0, 4]]})
        assert upd["version"] == 1
        with urllib.request.urlopen(
                f"http://{host}:{port}/status", timeout=300) as resp:
            status = validate_status(json.loads(resp.read()))
        assert status["artifacts"]["web"]["version"] == 1
        # typed rejections map to HTTP codes
        with pytest.raises(urllib.error.HTTPError) as ei:
            _post(host, port, "/query",
                  {"artifact": "ghost", "kind": "cut", "c": 1})
        assert ei.value.code == 404
        with pytest.raises(urllib.error.HTTPError) as ei:
            _post(host, port, "/decompose", {"n": 3})  # no edges
        assert ei.value.code == 400
    finally:
        server.stop()


def test_httpd_admission_maps_to_413():
    import urllib.error

    server = NucleusHTTPServer(
        Frontend(Router(), admission_budget_bytes=16))
    host, port = server.start()
    try:
        g = GRAPHS["er20"]()
        with pytest.raises(urllib.error.HTTPError) as ei:
            _post(host, port, "/decompose",
                  {"n": g.n, "edges": np.asarray(g.edges).tolist(),
                   "r": 2, "s": 3})
        assert ei.value.code == 413
        body = json.loads(ei.value.read())
        assert body["plan_bytes"] > body["budget_bytes"] == 16
    finally:
        server.stop()


# ---------------------------------------------------------------------------
# Platform setup
# ---------------------------------------------------------------------------

def test_merge_xla_flags_operator_wins(monkeypatch):
    monkeypatch.setenv(
        "XLA_FLAGS", "--xla_gpu_enable_async_collectives=false")
    merged = _merge_xla_flags(GPU_XLA_FLAGS)
    # the operator's value survives; missing flags are appended
    assert "--xla_gpu_enable_async_collectives=false" in merged
    assert merged.count("--xla_gpu_enable_async_collectives") == 1
    assert "--xla_gpu_enable_latency_hiding_scheduler=true" in merged


def test_setup_platform_clamps_cpu_devices(monkeypatch):
    monkeypatch.setenv("XLA_FLAGS", "")
    with pytest.warns(RuntimeWarning, match="cores"):
        applied = setup_platform(cpu_devices=1_000_000)
    import os
    assert applied["cpu_devices"] == (os.cpu_count() or 1)
    assert "--xla_force_host_platform_device_count" in applied["xla_flags"]


def test_setup_platform_noop_by_default(monkeypatch):
    monkeypatch.delenv("XLA_FLAGS", raising=False)
    applied = setup_platform()
    assert applied == {"platform": None, "cpu_devices": None,
                       "enable_x64": None, "xla_flags": None}
    assert "XLA_FLAGS" not in __import__("os").environ
