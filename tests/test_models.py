"""Model-zoo correctness: decode consistency, equivariance, MoE invariants."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models import (transformer as T, gin, egnn, dimenet, mace, din,
                          TransformerConfig, MoEConfig, MLAConfig,
                          make_batch_from_arrays, build_triplets)
from repro.data import synthetic_molecules


def _gqa_cfg(**kw):
    base = dict(name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                d_ff=64, vocab=53, attn_chunk=8, dtype=jnp.float32,
                remat=False)
    base.update(kw)
    return TransformerConfig(**base)


@pytest.mark.parametrize("variant", ["gqa", "mha", "mla", "moe", "moe_mla"])
def test_decode_matches_forward(variant):
    kw = {}
    if variant == "mha":
        kw = dict(n_kv_heads=4)
    if variant == "mla":
        kw = dict(n_kv_heads=4, mla=MLAConfig(kv_lora_rank=12,
                                              rope_head_dim=4))
    if variant == "moe":
        kw = dict(moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=32,
                                n_shared=1, capacity_factor=4.0))
    if variant == "moe_mla":
        kw = dict(n_kv_heads=4,
                  moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=32,
                                n_shared=1, capacity_factor=4.0),
                  mla=MLAConfig(kv_lora_rank=12, rope_head_dim=4))
    cfg = _gqa_cfg(**kw)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, cfg.vocab)
    full = T.forward(params, toks, cfg)
    cache = T.init_cache(cfg, 2, 12, dtype=jnp.float32)
    clen = jnp.int32(0)
    outs = []
    for i in range(10):
        lg, cache, clen = T.decode_step(params, toks[:, i:i + 1], cache,
                                        clen, cfg)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=3e-4,
                               rtol=3e-4)


def test_causality():
    """Changing future tokens must not change past logits."""
    cfg = _gqa_cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    t1 = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab)
    t2 = t1.at[0, 8:].set((t1[0, 8:] + 1) % cfg.vocab)
    l1 = T.forward(params, t1, cfg)
    l2 = T.forward(params, t2, cfg)
    np.testing.assert_allclose(np.asarray(l1[:, :8]), np.asarray(l2[:, :8]),
                               atol=1e-5)
    assert float(jnp.max(jnp.abs(l1[:, 8:] - l2[:, 8:]))) > 1e-4


def test_moe_capacity_and_routing():
    """MoE output must match a dense per-token expert evaluation when
    capacity is unconstrained."""
    cfg_moe = MoEConfig(n_experts=4, top_k=2, d_ff_expert=16, n_shared=0,
                        capacity_factor=8.0)
    key = jax.random.PRNGKey(3)
    d, Tn, E = 8, 32, 4
    params = {
        "router": jax.random.normal(key, (d, E)),
        "w1": jax.random.normal(jax.random.fold_in(key, 1), (E, d, 16)) * 0.2,
        "w2": jax.random.normal(jax.random.fold_in(key, 2), (E, 16, d)) * 0.2,
        "w3": jax.random.normal(jax.random.fold_in(key, 3), (E, d, 16)) * 0.2,
    }
    x = jax.random.normal(jax.random.fold_in(key, 4), (Tn, d))
    got = T.moe_block(x, params, cfg_moe)
    # dense oracle
    logits = x @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, eidx = jax.lax.top_k(probs, 2)
    gate = gate / gate.sum(-1, keepdims=True)
    want = jnp.zeros_like(x)
    for t in range(Tn):
        acc = jnp.zeros((d,))
        for j in range(2):
            e = int(eidx[t, j])
            h = jax.nn.silu(x[t] @ params["w1"][e]) * (x[t] @ params["w3"][e])
            acc = acc + gate[t, j] * (h @ params["w2"][e])
        want = want.at[t].set(acc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5,
                               rtol=1e-4)


def test_moe_drops_beyond_capacity():
    cfg_moe = MoEConfig(n_experts=2, top_k=1, d_ff_expert=8, n_shared=0,
                        capacity_factor=0.5)  # tight capacity
    key = jax.random.PRNGKey(4)
    d, Tn = 4, 16
    params = {
        "router": jnp.zeros((d, 2)).at[:, 0].set(10.0),  # all -> expert 0
        "w1": jnp.ones((2, d, 8)) * 0.1,
        "w2": jnp.ones((2, 8, d)) * 0.1,
        "w3": jnp.ones((2, d, 8)) * 0.1,
    }
    # positive activations => positive router logit => ALL tokens pick e0
    x = jnp.abs(jax.random.normal(key, (Tn, d))) + 0.1
    out = T.moe_block(x, params, cfg_moe)
    # capacity = ceil(16 * 1 / 2 * 0.5) = 4 tokens survive
    nonzero_rows = int(jnp.sum(jnp.any(jnp.abs(out) > 1e-9, axis=1)))
    assert nonzero_rows == 4, nonzero_rows


# ---------------------------------------------------------------------------
# GNN equivariance properties
# ---------------------------------------------------------------------------

def _random_rotation(seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((3, 3))
    q, r = np.linalg.qr(a)
    q = q @ np.diag(np.sign(np.diag(r)))
    if np.linalg.det(q) < 0:
        q[:, 0] = -q[:, 0]
    return jnp.asarray(q, jnp.float32)


def _mol_batch(seed=0, cap=None):
    m = synthetic_molecules(4, 8, 16, 8, seed=seed, triplet_cap=cap)
    return m


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_egnn_equivariance(seed):
    m = _mol_batch(seed)
    cfg = egnn.EGNNConfig(d_in=8, n_layers=2, d_hidden=16)
    params = egnn.init_params(jax.random.PRNGKey(seed), cfg)
    R = _random_rotation(seed)
    t = jnp.asarray([1.5, -2.0, 0.5])

    def run(pos):
        b = make_batch_from_arrays(m["nodes"], m["edge_src"], m["edge_dst"],
                                   pos=pos, graph_id=m["graph_id"],
                                   n_graphs=m["n_graphs"])
        return egnn.forward(params, b, cfg)

    out1, x1 = run(jnp.asarray(m["pos"]))
    out2, x2 = run(jnp.asarray(m["pos"]) @ R.T + t)
    # invariant outputs, equivariant coordinates
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-3,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(x1 @ R.T + t), np.asarray(x2),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_mace_equivariance(seed):
    m = _mol_batch(seed)
    cfg = mace.MACEConfig(d_in=8, n_layers=2, d_hidden=8, n_rbf=4)
    params = mace.init_params(jax.random.PRNGKey(seed), cfg)
    R = _random_rotation(seed + 10)
    t = jnp.asarray([0.3, 0.1, -0.7])

    def run(pos):
        b = make_batch_from_arrays(m["nodes"], m["edge_src"], m["edge_dst"],
                                   pos=pos, graph_id=m["graph_id"],
                                   n_graphs=m["n_graphs"])
        return mace.forward(params, b, cfg)

    e1, f1 = run(jnp.asarray(m["pos"]))
    e2, f2 = run(jnp.asarray(m["pos"]) @ R.T + t)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), atol=2e-3,
                               rtol=2e-3)  # invariant energy
    # vector features rotate: v' = v @ R.T
    np.testing.assert_allclose(np.asarray(f1["v"] @ R.T), np.asarray(f2["v"]),
                               atol=2e-3, rtol=2e-3)
    # rank-2 features conjugate: t' = R t R^T
    want_t = jnp.einsum("ab,ncbd,ed->ncae", R, f1["t"], R)
    np.testing.assert_allclose(np.asarray(want_t), np.asarray(f2["t"]),
                               atol=2e-3, rtol=2e-3)


def test_dimenet_rototranslation_invariance(seed=0):
    m = _mol_batch(seed, cap=8)
    cfg = dimenet.DimeNetConfig(d_in=8, n_blocks=2, d_hidden=16,
                                n_bilinear=2, n_spherical=3, n_radial=3)
    params = dimenet.init_params(jax.random.PRNGKey(seed), cfg)
    R = _random_rotation(seed + 20)

    def run(pos):
        b = make_batch_from_arrays(m["nodes"], m["edge_src"], m["edge_dst"],
                                   pos=pos, graph_id=m["graph_id"],
                                   n_graphs=m["n_graphs"],
                                   triplets=tuple(jnp.asarray(t)
                                                  for t in m["triplets"]))
        return dimenet.forward(params, b, cfg)

    e1 = run(jnp.asarray(m["pos"]))
    e2 = run(jnp.asarray(m["pos"]) @ R.T + 3.0)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), atol=1e-3,
                               rtol=1e-3)


def test_gin_permutation_invariance():
    rng = np.random.default_rng(0)
    N, E, F = 10, 30, 8
    nodes = rng.standard_normal((N, F)).astype(np.float32)
    src = rng.integers(0, N, E).astype(np.int32)
    dst = rng.integers(0, N, E).astype(np.int32)
    cfg = gin.GINConfig(d_in=F, n_layers=2, d_hidden=16, n_classes=3)
    params = gin.init_params(jax.random.PRNGKey(0), cfg)
    b1 = make_batch_from_arrays(nodes, src, dst)
    out1 = gin.forward(params, b1, cfg)
    perm = rng.permutation(N)
    inv = np.argsort(perm)
    b2 = make_batch_from_arrays(nodes[perm], inv[src], inv[dst])
    out2 = gin.forward(params, b2, cfg)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-4,
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# DIN / EmbeddingBag
# ---------------------------------------------------------------------------

def test_embedding_bag_matches_loop():
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.standard_normal((50, 8)), jnp.float32)
    ids = jnp.asarray([3, 4, 7, 0, 1, 2, 9], jnp.int32)
    offsets = jnp.asarray([0, 3, 3, 5], jnp.int32)   # bags: [0:3),[3:3),[3:5),[5:7)
    out = din.embedding_bag(table, ids, offsets, 4)
    want = np.stack([
        np.asarray(table)[[3, 4, 7]].sum(0),
        np.zeros(8),
        np.asarray(table)[[0, 1]].sum(0),
        np.asarray(table)[[2, 9]].sum(0),
    ])
    np.testing.assert_allclose(np.asarray(out), want, atol=1e-6)


def test_din_retrieval_matches_forward():
    """score_candidates(cand batch) == forward() on each candidate."""
    cfg = din.DINConfig(name="t", embed_dim=8, seq_len=6, attn_mlp=(8, 4),
                        mlp=(12, 6), n_items=100, n_cates=10,
                        n_user_feats=20)
    params = din.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    hist = rng.integers(0, 100, 6).astype(np.int32)
    cands = rng.integers(0, 100, 5).astype(np.int32)
    rbatch = {"hist_items": jnp.asarray(hist),
              "hist_cates": jnp.asarray(hist % 10),
              "user_id": jnp.asarray(3, jnp.int32),
              "cand_items": jnp.asarray(cands),
              "cand_cates": jnp.asarray(cands % 10)}
    scores = din.score_candidates(params, rbatch, cfg)
    fbatch = {"hist_items": jnp.asarray(np.tile(hist, (5, 1))),
              "hist_cates": jnp.asarray(np.tile(hist % 10, (5, 1))),
              "cand_item": jnp.asarray(cands),
              "cand_cate": jnp.asarray(cands % 10),
              "user_id": jnp.full((5,), 3, jnp.int32)}
    want = din.forward(params, fbatch, cfg)
    np.testing.assert_allclose(np.asarray(scores), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_din_padding_ignored():
    cfg = din.DINConfig(name="t", embed_dim=8, seq_len=6, attn_mlp=(8, 4),
                        mlp=(12, 6), n_items=100, n_cates=10,
                        n_user_feats=20)
    params = din.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    h1 = rng.integers(0, 100, (2, 6)).astype(np.int32)
    h1[:, 4:] = -1
    h2 = h1.copy()
    h2[:, 4:] = 55  # different garbage behind the pad...
    h2[:, 4:] = -1  # ...must stay -1; instead vary cates behind pads
    base = {"cand_item": jnp.asarray([1, 2], jnp.int32),
            "cand_cate": jnp.asarray([1, 2], jnp.int32),
            "user_id": jnp.asarray([0, 1], jnp.int32)}
    o1 = din.forward(params, {**base, "hist_items": jnp.asarray(h1),
                              "hist_cates": jnp.asarray(h1 % 10)}, cfg)
    o2 = din.forward(params, {**base, "hist_items": jnp.asarray(h2),
                              "hist_cates": jnp.asarray(h2 % 10)}, cfg)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)
