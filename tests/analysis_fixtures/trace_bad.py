"""NL1xx fixture: host syncs + Python control flow inside traced bodies.

Deliberately-bad snippets for tests/test_analysis.py — each violation's
line number is pinned there, so KEEP LINE NUMBERS STABLE (append only).
This file is never imported or executed.
"""
import jax
import jax.numpy as jnp
import numpy as np
from functools import partial


@jax.jit
def sync_in_jit(x):
    flag = bool(x)                      # line 15: NL101 bool()
    val = x.item()                      # line 16: NL101 .item()
    host = np.asarray(x)                # line 17: NL101 np.asarray()
    n = len(x)                          # line 18: NL103 len()
    if x > 0:                           # line 19: NL102 if
        return x + n
    return x * (flag + val + host.sum())


def outer(a, b):
    def body(carry):
        i, acc = carry
        while acc < 10:                 # line 27: NL102 while (loop body)
            acc = acc + 1
        return (i + 1, acc)

    def cond(carry):
        return carry[0] < 8

    return jax.lax.while_loop(cond, body, (a, b))


@partial(jax.jit, static_argnames=("k",))
def statics_are_clean(x, k):
    # k is declared static: branching on it is legal, no finding here
    if k > 2:
        return x * k
    n = x.shape[0]
    if n > 4:                           # shape access is static: clean
        return x[: n // 2]
    return x


@jax.jit
def suppressed_sync(x):
    return bool(x)                      # nucleuslint: disable=NL101
