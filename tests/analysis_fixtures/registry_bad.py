"""NL4xx fixture: a registered backend touching an undeclared knob.

Line numbers are pinned in tests/test_analysis.py — KEEP THEM STABLE
(append only).  Never imported or executed; register/_Registered/
BackendCapabilities are matched structurally by the rule, not imported.
"""


def register(backend):
    return backend


class _Registered:
    pass


class BackendCapabilities:
    pass


def _run_shared(problem, config):
    if config.compress:                  # line 22: NL401 via helper
        return problem
    return problem


def _run_quiet(problem, config):
    # reads only its declared knob: clean
    return _run_shared(problem, config) if config.mesh else problem


def _run_loud(problem, config):
    if config.use_pallas:                # line 33: NL401 undeclared
        return problem
    return problem


register(_Registered(
    name="quiet",
    capabilities=BackendCapabilities(knobs=frozenset({"mesh"})),
    _run=_run_quiet))

register(_Registered(
    name="loud",
    capabilities=BackendCapabilities(knobs=frozenset()),
    _run=_run_loud))
