"""NL3xx fixture (named serve/frontend.py so the single-writer rule
applies).  Line numbers are pinned in tests/test_analysis.py — KEEP THEM
STABLE (append only).  Never imported or executed.
"""
import threading


class Frontend:
    def __init__(self, router):
        self.router = router
        self._stats_lock = threading.Lock()
        self.stats = {"served": 0}
        self.queue_depth = 0            # __init__ writes are exempt

    def _count(self, name, by=1):
        with self._stats_lock:
            self.stats[name] += by      # seeds the guard convention

    def unguarded(self):
        self.stats["served"] = 0        # line 20: NL301 no lock held

    def submit(self, request):
        dec = self.router.route_many([request])   # line 23: NL302
        self._count("served")
        return dec

    def _run(self):
        # the worker thread may drive the engine: no finding here
        return self.router.route_many([])

    def _serve_batch(self, batch):
        self.router.update("a", None)   # worker method: clean
        with self._stats_lock:
            self.stats["served"] += len(batch)
