"""NL2xx fixture (named core/session.py so the warm-path key rule
applies).  Line numbers are pinned in tests/test_analysis.py — KEEP THEM
STABLE (append only).  Never imported or executed.
"""
import os
import time
from functools import partial

import jax


def run_per_call(fn, x):
    step = jax.jit(fn)                  # line 13: NL201 jit per call
    return step(x)


@jax.jit
def bakes_time(x):
    return x + time.time()              # line 19: NL202 traced capture


def bucket_key(problem):
    salt = os.getenv("SALT")            # line 23: NL202 warm-path key
    return (problem.n_s, salt)


@partial(jax.jit, static_argnames=("spec",))
def bad_static_default(x, spec=[1, 2]):  # line 28: NL203 mutable default
    return x


def caller(x):
    return bad_static_default(x, spec=[3, 4])   # line 33: NL203 literal
