"""nucleuslint: golden-finding fixtures, suppression/baseline round-trip,
and the clean-run-modulo-baseline gate (ISSUE 9 / DESIGN.md §12).

The fixture files under tests/analysis_fixtures/ pin (rule, line) pairs:
each rule family must catch its deliberately-bad snippet at exactly the
recorded location, and the clean snippets (static args, shape access,
worker methods, __init__ writes, declared knobs) must stay finding-free.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import pytest

from repro.analysis import (Finding, apply_baseline, dead_module_report,
                            load_baseline, load_project, run_analysis,
                            write_baseline)
from repro.analysis.baseline import DEFAULT_BASELINE
from repro.analysis.findings import parse_suppressions

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(REPO, "tests", "analysis_fixtures")


def fixture_findings(*names):
    paths = [os.path.join(FIXTURES, n) for n in names] if names \
        else [FIXTURES]
    project = load_project(paths, root=REPO)
    return run_analysis(project)


def pairs(findings):
    return sorted((f.rule, f.line) for f in findings)


# ---------------------------------------------------------------------------
# golden findings: one fixture per rule family, pinned rule ids + lines
# ---------------------------------------------------------------------------

def test_trace_family_catches_fixture():
    got = pairs(fixture_findings("trace_bad.py"))
    assert got == [
        ("NL101", 15),   # bool()
        ("NL101", 16),   # .item()
        ("NL101", 17),   # np.asarray()
        ("NL102", 19),   # if on traced
        ("NL102", 27),   # while inside lax.while_loop body
        ("NL103", 18),   # len() on traced
    ]


def test_recompile_family_catches_fixture():
    got = pairs(fixture_findings(os.path.join("core", "session.py")))
    assert got == [
        ("NL201", 13),   # jax.jit per call
        ("NL202", 19),   # time.time() baked into a trace
        ("NL202", 23),   # os.getenv in a warm-path key function
        ("NL203", 28),   # mutable default on a static param
        ("NL203", 33),   # unhashable literal at a call site
    ]


def test_concurrency_family_catches_fixture():
    got = pairs(fixture_findings(os.path.join("serve", "frontend.py")))
    assert got == [
        ("NL301", 20),   # unguarded write to a lock-guarded attribute
        ("NL302", 23),   # engine entry outside the worker
    ]


def test_registry_family_catches_fixture():
    got = pairs(fixture_findings("registry_bad.py"))
    assert got == [
        ("NL401", 22),   # undeclared knob read via forwarded helper
        ("NL401", 33),   # undeclared knob read in the adapter itself
    ]


def test_clean_snippets_stay_clean():
    """The negative space is as load-bearing as the positives: statics,
    shape access, __init__ writes, worker methods, declared knobs."""
    findings = fixture_findings()
    msgs = [f.message for f in findings]
    assert not any("statics_are_clean" in m for m in msgs)
    assert not any("suppressed_sync" in m for m in msgs)
    assert not any("_run_quiet" in m and "mesh" in m for m in msgs)
    by_rule_file = {(f.rule, f.path, f.line) for f in findings}
    # __init__ writes and worker-method engine calls never fire
    assert all(l not in (13, 29, 32)
               for r, p, l in by_rule_file if r in ("NL301", "NL302")
               and p.endswith("serve/frontend.py"))


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

def test_inline_suppression_silences_finding():
    # trace_bad.py's suppressed_sync has a bool() with an inline disable:
    # it must NOT appear (covered above), while the same pattern without
    # the comment (line 15) does.
    got = pairs(fixture_findings("trace_bad.py"))
    assert ("NL101", 15) in got
    assert all(line < 40 for _r, line in got)


def test_suppression_parser_semantics():
    sup = parse_suppressions([
        "x = 1",
        "y = 2  # nucleuslint: disable=NL101,NL102",
        "z = 3",
        "# nucleuslint: disable=all",
        "w = 4",
    ])
    assert sup[2] == frozenset({"NL101", "NL102"})
    assert sup[3] == frozenset({"NL101", "NL102"})   # next-line coverage
    assert sup[4] == frozenset({"all"}) and sup[5] == frozenset({"all"})
    assert 1 not in sup


# ---------------------------------------------------------------------------
# baseline round-trip
# ---------------------------------------------------------------------------

def test_baseline_round_trip(tmp_path):
    findings = fixture_findings("trace_bad.py")
    assert findings
    path = str(tmp_path / "baseline.json")
    write_baseline(findings, path)
    baseline = load_baseline(path)
    new, stale = apply_baseline(findings, baseline)
    assert new == [] and stale == []
    # a novel finding is NOT absorbed
    extra = Finding(path="x.py", line=1, col=0, rule="NL101",
                    message="novel", hint="")
    new, _ = apply_baseline(findings + [extra], baseline)
    assert new == [extra]
    # fixing one of two identical findings frees a slot -> stale entry
    dup = [findings[0], findings[0]]
    write_baseline(dup, path)
    new, stale = apply_baseline([findings[0]], load_baseline(path))
    assert new == [] and stale == [findings[0].key]


def test_baseline_rejects_foreign_json(tmp_path):
    path = tmp_path / "not_baseline.json"
    path.write_text(json.dumps({"format": "something-else"}))
    with pytest.raises(ValueError):
        load_baseline(str(path))


def test_missing_baseline_is_empty(tmp_path):
    assert load_baseline(str(tmp_path / "absent.json")) == {}


# ---------------------------------------------------------------------------
# the gate: src/repro is clean modulo the committed baseline
# ---------------------------------------------------------------------------

def test_src_repro_clean_modulo_committed_baseline():
    project = load_project([os.path.join(REPO, "src", "repro")], root=REPO)
    findings = run_analysis(project)
    baseline = load_baseline(os.path.join(REPO, DEFAULT_BASELINE))
    new, _stale = apply_baseline(findings, baseline)
    assert new == [], "new nucleuslint findings:\n" + "\n".join(
        f.render() for f in new)


def test_cli_gate_matches_library(tmp_path):
    """`python -m repro.analysis` (what make lint-nucleus runs) exits 0
    on the committed baseline and writes well-formed JSON."""
    out = tmp_path / "findings.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "src/repro",
         "--json", str(out)],
        cwd=REPO, capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")})
    assert proc.returncode == 0, proc.stdout + proc.stderr
    blob = json.loads(out.read_text())
    assert blob["tool"] == "nucleuslint" and blob["n_new"] == 0


# ---------------------------------------------------------------------------
# dead-module report
# ---------------------------------------------------------------------------

def test_dead_module_report_shape():
    os.chdir(REPO)
    rep = dead_module_report("src")
    assert rep["n_modules"] > 50
    assert rep["n_reachable"] <= rep["n_modules"]
    # the nucleus product reaches the engine...
    assert "repro.core.engine" not in rep["dead"]
    assert "repro.serve.frontend" not in rep["dead"]
    # ...and this test file importing repro.analysis keeps the linter
    # itself alive under the spec roots (tests count)
    assert "repro.analysis.driver" not in rep["dead"]
    # the nucleus-only view surfaces the LLM-era lanes
    assert "repro.launch.train" in rep["nucleus_unreachable"]
    assert any("repro.configs" in m for m in rep["nucleus_unreachable"])
    # every dead entry maps to a real file (report only, no deletions)
    for p in rep["dead_paths"]:
        assert os.path.exists(os.path.join(REPO, p))
