"""The warm ``Session`` front door (DESIGN.md §8).

Contracts:
  * PARITY — ``Session(cfg).decompose(p)`` is array-for-array identical to
    ``decompose(p, cfg)`` (core, rounds, trace, forest, tree) on every
    golden fixture, for exact and approximate peels: the shape padding
    (ghost s-rows + pre-peeled ghost r-cliques) and the schedule
    canonicalization are behaviour-invisible.
  * BUCKETS — similar-but-distinct shapes land in one shape class
    (``stats`` shows warm hits), the padding helpers hit the documented
    boundaries, and canonicalized schedules preserve the approx round cap.
  * FALLBACK — configs that resolve off the dense engine still work (and
    are counted as fallbacks), including ``backend='auto'``.
"""
import json

import jax
import numpy as np
import pytest

from repro.core import (GraphDelta, NucleusConfig, Session, build_problem,
                        decompose)
from repro.core.schedule import PeelSchedule
from repro.core.session import bucket_size, canonical_schedule
from repro.graph import generators, make_graph
from repro.graph.generators import golden_suite

pytestmark = pytest.mark.fast

GRAPHS = golden_suite()


def _assert_same(dec_s, dec_c, label):
    np.testing.assert_array_equal(dec_s.core, dec_c.core,
                                  err_msg=f"{label}: core")
    assert dec_s.rounds == dec_c.rounds, label
    assert type(dec_s.rounds) is int, label
    np.testing.assert_array_equal(dec_s.order_round, dec_c.order_round,
                                  err_msg=f"{label}: order_round")
    np.testing.assert_array_equal(dec_s.peel_value, dec_c.peel_value,
                                  err_msg=f"{label}: peel_value")
    if dec_c.has_hierarchy:
        np.testing.assert_array_equal(np.asarray(dec_s.tree.parent),
                                      np.asarray(dec_c.tree.parent),
                                      err_msg=f"{label}: tree parent")
        np.testing.assert_array_equal(np.asarray(dec_s.tree.level),
                                      np.asarray(dec_c.tree.level),
                                      err_msg=f"{label}: tree level")


# ---------------------------------------------------------------------------
# Padding + canonicalization helpers
# ---------------------------------------------------------------------------

def test_bucket_size_boundaries():
    assert bucket_size(0) == 64
    assert bucket_size(1) == 64
    assert bucket_size(64) == 64
    assert bucket_size(65) == 128
    assert bucket_size(128) == 128
    assert bucket_size(129) == 256
    assert bucket_size(3, floor=2) == 4


def test_canonical_schedule_exact_ignores_graph_size():
    a = canonical_schedule("exact", 3, 0.1, 10)
    b = canonical_schedule("exact", 3, 0.5, 10_000)
    assert a == b  # one static jit key for the whole exact class


def test_canonical_schedule_approx_preserves_cap():
    for n in (2, 10, 100, 1_000, 50_000):
        for delta in (0.1, 0.5):
            full = PeelSchedule(kind="approx", s_choose_r=3, delta=delta,
                                n=n)
            canon = canonical_schedule("approx", 3, delta, n)
            assert canon.cap() == full.cap(), (n, delta)
            assert canon.n <= n or n < 2
            if canon.n > 2:  # minimality: one less vertex drops the cap
                smaller = PeelSchedule(kind="approx", s_choose_r=3,
                                       delta=delta, n=canon.n - 1)
                assert smaller.cap() < full.cap(), (n, delta)


def test_same_cap_graphs_share_a_bucket():
    # delta is deliberately coarse: at delta=1.5 the approx round cap is
    # flat across nearby vertex counts, so canonicalization collapses the
    # two schedules onto one static key (at tiny delta the cap — and hence
    # the bucket — legitimately moves with nearly every n)
    cfg = NucleusConfig(r=2, s=3, method="approx", delta=1.5,
                        backend="dense", hierarchy="none")
    sess = Session(cfg)
    p1 = build_problem(generators.planted_cliques(40, [8, 6], 0.05, seed=1),
                       2, 3)
    p2 = build_problem(generators.planted_cliques(41, [8, 6], 0.05, seed=2),
                       2, 3)
    k1, k2 = sess.bucket_key(p1), sess.bucket_key(p2)
    # distinct graph sizes, same schedule class + shape class
    assert p1.g.n != p2.g.n
    assert k1 == k2


# ---------------------------------------------------------------------------
# Parity vs decompose()
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_session_parity_exact_fused(gname):
    problem = build_problem(GRAPHS[gname](), 2, 3)
    if problem.n_r == 0:
        pytest.skip("no r-cliques")
    cfg = NucleusConfig(r=2, s=3, backend="dense", hierarchy="fused")
    _assert_same(Session(cfg).decompose(problem), decompose(problem, cfg),
                 gname)


@pytest.mark.parametrize("gname", ["two_triangles", "planted40", "er20"])
def test_session_parity_approx(gname):
    problem = build_problem(GRAPHS[gname](), 2, 3)
    cfg = NucleusConfig(r=2, s=3, method="approx", delta=0.25,
                        backend="dense", hierarchy="fused")
    _assert_same(Session(cfg).decompose(problem), decompose(problem, cfg),
                 gname)


@pytest.mark.slow
@pytest.mark.parametrize("r,s", [(1, 2), (3, 4)])
def test_session_parity_other_rs(r, s):
    problem = build_problem(GRAPHS["planted40"](), r, s)
    if problem.n_r == 0:
        pytest.skip("no r-cliques")
    cfg = NucleusConfig(r=r, s=s, backend="dense", hierarchy="fused")
    _assert_same(Session(cfg).decompose(problem), decompose(problem, cfg),
                 f"r{r}s{s}")


def test_session_accepts_graphs_and_builds_problems():
    g = generators.planted_cliques(90, [9, 7], 0.04, seed=5)
    cfg = NucleusConfig(r=2, s=3, backend="dense", hierarchy="none")
    _assert_same(Session(cfg).decompose(g), decompose(g, cfg), "from-graph")


# ---------------------------------------------------------------------------
# Bucketing + stats
# ---------------------------------------------------------------------------

def test_same_bucket_stream_is_warm():
    cfg = NucleusConfig(r=2, s=3, backend="dense", hierarchy="fused")
    sess = Session(cfg)
    graphs = [generators.planted_cliques(100 + 3 * i, [10, 8], 0.03,
                                         seed=20 + i) for i in range(4)]
    problems = [build_problem(g, 2, 3) for g in graphs]
    shapes = {(p.n_r, p.n_s) for p in problems}
    assert len(shapes) > 1, "stream must have distinct shapes"
    decs = sess.decompose_many(problems)
    assert len(decs) == 4
    assert len(sess.stats["buckets"]) == 1, sess.stats
    assert sess.stats["cold"] == 1 and sess.stats["warm"] == 3, sess.stats
    for p, d in zip(problems, decs):
        _assert_same(d, decompose(p, cfg), f"n_r={p.n_r}")


def test_fallback_backends_still_work():
    problem = build_problem(GRAPHS["two_triangles"](), 2, 3)
    for backend, hierarchy in [("gather", "replay"), ("nh", "two_phase")]:
        cfg = NucleusConfig(r=2, s=3, backend=backend, hierarchy=hierarchy)
        sess = Session(cfg)
        dec = sess.decompose(problem)
        assert sess.stats["fallback"] == 1
        np.testing.assert_array_equal(dec.core,
                                      decompose(problem, cfg).core)


def test_use_pallas_rides_the_warm_path():
    """The round megakernel is bucketed like everything else: a stream of
    same-bucket problems with use_pallas=True shares ONE executable (cold
    once, warm after, no fallback) and stays array-identical to the
    unpadded decompose()."""
    cfg = NucleusConfig(r=2, s=3, backend="dense", hierarchy="fused",
                        use_pallas=True)
    sess = Session(cfg)
    graphs = [generators.planted_cliques(100 + 3 * i, [10, 8], 0.03,
                                         seed=20 + i) for i in range(3)]
    problems = [build_problem(g, 2, 3) for g in graphs]
    decs = sess.decompose_many(problems)
    assert sess.stats["fallback"] == 0, sess.stats
    assert len(sess.stats["buckets"]) == 1, sess.stats
    assert sess.stats["cold"] == 1 and sess.stats["warm"] == 2, sess.stats
    for p, d in zip(problems, decs):
        _assert_same(d, decompose(p, cfg), f"pallas-warm n_r={p.n_r}")


def test_pallas_over_budget_plan_falls_back():
    """A (megakernel) plan bigger than the VMEM-plan budget must take the
    cold path, not die: the Session races plan bytes against
    MEGAKERNEL_PLAN_BUDGET_BYTES before bucketing."""
    from repro.core import session as session_mod
    problem = build_problem(GRAPHS["planted40"](), 2, 3)
    cfg = NucleusConfig(r=2, s=3, backend="dense", hierarchy="fused",
                        use_pallas=True)
    old = session_mod.MEGAKERNEL_PLAN_BUDGET_BYTES
    try:
        session_mod.MEGAKERNEL_PLAN_BUDGET_BYTES = 1  # force over-budget
        sess = Session(cfg)
        dec = sess.decompose(problem)
        assert sess.stats["fallback"] == 1
        _assert_same(dec, decompose(problem, cfg), "pallas-over-budget")
    finally:
        session_mod.MEGAKERNEL_PLAN_BUDGET_BYTES = old


def test_fallback_preserves_auto_plan_provenance():
    """The fallback path executes the already-planned config — the
    serialized plan must still say 'auto' was requested, with the
    planner's real reasons (not 'explicitly configured')."""
    tiny = build_problem(GRAPHS["two_triangles"](), 2, 3)  # n_r < TINY_NR
    sess = Session(NucleusConfig(r=2, s=3, backend="auto",
                                 hierarchy="auto"))
    dec = sess.decompose(tiny)
    ref = decompose(tiny, NucleusConfig(r=2, s=3, backend="auto",
                                        hierarchy="auto"))
    assert sess.stats["fallback"] == 1  # tiny-on-cpu resolves off dense
    assert dec.plan == ref.plan
    assert dec.plan.was_auto
    assert dec.plan.requested_backend == "auto"
    assert "explicitly configured" not in dec.plan_report()


def test_session_resolves_auto_per_problem():
    big = generators.planted_cliques(120, [10, 8], 0.03, seed=7)
    sess = Session(NucleusConfig(r=2, s=3, backend="auto",
                                 hierarchy="auto"))
    dec = sess.decompose(big)
    assert dec.plan is not None and dec.plan.was_auto
    assert dec.config.backend in ("dense", "gather")
    _assert_same(dec, decompose(big, NucleusConfig(
        r=2, s=3, backend=dec.config.backend,
        hierarchy=dec.config.hierarchy)), "auto-session")


# ---------------------------------------------------------------------------
# Plan-budget gate, shape-only keys, LRU, boundaries (the PR-7 fixes)
# ---------------------------------------------------------------------------

def test_plan_budget_gate_counts_padded_bytes():
    """Regression: the gate must race the PADDED plan footprint against
    the budget.  The old gate used unpadded sizes, so a problem whose
    pow2-padded member matrix landed over budget was still sent down the
    megakernel path.  A budget between the two sizes must fall back."""
    from repro.core import session as session_mod
    from repro.kernels.segment_sum import DEFAULT_CHUNK_E
    problem = build_problem(GRAPHS["planted40"](), 2, 3)
    C = problem.n_sub
    unpadded = 4 * problem.n_s * C * C
    padded = 4 * session_mod.bucket_size(problem.n_s * C,
                                         DEFAULT_CHUNK_E) * C
    assert unpadded < padded, "fixture must straddle the pad boundary"
    cfg = NucleusConfig(r=2, s=3, backend="dense", hierarchy="fused",
                        use_pallas=True)
    old = session_mod.MEGAKERNEL_PLAN_BUDGET_BYTES
    try:
        session_mod.MEGAKERNEL_PLAN_BUDGET_BYTES = (unpadded + padded) // 2
        sess = Session(cfg)
        dec = sess.decompose(problem)
        assert sess.stats["fallback"] == 1, (
            "budget between unpadded and padded bytes must take the "
            "cold path")
        _assert_same(dec, decompose(problem, cfg), "padded-gate")
    finally:
        session_mod.MEGAKERNEL_PLAN_BUDGET_BYTES = old


def test_bucket_key_matches_plan_built_key():
    """The shape-derived ScatterSpec twin equals the spec of the real
    (array-materializing) plan — same bucket keys as the old path."""
    from repro.core import session as session_mod
    cfg = NucleusConfig(r=2, s=3, backend="dense", hierarchy="fused",
                        use_pallas=True)
    sess = Session(cfg)
    for gname in sorted(GRAPHS):
        problem = build_problem(GRAPHS[gname](), 2, 3)
        if problem.n_s == 0:
            continue
        key = sess.bucket_key(problem)
        n_r_pad = bucket_size(problem.n_r, sess.bucket_floor)
        real_spec = sess._pallas_plan(problem, n_r_pad)[2]
        # read the field by name: the key tuple grew a trailing `shards`
        # field for sharded shape classes (DESIGN.md §13)
        assert session_mod._Bucket(*key).pallas == real_spec, gname


def test_bucket_key_builds_no_plan_arrays(monkeypatch):
    """Probing a key must never materialize padded plan arrays."""
    cfg = NucleusConfig(r=2, s=3, backend="dense", hierarchy="fused",
                        use_pallas=True)
    sess = Session(cfg)
    problem = build_problem(GRAPHS["er20"](), 2, 3)

    def boom(*a, **k):
        raise AssertionError("bucket_key called _pallas_plan")

    monkeypatch.setattr(sess, "_pallas_plan", boom)
    key = sess.bucket_key(problem)
    assert key[-1] is not None  # pallas spec present, derived shape-only


def test_bucket_hit_lru_order():
    sess = Session(NucleusConfig(r=2, s=3), bucket_cap=2)
    assert sess._bucket_hit("a") is False
    assert sess._bucket_hit("b") is False
    assert sess._bucket_hit("a") is True    # refreshes a
    assert sess._bucket_hit("c") is False   # evicts b, the stalest
    assert set(sess.stats["buckets"]) == {"a", "c"}
    assert sess.stats["evictions"] == 1
    assert sess._bucket_hit("b") is False   # re-seen post-eviction: cold


def test_bucket_lru_eviction_bounds_stats():
    cfg = NucleusConfig(r=1, s=2, backend="dense", hierarchy="none")
    sess = Session(cfg, bucket_floor=1, bucket_cap=2)
    for gname in sorted(GRAPHS):
        sess.decompose(build_problem(GRAPHS[gname](), 1, 2))
    assert len(sess.stats["buckets"]) <= 2, sess.stats
    assert sess.stats["evictions"] > 0, sess.stats
    assert (sess.stats["cold"] + sess.stats["warm"]
            == sess.stats["decompositions"] - sess.stats["fallback"])


@pytest.mark.parametrize("n", [64, 65, 255, 256, 257])
def test_session_parity_at_bucket_boundaries(n):
    """Cycles sized to straddle both padding boundaries: n_r at the
    bucket floor (64) and just past it, and the megakernel edge axis at
    chunk_e (2*256 = 512) and one edge to either side."""
    edges = np.array([[i, (i + 1) % n] for i in range(n)])
    problem = build_problem(make_graph(n, edges), 1, 2)
    assert problem.n_r == n
    assert int(problem.mem_sids.shape[0]) == 2 * n
    cfg = NucleusConfig(r=1, s=2, backend="dense", hierarchy="fused",
                        use_pallas=True)
    _assert_same(Session(cfg).decompose(problem),
                 decompose(problem, cfg), f"cycle{n}")


def test_kcore_fast_lane_follows_pallas_default_profile(
        tmp_path, monkeypatch):
    """use_pallas=None routing is profile-driven: pallas_default=False
    sends r1s2 to the k-core fast lane, pallas_default=True pins the
    megakernel (no fast lane) — with identical results."""
    from repro.core import peel as peel_mod
    from repro.core import planner_profile
    problem = build_problem(GRAPHS["er20"](), 1, 2)
    calls = []
    real = peel_mod.kcore_coreness

    def spy(*a, **k):
        calls.append(1)
        return real(*a, **k)

    monkeypatch.setattr(peel_mod, "kcore_coreness", spy)

    def set_profile(flag):
        p = tmp_path / f"prof_{flag}.json"
        p.write_text(json.dumps({
            "format": "repro.planner-profile", "version": 1,
            "profiles": {jax.default_backend(): {"pallas_default": flag}}}))
        monkeypatch.setattr(planner_profile, "PROFILE_PATH", str(p))
        planner_profile.reset_cache()

    try:
        set_profile(False)
        r1 = peel_mod.exact_coreness(problem, backend="dense",
                                     use_pallas=None, hierarchy=True)
        assert calls, "pallas_default=False must route r1s2 to the lane"
        calls.clear()
        set_profile(True)
        r2 = peel_mod.exact_coreness(problem, backend="dense",
                                     use_pallas=None, hierarchy=True)
        assert not calls, "pallas_default=True pins the megakernel"
        np.testing.assert_array_equal(np.asarray(r1.core),
                                      np.asarray(r2.core))
        np.testing.assert_array_equal(np.asarray(r1.uf_parent),
                                      np.asarray(r2.uf_parent))
    finally:
        planner_profile.reset_cache()


# ---------------------------------------------------------------------------
# Session.update: the streaming warm path
# ---------------------------------------------------------------------------

def test_session_update_streams_warm():
    cfg = NucleusConfig(r=1, s=2, backend="dense", hierarchy="fused")
    sess = Session(cfg)
    g = GRAPHS["er20"]()
    dec = sess.decompose(build_problem(g, 1, 2))
    present = {tuple(r) for r in np.asarray(g.edges).tolist()}
    ins = next((u, v) for u in range(g.n) for v in range(u + 1, g.n)
               if (u, v) not in present)
    d2 = sess.update(dec, GraphDelta(insert=np.array([ins])))
    assert sess.stats["updates"] == 1
    assert sess.stats["stream_cold"] >= 1
    # the inverse edit lands in the same padded shape classes: warm
    d3 = sess.update(d2, GraphDelta(delete=np.array([ins])))
    assert sess.stats["updates"] == 2
    assert sess.stats["stream_warm"] >= 1, sess.stats
    fresh = decompose(build_problem(d3.problem.g, 1, 2), cfg)
    np.testing.assert_array_equal(np.asarray(d3.core),
                                  np.asarray(fresh.core))
    np.testing.assert_array_equal(np.asarray(d3.uf_parent),
                                  np.asarray(fresh.uf_parent))
    np.testing.assert_array_equal(np.asarray(d3.uf_L),
                                  np.asarray(fresh.uf_L))
