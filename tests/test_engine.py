"""Parity of the compiled peel engine against every other formulation.

The unified engine (repro.core.engine) must be *bit-identical* to the eager
work-efficient gather backend — same cores, same trace (order_round), same
round count — because both are driven by the one PeelSchedule; and the trace
replay must reproduce the callback-era interleaved hierarchy (join levels are
the canonical comparison metric, matching the two-phase ANH-TE tree which
the seed interleaved tests already pin down).
"""
import numpy as np
import pytest

from repro.graph import generators
from repro.core import (build_problem, replay_trace,
                        construct_tree_efficient, link_state_from_forest)
from repro.core.peel import exact_coreness, approx_coreness
from repro.core.hierarchy import build_hierarchy_levels
from repro.core.interleaved import build_hierarchy_interleaved, _resolve
from repro.core.nh_baseline import nh_coreness

GRAPHS = {
    "er30": generators.erdos_renyi(30, 0.25, seed=2),
    "planted": generators.planted_cliques(40, [8, 6, 5], 0.05, seed=3),
    "ba60": generators.barabasi_albert(60, 4, seed=4),
    "fig1": generators.paper_figure1_like(),
}
RS = [(1, 2), (2, 3), (2, 4)]


def problems():
    for gname in GRAPHS:
        for (r, s) in RS:
            yield pytest.param(gname, r, s, id=f"{gname}-r{r}s{s}")


def _sample_pairs(n_r, seed, k=60):
    rng = np.random.default_rng(seed)
    if n_r < 2:
        return np.zeros((0, 2), np.int64)
    return np.stack([rng.integers(0, n_r, k), rng.integers(0, n_r, k)], 1)


@pytest.mark.parametrize("gname,r,s", problems())
def test_engine_exact_matches_gather_and_oracle(gname, r, s):
    p = build_problem(GRAPHS[gname], r, s)
    if p.n_r == 0:
        pytest.skip("no r-cliques")
    eng = exact_coreness(p, backend="dense")
    gat = exact_coreness(p, backend="gather")
    oracle, _ = nh_coreness(p)
    np.testing.assert_array_equal(np.asarray(eng.core), oracle)
    np.testing.assert_array_equal(np.asarray(eng.core), np.asarray(gat.core))
    # the trace is part of the contract: identical schedules -> identical
    # peel rounds, so the hierarchy replay sees the same round stream
    np.testing.assert_array_equal(np.asarray(eng.order_round),
                                  np.asarray(gat.order_round))
    assert eng.rounds == gat.rounds


@pytest.mark.parametrize("delta", [0.1, 0.5, 1.0])
@pytest.mark.parametrize("gname,r,s", problems())
def test_engine_approx_matches_gather_and_bounds(gname, r, s, delta):
    from math import comb
    p = build_problem(GRAPHS[gname], r, s)
    if p.n_r == 0:
        pytest.skip("no r-cliques")
    eng = approx_coreness(p, delta=delta, backend="dense")
    gat = approx_coreness(p, delta=delta, backend="gather")
    np.testing.assert_array_equal(np.asarray(eng.core), np.asarray(gat.core))
    np.testing.assert_array_equal(np.asarray(eng.peel_value),
                                  np.asarray(gat.peel_value))
    np.testing.assert_array_equal(np.asarray(eng.order_round),
                                  np.asarray(gat.order_round))
    exact = np.asarray(exact_coreness(p).core)
    a = np.asarray(eng.core)
    factor = (comb(s, r) + delta) * (1 + delta)
    assert (a >= exact).all()
    assert (a <= np.maximum(np.ceil(factor * exact), exact)).all()


@pytest.mark.parametrize("gname,r,s", problems())
def test_pallas_scatter_matches_xla_fallback(gname, r, s):
    """The Pallas sorted-segment-sum decrement (interpret mode on CPU) must
    agree with the .at[].add oracle over the full peel."""
    p = build_problem(GRAPHS[gname], r, s)
    if p.n_r == 0:
        pytest.skip("no r-cliques")
    ref = exact_coreness(p, backend="dense", use_pallas=False)
    pal = exact_coreness(p, backend="dense", use_pallas=True)
    np.testing.assert_array_equal(np.asarray(ref.core), np.asarray(pal.core))
    np.testing.assert_array_equal(np.asarray(ref.order_round),
                                  np.asarray(pal.order_round))


@pytest.mark.parametrize("backend", ["gather", "dense"])
@pytest.mark.parametrize("gname,r,s", problems())
def test_trace_replay_hierarchy_matches_two_phase(gname, r, s, backend):
    """Trace-replay ANH-EL == callback-era join levels.  The seed pinned the
    callback-era tree to the two-phase ANH-TE tree, so TE join levels are the
    callback-era reference."""
    p = build_problem(GRAPHS[gname], r, s)
    if p.n_r == 0:
        pytest.skip("no r-cliques")
    res = build_hierarchy_interleaved(p, mode="exact", backend=backend)
    core = exact_coreness(p).core
    np.testing.assert_array_equal(np.asarray(res.core), np.asarray(core))
    t_te = build_hierarchy_levels(p, core)
    pairs = _sample_pairs(p.n_r, seed=7)
    np.testing.assert_array_equal(res.tree.join_levels(pairs),
                                  t_te.join_levels(pairs))


@pytest.mark.parametrize("gname,r,s", problems())
def test_trace_replay_equals_direct_replay(gname, r, s):
    """replay_trace over the dense-engine trace and over the gather trace
    build identical LINK states (same uf partition, same join levels)."""
    p = build_problem(GRAPHS[gname], r, s)
    if p.n_r == 0:
        pytest.skip("no r-cliques")
    st_e = replay_trace(p, exact_coreness(p, backend="dense"))
    st_g = replay_trace(p, exact_coreness(p, backend="gather"))
    t_e = construct_tree_efficient(p, st_e)
    t_g = construct_tree_efficient(p, st_g)
    pairs = _sample_pairs(p.n_r, seed=11)
    np.testing.assert_array_equal(t_e.join_levels(pairs),
                                  t_g.join_levels(pairs))


@pytest.mark.parametrize("mode", ["exact", "approx"])
@pytest.mark.parametrize("gname,r,s", problems())
def test_fused_forest_matches_replay_oracle(gname, r, s, mode):
    """The on-device LINK fixpoint (hierarchy=True: uf/L threaded through
    the compiled peel carry) must reproduce the host trace-replay state
    EXACTLY — same resolved parents, same L at every root, same tree."""
    p = build_problem(GRAPHS[gname], r, s)
    if p.n_r == 0:
        pytest.skip("no r-cliques")
    peel = (exact_coreness if mode == "exact"
            else lambda q, **kw: approx_coreness(q, delta=0.1, **kw))
    res = peel(p, backend="dense", hierarchy=True)
    assert res.has_hierarchy
    state = replay_trace(p, res)
    ref_parent = _resolve(state.parent, np.arange(p.n_r, dtype=np.int64))
    got_parent = np.asarray(res.uf_parent).astype(np.int64)
    np.testing.assert_array_equal(got_parent, ref_parent)
    roots = np.unique(ref_parent)
    np.testing.assert_array_equal(
        np.asarray(res.uf_L).astype(np.int64)[roots], state.L[roots])
    t_fused = construct_tree_efficient(p, link_state_from_forest(
        res.peel_value, res.uf_parent, res.uf_L))
    t_replay = construct_tree_efficient(p, state)
    pairs = _sample_pairs(p.n_r, seed=13)
    np.testing.assert_array_equal(t_fused.join_levels(pairs),
                                  t_replay.join_levels(pairs))


@pytest.mark.parametrize("gname,r,s", problems())
def test_fused_hierarchy_does_not_perturb_coreness(gname, r, s):
    """hierarchy=True only extends the carry: core/order/rounds unchanged."""
    p = build_problem(GRAPHS[gname], r, s)
    if p.n_r == 0:
        pytest.skip("no r-cliques")
    plain = exact_coreness(p, backend="dense")
    fused = exact_coreness(p, backend="dense", hierarchy=True)
    np.testing.assert_array_equal(np.asarray(plain.core),
                                  np.asarray(fused.core))
    np.testing.assert_array_equal(np.asarray(plain.order_round),
                                  np.asarray(fused.order_round))
    assert plain.rounds == fused.rounds


def test_engine_empty_problem():
    """A graph with no s-cliques: engine returns deg0 (all zero) cores."""
    g = generators.tiny_named("path4")
    p = build_problem(g, 2, 4)  # path has no K4s
    if p.n_r == 0:
        pytest.skip("no r-cliques")
    res = exact_coreness(p, backend="dense")
    np.testing.assert_array_equal(np.asarray(res.core),
                                  np.zeros(p.n_r, np.int64))


# ---------------------------------------------------------------------------
# Round megakernel (use_pallas=True now runs the fused round, not just the
# scatter): full-peel bit-identity incl. the fused hierarchy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["exact", "approx"])
@pytest.mark.parametrize("gname,r,s", problems())
def test_megakernel_full_peel_bit_identical(gname, r, s, mode):
    """The fused round megakernel must reproduce the multi-op XLA round
    body bit-for-bit across the whole peel — cores, trace, rounds AND the
    fused LINK forest (the forest consumes the per-round a_mask, so it
    would catch a divergence the final cores might mask)."""
    p = build_problem(GRAPHS[gname], r, s)
    if p.n_r == 0:
        pytest.skip("no r-cliques")
    peel = (exact_coreness if mode == "exact"
            else lambda q, **kw: approx_coreness(q, delta=0.1, **kw))
    ref = peel(p, backend="dense", use_pallas=False, hierarchy=True,
               fast_lane=False)
    mk = peel(p, backend="dense", use_pallas=True, hierarchy=True,
              fast_lane=False)
    for f in ("core", "order_round", "uf_parent", "uf_L"):
        np.testing.assert_array_equal(np.asarray(getattr(ref, f)),
                                      np.asarray(getattr(mk, f)),
                                      err_msg=f)
    assert ref.rounds == mk.rounds


# ---------------------------------------------------------------------------
# k-core fast lane (r1s2): bit-identity against the generic engine
# ---------------------------------------------------------------------------

KCORE_GRAPHS = list(GRAPHS) + ["er80"]
GRAPHS["er80"] = generators.erdos_renyi(80, 0.1, seed=9)


@pytest.mark.parametrize("mode", ["exact", "approx"])
@pytest.mark.parametrize("gname", KCORE_GRAPHS)
def test_kcore_lane_bit_identical(gname, mode):
    """The r1s2 vertex-degree lane (one-shot edge-list fixpoint) must be
    bit-identical to the generic incidence engine: same cores, same trace,
    same rounds, same resolved forest."""
    p = build_problem(GRAPHS[gname], 1, 2)
    peel = (exact_coreness if mode == "exact"
            else lambda q, **kw: approx_coreness(q, delta=0.1, **kw))
    ref = peel(p, backend="dense", use_pallas=False, hierarchy=True,
               fast_lane=False)
    kc = peel(p, backend="dense", hierarchy=True, fast_lane=True)
    for f in ("core", "order_round", "uf_parent", "uf_L"):
        np.testing.assert_array_equal(np.asarray(getattr(ref, f)),
                                      np.asarray(getattr(kc, f)),
                                      err_msg=f)
    assert ref.rounds == kc.rounds


def test_kcore_lane_is_the_r1s2_default():
    """peel._run routes (1,2) dense peels to the k-core lane unless the
    caller pins the Pallas megakernel."""
    from repro.core import peel as peel_mod
    calls = []
    orig = peel_mod.kcore_coreness

    def spy(problem, schedule, **kw):
        calls.append(kw)
        return orig(problem, schedule, **kw)

    p = build_problem(GRAPHS["er30"], 1, 2)
    try:
        peel_mod.kcore_coreness = spy
        exact_coreness(p, backend="dense")
        assert len(calls) == 1          # default: lane taken
        exact_coreness(p, backend="dense", use_pallas=True)
        assert len(calls) == 1          # pinned megakernel: lane skipped
    finally:
        peel_mod.kcore_coreness = orig


def test_kcore_lane_matches_replay_oracle():
    """The one-shot edge-list fixpoint forest == host trace replay (the
    confluence argument, end-to-end)."""
    p = build_problem(GRAPHS["ba60"], 1, 2)
    res = exact_coreness(p, backend="dense", hierarchy=True, fast_lane=True)
    state = replay_trace(p, res)
    ref_parent = _resolve(state.parent, np.arange(p.n_r, dtype=np.int64))
    np.testing.assert_array_equal(
        np.asarray(res.uf_parent).astype(np.int64), ref_parent)
    t_fused = construct_tree_efficient(p, link_state_from_forest(
        res.peel_value, res.uf_parent, res.uf_L))
    t_replay = construct_tree_efficient(p, state)
    pairs = _sample_pairs(p.n_r, seed=17)
    np.testing.assert_array_equal(t_fused.join_levels(pairs),
                                  t_replay.join_levels(pairs))
