"""Substrate tests: optimizer, checkpointing, distributed runtime, data."""
import os
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.optim import (AdamWConfig, init_state, apply_updates, schedule_lr,
                         init_compression, compress_grads, decompress_grads)
from repro.checkpoint import CheckpointManager
from repro.distributed import (StragglerMonitor, PreemptionGuard, ElasticPlan)
from repro.data import (TokenStream, TokenStreamConfig, RecsysStream,
                        RecsysStreamConfig, GraphMinibatchStream)
from repro.graph import generators


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=200, schedule="constant", grad_clip=0)
    state = init_state(params)
    for _ in range(200):
        grads = jax.tree.map(lambda w: 2 * w, params)
        params, state, _ = apply_updates(params, grads, state, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


@pytest.mark.parametrize("sched", ["constant", "cosine", "wsd"])
def test_schedules(sched):
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      schedule=sched, min_lr_frac=0.1)
    lrs = np.array([float(schedule_lr(cfg, jnp.asarray(s)))
                    for s in range(101)])
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1.0) < 1e-6          # warmup done
    assert lrs.max() <= 1.0 + 1e-6
    if sched == "cosine":
        assert lrs[100] == pytest.approx(0.1, abs=1e-6)
    if sched == "wsd":
        assert lrs[85] == pytest.approx(1.0, abs=1e-6)   # stable plateau
        assert lrs[100] == pytest.approx(0.1, abs=1e-3)  # decayed


def test_grad_clip():
    params = {"w": jnp.zeros(3)}
    cfg = AdamWConfig(grad_clip=1.0, warmup_steps=0, schedule="constant")
    _, _, m = apply_updates(params, {"w": jnp.asarray([100.0, 0, 0])},
                            init_state(params), cfg)
    assert float(m["grad_norm"]) == pytest.approx(100.0)


def test_gradient_compression_roundtrip():
    rng = np.random.default_rng(0)
    grads = {"a": jnp.asarray(rng.standard_normal((32, 16)), jnp.float32),
             "b": jnp.asarray(rng.standard_normal(7), jnp.float32)}
    cstate = init_compression(grads, rank=16, key=jax.random.PRNGKey(0))
    payload, cstate = compress_grads(grads, cstate, rank=16)
    approx = decompress_grads(payload, grads)
    # full-rank factorization after one power iteration is not exact, but
    # error feedback keeps the series unbiased: compressed + error == grads
    err = cstate.error["a"]
    np.testing.assert_allclose(np.asarray(approx["a"] + err),
                               np.asarray(grads["a"]), atol=1e-4)
    # 1-D params ride uncompressed
    np.testing.assert_allclose(np.asarray(approx["b"]),
                               np.asarray(grads["b"]), atol=0)


def test_gradient_compression_unbiased_over_time():
    """Error feedback: the TIME-AVERAGED transmitted signal converges to the
    true gradient (sum of payloads - T*g == residual, which stays bounded
    while T grows)."""
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.standard_normal((24, 24)), jnp.float32)}
    cstate = init_compression(g, rank=4, key=jax.random.PRNGKey(1))
    total = jnp.zeros_like(g["w"])
    norms = []
    T = 40
    for _ in range(T):
        payload, cstate = compress_grads(g, cstate, rank=4)
        total = total + decompress_grads(payload, g)["w"]
        norms.append(float(jnp.linalg.norm(cstate.error["w"])))
    gnorm = float(jnp.linalg.norm(g["w"]))
    avg_err = float(jnp.linalg.norm(total / T - g["w"]))
    assert avg_err < 0.25 * gnorm, (avg_err, gnorm)
    # residual reaches a steady state rather than growing linearly
    assert norms[-1] < 1.3 * max(norms[T // 2:]), norms[-5:]


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _state(seed):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 4)),
            "nested": {"b": jnp.arange(5, dtype=jnp.float32)},
            "step": jnp.asarray(seed)}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    s1 = _state(1)
    mgr.save(10, s1, extra={"data_step": 123}, blocking=True)
    like = jax.tree.map(jnp.zeros_like, s1)
    restored, step, extra = mgr.restore(like)
    assert step == 10 and extra["data_step"] == 123
    for a, b in zip(jax.tree.leaves(s1), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in [1, 2, 3, 4]:
        mgr.save(s, _state(s))     # async
    mgr.wait()
    assert sorted(mgr.all_steps()) == [3, 4]


def test_checkpoint_atomicity(tmp_path):
    """A leftover .tmp dir must never be picked up as a checkpoint."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, _state(5), blocking=True)
    os.makedirs(str(tmp_path / "step_9.tmp"))  # simulated crash mid-write
    assert mgr.latest_step() == 5


def test_checkpoint_elastic_remesh(tmp_path):
    """Restore re-shards onto a different device layout (elastic)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    mgr = CheckpointManager(str(tmp_path))
    s = _state(2)
    mgr.save(1, s, blocking=True)
    mesh = make_host_mesh()
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), s)
    restored, _, _ = mgr.restore(jax.tree.map(jnp.zeros_like, s),
                                 sharding_tree=sh)
    assert restored["w"].sharding == NamedSharding(mesh, P())
    np.testing.assert_allclose(np.asarray(restored["w"]), np.asarray(s["w"]))


def test_train_restore_resumes_exactly(tmp_path):
    """Loss trajectory with a checkpoint/restore mid-run == uninterrupted."""
    from repro.launch.train import train_lm
    r1 = train_lm("minicpm-2b", steps=30, smoke=True, quiet=True,
                  ckpt_dir=str(tmp_path / "a"), ckpt_every=15)
    r2a = train_lm("minicpm-2b", steps=15, smoke=True, quiet=True,
                   ckpt_dir=str(tmp_path / "b"), ckpt_every=15)
    r2b = train_lm("minicpm-2b", steps=30, smoke=True, quiet=True,
                   ckpt_dir=str(tmp_path / "b"), ckpt_every=15, resume=True)
    assert r2b.restored_from == 15
    np.testing.assert_allclose(r1.losses[15:], r2b.losses, rtol=1e-4)


# ---------------------------------------------------------------------------
# distributed runtime
# ---------------------------------------------------------------------------

def test_straggler_monitor_flags_slow_step():
    mon = StragglerMonitor(slack=3.0, warmup=3)
    for _ in range(6):
        mon.start_step()
        time.sleep(0.002)
        assert mon.end_step() is None
    mon.start_step()
    time.sleep(0.05)
    ev = mon.end_step()
    assert ev is not None and ev[1] > 3 * ev[2]


def test_preemption_guard_flag():
    g = PreemptionGuard(signals=())
    assert not g.should_stop
    g.request_stop()
    assert g.should_stop
    g.restore()


@pytest.mark.parametrize("n_dev,mp", [(512, 16), (256, 16), (128, 16),
                                      (384, 16)])
def test_elastic_plan_preserves_global_batch(n_dev, mp):
    plan = ElasticPlan.plan(n_dev, global_batch=256, model_parallel=mp)
    data = n_dev // mp
    assert plan.global_batch >= 256
    assert plan.per_device_batch * data == plan.global_batch
    assert np.prod(plan.mesh_shape) == n_dev


def test_elastic_plan_rejects_bad_split():
    with pytest.raises(ValueError):
        ElasticPlan.plan(100, global_batch=256, model_parallel=16)


# ---------------------------------------------------------------------------
# data pipelines
# ---------------------------------------------------------------------------

def test_token_stream_deterministic_and_resumable():
    cfg = TokenStreamConfig(vocab=97, seq_len=16, global_batch=4, seed=3)
    a = TokenStream(cfg).batch(7)
    b = TokenStream(cfg).batch(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = TokenStream(cfg).batch(8)
    assert (a["tokens"] != c["tokens"]).any()
    # labels = next-token
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_recsys_stream_label_signal():
    cfg = RecsysStreamConfig(n_items=6400, n_cates=50, n_users=1000,
                             seq_len=20, batch=512, seed=0)
    b = RecsysStream(cfg).batch(0)
    assert b["hist_items"].shape == (512, 20)
    assert set(np.unique(b["label"])) <= {0.0, 1.0}
    # positives share the user's cluster
    pos = b["label"] == 1
    assert ((b["cand_item"][pos] % 64) == (b["user_id"][pos] % 64)).all()


def test_graph_minibatch_stream_edges_are_real():
    g = generators.barabasi_albert(500, 5, seed=0)
    stream = GraphMinibatchStream(g, fanouts=[3, 2], batch_nodes=8,
                                  d_feat=4, n_classes=3, seed=0)
    b = stream.batch(0)
    n_e = int(b["edge_mask"].sum())
    n_n = int(b["node_mask"].sum())
    assert n_e > 0 and n_n >= 8
    # sampled edges connect nodes actually adjacent in the base graph
    edges = set(map(tuple, np.asarray(g.edges)))
    # recover global ids via the sampler's block (re-sample with same seed)
    blk = GraphMinibatchStream(g, fanouts=[3, 2], batch_nodes=8, d_feat=4,
                               n_classes=3, seed=0).sampler
    # structural check: masked src/dst indices stay within live nodes
    assert b["edge_src"][:n_e].max() < n_n
    assert b["edge_dst"][:n_e].max() < n_n
