"""Golden regression fixtures: every backend vs the committed canon.

tests/golden/*.json pin, per (graph, r, s): exact core numbers and the
canonicalized c-(r,s) nucleus partition at every distinct positive core
level (a cut of the ANH-EL hierarchy).  Regenerate deliberately with
`make regen-golden`; the JSON diff is the review artifact.

Checked backends: coreness via gather / dense / dense+pallas(interpret) /
shard_map; hierarchy via host trace replay, the fused on-device LINK
fixpoint, two-phase ANH-TE and per-level ANH-BL.
"""
import json
import os

import numpy as np
import pytest

from repro.graph.generators import golden_suite, GOLDEN_RS
from repro.core import (build_problem, exact_coreness, canonicalize_labels,
                        build_hierarchy_interleaved, build_hierarchy_levels,
                        build_hierarchy_basic, cut_hierarchy,
                        sharded_decomposition)

pytestmark = pytest.mark.fast

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "golden")

# the one graph-suite definition, shared with tools/regen_golden.py
GRAPHS = golden_suite()


def fixtures():
    for fname in sorted(os.listdir(GOLDEN_DIR)):
        if fname.endswith(".json"):
            yield pytest.param(fname, id=fname[:-len(".json")])


def _load(fname):
    with open(os.path.join(GOLDEN_DIR, fname)) as f:
        fx = json.load(f)
    problem = build_problem(GRAPHS[fx["graph"]](), fx["r"], fx["s"])
    assert problem.n_r == fx["n_r"], "graph/generator drift vs fixture"
    return fx, problem


def _check_partitions(fx, tree, label=""):
    for c_str, want in fx["partitions"].items():
        got = canonicalize_labels(cut_hierarchy(tree, int(c_str)))
        np.testing.assert_array_equal(
            got, np.asarray(want), err_msg=f"{label} cut level c={c_str}")


def test_golden_files_exist():
    assert len(list(fixtures())) == len(GRAPHS) * len(GOLDEN_RS)


@pytest.mark.parametrize("fname", fixtures())
def test_golden_coreness_all_backends(fname):
    fx, p = _load(fname)
    if p.n_r == 0:
        pytest.skip("no r-cliques")
    want = np.asarray(fx["core"])
    for label, res in [
            ("gather", exact_coreness(p, backend="gather")),
            ("dense", exact_coreness(p, backend="dense")),
            ("pallas", exact_coreness(p, backend="dense", use_pallas=True)),
    ]:
        np.testing.assert_array_equal(np.asarray(res.core), want,
                                      err_msg=f"backend={label}")


@pytest.mark.parametrize("fname", fixtures())
def test_golden_hierarchy_all_backends(fname):
    fx, p = _load(fname)
    if p.n_r == 0:
        pytest.skip("no r-cliques")
    core = exact_coreness(p).core
    trees = {
        "replay": build_hierarchy_interleaved(
            p, backend="dense", link="replay").tree,
        "fused": build_hierarchy_interleaved(
            p, backend="dense", link="fused").tree,
        "te": build_hierarchy_levels(p, core),
        "bl": build_hierarchy_basic(p, core),
    }
    for label, tree in trees.items():
        _check_partitions(fx, tree, label)


@pytest.mark.parametrize("fname", fixtures())
def test_golden_sharded_backend(fname):
    from repro.launch.mesh import make_host_mesh
    from repro.core import link_state_from_forest, construct_tree_efficient
    fx, p = _load(fname)
    if p.n_r == 0:
        pytest.skip("no r-cliques")
    core, _rounds, parent, L, raw = sharded_decomposition(
        p, make_host_mesh(), kind="exact", hierarchy=True)
    np.testing.assert_array_equal(np.asarray(core), np.asarray(fx["core"]))
    state = link_state_from_forest(raw, parent, L)
    tree = construct_tree_efficient(p, state)
    _check_partitions(fx, tree)
