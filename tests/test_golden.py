"""Golden regression fixtures: every backend vs the committed canon.

tests/golden/*.json pin, per (graph, r, s): exact core numbers and the
canonicalized c-(r,s) nucleus partition at every distinct positive core
level (a cut of the ANH-EL hierarchy).  Regenerate deliberately with
`make regen-golden`; the JSON diff is the review artifact.

All checked paths go through the ``decompose()`` facade (the public front
door): coreness via gather / dense / dense+pallas(interpret) / shard_map;
hierarchy via host trace replay, the fused on-device LINK fixpoint,
two-phase ANH-TE and per-level ANH-BL.
"""
import json
import os

import numpy as np
import pytest

from repro.graph.generators import golden_suite, GOLDEN_RS
from repro.core import (build_problem, canonicalize_labels, decompose,
                        NucleusConfig)

pytestmark = pytest.mark.fast

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "golden")

# the one graph-suite definition, shared with tools/regen_golden.py
GRAPHS = golden_suite()


def fixtures():
    for fname in sorted(os.listdir(GOLDEN_DIR)):
        if fname.endswith(".json"):
            yield pytest.param(fname, id=fname[:-len(".json")])


def _load(fname):
    with open(os.path.join(GOLDEN_DIR, fname)) as f:
        fx = json.load(f)
    problem = build_problem(GRAPHS[fx["graph"]](), fx["r"], fx["s"])
    assert problem.n_r == fx["n_r"], "graph/generator drift vs fixture"
    return fx, problem


def _check_partitions(fx, dec, label=""):
    for c_str, want in fx["partitions"].items():
        got = canonicalize_labels(dec.cut(int(c_str)))
        np.testing.assert_array_equal(
            got, np.asarray(want), err_msg=f"{label} cut level c={c_str}")


def test_golden_files_exist():
    assert len(list(fixtures())) == len(GRAPHS) * len(GOLDEN_RS)


@pytest.mark.parametrize("fname", fixtures())
def test_golden_coreness_all_backends(fname):
    fx, p = _load(fname)
    if p.n_r == 0:
        pytest.skip("no r-cliques")
    want = np.asarray(fx["core"])
    base = NucleusConfig(hierarchy="none")
    for label, cfg in [
            ("gather", {"backend": "gather"}),
            ("dense", {"backend": "dense"}),
            ("pallas", {"backend": "dense", "use_pallas": True}),
    ]:
        dec = decompose(p, base, **cfg)
        np.testing.assert_array_equal(dec.core, want,
                                      err_msg=f"backend={label}")


@pytest.mark.parametrize("fname", fixtures())
def test_golden_hierarchy_all_backends(fname):
    fx, p = _load(fname)
    if p.n_r == 0:
        pytest.skip("no r-cliques")
    for label, hierarchy in [("replay", "replay"), ("fused", "fused"),
                             ("te", "two_phase"), ("bl", "basic")]:
        dec = decompose(p, NucleusConfig(backend="dense",
                                         hierarchy=hierarchy))
        _check_partitions(fx, dec, label)


@pytest.mark.parametrize("fname", fixtures())
def test_golden_sharded_backend(fname):
    fx, p = _load(fname)
    if p.n_r == 0:
        pytest.skip("no r-cliques")
    dec = decompose(p, NucleusConfig(backend="sharded", hierarchy="fused"))
    np.testing.assert_array_equal(dec.core, np.asarray(fx["core"]))
    _check_partitions(fx, dec)
