"""Regression tests for the NL201 recompile hazards nucleuslint surfaced
in the launch drivers (ISSUE 9): `launch/serve.py` and `launch/train.py`
rebuilt `jax.jit(partial(step, cfg=...))` on every driver invocation, so
a second call to the same driver re-traced the whole step.  The fix is
the `core/distributed._jitted_decomposition` pattern — module-level
`functools.lru_cache` factories keyed on the (hashable, frozen) configs.

These tests pin (a) the memoization — same config twice returns the SAME
compiled wrapper, different configs don't collide — and (b) that the
linter stays clean on the fixed files, so the hazard can't silently come
back.
"""
from __future__ import annotations

import os

from repro.analysis import load_project, run_analysis

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _nl201(path_suffix: str):
    project = load_project(
        [os.path.join(REPO, "src", "repro", "launch")], root=REPO)
    return [f for f in run_analysis(project)
            if f.rule == "NL201" and f.path.endswith(path_suffix)]


# ---------------------------------------------------------------------------
# serve.py: decode / DIN scoring step factories
# ---------------------------------------------------------------------------

def test_serve_step_factories_are_memoized():
    from repro.configs import get_arch
    from repro.launch.serve import _decode_step_fn, _din_serve_step_fn

    cfg = get_arch("minicpm-2b").make_smoke_config()
    assert _decode_step_fn(cfg) is _decode_step_fn(cfg)
    din = get_arch("din").make_smoke_config()
    assert _din_serve_step_fn(din) is _din_serve_step_fn(din)
    # distinct configs must not collide in the cache
    other = get_arch("stablelm-12b").make_smoke_config()
    assert _decode_step_fn(cfg) is not _decode_step_fn(other)


def test_serve_py_has_no_jit_per_call_findings():
    assert _nl201("launch/serve.py") == []


# ---------------------------------------------------------------------------
# train.py: train step factories (lm plain / lm microbatched / din)
# ---------------------------------------------------------------------------

def test_train_step_factories_are_memoized():
    from repro.configs import get_arch
    from repro.launch.train import _din_train_step_fn, _lm_train_step_fn
    from repro.optim import adamw

    cfg = get_arch("minicpm-2b").make_smoke_config()
    opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=30,
                            schedule="cosine")
    assert _lm_train_step_fn(cfg, opt, 1) is _lm_train_step_fn(cfg, opt, 1)
    # microbatch count is part of the key (different traced program)
    assert _lm_train_step_fn(cfg, opt, 1) is not _lm_train_step_fn(cfg, opt, 2)
    # a different optimizer schedule is a different step
    opt2 = adamw.AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=60,
                             schedule="cosine")
    assert _lm_train_step_fn(cfg, opt, 1) is not _lm_train_step_fn(cfg, opt2, 1)

    din = get_arch("din").make_smoke_config()
    opt3 = adamw.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=20,
                             schedule="cosine", weight_decay=0.0)
    assert _din_train_step_fn(din, opt3) is _din_train_step_fn(din, opt3)


def test_train_din_still_trains_through_cached_step():
    """Functional check through the memoized factory: two short runs share
    the cached jitted step and still learn (loss drops)."""
    from repro.launch.train import _din_train_step_fn, train_din

    before = _din_train_step_fn.cache_info().currsize
    r1 = train_din(steps=6, smoke=True, batch=64, quiet=True)
    r2 = train_din(steps=6, smoke=True, batch=64, quiet=True)
    after = _din_train_step_fn.cache_info()
    assert r1.steps_done == r2.steps_done == 6
    assert r1.losses[-1] < r1.losses[0] * 1.5   # sane, not diverging
    # the second run reused the first run's compiled step
    assert after.currsize == before + 1 and after.hits >= 1


def test_train_py_has_no_jit_per_call_findings():
    assert _nl201("launch/train.py") == []
