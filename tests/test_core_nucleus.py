"""Correctness of the paper's algorithms against definition-level oracles."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.graph import generators
# oracle/parity tests import the building blocks from their submodules —
# the deprecated package-level names are exercised (once) by test_facade.py
from repro.core import build_problem, same_partition, edge_density
from repro.core.peel import exact_coreness, approx_coreness
from repro.core.hierarchy import (build_hierarchy_levels,
                                  build_hierarchy_basic)
from repro.core.interleaved import build_hierarchy_interleaved
from repro.core.nh_baseline import (nh_coreness, nh_hierarchy,
                                    brute_force_coreness)
from repro.core.nuclei import cut_hierarchy, nuclei_without_hierarchy

GRAPHS = {
    "triangle": generators.tiny_named("triangle"),
    "k4": generators.tiny_named("k4"),
    "path4": generators.tiny_named("path4"),
    "two_triangles": generators.tiny_named("two_triangles"),
    "bowtie_plus": generators.tiny_named("bowtie_plus"),
    "fig1": generators.paper_figure1_like(),
    "er20": generators.erdos_renyi(20, 0.35, seed=1),
    "er30": generators.erdos_renyi(30, 0.25, seed=2),
    "planted": generators.planted_cliques(40, [8, 6, 5], 0.05, seed=3),
    "ba60": generators.barabasi_albert(60, 4, seed=4),
}
RS = [(1, 2), (2, 3), (1, 3), (3, 4), (2, 4)]


def problems():
    for gname, g in GRAPHS.items():
        for (r, s) in RS:
            yield pytest.param(gname, r, s, id=f"{gname}-r{r}s{s}")


@pytest.mark.parametrize("gname,r,s", problems())
def test_exact_coreness_matches_oracles(gname, r, s):
    p = build_problem(GRAPHS[gname], r, s)
    if p.n_r == 0:
        pytest.skip("no r-cliques")
    got = np.asarray(exact_coreness(p).core)
    seq, _ = nh_coreness(p)
    np.testing.assert_array_equal(got, seq)
    bf = brute_force_coreness(p)
    np.testing.assert_array_equal(got, bf)


@pytest.mark.parametrize("gname,r,s", problems())
def test_exact_coreness_dense_backend(gname, r, s):
    p = build_problem(GRAPHS[gname], r, s)
    if p.n_r == 0:
        pytest.skip("no r-cliques")
    g = np.asarray(exact_coreness(p, backend="gather").core)
    d = np.asarray(exact_coreness(p, backend="dense").core)
    np.testing.assert_array_equal(g, d)


@pytest.mark.parametrize("delta", [0.1, 0.5, 1.0])
@pytest.mark.parametrize("gname,r,s", problems())
def test_approx_coreness_bounds(gname, r, s, delta):
    from math import comb
    p = build_problem(GRAPHS[gname], r, s)
    if p.n_r == 0:
        pytest.skip("no r-cliques")
    exact = np.asarray(exact_coreness(p).core)
    approx = np.asarray(approx_coreness(p, delta=delta).core)
    C = comb(s, r)
    factor = (C + delta) * (1 + delta)
    assert (approx >= exact).all(), "estimate must be >= true core"
    ok = approx <= np.maximum(np.ceil(factor * exact), exact)
    assert ok.all(), (approx[~ok], exact[~ok], factor)


def _sample_pairs(n_r, rng, k=60):
    if n_r < 2:
        return np.zeros((0, 2), np.int64)
    a = rng.integers(0, n_r, size=k)
    b = rng.integers(0, n_r, size=k)
    return np.stack([a, b], axis=1)


@pytest.mark.parametrize("gname,r,s", problems())
def test_hierarchy_te_matches_nh(gname, r, s):
    p = build_problem(GRAPHS[gname], r, s)
    if p.n_r == 0:
        pytest.skip("no r-cliques")
    core = exact_coreness(p).core
    t_te = build_hierarchy_levels(p, core)
    t_nh = nh_hierarchy(p, np.asarray(core))
    rng = np.random.default_rng(0)
    pairs = _sample_pairs(p.n_r, rng)
    np.testing.assert_array_equal(t_te.join_levels(pairs),
                                  t_nh.join_levels(pairs))


@pytest.mark.parametrize("gname,r,s", problems())
def test_hierarchy_bl_matches_te(gname, r, s):
    p = build_problem(GRAPHS[gname], r, s)
    if p.n_r == 0:
        pytest.skip("no r-cliques")
    core = exact_coreness(p).core
    t_te = build_hierarchy_levels(p, core)
    t_bl = build_hierarchy_basic(p, core)
    rng = np.random.default_rng(1)
    pairs = _sample_pairs(p.n_r, rng)
    np.testing.assert_array_equal(t_te.join_levels(pairs),
                                  t_bl.join_levels(pairs))


@pytest.mark.parametrize("gname,r,s", problems())
def test_hierarchy_el_interleaved_matches_te(gname, r, s):
    p = build_problem(GRAPHS[gname], r, s)
    if p.n_r == 0:
        pytest.skip("no r-cliques")
    res = build_hierarchy_interleaved(p, mode="exact")
    core = exact_coreness(p).core
    np.testing.assert_array_equal(np.asarray(res.core), np.asarray(core))
    t_te = build_hierarchy_levels(p, core)
    rng = np.random.default_rng(2)
    pairs = _sample_pairs(p.n_r, rng)
    np.testing.assert_array_equal(res.tree.join_levels(pairs),
                                  t_te.join_levels(pairs))


@pytest.mark.parametrize("gname,r,s", problems())
def test_chain_reduction_equivalent_to_all_pairs(gname, r, s):
    p = build_problem(GRAPHS[gname], r, s)
    if p.n_r == 0:
        pytest.skip("no r-cliques")
    core = exact_coreness(p).core
    t_chain = build_hierarchy_levels(p, core, chain=True)
    t_full = build_hierarchy_levels(p, core, chain=False)
    rng = np.random.default_rng(3)
    pairs = _sample_pairs(p.n_r, rng)
    np.testing.assert_array_equal(t_chain.join_levels(pairs),
                                  t_full.join_levels(pairs))


@pytest.mark.parametrize("gname", ["fig1", "planted", "er20"])
@pytest.mark.parametrize("r,s", [(1, 2), (2, 3), (1, 3)])
def test_cut_hierarchy_matches_connectivity(gname, r, s):
    p = build_problem(GRAPHS[gname], r, s)
    if p.n_r == 0:
        pytest.skip("no r-cliques")
    core = exact_coreness(p).core
    tree = build_hierarchy_levels(p, core)
    kmax = int(np.asarray(core).max())
    for c in range(1, kmax + 1):
        via_tree = cut_hierarchy(tree, c)
        via_cc = nuclei_without_hierarchy(p, core, c)
        assert same_partition(via_tree, via_cc), f"c={c}"


def test_k_core_special_case():
    """(1,2) nucleus == classic k-core; verify against a hand example."""
    g = generators.tiny_named("bowtie_plus")
    p = build_problem(g, 1, 2)
    core = np.asarray(exact_coreness(p).core)
    # two K4s joined by one edge: every vertex has k-core number 3
    np.testing.assert_array_equal(core, np.full(8, 3))


def test_k_truss_special_case():
    """(2,3) nucleus: triangle counts per edge in a K4 are 2."""
    g = generators.tiny_named("k4")
    p = build_problem(g, 2, 3)
    core = np.asarray(exact_coreness(p).core)
    np.testing.assert_array_equal(core, np.full(6, 2))


def _edge_density_bruteforce(g_edges, vertices):
    """Definition-level oracle: the per-edge Python set scan the vectorized
    ``edge_density`` replaced."""
    k = len(vertices)
    if k < 2:
        return 0.0
    vs = set(int(x) for x in vertices)
    inside = sum(1 for u, v in g_edges if int(u) in vs and int(v) in vs)
    return inside / (k * (k - 1) / 2)


def test_edge_density_matches_bruteforce():
    rng = np.random.default_rng(7)
    g = generators.erdos_renyi(30, 0.2, seed=9)
    edges = np.asarray(g.edges)
    for k in [0, 1, 2, 5, 13, 30]:
        for trial in range(4):
            vs = rng.choice(30, size=k, replace=False)
            got = edge_density(edges, vs)
            want = _edge_density_bruteforce(edges, vs)
            assert got == pytest.approx(want), (k, trial)
    # empty edge array
    assert edge_density(np.zeros((0, 2), np.int64), np.arange(5)) == 0.0


def test_fig1_like_hierarchy_structure():
    """The fig1-like graph must produce a nested multi-level hierarchy."""
    g = generators.paper_figure1_like()
    p = build_problem(g, 1, 3)
    core = exact_coreness(p).core
    tree = build_hierarchy_levels(p, core)
    assert tree.n_internal >= 2, "expected nested structure"
    lv = tree.level[tree.n_leaves:]
    assert (np.diff(np.sort(lv)) >= 0).all()
    # roots exist and levels of internal nodes are valid core values
    assert (tree.parent == -1).sum() >= 1
