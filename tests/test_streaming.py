"""Incremental ``update(delta)`` for live graphs (DESIGN.md §10).

Contracts:
  * PARITY — after any supported insert/delete sequence,
    ``Decomposition.update`` is array-for-array identical to a fresh
    ``decompose()`` of the edited graph: core, peel_value, the fused
    join forest, the hierarchy tree, and cut labels.  Holds for the
    r1s2 fast lane and for (2, 3) through the generic engine, under
    randomized op sequences.
  * DELTA — ``GraphDelta`` canonicalizes (u, v) order, rejects
    self-loops, and is strict about insert-present / delete-absent.
  * ERRORS — approx artifacts, unsupported (r, s), non-fused
    hierarchies, and problem-less (deserialized) artifacts fail with
    actionable messages instead of corrupting state.
"""
import numpy as np
import pytest

from repro.core import GraphDelta, NucleusConfig, decompose
from repro.core.streaming import SUPPORTED_RS
from repro.graph import make_graph
from repro.graph.generators import golden_suite

pytestmark = pytest.mark.fast

GRAPHS = golden_suite()


def _edge_set(g):
    return {tuple(r) for r in np.asarray(g.edges).tolist()}


def _absent_pairs(g, rng, k):
    present = _edge_set(g)
    out = []
    for u in range(g.n):
        for v in range(u + 1, g.n):
            if (u, v) not in present:
                out.append((u, v))
    rng.shuffle(out)
    return out[:k]


def _assert_matches_fresh(dec, cfg, label):
    """The updated artifact vs a fresh decompose of its own graph."""
    fresh = decompose(dec.problem.g, cfg)
    np.testing.assert_array_equal(np.asarray(dec.core),
                                  np.asarray(fresh.core),
                                  err_msg=f"{label}: core")
    np.testing.assert_array_equal(np.asarray(dec.peel_value),
                                  np.asarray(fresh.peel_value),
                                  err_msg=f"{label}: peel_value")
    if cfg.hierarchy == "fused":
        np.testing.assert_array_equal(np.asarray(dec.uf_parent),
                                      np.asarray(fresh.uf_parent),
                                      err_msg=f"{label}: uf_parent")
        np.testing.assert_array_equal(np.asarray(dec.uf_L),
                                      np.asarray(fresh.uf_L),
                                      err_msg=f"{label}: uf_L")
        np.testing.assert_array_equal(np.asarray(dec.tree.parent),
                                      np.asarray(fresh.tree.parent),
                                      err_msg=f"{label}: tree parent")
        np.testing.assert_array_equal(np.asarray(dec.tree.level),
                                      np.asarray(fresh.tree.level),
                                      err_msg=f"{label}: tree level")
        kmax = int(np.asarray(fresh.core).max(initial=0))
        for c in {1, max(kmax, 1)}:
            np.testing.assert_array_equal(dec.cut(c), fresh.cut(c),
                                          err_msg=f"{label}: cut({c})")


# ---------------------------------------------------------------------------
# GraphDelta
# ---------------------------------------------------------------------------

def test_graphdelta_canonicalizes_and_orders_ops():
    d = GraphDelta(insert=np.array([[5, 2]]), delete=np.array([[1, 0]]))
    np.testing.assert_array_equal(d.insert, [[2, 5]])
    np.testing.assert_array_equal(d.delete, [[0, 1]])
    assert d.n_ops == 2
    # deletes drain before inserts: freed capacity first, strictness after
    assert [op for op, _, _ in d.ops()] == ["delete", "insert"]


def test_graphdelta_rejects_self_loops():
    with pytest.raises(ValueError, match="self-loop"):
        GraphDelta(insert=np.array([[3, 3]]))
    with pytest.raises(ValueError, match="self-loop"):
        GraphDelta(delete=np.array([[0, 0]]))


def test_update_rejects_drifted_view():
    g = GRAPHS["two_triangles"]()
    cfg = NucleusConfig(r=1, s=2, backend="dense", hierarchy="fused")
    dec = decompose(g, cfg)
    present = next(iter(_edge_set(g)))
    with pytest.raises(ValueError, match="insert of present edge"):
        dec.update(GraphDelta(insert=np.array([present])))
    absent = _absent_pairs(g, np.random.default_rng(0), 1)[0]
    with pytest.raises(ValueError, match="delete of absent edge"):
        dec.update(GraphDelta(delete=np.array([absent])))
    with pytest.raises(ValueError, match="out of range"):
        dec.update(GraphDelta(insert=np.array([[0, g.n]])))


# ---------------------------------------------------------------------------
# Parity vs fresh decompose()
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("r,s", sorted(SUPPORTED_RS))
@pytest.mark.parametrize("gname", ["bowtie_plus", "er20"])
def test_update_parity_randomized(gname, r, s):
    """Randomized insert/delete sequence, parity checked after every
    delta — the artifact is maintained through the whole stream, not
    just one hop."""
    rng = np.random.default_rng(7)
    cfg = NucleusConfig(r=r, s=s, backend="dense", hierarchy="fused")
    g = GRAPHS[gname]()
    dec = decompose(g, cfg)
    for step in range(6):
        g = dec.problem.g
        present = sorted(_edge_set(g))
        absent = _absent_pairs(g, rng, 1)
        # keep the graph editable in both directions
        if absent and (rng.random() < 0.5 or len(present) <= 2):
            delta = GraphDelta(insert=np.array([absent[0]]))
        else:
            pair = present[rng.integers(len(present))]
            delta = GraphDelta(delete=np.array([pair]))
        dec = dec.update(delta)
        assert dec.rounds == -1 and dec.order_round is None
        _assert_matches_fresh(dec, cfg, f"{gname} r{r}s{s} step{step}")


@pytest.mark.parametrize("r,s", sorted(SUPPORTED_RS))
def test_update_batched_delta_mixed_ops(r, s):
    rng = np.random.default_rng(3)
    cfg = NucleusConfig(r=r, s=s, backend="dense", hierarchy="fused")
    g = GRAPHS["fig1"]()
    dec = decompose(g, cfg)
    dels = sorted(_edge_set(g))[:2]
    ins = _absent_pairs(g, rng, 2)
    delta = GraphDelta(insert=np.array(ins), delete=np.array(dels))
    dec = dec.update(delta)
    assert dec.update_stats.ops == delta.n_ops == 4
    _assert_matches_fresh(dec, cfg, f"batched r{r}s{s}")


def test_update_without_hierarchy():
    cfg = NucleusConfig(r=2, s=3, backend="dense", hierarchy="none")
    g = GRAPHS["two_triangles"]()
    dec = decompose(g, cfg)
    pair = _absent_pairs(g, np.random.default_rng(1), 1)[0]
    dec = dec.update(GraphDelta(insert=np.array([pair])))
    assert dec.uf_parent is None
    _assert_matches_fresh(dec, cfg, "no-hierarchy")


def test_update_insert_delete_roundtrip_restores_core():
    cfg = NucleusConfig(r=1, s=2, backend="dense", hierarchy="fused")
    g = GRAPHS["er20"]()
    dec0 = decompose(g, cfg)
    pair = _absent_pairs(g, np.random.default_rng(2), 1)[0]
    dec1 = dec0.update(GraphDelta(insert=np.array([pair])))
    dec2 = dec1.update(GraphDelta(delete=np.array([pair])))
    np.testing.assert_array_equal(np.asarray(dec2.core),
                                  np.asarray(dec0.core))
    np.testing.assert_array_equal(np.asarray(dec2.uf_parent),
                                  np.asarray(dec0.uf_parent))
    np.testing.assert_array_equal(np.asarray(dec2.uf_L),
                                  np.asarray(dec0.uf_L))


def test_update_localizes_small_edits():
    """The telemetry contract behind the stream bench: an edit in a
    low-core region never floods across a higher-core bottleneck — the
    K8's vertices are not candidates when the pendant path changes."""
    cfg = NucleusConfig(r=1, s=2, backend="dense", hierarchy="none")
    k8 = [[i, j] for i in range(8) for j in range(i + 1, 8)]
    dec = decompose(make_graph(11, np.array(k8 + [[8, 9], [9, 10]])), cfg)
    dec = dec.update(GraphDelta(insert=np.array([[8, 10]])))
    stats = dec.update_stats
    assert stats.candidates <= 3, stats  # the path triangle, not the K8
    _assert_matches_fresh(dec, cfg, "pendant-insert")


# ---------------------------------------------------------------------------
# Error paths
# ---------------------------------------------------------------------------

def test_update_requires_exact_method():
    cfg = NucleusConfig(r=2, s=3, method="approx", delta=0.25,
                        backend="dense", hierarchy="none")
    dec = decompose(GRAPHS["two_triangles"](), cfg)
    with pytest.raises(ValueError, match="exact"):
        dec.update(GraphDelta(insert=np.array([[0, 5]])))


def test_update_requires_supported_rs():
    cfg = NucleusConfig(r=3, s=4, backend="dense", hierarchy="none")
    dec = decompose(GRAPHS["planted40"](), cfg)
    with pytest.raises(ValueError, match=r"\(r, s\)"):
        dec.update(GraphDelta(insert=np.array([[0, 1]])))


def test_update_requires_fused_or_no_hierarchy():
    cfg = NucleusConfig(r=2, s=3, backend="dense", hierarchy="replay")
    dec = decompose(GRAPHS["two_triangles"](), cfg)
    with pytest.raises(ValueError, match="fused"):
        dec.update(GraphDelta(insert=np.array([[0, 5]])))


def test_update_requires_attached_problem():
    from repro.core.api import Decomposition
    cfg = NucleusConfig(r=2, s=3, backend="dense", hierarchy="fused")
    dec = decompose(GRAPHS["two_triangles"](), cfg)
    reloaded = Decomposition.from_json(dec.to_json())
    with pytest.raises(ValueError, match="re-decompose"):
        reloaded.update(GraphDelta(insert=np.array([[0, 5]])))
