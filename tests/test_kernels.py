"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# tricount
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,p,tile", [
    (64, 0.2, 32), (100, 0.1, 32), (128, 0.3, 64), (200, 0.05, 128),
    (256, 0.15, 128),
])
def test_tricount_matches_ref(n, p, tile):
    rng = np.random.default_rng(n)
    a = (rng.random((n, n)) < p).astype(np.float32)
    a = np.triu(a, 1)
    a = a + a.T
    got = ops.tricount(jnp.asarray(a), tile=tile)
    want = ref.tricount_per_edge_ref(jnp.asarray(a))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0)


@pytest.mark.parametrize("n", [31, 32, 33, 63, 64, 65, 127, 129, 1])
def test_tricount_arbitrary_n_pads_to_tile(n):
    """The wrapper pads to the tile boundary itself (n = tile ± 1 included);
    pad rows are masked out by the zero adjacency tile."""
    tile = 32 if n < 127 else 128
    rng = np.random.default_rng(n)
    a = (rng.random((n, n)) < 0.3).astype(np.float32)
    a = np.triu(a, 1)
    a = a + a.T
    got = ops.tricount(jnp.asarray(a), tile=tile)
    want = ref.tricount_per_edge_ref(jnp.asarray(a))
    assert got.shape == (n, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0)


@pytest.mark.parametrize("n,tile", [(31, 32), (33, 32), (64, 32), (65, 64),
                                    (100, 64)])
def test_tricount_oriented_matches_ref(n, tile):
    """(D @ Dᵀ) ⊙ D on an oriented DAG adjacency — the chunked (2,3)
    builder's count pass — kernel vs jnp oracle at awkward n."""
    rng = np.random.default_rng(n + 1000)
    a = np.triu((rng.random((n, n)) < 0.25), 1).astype(np.float32)  # DAG
    got = ops.tricount_oriented(jnp.asarray(a), tile=tile)
    want = ref.tricount_oriented_ref(jnp.asarray(a))
    assert got.shape == (n, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0)


def test_tricount_oriented_counts_triangles_once():
    """Summing per-DAG-edge extension counts gives each triangle exactly
    once (vs /6 for the symmetric kernel)."""
    from repro.graph import generators, count_cliques
    from repro.core.incidence import pick_rank
    g = generators.erdos_renyi(50, 0.2, seed=11)
    dg, _ = pick_rank(g)
    n = g.n
    a = np.zeros((n, n), np.float32)
    src = np.repeat(np.arange(n), np.asarray(dg.outdeg))
    a[src, np.asarray(dg.neighbors)] = 1.0
    per_edge = ops.tricount_oriented(jnp.asarray(a))
    assert int(np.round(float(jnp.sum(per_edge)))) == count_cliques(g, 3)


def test_tricount_agrees_with_clique_counter():
    """Kernel vs the repo's own 3-clique enumerator."""
    from repro.graph import generators, count_cliques
    g = generators.erdos_renyi(80, 0.15, seed=7)
    n = g.n
    a = np.zeros((n, n), np.float32)
    e = np.asarray(g.edges)
    a[e[:, 0], e[:, 1]] = 1
    a[e[:, 1], e[:, 0]] = 1
    per_edge = ops.tricount(jnp.asarray(a))
    assert int(np.round(float(jnp.sum(per_edge)) / 6)) == count_cliques(g, 3)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,Sq,Sk,D,bq,bk", [
    (1, 1, 64, 64, 32, 32, 32),
    (2, 3, 128, 128, 64, 64, 64),
    (1, 2, 96, 96, 64, 32, 32),      # padding path (96 % 64 != 0)
    (2, 1, 128, 128, 128, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(B, H, Sq, Sk, D, bq, bk, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, H, Sq, D)), dtype)
    k = jnp.asarray(rng.standard_normal((B, H, Sk, D)), dtype)
    v = jnp.asarray(rng.standard_normal((B, H, Sk, D)), dtype)
    got = ops.attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    want = ref.attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


def test_flash_attention_noncausal():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 2, 64, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 64, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 64, 32)), jnp.float32)
    got = ops.attention(q, k, v, causal=False, block_q=32, block_k=32)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


def test_flash_matches_model_online_attention():
    """The model's scan-based online attention == the Pallas kernel."""
    from repro.models.transformer import online_attention
    rng = np.random.default_rng(2)
    B, S, H, D = 2, 64, 4, 32
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    valid = jnp.full((B,), S, jnp.int32)
    a = online_attention(q, k, v, pos, valid, causal=True, chunk=16)
    b = ops.attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                      v.transpose(0, 2, 1, 3), causal=True,
                      block_q=32, block_k=32).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=2e-5)


# ---------------------------------------------------------------------------
# segment sum
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("E,d,N,bn,ce", [
    (512, 16, 100, 32, 64),
    (1000, 32, 300, 64, 128),
    (2048, 8, 64, 64, 256),       # few segments, long runs
    (300, 64, 1000, 128, 128),    # many empty segments
])
def test_segment_sum_matches_ref(E, d, N, bn, ce):
    rng = np.random.default_rng(E + N)
    ids = np.sort(rng.integers(0, N, E)).astype(np.int32)
    data = rng.standard_normal((E, d)).astype(np.float32)
    got = ops.segment_sum(jnp.asarray(data), jnp.asarray(ids), N,
                          block_n=bn, chunk_e=ce)
    want = ref.segment_sum_ref(jnp.asarray(data), jnp.asarray(ids), N)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4,
                               rtol=1e-4)


def test_segment_sum_skewed_degrees():
    """Power-law-ish segment sizes (one giant segment)."""
    rng = np.random.default_rng(5)
    E, d, N = 1024, 16, 128
    ids = np.concatenate([np.zeros(700, np.int32),
                          np.sort(rng.integers(1, N, E - 700)).astype(np.int32)])
    data = rng.standard_normal((E, d)).astype(np.float32)
    got = ops.segment_sum(jnp.asarray(data), jnp.asarray(ids), N,
                          block_n=32, chunk_e=64)
    want = ref.segment_sum_ref(jnp.asarray(data), jnp.asarray(ids), N)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3,
                               rtol=1e-3)


def test_segment_sum_hypothesis():
    pytest.importorskip("hypothesis")  # optional dep: skip, don't fail
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 400).map(lambda e: e),
           st.integers(1, 50), st.integers(2, 200), st.integers(0, 10_000))
    def inner(E, d, N, seed):
        rng = np.random.default_rng(seed)
        ids = np.sort(rng.integers(0, N, E)).astype(np.int32)
        data = rng.standard_normal((E, d)).astype(np.float32)
        got = ops.segment_sum(jnp.asarray(data), jnp.asarray(ids), N,
                              block_n=32, chunk_e=64)
        want = ref.segment_sum_ref(jnp.asarray(data), jnp.asarray(ids), N)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-3, rtol=1e-3)

    inner()
