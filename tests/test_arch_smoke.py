"""Per-arch smoke tests: REDUCED configs, one real forward/train step on CPU,
asserting output shapes and finiteness — as the assignment requires."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_arch, all_archs, ALL_ARCH_IDS
from repro.launch import steps as S
from repro.models import transformer as T
from repro.models import din as DIN
from repro.optim import adamw
from repro.data import synthetic_molecules

LM_ARCHS = [a for a, s in all_archs().items() if s.family == "lm"]
GNN_ARCHS = [a for a, s in all_archs().items() if s.family == "gnn"]


def _finite(tree):
    return all(bool(jnp.isfinite(x.astype(jnp.float32)).all())
               for x in jax.tree.leaves(tree)
               if hasattr(x, "dtype") and jnp.issubdtype(x.dtype,
                                                         jnp.floating))


@pytest.mark.parametrize("arch", sorted(LM_ARCHS))
def test_lm_smoke_train_step(arch):
    spec = get_arch(arch)
    cfg = spec.make_smoke_config()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init_state(params)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    B, Sq = 2, 16
    batch = {"tokens": jnp.zeros((B, Sq), jnp.int32) + 1,
             "labels": jnp.zeros((B, Sq), jnp.int32) + 2}
    params2, opt2, metrics = S.lm_train_step(params, opt, batch, cfg, opt_cfg)
    assert jnp.isfinite(metrics["loss"])
    assert _finite(params2)
    # params actually moved
    delta = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), params, params2)
    assert max(jax.tree.leaves(delta)) > 0


@pytest.mark.parametrize("arch", sorted(LM_ARCHS))
def test_lm_smoke_decode_step(arch):
    spec = get_arch(arch)
    cfg = spec.make_smoke_config()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    B, max_len = 2, 8
    cache = T.init_cache(cfg, B, max_len)
    toks = jnp.ones((B, 1), jnp.int32)
    nxt, cache2, nl = S.lm_decode_step(params, toks, cache, jnp.int32(0), cfg)
    assert nxt.shape == (B,)
    assert int(nl) == 1
    assert _finite(cache2)


def _gnn_node_batch(spec, cfg, N=40, E=120, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "nodes": jnp.asarray(rng.standard_normal((N, cfg.d_in)), jnp.float32),
        "edge_src": jnp.asarray(rng.integers(0, N, E), jnp.int32),
        "edge_dst": jnp.asarray(rng.integers(0, N, E), jnp.int32),
        "node_mask": jnp.ones((N,), bool),
        "edge_mask": jnp.ones((E,), bool),
        "graph_id": jnp.arange(N, dtype=jnp.int32),
        "labels": jnp.asarray(rng.integers(0, 3, N), jnp.int32),
        "label_mask": jnp.ones((N,), jnp.float32),
    }
    if spec.arch_id in ("egnn", "mace", "dimenet"):
        batch["pos"] = jnp.asarray(rng.standard_normal((N, 3)), jnp.float32)
    if spec.arch_id == "dimenet":
        from repro.models.gnn_common import build_triplets
        kj, ji, m = build_triplets(np.asarray(batch["edge_src"]),
                                   np.asarray(batch["edge_dst"]), N,
                                   cap_per_edge=4)
        batch["triplet_kj"] = jnp.asarray(kj)
        batch["triplet_ji"] = jnp.asarray(ji)
        batch["triplet_mask"] = jnp.asarray(m)
    return batch


@pytest.mark.parametrize("arch", sorted(GNN_ARCHS))
def test_gnn_smoke_node_train_step(arch):
    """Node-level task (full_graph shapes) on the reduced config."""
    spec = get_arch(arch)
    cfg = spec.make_smoke_config()
    # adapt output head for 3 classes (what the launcher does per cell)
    kw = dict(cfg.__dict__)
    if "n_classes" in kw:
        kw["n_classes"] = 3
        kw["graph_level"] = False
    if "n_out" in kw:
        kw["n_out"] = 3
    cfg = cfg.__class__(**kw)
    mod = S._GNN[arch]
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init_state(params)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10,
                                weight_decay=0.0)
    batch = _gnn_node_batch(spec, cfg)
    N = batch["nodes"].shape[0]
    p2, o2, metrics = S.gnn_train_step(params, opt, batch, cfg, arch,
                                       n_graphs=N, node_level=True,
                                       opt_cfg=opt_cfg)
    assert jnp.isfinite(metrics["loss"]), arch
    assert _finite(p2), arch


@pytest.mark.parametrize("arch", sorted(GNN_ARCHS))
def test_gnn_smoke_molecule_train_step(arch):
    spec = get_arch(arch)
    cfg = spec.make_smoke_config()
    m = synthetic_molecules(4, 8, 16, cfg.d_in, seed=1, triplet_cap=4)
    batch = {
        "nodes": jnp.asarray(m["nodes"]),
        "edge_src": jnp.asarray(m["edge_src"]),
        "edge_dst": jnp.asarray(m["edge_dst"]),
        "node_mask": jnp.ones((m["nodes"].shape[0],), bool),
        "edge_mask": jnp.ones((m["edge_src"].shape[0],), bool),
        "graph_id": jnp.asarray(m["graph_id"]),
        "energy": jnp.asarray(m["energy"])[:, None],
    }
    if spec.arch_id in ("egnn", "mace", "dimenet"):
        batch["pos"] = jnp.asarray(m["pos"])
    if spec.arch_id == "dimenet":
        kj, ji, msk = m["triplets"]
        batch["triplet_kj"] = jnp.asarray(kj)
        batch["triplet_ji"] = jnp.asarray(ji)
        batch["triplet_mask"] = jnp.asarray(msk)
    mod = S._GNN[arch]
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init_state(params)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10,
                                weight_decay=0.0)
    p2, o2, metrics = S.gnn_train_step(params, opt, batch, cfg, arch,
                                       n_graphs=m["n_graphs"],
                                       node_level=False, opt_cfg=opt_cfg)
    assert jnp.isfinite(metrics["loss"]), arch
    assert _finite(p2), arch


def test_din_smoke_train_and_serve():
    spec = get_arch("din")
    cfg = spec.make_smoke_config()
    params = DIN.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init_state(params)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10,
                                weight_decay=0.0)
    rng = np.random.default_rng(0)
    B, Sq = 8, cfg.seq_len
    hist = rng.integers(0, cfg.n_items, (B, Sq)).astype(np.int32)
    batch = {
        "hist_items": jnp.asarray(hist),
        "hist_cates": jnp.asarray(hist % cfg.n_cates),
        "cand_item": jnp.asarray(rng.integers(0, cfg.n_items, B), jnp.int32),
        "cand_cate": jnp.asarray(rng.integers(0, cfg.n_cates, B), jnp.int32),
        "user_id": jnp.asarray(rng.integers(0, cfg.n_user_feats, B),
                               jnp.int32),
        "label": jnp.asarray(rng.random(B) < 0.5, jnp.float32),
    }
    p2, o2, metrics = S.din_train_step(params, opt, batch, cfg, opt_cfg)
    assert jnp.isfinite(metrics["loss"])
    serve = dict(batch)
    serve.pop("label")
    scores = S.din_serve_step(p2, serve, cfg)
    assert scores.shape == (B,)
    assert bool(jnp.isfinite(scores).all())
    assert bool(((scores >= 0) & (scores <= 1)).all())


def test_nucleus_smoke():
    """The paper's own config: sharded decomposition on the host mesh
    matches the reference exact peeling."""
    from repro.graph import generators
    from repro.core import build_problem, decompose, NucleusConfig
    g = generators.planted_cliques(30, [6, 5], 0.08, seed=0)
    p = build_problem(g, 2, 3)
    sharded = decompose(p, NucleusConfig(backend="sharded",
                                         hierarchy="none"))
    want = decompose(p, NucleusConfig(backend="gather", hierarchy="none"))
    np.testing.assert_array_equal(sharded.core, want.core)


def test_every_assigned_arch_is_registered():
    want = {"stablelm-12b", "minicpm-2b", "minitron-4b",
            "moonshot-v1-16b-a3b", "deepseek-v2-lite-16b",
            "dimenet", "gin-tu", "mace", "egnn", "din"}
    assert want <= set(ALL_ARCH_IDS)
    # 40 assigned cells: 10 archs x 4 shapes
    n_cells = sum(len(get_arch(a).shapes) for a in want)
    assert n_cells == 40
