"""Hypothesis property tests on the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't abort collection
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.graph import generators, make_graph, connected_components, INT
from repro.core import build_problem, same_partition
from repro.core.peel import exact_coreness, approx_coreness
from repro.core.hierarchy import build_hierarchy_levels
from repro.core.interleaved import build_hierarchy_interleaved
from repro.core.nh_baseline import nh_coreness, nh_hierarchy
from repro.core.nuclei import cut_hierarchy, nuclei_without_hierarchy

import jax.numpy as jnp

pytestmark = pytest.mark.slow  # hypothesis lane: full-suite job only

SETTINGS = dict(max_examples=15, deadline=None,
                suppress_health_check=[HealthCheck.too_slow])


def _random_graph(n, m, seed):
    rng = np.random.default_rng(seed)
    if m == 0:
        return make_graph(n, np.zeros((0, 2), np.int64))
    e = rng.integers(0, n, size=(m, 2))
    return make_graph(n, e)


@settings(**SETTINGS)
@given(st.integers(4, 24), st.integers(0, 80), st.integers(0, 10**6),
       st.sampled_from([(1, 2), (2, 3), (1, 3)]))
def test_exact_matches_sequential_oracle(n, m, seed, rs):
    g = _random_graph(n, m, seed)
    p = build_problem(g, *rs)
    if p.n_r == 0:
        return
    got = np.asarray(exact_coreness(p).core)
    want, _ = nh_coreness(p)
    np.testing.assert_array_equal(got, want)


@settings(**SETTINGS)
@given(st.integers(6, 20), st.integers(5, 60), st.integers(0, 10**6))
def test_coreness_monotone_under_edge_addition(n, m, seed):
    """Adding edges never decreases any surviving edge's (2,3) core number."""
    rng = np.random.default_rng(seed)
    e = rng.integers(0, n, size=(m, 2))
    g1 = make_graph(n, e[: m // 2])
    g2 = make_graph(n, e)
    p1 = build_problem(g1, 2, 3)
    p2 = build_problem(g2, 2, 3)
    if p1.n_r == 0:
        return
    c1 = np.asarray(exact_coreness(p1).core)
    c2 = np.asarray(exact_coreness(p2).core)
    r1 = np.asarray(p1.r_cliques)
    r2 = np.asarray(p2.r_cliques)
    # map each r-clique of g1 into g2's table
    lut = {tuple(row): i for i, row in enumerate(r2)}
    for i, row in enumerate(r1):
        j = lut.get(tuple(row))
        assert j is not None
        assert c2[j] >= c1[i], (row, c1[i], c2[j])


@settings(**SETTINGS)
@given(st.integers(5, 20), st.integers(0, 60), st.integers(0, 10**6),
       st.sampled_from([0.1, 0.5, 1.0]))
def test_approx_bounds_hold(n, m, seed, delta):
    from math import comb
    g = _random_graph(n, m, seed)
    p = build_problem(g, 2, 3)
    if p.n_r == 0:
        return
    e = np.asarray(exact_coreness(p).core)
    a = np.asarray(approx_coreness(p, delta=delta).core)
    factor = (comb(3, 2) + delta) * (1 + delta)
    assert (a >= e).all()
    assert (a <= np.maximum(np.ceil(factor * e), e)).all()


@settings(**SETTINGS)
@given(st.integers(5, 18), st.integers(0, 50), st.integers(0, 10**6))
def test_interleaved_tree_matches_two_phase(n, m, seed):
    g = _random_graph(n, m, seed)
    p = build_problem(g, 2, 3)
    if p.n_r == 0:
        return
    res = build_hierarchy_interleaved(p)
    core = exact_coreness(p).core
    t_te = build_hierarchy_levels(p, core)
    rng = np.random.default_rng(seed)
    k = min(40, p.n_r * p.n_r)
    pairs = np.stack([rng.integers(0, p.n_r, k),
                      rng.integers(0, p.n_r, k)], axis=1)
    np.testing.assert_array_equal(res.tree.join_levels(pairs),
                                  t_te.join_levels(pairs))


@settings(**SETTINGS)
@given(st.integers(5, 18), st.integers(0, 50), st.integers(0, 10**6),
       st.integers(0, 10**6), st.sampled_from(["exact", "approx"]))
def test_fused_tree_cut_matches_no_hierarchy(n, m, seed, cut_seed, mode):
    """Cutting the fused on-device hierarchy at any level c induces exactly
    the partition that connectivity over {core >= c} computes from scratch
    (`nuclei_without_hierarchy`) — for both peel schedules.

    For the approximate schedule the hierarchy is built over the raw
    (unclipped) bucket values, so the baseline must see those same values.
    """
    g = _random_graph(n, m, seed)
    p = build_problem(g, 2, 3)
    if p.n_r == 0:
        return
    res = build_hierarchy_interleaved(p, mode=mode, backend="dense",
                                      link="fused")
    vals = res.state.core  # raw peel values (== core for exact)
    lo, hi = int(vals.min()), int(vals.max())
    c = lo + cut_seed % (hi - lo + 2)  # may exceed hi: empty cut is legal
    via_tree = cut_hierarchy(res.tree, c)
    via_cc = nuclei_without_hierarchy(p, jnp.asarray(vals, INT), c)
    assert same_partition(via_tree, via_cc), (c, via_tree, via_cc)


@settings(**SETTINGS)
@given(st.integers(2, 30), st.integers(0, 60), st.integers(0, 10**6))
def test_connectivity_matches_bfs(n, m, seed):
    g = _random_graph(n, m, seed)
    e = np.asarray(g.edges)
    labels = np.asarray(connected_components(
        n, jnp.asarray(e[:, 0], INT), jnp.asarray(e[:, 1], INT)))
    # BFS oracle
    adj = [[] for _ in range(n)]
    for u, v in e:
        adj[u].append(v)
        adj[v].append(u)
    want = -np.ones(n, np.int64)
    for s in range(n):
        if want[s] >= 0:
            continue
        stack, comp = [s], []
        want[s] = s
        while stack:
            x = stack.pop()
            comp.append(x)
            for y in adj[x]:
                if want[y] < 0:
                    want[y] = s
                    stack.append(y)
        mn = min(comp)
        for x in comp:
            want[x] = mn
    np.testing.assert_array_equal(labels, want)


@settings(**SETTINGS)
@given(st.integers(4, 16), st.integers(0, 40), st.integers(0, 10**6))
def test_hierarchy_tree_wellformed(n, m, seed):
    """Structural invariants: acyclic parents, monotone levels, leaves."""
    g = _random_graph(n, m, seed)
    p = build_problem(g, 1, 2)
    if p.n_r == 0:
        return
    core = exact_coreness(p).core
    t = build_hierarchy_levels(p, core)
    for i in range(t.n_nodes):
        par = t.parent[i]
        if par >= 0:
            assert par >= t.n_leaves            # parents are internal
            assert t.level[par] <= t.level[i]   # levels shrink upward
            assert par != i
    # every internal node has >= 2 children (TE construction invariant)
    from collections import Counter
    kids = Counter(t.parent[t.parent >= 0])
    for node, cnt in kids.items():
        assert cnt >= 2 or node < t.n_leaves
