"""Distributed nucleus decomposition + multi-device shard semantics.

The multi-device cases run in a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (device count locks at
first jax init, so it cannot change inside the main pytest process).
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax

from repro.graph import generators
from repro.core import build_problem
from repro.core.peel import exact_coreness, approx_coreness
from repro.core.distributed import sharded_decomposition
from repro.launch.mesh import make_host_mesh

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("gname,r,s", [
    ("planted", 2, 3), ("planted", 1, 2), ("ba", 2, 3), ("fig1", 1, 3),
])
def test_sharded_exact_matches_reference(gname, r, s):
    g = {"planted": generators.planted_cliques(40, [8, 6], 0.05, seed=1),
         "ba": generators.barabasi_albert(60, 4, seed=2),
         "fig1": generators.paper_figure1_like()}[gname]
    p = build_problem(g, r, s)
    core, rounds = sharded_decomposition(p, make_host_mesh(), kind="exact")
    np.testing.assert_array_equal(np.asarray(core),
                                  np.asarray(exact_coreness(p).core))


def test_sharded_approx_within_bounds():
    from math import comb
    g = generators.planted_cliques(40, [8, 6], 0.05, seed=3)
    p = build_problem(g, 2, 3)
    delta = 0.1
    core, rounds = sharded_decomposition(p, make_host_mesh(), kind="approx",
                                         delta=delta)
    e = np.asarray(exact_coreness(p).core)
    a = np.asarray(core)
    factor = (comb(3, 2) + delta) * (1 + delta)
    assert (a >= e).all()
    assert (a <= np.maximum(np.ceil(factor * e), e)).all()


_SUBPROC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax
    from repro.graph import generators
    from repro.core import build_problem
    from repro.core.peel import exact_coreness
    from repro.core.distributed import sharded_decomposition

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    g = generators.planted_cliques(40, [8, 6, 5], 0.05, seed=11)
    p = build_problem(g, 2, 3)
    core, rounds = sharded_decomposition(p, mesh, kind="exact")
    ref = exact_coreness(p).core
    print(json.dumps({
        "match": bool((np.asarray(core) == np.asarray(ref)).all()),
        "rounds": int(rounds),
        "n_devices": len(jax.devices()),
    }))
""")


@pytest.mark.slow
def test_sharded_decomposition_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", _SUBPROC_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["n_devices"] == 8
    assert res["match"], res


def test_sharded_hierarchy_matches_fused_single_device():
    """Default (1-device) mesh: the shard_map backend's fused forest equals
    the dense backend's, exactly (resolved parent + L at roots)."""
    g = generators.planted_cliques(40, [8, 6], 0.05, seed=5)
    p = build_problem(g, 2, 3)
    core, _r, parent, L, _raw = sharded_decomposition(
        p, make_host_mesh(), kind="exact", hierarchy=True)
    ref = exact_coreness(p, backend="dense", hierarchy=True)
    np.testing.assert_array_equal(np.asarray(core), np.asarray(ref.core))
    np.testing.assert_array_equal(np.asarray(parent),
                                  np.asarray(ref.uf_parent))
    roots = np.unique(np.asarray(parent))
    np.testing.assert_array_equal(np.asarray(L)[roots],
                                  np.asarray(ref.uf_L)[roots])


_SUBPROC_HIERARCHY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax
    from repro.graph import generators
    from repro.core import (build_problem, link_state_from_forest,
                            construct_tree_efficient)
    from repro.core.peel import exact_coreness, approx_coreness
    from repro.core.distributed import sharded_decomposition

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    g = generators.planted_cliques(40, [8, 6, 5], 0.05, seed=11)
    p = build_problem(g, 2, 3)
    out = {"n_devices": len(jax.devices())}
    rng = np.random.default_rng(0)
    pairs = np.stack([rng.integers(0, p.n_r, 60),
                      rng.integers(0, p.n_r, 60)], 1)
    for kind, peel in (("exact", exact_coreness), ("approx", approx_coreness)):
        core, rounds, parent, L, raw = sharded_decomposition(
            p, mesh, kind=kind, hierarchy=True)
        ref = peel(p, backend="dense", hierarchy=True)
        roots = np.unique(np.asarray(parent))
        # the tree is built ONLY from the distributed return (raw peel
        # values, not the clipped estimates) — self-contained by design
        t_sh = construct_tree_efficient(
            p, link_state_from_forest(raw, parent, L))
        t_ref = construct_tree_efficient(p, link_state_from_forest(
            ref.peel_value, ref.uf_parent, ref.uf_L))
        out[kind] = {
            "core": bool((np.asarray(core) == np.asarray(ref.core)).all()),
            "raw": bool((np.asarray(raw)
                         == np.asarray(ref.peel_value)).all()),
            "parent": bool((np.asarray(parent)
                            == np.asarray(ref.uf_parent)).all()),
            "L": bool((np.asarray(L)[roots]
                       == np.asarray(ref.uf_L)[roots]).all()),
            "joins": bool((t_sh.join_levels(pairs)
                           == t_ref.join_levels(pairs)).all()),
        }
    print(json.dumps(out))
""")


@pytest.mark.slow
def test_sharded_hierarchy_8_devices_matches_fused():
    """The distributed backend emits the SAME join forest as the fused
    single-device engine under a real 4x2 mesh (links all-gathered from
    device-local slabs, uf state replicated)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", _SUBPROC_HIERARCHY],
                         capture_output=True, text=True, env=env,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["n_devices"] == 8
    for kind in ("exact", "approx"):
        assert all(res[kind].values()), res


_SUBPROC_LM = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from functools import partial
    from repro.configs import get_arch
    from repro.distributed import sharding as shard_rules
    from repro.models import transformer as T
    from repro.optim import adamw
    from repro.launch import steps as S

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_arch("minicpm-2b").make_smoke_config()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rules = shard_rules.lm_param_rules(mesh, moe=False)
    p_sh = shard_rules.shard_tree(shard_rules.tree_specs(params, rules, mesh), mesh)
    params_sharded = jax.device_put(params, p_sh)
    opt = adamw.init_state(params_sharded)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=4)
    batch = {"tokens": jnp.ones((8, 16), jnp.int32),
             "labels": jnp.ones((8, 16), jnp.int32)}
    b_sh = {k: NamedSharding(mesh, P("data", None)) for k in batch}
    batch = jax.device_put(batch, b_sh)
    step = jax.jit(partial(S.lm_train_step, cfg=cfg, opt_cfg=opt_cfg))
    p1, o1, m1 = step(params_sharded, opt, batch)
    # single-device reference
    p1r, o1r, m1r = S.lm_train_step(params, adamw.init_state(params),
                                    jax.tree.map(lambda x: jax.device_put(x, jax.devices()[0]),
                                                 {"tokens": jnp.ones((8, 16), jnp.int32),
                                                  "labels": jnp.ones((8, 16), jnp.int32)}),
                                    cfg, opt_cfg)
    err = max(float(np.max(np.abs(np.asarray(a, dtype=np.float32)
                                  - np.asarray(b, dtype=np.float32))))
              for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p1r)))
    print(json.dumps({"loss_sharded": float(m1["loss"]),
                      "loss_ref": float(m1r["loss"]),
                      "max_param_err": err}))
""")


@pytest.mark.slow
def test_sharded_lm_train_step_matches_single_device():
    """FSDP+TP sharded step must be numerically identical to 1-device."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", _SUBPROC_LM],
                         capture_output=True, text=True, env=env,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert abs(res["loss_sharded"] - res["loss_ref"]) < 1e-4, res
    # f32 reduction order differs across shardings; AdamW's rsqrt amplifies
    # it slightly — 5e-4 on parameters is reduction-order noise
    assert res["max_param_err"] < 5e-4, res
