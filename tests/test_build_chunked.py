"""Chunked vs eager incidence builder: bit-identity + degenerate inputs.

The memory-bounded chunked builder (DESIGN.md §7) must be byte-identical to
the eager one on every array of the ``NucleusProblem`` — r-clique table,
incidence ids, mem-CSR, initial degrees — for every chunk size, including
the degenerate chunks (empty graphs, r-clique-free seed ranges) that exposed
the ``sort_join``/T=0 and tile-alignment bugs this suite pins.
"""
import json
import os

import numpy as np
import pytest
import jax.numpy as jnp

from repro.graph import generators
from repro.graph.cliques import (iter_clique_chunks, list_cliques, sort_join,
                                 sort_join_np)
from repro.graph.orientation import degree_rank
from repro.core import decompose, NucleusConfig, canonicalize_labels
from repro.core.incidence import (build_problem, pick_rank,
                                  _derive_chunk_size)

pytestmark = pytest.mark.fast

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "golden")

GRAPHS = {
    "bowtie_plus": generators.tiny_named("bowtie_plus"),
    "er20": generators.erdos_renyi(20, 0.35, seed=1),
    "planted": generators.planted_cliques(40, [8, 6, 5], 0.05, seed=3),
    "ba60": generators.barabasi_albert(60, 4, seed=4),
    "empty10": generators.erdos_renyi(10, 0.0, seed=0),
}
RS = [(1, 2), (2, 3), (2, 4), (3, 4)]
ARRAYS = ("r_cliques", "inc_rid", "mem_offsets", "mem_sids", "deg0")

_EAGER = {}


def _eager(gname, r, s):
    key = (gname, r, s)
    if key not in _EAGER:
        _EAGER[key] = build_problem(GRAPHS[gname], r, s)
    return _EAGER[key]


def assert_problems_identical(e, c):
    assert e.orientation == c.orientation
    for f in ARRAYS:
        a, b = np.asarray(getattr(e, f)), np.asarray(getattr(c, f))
        assert a.dtype == b.dtype, (f, a.dtype, b.dtype)
        assert a.shape == b.shape, (f, a.shape, b.shape)
        np.testing.assert_array_equal(a, b, err_msg=f)


def cells():
    for gname in GRAPHS:
        for (r, s) in RS:
            yield pytest.param(gname, r, s, id=f"{gname}-r{r}s{s}")


# ---------------------------------------------------------------------------
# Bit-identity across chunk sizes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [1, 7, None])
@pytest.mark.parametrize("gname,r,s", cells())
def test_chunked_matches_eager(gname, r, s, chunk):
    e = _eager(gname, r, s)
    c = build_problem(GRAPHS[gname], r, s, build="chunked", chunk_size=chunk)
    assert_problems_identical(e, c)
    if chunk == 1:
        assert c.build_stats["n_chunks"] == GRAPHS[gname].n


@pytest.mark.parametrize("gname", ["er20", "planted", "ba60"])
def test_chunked_fastpath_23_matches_eager(gname):
    """The dense (2,3) count pass (Pallas kernel / jnp oracle) and the
    sparse chunked path both reproduce the eager build exactly."""
    e = _eager(gname, 2, 3)
    fast = build_problem(GRAPHS[gname], 2, 3, build="chunked", fastpath=True)
    slow = build_problem(GRAPHS[gname], 2, 3, build="chunked", fastpath=False)
    assert fast.build_stats["fastpath"] and not slow.build_stats["fastpath"]
    assert_problems_identical(e, fast)
    assert_problems_identical(e, slow)


def test_fastpath_rejected_off_23():
    with pytest.raises(ValueError, match=r"fastpath.*\(2, 4\)"):
        build_problem(GRAPHS["er20"], 2, 4, build="chunked", fastpath=True)


def test_budget_derives_multiple_chunks():
    """A small budget forces real chunking; output is still identical and
    the accounted intermediate peak respects the budget."""
    g = GRAPHS["ba60"]
    budget = 50_000
    c = build_problem(g, 2, 4, build="chunked", memory_budget_bytes=budget)
    assert_problems_identical(_eager("ba60", 2, 4), c)
    st = c.build_stats
    assert st["n_chunks"] > 1
    if st["chunk_size"] > 1:  # above the 1-seed floor the budget binds
        assert st["peak_intermediate_bytes"] <= budget * 1.2, st


def test_chunk_size_derivation_clamps():
    g = GRAPHS["ba60"]
    dg, _ = pick_rank(g)
    assert _derive_chunk_size(dg, 4, 1) == 1           # floor
    assert _derive_chunk_size(dg, 2, 10**12) == g.n    # ceiling
    lo = _derive_chunk_size(dg, 4, 100_000)
    hi = _derive_chunk_size(dg, 4, 10_000_000)
    assert 1 <= lo <= hi <= g.n                        # monotone in budget


# ---------------------------------------------------------------------------
# Orientation metadata (the pick_rank bugfix)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gname,r,s", cells())
def test_orientation_recorded_and_stable(gname, r, s):
    e = _eager(gname, r, s)
    c = build_problem(GRAPHS[gname], r, s, build="chunked")
    assert e.orientation in ("degree", "approx_degeneracy")
    assert e.orientation == c.orientation


def test_caller_rank_recorded():
    g = GRAPHS["er20"]
    rank = degree_rank(g)
    e = build_problem(g, 2, 3, rank=rank)
    c = build_problem(g, 2, 3, rank=rank, build="chunked")
    assert e.orientation == "caller" and c.orientation == "caller"
    assert_problems_identical(e, c)


# ---------------------------------------------------------------------------
# Degenerate inputs (the sort_join T=0 regression)
# ---------------------------------------------------------------------------

def test_sort_join_empty_table():
    queries = jnp.asarray([[0, 1], [2, 3]], jnp.int32)
    empty = jnp.zeros((0, 2), jnp.int32)
    np.testing.assert_array_equal(np.asarray(sort_join(empty, queries)),
                                  [-1, -1])
    np.testing.assert_array_equal(sort_join_np(np.zeros((0, 2), np.int32),
                                               np.asarray(queries)),
                                  [-1, -1])
    # empty queries stay empty either way
    assert sort_join(empty, jnp.zeros((0, 2), jnp.int32)).shape == (0,)
    assert sort_join_np(np.zeros((0, 2), np.int32),
                        np.zeros((0, 2), np.int32)).shape == (0,)


def test_sort_join_np_matches_jnp():
    rng = np.random.default_rng(0)
    table = np.unique(rng.integers(0, 30, size=(40, 3)).astype(np.int32),
                      axis=0)
    order = np.lexsort(tuple(table[:, c] for c in reversed(range(3))))
    table = table[order]
    queries = rng.integers(0, 30, size=(64, 3)).astype(np.int32)
    queries[:8] = table[:8]  # guaranteed hits
    np.testing.assert_array_equal(
        sort_join_np(table, queries),
        np.asarray(sort_join(jnp.asarray(table), jnp.asarray(queries))))


def test_empty_graph_chunked():
    g = GRAPHS["empty10"]
    for (r, s) in RS:
        e = build_problem(g, r, s)
        c = build_problem(g, r, s, build="chunked", chunk_size=3)
        assert_problems_identical(e, c)


def test_chunk_iterator_concatenates_to_list_cliques():
    g = GRAPHS["planted"]
    dg, _ = pick_rank(g)
    whole = list_cliques(g, [2, 4], dg=dg)
    for chunk in (1, 9, g.n):
        parts = {2: [], 4: []}
        for _start, levels, peak in iter_clique_chunks(dg, [2, 4], chunk):
            assert peak >= 0
            for t in (2, 4):
                parts[t].append(levels[t])
        for t in (2, 4):
            got = np.concatenate(parts[t], axis=0)
            np.testing.assert_array_equal(got, np.asarray(whole.levels[t]))


# ---------------------------------------------------------------------------
# End-to-end decompose() parity against the golden fixtures
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gname,r,s", [("er20", 1, 2), ("planted40", 2, 3),
                                       ("k4", 3, 4)])
def test_decompose_chunked_matches_golden(gname, r, s):
    with open(os.path.join(GOLDEN_DIR, f"{gname}_r{r}s{s}.json")) as f:
        fx = json.load(f)
    g = generators.golden_suite()[gname]()
    dec = decompose(g, NucleusConfig(r=r, s=s, method="exact",
                                     backend="gather", hierarchy="replay",
                                     build="chunked",
                                     memory_budget_bytes=1 << 16))
    assert dec.n_r == fx["n_r"]
    np.testing.assert_array_equal(dec.core, fx["core"])
    for c_str, want in fx["partitions"].items():
        got = canonicalize_labels(dec.cut(int(c_str)))
        np.testing.assert_array_equal(got, want, err_msg=f"cut({c_str})")


# ---------------------------------------------------------------------------
# Property test: random graphs x (r, s) x chunk sizes
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_chunked_equivalence_hypothesis():
    pytest.importorskip("hypothesis")  # optional dep: skip, don't fail
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.integers(2, 28), st.floats(0.05, 0.5),
           st.integers(0, 10_000), st.sampled_from(RS),
           st.sampled_from([1, 7, 0]))
    def inner(n, p, seed, rs, chunk):
        r, s = rs
        g = generators.erdos_renyi(n, p, seed=seed)
        e = build_problem(g, r, s)
        c = build_problem(g, r, s, build="chunked",
                          chunk_size=(chunk or g.n))
        assert_problems_identical(e, c)

    inner()
