"""Sharded (distbuild) incidence builder: bit-identity + planner properties.

The sharded builder (DESIGN.md §13) assembles per-shard CSR slabs with a
count-then-fill exchange instead of a global concat + ``csr_from_pairs``;
these tests pin that it is byte-identical to the eager build on every
``NucleusProblem`` array for every golden graph x (r, s) x shard count,
that the work-estimate planner's chunk->shard assignment is a balanced
contiguous partition, and that the Session's sharded warm path rounds its
shape buckets to shard multiples (the PR-5 leftover: pow2 alone is not
shard-aware).

Multi-device cases run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (device count locks
at first jax init), same idiom as tests/test_distributed_core.py.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.graph import generators
from repro.core import decompose, NucleusConfig
from repro.core.incidence import build_problem, pick_rank
from repro.distbuild import (build_problem_sharded, estimate_eager_build_bytes,
                             plan_shards, seed_work_estimate)

pytestmark = pytest.mark.fast

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

GRAPHS = {
    "bowtie_plus": generators.tiny_named("bowtie_plus"),
    "er20": generators.erdos_renyi(20, 0.35, seed=1),
    "planted": generators.planted_cliques(40, [8, 6, 5], 0.05, seed=3),
    "ba60": generators.barabasi_albert(60, 4, seed=4),
    "empty10": generators.erdos_renyi(10, 0.0, seed=0),
}
RS = [(1, 2), (2, 3), (2, 4), (3, 4)]
ARRAYS = ("r_cliques", "inc_rid", "mem_offsets", "mem_sids", "deg0")

_EAGER = {}


def _eager(gname, r, s):
    key = (gname, r, s)
    if key not in _EAGER:
        _EAGER[key] = build_problem(GRAPHS[gname], r, s)
    return _EAGER[key]


def assert_problems_identical(e, c):
    assert e.orientation == c.orientation
    for f in ARRAYS:
        a, b = np.asarray(getattr(e, f)), np.asarray(getattr(c, f))
        assert a.dtype == b.dtype, (f, a.dtype, b.dtype)
        assert a.shape == b.shape, (f, a.shape, b.shape)
        np.testing.assert_array_equal(a, b, err_msg=f)


def cells():
    for gname in GRAPHS:
        for (r, s) in RS:
            yield pytest.param(gname, r, s, id=f"{gname}-r{r}s{s}")


# ---------------------------------------------------------------------------
# Bit-identity across shard counts (vs eager AND vs chunked)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", [1, 2, 4, 8])
@pytest.mark.parametrize("gname,r,s", cells())
def test_sharded_matches_eager(gname, r, s, n_shards):
    e = _eager(gname, r, s)
    c = build_problem(GRAPHS[gname], r, s, build="sharded", shards=n_shards)
    assert_problems_identical(e, c)
    st = c.build_stats
    assert st["build"] == "sharded" and st["n_shards"] == n_shards
    assert len(st["chunks_per_shard"]) == n_shards
    assert sum(st["chunks_per_shard"]) == st["n_chunks"]


@pytest.mark.parametrize("gname,r,s",
                         [("planted", 2, 3), ("ba60", 2, 4)])
def test_sharded_matches_chunked(gname, r, s):
    c = build_problem(GRAPHS[gname], r, s, build="chunked", chunk_size=7)
    sh = build_problem(GRAPHS[gname], r, s, build="sharded", shards=3,
                       chunk_size=2)
    assert_problems_identical(c, sh)


def test_sharded_small_budget_matches_eager():
    """A tiny budget forces many small chunks across shards; the output is
    still bit-identical and the builder's accounted peak is reported."""
    c = build_problem(GRAPHS["ba60"], 2, 4, build="sharded", shards=4,
                      memory_budget_bytes=50_000)
    assert_problems_identical(_eager("ba60", 2, 4), c)
    st = c.build_stats
    assert st["n_chunks"] > 4
    assert st["peak_intermediate_bytes"] > 0
    assert st["exchange_bytes"] > 0


def test_sharded_rejects_fastpath_and_stray_shards():
    with pytest.raises(ValueError, match="fastpath"):
        build_problem(GRAPHS["er20"], 2, 3, build="sharded", fastpath=True)
    with pytest.raises(ValueError, match="shards"):
        build_problem(GRAPHS["er20"], 2, 3, build="eager", shards=4)


# ---------------------------------------------------------------------------
# Planner: budget-derived chunk->shard assignment properties
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gname", ["er20", "planted", "ba60", "empty10"])
@pytest.mark.parametrize("n_shards", [1, 2, 3, 4, 8])
@pytest.mark.parametrize("budget", [10_000, 1_000_000, None])
def test_plan_shards_partition_properties(gname, n_shards, budget):
    g = GRAPHS[gname]
    dg, _ = pick_rank(g)
    plan = plan_shards(dg, 4, n_shards, memory_budget_bytes=budget)
    n = int(dg.n)
    assert plan.n_shards == n_shards
    # chunk bounds tile [0, n) contiguously
    cb = np.asarray(plan.chunk_bounds)
    assert cb[0] == 0 and cb[-1] == n
    assert (np.diff(cb) > 0).all() or n == 0
    # shard bounds are a monotone cover of the chunk index range
    sb = np.asarray(plan.shard_bounds)
    assert sb[0] == 0 and sb[-1] == plan.n_chunks
    assert (np.diff(sb) >= 0).all()
    # seed ranges partition [0, n): disjoint, ordered, exhaustive
    ranges = [plan.shard_seed_range(k) for k in range(n_shards)]
    assert ranges[0][0] == 0 and ranges[-1][1] == n
    for (a0, a1), (b0, b1) in zip(ranges, ranges[1:]):
        assert a1 == b0 and a0 <= a1 and b0 <= b1
    # balance bound: the quantile split can overshoot the ideal share by at
    # most one chunk's work
    work = np.asarray(plan.chunk_work)
    if plan.n_chunks and work.sum() > 0:
        ideal = work.sum() / n_shards
        assert max(plan.shard_work()) <= ideal + work.max() + 1e-9
        assert plan.skew() >= 1.0


def test_budget_derived_chunks_cannot_collapse_to_one():
    """The budget-derived chunk size is additionally capped at
    ceil(n / n_shards), so a generous budget still yields at least one
    chunk per shard to hand out (an uncapped derivation collapses the
    whole frontier into a single chunk on a single shard).  Whether every
    shard actually receives one is up to the work quantiles — a single
    dominant chunk may still leave trailing shards empty, which the
    balance bound already covers."""
    dg, _ = pick_rank(GRAPHS["ba60"])
    plan = plan_shards(dg, 3, 4, memory_budget_bytes=10**12)
    assert plan.n_chunks >= 4
    assert plan.chunk_size <= -(-int(dg.n) // 4)


def test_seed_work_estimate_and_eager_estimate():
    dg, _ = pick_rank(GRAPHS["planted"])
    w = seed_work_estimate(dg, 4)
    assert w.shape == (dg.n,) and (w >= 1).all()
    lo = estimate_eager_build_bytes(dg, 3)
    hi = estimate_eager_build_bytes(dg, 4)
    assert 0 < lo < hi  # monotone in s (dmax^(s-2) term)


def test_explicit_chunk_size_is_pinned():
    dg, _ = pick_rank(GRAPHS["ba60"])
    plan = plan_shards(dg, 4, 2, chunk_size=5)
    assert plan.chunk_size == 5
    assert plan.n_chunks == -(-int(dg.n) // 5)


# ---------------------------------------------------------------------------
# Auto-upgrade: backend='auto' + budget exceeded -> non-eager build
# ---------------------------------------------------------------------------

def test_auto_upgrades_overbudget_build():
    """With backend='auto' and a budget the eager estimate exceeds, the
    resolver upgrades the build ('chunked' on one device, 'sharded' on
    many — the multi-device arm runs in the subprocess test below)."""
    import jax
    g = GRAPHS["planted"]
    dec = decompose(g, NucleusConfig(r=2, s=3, backend="auto",
                                     memory_budget_bytes=1024))
    st = dec.problem.build_stats
    want = "sharded" if len(jax.devices()) > 1 else "chunked"
    assert st["build"] == want, st
    ref = decompose(g, NucleusConfig(r=2, s=3))
    np.testing.assert_array_equal(dec.core, ref.core)


# ---------------------------------------------------------------------------
# Session shape buckets: shard-multiple rounding (the PR-5 leftover)
# ---------------------------------------------------------------------------

def test_shard_bucket_size_rounds_to_shard_multiple():
    from repro.core.session import bucket_size, shard_bucket_size
    assert shard_bucket_size(100, 1) == bucket_size(100)
    assert shard_bucket_size(100, 8) == 128          # pow2 already divisible
    assert shard_bucket_size(100, 6) == 132          # 128 -> next mult of 6
    assert shard_bucket_size(0, 4) % 4 == 0
    for n in (1, 63, 64, 65, 1000):
        for k in (1, 2, 3, 5, 8):
            b = shard_bucket_size(n, k)
            assert b % k == 0 and b >= n


_SUBPROC_SHARDED = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax
    from repro.graph import generators
    from repro.core import build_problem, decompose, NucleusConfig
    from repro.core.distributed import make_sharded_decomposition
    from repro.core.schedule import PeelSchedule
    from repro.core.session import Session
    from repro.launch.mesh import make_host_mesh

    out = {"n_devices": len(jax.devices())}

    # ragged shape classes are rejected, not silently mis-sliced
    mesh = make_host_mesh()
    try:
        make_sharded_decomposition(mesh, 10, 129, 3,
                                   PeelSchedule(kind="exact", s_choose_r=3,
                                                delta=0.1, n=10))
        out["ragged_raises"] = False
    except ValueError as e:
        out["ragged_raises"] = "shard_bucket_size" in str(e)

    # sharded warm path: same bucket across two similar graphs -> one
    # compile, one warm hit; cores match the dense reference exactly
    cfg = NucleusConfig(r=2, s=3, backend="sharded", hierarchy="fused")
    sess = Session(cfg)
    match, buckets = True, set()
    for seed in (11, 12):
        g = generators.planted_cliques(40, [8, 6, 5], 0.05, seed=seed)
        p = build_problem(g, 2, 3)
        dec = sess.decompose(p)
        ref = decompose(p, NucleusConfig(r=2, s=3, backend="dense",
                                         hierarchy="fused"))
        match &= bool((np.asarray(dec.core) == np.asarray(ref.core)).all())
        match &= bool((np.asarray(dec.tree.parent)
                       == np.asarray(ref.tree.parent)).all())
    with sess._stats_lock:
        stats = {k: v for k, v in sess.stats.items() if k != "buckets"}
        for k in sess.stats["buckets"]:
            buckets.add((int(k[5]), int(k[8])))   # (n_s_pad, shards)
    out["match"] = match
    out["stats"] = {k: int(v) for k, v in stats.items()}
    out["buckets"] = sorted(buckets)

    # over-budget auto-upgrade picks the sharded build on a multi-device
    # host, and the plan's reasons surface the builder telemetry
    g = generators.planted_cliques(40, [8, 6, 5], 0.05, seed=11)
    dec = decompose(g, NucleusConfig(r=2, s=3, backend="auto",
                                     memory_budget_bytes=1024))
    st = dec.problem.build_stats
    out["auto_build"] = st["build"]
    out["auto_backend"] = dec.plan.backend
    out["reason_mentions_build"] = any("build 'sharded'" in r
                                       for r in dec.plan.reasons)
    print(json.dumps(out))
""")


@pytest.mark.slow
def test_sharded_session_and_auto_upgrade_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", _SUBPROC_SHARDED],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["n_devices"] == 8
    assert res["ragged_raises"] is True
    assert res["match"] is True
    assert res["stats"]["warm"] == 1 and res["stats"]["cold"] == 1
    assert res["stats"]["fallback"] == 0
    for n_s_pad, shards in res["buckets"]:
        assert shards == 8 and n_s_pad % 8 == 0
    assert res["auto_build"] == "sharded"
    assert res["auto_backend"] == "sharded"
    assert res["reason_mentions_build"] is True
