"""Registry conformance (DESIGN.md §8).

Four contracts:
  * REGISTRY — the four in-tree backends are registered in the canonical
    order, their capability declarations reproduce the DESIGN.md §6
    legality matrix exactly (29 triples, same order — pinned literally),
    and ``validate()``'s derived errors name the offending backend.
  * CONFORMANCE — every registered backend's declared capabilities are
    exercised: each legal triple runs on a tiny graph and produces exactly
    the artifacts the capabilities promise (trace iff ``records_trace``,
    forest iff fused), with ``rounds`` always a python int; each illegal
    knob raises ``ConfigError`` naming the backend.
  * PLANNER — ``resolve_plan``'s decision rules, unit-tested on explicit
    device/problem facts.
  * AUTO PARITY — ``backend='auto'``/``hierarchy='auto'`` produce
    array-for-array the same decomposition as the explicitly-configured
    equivalent on every golden fixture, and the plan round-trips through
    JSON.
"""
import itertools
import json

import numpy as np
import pytest

from repro.core import backends as B
from repro.core import (ConfigError, Decomposition, NucleusConfig,
                        build_problem, decompose)
from repro.core.api import BACKENDS, HIERARCHIES, METHODS
from repro.graph.generators import golden_suite

pytestmark = pytest.mark.fast

# The DESIGN.md §6 matrix, pinned literally: legal_combinations() must emit
# exactly these triples in exactly this order (the byte-identity oracle the
# capability derivation is checked against).
EXPECTED_LEGAL = [
    ("exact", "dense", "none"), ("exact", "dense", "fused"),
    ("exact", "dense", "replay"), ("exact", "dense", "two_phase"),
    ("exact", "dense", "basic"),
    ("exact", "gather", "none"), ("exact", "gather", "replay"),
    ("exact", "gather", "two_phase"), ("exact", "gather", "basic"),
    ("exact", "sharded", "none"), ("exact", "sharded", "fused"),
    ("exact", "sharded", "two_phase"), ("exact", "sharded", "basic"),
    ("exact", "nh", "none"), ("exact", "nh", "two_phase"),
    ("exact", "nh", "basic"),
    ("approx", "dense", "none"), ("approx", "dense", "fused"),
    ("approx", "dense", "replay"), ("approx", "dense", "two_phase"),
    ("approx", "dense", "basic"),
    ("approx", "gather", "none"), ("approx", "gather", "replay"),
    ("approx", "gather", "two_phase"), ("approx", "gather", "basic"),
    ("approx", "sharded", "none"), ("approx", "sharded", "fused"),
    ("approx", "sharded", "two_phase"), ("approx", "sharded", "basic"),
]


@pytest.fixture(scope="module")
def problem():
    return build_problem(golden_suite()["two_triangles"](), 2, 3)


# ---------------------------------------------------------------------------
# Registry + derived legality
# ---------------------------------------------------------------------------

def test_registry_order_is_canonical():
    assert B.names() == ("dense", "gather", "sharded", "nh")
    assert BACKENDS == B.names()
    for b in B.all_backends():
        assert isinstance(b, B.Backend)


def test_legal_combinations_byte_identical():
    """The derived matrix == the pre-registry hand-coded matrix, same
    triples, same order."""
    assert NucleusConfig.legal_combinations() == EXPECTED_LEGAL


def test_capability_matrix_matches_design_table():
    want = {
        "dense": ("none", "fused", "replay", "two_phase", "basic"),
        "gather": ("none", "replay", "two_phase", "basic"),
        "sharded": ("none", "fused", "two_phase", "basic"),
        "nh": ("none", "two_phase", "basic"),
    }
    for name, hierarchies in want.items():
        assert B.get(name).capabilities.hierarchies == hierarchies
    assert B.get("nh").capabilities.methods == ("exact",)


def test_unknown_backend_lists_registered_and_auto():
    with pytest.raises(ConfigError, match="auto"):
        NucleusConfig(backend="cuda").validate()
    with pytest.raises(ConfigError, match="auto"):
        NucleusConfig(hierarchy="bogus").validate()


def test_illegal_knobs_name_the_backend():
    """Every derived error message names the offending backend (and the
    capability-compatible alternatives come from the registry)."""
    cases = [
        (dict(backend="gather", hierarchy="fused"), "gather"),
        (dict(backend="nh", hierarchy="fused"), "nh"),
        (dict(backend="sharded", hierarchy="replay"), "sharded"),
        (dict(backend="nh", hierarchy="replay"), "nh"),
        (dict(backend="nh", method="approx"), "nh"),
        (dict(backend="gather", use_pallas=True, hierarchy="none"), "gather"),
        (dict(backend="sharded", use_pallas=True, hierarchy="none"),
         "sharded"),
        (dict(backend="dense", compress=True), "dense"),
        (dict(backend="gather", compress=True, hierarchy="none"), "gather"),
        (dict(backend="dense", mesh=object()), "dense"),
        (dict(backend="nh", mesh=object(), hierarchy="none"), "nh"),
    ]
    for kwargs, name in cases:
        with pytest.raises(ConfigError) as ei:
            NucleusConfig(**kwargs).validate()
        assert name in str(ei.value), \
            f"{kwargs}: error must name backend {name!r}: {ei.value}"


def test_auto_with_unsatisfiable_knobs_is_config_error():
    # no registered backend honours pallas AND compress at once
    with pytest.raises(ConfigError, match="auto"):
        NucleusConfig(backend="auto", use_pallas=True,
                      compress=True).validate()


def test_register_rejects_duplicate_names():
    entry = B.get("dense")
    with pytest.raises(ValueError, match="already registered"):
        B.register(entry)


def test_runtime_registered_backend_is_live(problem):
    """The module contract: one register() call and validate(), the legal
    matrix and decompose() dispatch all follow — no snapshot staleness."""
    class _Oracle:
        name = "test_oracle"
        capabilities = B.BackendCapabilities(
            methods=("exact",), compiled_peel=False, records_trace=False,
            knobs=frozenset(), summary="a runtime-registered test backend")

        def run(self, prob, config):
            from repro.core.nh_baseline import nh_coreness
            core, rho = nh_coreness(prob)
            return B.BackendResult(core=np.asarray(core), rounds=int(rho))

    B.register(_Oracle())
    try:
        cfg = NucleusConfig(r=2, s=3, backend="test_oracle",
                            hierarchy="two_phase")
        cfg.validate()
        legal = NucleusConfig.legal_combinations()
        assert ("exact", "test_oracle", "two_phase") in legal
        assert len(legal) == 29 + 3  # none/two_phase/basic, exact-only
        dec = decompose(problem, cfg)
        ref = decompose(problem, NucleusConfig(r=2, s=3, backend="nh",
                                               hierarchy="two_phase"))
        np.testing.assert_array_equal(dec.core, ref.core)
        with pytest.raises(ConfigError, match="test_oracle"):
            NucleusConfig(backend="test_oracle", hierarchy="fused",
                          method="exact").validate()
    finally:
        del B._REGISTRY["test_oracle"]
    assert len(NucleusConfig.legal_combinations()) == 29


# ---------------------------------------------------------------------------
# Conformance: every declared capability is exercised
# ---------------------------------------------------------------------------

def _conformance_combo(problem, method, backend, hierarchy):
    caps = B.get(backend).capabilities
    dec = decompose(problem, NucleusConfig(
        r=2, s=3, method=method, backend=backend, hierarchy=hierarchy))
    label = f"{method}/{backend}/{hierarchy}"
    # rounds normalization: every backend adapter coerces (the old facade's
    # sharded+fused branch did not)
    assert type(dec.rounds) is int, label
    if caps.records_trace:
        assert dec.order_round is not None, label
        assert dec.peel_value is not None, label
    else:
        assert dec.order_round is None, label
    if hierarchy == "fused":
        assert caps.compiled_peel, label
        assert dec.uf_parent is not None and dec.uf_L is not None, label
    assert dec.plan is not None and not dec.plan.was_auto, label
    assert dec.plan.backend == backend, label


@pytest.mark.parametrize("method,backend,hierarchy", [
    pytest.param(m, b, h, id=f"{m}-{b}-{h}",
                 marks=[] if b != "sharded" else [pytest.mark.slow])
    for (m, b, h) in EXPECTED_LEGAL])
def test_conformance_every_legal_triple(problem, method, backend, hierarchy):
    _conformance_combo(problem, method, backend, hierarchy)


# ---------------------------------------------------------------------------
# Planner decision rules (explicit facts -> deterministic choices)
# ---------------------------------------------------------------------------

def _plan(cfg, *, n_r=1000, n_s=1000, n_sub=3, device_kind="cpu",
          n_devices=1, profile_path="/nonexistent/planner_profile.json"):
    # profile_path defaults to a missing file so the decision-rule tests
    # exercise the static constants regardless of the committed profile
    return B.resolve_plan(cfg, n_r=n_r, n_s=n_s, n_sub=n_sub,
                          device_kind=device_kind, n_devices=n_devices,
                          profile_path=profile_path)


def test_planner_explicit_backend_is_kept():
    p = _plan(NucleusConfig(backend="gather", hierarchy="two_phase"))
    assert (p.backend, p.hierarchy) == ("gather", "two_phase")
    assert not p.was_auto


def test_planner_mesh_forces_sharded():
    p = _plan(NucleusConfig(backend="auto", mesh=object()))
    assert p.backend == "sharded" and p.was_auto


def test_planner_compress_forces_sharded():
    p = _plan(NucleusConfig(backend="auto", compress=True))
    assert p.backend == "sharded"


def test_planner_pallas_forces_dense():
    p = _plan(NucleusConfig(backend="auto", use_pallas=True))
    assert p.backend == "dense"


def test_planner_accelerator_prefers_dense():
    p = _plan(NucleusConfig(backend="auto"), device_kind="tpu")
    assert p.backend == "dense"


def test_planner_cpu_tiny_prefers_gather_else_dense():
    assert _plan(NucleusConfig(backend="auto", hierarchy="auto"),
                 n_r=B.TINY_NR - 1).backend == "gather"
    assert _plan(NucleusConfig(backend="auto", hierarchy="auto"),
                 n_r=B.TINY_NR).backend == "dense"


def test_planner_multi_device_needs_enough_work():
    big = B.SHARD_MIN_INCIDENCE
    assert _plan(NucleusConfig(backend="auto"), n_devices=8,
                 n_s=big, n_sub=1).backend == "sharded"
    assert _plan(NucleusConfig(backend="auto"), n_devices=8,
                 n_s=1000, n_sub=3).backend == "dense"


def test_planner_memory_budget_steers_to_gather():
    cfg = NucleusConfig(backend="auto", hierarchy="auto", build="chunked",
                        memory_budget_bytes=1 << 10)
    p = _plan(cfg, n_s=100_000, n_sub=3)
    assert p.backend == "gather" and p.hierarchy == "replay"
    # the default hierarchy='fused' needs a compiled loop, which overrides
    # the budget preference (capability filter beats preference order)
    fused = NucleusConfig(backend="auto", build="chunked",
                          memory_budget_bytes=1 << 10)
    assert _plan(fused, n_s=100_000, n_sub=3).backend == "dense"


def test_planner_explicit_hierarchy_constrains_candidates():
    # fused needs a compiled peel: tiny-on-cpu may not fall back to gather
    p = _plan(NucleusConfig(backend="auto", hierarchy="fused"),
              n_r=B.TINY_NR - 1)
    assert p.backend == "dense"
    assert p.hierarchy == "fused"


def test_planner_hierarchy_auto_follows_capabilities():
    assert _plan(NucleusConfig(backend="dense",
                               hierarchy="auto")).hierarchy == "fused"
    assert _plan(NucleusConfig(backend="gather",
                               hierarchy="auto")).hierarchy == "replay"
    assert _plan(NucleusConfig(backend="nh",
                               hierarchy="auto")).hierarchy == "two_phase"
    assert _plan(NucleusConfig(backend="sharded",
                               hierarchy="auto")).hierarchy == "fused"


def test_plan_report_is_human_readable():
    p = _plan(NucleusConfig(backend="auto", hierarchy="auto"))
    rep = p.report()
    assert "backend='dense'" in rep and "requested backend='auto'" in rep
    assert any(line.startswith("  - ") for line in rep.splitlines())


# ---------------------------------------------------------------------------
# Telemetry-driven thresholds (planner_profile.json -> resolve_plan)
# ---------------------------------------------------------------------------

from repro.core import planner_profile as PP  # noqa: E402


@pytest.fixture(autouse=True)
def _fresh_profile_cache():
    PP.reset_cache()
    yield
    PP.reset_cache()


def _write_profile(tmp_path, profiles, name="prof.json"):
    path = tmp_path / name
    path.write_text(json.dumps({"format": PP.FORMAT, "version": PP.VERSION,
                                "profiles": profiles}))
    return str(path)


def test_profile_thresholds_drive_the_planner(tmp_path):
    """A measured tiny_nr crossover replaces the static constant, and the
    Plan reasons say which profile entry fired."""
    path = _write_profile(tmp_path, {"cpu": {"tiny_nr": 200}})
    cfg = NucleusConfig(backend="auto", hierarchy="auto")
    small = _plan(cfg, n_r=150, profile_path=path)
    assert small.backend == "gather"       # 150 < measured 200
    assert any("planner_profile['cpu']" in r for r in small.reasons)
    big = _plan(cfg, n_r=250, profile_path=path)
    assert big.backend == "dense"
    # the static constant would have said dense for n_r=150
    assert _plan(cfg, n_r=150).backend == "dense"


def test_profile_device_kind_beats_platform(tmp_path):
    path = _write_profile(tmp_path, {
        "TPU v4": {"tiny_nr": 10}, "tpu": {"tiny_nr": 99}})
    entry, source = PP.profile_entry(device_kind="TPU v4", platform="tpu",
                                     path=path)
    assert entry["tiny_nr"] == 10 and "TPU v4" in source


def test_profile_per_key_fallback(tmp_path):
    """An entry that measured only one crossover keeps the static value
    for the other (shard_min_incidence is unmeasured on 1 device)."""
    path = _write_profile(tmp_path, {"cpu": {"tiny_nr": 33}})
    th = PP.thresholds(device_kind="cpu", path=path)
    assert th["tiny_nr"] == 33
    assert th["shard_min_incidence"] == PP.STATIC_SHARD_MIN_INCIDENCE


def test_profile_missing_is_silent_static(tmp_path):
    th = PP.thresholds(device_kind="cpu",
                       path=str(tmp_path / "never_written.json"))
    assert th["tiny_nr"] == PP.STATIC_TINY_NR
    assert th["source"] == "static defaults"


def test_profile_malformed_warns_once_then_static(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text("{not json")
    with pytest.warns(UserWarning, match="falling back to the static"):
        th = PP.thresholds(device_kind="cpu", path=str(path))
    assert th["tiny_nr"] == PP.STATIC_TINY_NR
    import warnings as W
    with W.catch_warnings():
        W.simplefilter("error")        # second load must NOT warn again
        th2 = PP.thresholds(device_kind="cpu", path=str(path))
    assert th2["source"] == "static defaults"
    # a wrong format sentinel is malformed too
    path2 = tmp_path / "wrong_format.json"
    path2.write_text(json.dumps({"format": "something-else", "profiles": {}}))
    with pytest.warns(UserWarning, match="falling back to the static"):
        assert PP.load_profile(str(path2)) is None


def test_pallas_default_from_profile(tmp_path):
    path = _write_profile(tmp_path, {"cpu": {"pallas_default": True},
                                     "tpu": {"tiny_nr": 5}})
    assert PP.pallas_default(platform="cpu", path=path) is True
    # entry exists but never measured the kernel race -> None + warn
    with pytest.warns(UserWarning, match="calibrate_planner"):
        assert PP.pallas_default(platform="tpu", path=path) is None
    with pytest.warns(UserWarning, match="calibrate_planner"):
        assert PP.pallas_default(platform="rocm", path=path) is None


def test_pallas_by_default_consults_the_profile(tmp_path, monkeypatch):
    """engine.pallas_by_default (the use_pallas=None oracle) follows the
    profile verdict when one covers this platform."""
    from repro.core import engine as engine_mod
    path = _write_profile(tmp_path, {
        "cpu": {"pallas_default": True}, "tpu": {"pallas_default": True}})
    monkeypatch.setattr(PP, "PROFILE_PATH", path)
    assert engine_mod.pallas_by_default() is True
    PP.reset_cache()
    path2 = _write_profile(tmp_path, {
        "cpu": {"pallas_default": False}, "tpu": {"pallas_default": False}},
        name="prof2.json")
    monkeypatch.setattr(PP, "PROFILE_PATH", path2)
    assert engine_mod.pallas_by_default() is False


def test_planner_records_kcore_fast_lane():
    p = _plan(NucleusConfig(backend="auto", hierarchy="auto"))
    assert not any("kcore" in r for r in p.reasons)   # r/s unknown
    p12 = B.resolve_plan(NucleusConfig(backend="auto", hierarchy="auto"),
                         n_r=1000, n_s=1000, n_sub=2, device_kind="cpu",
                         n_devices=1, r=1, s=2,
                         profile_path="/nonexistent/planner_profile.json")
    assert any("fast lane 'kcore'" in r for r in p12.reasons)
    p23 = B.resolve_plan(NucleusConfig(backend="auto", hierarchy="auto"),
                         n_r=1000, n_s=1000, n_sub=3, device_kind="cpu",
                         n_devices=1, r=2, s=3,
                         profile_path="/nonexistent/planner_profile.json")
    assert not any("kcore" in r for r in p23.reasons)


def test_committed_profile_is_loadable():
    """The shipped src/repro/core/planner_profile.json parses and covers
    the reference platform (cpu)."""
    blob = PP.load_profile()
    assert blob is not None, "committed planner_profile.json missing/bad"
    assert "cpu" in blob["profiles"]
    th = PP.thresholds(device_kind="cpu")
    assert "planner_profile" in th["source"]
    assert th["tiny_nr"] >= 1


# ---------------------------------------------------------------------------
# Auto-planner parity vs explicit configs over the golden fixtures
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gname", sorted(golden_suite()))
def test_auto_parity_golden_fixtures(gname):
    problem = build_problem(golden_suite()[gname](), 2, 3)
    if problem.n_r == 0:
        pytest.skip("no r-cliques")
    auto = decompose(problem, NucleusConfig(r=2, s=3, backend="auto",
                                            hierarchy="auto"))
    assert auto.plan is not None and auto.plan.was_auto
    explicit = decompose(problem, NucleusConfig(
        r=2, s=3, backend=auto.config.backend,
        hierarchy=auto.config.hierarchy))
    np.testing.assert_array_equal(auto.core, explicit.core)
    assert auto.rounds == explicit.rounds
    if auto.order_round is not None:
        np.testing.assert_array_equal(auto.order_round, explicit.order_round)
        np.testing.assert_array_equal(auto.peel_value, explicit.peel_value)
    if auto.has_hierarchy:
        np.testing.assert_array_equal(np.asarray(auto.tree.parent),
                                      np.asarray(explicit.tree.parent))
        np.testing.assert_array_equal(np.asarray(auto.tree.level),
                                      np.asarray(explicit.tree.level))


def test_auto_plan_rides_the_serialized_artifact():
    problem = build_problem(golden_suite()["planted40"](), 2, 3)
    dec = decompose(problem, NucleusConfig(r=2, s=3, backend="auto",
                                           hierarchy="auto"))
    loaded = Decomposition.from_json(dec.to_json())
    assert loaded.plan == dec.plan
    assert loaded.plan_report() == dec.plan_report()
    d = json.loads(dec.to_json())
    assert d["plan"]["requested_backend"] == "auto"
    assert d["config"]["backend"] == dec.plan.backend  # resolved, not auto
