"""Paper-table benchmarks (Figs. 6-10 + §8.3), CPU-scale.

One function per paper figure; each returns CSV rows.  All wall-clock
comparisons are honest same-machine runs; the parallel-vs-sequential
comparisons measure the BATCHED (data-parallel formulation) implementations
against the sequential NH oracle, mirroring the paper's ANH-* vs NH setup.
"""
from __future__ import annotations

import numpy as np

from repro.core import (build_problem, exact_coreness, approx_coreness,
                        build_hierarchy_levels, build_hierarchy_basic,
                        build_hierarchy_interleaved, nh_full, nh_coreness,
                        cut_hierarchy, nuclei_without_hierarchy,
                        edge_density, nucleus_vertex_sets)
from .common import suite, timed, row

RS_GRID = [(1, 2), (2, 3), (1, 3), (2, 4), (3, 4)]


def fig6_variants(quick=False) -> list[str]:
    """ANH-TE vs ANH-EL vs ANH-BL across (r, s)."""
    rows = []
    graphs = suite(["ba2k", "planted1k"] if quick else
                   ["ba2k", "er2k", "planted1k"])
    rs = [(1, 2), (2, 3)] if quick else RS_GRID
    for gname, g in graphs.items():
        for (r, s) in rs:
            problem = build_problem(g, r, s)
            if problem.n_r == 0:
                continue
            core = exact_coreness(problem).core

            _, t_te = timed(lambda: build_hierarchy_levels(problem, core))
            _, t_bl = timed(lambda: build_hierarchy_basic(problem, core))
            res, t_el = timed(lambda: build_hierarchy_interleaved(problem))
            links = res.state.stats_links
            rows.append(row(f"fig6/{gname}/r{r}s{s}/anh-te", t_te,
                            f"n_r={problem.n_r}"))
            rows.append(row(f"fig6/{gname}/r{r}s{s}/anh-el", t_el,
                            f"links={links}"))
            rows.append(row(f"fig6/{gname}/r{r}s{s}/anh-bl", t_bl, ""))
    return rows


def fig7_grid(quick=False) -> list[str]:
    """Best hierarchy times across the (r, s) grid."""
    rows = []
    graphs = suite(["planted1k"] if quick else ["ba2k", "planted1k"])
    rs = [(1, 2), (2, 3)] if quick else RS_GRID + [(1, 4), (2, 5), (4, 5)]
    for gname, g in graphs.items():
        for (r, s) in rs:
            try:
                problem = build_problem(g, r, s)
            except Exception:
                continue
            if problem.n_r == 0:
                continue
            core = exact_coreness(problem).core
            _, t_te = timed(lambda: build_hierarchy_levels(problem, core))
            res, t_el = timed(lambda: build_hierarchy_interleaved(problem))
            best = min(t_te, t_el)
            which = "te" if t_te <= t_el else "el"
            rows.append(row(f"fig7/{gname}/r{r}s{s}", best,
                            f"best={which};n_s={problem.n_s}"))
    return rows


def fig8_scaling(quick=False) -> list[str]:
    """Scalability.  This container has ONE core, so the paper's
    thread-scaling axis is replaced by (a) problem-size scaling of the
    batched algorithm and (b) the measured peel-round count (the span term
    that sets parallel time on a real machine)."""
    from repro.graph import generators
    rows = []
    sizes = [500, 1_000] if quick else [500, 1_000, 2_000, 4_000]
    for n in sizes:
        g = generators.barabasi_albert(n, 8, seed=7)
        problem = build_problem(g, 2, 3)
        res, t = timed(lambda: exact_coreness(problem))
        rows.append(row(f"fig8/ba{n}/exact", t,
                        f"rounds={res.rounds};m={g.m}"))
        res_a, t_a = timed(lambda: approx_coreness(problem, delta=0.1))
        rows.append(row(f"fig8/ba{n}/approx", t_a,
                        f"rounds={res_a.rounds}"))
    return rows


def fig9_baselines(quick=False) -> list[str]:
    """Interleaved parallel formulation vs sequential NH (end-to-end)."""
    rows = []
    graphs = suite(["planted1k"] if quick else ["ba2k", "planted1k"])
    for gname, g in graphs.items():
        for (r, s) in [(1, 2), (2, 3)] + ([] if quick else [(3, 4)]):
            problem = build_problem(g, r, s)
            if problem.n_r == 0:
                continue
            _, t_par = timed(lambda: build_hierarchy_interleaved(problem))
            _, t_nh = timed(lambda: nh_full(problem))
            rows.append(row(f"fig9/{gname}/r{r}s{s}/ours", t_par,
                            f"vs_nh={t_nh / max(t_par, 1e-9):.2f}x"))
            rows.append(row(f"fig9/{gname}/r{r}s{s}/nh", t_nh, ""))
    return rows


def fig10_nuclei(quick=False) -> list[str]:
    """Hierarchy usefulness: cut vs re-run connectivity, plus densities."""
    rows = []
    graphs = suite(["planted1k"])
    for gname, g in graphs.items():
        for (r, s) in [(2, 3)] + ([] if quick else [(2, 4)]):
            problem = build_problem(g, r, s)
            core = exact_coreness(problem).core
            tree = build_hierarchy_levels(problem, core)
            kmax = int(np.asarray(core).max())
            cs = sorted(set([1, max(1, kmax // 2), kmax]))

            def with_tree():
                return [cut_hierarchy(tree, c) for c in cs]

            def without():
                return [nuclei_without_hierarchy(problem, core, c)
                        for c in cs]

            labels, t_with = timed(with_tree)
            _, t_without = timed(without)
            dens = []
            for lab, c in zip(labels, cs):
                vs = nucleus_vertex_sets(problem, lab)
                if vs:
                    biggest = max(vs.values(), key=len)
                    dens.append(edge_density(np.asarray(problem.g.edges),
                                             biggest))
            rows.append(row(f"fig10/{gname}/r{r}s{s}/with_hierarchy", t_with,
                            f"speedup={t_without / max(t_with, 1e-9):.1f}x"))
            rows.append(row(f"fig10/{gname}/r{r}s{s}/without", t_without,
                            f"densities={'|'.join(f'{d:.2f}' for d in dens)}"))
    return rows


def approx_quality(quick=False) -> list[str]:
    """§8.3: approximation speed + multiplicative error statistics."""
    rows = []
    graphs = suite(["ba2k", "planted1k"] if quick
                   else ["ba2k", "er2k", "planted1k"])
    for gname, g in graphs.items():
        for (r, s) in [(2, 3)] + ([] if quick else [(1, 2), (2, 4)]):
            problem = build_problem(g, r, s)
            if problem.n_r == 0:
                continue
            exact_res, t_e = timed(lambda: exact_coreness(problem))
            for delta in ([0.1] if quick else [0.1, 0.5, 1.0]):
                approx_res, t_a = timed(
                    lambda: approx_coreness(problem, delta=delta))
                e = np.asarray(exact_res.core).astype(np.float64)
                a = np.asarray(approx_res.core).astype(np.float64)
                sel = e > 0
                if not sel.any():
                    continue
                ratio = a[sel] / e[sel]
                rows.append(row(
                    f"approx/{gname}/r{r}s{s}/d{delta}", t_a,
                    f"speedup={t_e / max(t_a, 1e-9):.2f}x;"
                    f"err_mean={ratio.mean():.2f};"
                    f"err_med={np.median(ratio):.2f};"
                    f"err_max={ratio.max():.2f};"
                    f"rounds={approx_res.rounds}vs{exact_res.rounds}"))
    return rows


ALL = {
    "fig6": fig6_variants,
    "fig7": fig7_grid,
    "fig8": fig8_scaling,
    "fig9": fig9_baselines,
    "fig10": fig10_nuclei,
    "approx": approx_quality,
}
