"""Paper-table benchmarks (Figs. 6-10 + §8.3), CPU-scale.

One function per paper figure; each returns CSV rows.  All wall-clock
comparisons are honest same-machine runs; the parallel-vs-sequential
comparisons measure the BATCHED (data-parallel formulation) implementations
against the sequential NH oracle, mirroring the paper's ANH-* vs NH setup.

Every lane drives the public front door (``repro.core.decompose``) —
hierarchy rows are end-to-end (peel + tree materialization), which is what a
caller actually pays; the sequential NH baseline and the from-scratch
connectivity baseline are imported from their submodules (they are the
comparison oracles, not facade workloads).  The ``facade`` lane records the
decompose-once/query-many serving claim: queries/sec for ``.cut(c)`` over a
sweep of levels vs from-scratch connectivity per query, plus the JSON
round-trip cost.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import build_problem, decompose, NucleusConfig, make_schedule
from repro.core.engine import BIG
from repro.core.nh_baseline import nh_full
from repro.core.nuclei import nuclei_without_hierarchy
from .common import suite, timed, row

RS_GRID = [(1, 2), (2, 3), (1, 3), (2, 4), (3, 4)]

# facade shorthands: every lane composes these axes
_GATHER = NucleusConfig(backend="gather", hierarchy="none")
_DENSE = NucleusConfig(backend="dense", hierarchy="none")


def _dense_eager(problem, kind: str, delta: float = 0.1):
    """The pre-engine eager dense formulation: one fixed-shape pass per round
    with per-op dispatch and a host sync on the bucket minimum.  Kept ONLY as
    the benchmark baseline the compiled engine lane is measured against —
    src/repro has exactly one peel-round body (repro.core.engine)."""
    schedule = make_schedule(problem, kind, delta)
    n_r = problem.n_r
    deg = problem.deg0
    core = jnp.full((n_r,), -1, jnp.int32)
    peeled = jnp.zeros((n_r,), bool)
    s_alive = jnp.ones((problem.n_s,), bool)
    sched = schedule.init_carry()
    rounds, n_left = 0, n_r
    while n_left > 0:
        dmin = int(jnp.min(jnp.where(peeled, BIG, deg)))  # host sync
        sched, level = schedule.next_level(sched, dmin)
        a_mask = (~peeled) & (deg <= level)
        core = jnp.where(a_mask, level, core)
        peeled = peeled | a_mask
        n_left -= int(jnp.sum(a_mask))
        dead_now = jnp.any(peeled[problem.inc_rid], axis=1) & s_alive
        s_alive = s_alive & ~dead_now
        members = problem.inc_rid.reshape(-1)
        dead_rep = jnp.repeat(dead_now, problem.n_sub,
                              total_repeat_length=members.shape[0])
        deg = deg.at[members].add(-dead_rep.astype(jnp.int32))
        rounds += 1
    if kind == "approx":
        core = jnp.minimum(core, problem.deg0)
    return core, rounds


def _with_tree(problem, hierarchy: str, **overrides):
    """decompose + materialize the tree — the end-to-end hierarchy cost."""
    dec = decompose(problem, NucleusConfig(backend="dense",
                                           hierarchy=hierarchy), **overrides)
    dec.tree
    return dec


def fig6_variants(quick=False) -> list[str]:
    """ANH-TE vs ANH-EL vs ANH-BL across (r, s) — end-to-end decompose()."""
    rows = []
    graphs = suite(["ba2k", "planted1k"] if quick else
                   ["ba2k", "er2k", "planted1k"])
    rs = [(1, 2), (2, 3)] if quick else RS_GRID
    for gname, g in graphs.items():
        for (r, s) in rs:
            problem = build_problem(g, r, s)
            if problem.n_r == 0:
                continue
            # warmup=1 keeps the one-time engine compile out of whichever
            # builder happens to run first (all three share the peel)
            _, t_te = timed(lambda: _with_tree(problem, "two_phase"),
                            warmup=1)
            _, t_bl = timed(lambda: _with_tree(problem, "basic"), warmup=1)
            dec, t_el = timed(lambda: _with_tree(problem, "replay"),
                              warmup=1)
            links = dec.link_stats[0] if dec.link_stats else 0
            rows.append(row(f"fig6/{gname}/r{r}s{s}/anh-te", t_te,
                            f"n_r={problem.n_r}"))
            rows.append(row(f"fig6/{gname}/r{r}s{s}/anh-el", t_el,
                            f"links={links}"))
            rows.append(row(f"fig6/{gname}/r{r}s{s}/anh-bl", t_bl, ""))
    return rows


def fig7_grid(quick=False) -> list[str]:
    """Best hierarchy times across the (r, s) grid."""
    rows = []
    graphs = suite(["planted1k"] if quick else ["ba2k", "planted1k"])
    rs = [(1, 2), (2, 3)] if quick else RS_GRID + [(1, 4), (2, 5), (4, 5)]
    for gname, g in graphs.items():
        for (r, s) in rs:
            try:
                problem = build_problem(g, r, s)
            except Exception:
                continue
            if problem.n_r == 0:
                continue
            _, t_te = timed(lambda: _with_tree(problem, "two_phase"),
                            warmup=1)
            _, t_el = timed(lambda: _with_tree(problem, "replay"), warmup=1)
            best = min(t_te, t_el)
            which = "te" if t_te <= t_el else "el"
            rows.append(row(f"fig7/{gname}/r{r}s{s}", best,
                            f"best={which};n_s={problem.n_s}"))
    return rows


def fig8_scaling(quick=False) -> list[str]:
    """Scalability.  This container has ONE core, so the paper's
    thread-scaling axis is replaced by (a) problem-size scaling of the
    batched algorithm, (b) the measured peel-round count (the span term
    that sets parallel time on a real machine), and (c) the engine lane:
    the compiled lax.while_loop engine vs the eager per-round dense loop
    it replaced (compile time excluded via warmup)."""
    from repro.graph import generators
    rows = []
    sizes = [500, 1_000] if quick else [500, 1_000, 2_000, 4_000]
    for n in sizes:
        g = generators.barabasi_albert(n, 8, seed=7)
        problem = build_problem(g, 2, 3)
        res, t = timed(lambda: decompose(problem, _GATHER))
        rows.append(row(f"fig8/ba{n}/exact", t,
                        f"rounds={res.rounds};m={g.m}"))
        res_a, t_a = timed(lambda: decompose(problem, _GATHER,
                                             method="approx", delta=0.1))
        rows.append(row(f"fig8/ba{n}/approx", t_a,
                        f"rounds={res_a.rounds}"))
        for kind in ("exact", "approx"):
            _, t_eager = timed(lambda: _dense_eager(problem, kind))
            res_e, t_eng = timed(
                lambda: decompose(problem, _DENSE, method=kind).core,
                warmup=1)
            rows.append(row(f"fig8/ba{n}/dense_eager/{kind}", t_eager, ""))
            rows.append(row(
                f"fig8/ba{n}/engine/{kind}", t_eng,
                f"speedup_vs_eager={t_eager / max(t_eng, 1e-9):.2f}x"))
    return rows


def fig9_baselines(quick=False) -> list[str]:
    """Interleaved parallel formulation vs sequential NH (end-to-end)."""
    rows = []
    graphs = suite(["planted1k"] if quick else ["ba2k", "planted1k"])
    for gname, g in graphs.items():
        for (r, s) in [(1, 2), (2, 3)] + ([] if quick else [(3, 4)]):
            problem = build_problem(g, r, s)
            if problem.n_r == 0:
                continue
            _, t_par = timed(
                lambda: decompose(problem,
                                  NucleusConfig(backend="gather",
                                                hierarchy="replay")).tree)
            _, t_nh = timed(lambda: nh_full(problem))
            rows.append(row(f"fig9/{gname}/r{r}s{s}/ours", t_par,
                            f"vs_nh={t_nh / max(t_par, 1e-9):.2f}x"))
            rows.append(row(f"fig9/{gname}/r{r}s{s}/nh", t_nh, ""))
    return rows


def fig10_nuclei(quick=False) -> list[str]:
    """Hierarchy usefulness: cut vs re-run connectivity, plus densities."""
    rows = []
    graphs = suite(["planted1k"])
    for gname, g in graphs.items():
        for (r, s) in [(2, 3)] + ([] if quick else [(2, 4)]):
            problem = build_problem(g, r, s)
            dec = _with_tree(problem, "two_phase")
            core = dec.core
            kmax = int(core.max())
            cs = sorted(set([1, max(1, kmax // 2), kmax]))

            def with_tree():
                return [dec.tree.ancestor_at_level(c) for c in cs]

            def without():
                return [nuclei_without_hierarchy(problem, core, c)
                        for c in cs]

            _, t_with = timed(with_tree)
            _, t_without = timed(without)
            dens = []
            for c in cs:
                nuclei = dec.nuclei(c)
                if nuclei:
                    biggest = max(nuclei.values(),
                                  key=lambda nc: len(nc.vertices))
                    dens.append(biggest.density)
            rows.append(row(f"fig10/{gname}/r{r}s{s}/with_hierarchy", t_with,
                            f"speedup={t_without / max(t_with, 1e-9):.1f}x"))
            rows.append(row(f"fig10/{gname}/r{r}s{s}/without", t_without,
                            f"densities={'|'.join(f'{d:.2f}' for d in dens)}"))
    return rows


def approx_quality(quick=False) -> list[str]:
    """§8.3: approximation speed + multiplicative error statistics."""
    rows = []
    graphs = suite(["ba2k", "planted1k"] if quick
                   else ["ba2k", "er2k", "planted1k"])
    for gname, g in graphs.items():
        for (r, s) in [(2, 3)] + ([] if quick else [(1, 2), (2, 4)]):
            problem = build_problem(g, r, s)
            if problem.n_r == 0:
                continue
            exact_res, t_e = timed(lambda: decompose(problem, _GATHER))
            for delta in ([0.1] if quick else [0.1, 0.5, 1.0]):
                approx_res, t_a = timed(
                    lambda: decompose(problem, _GATHER, method="approx",
                                      delta=delta))
                e = exact_res.core.astype(np.float64)
                a = approx_res.core.astype(np.float64)
                sel = e > 0
                if not sel.any():
                    continue
                ratio = a[sel] / e[sel]
                rows.append(row(
                    f"approx/{gname}/r{r}s{s}/d{delta}", t_a,
                    f"speedup={t_e / max(t_a, 1e-9):.2f}x;"
                    f"err_mean={ratio.mean():.2f};"
                    f"err_med={np.median(ratio):.2f};"
                    f"err_max={ratio.max():.2f};"
                    f"rounds={approx_res.rounds}vs{exact_res.rounds}"))
    return rows


def engine_lane(quick=False) -> list[str]:
    """Compiled-vs-eager per figure graph: the unified lax.while_loop engine
    (one jitted call, trace recorded on device) against the eager dense
    round loop and the eager work-efficient gather loop."""
    rows = []
    graphs = suite(["ba2k"] if quick else ["ba2k", "er2k", "planted1k"])
    for gname, g in graphs.items():
        for (r, s) in [(1, 2), (2, 3)] + ([] if quick else [(2, 4)]):
            problem = build_problem(g, r, s)
            if problem.n_r == 0:
                continue
            for kind in ("exact", "approx"):
                _, t_gather = timed(
                    lambda: decompose(problem, _GATHER, method=kind).core)
                _, t_eager = timed(lambda: _dense_eager(problem, kind))
                res, t_eng = timed(
                    lambda: decompose(problem, _DENSE, method=kind),
                    warmup=1)
                rows.append(row(
                    f"engine/{gname}/r{r}s{s}/{kind}", t_eng,
                    f"vs_dense_eager={t_eager / max(t_eng, 1e-9):.2f}x;"
                    f"vs_gather={t_gather / max(t_eng, 1e-9):.2f}x;"
                    f"rounds={res.rounds}"))
    return rows


def hierarchy_lane(quick=False) -> list[str]:
    """On-device hierarchy construction: the fused engine (coreness + LINK
    fixpoint in ONE jitted call) vs host trace-replay vs the two-phase
    ANH-TE build — the repo's analog of the paper's hierarchy-construction
    comparison (Shi et al. report 58.84x over sequential there).  All
    lanes are end-to-end (peel + hierarchy); compile time excluded via
    warmup on the compiled lanes."""
    rows = []
    graphs = suite(["ba2k"] if quick else ["ba2k", "ba4k"])
    rs = [(1, 2), (2, 3)]
    for gname, g in graphs.items():
        for (r, s) in rs:
            problem = build_problem(g, r, s)
            if problem.n_r == 0:
                continue
            for mode in ("exact", "approx"):
                res_f, t_fused = timed(
                    lambda: _with_tree(problem, "fused", method=mode),
                    warmup=1)
                _, t_replay = timed(
                    lambda: _with_tree(problem, "replay", method=mode),
                    warmup=1)
                _, t_two = timed(
                    lambda: _with_tree(problem, "two_phase", method=mode),
                    warmup=1)
                base = f"hierarchy/{gname}/r{r}s{s}/{mode}"
                rows.append(row(f"{base}/fused", t_fused,
                                f"vs_replay={t_replay / max(t_fused, 1e-9):.2f}x;"
                                f"vs_two_phase={t_two / max(t_fused, 1e-9):.2f}x;"
                                f"rounds={res_f.rounds}"))
                rows.append(row(f"{base}/host_replay", t_replay,
                                f"n_r={problem.n_r};n_s={problem.n_s}"))
                rows.append(row(f"{base}/two_phase", t_two, ""))
    return rows


def facade_lane(quick=False) -> list[str]:
    """Decompose-once/query-many: the serving claim behind
    `serve --arch nucleus`.  One decompose() builds the artifact; then
    .cut(c) sweeps every level twice — cold (first query per level pays the
    lazy tree walk) and cached (the serving hot path) — against from-scratch
    connectivity per query, plus the JSON round-trip a serving process
    loads."""
    from repro.core import Decomposition
    rows = []
    graphs = suite(["planted1k"] if quick else ["ba2k", "planted1k"])
    for gname, g in graphs.items():
        for (r, s) in [(2, 3)]:
            problem = build_problem(g, r, s)
            if problem.n_r == 0:
                continue
            cfg = NucleusConfig(r=r, s=s, backend="dense", hierarchy="fused")
            dec, t_dec = timed(lambda: decompose(problem, cfg), warmup=1)
            kmax = int(dec.core.max())
            cs = list(range(1, kmax + 1)) or [1]
            rows.append(row(f"facade/{gname}/r{r}s{s}/decompose_once", t_dec,
                            f"n_r={problem.n_r};kmax={kmax}"))

            def cold_sweep():
                # fresh Decomposition over the ALREADY-computed arrays, so
                # the timer covers exactly the lazy tree materialization +
                # first cut per level — not a peel re-run
                d = Decomposition(cfg, problem=problem, core=dec.core,
                                  rounds=dec.rounds,
                                  peel_value=dec.peel_value,
                                  uf_parent=dec.uf_parent, uf_L=dec.uf_L)
                for c in cs:
                    d.cut(c)
                return d

            _, t_cold = timed(cold_sweep)
            rows.append(row(
                f"facade/{gname}/r{r}s{s}/cut_sweep_cold",
                t_cold / len(cs),
                f"qps={len(cs) / max(t_cold, 1e-9):.0f};levels={len(cs)}"))

            def cached_sweep():
                for c in cs:
                    dec.cut(c)

            dec.cut(cs[0])  # materialize tree outside the cached timer
            _, t_hot = timed(cached_sweep, warmup=1)
            rows.append(row(
                f"facade/{gname}/r{r}s{s}/cut_sweep_cached",
                t_hot / len(cs),
                f"qps={len(cs) / max(t_hot, 1e-9):.0f}"))

            def no_hierarchy_sweep():
                for c in cs:
                    nuclei_without_hierarchy(problem, dec.core, c)

            _, t_without = timed(no_hierarchy_sweep)
            rows.append(row(
                f"facade/{gname}/r{r}s{s}/no_hierarchy_sweep",
                t_without / len(cs),
                f"facade_speedup_cold={t_without / max(t_cold, 1e-9):.1f}x;"
                f"cached={t_without / max(t_hot, 1e-9):.1f}x"))

            blob = dec.to_json()
            _, t_load = timed(lambda: Decomposition.from_json(blob))
            rows.append(row(f"facade/{gname}/r{r}s{s}/json_load", t_load,
                            f"bytes={len(blob)}"))
    return rows


def build_lane(quick=False) -> list[str]:
    """Memory-bounded chunked incidence build vs the eager one-burst
    builder: peak memory + wall-clock vs chunk size (DESIGN.md §7).  Every
    cell runs in a fresh subprocess (benchmarks.build_child) so high-water
    marks cannot bleed between configs; the derived column records the
    peak-RSS delta, the builder's own accounted intermediate peak, and
    whether the output digest matches the eager build (it must)."""
    import os
    from .build_child import run_build_child
    rows = []
    MB = 1 << 20
    cells = [("ba2k", 2, 4, [4 * MB, 1 * MB])] if quick else [
        ("ba4k", 2, 3, [32 * MB, 8 * MB]),
        ("ba4k", 2, 4, [16 * MB, 4 * MB]),
        ("planted3k", 2, 4, [64 * MB, 16 * MB]),
    ]
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

    def child(graph, r, s, build, budget=None):
        return run_build_child(root, graph, r, s, build, budget)

    for graph, r, s, budgets in cells:
        base = f"build/{graph}/r{r}s{s}"
        eager = child(graph, r, s, "eager")
        rows.append(row(f"{base}/eager", eager["wall_s"],
                        f"peak_rss_kb={eager['peak_delta_kb']};"
                        f"accounted_kb={eager['accounted_bytes'] // 1024};"
                        f"n_s={eager['n_s']}"))
        for budget in budgets:
            ck = child(graph, r, s, "chunked", budget)
            ok = ck["digest"] == eager["digest"]
            acc_ratio = eager["accounted_bytes"] / max(ck["accounted_bytes"],
                                                       1)
            rss_ratio = (eager["peak_delta_kb"] /
                         max(ck["peak_delta_kb"], 1)
                         if eager["peak_delta_kb"] > 0 and
                         ck["peak_delta_kb"] > 0 else float("nan"))
            rows.append(row(
                f"{base}/chunked_{budget // MB}M", ck["wall_s"],
                f"digest_match={ok};chunks={ck['stats']['n_chunks']};"
                f"chunk_size={ck['stats']['chunk_size']};"
                f"peak_rss_kb={ck['peak_delta_kb']};"
                f"accounted_kb={ck['accounted_bytes'] // 1024};"
                f"mem_vs_eager_accounted={acc_ratio:.1f}x;"
                f"mem_vs_eager_rss={rss_ratio:.1f}x;"
                f"wall_vs_eager={ck['wall_s'] / max(eager['wall_s'], 1e-9):.2f}x"))
    return rows


def distbuild_lane(quick=False) -> list[str]:
    """Sharded incidence build (distbuild, DESIGN.md §13) vs the eager
    one-burst builder: digest parity at several shard counts with per-cell
    peak RSS (fresh subprocess per cell, each with its forced host device
    count), plus the scale-out demo — a planted graph whose *estimated*
    eager build working set exceeds ``memory_budget_bytes`` completes
    ``decompose()`` end-to-end through the sharded build under
    ``backend='auto'``.  The derived columns record the planner's work skew
    and the exchange volume of the count-then-fill CSR assembly."""
    import os
    from .build_child import run_build_child
    from .distbuild_child import run_distbuild_child
    rows = []
    MB = 1 << 20
    cells = [("ba2k", 2, 3, [2, 4])] if quick else [
        ("ba4k", 2, 3, [2, 4, 8]),
        ("planted3k", 2, 4, [4, 8]),
    ]
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

    for graph, r, s, shard_counts in cells:
        base = f"distbuild/{graph}/r{r}s{s}"
        eager = run_build_child(root, graph, r, s, "eager")
        rows.append(row(f"{base}/eager", eager["wall_s"],
                        f"peak_rss_kb={eager['peak_delta_kb']};"
                        f"n_s={eager['n_s']}"))
        for k in shard_counts:
            sh = run_distbuild_child(root, graph, r, s, k)
            ok = sh["digest"] == eager["digest"]
            st = sh["stats"]
            rows.append(row(
                f"{base}/sharded_x{k}", sh["wall_s"],
                f"digest_match={ok};shards={k};"
                f"chunks={st['n_chunks']};skew={st['skew']:.2f};"
                f"exchange_kb={st['exchange_bytes'] // 1024};"
                f"peak_rss_kb={sh['peak_delta_kb']};"
                f"accounted_kb={sh['accounted_bytes'] // 1024};"
                f"wall_vs_eager="
                f"{sh['wall_s'] / max(eager['wall_s'], 1e-9):.2f}x"))

    # end-to-end cell at (2,3), not (2,4): the demo is the BUILD escaping
    # the single-host budget (the (2,3) estimate is still ~1000x over it);
    # a (2,4) peel of planted3k's 5.7M s-cliques on a 1-core CPU container
    # would dominate the lane's wall-clock without testing anything new
    graph, r, s, budget = ("ba2k", 2, 3, 1 * MB) if quick else \
        ("planted3k", 2, 3, 8 * MB)
    e2e = run_distbuild_child(root, graph, r, s, 4, budget=budget,
                              mode="decompose")
    over = e2e["est_eager_bytes"] > e2e["budget"]
    rows.append(row(
        f"distbuild/{graph}/r{r}s{s}/overbudget_decompose", e2e["wall_s"],
        f"build={e2e['build']};backend={e2e['backend']};"
        f"over_budget={over};est_kb={e2e['est_eager_bytes'] // 1024};"
        f"budget_kb={e2e['budget'] // 1024};shards={e2e['n_shards']};"
        f"rounds={e2e['rounds']};core_max={e2e['core_max']}"))
    return rows


def session_lane(quick=False) -> list[str]:
    """Cold ``decompose()`` vs warm ``Session.decompose_many`` over one
    shape bucket: a stream of similar-but-not-identical graphs (every
    (n_r, n_s) distinct, so each cold call pays a fresh engine compile),
    then the same stream through one ``Session`` (first call compiles the
    bucket executable, the rest reuse it).  The derived column records the
    per-graph split and the bucket stats — the number EXPERIMENTS.md's
    "Session lane" quotes."""
    import time

    from repro.core import NucleusConfig, Session

    rows = []
    n_graphs = 4 if quick else 8
    cfg = NucleusConfig(r=2, s=3, backend="dense", hierarchy="fused")
    graphs = {}
    from repro.graph import generators
    for i in range(n_graphs):
        g = generators.planted_cliques(230 + 7 * i, [12, 9, 7], 0.02,
                                       seed=40 + i)
        graphs[f"planted{230 + 7 * i}"] = g
    problems = [build_problem(g, 2, 3) for g in graphs.values()]

    cold_ts = []
    for p in problems:
        t0 = time.perf_counter()
        decompose(p, cfg)
        cold_ts.append(time.perf_counter() - t0)
    sess = Session(cfg)
    warm_ts = []
    for p in problems:
        t0 = time.perf_counter()
        sess.decompose(p)
        warm_ts.append(time.perf_counter() - t0)
    t_cold, t_warm = sum(cold_ts), sum(warm_ts)
    warm_steady = warm_ts[1:] or warm_ts
    rows.append(row("session/cold_decompose_each", t_cold / n_graphs,
                    f"graphs={n_graphs};total_s={t_cold:.2f}"))
    rows.append(row("session/warm_decompose_each",
                    sum(warm_steady) / len(warm_steady),
                    f"first_call_s={warm_ts[0]:.2f};"
                    f"buckets={len(sess.stats['buckets'])};"
                    f"warm_hits={sess.stats['warm']}"))
    rows.append(row(
        "session/whole_stream", t_warm / n_graphs,
        f"session_speedup_total={t_cold / max(t_warm, 1e-9):.1f}x;"
        f"steady_state={(t_cold / n_graphs) / max(sum(warm_steady) / len(warm_steady), 1e-9):.1f}x"))
    return rows


def stream_lane(quick=False) -> list[str]:
    """Incremental ``update(delta)`` vs full re-decompose on a live
    graph: single-edge insert/delete ops run through
    ``Decomposition.update`` (steady-state, after the first op compiles
    the padded local stages) against a fresh ``decompose()`` of each
    edited graph — which pays incidence rebuild plus a per-shape engine
    compile, exactly what a live service pays without the incremental
    path.  The derived column carries the speedup EXPERIMENTS.md's
    stream table quotes (the >=5x single-edge claim on ba4k)."""
    import time

    from repro.core import GraphDelta
    from repro.graph import generators

    rows = []
    if quick:
        g = generators.barabasi_albert(800, 4, seed=11)
        gname, n_ops = "ba800", 3
    else:
        g = suite(["ba4k"])["ba4k"]
        gname, n_ops = "ba4k", 6
    rng = np.random.default_rng(11)
    n = g.n
    for (r, s) in ((1, 2), (2, 3)):
        cfg = NucleusConfig(r=r, s=s, backend="dense", hierarchy="fused")
        dec = decompose(g, cfg)
        es = set(map(tuple, np.asarray(g.edges).tolist()))
        ops = []
        for i in range(n_ops + 1):  # op 0 is the compile warmup
            if i % 2 == 0:
                while True:
                    u, v = sorted(int(x) for x in rng.integers(0, n, 2))
                    if u != v and (u, v) not in es:
                        break
                es.add((u, v))
                ops.append(("insert", u, v))
            else:
                pool = sorted(es)
                u, v = pool[int(rng.integers(len(pool)))]
                es.remove((u, v))
                ops.append(("delete", u, v))
        upd_ts, full_ts = [], []
        for i, (op, u, v) in enumerate(ops):
            delta = GraphDelta(**{op: np.array([[u, v]])})
            t0 = time.perf_counter()
            dec = dec.update(delta)
            dt = time.perf_counter() - t0
            if i == 0:
                continue
            upd_ts.append(dt)
            # every edit shifts the shape, so each fresh decompose pays
            # the compile a live service would pay per edit
            t0 = time.perf_counter()
            decompose(dec.problem.g, cfg)
            full_ts.append(time.perf_counter() - t0)
        upd, full = float(np.median(upd_ts)), float(np.median(full_ts))
        st = dec.update_stats
        rows.append(row(
            f"stream/{gname}_r{r}s{s}_update", upd,
            f"ops={len(upd_ts)};candidates_last={st.candidates};"
            f"speedup_vs_full={full / max(upd, 1e-9):.1f}x"))
        rows.append(row(
            f"stream/{gname}_r{r}s{s}_full_redecompose", full,
            f"n={n};edges={int(dec.problem.g.edges.shape[0])}"))
    return rows


def server_lane(quick=False) -> list[str]:
    """Multi-tenant server claims (DESIGN.md §11): the persistent-cache
    restart warm path (fresh subprocess per cell — cold in-memory jit
    caches are the measurand) and coalesced-batch throughput through the
    Frontend vs one-at-a-time routing."""
    import os
    import tempfile
    import time as _time

    from repro.core import build_problem
    from repro.graph import generators
    from repro.serve import Frontend, Request, Router
    from .serve_child import run_serve_child

    rows = []
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

    # -- restart warm path: cold process vs pre-warmed restart ------------
    with tempfile.TemporaryDirectory(prefix="nucleus-bench-cache-") as cd:
        cold = run_serve_child(root, "cold", cache_dir="")
        run_serve_child(root, "seed", cache_dir=cd)  # the "previous run"
        warm = run_serve_child(root, "warm", cache_dir=cd)
    speedup = cold["wall_s"] / max(warm["wall_s"], 1e-9)
    rows.append(row(
        "server/restart_cold_first_decompose", cold["wall_s"],
        f"n_r={cold['n_r']};kmax={cold['kmax']}"))
    rows.append(row(
        "server/restart_warm_first_decompose", warm["wall_s"],
        f"prewarmed_buckets={warm['prewarmed']};"
        f"prewarm_s={warm['prewarm_s']:.3f};"
        f"speedup_vs_cold={speedup:.1f}x"))

    # -- coalesced-batch throughput vs a one-at-a-time request loop -------
    # both cells go through the server path (Frontend -> Router -> warm
    # Session); one-at-a-time pays the worker wakeup + batch window per
    # request, the burst submit lands in one coalesced decompose_many
    n_graphs = 4 if quick else 8
    router = Router()
    mk = lambda i: Request(graph=build_problem(
        generators.planted_cliques(118 + 2 * i, [10, 8, 6], 0.03,
                                   seed=10 + i), 2, 3), r=2, s=3)
    router.route(mk(0))  # warm the shared bucket (compile excluded)
    front = Frontend(router).start()
    serial_reqs = [mk(i) for i in range(1, n_graphs + 1)]
    t0 = _time.perf_counter()
    for req in serial_reqs:
        front.submit_wait(req)
    serial = _time.perf_counter() - t0
    batch_reqs = [mk(i) for i in range(n_graphs + 1, 2 * n_graphs + 1)]
    t0 = _time.perf_counter()
    futs = [front.submit(req) for req in batch_reqs]
    for f in futs:
        f.result(timeout=300)
    coalesced = _time.perf_counter() - t0
    stats = dict(front.stats)
    front.stop()
    rows.append(row(
        "server/batch_one_at_a_time_per_graph", serial / n_graphs,
        f"graphs={n_graphs}"))
    rows.append(row(
        "server/batch_coalesced_per_graph", coalesced / n_graphs,
        f"graphs={n_graphs};coalesced={stats['coalesced']};"
        f"speedup_vs_one_at_a_time="
        f"{serial / max(coalesced, 1e-9):.2f}x"))
    return rows


ALL = {
    "fig6": fig6_variants,
    "fig7": fig7_grid,
    "fig8": fig8_scaling,
    "fig9": fig9_baselines,
    "fig10": fig10_nuclei,
    "approx": approx_quality,
    "engine": engine_lane,
    "hierarchy": hierarchy_lane,
    "facade": facade_lane,
    "build": build_lane,
    "distbuild": distbuild_lane,
    "session": session_lane,
    "stream": stream_lane,
    "server": server_lane,
}
