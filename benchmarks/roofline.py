"""Roofline analysis from dry-run artifacts (TPU v5e targets).

Per (arch x shape x mesh) artifact:
  compute term    = HLO_FLOPs / peak_FLOPs        [s]
  memory term     = HLO_bytes / HBM_bw            [s]
  collective term = collective_bytes / link_bw    [s]
(cost_analysis numbers come from the per-device SPMD module, so terms are
per-chip directly; the assignment's /chips normalization is equivalent.)

Also reports MODEL_FLOPS (6·N·D dense / 6·N_active·D MoE, and analytic
per-family estimates for GNN/recsys/nucleus) and the useful-compute ratio
MODEL_FLOPS / (HLO_FLOPs * chips).
"""
from __future__ import annotations

import glob
import json
import os
from math import comb
from typing import Dict, Optional

import numpy as np

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link (per chip, per direction)

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "artifacts")


def _lm_model_flops(arch_id: str, kind: str, dims: Dict[str, int]) -> float:
    from repro.configs import get_arch
    spec = get_arch(arch_id)
    cfg = spec.make_config()
    n_active = cfg.active_param_count()
    B, S = dims["global_batch"], dims["seq_len"]
    if kind == "train":
        return 6.0 * n_active * B * S
    if kind == "prefill":
        return 2.0 * n_active * B * S
    # decode: one token per sequence + attention over the cache
    attn_reads = 4.0 * cfg.n_layers * B * S * cfg.n_kv_heads * cfg.head_dim
    return 2.0 * n_active * B + attn_reads


def _gnn_model_flops(arch_id: str, dims: Dict[str, int]) -> float:
    from repro.configs import get_arch
    spec = get_arch(arch_id)
    cfg = spec.make_config()
    if "batch" in dims and "n_nodes" in dims and dims.get("batch"):
        N = dims["n_nodes"] * dims["batch"]
        E = dims["n_edges"] * dims["batch"]
    else:
        N, E = dims["n_nodes"], dims["n_edges"]
    d_in = dims.get("d_feat", 16)
    h = cfg.d_hidden
    if arch_id == "gin-tu":
        per_node = d_in * h + h * h + (cfg.n_layers - 1) * 2 * h * h
        return 6.0 * (N * per_node + E * h)
    if arch_id == "egnn":
        per_edge = cfg.n_layers * (2 * h + 1 + h) * h     # phi_e ~ 2 layers
        per_node = cfg.n_layers * 3 * h * h + d_in * h
        return 6.0 * (E * per_edge + N * per_node)
    if arch_id == "dimenet":
        T = E * dims.get("triplet_cap", 8)
        nb, nsbf = cfg.n_bilinear, cfg.n_spherical * cfg.n_radial
        per_trip = nb * h * h + nsbf * nb + h * h
        per_edge = cfg.n_blocks * 4 * h * h + (2 * h + cfg.n_radial) * h
        return 6.0 * (T * per_trip * cfg.n_blocks + E * per_edge)
    if arch_id == "mace":
        C = cfg.d_hidden
        paths = cfg.n_paths * 13            # scalar+vec+tensor component muls
        per_edge = cfg.n_layers * (cfg.n_rbf * 64 + 64 * paths * C / 64 + paths * C)
        per_node = cfg.n_layers * (20 * C + 6 * C * C)
        return 6.0 * (E * per_edge + N * per_node)
    raise ValueError(arch_id)


def _recsys_model_flops(kind: str, dims: Dict[str, int]) -> float:
    from repro.configs import get_arch
    cfg = get_arch("din").make_config()
    d = cfg.embed_dim
    attn_p = 8 * d * cfg.attn_mlp[0] + cfg.attn_mlp[0] * cfg.attn_mlp[1] \
        + cfg.attn_mlp[1]
    mlp_p = 5 * d * cfg.mlp[0] + cfg.mlp[0] * cfg.mlp[1] + cfg.mlp[1]
    per_req = cfg.seq_len * (attn_p + 2 * d) + mlp_p
    B = dims.get("n_candidates") or dims.get("batch", 1)
    mult = 6.0 if kind == "train" else 2.0
    return mult * B * per_req


def _nucleus_model_flops(dims: Dict[str, int]) -> float:
    # useful integer work: each incidence entry read+decremented once
    return 2.0 * dims["n_s"] * dims["C"]


def model_flops(arch_id: str, kind: str, shape_name: str) -> Optional[float]:
    from repro.configs import get_arch
    spec = get_arch(arch_id)
    dims = spec.shape(shape_name).dims
    if spec.family == "lm":
        return _lm_model_flops(arch_id, kind, dims)
    if spec.family == "gnn":
        return _gnn_model_flops(arch_id, dims)
    if spec.family == "recsys":
        return _recsys_model_flops(kind, dims)
    if spec.family == "core":
        return _nucleus_model_flops(dims)
    return None


def analyze_artifact(path: str) -> Optional[Dict]:
    with open(path) as f:
        art = json.load(f)
    if art.get("status") != "ok":
        return {"arch": art.get("arch"), "shape": art.get("shape"),
                "mesh": art.get("mesh"), "status": art.get("status"),
                "skip_reason": art.get("skip_reason"),
                "error": art.get("error"), "tag": art.get("tag", "")}
    cost = art.get("cost_extrapolated") or art["cost"]
    flops_dev = cost.get("flops") or 0.0
    bytes_dev = cost.get("bytes accessed") or 0.0
    coll_dev = art.get("collective_bytes_total_extrapolated",
                       art["collective_bytes_total"])
    chips = art["n_devices"]
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(art["arch"], art["kind"], art["shape"])
    useful = (mf / (flops_dev * chips)) if (mf and flops_dev) else None
    bound = max(terms.values())
    # roofline fraction: useful model flops at peak vs the bound term
    frac = None
    if mf and bound > 0:
        frac = (mf / chips / PEAK_FLOPS) / bound
    return {
        "arch": art["arch"], "shape": art["shape"], "mesh": art["mesh"],
        "status": "ok", "kind": art["kind"], "tag": art.get("tag", ""),
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "dominant": dominant, "bound_s": bound,
        "model_flops": mf, "hlo_flops_per_dev": flops_dev,
        "useful_compute_ratio": useful, "roofline_fraction": frac,
        "collectives": art.get("collectives_extrapolated",
                               art.get("collectives", {})),
        "memory": art.get("memory", {}),
        "extrapolated": "cost_extrapolated" in art,
    }


def full_table(tag: str = "", mesh: str = "pod16x16") -> list[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(ARTIFACT_DIR, "*.json"))):
        base = os.path.basename(path)
        if mesh not in base:
            continue
        if tag and not base.endswith(f"{mesh}-{tag}.json"):
            continue
        if not tag and not base.endswith(f"{mesh}.json"):
            continue
        r = analyze_artifact(path)
        if r:
            rows.append(r)
    return rows


def format_table(rows: list[Dict]) -> str:
    out = [f"{'arch':24s} {'shape':16s} {'dom':10s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'collect_s':>10s} {'useful':>7s} "
           f"{'roofline':>8s}"]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"{r['arch']:24s} {r['shape']:16s} "
                       f"[{r['status']}: {str(r.get('skip_reason') or r.get('error'))[:60]}]")
            continue
        u = f"{r['useful_compute_ratio']:.3f}" if r["useful_compute_ratio"] else "-"
        f = f"{r['roofline_fraction']:.3f}" if r["roofline_fraction"] else "-"
        out.append(
            f"{r['arch']:24s} {r['shape']:16s} {r['dominant']:10s} "
            f"{r['compute_s']:10.4f} {r['memory_s']:10.4f} "
            f"{r['collective_s']:10.4f} {u:>7s} {f:>8s}")
    return "\n".join(out)


def main() -> None:
    import argparse
    import sys
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--tag", default="")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    rows = full_table(tag=args.tag, mesh=args.mesh)
    if args.json:
        print(json.dumps(rows, indent=2))
    else:
        print(format_table(rows))
    # failed dry-run cells must redden the lane, not silently thin the table
    errors = [r for r in rows if r.get("status") == "error"]
    if errors:
        for r in errors:
            print(f"ERROR artifact {r['arch']}--{r['shape']}--{r['mesh']}: "
                  f"{str(r.get('error'))[:200]}", file=sys.stderr)
        print(f"{len(errors)} artifact(s) have status=error; re-run "
              "repro.launch.dryrun (error artifacts are retried "
              "automatically).", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
