"""Perf hillclimbing driver: lower a cell with an optimization variant
(tagged), then print the before/after roofline terms.

    PYTHONPATH=src python -m benchmarks.hillclimb --cell minicpm --iter 1

Each iteration is a (hypothesis, change) pair registered below; results land
as tagged artifacts next to the baselines and are summarized for
EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _specs_lm_context_parallel(mesh_axes=("data", "model")):
    from jax.sharding import PartitionSpec as P
    dp = ("data",)
    return (
        # context-parallel attention: shard SEQUENCE over "model" for the
        # attention tensors (heads may not divide the axis; sequence always
        # does), keep kv gathered; residual stream shards d over "model".
        ("act_q", P(dp, "model", None, None)),
        ("act_kv", P(dp, None, None, None)),
        ("act_resid", P(dp, None, "model")),
    )


def _specs_resid_only():
    from jax.sharding import PartitionSpec as P
    return (("act_resid", P(("data",), None, "model")),)


def _specs_moe_dispatch():
    from jax.sharding import PartitionSpec as P
    return (("act_moe_disp", P("model", "data", None)),
            ("act_resid", P(("data",), None, "model")))


def _specs_moe_ep_data():
    from jax.sharding import PartitionSpec as P
    return (("act_moe_disp", P("data", None, "model")),
            ("act_resid", P(("data",), None, "model")))


def _specs_moe_ep_data_cp():
    from jax.sharding import PartitionSpec as P
    dp = ("data",)
    return (("act_moe_disp", P("data", None, "model")),
            ("act_resid", P(dp, None, "model")),
            ("act_q", P(dp, "model", None, None)),
            ("act_kv", P(dp, None, None, None)))


def _specs_moe_dispatch_cp():
    from jax.sharding import PartitionSpec as P
    dp = ("data",)
    return (("act_moe_disp", P("model", "data", None)),
            ("act_resid", P(dp, None, "model")),
            ("act_q", P(dp, "model", None, None)),
            ("act_kv", P(dp, None, None, None)))


EXPERIMENTS = {
    # cell key: (arch, shape, iteration -> (tag, overrides, hypothesis))
    "minicpm": ("minicpm-2b", "train_4k", {
        1: ("cp-attn",
            lambda: {"act_specs": _specs_lm_context_parallel()},
            "attention activations replicate across 'model' (36 heads % 16 "
            "!= 0 blocks head sharding; GSPMD gives up) -> shard the "
            "SEQUENCE dim of q/attn-out over 'model' (context parallelism) "
            "and the residual stream's d over 'model'. Predict: compute "
            "term ~4x down (attn no longer replicated), memory term 5-10x "
            "down (the (B,Sq,H,chunk) softmax intermediates shard 16x), "
            "collective term up mildly (kv all-gathers)."),
        2: ("cp-attn-bf16",
            lambda: {"act_specs": _specs_lm_context_parallel(),
                     "attn_chunk": 2048},
            "larger attention chunk (1024->2048) halves the number of "
            "mask/stat passes per token; predict memory term down ~15%, "
            "compute flat."),
        4: ("fused-softmax",
            lambda: {"act_specs": _specs_lm_context_parallel()},
            "the memory term is dominated by elementwise passes over the "
            "(B,Sq,H,chunk) score tensor (~8 full passes/chunk in the "
            "online softmax: 2 wheres + isfinite guards + f32 PV). "
            "Restructure: additive (B,Sq,chunk) mask bias, finite -1e30 "
            "sentinel (no guards), bf16 probabilities into the PV matmul "
            "(code change in transformer.online_attention, applies to all "
            "LM archs). Predict: memory term ~25-35% down, compute flat."),
        5: ("bf16-dot",
            lambda: {"act_specs": _specs_lm_context_parallel()},
            "per-op byte profile of iter 4: 'convert' (608 GB / 2 layers) "
            "and copy/transpose (~400 GB) dominate — the per-chunk "
            "bf16->f32 operand upcasts and the attention moveaxis churn. "
            "Rewrite online_attention: bf16 x bf16 dot_general with f32 "
            "accumulation (MXU-native), single in/out transposes, scale "
            "folded into the bias add. Predict: memory term 30-40% down, "
            "compute flat."),
        3: ("resid-only",
            lambda: {"act_specs": _specs_resid_only()},
            "ablation: residual-stream sharding alone (no context "
            "parallelism) — isolates how much of iter-1's win came from "
            "the resid constraint vs the attention sharding."),
    }),
    "moonshot": ("moonshot-v1-16b-a3b", "train_4k", {
        1: ("moe-disp",
            lambda: {"act_specs": _specs_moe_dispatch()},
            "the (E, cap, d) MoE dispatch buffers carry no sharding "
            "constraint -> GSPMD replicates expert matmuls across the "
            "'data' axis (16x waste on the FFN ~ the dominant flops). "
            "Constrain dispatch P(model, data, None) so E shards over "
            "'model' (EP) and capacity over 'data'. Predict: compute term "
            "~10x down, memory term ~5x down, collective term down "
            "(smaller gathered buffers)."),
        2: ("moe-disp-cp",
            lambda: {"act_specs": _specs_moe_dispatch_cp()},
            "stack context-parallel attention (iter minicpm/1) on top of "
            "the dispatch fix; predict further memory reduction from "
            "sharded softmax intermediates."),
        3: ("ep-data",
            lambda: {"act_specs": _specs_moe_ep_data(),
                     "moe_ep_data": True},
            "iter 1 removed the replicated expert compute but GSPMD "
            "lowered the cross-axis dispatch as ~6 TB of all-gathers "
            "(experts over 'model' vs tokens over 'data' forces every "
            "token row across the mesh). Re-layout: experts over 'data' "
            "(the token axis — dispatch becomes an intra-axis all-to-all "
            "pattern) and TP WITHIN each expert over 'model'. Predict: "
            "all-gather bytes ~10x down, collective term < memory term."),
        4: ("ep-data-cp",
            lambda: {"act_specs": _specs_moe_ep_data_cp(),
                     "moe_ep_data": True},
            "stack context-parallel attention on the ep-data layout; "
            "predict memory term down (attention intermediates shard) "
            "with collectives flat."),
    }),
    "nucleus": ("nucleus", "orkut_23", {
        1: ("ar16",
            lambda: {"compress": True},
            "the per-round (n_r,) int32 delta all-reduce dominates "
            "(collective-bound cell). Send int16 with per-shard saturation "
            "+ error feedback (remainder re-sent next round; exactness "
            "proven by monotone peel levels). Predict: collective term "
            "2x down, compute/memory unchanged."),
    }),
    "deepseek": ("deepseek-v2-lite-16b", "train_4k", {
        1: ("ep-data",
            lambda: {"act_specs": _specs_moe_ep_data(),
                     "moe_ep_data": True},
            "transfer moonshot/3's winning layout (EP over 'data', TP "
            "inside experts over 'model', dispatch constrained) to the "
            "MLA+MoE arch; predict compute ~3x down, memory ~2x down."),
        2: ("ep-data-cp",
            lambda: {"act_specs": _specs_moe_ep_data_cp(),
                     "moe_ep_data": True},
            "stack context-parallel attention (moonshot/4, minicpm/1); "
            "MLA's q/k are (B,S,16,192) with shared-rope broadcast "
            "intermediates — predict memory down another ~2x."),
    }),
}


def show(arch, shape, tags):
    from . import roofline
    print(f"\n=== {arch} x {shape} ===")
    base = roofline.analyze_artifact(os.path.join(
        roofline.ARTIFACT_DIR, f"{arch}--{shape}--pod16x16.json"))
    rows = [("baseline", base)]
    for t in tags:
        p = os.path.join(roofline.ARTIFACT_DIR,
                         f"{arch}--{shape}--pod16x16-{t}.json")
        if os.path.exists(p):
            rows.append((t, roofline.analyze_artifact(p)))
    print(f"{'variant':16s} {'dom':10s} {'compute_s':>10s} {'memory_s':>10s}"
          f" {'collect_s':>10s} {'useful':>7s} {'roofline':>9s}")
    for name, r in rows:
        if r.get("status") != "ok":
            print(f"{name:16s} ERROR {r.get('error')}")
            continue
        u = r.get("useful_compute_ratio")
        f = r.get("roofline_fraction")
        print(f"{name:16s} {r['dominant']:10s} {r['compute_s']:10.3f} "
              f"{r['memory_s']:10.3f} {r['collective_s']:10.3f} "
              f"{u or 0:7.3f} {f or 0:9.4f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=sorted(EXPERIMENTS))
    ap.add_argument("--iter", type=int, default=0,
                    help="0 = just show the comparison table")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    arch, shape, iters = EXPERIMENTS[args.cell]
    if args.iter:
        tag, overrides_fn, hypothesis = iters[args.iter]
        print(f"HYPOTHESIS: {hypothesis}\n")
        from repro.launch.dryrun import run_cell, artifact_path
        path = artifact_path(arch, shape, False, tag)
        if os.path.exists(path) and not args.force:
            print(f"cached: {path}")
        else:
            res = run_cell(arch, shape, False,
                           opt_overrides=overrides_fn(), tag=tag)
            with open(path, "w") as f:
                json.dump(res, f, indent=2)
            print(f"wrote {path}: {res.get('status')}"
                  f" {res.get('error', '')}")
    show(arch, shape, [t for t, _, _ in iters.values()])


if __name__ == "__main__":
    main()
