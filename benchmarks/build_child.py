"""Subprocess worker for the `build` bench lane and `make bench-build`.

Builds ONE (graph, r, s) incidence structure with the requested builder in a
fresh process and prints a JSON record:

  wall_s            build wall-clock (graph generation excluded)
  peak_delta_kb     VmHWM after the build minus VmRSS right before it — the
                    build's own high-water contribution.  ``masked`` is true
                    when the import phase already peaked higher (the build
                    never moved the high-water mark), in which case
                    ``peak_delta_kb`` only bounds the build from above.
  accounted_bytes   the builder's own intermediate-memory meter
                    (``build_stats['peak_intermediate_bytes']``) —
                    deterministic, allocator-independent
  digest            SHA-256 over the five output arrays + orientation: the
                    bit-identity fingerprint the eager/chunked comparison
                    and the CI budget gate (tools/check_build_budget.py) use

A fresh process per cell is the only honest way to compare high-water marks
across builder configs.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import subprocess
import sys
import time

import numpy as np


def run_build_child(root: str, graph: str, r: int, s: int, build: str,
                    budget: int | None = None,
                    chunk_size: int | None = None,
                    timeout: int = 1200) -> dict:
    """Launch this module in a fresh subprocess and parse its JSON record.

    The one launcher shared by the `build` bench lane and the
    `make bench-build` CI gate (tools/check_build_budget.py)."""
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, "-m", "benchmarks.build_child", "--graph", graph,
           "--r", str(r), "--s", str(s), "--build", build]
    if budget is not None:
        cmd += ["--budget", str(budget)]
    if chunk_size is not None:
        cmd += ["--chunk-size", str(chunk_size)]
    out = subprocess.run(cmd, cwd=root, env=env, capture_output=True,
                         text=True, check=True, timeout=timeout)
    return json.loads(out.stdout.strip().splitlines()[-1])


def _proc_status_kb(field: str) -> int:
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith(field + ":"):
                    return int(line.split()[1])
    except OSError:
        pass
    if field == "VmHWM":  # some sandboxed kernels omit VmHWM; rusage has it
        import resource
        return int(resource.getrusage(resource.RUSAGE_SELF).ru_maxrss)
    return -1


def problem_digest(problem) -> str:
    h = hashlib.sha256()
    for f in ("r_cliques", "inc_rid", "mem_offsets", "mem_sids", "deg0"):
        a = np.ascontiguousarray(np.asarray(getattr(problem, f)))
        h.update(f.encode())
        h.update(str(a.shape).encode())
        h.update(str(a.dtype).encode())
        h.update(a.tobytes())
    h.update(problem.orientation.encode())
    return h.hexdigest()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", required=True, help="benchmarks.common suite name")
    ap.add_argument("--r", type=int, required=True)
    ap.add_argument("--s", type=int, required=True)
    ap.add_argument("--build", default="eager", choices=["eager", "chunked"])
    ap.add_argument("--budget", type=int, default=None,
                    help="memory_budget_bytes for build=chunked")
    ap.add_argument("--chunk-size", type=int, default=None)
    args = ap.parse_args()

    from benchmarks.common import suite
    from repro.core.incidence import build_problem

    g = suite([args.graph])[args.graph]
    kw = {}
    if args.build == "chunked":
        kw = {"memory_budget_bytes": args.budget,
              "chunk_size": args.chunk_size}

    rss0 = _proc_status_kb("VmRSS")
    hwm0 = _proc_status_kb("VmHWM")
    t0 = time.perf_counter()
    problem = build_problem(g, args.r, args.s, build=args.build, **kw)
    wall = time.perf_counter() - t0
    hwm1 = _proc_status_kb("VmHWM")

    print(json.dumps({
        "graph": args.graph, "r": args.r, "s": args.s, "build": args.build,
        "budget": args.budget, "n_r": problem.n_r, "n_s": problem.n_s,
        "wall_s": wall,
        "peak_delta_kb": (hwm1 - rss0) if (hwm1 > 0 and rss0 > 0) else -1,
        "masked": bool(hwm1 > 0 and hwm1 == hwm0 and hwm0 > rss0),
        "accounted_bytes": int(
            problem.build_stats["peak_intermediate_bytes"]),
        "stats": problem.build_stats,
        "orientation": problem.orientation,
        "digest": problem_digest(problem),
    }))


if __name__ == "__main__":
    main()
