"""Subprocess worker for the `server` bench lane (the restart claim).

Each mode runs in a FRESH process — cold in-memory jit caches are the
whole point — and times the server's first decompose on a PREBUILT
problem (the Session-lane convention: the build stage has its own lane,
the timer isolates what the warm path saves — compile + execute):

  cold   no persistent cache: a from-scratch server process, the
         baseline the restart claim is measured against.
  seed   persistent compilation cache enabled: the same cold first
         decompose, but compiles land in --cache-dir and the router's
         session manifest is saved on exit — the "previous server run".
  warm   persistent cache + manifest: a restarted server.  The pools
         are pre-warmed from the manifest (all-ghost problems replay
         the exact jit keys, so XLA loads compiles from disk instead of
         building them), then the first real decompose is timed.

Prints one JSON record on stdout; `run_serve_child` is the launcher the
bench lane uses.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time


def run_serve_child(root: str, mode: str, cache_dir: str,
                    r: int = 2, s: int = 3,
                    timeout: int = 1200) -> dict:
    """Launch this module in a fresh subprocess and parse its JSON record."""
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, "-m", "benchmarks.serve_child", "--mode", mode,
           "--r", str(r), "--s", str(s)]
    if cache_dir:
        cmd += ["--cache-dir", cache_dir]
    out = subprocess.run(cmd, cwd=root, env=env, capture_output=True,
                         text=True, check=True, timeout=timeout)
    return json.loads(out.stdout.strip().splitlines()[-1])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", required=True,
                    choices=["cold", "seed", "warm"])
    ap.add_argument("--cache-dir", default="",
                    help="persistent cache + manifest dir (seed/warm)")
    ap.add_argument("--r", type=int, default=2)
    ap.add_argument("--s", type=int, default=3)
    args = ap.parse_args()
    if args.mode in ("seed", "warm") and not args.cache_dir:
        raise SystemExit(f"--mode {args.mode} requires --cache-dir")

    from repro.core.incidence import build_problem
    from repro.graph import generators
    from repro.serve import (Request, Router, init_persistent_cache,
                             load_manifest, prewarm_router, save_manifest)

    # the selftest/warm-pool graph class: same shapes across modes, so
    # the warm child's manifest buckets match the graph it then serves
    g = generators.planted_cliques(120, [10, 8, 6], 0.03, seed=3)
    problem = build_problem(g, args.r, args.s)

    router = Router()
    prewarm_s, prewarmed = 0.0, 0
    if args.cache_dir:
        init_persistent_cache(args.cache_dir)
    if args.mode == "warm":
        manifest = load_manifest(args.cache_dir)
        if manifest is None:
            raise SystemExit(
                f"no session manifest in {args.cache_dir}; run a seed "
                f"child first")
        t0 = time.perf_counter()
        prewarmed = prewarm_router(router, manifest)
        prewarm_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    dec = router.route(Request(graph=problem, r=args.r, s=args.s))
    wall = time.perf_counter() - t0

    if args.mode == "seed":
        save_manifest(router, args.cache_dir)

    stats = router.report()["pools"][0]["stats"]
    print(json.dumps({
        "mode": args.mode, "r": args.r, "s": args.s,
        "wall_s": wall, "prewarm_s": prewarm_s, "prewarmed": prewarmed,
        "warm": stats["warm"], "cold": stats["cold"],
        "n_r": dec.n_r,
        "kmax": int(dec.core.max()) if dec.n_r else 0,
    }))


if __name__ == "__main__":
    main()
