"""Benchmark plumbing: timed runs + the standard graph suite.

The paper's SNAP graphs are offline; the suite substitutes synthetic graphs
with matched *structure* (power-law BA for social-like graphs, planted
cliques for nucleus-rich structure, ER for background) at CPU-tractable
scale.  Every benchmark prints `name,us_per_call,derived` CSV rows.
"""
from __future__ import annotations

import time
from typing import Callable, Dict

import numpy as np

from repro.graph import generators, Graph

_SUITE: Dict[str, Callable[[], Graph]] = {
    "ba2k": lambda: generators.barabasi_albert(2_000, 8, seed=1),
    "er2k": lambda: generators.erdos_renyi_sparse(2_000, 16_000, seed=2),
    "planted1k": lambda: generators.planted_cliques(
        1_000, [24, 18, 14, 10], 0.01, seed=3),
    "ba4k": lambda: generators.barabasi_albert(4_000, 8, seed=7),
    "ba5k": lambda: generators.barabasi_albert(5_000, 6, seed=4),
    # nucleus-rich at build-bench scale: the planted 100-clique makes the
    # eager (2,4) expansion's intermediate candidate arrays ~100 MB — the
    # memory-headroom demo for the chunked incidence builder
    "planted3k": lambda: generators.planted_cliques(
        3_000, [100, 80, 60], 0.02, seed=5),
}

_CACHE: Dict[str, Graph] = {}


def suite(names=None) -> Dict[str, Graph]:
    names = names or list(_SUITE)
    for n in names:
        if n not in _CACHE:
            _CACHE[n] = _SUITE[n]()
    return {n: _CACHE[n] for n in names}


def timed(fn: Callable, repeats: int = 1, warmup: int = 0):
    for _ in range(warmup):
        fn()
    ts = []
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        ts.append(time.perf_counter() - t0)
    return out, min(ts)


def row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.0f},{derived}"
