"""Subprocess worker for the `distbuild` bench lane.

Two modes, each in a fresh process with ``XLA_FLAGS`` forcing the requested
host device count (the same idiom the sharded tests use):

  build      build ONE (graph, r, s) incidence structure with the sharded
             builder (``build='sharded'``) and print the same JSON record as
             ``benchmarks.build_child`` — wall_s / peak_delta_kb / masked /
             accounted_bytes / digest — plus the sharded ``build_stats``
             block (chunks_per_shard, skew, exchange_bytes).  The lane
             compares the digest against the eager build's: they must match
             bit-for-bit at every shard count.

  decompose  the over-budget end-to-end demo: run ``decompose()`` under
             ``backend='auto'`` with a ``memory_budget_bytes`` the eager
             build's estimated working set exceeds, so the resolver upgrades
             the build to 'sharded' and the plan peels on the same sharded
             slabs.  The record carries the estimate, the resolved
             build/backend, and a digest of the core array.

A fresh process per cell is the only honest way to compare high-water marks
across builder configs, and the only way to vary the forced device count.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import subprocess
import sys
import time

import numpy as np


def run_distbuild_child(root: str, graph: str, r: int, s: int, shards: int,
                        budget: int | None = None,
                        chunk_size: int | None = None,
                        mode: str = "build",
                        timeout: int = 1800) -> dict:
    """Launch this module in a fresh subprocess (with ``shards`` forced
    host devices) and parse its JSON record."""
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    flags = env.get("XLA_FLAGS", "")
    env["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={shards}").strip()
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, "-m", "benchmarks.distbuild_child",
           "--graph", graph, "--r", str(r), "--s", str(s),
           "--shards", str(shards), "--mode", mode]
    if budget is not None:
        cmd += ["--budget", str(budget)]
    if chunk_size is not None:
        cmd += ["--chunk-size", str(chunk_size)]
    out = subprocess.run(cmd, cwd=root, env=env, capture_output=True,
                        text=True, check=True, timeout=timeout)
    return json.loads(out.stdout.strip().splitlines()[-1])


def _build_cell(args) -> dict:
    from benchmarks.build_child import _proc_status_kb, problem_digest
    from benchmarks.common import suite
    from repro.core.incidence import build_problem

    g = suite([args.graph])[args.graph]
    rss0 = _proc_status_kb("VmRSS")
    hwm0 = _proc_status_kb("VmHWM")
    t0 = time.perf_counter()
    problem = build_problem(g, args.r, args.s, build="sharded",
                            shards=args.shards,
                            memory_budget_bytes=args.budget,
                            chunk_size=args.chunk_size)
    wall = time.perf_counter() - t0
    hwm1 = _proc_status_kb("VmHWM")
    return {
        "graph": args.graph, "r": args.r, "s": args.s, "build": "sharded",
        "shards": args.shards, "budget": args.budget,
        "n_r": problem.n_r, "n_s": problem.n_s,
        "wall_s": wall,
        "peak_delta_kb": (hwm1 - rss0) if (hwm1 > 0 and rss0 > 0) else -1,
        "masked": bool(hwm1 > 0 and hwm1 == hwm0 and hwm0 > rss0),
        "accounted_bytes": int(
            problem.build_stats["peak_intermediate_bytes"]),
        "stats": problem.build_stats,
        "orientation": problem.orientation,
        "digest": problem_digest(problem),
    }


def _decompose_cell(args) -> dict:
    from benchmarks.common import suite
    from repro.core import NucleusConfig, decompose
    from repro.core.incidence import pick_rank
    from repro.distbuild import estimate_eager_build_bytes

    g = suite([args.graph])[args.graph]
    dg, _ = pick_rank(g)
    est = int(estimate_eager_build_bytes(dg, args.s))
    cfg = NucleusConfig(r=args.r, s=args.s, backend="auto",
                        memory_budget_bytes=args.budget)
    t0 = time.perf_counter()
    dec = decompose(g, cfg)
    wall = time.perf_counter() - t0
    stats = (dec.problem.build_stats or {}) if dec.problem is not None else {}
    core = np.ascontiguousarray(np.asarray(dec.core))
    return {
        "graph": args.graph, "r": args.r, "s": args.s, "mode": "decompose",
        "budget": args.budget, "est_eager_bytes": est,
        "build": stats.get("build"), "n_shards": stats.get("n_shards"),
        "skew": stats.get("skew"),
        "backend": None if dec.plan is None else dec.plan.backend,
        "wall_s": wall, "rounds": int(dec.rounds),
        "n_r": int(core.shape[0]), "core_max": int(core.max(initial=0)),
        "core_digest": hashlib.sha256(core.tobytes()).hexdigest(),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", required=True,
                    help="benchmarks.common suite name")
    ap.add_argument("--r", type=int, required=True)
    ap.add_argument("--s", type=int, required=True)
    ap.add_argument("--shards", type=int, required=True,
                    help="shard count (launcher forces this many devices)")
    ap.add_argument("--budget", type=int, default=None,
                    help="memory_budget_bytes (planner chunk sizing; "
                         "decompose mode: the auto-upgrade threshold)")
    ap.add_argument("--chunk-size", type=int, default=None)
    ap.add_argument("--mode", default="build",
                    choices=["build", "decompose"])
    args = ap.parse_args()

    rec = _build_cell(args) if args.mode == "build" else _decompose_cell(args)
    print(json.dumps(rec))


if __name__ == "__main__":
    main()
