"""Apply the winning §Perf recipe to every LM cell -> tagged '-opt' artifacts.

Recipe (from the hillclimb log):
  dense archs:  context-parallel attention + model-sharded residual
  MoE archs:    + experts-over-data (EP on the token axis, TP inside experts)
"""
from __future__ import annotations

import json
import os


def main() -> None:
    from repro.launch.dryrun import run_cell, artifact_path
    from jax.sharding import PartitionSpec as P
    dp = ("data",)
    cp = (("act_q", P(dp, "model", None, None)),
          ("act_kv", P(dp, None, None, None)),
          ("act_resid", P(dp, None, "model")))
    moe = cp + (("act_moe_disp", P("data", None, "model")),)
    # decode (Sq == 1): sequence sharding is meaningless — constrain only
    # the residual stream; MoE keeps the EP-over-data layout
    resid = (("act_resid", P(dp, None, "model")),)
    moe_resid = resid + (("act_moe_disp", P("data", None, "model")),)
    plans = {
        "stablelm-12b": ({"act_specs": cp}, {"act_specs": resid}),
        "minicpm-2b": ({"act_specs": cp}, {"act_specs": resid}),
        "minitron-4b": ({"act_specs": cp}, {"act_specs": resid}),
        "moonshot-v1-16b-a3b": ({"act_specs": moe, "moe_ep_data": True},
                                {"act_specs": moe_resid,
                                 "moe_ep_data": True}),
        "deepseek-v2-lite-16b": ({"act_specs": moe, "moe_ep_data": True},
                                 {"act_specs": moe_resid,
                                  "moe_ep_data": True}),
    }
    for arch, (ov_main, ov_decode) in plans.items():
        for shape in ("train_4k", "prefill_32k", "decode_32k"):
            ov = ov_decode if shape == "decode_32k" else ov_main
            path = artifact_path(arch, shape, False, "opt")
            if os.path.exists(path):
                print(f"cached {path}")
                continue
            print(f"== {arch} x {shape} (opt) ==", flush=True)
            try:
                res = run_cell(arch, shape, False, opt_overrides=ov,
                               tag="opt")
            except Exception as e:
                res = {"arch": arch, "shape": shape, "mesh": "pod16x16",
                       "status": "error", "error": repr(e)[:1500],
                       "tag": "opt"}
            with open(path, "w") as f:
                json.dump(res, f, indent=2)
            print(res.get("status"), res.get("error", ""), flush=True)


if __name__ == "__main__":
    main()
