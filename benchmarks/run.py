"""Benchmark driver: one bench per paper table/figure + the roofline table.

`python -m benchmarks.run [--quick] [--only fig6,fig9] [--json out.json]`
prints `name,us_per_call,derived` CSV rows, then the roofline table if
dry-run artifacts exist; `--json` additionally writes the rows as a JSON
artifact (what the CI bench job uploads).  Every row — CSV header comment
and JSON alike — is stamped with the device that produced it (platform,
device kind, device count), so archived artifacts say what hardware they
measured.

The `engine` lane (and the engine rows inside fig8) time the compiled
`lax.while_loop` peel engine against the eager dense round loop it replaced;
the `hierarchy` lane times fused-on-device ANH-EL against host trace-replay
and the two-phase build; the `facade` lane records the decompose-once/
query-many serving claim (`.cut(c)` sweep qps vs from-scratch connectivity,
plus the serialized-artifact load cost); the `build` lane compares the
memory-bounded chunked incidence builder against the eager one (peak
memory + wall-clock vs chunk size, fresh subprocess per cell); the
`session` lane records the warm-pool claim (cold per-shape `decompose()`
compiles vs one shape-bucketed `Session` executable); the `stream` lane
records the live-graph claim (single-edge `update(delta)` vs full
re-decompose of the edited graph); the `server` lane records the
multi-tenant server claims (persistent-cache restart warm path, fresh
subprocess per cell, plus coalesced-batch throughput through the
`Frontend`).  Compile time is excluded via a warmup call — except in the
`session`, `stream`, and `server` lanes, where per-shape compile time IS
(part of) the measurand — so the rows measure steady-state wall-clock
(what EXPERIMENTS.md records).
"""
from __future__ import annotations

import argparse
import json
import sys


def _parse_row(row: str) -> dict:
    name, us, derived = row.split(",", 2)
    return {"name": name, "us_per_call": float(us), "derived": derived}


def _device_meta() -> dict:
    """Which hardware produced the rows — stamped into every lane's JSON
    output so EXPERIMENTS.md tables (and the planner-calibration story)
    can say what they were measured on."""
    import jax
    return {
        "platform": jax.default_backend(),
        "device_kind": jax.devices()[0].device_kind,
        "device_count": jax.device_count(),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced graph suite / grid")
    ap.add_argument("--only", default="",
                    help="comma-separated subset of benches")
    ap.add_argument("--list", action="store_true",
                    help="list available benches and exit")
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--json", default="",
                    help="also write rows to this path as a JSON artifact")
    args = ap.parse_args()

    # device decisions once, before the first jax op initializes a
    # backend (honors JAX_PLATFORMS etc.; --platform-style overrides
    # belong to the entrypoints, the bench driver just pins the timing)
    from repro.launch.platform import setup_platform
    setup_platform()

    from . import bench_paper
    if args.list:
        for name, fn in bench_paper.ALL.items():
            doc = (fn.__doc__ or "").strip().splitlines()[0]
            print(f"{name}: {doc}")
        return
    only = set(filter(None, args.only.split(",")))
    meta = _device_meta()
    collected = []
    print("name,us_per_call,derived")
    print(f"# device: platform={meta['platform']} "
          f"kind={meta['device_kind']!r} count={meta['device_count']}",
          flush=True)
    for name, fn in bench_paper.ALL.items():
        if only and name not in only:
            continue
        try:
            for r in fn(quick=args.quick):
                print(r, flush=True)
                collected.append({**_parse_row(r), **meta})
        except Exception as e:  # keep the suite running
            print(f"{name}/ERROR,0,{e!r}", flush=True)
            collected.append({"name": f"{name}/ERROR", "us_per_call": 0.0,
                              "derived": repr(e), **meta})

    if args.json:
        # the dead-module inventory (repro.analysis, DESIGN.md §12) rides
        # the bench artifact so the unreachable set is tracked per commit;
        # pure-AST analysis, so a failure must never redden the bench lane
        try:
            from repro.analysis import dead_module_report
            dead = dead_module_report("src")
        except Exception as e:
            dead = {"error": repr(e)}
        with open(args.json, "w") as f:
            json.dump({"meta": meta, "rows": collected,
                       "dead_modules": dead}, f, indent=1)
            f.write("\n")

    if not args.skip_roofline and not only:
        from . import roofline
        rows = roofline.full_table()
        if rows:
            print("\n# Roofline (single-pod 16x16, per chip):")
            print(roofline.format_table(rows))


if __name__ == "__main__":
    main()
