"""Model zoo: the assigned architectures, pure-functional JAX.

LM family:  transformer (GQA / MLA attention, dense / MoE FFN)
GNN family: gin, egnn, dimenet, mace over GraphBatch (+ gnn_common substrate)
RecSys:     din (+ the EmbeddingBag substrate)
"""
from . import transformer
from . import gnn_common
from . import gin
from . import egnn
from . import dimenet
from . import mace
from . import din
from .gnn_common import GraphBatch, make_batch_from_arrays, build_triplets
from .transformer import TransformerConfig, MoEConfig, MLAConfig
