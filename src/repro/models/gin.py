"""GIN (Graph Isomorphism Network) — sum aggregation + MLP, learnable eps.

[arXiv:1810.00826] config gin-tu: n_layers=5, d_hidden=64, aggregator=sum.
Message passing = gather(src) -> segment_sum(dst): the JAX-native SpMM.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .gnn_common import GraphBatch, masked_segment_sum, mlp_init, mlp_apply


@dataclasses.dataclass(frozen=True)
class GINConfig:
    name: str = "gin-tu"
    n_layers: int = 5
    d_hidden: int = 64
    d_in: int = 16
    n_classes: int = 8
    graph_level: bool = True   # graph classification (TU datasets) vs node
    dtype: Any = jnp.float32


def init_params(key: jax.Array, cfg: GINConfig) -> Dict[str, Any]:
    keys = jax.random.split(key, cfg.n_layers + 2)
    layers = []
    for i in range(cfg.n_layers):
        d_in = cfg.d_in if i == 0 else cfg.d_hidden
        layers.append({
            "mlp": mlp_init(keys[i], [d_in, cfg.d_hidden, cfg.d_hidden],
                            cfg.dtype),
            "eps": jnp.zeros((), jnp.float32),
        })
    return {
        "layers": layers,
        "readout": mlp_init(keys[-1], [cfg.d_hidden, cfg.d_hidden,
                                       cfg.n_classes], cfg.dtype),
    }


def forward(params: Dict[str, Any], batch: GraphBatch,
            cfg: GINConfig) -> jnp.ndarray:
    """Returns (n_graphs, n_classes) if graph_level else (N, n_classes)."""
    h = batch.nodes.astype(cfg.dtype)
    N = h.shape[0]
    for lp in params["layers"]:
        msg = h[batch.edge_src]
        agg = masked_segment_sum(msg, batch.edge_dst, batch.edge_mask, N)
        h = mlp_apply(lp["mlp"], (1.0 + lp["eps"]) * h + agg)
        h = jnp.where(batch.node_mask[:, None], h, 0)
    if cfg.graph_level:
        pooled = jax.ops.segment_sum(h, batch.graph_id, batch.n_graphs)
        return mlp_apply(params["readout"], pooled)
    return mlp_apply(params["readout"], h)


def loss_fn(params, batch: GraphBatch, labels: jnp.ndarray, cfg: GINConfig,
            label_mask: jnp.ndarray | None = None) -> jnp.ndarray:
    logits = forward(params, batch, cfg).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    nll = logz - gold
    if label_mask is not None:
        return jnp.sum(nll * label_mask) / jnp.maximum(label_mask.sum(), 1)
    return jnp.mean(nll)
