"""EGNN — E(n)-equivariant GNN [arXiv:2102.09844].

Config egnn: n_layers=4, d_hidden=64.  Messages are built from invariants
(h_i, h_j, ||x_i - x_j||^2); coordinates update along relative vectors, so
the network is exactly E(n)-equivariant (verified by property tests).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .gnn_common import (GraphBatch, masked_segment_sum, mlp_init, mlp_apply)


@dataclasses.dataclass(frozen=True)
class EGNNConfig:
    name: str = "egnn"
    n_layers: int = 4
    d_hidden: int = 64
    d_in: int = 16
    n_out: int = 1            # graph-level regression targets (e.g. energy)
    coord_agg_clip: float = 100.0
    dtype: Any = jnp.float32


def init_params(key: jax.Array, cfg: EGNNConfig) -> Dict[str, Any]:
    keys = jax.random.split(key, 4 * cfg.n_layers + 2)
    d = cfg.d_hidden
    layers = []
    for i in range(cfg.n_layers):
        layers.append({
            "phi_e": mlp_init(keys[4 * i], [2 * d + 1, d, d], cfg.dtype),
            "phi_x": mlp_init(keys[4 * i + 1], [d, d, 1], cfg.dtype),
            "phi_h": mlp_init(keys[4 * i + 2], [2 * d, d, d], cfg.dtype),
            "phi_inf": mlp_init(keys[4 * i + 3], [d, 1], cfg.dtype),
        })
    return {
        "encode": mlp_init(keys[-2], [cfg.d_in, d], cfg.dtype),
        "layers": layers,
        "readout": mlp_init(keys[-1], [d, d, cfg.n_out], cfg.dtype),
    }


def forward(params: Dict[str, Any], batch: GraphBatch, cfg: EGNNConfig
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (graph outputs (n_graphs, n_out), final coords (N, 3))."""
    assert batch.pos is not None, "EGNN requires positions"
    h = mlp_apply(params["encode"], batch.nodes.astype(cfg.dtype))
    x = batch.pos.astype(cfg.dtype)
    N = h.shape[0]
    src, dst, em = batch.edge_src, batch.edge_dst, batch.edge_mask
    for lp in params["layers"]:
        rel = x[dst] - x[src]                          # (E, 3)
        d2 = jnp.sum(rel * rel, axis=-1, keepdims=True)
        feats = jnp.concatenate([h[dst], h[src], d2], axis=-1)
        m = mlp_apply(lp["phi_e"], feats)              # (E, d)
        # soft edge gating (EGNN eq. 8 attention variant)
        gate = jax.nn.sigmoid(mlp_apply(lp["phi_inf"], m))
        m = m * gate
        # coordinate update: x_i += mean_j (x_i - x_j) * phi_x(m_ij)
        w = mlp_apply(lp["phi_x"], m)
        w = jnp.clip(w, -cfg.coord_agg_clip, cfg.coord_agg_clip)
        upd = masked_segment_sum(rel * w, dst, em, N)
        deg = masked_segment_sum(jnp.ones_like(w), dst, em, N)
        x = x + upd / jnp.maximum(deg, 1.0)
        # node update from aggregated messages
        agg = masked_segment_sum(m, dst, em, N)
        h = h + mlp_apply(lp["phi_h"], jnp.concatenate([h, agg], axis=-1))
        h = jnp.where(batch.node_mask[:, None], h, 0)
        x = jnp.where(batch.node_mask[:, None], x, batch.pos)
    pooled = jax.ops.segment_sum(h, batch.graph_id, batch.n_graphs)
    return mlp_apply(params["readout"], pooled), x


def loss_fn(params, batch: GraphBatch, targets: jnp.ndarray,
            cfg: EGNNConfig) -> jnp.ndarray:
    out, _ = forward(params, batch, cfg)
    return jnp.mean(jnp.square(out.astype(jnp.float32)
                               - targets.astype(jnp.float32)))
