"""Shared GNN substrate: padded graph batches + segment message passing.

JAX has no EmbeddingBag / CSR SpMM — message passing here IS
`jnp.take` (gather) + `jax.ops.segment_sum` (scatter-reduce) over an edge
index, exactly as the assignment requires.  All shapes are static (padded
with masked edges/nodes) so every model lowers for the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class GraphBatch:
    """A (possibly batched) graph with static shapes.

    nodes:      (N, F) node features
    edge_src/dst: (E,) int32 — messages flow src -> dst
    node_mask:  (N,) bool — padding nodes are False
    edge_mask:  (E,) bool
    pos:        (N, 3) positions (equivariant models) or None
    graph_id:   (N,) int32 — which graph each node belongs to (pooling)
    n_graphs:   static number of graphs in the batch
    triplet_kj / triplet_ji: (T,) edge ids forming directed triplets
        k->j (in-edge) feeding j->i (out-edge), for angular models
    triplet_mask: (T,) bool
    """

    nodes: jnp.ndarray
    edge_src: jnp.ndarray
    edge_dst: jnp.ndarray
    node_mask: jnp.ndarray
    edge_mask: jnp.ndarray
    graph_id: jnp.ndarray
    n_graphs: int
    pos: Optional[jnp.ndarray] = None
    triplet_kj: Optional[jnp.ndarray] = None
    triplet_ji: Optional[jnp.ndarray] = None
    triplet_mask: Optional[jnp.ndarray] = None

    @property
    def n_nodes(self) -> int:
        return int(self.nodes.shape[0])

    @property
    def n_edges(self) -> int:
        return int(self.edge_src.shape[0])


def segment_mean(data: jnp.ndarray, segment_ids: jnp.ndarray,
                 num_segments: int) -> jnp.ndarray:
    tot = jax.ops.segment_sum(data, segment_ids, num_segments)
    cnt = jax.ops.segment_sum(jnp.ones((data.shape[0],), data.dtype),
                              segment_ids, num_segments)
    return tot / jnp.maximum(cnt, 1)[..., None]


def masked_segment_sum(data: jnp.ndarray, segment_ids: jnp.ndarray,
                       mask: jnp.ndarray, num_segments: int) -> jnp.ndarray:
    data = jnp.where(mask[..., None], data, 0)
    # masked edges scatter to segment 0 harmlessly (their data is zero)
    return jax.ops.segment_sum(data, jnp.where(mask, segment_ids, 0),
                               num_segments)


def mlp_init(key, sizes: Sequence[int], dtype=jnp.float32) -> Dict[str, Any]:
    params = {}
    keys = jax.random.split(key, len(sizes) - 1)
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        params[f"w{i}"] = (jax.random.normal(keys[i], (a, b), jnp.float32)
                           / np.sqrt(a)).astype(dtype)
        params[f"b{i}"] = jnp.zeros((b,), dtype)
    return params


def mlp_apply(params: Dict[str, Any], x: jnp.ndarray,
              act=jax.nn.silu, final_act=None) -> jnp.ndarray:
    n = sum(1 for k in params if k.startswith("w"))
    for i in range(n):
        x = x @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1:
            x = act(x)
        elif final_act is not None:
            x = final_act(x)
    return x


def make_batch_from_arrays(nodes, edge_src, edge_dst, *, pos=None,
                           graph_id=None, n_graphs=1, node_mask=None,
                           edge_mask=None, triplets=None) -> GraphBatch:
    N = nodes.shape[0]
    E = edge_src.shape[0]
    t_kj = t_ji = t_m = None
    if triplets is not None:
        t_kj, t_ji, t_m = triplets
    return GraphBatch(
        nodes=jnp.asarray(nodes),
        edge_src=jnp.asarray(edge_src, jnp.int32),
        edge_dst=jnp.asarray(edge_dst, jnp.int32),
        node_mask=(jnp.ones((N,), bool) if node_mask is None
                   else jnp.asarray(node_mask, bool)),
        edge_mask=(jnp.ones((E,), bool) if edge_mask is None
                   else jnp.asarray(edge_mask, bool)),
        graph_id=(jnp.zeros((N,), jnp.int32) if graph_id is None
                  else jnp.asarray(graph_id, jnp.int32)),
        n_graphs=n_graphs,
        pos=None if pos is None else jnp.asarray(pos),
        triplet_kj=t_kj, triplet_ji=t_ji, triplet_mask=t_m)


def build_triplets(edge_src: np.ndarray, edge_dst: np.ndarray,
                   n_nodes: int, cap_per_edge: Optional[int] = None):
    """Directed triplets (k->j, j->i), k != i, for angular message models.

    Returns (triplet_kj, triplet_ji, mask) as numpy; capacity-capped per
    out-edge when `cap_per_edge` is given (large graphs — documented in the
    configs), which is what any production DimeNet must do.
    """
    E = edge_src.shape[0]
    order = np.argsort(edge_dst, kind="stable")
    by_dst_off = np.zeros(n_nodes + 1, np.int64)
    np.add.at(by_dst_off, edge_dst + 1, 1)
    by_dst_off = np.cumsum(by_dst_off)
    in_edges_sorted = order  # edge ids sorted by dst
    t_kj, t_ji = [], []
    for e in range(E):
        j = edge_src[e]          # out-edge e: j -> i
        i = edge_dst[e]
        lo, hi = by_dst_off[j], by_dst_off[j + 1]
        in_e = in_edges_sorted[lo:hi]          # edges k -> j
        in_e = in_e[edge_src[in_e] != i]       # exclude backtrack k == i
        if cap_per_edge is not None and in_e.shape[0] > cap_per_edge:
            in_e = in_e[:cap_per_edge]
        t_kj.append(in_e)
        t_ji.append(np.full(in_e.shape[0], e, np.int64))
    kj = np.concatenate(t_kj) if t_kj else np.zeros(0, np.int64)
    ji = np.concatenate(t_ji) if t_ji else np.zeros(0, np.int64)
    mask = np.ones(kj.shape[0], bool)
    return kj.astype(np.int32), ji.astype(np.int32), mask


def radial_basis(dist: jnp.ndarray, n_rbf: int, cutoff: float) -> jnp.ndarray:
    """Sine/Bessel-style radial basis on [0, cutoff] (DimeNet eq. 6)."""
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    d = jnp.maximum(dist[..., None], 1e-9)
    rbf = jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * d / cutoff) / d
    return rbf * envelope(dist / cutoff)[..., None]


def envelope(x: jnp.ndarray, p: int = 6) -> jnp.ndarray:
    """Smooth polynomial cutoff envelope (DimeNet eq. 8)."""
    a = -(p + 1) * (p + 2) / 2.0
    b = p * (p + 2.0)
    c = -p * (p + 1) / 2.0
    xe = 1.0 + a * x ** p + b * x ** (p + 1) + c * x ** (p + 2)
    return jnp.where(x < 1.0, xe, 0.0)
