"""DimeNet — directional message passing with angular basis [arXiv:2003.03123].

Config dimenet: n_blocks=6, d_hidden=128, n_bilinear=8, n_spherical=7,
n_radial=6.  Messages live on DIRECTED EDGES; interaction blocks aggregate
over triplets (k->j feeding j->i) with a 2D basis in (distance, angle) and the
bilinear layer of the paper.  This is the "triplet gather" kernel regime: two
gathers + one segment-sum per block — not expressible as SpMM.

TPU adaptation (DESIGN.md §3): the angular basis uses Legendre polynomials
P_l(cos angle) x sine radial basis instead of spherical Bessel roots (same
shapes/rank; avoids host-side root finding), and triplet lists on large
graphs are capacity-capped per edge by the data pipeline.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from .gnn_common import (GraphBatch, masked_segment_sum, mlp_init, mlp_apply,
                         radial_basis)


@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str = "dimenet"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    d_in: int = 16
    n_out: int = 1
    cutoff: float = 5.0
    dtype: Any = jnp.float32


def _legendre(cos_t: jnp.ndarray, lmax: int) -> jnp.ndarray:
    """P_0..P_{lmax-1}(cos_t), recurrence; returns (..., lmax)."""
    p0 = jnp.ones_like(cos_t)
    if lmax == 1:
        return p0[..., None]
    ps = [p0, cos_t]
    for l in range(2, lmax):
        ps.append(((2 * l - 1) * cos_t * ps[-1] - (l - 1) * ps[-2]) / l)
    return jnp.stack(ps, axis=-1)


def init_params(key: jax.Array, cfg: DimeNetConfig) -> Dict[str, Any]:
    d, nb = cfg.d_hidden, cfg.n_bilinear
    n_sbf = cfg.n_spherical * cfg.n_radial
    keys = iter(jax.random.split(key, 8 * cfg.n_blocks + 8))
    blocks = []
    for _ in range(cfg.n_blocks):
        blocks.append({
            "w_kj": mlp_init(next(keys), [d, d], cfg.dtype),
            "w_rbf": mlp_init(next(keys), [cfg.n_radial, d], cfg.dtype),
            "w_sbf": mlp_init(next(keys), [n_sbf, nb], cfg.dtype),
            "w_bil": (jax.random.normal(next(keys), (nb, d, d), jnp.float32)
                      / np.sqrt(d * nb)).astype(cfg.dtype),
            "mlp_ji": mlp_init(next(keys), [d, d], cfg.dtype),
            "mlp_out": mlp_init(next(keys), [d, d, d], cfg.dtype),
            "out_rbf": mlp_init(next(keys), [cfg.n_radial, d], cfg.dtype),
            "out_atom": mlp_init(next(keys), [d, d, cfg.n_out], cfg.dtype),
        })
    return {
        "embed_node": mlp_init(next(keys), [cfg.d_in, d], cfg.dtype),
        "embed_edge": mlp_init(next(keys), [2 * d + cfg.n_radial, d, d],
                               cfg.dtype),
        "out0_rbf": mlp_init(next(keys), [cfg.n_radial, d], cfg.dtype),
        "out0_atom": mlp_init(next(keys), [d, d, cfg.n_out], cfg.dtype),
        "blocks": blocks,
    }


def forward(params: Dict[str, Any], batch: GraphBatch,
            cfg: DimeNetConfig) -> jnp.ndarray:
    """Graph-level outputs (n_graphs, n_out) — energies for molecules."""
    assert batch.pos is not None and batch.triplet_kj is not None
    x = batch.pos.astype(cfg.dtype)
    src, dst, em = batch.edge_src, batch.edge_dst, batch.edge_mask
    N, E = batch.n_nodes, batch.n_edges
    rel = x[dst] - x[src]
    dist = jnp.sqrt(jnp.maximum(jnp.sum(rel * rel, -1), 1e-12))
    rbf = radial_basis(dist, cfg.n_radial, cfg.cutoff).astype(cfg.dtype)

    # triplet geometry: angle at j between k->j and j->i
    kj, ji, tm = batch.triplet_kj, batch.triplet_ji, batch.triplet_mask
    v_ji = rel[ji]                       # j -> i
    v_jk = -rel[kj]                      # j -> k  (reverse of k->j)
    cos_t = jnp.sum(v_ji * v_jk, -1) / jnp.maximum(dist[ji] * dist[kj], 1e-9)
    cos_t = jnp.clip(cos_t, -1.0, 1.0)
    leg = _legendre(cos_t, cfg.n_spherical)                    # (T, n_sph)
    rbf_kj = radial_basis(dist[kj], cfg.n_radial, cfg.cutoff)
    sbf = (leg[:, :, None] * rbf_kj[:, None, :]).reshape(
        kj.shape[0], -1).astype(cfg.dtype)                     # (T, n_sbf)

    h = mlp_apply(params["embed_node"], batch.nodes.astype(cfg.dtype))
    m = mlp_apply(params["embed_edge"],
                  jnp.concatenate([h[src], h[dst], rbf], axis=-1))  # (E, d)
    m = jnp.where(em[:, None], m, 0)

    # output block 0 (from the embedding)
    per_atom = mlp_apply(params["out0_atom"],
                         masked_segment_sum(
                             m * mlp_apply(params["out0_rbf"], rbf),
                             dst, em, N))
    for bp in params["blocks"]:
        # directional aggregation over triplets with the bilinear layer
        m_kj = mlp_apply(bp["w_kj"], m)[kj]
        m_kj = m_kj * mlp_apply(bp["w_rbf"], rbf)[kj]
        sbf_p = mlp_apply(bp["w_sbf"], sbf)                    # (T, nb)
        inter = jnp.einsum("tb,bde,te->td", sbf_p, bp["w_bil"], m_kj)
        agg = masked_segment_sum(inter, ji, tm, E)             # (E, d)
        m = m + jax.nn.silu(mlp_apply(bp["mlp_ji"], m)) + agg
        m = jax.nn.silu(mlp_apply(bp["mlp_out"], m))
        m = jnp.where(em[:, None], m, 0)
        per_atom = per_atom + mlp_apply(
            bp["out_atom"],
            masked_segment_sum(m * mlp_apply(bp["out_rbf"], rbf), dst, em, N))
    per_atom = jnp.where(batch.node_mask[:, None], per_atom, 0)
    return jax.ops.segment_sum(per_atom, batch.graph_id, batch.n_graphs)


def loss_fn(params, batch: GraphBatch, targets: jnp.ndarray,
            cfg: DimeNetConfig) -> jnp.ndarray:
    out = forward(params, batch, cfg)
    return jnp.mean(jnp.square(out.astype(jnp.float32)
                               - targets.astype(jnp.float32)))
