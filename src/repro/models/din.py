"""DIN — Deep Interest Network [arXiv:1706.06978].

Config din: embed_dim=18, seq_len=100, attn_mlp=80-40, mlp=200-80,
interaction=target-attention.

The hot path is the sparse embedding substrate: JAX has no EmbeddingBag, so
`embedding_bag` below IS the implementation — `jnp.take` over the (vocab, d)
table + `jax.ops.segment_sum` / masked mean reduce.  Tables are vocab-sharded
over the "model" mesh axis in the distributed configs (each device owns
vocab/|model| rows; GSPMD turns the gather into a collective).

`score_candidates` implements retrieval_cand: one user's history scored
against 10^6 candidates as one batched target-attention einsum — not a loop.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from .gnn_common import mlp_init, mlp_apply


@dataclasses.dataclass(frozen=True)
class DINConfig:
    name: str = "din"
    embed_dim: int = 18
    seq_len: int = 100
    attn_mlp: tuple = (80, 40)
    mlp: tuple = (200, 80)
    n_items: int = 1_000_000
    n_cates: int = 10_000
    n_user_feats: int = 100_000
    dtype: Any = jnp.float32


# ---------------------------------------------------------------------------
# EmbeddingBag substrate
# ---------------------------------------------------------------------------

def embedding_lookup(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """Plain gather; ids < 0 yield zero rows (padding)."""
    safe = jnp.maximum(ids, 0)
    rows = jnp.take(table, safe, axis=0)
    return jnp.where((ids >= 0)[..., None], rows, 0)


def embedding_bag(table: jnp.ndarray, ids: jnp.ndarray,
                  offsets: jnp.ndarray, n_bags: int,
                  mode: str = "sum") -> jnp.ndarray:
    """torch-style EmbeddingBag: ragged ids + offsets -> (n_bags, d).

    ids: (L,) flat indices; offsets: (n_bags,) bag starts.  Built from
    take + segment_sum, as the assignment requires.
    """
    L = ids.shape[0]
    rows = embedding_lookup(table, ids)
    bag_id = jnp.searchsorted(offsets, jnp.arange(L), side="right") - 1
    out = jax.ops.segment_sum(rows, bag_id, n_bags)
    if mode == "mean":
        cnt = jax.ops.segment_sum((ids >= 0).astype(rows.dtype), bag_id,
                                  n_bags)
        out = out / jnp.maximum(cnt, 1)[:, None]
    return out


# ---------------------------------------------------------------------------
# DIN model
# ---------------------------------------------------------------------------

def init_params(key: jax.Array, cfg: DINConfig) -> Dict[str, Any]:
    d = cfg.embed_dim
    keys = iter(jax.random.split(key, 8))

    def table(k, n):
        return (jax.random.normal(k, (n, d), jnp.float32) * 0.01
                ).astype(cfg.dtype)

    # user vector = 2d (item+cate hist) ; candidate = 2d ; user profile = d
    d_cat = 2 * d + 2 * d + d
    return {
        "item_table": table(next(keys), cfg.n_items),
        "cate_table": table(next(keys), cfg.n_cates),
        "user_table": table(next(keys), cfg.n_user_feats),
        # attention MLP input: [h, c, h - c, h * c] over 2d-dim vectors
        "attn": mlp_init(next(keys), [8 * d, *cfg.attn_mlp, 1], cfg.dtype),
        "mlp": mlp_init(next(keys), [d_cat, *cfg.mlp, 1], cfg.dtype),
    }


def _hist_embed(params, hist_items, hist_cates):
    h = jnp.concatenate([
        embedding_lookup(params["item_table"], hist_items),
        embedding_lookup(params["cate_table"], hist_cates)], axis=-1)
    return h  # (..., S, 2d)


def _cand_embed(params, cand_item, cand_cate):
    return jnp.concatenate([
        embedding_lookup(params["item_table"], cand_item),
        embedding_lookup(params["cate_table"], cand_cate)], axis=-1)


def target_attention(params, hist: jnp.ndarray, cand: jnp.ndarray,
                     hist_mask: jnp.ndarray) -> jnp.ndarray:
    """DIN local activation unit.

    hist: (..., S, 2d); cand: (..., 2d) -> user interest vector (..., 2d).
    Weights are NOT softmax-normalized (paper §4.3 keeps intensity).
    """
    c = jnp.broadcast_to(cand[..., None, :], hist.shape)
    feats = jnp.concatenate([hist, c, hist - c, hist * c], axis=-1)
    w = mlp_apply(params["attn"], feats, act=jax.nn.sigmoid)[..., 0]
    w = jnp.where(hist_mask, w, 0.0)
    return jnp.einsum("...s,...sd->...d", w, hist)


def forward(params: Dict[str, Any], batch: Dict[str, jnp.ndarray],
            cfg: DINConfig) -> jnp.ndarray:
    """CTR logits (B,). batch: hist_items/hist_cates (B,S), cand_item/
    cand_cate (B,), user_id (B,)."""
    hist = _hist_embed(params, batch["hist_items"], batch["hist_cates"])
    cand = _cand_embed(params, batch["cand_item"], batch["cand_cate"])
    mask = batch["hist_items"] >= 0
    interest = target_attention(params, hist, cand, mask)
    user = embedding_lookup(params["user_table"], batch["user_id"])
    z = jnp.concatenate([interest, cand, user], axis=-1)
    return mlp_apply(params["mlp"], z)[..., 0]


def score_candidates(params: Dict[str, Any], batch: Dict[str, jnp.ndarray],
                     cfg: DINConfig) -> jnp.ndarray:
    """retrieval_cand: one user vs (n_cand,) candidates, fully batched.

    batch: hist_items/hist_cates (S,), user_id (), cand_items/cand_cates
    (n_cand,).  Returns (n_cand,) scores.
    """
    hist = _hist_embed(params, batch["hist_items"], batch["hist_cates"])
    mask = batch["hist_items"] >= 0
    cands = _cand_embed(params, batch["cand_items"], batch["cand_cates"])
    # (n_cand, S, 2d) attention features without materializing broadcast:
    # vmap the activation unit over candidates.
    att = jax.vmap(lambda c: target_attention(params, hist, c, mask))(cands)
    user = embedding_lookup(params["user_table"], batch["user_id"])
    user_b = jnp.broadcast_to(user, (cands.shape[0], user.shape[-1]))
    z = jnp.concatenate([att, cands, user_b], axis=-1)
    return mlp_apply(params["mlp"], z)[..., 0]


def loss_fn(params, batch: Dict[str, jnp.ndarray], labels: jnp.ndarray,
            cfg: DINConfig) -> jnp.ndarray:
    logits = forward(params, batch, cfg).astype(jnp.float32)
    y = labels.astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))
