"""Decoder-only transformer family: GQA / MLA attention, SwiGLU / MoE FFN.

Design points (all serve the multi-pod dry-run and the roofline):
  * `lax.scan` over layers with stacked params — one layer body in the HLO,
    so 40-layer × 512-device programs lower and compile quickly.
  * Attention is an online-softmax (flash-style) scan over KV chunks — the
    (S, S) score matrix is never materialized, so prefill_32k lowers with
    honest memory.  Decode (S_q small) runs the same code path.
  * MoE uses sort-based capacity dispatch: top-k routing, tokens grouped by
    expert via argsort, per-expert matmuls under a scan.  FLOPs are
    proportional to *active* parameters (capacity-dropped), never E× dense.
  * MLA (DeepSeek-V2) compresses KV through a LoRA bottleneck; the KV cache
    stores the compressed latent (kv_lora_rank + rope dims per token).
  * Everything is pure-functional pytrees; sharding lives in
    `repro.distributed.sharding` as PartitionSpec pytrees mirroring params.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0          # shared (always-on) experts, DeepSeek-style
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    rope_head_dim: int = 64    # decoupled RoPE key dims (shared across heads)


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    dtype: Any = jnp.bfloat16
    attn_chunk: int = 1024     # KV chunk for the online-softmax scan
    remat: bool = True
    tie_embeddings: bool = False
    # cost-accounting mode: fully unroll the layer/attention scans so XLA's
    # HloCostAnalysis (which counts while bodies ONCE) reports true totals.
    # Identical math; used by the dry-run for the roofline terms.
    cost_unroll: bool = False
    # sharding HINT consumed by the launcher's rule tables: shard MoE
    # experts over the data axis (EP-over-data + TP-over-model within each
    # expert) instead of the default experts-over-model
    moe_ep_data: bool = False
    # activation sharding constraints: hashable tuple of (name, PartitionSpec)
    # set by the launcher.  Names: act_q, act_kv, act_attn_out, act_resid,
    # act_moe_disp, act_logits.  None entries / missing names = GSPMD's choice.
    act_specs: Any = None

    def act_spec(self, name: str):
        if not self.act_specs:
            return None
        return dict(self.act_specs).get(name)

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    def param_count(self) -> int:
        """Total parameters (for 6ND MODEL_FLOPS accounting)."""
        d, h = self.d_model, self.head_dim
        if self.mla is not None:
            r, pr = self.mla.kv_lora_rank, self.mla.rope_head_dim
            attn = d * (self.n_heads * h) + d * (r + pr) \
                + r * (self.n_heads * 2 * h) + self.n_heads * h * d
        else:
            attn = d * (self.n_heads + 2 * self.n_kv_heads) * h \
                + self.n_heads * h * d
        if self.moe is not None:
            ff = self.moe.d_ff_expert
            moe = self.moe.n_experts * 3 * d * ff + d * self.moe.n_experts \
                + self.moe.n_shared * 3 * d * ff
            per_layer = attn + moe + 2 * d
        else:
            per_layer = attn + 3 * d * self.d_ff + 2 * d
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + d

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        ff = self.moe.d_ff_expert
        attn = self._attn_params()
        act = attn + (self.moe.top_k + self.moe.n_shared) * 3 * d * ff \
            + d * self.moe.n_experts + 2 * d
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * act + emb + d

    def _attn_params(self) -> int:
        d, h = self.d_model, self.head_dim
        if self.mla is not None:
            r, pr = self.mla.kv_lora_rank, self.mla.rope_head_dim
            return d * (self.n_heads * h) + d * (r + pr) \
                + r * (self.n_heads * 2 * h) + self.n_heads * h * d
        return d * (self.n_heads + 2 * self.n_kv_heads) * h + self.n_heads * h * d


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def _constrain(x: jnp.ndarray, spec) -> jnp.ndarray:
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def rope_angles(positions: jnp.ndarray, dim: int, theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., dim/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, H, D); cos/sin: (B, S, D/2) broadcast over heads."""
    x1, x2 = x[..., 0::2], x[..., 1::2]
    c, s = cos[:, :, None, :], sin[:, :, None, :]
    o1 = x1 * c - x2 * s
    o2 = x2 * c + x1 * s
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def online_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     q_pos: jnp.ndarray, k_valid_len: jnp.ndarray,
                     causal: bool, chunk: int,
                     unroll: bool = False) -> jnp.ndarray:
    """Flash-style attention: scan over KV chunks with running (max, sum).

    q: (B, Sq, H, D); k, v: (B, Sk, Hkv, D).  GQA: H % Hkv == 0 — kv heads
    are repeated by reshape-grouping (no materialized repeat).
    q_pos: (B, Sq) absolute positions for the causal mask.
    k_valid_len: (B,) number of valid cache slots (for padded decode caches).
    Returns (B, Sq, H, D).

    Perf notes (EXPERIMENTS.md §Perf, minicpm/4-5): scores come straight
    from a bf16 x bf16 dot_general with f32 accumulation (MXU-native; no
    operand upcasts), masking is one additive (B, Sq, chunk) bias, the
    internal layout is (B, Hkv, G, Sq, ...) so no per-chunk transposes, and
    probabilities re-enter the PV matmul in bf16.
    """
    B, Sq, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = jnp.float32(1.0 / np.sqrt(D))
    # one transpose in: (B, Sq, Hkv, G, D) -> (B, Hkv, G, Sq, D)
    qt = jnp.transpose(q.reshape(B, Sq, Hkv, G, D), (0, 2, 3, 1, 4))
    n_chunks = -(-Sk // chunk)
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, chunk, Hkv, D)
    vc = v.reshape(B, n_chunks, chunk, Hkv, D)
    NEG = -1e30  # finite -inf sentinel (fully-masked rows = pad queries only)

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, c_idx = inp            # (B, chunk, Hkv, D) x2, scalar
        kpos = c_idx * chunk + jnp.arange(chunk)          # (chunk,)
        mask = kpos[None, None, :] < k_valid_len[:, None, None]  # (B,1,chunk)
        if causal:
            mask = mask & (kpos[None, None, :] <= q_pos[:, :, None])
        bias = jnp.where(mask, 0.0, NEG)                  # (B, Sq, chunk)
        # scores (B, Hkv, G, Sq, chunk): bf16 x bf16 -> f32 on the MXU
        s = jax.lax.dot_general(
            qt, kb, (((4,), (3,)), ((0, 1), (0, 2))),
            preferred_element_type=jnp.float32)
        s = s * scale + bias[:, None, None, :, :]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        # (B, Hkv, G, Sq, chunk) x (B, chunk, Hkv, D) -> (B, Hkv, G, Sq, D)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), vb, (((4,), (1,)), ((0, 1), (0, 2))),
            preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, G, Sq), NEG, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, D), jnp.float32)
    idxs = jnp.arange(n_chunks)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (jnp.moveaxis(kc, 1, 0),
                                   jnp.moveaxis(vc, 1, 0), idxs),
                                  unroll=n_chunks if unroll else 1)
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    # one transpose out: (B, Hkv, G, Sq, D) -> (B, Sq, H, D)
    out = jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(B, Sq, H, D)
    return out.astype(q.dtype)


def swiglu(x: jnp.ndarray, w1: jnp.ndarray, w2: jnp.ndarray,
           w3: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


# ---------------------------------------------------------------------------
# MoE: top-k routing + sort-based capacity dispatch
# ---------------------------------------------------------------------------

def moe_block(x: jnp.ndarray, params: Dict[str, jnp.ndarray],
              cfg: MoEConfig, disp_spec=None) -> jnp.ndarray:
    """x: (T, d) flat tokens -> (T, d). Capacity-dropped sorted dispatch."""
    T, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    cap = int(np.ceil(T * K / E * cfg.capacity_factor))
    logits = (x.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    gate, eidx = jax.lax.top_k(probs, K)                        # (T, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    # flatten assignments, group by expert via one stable sort
    flat_e = eidx.reshape(-1)                                   # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_g = gate.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    e_s, t_s, g_s = flat_e[order], flat_t[order], flat_g[order]
    # position within expert group; drop tokens beyond capacity
    ones = jnp.ones_like(e_s)
    pos_in_e = jax.lax.associative_scan(jnp.add, ones) - 1
    seg_start = jnp.concatenate([jnp.zeros((1,), e_s.dtype), e_s[:-1]]) != e_s
    run_start = jnp.where(seg_start, pos_in_e, 0)
    run_start = jax.lax.associative_scan(jnp.maximum, run_start)
    slot = pos_in_e - run_start                                 # rank in group
    keep = slot < cap
    slot = jnp.where(keep, slot, cap - 1)
    # gather tokens into the (E, cap, d) buffer expert-by-expert via scan
    buf_idx = e_s * cap + slot
    dispatch = jnp.zeros((E * cap, d), x.dtype)
    dispatch = dispatch.at[buf_idx].add(jnp.where(keep[:, None], x[t_s], 0))
    dispatch = dispatch.reshape(E, cap, d)
    if disp_spec is not None:
        dispatch = jax.lax.with_sharding_constraint(dispatch, disp_spec)

    def expert(h, w):
        return jax.nn.silu(h @ w["w1"]) * (h @ w["w3"]) @ w["w2"]

    out_buf = jax.vmap(expert)(dispatch, {
        "w1": params["w1"], "w2": params["w2"], "w3": params["w3"]})
    out_flat = out_buf.reshape(E * cap, d)
    contrib = out_flat[buf_idx] * (g_s * keep)[:, None].astype(x.dtype)
    y = jnp.zeros((T, d), x.dtype).at[t_s].add(contrib)
    if cfg.n_shared:
        y = y + swiglu(x, params["sw1"], params["sw2"], params["sw3"])
    return y


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------

def _attn(x, params, cfg: TransformerConfig, positions, cache=None,
          cache_len=None):
    """Self-attention (GQA or MLA). Returns (out, new_cache_kv)."""
    B, S, d = x.shape
    H, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cfg.mla is not None:
        r, pr = cfg.mla.kv_lora_rank, cfg.mla.rope_head_dim
        q = (x @ params["wq"]).reshape(B, S, H, D)
        latent = x @ params["w_dkv"]                       # (B, S, r)
        k_rope = (x @ params["w_kr"]).reshape(B, S, 1, pr)
        cos, sin = rope_angles(positions, pr, cfg.rope_theta)
        k_rope = apply_rope(k_rope, cos, sin)
        q_rope = apply_rope(q[..., :pr].reshape(B, S, H, pr), cos, sin)
        if cache is not None:
            lat_c, kr_c = cache                            # (B, Sc, r), (B, Sc, 1, pr)
            off = cache_len
            lat_c = jax.lax.dynamic_update_slice(lat_c, latent, (0, off, 0))
            kr_c = jax.lax.dynamic_update_slice(kr_c, k_rope, (0, off, 0, 0))
            latent, k_rope = lat_c, kr_c
        Sk = latent.shape[1]
        k_nope = (latent @ params["w_uk"]).reshape(B, Sk, H, D - pr)
        v = (latent @ params["w_uv"]).reshape(B, Sk, H, D)
        k = jnp.concatenate(
            [jnp.broadcast_to(k_rope, (B, Sk, H, pr)), k_nope], axis=-1)
        q = jnp.concatenate([q_rope, q[..., pr:]], axis=-1)
        kv_heads_eff = H
        new_cache = (latent, k_rope) if cache is not None else None
    else:
        q = (x @ params["wq"]).reshape(B, S, H, D)
        k = (x @ params["wk"]).reshape(B, S, Hkv, D)
        v = (x @ params["wv"]).reshape(B, S, Hkv, D)
        cos, sin = rope_angles(positions, D, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        if cache is not None:
            k_c, v_c = cache                               # (B, Sc, Hkv, D)
            off = cache_len
            k = jax.lax.dynamic_update_slice(k_c, k, (0, off, 0, 0))
            v = jax.lax.dynamic_update_slice(v_c, v, (0, off, 0, 0))
        new_cache = (k, v) if cache is not None else None
        kv_heads_eff = Hkv
    valid = (cache_len + S) * jnp.ones((B,), jnp.int32) if cache is not None \
        else jnp.full((B,), k.shape[1], jnp.int32)
    q = _constrain(q, cfg.act_spec("act_q"))
    k = _constrain(k, cfg.act_spec("act_kv"))
    v = _constrain(v, cfg.act_spec("act_kv"))
    out = online_attention(q, k, v, positions, valid, causal=True,
                           chunk=cfg.attn_chunk, unroll=cfg.cost_unroll)
    out = _constrain(out, cfg.act_spec("act_q"))
    out = out.reshape(B, S, H * D) @ params["wo"]
    return out, new_cache


def _layer(x, params, cfg: TransformerConfig, positions, cache=None,
           cache_len=None):
    h, new_cache = _attn(rmsnorm(x, params["ln1"], cfg.norm_eps), params,
                         cfg, positions, cache, cache_len)
    x = _constrain(x + h, cfg.act_spec("act_resid"))
    z = rmsnorm(x, params["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        B, S, d = z.shape
        y = moe_block(z.reshape(B * S, d), params, cfg.moe,
                      disp_spec=cfg.act_spec("act_moe_disp")).reshape(B, S, d)
    else:
        y = swiglu(z, params["w1"], params["w2"], params["w3"])
    return _constrain(x + y, cfg.act_spec("act_resid")), new_cache


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _dense(key, shape, dtype, scale=None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[0]
    s = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


def init_params(key: jax.Array, cfg: TransformerConfig) -> Dict[str, Any]:
    """Stacked-layer params: every per-layer array has leading dim n_layers."""
    d, H, Hkv, D = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    L, dt = cfg.n_layers, cfg.dtype
    keys = iter(jax.random.split(key, 64))
    layer: Dict[str, jnp.ndarray] = {
        "ln1": jnp.ones((L, d), dt),
        "ln2": jnp.ones((L, d), dt),
        "wo": _dense(next(keys), (L, H * D, d), dt),
    }
    if cfg.mla is not None:
        r, pr = cfg.mla.kv_lora_rank, cfg.mla.rope_head_dim
        layer.update(
            wq=_dense(next(keys), (L, d, H * D), dt),
            w_dkv=_dense(next(keys), (L, d, r), dt),
            w_kr=_dense(next(keys), (L, d, pr), dt),
            w_uk=_dense(next(keys), (L, r, H * (D - pr)), dt),
            w_uv=_dense(next(keys), (L, r, H * D), dt),
        )
    else:
        layer.update(
            wq=_dense(next(keys), (L, d, H * D), dt),
            wk=_dense(next(keys), (L, d, Hkv * D), dt),
            wv=_dense(next(keys), (L, d, Hkv * D), dt),
        )
    if cfg.moe is not None:
        E, f = cfg.moe.n_experts, cfg.moe.d_ff_expert
        layer.update(
            router=_dense(next(keys), (L, d, E), jnp.float32),
            w1=_dense(next(keys), (L, E, d, f), dt),
            w2=_dense(next(keys), (L, E, f, d), dt),
            w3=_dense(next(keys), (L, E, d, f), dt),
        )
        if cfg.moe.n_shared:
            fs = f * cfg.moe.n_shared
            layer.update(
                sw1=_dense(next(keys), (L, d, fs), dt),
                sw2=_dense(next(keys), (L, fs, d), dt),
                sw3=_dense(next(keys), (L, d, fs), dt),
            )
    else:
        layer.update(
            w1=_dense(next(keys), (L, d, cfg.d_ff), dt),
            w2=_dense(next(keys), (L, cfg.d_ff, d), dt),
            w3=_dense(next(keys), (L, d, cfg.d_ff), dt),
        )
    params: Dict[str, Any] = {
        "embed": _dense(next(keys), (cfg.vocab, d), dt, scale=1.0),
        "ln_f": jnp.ones((d,), dt),
        "layers": layer,
    }
    if not cfg.tie_embeddings:
        params["unembed"] = _dense(next(keys), (d, cfg.vocab), dt)
    return params


# ---------------------------------------------------------------------------
# forward / loss / decode
# ---------------------------------------------------------------------------

def forward(params: Dict[str, Any], tokens: jnp.ndarray,
            cfg: TransformerConfig) -> jnp.ndarray:
    """tokens (B, S) -> logits (B, S, vocab)."""
    B, S = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(x, lp):
        y, _ = _layer(x, lp, cfg, positions)
        return y, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["layers"],
                        unroll=cfg.n_layers if cfg.cost_unroll else 1)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    unemb = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    return x @ unemb


def loss_fn(params: Dict[str, Any], tokens: jnp.ndarray,
            labels: jnp.ndarray, cfg: TransformerConfig) -> jnp.ndarray:
    logits = forward(params, tokens, cfg).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def init_cache(cfg: TransformerConfig, batch: int, max_len: int,
               dtype=None) -> Any:
    """Stacked KV cache, one leading layer axis (scan-carried)."""
    dt = dtype or cfg.dtype
    L, Hkv, D = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    if cfg.mla is not None:
        r, pr = cfg.mla.kv_lora_rank, cfg.mla.rope_head_dim
        return (jnp.zeros((L, batch, max_len, r), dt),
                jnp.zeros((L, batch, max_len, 1, pr), dt))
    return (jnp.zeros((L, batch, max_len, Hkv, D), dt),
            jnp.zeros((L, batch, max_len, Hkv, D), dt))


def decode_step(params: Dict[str, Any], tokens: jnp.ndarray,
                cache: Any, cache_len: jnp.ndarray,
                cfg: TransformerConfig):
    """One decode step: tokens (B, S_new) appended at cache_len.

    Returns (logits (B, S_new, vocab), new_cache, new_len).
    """
    B, S = tokens.shape
    x = params["embed"][tokens]
    positions = cache_len + jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(x, inp):
        lp, c = inp
        y, new_c = _layer(x, lp, cfg, positions, cache=c, cache_len=cache_len)
        return y, new_c

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache),
                                unroll=cfg.n_layers if cfg.cost_unroll else 1)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    unemb = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    return x @ unemb, new_cache, cache_len + S
