"""MACE — higher-order equivariant message passing [arXiv:2206.07697].

Config mace: n_layers=2, d_hidden=128, l_max=2, correlation_order=3, n_rbf=8.

TPU adaptation (DESIGN.md §3): irreps are carried in CARTESIAN form —
  l=0: scalars            (N, C)
  l=1: vectors            (N, C, 3)
  l=2: traceless symmetric rank-2 tensors (N, C, 3, 3)
Clebsch-Gordan tensor products for l <= 2 become exact Cartesian identities
(dot, cross, outer-traceless, matvec, double-contraction), so the model is
*exactly* E(3)-equivariant (property-tested under random rotations) without
Wigner matrices.  The Atomic Cluster Expansion (correlation order 3) is the
set of degree-<=3 invariant/covariant polynomial contractions of the
aggregated one-particle basis A — the same structure MACE builds with
generalized CG contractions, expressed over Cartesian tensors.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .gnn_common import (GraphBatch, mlp_init, mlp_apply, radial_basis)


@dataclasses.dataclass(frozen=True)
class MACEConfig:
    name: str = "mace"
    n_layers: int = 2
    d_hidden: int = 128        # channels C per irrep
    l_max: int = 2
    correlation_order: int = 3
    n_rbf: int = 8
    d_in: int = 16
    n_out: int = 1
    cutoff: float = 5.0
    dtype: Any = jnp.float32

    @property
    def n_paths(self) -> int:
        return 10  # edge-basis paths below


def _sym_traceless(t: jnp.ndarray) -> jnp.ndarray:
    """Project (.., 3, 3) onto the l=2 irrep: symmetric, trace-free."""
    s = 0.5 * (t + jnp.swapaxes(t, -1, -2))
    tr = jnp.trace(s, axis1=-2, axis2=-1)[..., None, None]
    eye = jnp.eye(3, dtype=t.dtype)
    return s - tr * eye / 3.0


def _mix(w: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Channel mixing (C_out, C_in) applied on axis 1 of (N, C_in, ...)."""
    return jnp.einsum("oc,nc...->no...", w, x)


def init_params(key: jax.Array, cfg: MACEConfig) -> Dict[str, Any]:
    C = cfg.d_hidden
    keys = iter(jax.random.split(key, 16 * cfg.n_layers + 4))

    def mixer(k):
        return (jax.random.normal(k, (C, C), jnp.float32)
                / np.sqrt(C)).astype(cfg.dtype)

    layers = []
    for _ in range(cfg.n_layers):
        layers.append({
            # radial MLP: per-path, per-channel weights R(r)
            "radial": mlp_init(next(keys), [cfg.n_rbf, 64,
                                            cfg.n_paths * C], cfg.dtype),
            # per-path channel mixers for message construction
            "mix_s": mixer(next(keys)), "mix_v": mixer(next(keys)),
            "mix_t": mixer(next(keys)),
            # ACE product-basis mixing weights (one per contraction path)
            "b0": (jax.random.normal(next(keys), (8, C), jnp.float32)
                   * 0.3).astype(cfg.dtype),
            "b1": (jax.random.normal(next(keys), (5, C), jnp.float32)
                   * 0.3).astype(cfg.dtype),
            "b2": (jax.random.normal(next(keys), (5, C), jnp.float32)
                   * 0.3).astype(cfg.dtype),
            "upd_s": mixer(next(keys)), "upd_v": mixer(next(keys)),
            "upd_t": mixer(next(keys)),
            "res_s": mixer(next(keys)), "res_v": mixer(next(keys)),
            "res_t": mixer(next(keys)),
            "gate": mlp_init(next(keys), [C, 2 * C], cfg.dtype),
        })
    return {
        "encode": mlp_init(next(keys), [cfg.d_in, C], cfg.dtype),
        "layers": layers,
        "readout": mlp_init(next(keys), [C, C, cfg.n_out], cfg.dtype),
    }


def _edge_basis(s_j, v_j, t_j, rhat, R):
    """One-particle basis phi: covariant products of neighbor features with
    Y_0(r)=1, Y_1(r)=rhat, Y_2(r)=rhat rhat^T - I/3.  R: (E, n_paths, C)."""
    eye = jnp.eye(3, dtype=s_j.dtype)
    y2 = rhat[:, None, :] * rhat[:, :, None] - eye / 3.0       # (E, 3, 3)
    y2 = y2[:, None]                                           # (E, 1, 3, 3)
    rh = rhat[:, None]                                         # (E, 1, 3)
    # scalar outputs
    a0 = (R[:, 0] * s_j,                                       # s * Y0
          R[:, 1] * jnp.einsum("eck,ek->ec", v_j, rhat),       # v . rhat
          R[:, 2] * jnp.einsum("ecij,eij->ec", t_j,
                               y2[:, 0]))                      # t : Y2
    # vector outputs
    a1 = (R[:, 3, :, None] * (s_j[..., None] * rh),            # s * Y1
          R[:, 4, :, None] * v_j,                              # v * Y0
          R[:, 5, :, None] * jnp.cross(v_j, jnp.broadcast_to(
              rh, v_j.shape)),                                 # v x rhat
          R[:, 6, :, None] * jnp.einsum("ecij,ej->eci", t_j, rhat))
    # tensor outputs
    a2 = (R[:, 7, :, None, None] * (s_j[..., None, None] * y2),
          R[:, 8, :, None, None] * _sym_traceless(
              v_j[..., :, None] * rh[..., None, :]),           # v (x) rhat
          R[:, 9, :, None, None] * t_j)                        # t * Y0
    return sum(a0[1:], a0[0]), sum(a1[1:], a1[0]), sum(a2[1:], a2[0])


def _ace_products(A0, A1, A2, lp):
    """Correlation-order <= 3 contractions of the aggregated basis A."""
    dot = lambda a, b: jnp.einsum("nci,nci->nc", a, b)
    ddot = lambda a, b: jnp.einsum("ncij,ncij->nc", a, b)
    matvec = lambda t, v: jnp.einsum("ncij,ncj->nci", t, v)
    # invariants (order 1, 2, 3)
    b0 = (lp["b0"][0] * A0
          + lp["b0"][1] * A0 * A0
          + lp["b0"][2] * dot(A1, A1)
          + lp["b0"][3] * ddot(A2, A2)
          + lp["b0"][4] * A0 * A0 * A0
          + lp["b0"][5] * A0 * dot(A1, A1)
          + lp["b0"][6] * dot(A1, matvec(A2, A1))
          + lp["b0"][7] * A0 * ddot(A2, A2))
    # covariant l=1 (order <= 3)
    b1 = (lp["b1"][0][:, None] * A1
          + lp["b1"][1][:, None] * (A0[..., None] * A1)
          + lp["b1"][2][:, None] * matvec(A2, A1)
          + lp["b1"][3][:, None] * (A0[..., None] ** 2 * A1)
          + lp["b1"][4][:, None] * (A0[..., None] * matvec(A2, A1)))
    # covariant l=2 (order <= 3)
    outer11 = _sym_traceless(A1[..., :, None] * A1[..., None, :])
    b2 = (lp["b2"][0][:, None, None] * A2
          + lp["b2"][1][:, None, None] * (A0[..., None, None] * A2)
          + lp["b2"][2][:, None, None] * outer11
          + lp["b2"][3][:, None, None] * (A0[..., None, None] ** 2 * A2)
          + lp["b2"][4][:, None, None] * _sym_traceless(
              jnp.einsum("ncik,nckj->ncij", A2, A2)))
    return b0, b1, b2


def forward(params: Dict[str, Any], batch: GraphBatch, cfg: MACEConfig
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Returns (graph outputs, final node irreps {s, v, t})."""
    assert batch.pos is not None
    x = batch.pos.astype(cfg.dtype)
    src, dst, em = batch.edge_src, batch.edge_dst, batch.edge_mask
    N, C = batch.n_nodes, cfg.d_hidden
    rel = x[dst] - x[src]
    dist = jnp.sqrt(jnp.maximum(jnp.sum(rel * rel, -1), 1e-12))
    rhat = rel / dist[:, None]
    rbf = radial_basis(dist, cfg.n_rbf, cfg.cutoff).astype(cfg.dtype)

    s = mlp_apply(params["encode"], batch.nodes.astype(cfg.dtype))  # (N, C)
    v = jnp.zeros((N, C, 3), cfg.dtype)
    t = jnp.zeros((N, C, 3, 3), cfg.dtype)
    energy = 0.0
    for lp in params["layers"]:
        R = mlp_apply(lp["radial"], rbf).reshape(-1, cfg.n_paths, C)
        R = R * em[:, None, None]
        s_j = _mix(lp["mix_s"], s)[src]
        v_j = _mix(lp["mix_v"], v)[src]
        t_j = _mix(lp["mix_t"], t)[src]
        p0, p1, p2 = _edge_basis(s_j, v_j, t_j, rhat, R)
        A0 = jax.ops.segment_sum(p0, dst, N)
        A1 = jax.ops.segment_sum(p1, dst, N)
        A2 = jax.ops.segment_sum(p2, dst, N)
        B0, B1, B2 = _ace_products(A0, A1, A2, lp)
        # gated residual update (gates are invariant functions of B0)
        g = mlp_apply(lp["gate"], B0)
        g1, g2 = jnp.split(jax.nn.sigmoid(g), 2, axis=-1)
        s = _mix(lp["res_s"], s) + _mix(lp["upd_s"], B0)
        v = _mix(lp["res_v"], v) + g1[..., None] * _mix(lp["upd_v"], B1)
        t = _mix(lp["res_t"], t) + g2[..., None, None] * _mix(lp["upd_t"], B2)
        s = jnp.where(batch.node_mask[:, None], s, 0)
        v = jnp.where(batch.node_mask[:, None, None], v, 0)
        t = jnp.where(batch.node_mask[:, None, None, None], t, 0)
        energy = energy + mlp_apply(params["readout"], s)
    pooled = jax.ops.segment_sum(energy, batch.graph_id, batch.n_graphs)
    return pooled, {"s": s, "v": v, "t": t}


def loss_fn(params, batch: GraphBatch, targets: jnp.ndarray,
            cfg: MACEConfig) -> jnp.ndarray:
    out, _ = forward(params, batch, cfg)
    return jnp.mean(jnp.square(out.astype(jnp.float32)
                               - targets.astype(jnp.float32)))
