"""Graph container: fixed-shape edge arrays + CSR views built with segment ops.

The paper's shared-memory graph (adjacency lists + hash sets) is replaced by a
TPU-friendly representation: a canonical undirected edge array (u < v), a CSR
over the *oriented* graph (low-out-degree DAG), and padded adjacency matrices
for vectorized set intersection.  Everything is a jnp array; construction runs
eagerly (data-dependent shapes) while per-round algorithm bodies stay
fixed-shape and vectorized.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

INT = jnp.int32
# Sentinel used to pad adjacency rows; must compare greater than any vertex id.
PAD = np.iinfo(np.int32).max


@dataclasses.dataclass(frozen=True)
class Graph:
    """Simple undirected graph.

    Attributes:
      n: number of vertices.
      edges: (m, 2) int32, canonical (u < v), lexicographically sorted, unique.
    """

    n: int
    edges: jnp.ndarray  # (m, 2) int32

    @property
    def m(self) -> int:
        return int(self.edges.shape[0])

    def degrees(self) -> jnp.ndarray:
        deg = jnp.zeros((self.n,), INT)
        deg = deg.at[self.edges[:, 0]].add(1)
        deg = deg.at[self.edges[:, 1]].add(1)
        return deg


def make_graph(n: int, edges) -> Graph:
    """Canonicalize an edge list: undirected, dedup, drop self-loops."""
    e = jnp.asarray(edges, INT).reshape((-1, 2))
    if e.shape[0]:
        lo = jnp.minimum(e[:, 0], e[:, 1])
        hi = jnp.maximum(e[:, 0], e[:, 1])
        keep = lo != hi
        lo, hi = lo[keep], hi[keep]
        order = jnp.lexsort((hi, lo))
        lo, hi = lo[order], hi[order]
        if lo.shape[0]:
            dup = jnp.concatenate([jnp.zeros((1,), bool),
                                   (lo[1:] == lo[:-1]) & (hi[1:] == hi[:-1])])
            lo, hi = lo[~dup], hi[~dup]
        e = jnp.stack([lo, hi], axis=1)
    return Graph(n=n, edges=e)


@dataclasses.dataclass(frozen=True)
class Digraph:
    """Oriented graph (DAG under a total order), CSR + padded adjacency.

    adj is (n, dmax) int32 with rows sorted ascending and padded with PAD so
    that vectorized `searchsorted` membership tests are valid on every row.
    """

    n: int
    offsets: jnp.ndarray  # (n + 1,) int32
    neighbors: jnp.ndarray  # (m,) int32 sorted within each row
    adj: jnp.ndarray  # (n, dmax) int32, PAD-padded
    outdeg: jnp.ndarray  # (n,) int32

    @property
    def dmax(self) -> int:
        return int(self.adj.shape[1])


def orient(g: Graph, rank: jnp.ndarray) -> Digraph:
    """Direct each edge from lower to higher `rank` (ties by vertex id).

    `rank` is a total-order key; with a degeneracy-like order the resulting
    out-degree is O(alpha) which bounds the clique-extension candidate sets.
    """
    u, v = g.edges[:, 0], g.edges[:, 1]
    # Direct u->v if (rank[u], u) < (rank[v], v).
    forward = (rank[u] < rank[v]) | ((rank[u] == rank[v]) & (u < v))
    src = jnp.where(forward, u, v)
    dst = jnp.where(forward, v, u)
    return _build_digraph(g.n, src, dst)


def _build_digraph(n: int, src: jnp.ndarray, dst: jnp.ndarray) -> Digraph:
    m = int(src.shape[0])
    # Sort by (src, dst) so each row's neighbor list is ascending.
    order = jnp.lexsort((dst, src))
    src_s, dst_s = src[order], dst[order]
    outdeg = jnp.zeros((n,), INT).at[src_s].add(1)
    offsets = jnp.concatenate([jnp.zeros((1,), INT), jnp.cumsum(outdeg)]).astype(INT)
    dmax = int(outdeg.max()) if m else 1
    dmax = max(dmax, 1)
    # Scatter neighbors into a padded (n, dmax) matrix.
    pos_in_row = jnp.arange(m, dtype=INT) - offsets[src_s]
    adj = jnp.full((n, dmax), PAD, INT).at[src_s, pos_in_row].set(dst_s)
    return Digraph(n=n, offsets=offsets, neighbors=dst_s, adj=adj, outdeg=outdeg)


def csr_from_pairs(keys: jnp.ndarray, vals: jnp.ndarray, n_keys: int):
    """Build a CSR (offsets, vals grouped by key) from (key, val) pairs."""
    order = jnp.argsort(keys, stable=True)
    v = vals[order]
    counts = jnp.zeros((n_keys,), INT)
    if int(keys.shape[0]):
        counts = counts.at[keys].add(1)
    offsets = jnp.concatenate([jnp.zeros((1,), INT), jnp.cumsum(counts)]).astype(INT)
    return offsets, v


def is_member(dg: Digraph, row: jnp.ndarray, query: jnp.ndarray) -> jnp.ndarray:
    """Vectorized membership: is `query[i]` in dg.adj[row[i]]? (binary search)."""
    rows = dg.adj[row]  # (B, dmax)
    idx = jnp.clip(jnp.sum(rows < query[:, None], axis=1), 0, dg.dmax - 1)
    return jnp.take_along_axis(rows, idx[:, None], axis=1)[:, 0] == query
