"""Parallel k-clique listing over a low-out-degree orientation.

TPU adaptation of Shi et al.'s REC-LIST-CLIQUES [54]: instead of recursive
work-stealing with per-thread hash/binary-search intersection, we run
*level-synchronous expansion*.  Level t holds all t-cliques as a flat
(N_t, t) array plus each clique's candidate set (the intersection of the
out-neighborhoods of its members) as a padded, row-sorted (N_t, dmax) array.
Extension = one vectorized batched binary search (VPU-friendly) + row sort.
Each clique is produced exactly once because the DAG orientation induces a
unique discovery order.

Shapes are data-dependent *between* levels (resolved eagerly); the work inside
a level is fixed-shape vectorized math.
"""
from __future__ import annotations

import dataclasses
from itertools import combinations
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from .container import Digraph, Graph, INT, PAD, orient
from .orientation import degree_rank


def _intersect_rows(cand: jnp.ndarray, ncand: jnp.ndarray, w: jnp.ndarray,
                    adj: jnp.ndarray, outdeg: jnp.ndarray):
    """Row-wise cand[i] := cand[i] & adj[w[i]]; rows stay sorted/PAD-padded."""
    rows = adj[w]  # (N, dmax_adj)

    def one(sorted_row, nvalid, queries):
        pos = jnp.searchsorted(sorted_row, queries)
        pos = jnp.clip(pos, 0, sorted_row.shape[0] - 1)
        hit = (sorted_row[pos] == queries) & (pos < nvalid) & (queries != PAD)
        return jnp.where(hit, queries, PAD)

    kept = jax.vmap(one)(rows, outdeg[w], cand)
    kept = jnp.sort(kept, axis=1)  # PADs (int32 max) move to the tail
    nkept = jnp.sum(kept != PAD, axis=1).astype(INT)
    return kept, nkept


@dataclasses.dataclass
class CliqueLevels:
    """t-cliques for the levels requested; rows are ascending vertex ids."""

    levels: Dict[int, jnp.ndarray]

    def count(self, t: int) -> int:
        return int(self.levels[t].shape[0])


def list_cliques(g: Graph, ks, rank: Optional[jnp.ndarray] = None,
                 dg: Optional[Digraph] = None) -> CliqueLevels:
    """List all t-cliques for each t in `ks` (max(ks) drives the expansion)."""
    ks = sorted(set(int(k) for k in ks))
    kmax = ks[-1]
    if dg is None:
        dg = orient(g, degree_rank(g) if rank is None else rank)
    out: Dict[int, jnp.ndarray] = {}

    # Level 1: every vertex, candidates = its out-neighborhood.
    verts = jnp.arange(g.n, dtype=INT)[:, None]
    cand = dg.adj
    ncand = dg.outdeg
    if 1 in ks:
        out[1] = verts

    for t in range(2, kmax + 1):
        # Drop partials that cannot extend.
        keep = ncand > 0
        verts, cand, ncand = verts[keep], cand[keep], ncand[keep]
        if verts.shape[0] == 0:
            for kk in ks:
                if kk >= t:
                    out[kk] = jnp.zeros((0, kk), INT)
            return CliqueLevels(out)
        counts = ncand
        total = int(jnp.sum(counts))
        starts = jnp.cumsum(counts) - counts
        rep = jnp.repeat(jnp.arange(verts.shape[0], dtype=INT), counts,
                         total_repeat_length=total)
        pos = jnp.arange(total, dtype=INT) - starts[rep]
        c = cand[rep, pos]
        verts = jnp.concatenate([verts[rep], c[:, None]], axis=1)
        if t in ks:
            out[t] = jnp.sort(verts, axis=1)
        if t < kmax:
            cand, ncand = _intersect_rows(cand[rep], counts[rep], c, dg.adj, dg.outdeg)
    return CliqueLevels(out)


def count_cliques(g: Graph, k: int, rank: Optional[jnp.ndarray] = None) -> int:
    """Count k-cliques (counting pass: last level is not materialized)."""
    if k == 1:
        return g.n
    if k == 2:
        return g.m
    dg = orient(g, degree_rank(g) if rank is None else rank)
    verts = jnp.arange(g.n, dtype=INT)[:, None]
    cand, ncand = dg.adj, dg.outdeg
    for t in range(2, k):
        keep = ncand > 0
        verts, cand, ncand = verts[keep], cand[keep], ncand[keep]
        if verts.shape[0] == 0:
            return 0
        counts = ncand
        total = int(jnp.sum(counts))
        starts = jnp.cumsum(counts) - counts
        rep = jnp.repeat(jnp.arange(verts.shape[0], dtype=INT), counts,
                         total_repeat_length=total)
        pos = jnp.arange(total, dtype=INT) - starts[rep]
        c = cand[rep, pos]
        verts = verts[rep]
        cand, ncand = _intersect_rows(cand[rep], counts[rep], c, dg.adj, dg.outdeg)
    return int(jnp.sum(ncand))


# ---------------------------------------------------------------------------
# Row-id machinery: the paper's "parallel hash table keyed by r-cliques".
# ---------------------------------------------------------------------------

def lexsort_rows(rows: jnp.ndarray) -> jnp.ndarray:
    """Order that sorts rows lexicographically (column 0 most significant)."""
    keys = tuple(rows[:, c] for c in reversed(range(rows.shape[1])))
    return jnp.lexsort(keys)


def unique_rows(rows: jnp.ndarray):
    """(unique_sorted_rows, inverse_ids). Eager (data-dependent output size)."""
    if rows.shape[0] == 0:
        return rows, jnp.zeros((0,), INT)
    order = lexsort_rows(rows)
    srows = rows[order]
    neq = jnp.any(srows[1:] != srows[:-1], axis=1)
    first = jnp.concatenate([jnp.ones((1,), bool), neq])
    ids_sorted = (jnp.cumsum(first) - 1).astype(INT)
    inverse = jnp.zeros((rows.shape[0],), INT).at[order].set(ids_sorted)
    return srows[first], inverse


def sort_join(table: jnp.ndarray, queries: jnp.ndarray) -> jnp.ndarray:
    """Map each query row to its index in `table` (-1 when absent).

    `table` must be lexicographically sorted unique rows (ids = positions).
    One lexsort + forward cummax — the vectorized replacement for per-element
    hash lookups.
    """
    T, Q = int(table.shape[0]), int(queries.shape[0])
    if Q == 0:
        return jnp.zeros((0,), INT)
    comb = jnp.concatenate([table, queries], axis=0)
    flag = jnp.concatenate([jnp.zeros((T,), INT), jnp.ones((Q,), INT)])
    keys = (flag,) + tuple(comb[:, c] for c in reversed(range(comb.shape[1])))
    order = jnp.lexsort(keys)
    ids_sorted = jnp.where(order < T, order.astype(INT), -1)
    filled = jax.lax.cummax(ids_sorted)
    # Validate that the fill actually matches (guards absent queries).
    matched_rows = table[jnp.clip(filled, 0, max(T - 1, 0))]
    ok = (filled >= 0) & jnp.all(matched_rows == comb[order], axis=1)
    ids_sorted = jnp.where(ok, filled, -1).astype(INT)
    inv = jnp.argsort(order)  # comb index -> sorted position
    return ids_sorted[inv[T:]]


def subset_columns(s: int, r: int):
    """All C(s, r) sorted column-index subsets (static python)."""
    return list(combinations(range(s), r))
