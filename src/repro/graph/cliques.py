"""Parallel k-clique listing over a low-out-degree orientation.

TPU adaptation of Shi et al.'s REC-LIST-CLIQUES [54]: instead of recursive
work-stealing with per-thread hash/binary-search intersection, we run
*level-synchronous expansion*.  Level t holds all t-cliques as a flat
(N_t, t) array plus each clique's candidate set (the intersection of the
out-neighborhoods of its members) as a padded, row-sorted (N_t, dmax) array.
Extension = one vectorized batched binary search (VPU-friendly) + row sort.
Each clique is produced exactly once because the DAG orientation induces a
unique discovery order.

Shapes are data-dependent *between* levels (resolved eagerly); the work inside
a level is fixed-shape vectorized math.
"""
from __future__ import annotations

import dataclasses
from itertools import combinations
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .container import Digraph, Graph, INT, PAD, orient
from .orientation import degree_rank


def _intersect_rows(cand: jnp.ndarray, ncand: jnp.ndarray, w: jnp.ndarray,
                    adj: jnp.ndarray, outdeg: jnp.ndarray):
    """Row-wise cand[i] := cand[i] & adj[w[i]]; rows stay sorted/PAD-padded."""
    rows = adj[w]  # (N, dmax_adj)

    def one(sorted_row, nvalid, queries):
        pos = jnp.searchsorted(sorted_row, queries)
        pos = jnp.clip(pos, 0, sorted_row.shape[0] - 1)
        hit = (sorted_row[pos] == queries) & (pos < nvalid) & (queries != PAD)
        return jnp.where(hit, queries, PAD)

    kept = jax.vmap(one)(rows, outdeg[w], cand)
    kept = jnp.sort(kept, axis=1)  # PADs (int32 max) move to the tail
    nkept = jnp.sum(kept != PAD, axis=1).astype(INT)
    return kept, nkept


@dataclasses.dataclass
class CliqueLevels:
    """t-cliques for the levels requested; rows are ascending vertex ids."""

    levels: Dict[int, jnp.ndarray]

    def count(self, t: int) -> int:
        return int(self.levels[t].shape[0])


def expand_levels(dg: Digraph, seeds: jnp.ndarray, ks):
    """Level-synchronous expansion from the level-1 `seeds` vertices.

    Returns ``({t: (N_t, t) rows for t in ks}, peak_bytes)`` where rows are
    ascending vertex ids and ``peak_bytes`` is the largest intermediate
    footprint (verts + candidate arrays) any level materialized.  Because the
    DAG orientation gives every clique a unique minimum-rank discovery path,
    expansions from disjoint seed sets are independent and duplicate-free:
    concatenating per-seed-range outputs in seed order reproduces the
    all-vertices expansion row-for-row.  This is the chunking invariant the
    memory-bounded incidence builder relies on (DESIGN.md §7).
    """
    ks = sorted(set(int(k) for k in ks))
    kmax = ks[-1]
    out: Dict[int, jnp.ndarray] = {}
    verts = seeds.astype(INT)[:, None]
    if int(seeds.shape[0]) == dg.n:  # full frontier: no gather copy needed
        cand, ncand = dg.adj, dg.outdeg
    else:
        cand, ncand = dg.adj[seeds], dg.outdeg[seeds]
    peak = int(verts.nbytes) + int(cand.nbytes) + int(ncand.nbytes)
    if 1 in ks:
        out[1] = verts

    for t in range(2, kmax + 1):
        # Drop partials that cannot extend.
        keep = ncand > 0
        verts, cand, ncand = verts[keep], cand[keep], ncand[keep]
        if verts.shape[0] == 0:
            for kk in ks:
                if kk >= t:
                    out[kk] = jnp.zeros((0, kk), INT)
            return out, peak
        counts = ncand
        total = int(jnp.sum(counts))
        starts = jnp.cumsum(counts) - counts
        rep = jnp.repeat(jnp.arange(verts.shape[0], dtype=INT), counts,
                         total_repeat_length=total)
        pos = jnp.arange(total, dtype=INT) - starts[rep]
        c = cand[rep, pos]
        verts = jnp.concatenate([verts[rep], c[:, None]], axis=1)
        if t in ks:
            out[t] = jnp.sort(verts, axis=1)
        if t < kmax:
            cand, ncand = _intersect_rows(cand[rep], counts[rep], c, dg.adj,
                                          dg.outdeg)
        # verts[rep]+concat, plus the intersect's gathers/position/sort
        # transients (~4 candidate-width arrays) — mirrors the np meter
        level_bytes = 2 * int(verts.nbytes) + (4 * int(cand.nbytes) +
                                               int(ncand.nbytes)
                                               if t < kmax else 0)
        peak = max(peak, level_bytes)
    return out, peak


def list_cliques(g: Graph, ks, rank: Optional[jnp.ndarray] = None,
                 dg: Optional[Digraph] = None) -> CliqueLevels:
    """List all t-cliques for each t in `ks` (max(ks) drives the expansion)."""
    if dg is None:
        dg = orient(g, degree_rank(g) if rank is None else rank)
    out, _ = expand_levels(dg, jnp.arange(g.n, dtype=INT), ks)
    return CliqueLevels(out)


def _intersect_rows_np(cand: np.ndarray, w: np.ndarray, adj: np.ndarray,
                       outdeg: np.ndarray):
    """Numpy twin of ``_intersect_rows`` (same results on the same inputs).

    Batched binary search via a single global ``searchsorted``: each row of
    `adj[w]` is ascending and PAD < 2^32, so offsetting row i by i<<32 makes
    the flattened array globally sorted and per-row searches exact.
    """
    rows = adj[w]  # (N, da)
    N, da = rows.shape
    base = np.arange(N, dtype=np.int64) << 32
    flat = (rows.astype(np.int64) + base[:, None]).ravel()
    q = (cand.astype(np.int64) + base[:, None]).ravel()
    pos = np.searchsorted(flat, q).reshape(N, -1) - \
        (np.arange(N, dtype=np.int64) * da)[:, None]
    pos = np.clip(pos, 0, da - 1)
    hit = (np.take_along_axis(rows, pos, axis=1) == cand) & \
        (pos < outdeg[w][:, None]) & (cand != PAD)
    kept = np.where(hit, cand, PAD).astype(np.int32)
    kept.sort(axis=1)
    nkept = (kept != PAD).sum(axis=1).astype(np.int32)
    # the transients this call held live (the int64 flat/q copies dominate):
    # what the chunked builder's memory meter must charge
    work_bytes = rows.nbytes + flat.nbytes + q.nbytes + pos.nbytes + \
        kept.nbytes
    return kept, nkept, work_bytes


def _expand_levels_np(adj: np.ndarray, outdeg: np.ndarray, seeds: np.ndarray,
                      ks):
    """Numpy twin of ``expand_levels`` for the host-side chunked builder.

    Same discovery order, same rows, same dtypes — pure-integer ops with no
    XLA dispatch, so thousands of small chunks stay cheap on CPU.
    """
    ks = sorted(set(int(k) for k in ks))
    kmax = ks[-1]
    out = {}
    verts = seeds.astype(np.int32)[:, None]
    cand = adj[seeds]
    ncand = outdeg[seeds]
    peak = verts.nbytes + cand.nbytes + ncand.nbytes
    if 1 in ks:
        out[1] = verts
    for t in range(2, kmax + 1):
        keep = ncand > 0
        verts, cand, ncand = verts[keep], cand[keep], ncand[keep]
        if verts.shape[0] == 0:
            for kk in ks:
                if kk >= t:
                    out[kk] = np.zeros((0, kk), np.int32)
            return out, peak
        counts = ncand
        rep = np.repeat(np.arange(verts.shape[0], dtype=np.int64), counts)
        starts = np.cumsum(counts) - counts
        pos = np.arange(rep.size, dtype=np.int64) - starts[rep]
        c = cand[rep, pos]
        cand_rep = cand[rep]
        verts = np.concatenate([verts[rep], c[:, None]], axis=1)
        if t in ks:
            out[t] = np.sort(verts, axis=1)
        work_bytes = 0
        if t < kmax:
            cand, ncand, work_bytes = _intersect_rows_np(cand_rep, c, adj,
                                                         outdeg)
        level_bytes = 2 * verts.nbytes + rep.nbytes + pos.nbytes + \
            cand_rep.nbytes + work_bytes
        peak = max(peak, level_bytes)
        del cand_rep
    return out, peak


def iter_clique_chunks(dg: Digraph, ks, chunk_size: int, *,
                       start: int = 0, stop: Optional[int] = None):
    """Chunked clique listing: expand `chunk_size` source vertices at a time.

    Yields ``(start, levels, peak_bytes)`` per contiguous seed range, with
    levels as host numpy arrays.  Chunks are independent and duplicate-free
    (see ``expand_levels``); concatenating each level over chunks in yield
    order is row-identical to ``list_cliques``.  Peak live memory is one
    chunk's expansion instead of the whole graph's.

    ``start``/``stop`` restrict the walk to the seed range [start, stop) —
    a shard of the level-1 frontier.  Chunk boundaries are anchored at
    ``start``, so a distributed build whose shard boundaries fall on chunk
    boundaries (``repro.distbuild``) yields exactly the chunks the
    whole-frontier walk would have produced for that range.
    """
    chunk_size = max(1, int(chunk_size))
    stop = dg.n if stop is None else min(int(stop), dg.n)
    adj = np.asarray(dg.adj)
    outdeg = np.asarray(dg.outdeg)
    for s0 in range(int(start), stop, chunk_size):
        seeds = np.arange(s0, min(s0 + chunk_size, stop), dtype=np.int32)
        levels, peak = _expand_levels_np(adj, outdeg, seeds, ks)
        yield s0, levels, peak


def sort_join_np(table: np.ndarray, queries: np.ndarray) -> np.ndarray:
    """Numpy twin of ``sort_join`` (same ids on the same inputs).

    Used by the chunked incidence builder so per-block joins run without
    XLA dispatch; the jnp version stays the canonical device path.
    """
    T, Q = int(table.shape[0]), int(queries.shape[0])
    if Q == 0:
        return np.zeros((0,), np.int32)
    if T == 0:
        return np.full((Q,), -1, np.int32)
    comb_rows = np.concatenate([table, queries], axis=0)
    flag = np.concatenate([np.zeros((T,), np.int32), np.ones((Q,), np.int32)])
    keys = (flag,) + tuple(comb_rows[:, c]
                           for c in reversed(range(comb_rows.shape[1])))
    order = np.lexsort(keys)
    ids_sorted = np.where(order < T, order, -1).astype(np.int64)
    filled = np.maximum.accumulate(ids_sorted)
    matched_rows = table[np.clip(filled, 0, T - 1)]
    ok = (filled >= 0) & (matched_rows == comb_rows[order]).all(axis=1)
    ids_sorted = np.where(ok, filled, -1).astype(np.int32)
    inv = np.argsort(order)
    return ids_sorted[inv[T:]]


def count_cliques(g: Graph, k: int, rank: Optional[jnp.ndarray] = None) -> int:
    """Count k-cliques (counting pass: last level is not materialized)."""
    if k == 1:
        return g.n
    if k == 2:
        return g.m
    dg = orient(g, degree_rank(g) if rank is None else rank)
    verts = jnp.arange(g.n, dtype=INT)[:, None]
    cand, ncand = dg.adj, dg.outdeg
    for t in range(2, k):
        keep = ncand > 0
        verts, cand, ncand = verts[keep], cand[keep], ncand[keep]
        if verts.shape[0] == 0:
            return 0
        counts = ncand
        total = int(jnp.sum(counts))
        starts = jnp.cumsum(counts) - counts
        rep = jnp.repeat(jnp.arange(verts.shape[0], dtype=INT), counts,
                         total_repeat_length=total)
        pos = jnp.arange(total, dtype=INT) - starts[rep]
        c = cand[rep, pos]
        verts = verts[rep]
        cand, ncand = _intersect_rows(cand[rep], counts[rep], c, dg.adj, dg.outdeg)
    return int(jnp.sum(ncand))


# ---------------------------------------------------------------------------
# Row-id machinery: the paper's "parallel hash table keyed by r-cliques".
# ---------------------------------------------------------------------------

def lexsort_rows(rows: jnp.ndarray) -> jnp.ndarray:
    """Order that sorts rows lexicographically (column 0 most significant)."""
    keys = tuple(rows[:, c] for c in reversed(range(rows.shape[1])))
    return jnp.lexsort(keys)


def unique_rows(rows: jnp.ndarray):
    """(unique_sorted_rows, inverse_ids). Eager (data-dependent output size)."""
    if rows.shape[0] == 0:
        return rows, jnp.zeros((0,), INT)
    order = lexsort_rows(rows)
    srows = rows[order]
    neq = jnp.any(srows[1:] != srows[:-1], axis=1)
    first = jnp.concatenate([jnp.ones((1,), bool), neq])
    ids_sorted = (jnp.cumsum(first) - 1).astype(INT)
    inverse = jnp.zeros((rows.shape[0],), INT).at[order].set(ids_sorted)
    return srows[first], inverse


def sort_join(table: jnp.ndarray, queries: jnp.ndarray) -> jnp.ndarray:
    """Map each query row to its index in `table` (-1 when absent).

    `table` must be lexicographically sorted unique rows (ids = positions).
    One lexsort + forward cummax — the vectorized replacement for per-element
    hash lookups.
    """
    T, Q = int(table.shape[0]), int(queries.shape[0])
    if Q == 0:
        return jnp.zeros((0,), INT)
    if T == 0:
        # Empty table: nothing can match.  (The general path below would
        # index table[0] on a zero-row array — the degenerate input every
        # r-clique-free chunk of the chunked builder produces.)
        return jnp.full((Q,), -1, INT)
    comb = jnp.concatenate([table, queries], axis=0)
    flag = jnp.concatenate([jnp.zeros((T,), INT), jnp.ones((Q,), INT)])
    keys = (flag,) + tuple(comb[:, c] for c in reversed(range(comb.shape[1])))
    order = jnp.lexsort(keys)
    ids_sorted = jnp.where(order < T, order.astype(INT), -1)
    filled = jax.lax.cummax(ids_sorted)
    # Validate that the fill actually matches (guards absent queries).
    matched_rows = table[jnp.clip(filled, 0, max(T - 1, 0))]
    ok = (filled >= 0) & jnp.all(matched_rows == comb[order], axis=1)
    ids_sorted = jnp.where(ok, filled, -1).astype(INT)
    inv = jnp.argsort(order)  # comb index -> sorted position
    return ids_sorted[inv[T:]]


def subset_columns(s: int, r: int):
    """All C(s, r) sorted column-index subsets (static python)."""
    return list(combinations(range(s), r))
