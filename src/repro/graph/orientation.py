"""Low out-degree orientations.

The paper uses the parallel O(alpha)-orientation of Shi et al. / Besta et al.
(O(m) work, O(log^2 n) span).  We provide two TPU-friendly orders:

  * `degree_rank`: order by degree (out-degree bounded by O(sqrt(m))) —
    a single sort, the cheapest option.
  * `approx_degeneracy_rank`: the (2+eps)-approximate degeneracy order via
    batched peeling (remove all vertices with degree <= (1+eps) * avg of the
    remaining subgraph each round; O(log n) rounds).  This is the standard
    work-efficient parallel substitute for the sequential degeneracy order
    and matches the paper's O(alpha) out-degree guarantee up to (2+eps).
"""
from __future__ import annotations

import jax.numpy as jnp

from .container import Graph, INT


def degree_rank(g: Graph) -> jnp.ndarray:
    deg = g.degrees()
    # rank = position in ascending-degree order; ties by id handled in orient().
    return deg.astype(INT)


def approx_degeneracy_rank(g: Graph, eps: float = 0.5, max_rounds: int = 10_000) -> jnp.ndarray:
    """(2+eps)-approximate degeneracy peeling order.

    Each round removes every vertex whose degree in the surviving subgraph is
    at most (1+eps) * (2 * m_live / n_live); all vertices removed in the same
    round share a rank.  O(log_{1+eps} n) rounds, each a fixed pattern of
    segment ops.
    """
    n = g.n
    u, v = g.edges[:, 0], g.edges[:, 1]
    alive = jnp.ones((n,), bool)
    rank = jnp.zeros((n,), INT)
    r = 0
    while bool(alive.any()) and r < max_rounds:
        e_live = alive[u] & alive[v]
        deg = jnp.zeros((n,), INT)
        deg = deg.at[u].add(e_live.astype(INT))
        deg = deg.at[v].add(e_live.astype(INT))
        n_live = jnp.sum(alive)
        m_live = jnp.sum(e_live)
        thresh = jnp.ceil((1.0 + eps) * 2.0 * m_live / jnp.maximum(n_live, 1))
        peel = alive & (deg <= thresh)
        # Guard: always make progress (threshold >= 0 removes deg-0 vertices).
        rank = jnp.where(peel, r, rank)
        alive = alive & ~peel
        r += 1
    return rank
