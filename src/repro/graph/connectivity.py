"""Connected components via min-hooking + pointer jumping.

Data-parallel replacement for both the paper's linear-work connectivity [22]
and the Jayanti–Tarjan concurrent union-find: every round scatter-mins the
smaller endpoint label over each edge, then pointer-jumps labels to their
fixpoint.  Deterministic, O(log n) rounds w.h.p. on real graphs, each round a
fixed pattern of gathers/scatters (the shape TPUs execute well).  We trade the
paper's O(m) work for O(m log n); DESIGN.md records the trade.

Both loops are fixed-carry ``lax.while_loop``s: no ``bool(...)`` host sync per
round, so they trace under ``jit`` / ``shard_map`` and the fused hierarchy
engine (``repro.core.engine``) can nest them inside its peel loop.  Eager
callers get the same device-resident loop (one dispatch per call).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .container import INT


def pointer_jump(labels: jnp.ndarray, iters: int | None = None) -> jnp.ndarray:
    """Resolve label forest to roots: labels <- labels[labels] to fixpoint.

    Pointer doubling squares path lengths each step, so the default cap of
    n+1 iterations is never binding (depth halves per step); `iters` bounds
    the trip count explicitly when the caller knows the depth.
    """
    n = int(labels.shape[0])
    if n == 0:
        return labels
    cap = iters if iters is not None else n + 1

    # the l[l] gather lives in body only (this is the innermost loop of the
    # fused engine's 4-deep nest; a gather in cond would double it)
    def cond(carry):
        _, changed, i = carry
        return changed & (i < cap)

    def body(carry):
        l, _, i = carry
        nxt = l[l]
        return nxt, jnp.any(nxt != l), i + 1

    out, _, _ = jax.lax.while_loop(
        cond, body, (labels, jnp.asarray(True), jnp.zeros((), INT)))
    return out


def connected_components(n: int, u: jnp.ndarray, v: jnp.ndarray,
                         init: jnp.ndarray | None = None) -> jnp.ndarray:
    """Component labels (min vertex id reachable) for graph (n, edges u-v).

    `init` seeds labels (e.g. an existing union-find forest, resolved or
    not); self-edges are no-ops, so callers with fixed-shape edge buffers can
    mask invalid slots to (0, 0).  Returned labels are fully resolved
    (labels[labels] == labels).
    """
    labels = (jnp.arange(n, dtype=INT) if init is None
              else pointer_jump(init.astype(INT)))
    if int(u.shape[0]) == 0 or n == 0:
        return labels

    def hook(l):
        lu, lv = l[u], l[v]
        m = jnp.minimum(lu, lv)
        # Hook at the ROOTS (lu, lv), not the endpoints: hooking endpoints
        # only relabels vertices incident to the current edge set, which
        # fractures components seeded via `init` whose members are not
        # endpoints.  Root-hooking + jumping converges for both cases.
        return pointer_jump(l.at[lu].min(m).at[lv].min(m))

    def cond(carry):
        _, changed = carry
        return changed

    def body(carry):
        l, _ = carry
        new = hook(l)
        return new, jnp.any(new != l)

    labels, _ = jax.lax.while_loop(cond, body, (labels, jnp.asarray(True)))
    return labels
