"""Connected components via min-hooking + pointer jumping.

Data-parallel replacement for both the paper's linear-work connectivity [22]
and the Jayanti–Tarjan concurrent union-find: every round scatter-mins the
smaller endpoint label over each edge, then pointer-jumps labels to their
fixpoint.  Deterministic, O(log n) rounds w.h.p. on real graphs, each round a
fixed pattern of gathers/scatters (the shape TPUs execute well).  We trade the
paper's O(m) work for O(m log n); DESIGN.md records the trade.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .container import INT


def pointer_jump(labels: jnp.ndarray, iters: int | None = None) -> jnp.ndarray:
    """Resolve label forest to roots: labels[i] <- labels[labels[i]] to fixpoint."""
    n = int(labels.shape[0])
    if n == 0:
        return labels
    max_iters = iters if iters is not None else max(1, n.bit_length() + 1)
    for _ in range(max_iters):
        nxt = labels[labels]
        if bool(jnp.all(nxt == labels)):
            return labels
        labels = nxt
    return labels


def connected_components(n: int, u: jnp.ndarray, v: jnp.ndarray,
                         init: jnp.ndarray | None = None) -> jnp.ndarray:
    """Component labels (min vertex id reachable) for graph (n, edges u-v).

    `init` seeds labels (e.g. an existing union-find forest, resolved or not).
    """
    labels = jnp.arange(n, dtype=INT) if init is None else pointer_jump(init.astype(INT))
    if int(u.shape[0]) == 0:
        return labels
    while True:
        lu, lv = labels[u], labels[v]
        m = jnp.minimum(lu, lv)
        # Hook at the ROOTS (lu, lv), not the endpoints: hooking endpoints
        # only relabels vertices incident to the current edge set, which
        # fractures components seeded via `init` whose members are not
        # endpoints.  Root-hooking + jumping converges for both cases.
        new = labels.at[lu].min(m).at[lv].min(m)
        new = pointer_jump(new)
        if bool(jnp.all(new == labels)):
            return labels
        labels = new
