"""Batched union-find on top of min-hooking connectivity.

The root of every set is the minimum member id — deterministic, so parallel
runs and the sequential oracle agree on representatives.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .connectivity import connected_components, pointer_jump
from .container import INT


@dataclasses.dataclass
class BatchedUnionFind:
    parent: jnp.ndarray  # (n,) int32, parent[i] <= i invariant after resolve

    @classmethod
    def create(cls, n: int) -> "BatchedUnionFind":
        return cls(parent=jnp.arange(n, dtype=INT))

    def find_all(self) -> jnp.ndarray:
        self.parent = pointer_jump(self.parent)
        return self.parent

    def union_edges(self, u: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
        """Unite endpoints of all edges at once; returns resolved labels."""
        self.parent = connected_components(int(self.parent.shape[0]), u, v,
                                           init=self.parent)
        return self.parent
