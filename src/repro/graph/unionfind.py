"""Batched union-find on top of min-hooking connectivity.

The root of every set is the minimum member id — deterministic, so parallel
runs and the sequential oracle agree on representatives.

The functional API (``uf_create`` / ``uf_find_all`` / ``uf_union_edges``) is
pure and jit-compatible: state is a plain (n,) parent array, every op returns
a new array, and the underlying loops are ``lax.while_loop``s — this is the
form the fused hierarchy engine threads through its peel carry
(DESIGN.md §5).  ``BatchedUnionFind`` wraps it for eager host callers.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .connectivity import connected_components, pointer_jump
from .container import INT


def uf_create(n: int) -> jnp.ndarray:
    """Fresh parent array: every element its own root."""
    return jnp.arange(n, dtype=INT)


def uf_find_all(parent: jnp.ndarray) -> jnp.ndarray:
    """Fully resolved parent array (parent[parent] == parent)."""
    return pointer_jump(parent)


def uf_union_edges(parent: jnp.ndarray, u: jnp.ndarray,
                   v: jnp.ndarray) -> jnp.ndarray:
    """Unite endpoints of all edges at once; returns resolved parents.

    Self-edges are no-ops, so fixed-shape callers mask dead slots to (0, 0).
    """
    return connected_components(int(parent.shape[0]), u, v, init=parent)


@dataclasses.dataclass
class BatchedUnionFind:
    parent: jnp.ndarray  # (n,) int32, parent[i] <= i invariant after resolve

    @classmethod
    def create(cls, n: int) -> "BatchedUnionFind":
        return cls(parent=uf_create(n))

    def find_all(self) -> jnp.ndarray:
        self.parent = uf_find_all(self.parent)
        return self.parent

    def union_edges(self, u: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
        """Unite endpoints of all edges at once; returns resolved labels."""
        self.parent = uf_union_edges(self.parent, u, v)
        return self.parent
