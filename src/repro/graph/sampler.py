"""GraphSAGE-style fanout neighbor sampler (minibatch_lg shape).

A real sampler: uniform without-replacement-ish sampling from CSR neighbor
lists, layer by layer, returning the union subgraph with static worst-case
shapes (padded) so the sampled step can be jitted / dry-run lowered.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .container import Graph


@dataclasses.dataclass(frozen=True)
class SampledBlock:
    """One sampled k-hop block (padded to static capacity)."""

    node_ids: np.ndarray      # (cap_nodes,) global ids, -1 pad
    n_nodes: int
    edge_src: np.ndarray      # (cap_edges,) local indices into node_ids
    edge_dst: np.ndarray
    n_edges: int
    seed_count: int           # first `seed_count` node_ids are the seeds


class NeighborSampler:
    def __init__(self, g: Graph, fanouts: Sequence[int], seed: int = 0):
        edges = np.asarray(g.edges)
        # symmetric CSR
        src = np.concatenate([edges[:, 0], edges[:, 1]])
        dst = np.concatenate([edges[:, 1], edges[:, 0]])
        order = np.argsort(src, kind="stable")
        self.dst = dst[order]
        counts = np.bincount(src, minlength=g.n)
        self.offsets = np.concatenate([[0], np.cumsum(counts)])
        self.n = g.n
        self.fanouts = list(fanouts)
        self.rng = np.random.default_rng(seed)

    @staticmethod
    def capacities(batch_nodes: int, fanouts: Sequence[int]):
        """Static worst-case (n_nodes, n_edges) for a padded block."""
        nodes, layer = batch_nodes, batch_nodes
        edges = 0
        for f in fanouts:
            edges += layer * f
            layer *= f
            nodes += layer
        return nodes, edges

    def sample(self, seeds: np.ndarray) -> SampledBlock:
        cap_nodes, cap_edges = self.capacities(len(seeds), self.fanouts)
        frontier = np.asarray(seeds, dtype=np.int64)
        all_src, all_dst = [], []
        node_list = [frontier]
        for f in self.fanouts:
            deg = self.offsets[frontier + 1] - self.offsets[frontier]
            # uniform with replacement when deg > 0 (standard GraphSAGE)
            draw = self.rng.integers(0, np.maximum(deg, 1)[:, None],
                                     size=(len(frontier), f))
            nbr = self.dst[self.offsets[frontier][:, None] + draw]
            valid = np.broadcast_to(deg[:, None] > 0, (len(frontier), f))
            src = np.repeat(frontier, f).reshape(len(frontier), f)
            all_src.append(src[valid])
            all_dst.append(nbr[valid])
            frontier = np.unique(nbr[valid])
            node_list.append(frontier)
        nodes = np.unique(np.concatenate(node_list))
        # relabel: seeds first
        seeds64 = np.asarray(seeds, dtype=np.int64)
        rest = np.setdiff1d(nodes, seeds64, assume_unique=False)
        node_ids = np.concatenate([seeds64, rest])
        lookup = {int(v): i for i, v in enumerate(node_ids)}
        src = np.array([lookup[int(x)] for x in np.concatenate(all_src)], dtype=np.int32)
        dst = np.array([lookup[int(x)] for x in np.concatenate(all_dst)], dtype=np.int32)
        # pad to capacity
        pad_nodes = np.full(cap_nodes, -1, dtype=np.int64)
        pad_nodes[: len(node_ids)] = node_ids
        pad_src = np.zeros(cap_edges, dtype=np.int32)
        pad_dst = np.zeros(cap_edges, dtype=np.int32)
        pad_src[: len(src)] = src
        pad_dst[: len(dst)] = dst
        return SampledBlock(node_ids=pad_nodes, n_nodes=len(node_ids),
                            edge_src=pad_src, edge_dst=pad_dst,
                            n_edges=len(src), seed_count=len(seeds))
