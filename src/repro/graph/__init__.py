from .container import Graph, Digraph, make_graph, orient, csr_from_pairs, PAD, INT
from .orientation import degree_rank, approx_degeneracy_rank
from .cliques import (CliqueLevels, list_cliques, count_cliques, unique_rows,
                      sort_join, lexsort_rows, subset_columns, expand_levels,
                      iter_clique_chunks)
from .connectivity import connected_components, pointer_jump
from .unionfind import (BatchedUnionFind, uf_create, uf_find_all,
                        uf_union_edges)
from . import generators
from .sampler import NeighborSampler, SampledBlock
