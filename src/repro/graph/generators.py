"""Graph generators for tests and benchmarks (SNAP graphs are offline)."""
from __future__ import annotations

import numpy as np

from .container import Graph, make_graph


def erdos_renyi(n: int, p: float, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    iu = np.triu_indices(n, k=1)
    mask = rng.random(iu[0].shape[0]) < p
    edges = np.stack([iu[0][mask], iu[1][mask]], axis=1)
    return make_graph(n, edges)


def erdos_renyi_sparse(n: int, m_target: int, seed: int = 0) -> Graph:
    """O(m) sampling for large sparse graphs."""
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n, size=2 * m_target)
    v = rng.integers(0, n, size=2 * m_target)
    keep = u != v
    return make_graph(n, np.stack([u[keep], v[keep]], axis=1)[:m_target])


def barabasi_albert(n: int, k: int, seed: int = 0) -> Graph:
    """Preferential attachment: power-law degrees (high clique counts)."""
    rng = np.random.default_rng(seed)
    targets = list(range(k))
    repeated: list[int] = list(range(k))
    edges = []
    for v in range(k, n):
        chosen = rng.choice(repeated, size=min(k, len(repeated)), replace=False)
        for t in set(int(c) for c in chosen):
            edges.append((v, t))
            repeated.append(t)
            repeated.append(v)
    return make_graph(n, np.asarray(edges, dtype=np.int64))


def planted_cliques(n: int, clique_sizes, p_background: float = 0.01,
                    seed: int = 0) -> Graph:
    """Background ER graph + planted cliques => a known nested-density structure."""
    rng = np.random.default_rng(seed)
    g = erdos_renyi(n, p_background, seed=seed)
    edges = [np.asarray(g.edges)]
    start = 0
    for size in clique_sizes:
        members = np.arange(start, min(start + size, n))
        iu = np.triu_indices(len(members), k=1)
        edges.append(np.stack([members[iu[0]], members[iu[1]]], axis=1))
        start += max(1, size // 2)  # overlap consecutive cliques
    return make_graph(n, np.concatenate(edges, axis=0))


def paper_figure1_like() -> Graph:
    """A small graph with the nested (1,3)-nucleus structure of paper Fig. 1.

    Vertices 0-3: a K5-ish dense core (core 4 region needs every vertex in >=4
    triangles); 4-6: triangle-rich ring attached to the core; 7: bridge vertex
    in 2 triangles; 8: vertex in exactly 1 triangle.
    """
    edges = [
        # dense core: K5 on 0..4
        (0, 1), (0, 2), (0, 3), (0, 4), (1, 2), (1, 3), (1, 4), (2, 3), (2, 4), (3, 4),
        # middle shell: triangles sharing edges with the core boundary
        (3, 5), (4, 5), (5, 6), (3, 6), (5, 7), (6, 7),
        # outer: one triangle
        (7, 8), (6, 8),
    ]
    return make_graph(9, np.asarray(edges, dtype=np.int64))


def golden_suite():
    """The golden-fixture graph suite: name -> zero-arg Graph factory.

    The SINGLE definition shared by tools/regen_golden.py (writes
    tests/golden/*.json) and tests/test_golden.py (re-derives and checks) —
    a seed or parameter drifting between writer and checker would otherwise
    surface as a misleading backend-mismatch failure.
    """
    return {
        "triangle": lambda: tiny_named("triangle"),
        "k4": lambda: tiny_named("k4"),
        "path4": lambda: tiny_named("path4"),
        "two_triangles": lambda: tiny_named("two_triangles"),
        "bowtie_plus": lambda: tiny_named("bowtie_plus"),
        "fig1": paper_figure1_like,
        # seeded generators: deterministic, big enough for multi-level trees
        "er20": lambda: erdos_renyi(20, 0.35, seed=1),
        "planted40": lambda: planted_cliques(40, [8, 6, 5], 0.05, seed=3),
    }


GOLDEN_RS = [(1, 2), (2, 3), (3, 4)]


def tiny_named(name: str) -> Graph:
    if name == "triangle":
        return make_graph(3, [(0, 1), (1, 2), (0, 2)])
    if name == "k4":
        return make_graph(4, [(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)])
    if name == "path4":
        return make_graph(4, [(0, 1), (1, 2), (2, 3)])
    if name == "two_triangles":
        # two triangles sharing one vertex
        return make_graph(5, [(0, 1), (1, 2), (0, 2), (2, 3), (3, 4), (2, 4)])
    if name == "bowtie_plus":
        # two K4s joined by an edge
        e = [(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3),
             (4, 5), (4, 6), (4, 7), (5, 6), (5, 7), (6, 7), (3, 4)]
        return make_graph(8, e)
    raise ValueError(name)
