"""AdamW + schedules + gradient utilities (self-contained, no optax).

Includes the distributed-optimization tricks the framework exposes:
  * global-norm gradient clipping,
  * cosine and WSD (warmup-stable-decay, MiniCPM [arXiv:2404.06395]) schedules,
  * PowerSGD-style low-rank gradient compression with error feedback
    (`compress_grads` / `decompress_grads`) for bandwidth-bound meshes,
  * microbatched gradient accumulation via `lax.scan` (see train loop).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"       # "constant" | "cosine" | "wsd"
    warmup_steps: int = 100
    total_steps: int = 10_000
    decay_frac: float = 0.1        # WSD: fraction of steps spent decaying
    min_lr_frac: float = 0.1


def schedule_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        frac = jnp.ones_like(s)
    elif cfg.schedule == "cosine":
        t = jnp.clip((s - cfg.warmup_steps)
                     / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
            1 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "wsd":
        # warmup -> stable plateau -> short linear decay (MiniCPM)
        decay_steps = int(cfg.total_steps * cfg.decay_frac)
        stable_end = cfg.total_steps - decay_steps
        t = jnp.clip((s - stable_end) / max(decay_steps, 1), 0.0, 1.0)
        frac = 1.0 - (1.0 - cfg.min_lr_frac) * t
    else:
        raise ValueError(cfg.schedule)
    return cfg.lr * warm * frac


def init_state(params: Any) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: Any) -> jnp.ndarray:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))),
                      tree)
    return jnp.sqrt(sum(jax.tree.leaves(sq)))


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def apply_updates(params: Any, grads: Any, state: AdamWState,
                  cfg: AdamWConfig) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = schedule_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g32
        nu = b2 * nu + (1 - b2) * jnp.square(g32)
        mhat = mu / bc1
        nhat = nu / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state.mu)
    flat_nu = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, mu=new_mu, nu=new_nu), metrics


# ---------------------------------------------------------------------------
# PowerSGD-style low-rank gradient compression with error feedback
# ---------------------------------------------------------------------------

class CompressionState(NamedTuple):
    error: Any   # error-feedback residuals, same structure as params
    q: Any       # per-matrix right factors (warm-started power iteration)


def init_compression(params: Any, rank: int, key: jax.Array) -> CompressionState:
    flat, tdef = jax.tree.flatten(params)
    keys = jax.random.split(key, len(flat))
    errs, qs = [], []
    for p, k in zip(flat, keys):
        errs.append(jnp.zeros(p.shape, jnp.float32))
        if p.ndim >= 2:
            n = int(np.prod(p.shape[1:]))
            qs.append(jax.random.normal(k, (n, rank), jnp.float32))
        else:
            qs.append(None)
    return CompressionState(error=tdef.unflatten(errs), q=tdef.unflatten(qs))


def compress_grads(grads: Any, cstate: CompressionState, rank: int):
    """One power-iteration low-rank factorization per matrix gradient.

    Returns (payload to all-reduce, new state).  Payload for a matrix of
    shape (m, n) is (P (m, r), Q (n, r)) — r(m+n) instead of mn words on the
    wire; 1-D params ride along uncompressed.  Error feedback accumulates
    what the low-rank projection dropped.
    """
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(cstate.error)
    # q holds None for 1-D params: flatten with None as a leaf
    flat_q = jax.tree.flatten(cstate.q, is_leaf=lambda x: x is None)[0]
    payload, new_e, new_q = [], [], []
    for g, e, q in zip(flat_g, flat_e, flat_q):
        g32 = g.astype(jnp.float32) + e
        if g.ndim >= 2 and q is not None:
            m = g32.reshape(g32.shape[0], -1)
            p = m @ q                                   # (m, r)
            p, _ = jnp.linalg.qr(p)
            q_new = m.T @ p                             # (n, r)
            approx = (p @ q_new.T).reshape(g.shape)
            payload.append((p, q_new))
            new_e.append(g32 - approx)
            new_q.append(q_new)
        else:
            payload.append(g32)
            new_e.append(jnp.zeros_like(g32))
            new_q.append(None)
    return (tdef.unflatten(payload),
            CompressionState(error=tdef.unflatten(new_e),
                             q=tdef.unflatten(new_q)))


def decompress_grads(payload: Any, like: Any) -> Any:
    flat_p, tdef = jax.tree.flatten(payload,
                                    is_leaf=lambda x: isinstance(x, tuple))
    flat_l = jax.tree.leaves(like)
    out = []
    for pay, l in zip(flat_p, flat_l):
        if isinstance(pay, tuple):
            p, q = pay
            out.append((p @ q.T).reshape(l.shape).astype(l.dtype))
        else:
            out.append(pay.astype(l.dtype))
    return tdef.unflatten(out)
