from .adamw import (AdamWConfig, AdamWState, init_state, apply_updates,
                    schedule_lr, global_norm, clip_by_global_norm,
                    CompressionState, init_compression, compress_grads,
                    decompress_grads)
