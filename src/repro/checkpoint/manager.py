"""Fault-tolerant checkpointing: atomic, async, elastic.

  * **Atomic**: state is written to `step_N.tmp/` then `os.rename`d to
    `step_N/` — a crash mid-write can never corrupt the latest checkpoint.
  * **Async**: the device->host transfer happens synchronously (cheap), the
    disk write runs on a background thread so the train loop is not blocked;
    `wait()` joins before the next save or at exit.
  * **Elastic re-mesh restore**: checkpoints store LOGICAL arrays (+ the data
    step for pipeline resume).  `restore(..., sharding_tree=)` re-shards onto
    whatever mesh the new job has — a different device count than the writer
    is fine, which is what elastic scaling after node failure requires.
  * Retention: keeps the newest `keep` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state: Any, extra: Optional[Dict] = None,
             blocking: bool = False) -> None:
        self.wait()
        host_flat, _ = _flatten_with_paths(state)
        host = {k: np.asarray(v) for k, v in host_flat.items()}
        meta = {"step": int(step), "extra": extra or {}}

        def write():
            tmp = os.path.join(self.directory, f"step_{step}.tmp")
            final = os.path.join(self.directory, f"step_{step}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"), **host)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)          # the atomic commit point
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_", 1)[1]))
                except ValueError:
                    pass
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return max(steps) if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                sharding_tree: Any = None):
        """Restore into the structure of `like`; reshard if shardings given.

        Returns (state, step, extra).  `sharding_tree` mirrors `like` with
        jax.sharding.Sharding leaves (or None to keep host arrays) — this is
        the elastic re-mesh path: the stored arrays are logical, so any mesh
        shape works.
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step}")
        arrays = np.load(os.path.join(path, "arrays.npz"))
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        flat_like, treedef = _flatten_with_paths(like)
        missing = set(flat_like) - set(arrays.files)
        if missing:
            raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]}")
        leaves = []
        flat_sh = (_flatten_with_paths(sharding_tree)[0]
                   if sharding_tree is not None else {})
        for key in flat_like:
            arr = arrays[key]
            want = flat_like[key]
            if hasattr(want, "dtype") and arr.dtype != want.dtype:
                arr = arr.astype(want.dtype)
            sh = flat_sh.get(key)
            leaves.append(jax.device_put(arr, sh) if sh is not None else arr)
        # rebuild in treedef leaf order
        paths_in_order = [
            "/".join(str(p) for p in path)
            for path, _ in jax.tree_util.tree_flatten_with_path(like)[0]]
        by_key = dict(zip(flat_like.keys(), leaves))
        state = jax.tree_util.tree_unflatten(
            treedef, [by_key[k] for k in paths_in_order])
        return state, meta["step"], meta["extra"]
