from .manager import CheckpointManager
