"""minicpm-2b [dense]: 40L d_model=2304 36H (GQA kv=36 = MHA) d_ff=5760
vocab=122753 — WSD schedule (llama-like) [arXiv:2404.06395; hf].

vocab padded 122753 -> 122880 (multiple of 256) for clean vocab sharding;
the pad rows are never emitted by the data pipeline.  MiniCPM's WSD
learning-rate schedule is implemented in repro.optim (schedule="wsd") and is
the default for this arch's training example.
"""
import jax.numpy as jnp

from ..models.transformer import TransformerConfig
from .base import ArchSpec, register, pad_vocab
from .lm_common import lm_shapes, lm_input_specs


def make_config() -> TransformerConfig:
    return TransformerConfig(
        name="minicpm-2b", n_layers=40, d_model=2304, n_heads=36,
        n_kv_heads=36, d_ff=5760, vocab=pad_vocab(122753),  # -> 122880
        dtype=jnp.bfloat16, attn_chunk=1024)


def make_smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="minicpm-2b-smoke", n_layers=2, d_model=72, n_heads=6,
        n_kv_heads=6, d_ff=180, vocab=512, dtype=jnp.float32, attn_chunk=32,
        remat=False)


SPEC = register(ArchSpec(
    arch_id="minicpm-2b", family="lm",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=lm_shapes(), input_specs=lm_input_specs,
    notes="dense MHA decoder (kv=36); WSD schedule; head_dim=64"))
