"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H d_ff=1408 vocab=102400,
MoE 64e top-6, MLA kv_lora=512, 2 shared experts [arXiv:2405.04434].

The assignment fixes 64 routed experts (the HF config's 160-expert variant is
noted but the bracketed assignment spec wins).  MLA caches the 512-dim latent
+ 64 shared-rope dims per token instead of full K/V — the cache is ~5.7x
smaller than GQA kv=16 would be at the same shape.
"""
import jax.numpy as jnp

from ..models.transformer import TransformerConfig, MoEConfig, MLAConfig
from .base import ArchSpec, register
from .lm_common import lm_shapes, lm_input_specs


def make_config() -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-v2-lite-16b", n_layers=27, d_model=2048, n_heads=16,
        n_kv_heads=16, d_ff=1408, vocab=102400,
        moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2),
        mla=MLAConfig(kv_lora_rank=512, rope_head_dim=64),
        dtype=jnp.bfloat16, attn_chunk=1024)


def make_smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=96, vocab=512,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=96, n_shared=1),
        mla=MLAConfig(kv_lora_rank=16, rope_head_dim=8),
        dtype=jnp.float32, attn_chunk=32, remat=False)


SPEC = register(ArchSpec(
    arch_id="deepseek-v2-lite-16b", family="lm",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=lm_shapes(), input_specs=lm_input_specs,
    notes="MLA (kv_lora=512, rope_dim=64) + MoE 64e top-6 + 2 shared"))
