"""egnn [gnn]: n_layers=4 d_hidden=64 equivariance=E(n)
[arXiv:2102.09844; paper].  Scalar-distance messages + coord updates."""
from ..models.egnn import EGNNConfig
from .base import ArchSpec, register
from .gnn_shapes import GNN_SHAPES, gnn_input_specs


def make_config() -> EGNNConfig:
    return EGNNConfig(name="egnn", n_layers=4, d_hidden=64)


def make_smoke_config() -> EGNNConfig:
    return EGNNConfig(name="egnn-smoke", n_layers=2, d_hidden=16, d_in=8)


SPEC = register(ArchSpec(
    arch_id="egnn", family="gnn",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=GNN_SHAPES, input_specs=gnn_input_specs("egnn"),
    notes="E(n)-equivariant; positions synthetic on citation/product graphs"))
