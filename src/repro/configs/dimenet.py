"""dimenet [gnn]: n_blocks=6 d_hidden=128 n_bilinear=8 n_spherical=7
n_radial=6 [arXiv:2003.03123].  Triplet-gather regime; triplet lists are
capacity-capped per edge on the large graphs (cap in gnn_shapes dims)."""
from ..models.dimenet import DimeNetConfig
from .base import ArchSpec, register
from .gnn_shapes import GNN_SHAPES, gnn_input_specs


def make_config() -> DimeNetConfig:
    return DimeNetConfig(name="dimenet", n_blocks=6, d_hidden=128,
                         n_bilinear=8, n_spherical=7, n_radial=6)


def make_smoke_config() -> DimeNetConfig:
    return DimeNetConfig(name="dimenet-smoke", n_blocks=2, d_hidden=16,
                         n_bilinear=2, n_spherical=3, n_radial=3, d_in=8)


SPEC = register(ArchSpec(
    arch_id="dimenet", family="gnn",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=GNN_SHAPES, input_specs=gnn_input_specs("dimenet"),
    notes="directional message passing; Legendre x sine angular basis (TPU "
          "adaptation of the spherical Bessel basis, DESIGN.md §3)"))
