"""Shared shape grid + input specs for the GNN-family architectures.

Shapes carry their own feature/label dims (taken from the public datasets the
shapes correspond to: cora / reddit / ogbn-products / QM9-like molecules).
Equivariant archs get synthetic 3-D positions on every shape (DESIGN.md §5);
DimeNet additionally gets capacity-capped triplet lists (cap recorded here).
"""
from __future__ import annotations

from typing import Any, Dict

import jax.numpy as jnp

from .base import ShapeCell, sds

# static sampler capacities for minibatch_lg (fanout 15-10 over 1024 seeds)
_MB_NODES = 1024 * (1 + 15 + 150)      # 169,984
_MB_EDGES = 1024 * (15 + 150)          # 168,960

GNN_SHAPES = (
    ShapeCell("full_graph_sm", "train",
              {"n_nodes": 2_708, "n_edges": 10_556, "d_feat": 1_433,
               "n_classes": 7, "triplet_cap": 8}),
    ShapeCell("minibatch_lg", "train",
              {"n_nodes": _MB_NODES, "n_edges": _MB_EDGES, "d_feat": 602,
               "n_classes": 41, "triplet_cap": 8,
               "base_nodes": 232_965, "base_edges": 114_615_892,
               "batch_nodes": 1_024, "fanout": (15, 10)}),
    ShapeCell("ogb_products", "train",
              {"n_nodes": 2_449_029, "n_edges": 61_859_140, "d_feat": 100,
               "n_classes": 47, "triplet_cap": 4}),
    ShapeCell("molecule", "train",
              {"n_nodes": 30, "n_edges": 64, "batch": 128, "d_feat": 16,
               "triplet_cap": 8}),
)


def needs_pos(arch_id: str) -> bool:
    return arch_id in ("egnn", "mace", "dimenet")


def needs_triplets(arch_id: str) -> bool:
    return arch_id == "dimenet"


def gnn_input_specs(arch_id: str):
    def specs(cfg: Any, cell: ShapeCell) -> Dict[str, Any]:
        d = cell.dims
        if cell.name == "molecule":
            N = d["n_nodes"] * d["batch"]
            E = d["n_edges"] * d["batch"]
            n_graphs = d["batch"]
            node_level = False
        else:
            N, E = d["n_nodes"], d["n_edges"]
            n_graphs = N          # node-level: identity "pooling"
            node_level = True
        batch = {
            "nodes": sds((N, d["d_feat"])),
            "edge_src": sds((E,), jnp.int32),
            "edge_dst": sds((E,), jnp.int32),
            "node_mask": sds((N,), jnp.bool_),
            "edge_mask": sds((E,), jnp.bool_),
            "graph_id": sds((N,), jnp.int32),
        }
        if needs_pos(arch_id):
            batch["pos"] = sds((N, 3))
        if needs_triplets(arch_id):
            T = E * d["triplet_cap"]
            batch["triplet_kj"] = sds((T,), jnp.int32)
            batch["triplet_ji"] = sds((T,), jnp.int32)
            batch["triplet_mask"] = sds((T,), jnp.bool_)
        if node_level:
            batch["labels"] = sds((N,), jnp.int32)
            batch["label_mask"] = sds((N,))
        else:
            batch["energy"] = sds((n_graphs, 1))
        return {"batch": batch, "n_graphs": n_graphs,
                "node_level": node_level}
    return specs
