"""The paper's own workload as a distributed config: (r, s) nucleus
decomposition at SNAP-graph scale, lowered via shard_map for the dry-run.

Cells correspond to the paper's largest inputs (Table 1): livejournal and
orkut at (2,3) (k-truss-style) and (1,2) (k-core).  Dims record the r-clique
and s-clique counts the incidence structure must hold; the s-clique axis is
sharded across the full mesh, r-clique state is replicated (one all-reduce
per peel round — see repro.core.distributed).
"""
import jax.numpy as jnp

from ..core.schedule import PeelSchedule
from .base import ArchSpec, ShapeCell, register, sds

SHAPES = (
    # n_r = #r-cliques, n_s = #s-cliques, C = C(s, r)
    ShapeCell("livejournal_23", "decomp",
              {"n_r": 34_681_189, "n_s": 177_820_130, "C": 3,
               "r": 2, "s": 3, "n": 3_997_962}),
    ShapeCell("orkut_23", "decomp",
              {"n_r": 117_185_083, "n_s": 627_584_181, "C": 3,
               "r": 2, "s": 3, "n": 3_072_441}),
    ShapeCell("orkut_12", "decomp",
              {"n_r": 3_072_441, "n_s": 117_185_083, "C": 2,
               "r": 1, "s": 2, "n": 3_072_441}),
    ShapeCell("livejournal_34", "decomp",
              {"n_r": 177_820_130, "n_s": 509_334_804, "C": 4,
               "r": 3, "s": 4, "n": 3_997_962}),
)


def make_config():
    return {"kind": "nucleus", "schedule": "approx", "delta": 0.1,
            "compress": False}


def make_smoke_config():
    return {"kind": "nucleus", "schedule": "exact", "delta": 0.1,
            "compress": False}


def make_peel_schedule(cfg, cell: ShapeCell) -> PeelSchedule:
    """The unified engine schedule for a shape cell — the ONLY place the
    production lowering decides exact vs approx bucket semantics."""
    d = cell.dims
    return PeelSchedule(kind=cfg.get("schedule", "approx"),
                        s_choose_r=d["C"], delta=cfg.get("delta", 0.1),
                        n=d["n"])


def max_rounds_bound(cfg, cell: ShapeCell) -> int:
    """Static while_loop trip cap for lowering: the approx schedule peels in
    O(log^2 n) rounds; exact is capped by n_r (every round peels >= 1)."""
    import numpy as np
    d = cell.dims
    if cfg.get("schedule", "approx") == "approx":
        return 64 * int(np.ceil(np.log(max(d["n"], 2)) ** 2))
    return d["n_r"] + 2


def input_specs(cfg, cell: ShapeCell):
    d = cell.dims
    return {"inc_rid": sds((d["n_s"], d["C"]), jnp.int32),
            "deg0": sds((d["n_r"],), jnp.int32)}


SPEC = register(ArchSpec(
    arch_id="nucleus", family="core",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=SHAPES, input_specs=input_specs,
    notes="the paper's technique itself, sharded: the unified peel engine "
          "(repro.core.engine) under shard_map, one int32 (n_r,) all-reduce "
          "per peel round; approx schedule bounds rounds at O(log^2 n)"))
