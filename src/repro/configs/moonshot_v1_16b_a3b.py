"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=163840, MoE 64 experts top-6 — kimi/moonlight
[hf:moonshotai/Moonlight-16B-A3B; hf].

d_ff=1408 is the PER-EXPERT hidden dim (the "a3b" active-3B pattern); 2
shared experts carry the always-on path, matching the Moonlight block.
"""
import jax.numpy as jnp

from ..models.transformer import TransformerConfig, MoEConfig
from .base import ArchSpec, register
from .lm_common import lm_shapes, lm_input_specs


def make_config() -> TransformerConfig:
    return TransformerConfig(
        name="moonshot-v1-16b-a3b", n_layers=48, d_model=2048, n_heads=16,
        n_kv_heads=16, d_ff=1408, vocab=163840,
        moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2),
        dtype=jnp.bfloat16, attn_chunk=1024)


def make_smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="moonshot-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=96, vocab=512,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=96, n_shared=1),
        dtype=jnp.float32, attn_chunk=32, remat=False)


SPEC = register(ArchSpec(
    arch_id="moonshot-v1-16b-a3b", family="lm",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=lm_shapes(), input_specs=lm_input_specs,
    notes="MoE 64e top-6 + 2 shared, expert parallel over 'model'"))
