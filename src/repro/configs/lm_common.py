"""Shared shape grid + input specs for the LM-family architectures."""
from __future__ import annotations

from typing import Any, Dict

import jax.numpy as jnp

from ..models import transformer as T
from .base import ShapeCell, sds

FULL_ATTN_SKIP = ("pure full-attention architecture (GQA/MLA softmax "
                  "attention): long_500k requires sub-quadratic attention; "
                  "skipped per the shape-grid rules, see DESIGN.md §5")


def lm_shapes() -> tuple:
    return (
        ShapeCell("train_4k", "train",
                  {"seq_len": 4096, "global_batch": 256}),
        ShapeCell("prefill_32k", "prefill",
                  {"seq_len": 32768, "global_batch": 32}),
        ShapeCell("decode_32k", "decode",
                  {"seq_len": 32768, "global_batch": 128}),
        ShapeCell("long_500k", "decode",
                  {"seq_len": 524288, "global_batch": 1},
                  skip_reason=FULL_ATTN_SKIP),
    )


def lm_input_specs(cfg: T.TransformerConfig, cell: ShapeCell
                   ) -> Dict[str, Any]:
    B = cell.dims["global_batch"]
    S = cell.dims["seq_len"]
    if cell.kind == "train":
        return {"batch": {"tokens": sds((B, S), jnp.int32),
                          "labels": sds((B, S), jnp.int32)}}
    if cell.kind == "prefill":
        return {"batch": {"tokens": sds((B, S), jnp.int32)}}
    if cell.kind == "decode":
        L, Hkv, D = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
        if cfg.mla is not None:
            r, pr = cfg.mla.kv_lora_rank, cfg.mla.rope_head_dim
            cache = (sds((L, B, S, r), cfg.dtype),
                     sds((L, B, S, 1, pr), cfg.dtype))
        else:
            cache = (sds((L, B, S, Hkv, D), cfg.dtype),
                     sds((L, B, S, Hkv, D), cfg.dtype))
        return {"tokens": sds((B, 1), jnp.int32),
                "cache": cache,
                "cache_len": sds((), jnp.int32)}
    raise ValueError(cell.kind)
