"""mace [gnn]: n_layers=2 d_hidden=128 l_max=2 correlation_order=3 n_rbf=8
equivariance=E(3)-ACE [arXiv:2206.07697].  Cartesian-irrep tensor products
(exactly equivariant; see DESIGN.md §3 and the rotation property tests)."""
from ..models.mace import MACEConfig
from .base import ArchSpec, register
from .gnn_shapes import GNN_SHAPES, gnn_input_specs


def make_config() -> MACEConfig:
    return MACEConfig(name="mace", n_layers=2, d_hidden=128, l_max=2,
                      correlation_order=3, n_rbf=8)


def make_smoke_config() -> MACEConfig:
    return MACEConfig(name="mace-smoke", n_layers=1, d_hidden=8, l_max=2,
                      correlation_order=3, n_rbf=4, d_in=8)


SPEC = register(ArchSpec(
    arch_id="mace", family="gnn",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=GNN_SHAPES, input_specs=gnn_input_specs("mace"),
    notes="higher-order equivariant message passing, correlation order 3"))
