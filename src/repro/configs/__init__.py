"""Architecture configs: one module per assigned arch (+ the paper's own).

`get_arch(id)` returns the registered ArchSpec; importing this package
registers all architectures.
"""
from .base import ArchSpec, ShapeCell, get_arch, all_archs, sds
from . import (stablelm_12b, minicpm_2b, minitron_4b, moonshot_v1_16b_a3b,
               deepseek_v2_lite_16b, gin_tu, egnn, dimenet, mace, din,
               nucleus)

ALL_ARCH_IDS = tuple(sorted(all_archs()))
