"""din [recsys]: embed_dim=18 seq_len=100 attn_mlp=80-40 mlp=200-80
interaction=target-attn [arXiv:1706.06978; paper].

Embedding tables: 10^6 items / 10^4 categories / 10^5 user features,
vocab-sharded over the "model" mesh axis (EmbeddingBag substrate in
repro.models.din)."""
import jax.numpy as jnp

from ..models.din import DINConfig
from .base import ArchSpec, register, ShapeCell, sds

SHAPES = (
    ShapeCell("train_batch", "train", {"batch": 65_536}),
    ShapeCell("serve_p99", "serve", {"batch": 512}),
    ShapeCell("serve_bulk", "serve", {"batch": 262_144}),
    ShapeCell("retrieval_cand", "retrieval",
              {"batch": 1, "n_candidates": 1_000_000}),
)


def make_config() -> DINConfig:
    return DINConfig(name="din", embed_dim=18, seq_len=100,
                     attn_mlp=(80, 40), mlp=(200, 80),
                     n_items=1_000_000, n_cates=10_000, n_user_feats=100_000)


def make_smoke_config() -> DINConfig:
    return DINConfig(name="din-smoke", embed_dim=8, seq_len=12,
                     attn_mlp=(16, 8), mlp=(24, 12),
                     n_items=1_000, n_cates=50, n_user_feats=100)


def input_specs(cfg: DINConfig, cell: ShapeCell):
    B = cell.dims["batch"]
    S = cfg.seq_len
    if cell.kind == "retrieval":
        # pad the candidate set to a 512-multiple so it shards evenly over
        # both production meshes (1,000,000 -> 1,000,448; pad rows scored
        # and dropped by the caller)
        NC = -(-cell.dims["n_candidates"] // 512) * 512
        return {"batch": {
            "hist_items": sds((S,), jnp.int32),
            "hist_cates": sds((S,), jnp.int32),
            "user_id": sds((), jnp.int32),
            "cand_items": sds((NC,), jnp.int32),
            "cand_cates": sds((NC,), jnp.int32),
        }}
    batch = {
        "hist_items": sds((B, S), jnp.int32),
        "hist_cates": sds((B, S), jnp.int32),
        "cand_item": sds((B,), jnp.int32),
        "cand_cate": sds((B,), jnp.int32),
        "user_id": sds((B,), jnp.int32),
    }
    if cell.kind == "train":
        batch["label"] = sds((B,), jnp.float32)
    return {"batch": batch}


SPEC = register(ArchSpec(
    arch_id="din", family="recsys",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=SHAPES, input_specs=input_specs,
    notes="target-attention CTR; EmbeddingBag = take + segment_sum"))
