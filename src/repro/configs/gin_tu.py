"""gin-tu [gnn]: n_layers=5 d_hidden=64 aggregator=sum eps=learnable
[arXiv:1810.00826; paper].  SpMM regime: gather -> segment_sum."""
from ..models.gin import GINConfig
from .base import ArchSpec, register, ShapeCell
from .gnn_shapes import GNN_SHAPES, gnn_input_specs


def make_config() -> GINConfig:
    # d_in / n_classes are shape-dependent; the launcher overrides them from
    # the ShapeCell dims (see launch.dryrun._gnn_cfg_for_cell).
    return GINConfig(name="gin-tu", n_layers=5, d_hidden=64)


def make_smoke_config() -> GINConfig:
    return GINConfig(name="gin-tu-smoke", n_layers=2, d_hidden=16, d_in=8,
                     n_classes=3)


SPEC = register(ArchSpec(
    arch_id="gin-tu", family="gnn",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=GNN_SHAPES, input_specs=gnn_input_specs("gin-tu"),
    notes="sum-aggregation isomorphism network; learnable eps"))
