"""Arch registry plumbing: every config module registers an ArchSpec.

An ArchSpec knows how to build (a) the FULL published config (dry-run /
roofline only — never allocated on CPU), (b) a REDUCED smoke config (runs a
real train/serve step on CPU), and (c) `input_specs(shape)` — the
ShapeDtypeStruct stand-ins for each of the arch's assigned input shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

_REGISTRY: Dict[str, "ArchSpec"] = {}


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (arch x input-shape) dry-run cell."""

    name: str
    kind: str                     # train | prefill | decode | serve | retrieval
    dims: Dict[str, int]
    skip_reason: Optional[str] = None   # e.g. long_500k on full attention


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                   # "lm" | "gnn" | "recsys"
    make_config: Callable[[], Any]
    make_smoke_config: Callable[[], Any]
    shapes: Tuple[ShapeCell, ...]
    input_specs: Callable[[Any, ShapeCell], Dict[str, Any]]
    notes: str = ""

    def shape(self, name: str) -> ShapeCell:
        for c in self.shapes:
            if c.name == name:
                return c
        raise KeyError(f"{self.arch_id}: unknown shape {name}")


def register(spec: ArchSpec) -> ArchSpec:
    _REGISTRY[spec.arch_id] = spec
    return spec


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def all_archs():
    return dict(_REGISTRY)


def sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def pad_vocab(v: int, multiple: int = 256) -> int:
    """Pad vocab to a shardable multiple (noted per config)."""
    return -(-v // multiple) * multiple
