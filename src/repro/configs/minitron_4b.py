"""minitron-4b [dense]: 32L d_model=3072 24H (GQA kv=8) d_ff=9216
vocab=256000 — pruned nemotron [arXiv:2407.14679; hf]."""
import jax.numpy as jnp

from ..models.transformer import TransformerConfig
from .base import ArchSpec, register
from .lm_common import lm_shapes, lm_input_specs


def make_config() -> TransformerConfig:
    return TransformerConfig(
        name="minitron-4b", n_layers=32, d_model=3072, n_heads=24,
        n_kv_heads=8, d_ff=9216, vocab=256000,  # 256000 % 256 == 0
        dtype=jnp.bfloat16, attn_chunk=1024)


def make_smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="minitron-4b-smoke", n_layers=2, d_model=48, n_heads=4,
        n_kv_heads=2, d_ff=144, vocab=512, dtype=jnp.float32, attn_chunk=32,
        remat=False)


SPEC = register(ArchSpec(
    arch_id="minitron-4b", family="lm",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=lm_shapes(), input_specs=lm_input_specs,
    notes="width/depth-pruned nemotron; GQA kv=8; head_dim=128"))
