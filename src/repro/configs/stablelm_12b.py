"""stablelm-12b [dense]: 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352  [hf:stabilityai/stablelm-2-12b; hf]."""
import jax.numpy as jnp

from ..models.transformer import TransformerConfig
from .base import ArchSpec, register, pad_vocab
from .lm_common import lm_shapes, lm_input_specs


def make_config() -> TransformerConfig:
    return TransformerConfig(
        name="stablelm-12b", n_layers=40, d_model=5120, n_heads=32,
        n_kv_heads=8, d_ff=13824, vocab=pad_vocab(100352),  # 100352 % 256 == 0
        dtype=jnp.bfloat16, attn_chunk=1024)


def make_smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="stablelm-12b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=160, vocab=512, dtype=jnp.float32, attn_chunk=32,
        remat=False)


SPEC = register(ArchSpec(
    arch_id="stablelm-12b", family="lm",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=lm_shapes(), input_specs=lm_input_specs,
    notes="dense GQA decoder; head_dim=160"))
