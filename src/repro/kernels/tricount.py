"""Pallas TPU kernel: tiled boolean-matmul triangle counting.

The paper's hottest loop is s-clique extension by neighborhood intersection
(per-thread hash probes on CPU).  The MXU-native reformulation of the (2,3)
case: with a dense 0/1 adjacency block decomposition,

    per-edge triangle counts  T = (A @ A) ⊙ A

Each grid cell (i, j) accumulates A[i,:] @ A[:,j] over the k-blocks in a VMEM
f32 scratch accumulator and masks by the A[i,j] tile on the last k step — one
HBM pass over A per output tile row/col, no (n, n) f32 intermediate.
Tiles default to (128, 128): the MXU systolic shape.

This kernel is the TPU analogue of the paper's intersection loop, and is what
`repro.graph.cliques` would call on-device for r=2, s=3; ops.py exposes the
jitted wrapper and ref.py the pure-jnp oracle used by the allclose tests.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE = 128


def _tricount_kernel(a_ik_ref, a_kj_ref, a_ij_ref, out_ref, acc_ref, *,
                     n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ik_ref[...], a_kj_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finish():
        out_ref[...] = acc_ref[...] * a_ij_ref[...]


def tricount_per_edge(adj: jnp.ndarray, tile: int = TILE,
                      interpret: bool | None = None) -> jnp.ndarray:
    """Per-pair triangle counts (A @ A) ⊙ A.

    adj: (n, n) float32 in {0,1}, symmetric, zero diagonal, n % tile == 0.
    Returns (n, n) float32 counts (count[u,v] = #common neighbors if edge).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = adj.shape[0]
    assert adj.shape == (n, n) and n % tile == 0, adj.shape
    n_b = n // tile
    return pl.pallas_call(
        partial(_tricount_kernel, n_k=n_b),
        grid=(n_b, n_b, n_b),
        in_specs=[
            pl.BlockSpec((tile, tile), lambda i, j, k: (i, k)),
            pl.BlockSpec((tile, tile), lambda i, j, k: (k, j)),
            pl.BlockSpec((tile, tile), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((tile, tile), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((tile, tile), jnp.float32)],
        interpret=interpret,
    )(adj, adj, adj)


def triangle_count(adj: jnp.ndarray, tile: int = TILE,
                   interpret: bool | None = None) -> jnp.ndarray:
    """Total triangles = sum((A@A) ⊙ A) / 6."""
    return jnp.sum(tricount_per_edge(adj, tile, interpret)) / 6.0
