"""Pallas TPU kernel: tiled boolean-matmul triangle counting.

The paper's hottest loop is s-clique extension by neighborhood intersection
(per-thread hash probes on CPU).  The MXU-native reformulation of the (2,3)
case: with a dense 0/1 adjacency block decomposition,

    per-edge triangle counts  T = (A @ A) ⊙ A

Each grid cell (i, j) accumulates A[i,:] @ A[:,j] over the k-blocks in a VMEM
f32 scratch accumulator and masks by the A[i,j] tile on the last k step — one
HBM pass over A per output tile row/col, no (n, n) f32 intermediate.
Tiles default to (128, 128): the MXU systolic shape.

Two entry points share the kernel body:

  * ``tricount_per_edge(A)``   — (A @ A) ⊙ A on a symmetric adjacency (the
    undirected per-edge triangle counts).
  * ``tricount_oriented(D)``   — (D @ Dᵀ) ⊙ D on a DAG adjacency: for each
    oriented edge u→v the count is |N⁺(u) ∩ N⁺(v)|, i.e. the number of
    3-clique extensions of that edge under the low-out-degree orientation.
    This is the count pass of the chunked (2,3) incidence builder
    (DESIGN.md §7): allocation sizes come off the MXU without ever
    materializing a candidate array.

Arbitrary n is handled by zero-padding to the tile boundary inside the
wrapper; pad rows/cols contribute nothing because the output is masked by
the (zero-padded) adjacency tile.  ops.py exposes the jitted wrappers and
ref.py the pure-jnp oracles used by the allclose tests.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE = 128


def _tricount_kernel(a_ik_ref, a_kj_ref, a_ij_ref, out_ref, acc_ref, *,
                     n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ik_ref[...], a_kj_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finish():
        out_ref[...] = acc_ref[...] * a_ij_ref[...]


def _pad_square(x: jnp.ndarray, tile: int) -> jnp.ndarray:
    """Zero-pad an (n, n) matrix to the next tile multiple on both axes."""
    n = x.shape[0]
    pad = (-n) % tile
    if pad == 0:
        return x
    return jnp.pad(x, ((0, pad), (0, pad)))


def _masked_matmul(x: jnp.ndarray, y: jnp.ndarray, mask: jnp.ndarray,
                   tile: int, interpret: bool | None) -> jnp.ndarray:
    """(x @ y) ⊙ mask, tiled; all operands (n, n) f32, n already padded."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = x.shape[0]
    n_b = n // tile
    return pl.pallas_call(
        partial(_tricount_kernel, n_k=n_b),
        grid=(n_b, n_b, n_b),
        in_specs=[
            pl.BlockSpec((tile, tile), lambda i, j, k: (i, k)),
            pl.BlockSpec((tile, tile), lambda i, j, k: (k, j)),
            pl.BlockSpec((tile, tile), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((tile, tile), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((tile, tile), jnp.float32)],
        interpret=interpret,
    )(x, y, mask)


def tricount_per_edge(adj: jnp.ndarray, tile: int = TILE,
                      interpret: bool | None = None) -> jnp.ndarray:
    """Per-pair triangle counts (A @ A) ⊙ A.

    adj: (n, n) float32 in {0,1}, symmetric, zero diagonal; any n (the
    wrapper zero-pads to the tile boundary — pad rows are masked out by the
    zero adjacency tile).  Returns (n, n) float32 counts
    (count[u,v] = #common neighbors if edge).
    """
    n = adj.shape[0]
    assert adj.shape == (n, n), adj.shape
    a = _pad_square(adj, tile)
    return _masked_matmul(a, a, a, tile, interpret)[:n, :n]


def tricount_oriented(adj: jnp.ndarray, tile: int = TILE,
                      interpret: bool | None = None) -> jnp.ndarray:
    """Per-DAG-edge extension counts (D @ Dᵀ) ⊙ D.

    adj: (n, n) float32 in {0,1}, the *oriented* adjacency (adj[u, v] = 1 iff
    u→v).  Returns (n, n) float32 with out[u, v] = |N⁺(u) ∩ N⁺(v)| when u→v
    (0 elsewhere) — exactly the number of triangles the chunked (2,3)
    builder will list for that edge, each triangle counted once.
    """
    n = adj.shape[0]
    assert adj.shape == (n, n), adj.shape
    a = _pad_square(adj, tile)
    return _masked_matmul(a, a.T, a, tile, interpret)[:n, :n]


def triangle_count(adj: jnp.ndarray, tile: int = TILE,
                   interpret: bool | None = None) -> jnp.ndarray:
    """Total triangles = sum((A@A) ⊙ A) / 6."""
    return jnp.sum(tricount_per_edge(adj, tile, interpret)) / 6.0
