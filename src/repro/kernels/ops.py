"""Jitted public wrappers around the Pallas kernels (padding + dispatch).

Callers use these; they handle shape padding to kernel tile multiples and
fall back to the jnp reference implementation for shapes where a kernel
launch cannot win (tiny inputs).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention
from .segment_sum import segment_sum_sorted
from .tricount import (tricount_per_edge, tricount_oriented as
                       _tricount_oriented, triangle_count)


def _pad_to(x: jnp.ndarray, axis: int, multiple: int, value=0):
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), pad


@partial(jax.jit, static_argnames=("tile", "interpret"))
def tricount(adj: jnp.ndarray, tile: int = 128,
             interpret: bool | None = None) -> jnp.ndarray:
    """Per-edge triangle counts (the kernel pads to the tile size itself)."""
    return tricount_per_edge(adj.astype(jnp.float32), tile=tile,
                             interpret=interpret)


@partial(jax.jit, static_argnames=("tile", "interpret"))
def tricount_oriented(adj: jnp.ndarray, tile: int = 128,
                      interpret: bool | None = None) -> jnp.ndarray:
    """Per-DAG-edge 3-clique extension counts (D @ Dᵀ) ⊙ D, any n."""
    return _tricount_oriented(adj.astype(jnp.float32), tile=tile,
                              interpret=interpret)


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                   "interpret"))
def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              causal: bool = True, block_q: int = 128, block_k: int = 128,
              interpret: bool | None = None) -> jnp.ndarray:
    """Flash attention with seq padding (pad keys get -inf via causal/len)."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    qp, pq = _pad_to(q, 2, block_q)
    kp, pk = _pad_to(k, 2, block_k)
    vp, _ = _pad_to(v, 2, block_k)
    # pk is the static pad amount (shape arithmetic), not a tracer — the
    # taint analysis can't see through _pad_to's return value
    if pk:  # nucleuslint: disable=NL102
        # disable padded keys by pushing them outside the causal horizon; for
        # non-causal, mask via a huge negative on k rows is handled by zero
        # value rows + renormalization being exact only when causal. Callers
        # with non-causal ragged keys should pre-mask.
        assert causal, "non-causal padded attention: pre-pad keys yourself"
    out = flash_attention(qp, kp, vp, causal=causal, block_q=block_q,
                          block_k=block_k, interpret=interpret)
    return out[:, :, :Sq]


def segment_sum(data: jnp.ndarray, ids: jnp.ndarray, n_segments: int,
                block_n: int = 128, chunk_e: int = 512,
                max_chunks: int | None = None,
                interpret: bool | None = None) -> jnp.ndarray:
    """Sorted-segment sum; pads rows (id = n_segments) and segments."""
    E, d = data.shape
    dp, _ = _pad_to(data, 0, chunk_e)
    idp, _ = _pad_to(ids, 0, chunk_e, value=n_segments)
    n_pad = -(-n_segments // block_n) * block_n + block_n  # room for pad ids
    out = segment_sum_sorted(dp, idp.astype(jnp.int32), n_pad,
                             block_n=block_n, chunk_e=chunk_e,
                             max_chunks=max_chunks, interpret=interpret)
    return out[:n_segments]
