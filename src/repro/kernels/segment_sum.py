"""Pallas TPU kernel: sorted-segment sum (the GNN / EmbeddingBag hot loop).

`jax.ops.segment_sum` lowers to scatter-add, which serializes on TPU.  For
SORTED segment ids (what a CSR edge array gives for free) the reduction is a
band-structured one-hot matmul:

    out[n0:n1] = Σ_chunks onehot(ids_chunk, [n0, n1)) @ data_chunk

Grid = (out_blocks, chunks_per_block).  A scalar-prefetch array `chunk0[i]`
(first input chunk touching output block i, via searchsorted on the host/XLA
side) makes the input BlockSpec index_map *data-dependent*: each output block
only visits chunks that can intersect it — O(E/C + N/B) grid steps total
instead of O(E/C * N/B).  The one-hot contraction runs on the MXU.

max_chunks bounds the chunks any single output block can span; chunks beyond
a block's live range are skipped with @pl.when (no memory traffic: the
index_map clamps to the last live chunk).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_N = 128
DEFAULT_CHUNK_E = 512


def sorted_ids_plan(ids: np.ndarray, n_segments: int,
                    block_n: int = DEFAULT_BLOCK_N,
                    chunk_e: int = DEFAULT_CHUNK_E):
    """Pad a concrete sorted id array so `segment_sum_sorted` jits.

    Returns ``(ids_padded, n_seg_pad, max_chunks)``: ids padded to a chunk_e
    multiple (pad id = n_seg_pad, outside every output block), the segment
    count padded to a block_n multiple, and the static per-block chunk-span
    bound the kernel needs under jit.  Everything here is eager numpy — call
    it once at plan-build time, then feed the jitted hot loop.
    """
    ids = np.asarray(ids, np.int32)
    n_seg_pad = -(-max(n_segments, 1) // block_n) * block_n
    E = ids.shape[0]
    E_pad = -(-max(E, 1) // chunk_e) * chunk_e
    ids_padded = np.full(E_pad, n_seg_pad, np.int32)
    ids_padded[:E] = ids
    # same intersection logic as segment_sum_sorted, concretely
    bounds_lo = np.arange(n_seg_pad // block_n, dtype=np.int64) * block_n
    chunk_first = ids_padded[::chunk_e]
    chunk_last = ids_padded[chunk_e - 1::chunk_e]
    c0 = np.searchsorted(chunk_last, bounds_lo, side="left")
    c1 = np.searchsorted(chunk_first, bounds_lo + block_n, side="left")
    max_chunks = max(int(np.max(np.maximum(c1 - c0, 0), initial=0)), 1)
    return ids_padded, n_seg_pad, max_chunks


def _segsum_kernel(chunk0_ref, nchunks_ref, ids_ref, data_ref, out_ref,
                   acc_ref, *, block_n: int, chunk_e: int, max_chunks: int):
    i = pl.program_id(0)   # output block
    j = pl.program_id(1)   # chunk-within-block

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(j < nchunks_ref[i])
    def _body():
        ids = ids_ref[...]                       # (1, chunk_e) int32
        data = data_ref[...]                     # (chunk_e, d)
        n0 = i * block_n
        rows = n0 + jax.lax.broadcasted_iota(jnp.int32,
                                             (block_n, chunk_e), 0)
        onehot = (ids == rows).astype(jnp.float32)   # (block_n, chunk_e)
        acc_ref[...] += jax.lax.dot(onehot, data.astype(jnp.float32),
                                    preferred_element_type=jnp.float32)

    @pl.when(j == max_chunks - 1)
    def _finish():
        out_ref[0] = acc_ref[...].astype(out_ref.dtype)


def segment_sum_sorted(data: jnp.ndarray, ids: jnp.ndarray, n_segments: int,
                       block_n: int = DEFAULT_BLOCK_N,
                       chunk_e: int = DEFAULT_CHUNK_E,
                       max_chunks: int | None = None,
                       interpret: bool | None = None) -> jnp.ndarray:
    """data: (E, d), ids: (E,) int32 SORTED ascending -> (n_segments, d).

    E % chunk_e == 0 and n_segments % block_n == 0 (pad at the wrapper; use
    id = n_segments for padding rows — they fall outside every block).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    E, d = data.shape
    assert E % chunk_e == 0 and n_segments % block_n == 0
    n_blocks = n_segments // block_n
    n_chunks_total = E // chunk_e
    # first/last chunk intersecting each output block (host-side searchsorted
    # on chunk boundary ids — XLA ops, cheap, jit-compatible)
    bounds_lo = jnp.arange(n_blocks, dtype=jnp.int32) * block_n
    bounds_hi = bounds_lo + block_n
    chunk_first_id = ids[::chunk_e]                     # (n_chunks,)
    chunk_last_id = ids[chunk_e - 1::chunk_e]
    # chunk k intersects block i iff first_id < hi and last_id >= lo
    c0 = jnp.searchsorted(chunk_last_id, bounds_lo, side="left")
    c1 = jnp.searchsorted(chunk_first_id, bounds_hi, side="left")
    nchunks = jnp.maximum(c1 - c0, 0).astype(jnp.int32)
    if max_chunks is None:
        # exact bound requires concrete ids (eager call); under jit pass an
        # explicit static bound (e.g. from the data pipeline's degree cap)
        if isinstance(nchunks, jax.core.Tracer):
            raise ValueError("segment_sum_sorted under jit needs max_chunks")
        max_chunks = max(int(jnp.max(nchunks)), 1)
    c0 = jnp.minimum(c0, n_chunks_total - 1).astype(jnp.int32)
    nchunks = jnp.minimum(nchunks, max_chunks)

    grid = (n_blocks, max_chunks)
    ids2d = ids.reshape(1, E)

    def ids_map(i, j, chunk0_ref, nchunks_ref):
        k = chunk0_ref[i] + jnp.minimum(j, nchunks_ref[i] - 1)
        k = jnp.clip(k, 0, n_chunks_total - 1)
        return (0, k)

    def data_map(i, j, chunk0_ref, nchunks_ref):
        k = chunk0_ref[i] + jnp.minimum(j, nchunks_ref[i] - 1)
        k = jnp.clip(k, 0, n_chunks_total - 1)
        return (k, 0)

    def out_map(i, j, chunk0_ref, nchunks_ref):
        return (i, 0, 0)

    return pl.pallas_call(
        partial(_segsum_kernel, block_n=block_n, chunk_e=chunk_e,
                max_chunks=max_chunks),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, chunk_e), ids_map),
                pl.BlockSpec((chunk_e, d), data_map),
            ],
            out_specs=pl.BlockSpec((1, block_n, d), out_map),
            scratch_shapes=[pltpu.VMEM((block_n, d), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((n_blocks, block_n, d), data.dtype),
        interpret=interpret,
    )(c0, nchunks, ids2d, data).reshape(n_segments, d)
