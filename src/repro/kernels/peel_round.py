"""Pallas TPU megakernel: one fused peel round over the CSR incidence plan.

The dense engine's round body is a chain of ~6 separate XLA ops —
threshold-select (bucket extraction), the dead-s-clique membership gather,
and the sorted-segment decrement — each streaming the O(E = n_s * C)
incidence once.  This kernel fuses the whole chain into a single launch
over the rid-sorted CSR edge array, reusing ``segment_sum``'s
band-structured grid: output block i of r-clique state only visits the
input chunks whose rid range can intersect it (scalar-prefetched
``chunk0``/``nchunks``), and the per-edge dead test feeds the one-hot MXU
contraction directly instead of materializing ``dead_now`` in HBM.

The fusion is legal because every per-edge quantity is a pure function of
the PREVIOUS round's state plus the round's peel level:

    new_peeled[r]  = old_peeled[r] | (deg[r] <= level)         (select)
    s_alive[s]     = ~OR_c old_peeled[members[s, c]]           (derived!)
    dead_now[s]    = s_alive[s] & OR_c new_peeled[members[s, c]]
    delta[r]       = #{edges (r, s) : dead_now[s]}             (decrement)

``s_alive`` does not need to be carried at all — an s-clique is alive iff
no member peeled in an earlier round, which the (monotone) ``old_peeled``
already encodes — so the kernel reads only (deg, peeled) and writes the
full post-round (deg, peeled, core, order) in one pass, with separate
in/out refs (the sequential TPU grid never sees a read-after-write
hazard).  The minimum-degree reduction and the schedule advance stay
outside (O(n_r) jnp ops inside the while_loop body).

Plan arrays (static per problem, built once by ``peel_round_plan``):
``ids[k]`` = the r-clique of CSR edge k (ascending), ``members[k, :]`` =
the full member row of edge k's s-clique (so the dead test needs no
second indirection).  Padding edges carry ``ids = n_r_pad`` (outside every
output block) and ``members = -1`` (treated as already-peeled, so their
dead test is always False).  ``kernels.ref.peel_round_ref`` is the jnp
oracle twin; interpret mode is the CPU fallback (correctness oracle, not a
fast path).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .segment_sum import DEFAULT_BLOCK_N, DEFAULT_CHUNK_E


def peel_round_plan(rids: np.ndarray, members: np.ndarray, n_r: int,
                    block_n: int = DEFAULT_BLOCK_N,
                    chunk_e: int = DEFAULT_CHUNK_E,
                    e_pad: int | None = None,
                    n_r_pad: int | None = None,
                    max_chunks: int | None = None):
    """Pad the concrete CSR plan so ``fused_peel_round`` jits.

    rids: (E,) int32 ascending r-clique id per CSR edge; members: (E, C)
    the full s-clique member row of each edge.  Returns ``(ids_padded,
    members_padded, n_r_pad, max_chunks)``.  ``e_pad``/``n_r_pad``/
    ``max_chunks`` override the minimal pads — the Session passes its pow2
    bucket shapes here so same-bucket problems share one executable.
    Everything is eager numpy: call once at plan-build time.
    """
    rids = np.asarray(rids, np.int32)
    members = np.asarray(members, np.int32)
    E, C = members.shape
    if n_r_pad is None:
        n_r_pad = -(-max(n_r, 1) // block_n) * block_n
    assert n_r_pad % block_n == 0 and n_r_pad >= n_r
    if e_pad is None:
        e_pad = -(-max(E, 1) // chunk_e) * chunk_e
    assert e_pad % chunk_e == 0 and e_pad >= E
    ids_padded = np.full(e_pad, n_r_pad, np.int32)
    ids_padded[:E] = rids
    members_padded = np.full((e_pad, C), -1, np.int32)
    members_padded[:E] = members
    # per-block chunk-span bound: same intersection logic the wrapper
    # replays with jnp searchsorted at trace time
    bounds_lo = np.arange(n_r_pad // block_n, dtype=np.int64) * block_n
    chunk_first = ids_padded[::chunk_e]
    chunk_last = ids_padded[chunk_e - 1::chunk_e]
    c0 = np.searchsorted(chunk_last, bounds_lo, side="left")
    c1 = np.searchsorted(chunk_first, bounds_lo + block_n, side="left")
    need = max(int(np.max(np.maximum(c1 - c0, 0), initial=0)), 1)
    if max_chunks is None:
        max_chunks = need
    assert max_chunks >= need
    return ids_padded, members_padded, n_r_pad, max_chunks


def _round_kernel(chunk0_ref, nchunks_ref, params_ref, ids_ref, mem_ref,
                  deg_ref, peeled_ref, core_ref, order_ref,
                  deg_out, peeled_out, core_out, order_out, acc_ref, *,
                  block_n: int, chunk_e: int, max_chunks: int, n_r_pad: int):
    i = pl.program_id(0)   # output block of r-clique state
    j = pl.program_id(1)   # chunk-within-block
    level = params_ref[0]
    rnd = params_ref[1]

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(j < nchunks_ref[i])
    def _body():
        ids = ids_ref[0, :]                       # (chunk_e,) int32
        mem = mem_ref[...]                        # (chunk_e, C) int32
        deg = deg_ref[0, :]                       # (n_r_pad,) int32
        peeled = peeled_ref[0, :]                 # (n_r_pad,) int32 0/1
        memc = jnp.clip(mem, 0, n_r_pad - 1)
        # member state BEFORE this round; pad members (-1) read as peeled
        was = (peeled[memc] > 0) | (mem < 0)      # (chunk_e, C)
        gone = was | (deg[memc] <= level)         # == new_peeled[member]
        # s-clique alive (no member peeled before) AND dying now
        dead = (~jnp.any(was, axis=1)) & jnp.any(gone, axis=1)
        rows = i * block_n + jax.lax.broadcasted_iota(
            jnp.int32, (block_n, chunk_e), 0)
        onehot = (ids[None, :] == rows).astype(jnp.float32)
        acc_ref[...] += jax.lax.dot(
            onehot, dead.astype(jnp.float32)[:, None],
            preferred_element_type=jnp.float32)

    @pl.when(j == max_chunks - 1)
    def _finish():
        n0 = i * block_n
        degb = deg_ref[0, pl.ds(n0, block_n)]
        peeledb = peeled_ref[0, pl.ds(n0, block_n)]
        coreb = core_ref[0, pl.ds(n0, block_n)]
        orderb = order_ref[0, pl.ds(n0, block_n)]
        a = (peeledb == 0) & (degb <= level)      # this round's bucket
        newp = (peeledb > 0) | a
        delta = acc_ref[:, 0].astype(jnp.int32)
        # peeled cliques keep deg frozen (core already assigned)
        deg_out[0, :] = jnp.where(newp, degb, degb - delta)
        peeled_out[0, :] = newp.astype(jnp.int32)
        core_out[0, :] = jnp.where(a, level, coreb)
        order_out[0, :] = jnp.where(a, rnd, orderb)


def fused_peel_round(ids: jnp.ndarray, members: jnp.ndarray,
                     deg: jnp.ndarray, peeled: jnp.ndarray,
                     core: jnp.ndarray, order: jnp.ndarray,
                     level: jnp.ndarray, rnd: jnp.ndarray,
                     chunk0: jnp.ndarray, nchunks: jnp.ndarray, *,
                     block_n: int = DEFAULT_BLOCK_N,
                     chunk_e: int = DEFAULT_CHUNK_E,
                     max_chunks: int,
                     interpret: bool | None = None):
    """One fused peel round: (deg, peeled, core, order) -> same, updated.

    ids: (E_pad,) int32 ascending (pad id = n_r_pad); members: (E_pad, C);
    deg/peeled/core/order: (n_r_pad,) int32 (peeled is 0/1; pad entries
    must come in peeled=1 so they stay inert); level/rnd: int32 scalars;
    chunk0/nchunks: (n_r_pad // block_n,) per-block chunk windows (from
    ``chunk_windows``).  Shapes must satisfy E_pad % chunk_e == 0 and
    n_r_pad % block_n == 0 (use ``peel_round_plan``).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    E_pad = ids.shape[0]
    n_r_pad = deg.shape[0]
    assert E_pad % chunk_e == 0 and n_r_pad % block_n == 0
    n_blocks = n_r_pad // block_n
    n_chunks_total = E_pad // chunk_e
    params = jnp.stack([jnp.asarray(level, jnp.int32),
                        jnp.asarray(rnd, jnp.int32)])
    ids2d = ids.reshape(1, E_pad)
    mem = members
    state2d = [x.reshape(1, n_r_pad) for x in (deg, peeled, core, order)]

    def ids_map(i, j, chunk0_ref, nchunks_ref, params_ref):
        k = chunk0_ref[i] + jnp.minimum(j, nchunks_ref[i] - 1)
        k = jnp.clip(k, 0, n_chunks_total - 1)
        return (0, k)

    def mem_map(i, j, chunk0_ref, nchunks_ref, params_ref):
        k = chunk0_ref[i] + jnp.minimum(j, nchunks_ref[i] - 1)
        k = jnp.clip(k, 0, n_chunks_total - 1)
        return (k, 0)

    def full_map(i, j, chunk0_ref, nchunks_ref, params_ref):
        return (0, 0)

    def out_map(i, j, chunk0_ref, nchunks_ref, params_ref):
        return (0, i)

    C = members.shape[1]
    out_shape = [jax.ShapeDtypeStruct((1, n_r_pad), jnp.int32)] * 4
    outs = pl.pallas_call(
        partial(_round_kernel, block_n=block_n, chunk_e=chunk_e,
                max_chunks=max_chunks, n_r_pad=n_r_pad),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(n_blocks, max_chunks),
            in_specs=[
                pl.BlockSpec((1, chunk_e), ids_map),
                pl.BlockSpec((chunk_e, C), mem_map),
                pl.BlockSpec((1, n_r_pad), full_map),
                pl.BlockSpec((1, n_r_pad), full_map),
                pl.BlockSpec((1, n_r_pad), full_map),
                pl.BlockSpec((1, n_r_pad), full_map),
            ],
            out_specs=[pl.BlockSpec((1, block_n), out_map)] * 4,
            scratch_shapes=[pltpu.VMEM((block_n, 1), jnp.float32)],
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(chunk0, nchunks, params, ids2d, mem, *state2d)
    return tuple(o.reshape(n_r_pad) for o in outs)


def chunk_windows(ids: jnp.ndarray, n_r_pad: int, block_n: int,
                  chunk_e: int, max_chunks: int):
    """(chunk0, nchunks) per output block — the scalar-prefetch windows.

    jnp searchsorted over the chunk boundary ids (loop-invariant: compute
    once outside the peel while_loop and close over the result).
    """
    E_pad = ids.shape[0]
    n_blocks = n_r_pad // block_n
    n_chunks_total = E_pad // chunk_e
    bounds_lo = jnp.arange(n_blocks, dtype=jnp.int32) * block_n
    chunk_first = ids[::chunk_e]
    chunk_last = ids[chunk_e - 1::chunk_e]
    c0 = jnp.searchsorted(chunk_last, bounds_lo, side="left")
    c1 = jnp.searchsorted(chunk_first, bounds_lo + block_n, side="left")
    nchunks = jnp.minimum(jnp.maximum(c1 - c0, 0),
                          max_chunks).astype(jnp.int32)
    c0 = jnp.minimum(c0, n_chunks_total - 1).astype(jnp.int32)
    return c0, nchunks
