"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tricount_per_edge_ref(adj: jnp.ndarray) -> jnp.ndarray:
    return (adj @ adj) * adj


def triangle_count_ref(adj: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(tricount_per_edge_ref(adj)) / 6.0


def tricount_oriented_ref(adj: jnp.ndarray) -> jnp.ndarray:
    """(D @ Dᵀ) ⊙ D: per-DAG-edge common-out-neighbor counts."""
    return (adj @ adj.T) * adj


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True) -> jnp.ndarray:
    """Materialized-softmax attention. q/k/v: (B, H, S, D)."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(D)
    if causal:
        mask = jnp.arange(Sk)[None, :] <= jnp.arange(Sq)[:, None]
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def segment_sum_ref(data: jnp.ndarray, ids: jnp.ndarray,
                    n_segments: int) -> jnp.ndarray:
    return jax.ops.segment_sum(data, ids, n_segments)


def peel_round_ref(ids: jnp.ndarray, members: jnp.ndarray, deg: jnp.ndarray,
                   peeled: jnp.ndarray, core: jnp.ndarray,
                   order: jnp.ndarray, level, rnd):
    """Oracle twin of ``peel_round.fused_peel_round``: one peel round over
    the per-edge CSR plan, pure jnp.  Same contract: ids (E_pad,) with pad
    id = n_r_pad, members (E_pad, C) with pad member = -1 (read as already
    peeled), deg/peeled/core/order (n_r_pad,) int32 (peeled 0/1)."""
    n_r_pad = deg.shape[0]
    memc = jnp.clip(members, 0, n_r_pad - 1)
    was = (peeled[memc] > 0) | (members < 0)
    gone = was | (deg[memc] <= level)
    dead = (~jnp.any(was, axis=1)) & jnp.any(gone, axis=1)
    # pad edges carry id = n_r_pad: give the scatter one spill row
    delta = jnp.zeros((n_r_pad + 1,), jnp.int32).at[ids].add(
        dead.astype(jnp.int32))[:n_r_pad]
    a = (peeled == 0) & (deg <= level)
    newp = (peeled > 0) | a
    deg = jnp.where(newp, deg, deg - delta)
    return (deg, newp.astype(jnp.int32),
            jnp.where(a, level, core).astype(jnp.int32),
            jnp.where(a, rnd, order).astype(jnp.int32))
