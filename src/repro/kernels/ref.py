"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tricount_per_edge_ref(adj: jnp.ndarray) -> jnp.ndarray:
    return (adj @ adj) * adj


def triangle_count_ref(adj: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(tricount_per_edge_ref(adj)) / 6.0


def tricount_oriented_ref(adj: jnp.ndarray) -> jnp.ndarray:
    """(D @ Dᵀ) ⊙ D: per-DAG-edge common-out-neighbor counts."""
    return (adj @ adj.T) * adj


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True) -> jnp.ndarray:
    """Materialized-softmax attention. q/k/v: (B, H, S, D)."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(D)
    if causal:
        mask = jnp.arange(Sk)[None, :] <= jnp.arange(Sq)[:, None]
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def segment_sum_ref(data: jnp.ndarray, ids: jnp.ndarray,
                    n_segments: int) -> jnp.ndarray:
    return jax.ops.segment_sum(data, ids, n_segments)
