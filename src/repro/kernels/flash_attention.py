"""Pallas TPU kernel: flash attention (online softmax, causal option).

The serving/prefill hot spot of the LM family.  Grid = (batch*heads,
q_blocks, kv_blocks); the kv axis is innermost so the running (max, sum, acc)
statistics live in VMEM scratch across kv steps — the (Sq, Sk) score matrix
never exists in HBM.  Causal masking skips fully-masked kv blocks via
@pl.when (block-level early exit), halving prefill work.

Block sizes default to (128, 128): MXU-aligned on both matmuls
(Q @ K^T and P @ V).  d_head rides whole in VMEM (<= 256 for all configs).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  n_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = True
    if causal:
        # kv block strictly after the last query of this q block: skip
        run = ki * block_k <= qi * block_q + block_q - 1

    @pl.when(run)
    def _body():
        q = q_ref[0]                       # (block_q, d)
        k = k_ref[0]                       # (block_k, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            p.astype(v_ref.dtype), v_ref[0],
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool | None = None) -> jnp.ndarray:
    """q: (B, H, Sq, D); k, v: (B, H, Sk, D) -> (B, H, Sq, D).

    GQA callers repeat/reshape kv heads before the call (zero-copy view).
    Sq % block_q == 0 and Sk % block_k == 0 (pad at the wrapper).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk)
    n_q, n_k = Sq // block_q, Sk // block_k
    qf = q.reshape(B * H, Sq, D)
    kf = k.reshape(B * H, Sk, D)
    vf = v.reshape(B * H, Sk, D)
    out = pl.pallas_call(
        partial(_flash_kernel, scale=1.0 / np.sqrt(D), causal=causal,
                block_q=block_q, block_k=block_k, n_k=n_k),
        grid=(B * H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, D)
