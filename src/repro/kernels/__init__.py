"""Pallas TPU kernels for the perf-critical compute layers.

  tricount        — tiled boolean matmul (A@A)⊙A: the paper's intersection
                    loop on the MXU
  flash_attention — online-softmax attention (LM prefill/serve hot spot)
  segment_sum     — sorted-segment one-hot-matmul reduction (GNN / recsys)
  peel_round      — fused peel-round megakernel (select + dead-s-clique
                    gather + segment decrement in one launch)

Each kernel ships ops.py (jitted wrapper) + ref.py (pure-jnp oracle); tests
sweep shapes/dtypes in interpret mode on CPU.
"""
from . import ops
from . import ref
