"""Chunk -> shard work planner for the sharded incidence build.

Two nested partitions of the level-1 frontier (the seed vertices):

  * **Chunks** bound peak expansion memory per worker — the same
    budget-derived sizing the single-host chunked builder uses
    (``incidence._derive_chunk_size``), so one chunk's expansion fits
    ``memory_budget_bytes`` regardless of which shard runs it.
  * **Shards** get contiguous *chunk ranges* balanced by a per-seed work
    estimate.  Contiguity is load-bearing: seed ranges expand to
    contiguous row ranges of the DAG-expansion-ordered clique tables
    (``expand_levels``' chunking invariant), so each shard's s-clique
    output is a contiguous slab of the final s-table and the assembly
    needs no global sort or concatenate.

The work estimate is the expansion's own cost model: seed v's level-2
frontier has ``outdeg(v)`` rows and each deeper level multiplies by at
most ``dmax``, so ``w(v) = outdeg(v) * dmax^(s-2)`` bounds the rows seed
v materializes.  Per-chunk totals come off ONE prefix sum over ``w``
(O(n), no expansion), and shard boundaries are placed by searching the
chunk-work prefix for the equal-work quantiles — which guarantees

    max shard work <= total work / n_shards + max single-chunk work,

the classic contiguous-partition bound (a chunk is never split).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from ..graph.container import Digraph


def seed_work_estimate(dg: Digraph, s: int) -> np.ndarray:
    """(n,) float64 per-seed expansion-work estimate (rows materialized)."""
    outdeg = np.asarray(dg.outdeg, dtype=np.float64)
    depth = float(max(dg.dmax, 1)) ** max(s - 2, 0)
    # +1 keeps zero-out-degree seeds visible: every seed still costs a row
    # in level 1, and all-zero work would degenerate the quantile search
    return outdeg * depth + 1.0


def estimate_eager_build_bytes(dg: Digraph, s: int) -> int:
    """Upper estimate of the eager builder's peak intermediate bytes.

    The same per-seed constant ``_derive_chunk_size`` budgets with
    (~28 B per candidate element at the deepest level), summed over the
    whole frontier — what the planner compares against
    ``memory_budget_bytes`` to decide a single host cannot afford the
    one-burst expansion."""
    outdeg = np.asarray(dg.outdeg, dtype=np.float64)
    dmax = max(dg.dmax, 1)
    rows = outdeg * float(dmax) ** max(s - 2, 0)
    return int(28.0 * (s + dmax) * float(rows.sum()))


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """The planner's decision: chunk boundaries + contiguous shard ranges.

    ``chunk_bounds``  (n_chunks + 1,) seed-vertex boundaries; chunk i is
                      seeds [chunk_bounds[i], chunk_bounds[i+1]).
    ``shard_bounds``  (n_shards + 1,) chunk-index boundaries; shard k owns
                      chunks [shard_bounds[k], shard_bounds[k+1]) — possibly
                      empty on tiny graphs.
    ``chunk_work``    (n_chunks,) estimated rows per chunk.
    """

    n_shards: int
    chunk_size: int
    chunk_bounds: Tuple[int, ...]
    shard_bounds: Tuple[int, ...]
    chunk_work: Tuple[float, ...]

    @property
    def n_chunks(self) -> int:
        return len(self.chunk_bounds) - 1

    def shard_seed_range(self, k: int) -> Tuple[int, int]:
        """Seed-vertex range [start, stop) shard k expands."""
        c0, c1 = self.shard_bounds[k], self.shard_bounds[k + 1]
        return self.chunk_bounds[c0], self.chunk_bounds[c1]

    def chunks_per_shard(self) -> Tuple[int, ...]:
        b = self.shard_bounds
        return tuple(b[k + 1] - b[k] for k in range(self.n_shards))

    def shard_work(self) -> Tuple[float, ...]:
        w = np.asarray(self.chunk_work)
        b = self.shard_bounds
        return tuple(float(w[b[k]:b[k + 1]].sum())
                     for k in range(self.n_shards))

    def skew(self) -> float:
        """max/mean of estimated shard work over non-trivial plans (1.0 is
        perfect balance; empty plans report 1.0)."""
        work = self.shard_work()
        mean = sum(work) / max(len(work), 1)
        return float(max(work) / mean) if mean > 0 else 1.0


def plan_shards(dg: Digraph, s: int, n_shards: int, *,
                memory_budget_bytes: Optional[int] = None,
                chunk_size: Optional[int] = None) -> ShardPlan:
    """Partition the frontier into chunks and assign them to shards.

    ``chunk_size`` pins the chunk width (tests / parity against the
    chunked builder); otherwise it derives from the budget exactly as the
    single-host chunked builder's (``incidence._derive_chunk_size``), so
    a shard never holds more expansion state than one budget's worth.
    """
    from ..core.incidence import DEFAULT_BUILD_BUDGET, _derive_chunk_size
    n_shards = max(int(n_shards), 1)
    if chunk_size is None:
        budget = memory_budget_bytes if memory_budget_bytes is not None \
            else DEFAULT_BUILD_BUDGET
        chunk_size = _derive_chunk_size(dg, s, budget)
        # a generous budget can derive a chunk wider than n / n_shards,
        # which would starve shards; cap so every shard can get a chunk
        # (an EXPLICIT chunk_size is respected as pinned)
        if dg.n:
            chunk_size = min(chunk_size, -(-int(dg.n) // n_shards))
    chunk_size = max(1, int(chunk_size))
    n = int(dg.n)
    chunk_bounds = list(range(0, n, chunk_size)) + [n]
    if n == 0:
        chunk_bounds = [0, 0]
    n_chunks = len(chunk_bounds) - 1

    w = seed_work_estimate(dg, s)
    cum = np.concatenate([[0.0], np.cumsum(w)]) if n else np.zeros((1,))
    chunk_work = tuple(
        float(cum[chunk_bounds[i + 1]] - cum[chunk_bounds[i]])
        for i in range(n_chunks))

    # equal-work quantiles over the chunk-work prefix: shard k ends at the
    # first chunk boundary whose cumulative work reaches k/n_shards of the
    # total — a chunk is never split, so each shard overshoots its quantile
    # by at most one chunk's work (the balance bound the tests pin)
    prefix = np.concatenate([[0.0], np.cumsum(np.asarray(chunk_work))])
    total = float(prefix[-1])
    targets = total * np.arange(1, n_shards) / n_shards
    inner = np.clip(np.searchsorted(prefix, targets, side="left"),
                    0, n_chunks)
    shard_bounds = (0,) + tuple(int(x) for x in np.sort(inner)) + (n_chunks,)
    return ShardPlan(n_shards=n_shards, chunk_size=chunk_size,
                     chunk_bounds=tuple(chunk_bounds),
                     shard_bounds=shard_bounds,
                     chunk_work=chunk_work)
