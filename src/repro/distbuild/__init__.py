"""``repro.distbuild``: sharded clique listing + incidence build.

The chunked builder (DESIGN.md §7) bounds peak memory on ONE host; the
sharded backend (``core.distributed``) partitions the *peel* — but every
graph still entered the system through a single-host incidence build.
This package fuses the two (DESIGN.md §13):

  * ``planner``   — partition the level-1 frontier into budget-sized
    source-vertex chunks and assign contiguous chunk ranges to shards by
    an oriented-degree work estimate (prefix sums, O(n) total).
  * ``builder``   — each shard expands its own chunks (the DAG
    orientation makes seed ranges independent and duplicate-free, so the
    expansion is embarrassingly parallel) and its s-clique rows land as a
    CONTIGUOUS SLAB of the global DAG-expansion-ordered s-table — the
    exact s-axis layout ``core.distributed`` partitions — with no global
    concatenate.
  * ``exchange``  — the only cross-shard structure, the r-clique
    membership CSR, is built by a two-pass count-then-fill exchange:
    per-shard degree counts are summed (the all-reduce a multi-host run
    would issue), then every shard fills its slab's s-ids into disjoint
    cursor ranges of the global CSR.

Output is BIT-IDENTICAL to the eager and chunked builders for every shard
count (the digest-parity suite pins 1/2/4/8); ``build_problem(...,
build="sharded")`` is the front door.
"""
from .builder import build_problem_sharded
from .planner import (ShardPlan, estimate_eager_build_bytes, plan_shards,
                      seed_work_estimate)

__all__ = [
    "ShardPlan",
    "build_problem_sharded",
    "estimate_eager_build_bytes",
    "plan_shards",
    "seed_work_estimate",
]
