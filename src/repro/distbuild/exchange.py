"""Cross-shard count-then-fill exchange for the membership CSR.

The membership CSR (r-clique id -> incident s-clique ids) is the one
structure whose rows mix contributions from every shard: an r-clique's
incident s-cliques can live in any slab.  Rather than shipping s-rows
around, the exchange moves only (n_r,)-sized count vectors:

  pass 1 (count / all-reduce)
      each shard bincounts the r-ids in its own ``inc`` slab; the
      element-wise sum of the per-shard vectors is ``deg0``, and its
      cumsum is ``mem_offsets`` — every shard can now compute, for every
      r-clique, where ITS contribution starts:

          base_k[rid] = mem_offsets[rid] + sum_{j<k} counts_j[rid]

  pass 2 (fill, no communication)
      shard k writes its slab's s-ids into the disjoint cursor ranges
      ``[base_k, base_k + counts_k)`` using the same stable-argsort
      cursor fill as the chunked builder — because slabs are ascending
      global s-id ranges, the concatenation of shard contributions per
      r-clique is exactly ``csr_from_pairs``' stable grouping, so
      ``mem_sids`` is bit-identical to the eager builder's.

``exchange_bytes`` charges the count all-reduce (each shard contributes
one (n_r,) int64 vector); the caller adds the r-table broadcast.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def shard_degree_counts(inc: np.ndarray, slab_bounds: np.ndarray,
                        n_r: int) -> np.ndarray:
    """Pass 1: (n_shards, n_r) int64 per-shard r-clique degree counts."""
    n_shards = len(slab_bounds) - 1
    counts = np.zeros((n_shards, n_r), np.int64)
    for k in range(n_shards):
        lo, hi = int(slab_bounds[k]), int(slab_bounds[k + 1])
        if hi > lo:
            counts[k] = np.bincount(inc[lo:hi].reshape(-1), minlength=n_r)
    return counts


def assemble_mem_csr(inc: np.ndarray, slab_bounds: np.ndarray, n_r: int,
                     q_block: int) -> Tuple[np.ndarray, np.ndarray,
                                            np.ndarray, int]:
    """Two-pass exchange: ``(mem_offsets, mem_sids, deg0, exchange_bytes)``.

    ``inc`` is the global (n_s, C) member-id table, ``slab_bounds`` the
    (n_shards + 1,) global-row boundaries of each shard's slab, ``q_block``
    the fill block size (rows) bounding pass-2 transients.
    """
    n_s, C = int(inc.shape[0]), int(inc.shape[1])
    n_shards = len(slab_bounds) - 1

    counts = shard_degree_counts(inc, slab_bounds, n_r)
    deg0 = counts.sum(axis=0).astype(np.int32)  # the all-reduce result
    mem_offsets = np.concatenate(
        [np.zeros((1,), np.int32),
         np.cumsum(deg0, dtype=np.int64).astype(np.int32)])

    mem_sids = np.empty((n_s * C,), np.int32)
    earlier = np.zeros((n_r,), np.int64)  # sum of counts of shards < k
    for k in range(n_shards):
        lo, hi = int(slab_bounds[k]), int(slab_bounds[k + 1])
        cursor = mem_offsets[:-1].astype(np.int64) + earlier
        # blocks never cross the slab boundary: the cursor state is
        # shard-local, so shard k's fill touches only its own ranges
        for b0 in range(lo, hi, q_block):
            blk = inc[b0:min(b0 + q_block, hi)]
            rid = blk.reshape(-1)
            sid = np.repeat(
                np.arange(b0, b0 + blk.shape[0], dtype=np.int32), C)
            ordr = np.argsort(rid, kind="stable")
            rid_s, sid_s = rid[ordr], sid[ordr]
            uniq, cnts = np.unique(rid_s, return_counts=True)
            run_starts = np.cumsum(cnts) - cnts
            occ = np.arange(rid_s.size, dtype=np.int64) - \
                np.repeat(run_starts, cnts)
            mem_sids[cursor[rid_s] + occ] = sid_s
            cursor[uniq] += cnts
        earlier += counts[k]

    exchange_bytes = int(counts.nbytes)
    return mem_offsets, mem_sids, deg0, exchange_bytes
