"""Shard-local expansion + direct slab assembly of the incidence structure.

Execution model: shard k expands ONLY its planned seed range (contiguous
chunks, each one budget-bounded exactly like the single-host chunked
builder's), so its s-clique rows are a contiguous slab of the global
DAG-expansion-ordered s-table.  The slab boundaries are known from the
per-shard row counts alone, so the global ``inc_rid`` array is allocated
once and every shard writes its own ``[slab_lo, slab_hi)`` rows — there is
no global concatenate of vertex-tuple tables and no single-host
``csr_from_pairs`` pass (the mem-CSR comes from ``exchange``'s two-pass
count-then-fill).

The only globally shared inputs are the r-clique table (lexsorted unique
rows — every shard joins its slab against the same table, the broadcast a
multi-host run would issue) and the per-r-clique degree counts (the
all-reduce).  Both are charged to ``build_stats["exchange_bytes"]``.

Bit-identity with the eager/chunked builders follows from three facts the
test suite pins per shard count:

  * contiguous seed ranges expand independently and duplicate-free
    (``expand_levels``' chunking invariant), so slab-major row order IS the
    whole-frontier expansion order;
  * ``sort_join_np`` is a per-row pure function of (table, row) — block and
    slab boundaries cannot change the ids;
  * the count-then-fill exchange reproduces ``csr_from_pairs``' stable
    grouping because slabs are filled in ascending global s-id order.

This file runs the shards sequentially in one process — the point is the
communication/layout schedule (what each shard reads, writes, and
exchanges), which is identical whether the loop bodies run here or on
eight hosts.
"""
from __future__ import annotations

from math import comb
from typing import Any, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from ..graph import Graph
from ..graph.cliques import iter_clique_chunks, sort_join_np, subset_columns
from .exchange import assemble_mem_csr
from .planner import estimate_eager_build_bytes, plan_shards


def build_problem_sharded(g: Graph, r: int, s: int,
                          rank: Optional[jnp.ndarray] = None, *,
                          n_shards: Optional[int] = None,
                          memory_budget_bytes: Optional[int] = None,
                          chunk_size: Optional[int] = None):
    """Sharded twin of ``incidence.build_problem`` (front door for
    ``build="sharded"``).

    ``n_shards`` defaults to ``jax.device_count()`` — the mesh the peel
    will run on, so build slabs line up with peel shards.  Output is
    bit-identical to the eager builder for every shard count.
    """
    # late import: incidence lazily dispatches here from build_problem, so
    # a module-level import back into it would be circular on first touch
    from ..core.incidence import (DEFAULT_BUILD_BUDGET, NucleusProblem,
                                  _fill_parts, _resolve_digraph)
    assert 1 <= r < s, (r, s)
    if n_shards is None:
        import jax
        n_shards = jax.device_count()
    dg, orientation = _resolve_digraph(g, rank)
    budget = memory_budget_bytes if memory_budget_bytes is not None \
        else DEFAULT_BUILD_BUDGET
    plan = plan_shards(dg, s, n_shards,
                       memory_budget_bytes=memory_budget_bytes,
                       chunk_size=chunk_size)

    # --- shard-local expansion: each shard walks only its chunk range ----
    C = comb(s, r)
    all_r_parts: List[np.ndarray] = []
    s_slabs: List[np.ndarray] = []
    expand_peak = 0
    for k in range(plan.n_shards):
        seed0, seed1 = plan.shard_seed_range(k)
        s_parts: List[np.ndarray] = []
        for _s0, levels, chunk_peak in iter_clique_chunks(
                dg, [r, s], plan.chunk_size, start=seed0, stop=seed1):
            expand_peak = max(expand_peak, int(chunk_peak))
            all_r_parts.append(np.asarray(levels[r]))
            s_parts.append(np.asarray(levels[s]))
        s_slabs.append(_fill_parts(s_parts, s) if s_parts
                       else np.zeros((0, s), np.int32))

    # --- r-clique table: the broadcast side of the exchange --------------
    # r rows are globally unique (DAG orientation), so gathering the shard
    # parts and lexsorting yields the same table as the eager path; every
    # shard then joins against this one table.
    r_rows = _fill_parts(all_r_parts, r)
    if r_rows.shape[0]:
        order = np.lexsort(tuple(r_rows[:, c] for c in reversed(range(r))))
        r_table = r_rows[order]
    else:
        r_table = r_rows.reshape(0, r)
    n_r = int(r_table.shape[0])

    # --- slab bounds + per-shard blocked join into the global inc --------
    slab_bounds = np.concatenate(
        [[0], np.cumsum([int(sl.shape[0]) for sl in s_slabs],
                        dtype=np.int64)])
    n_s = int(slab_bounds[-1])
    q_block = max(1, int(budget // max(8 * 4 * C * max(r, 1), 1)))
    inc = np.empty((n_s, C), np.int32)
    join_bytes = 0
    for k in range(plan.n_shards):
        slab, base = s_slabs[k], int(slab_bounds[k])
        for b0 in range(0, slab.shape[0], q_block):
            blk = slab[b0:b0 + q_block]
            qs = np.concatenate([blk[:, list(cols)]
                                 for cols in subset_columns(s, r)], axis=0)
            join_bytes = max(join_bytes, 3 * int(qs.nbytes))
            ids = sort_join_np(r_table, qs)
            inc[base + b0:base + b0 + blk.shape[0]] = \
                np.stack(np.split(ids, C), axis=1)
        s_slabs[k] = None  # release the slab's vertex tuples as we go

    # --- two-pass count-then-fill exchange for the mem-CSR ---------------
    mem_offsets, mem_sids, deg0, exchange_bytes = assemble_mem_csr(
        inc, slab_bounds, n_r, q_block)
    exchange_bytes += max(plan.n_shards - 1, 0) * int(r_table.nbytes)

    stats: Dict[str, Any] = {
        "build": "sharded",
        "n_shards": int(plan.n_shards),
        "chunk_size": int(plan.chunk_size),
        "n_chunks": int(plan.n_chunks),
        "chunks_per_shard": [int(c) for c in plan.chunks_per_shard()],
        "shard_work": [float(w) for w in plan.shard_work()],
        "skew": float(plan.skew()),
        "exchange_bytes": int(exchange_bytes),
        "peak_intermediate_bytes": max(expand_peak, join_bytes),
        "memory_budget_bytes": memory_budget_bytes,
        "eager_estimate_bytes": int(estimate_eager_build_bytes(dg, s)),
        "fastpath": False,
    }
    return NucleusProblem(
        g=g, r=r, s=s, r_cliques=jnp.asarray(r_table),
        inc_rid=jnp.asarray(inc), mem_offsets=jnp.asarray(mem_offsets),
        mem_sids=jnp.asarray(mem_sids), deg0=jnp.asarray(deg0),
        orientation=orientation, build_stats=stats)
