"""Parallel peeling: exact (ARB-NUCLEUS analog) and approximate (Alg. 2).

Two backends, one schedule (``repro.core.schedule.PeelSchedule``) and one
round body (``repro.core.engine.peel_round``):

  * ``gather``: each round touches only the s-cliques incident to the peeled
    set (CSR gather + unique + segment add) — the work-efficient formulation
    matching the paper's bounds; shapes are data-dependent per round, so this
    backend stays an eager host loop.
  * ``dense``: delegates to the compiled engine — every round is a
    fixed-shape pass over the whole incidence structure inside one
    ``lax.while_loop``, so the entire peel is a single jitted call.  For the
    approximate algorithm rounds = O(log^2 n), so this is the TPU-preferred
    backend there (hillclimb lever + measurements in EXPERIMENTS.md).

Both backends record the peel trace (``order_round`` + raw peel values),
which ``interleaved.replay_trace`` consumes to build the ANH-EL hierarchy
without any in-loop callback.  These two entry points back the registered
``dense`` and ``gather`` backends (``repro.core.backends``): the registry
entry declares the capabilities (gather has no compiled loop, so no fused
hierarchy; both record the trace) and ``decompose()`` dispatches through
it — the capability declarations there, not this module, are what
``NucleusConfig.validate()`` derives legality from.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional

import numpy as np
import jax.numpy as jnp

from ..graph import INT
from .engine import BIG, dense_coreness, make_schedule, pallas_by_default
from .incidence import NucleusProblem
from .kcore import kcore_coreness
from .schedule import PeelSchedule


@dataclasses.dataclass
class PeelResult:
    core: jnp.ndarray          # (n_r,) int32 — exact or estimated core numbers
    rounds: int                # number of peel rounds (peeling-complexity proxy)
    order_round: jnp.ndarray   # (n_r,) round index at which each clique peeled
    peel_value: Optional[jnp.ndarray] = None  # (n_r,) raw bucket value
    # assigned at peel time (pre-clipping) — the trace value LINK replay
    # needs; == core for exact peeling.  None is a construction-time
    # sentinel only: __post_init__ replaces it with ``core``, so a
    # materialized PeelResult always carries a real array.
    uf_parent: Optional[jnp.ndarray] = None  # (n_r,) resolved ANH-EL union-
    uf_L: Optional[jnp.ndarray] = None       # find + nearest-lower-core table
    # (hierarchy=True only) — the join forest of the fused LINK fixpoint.

    def __post_init__(self):
        if self.peel_value is None:
            self.peel_value = self.core

    @property
    def has_hierarchy(self) -> bool:
        return self.uf_parent is not None


def _gather_incident_sids(problem: NucleusProblem, a_ids: jnp.ndarray) -> jnp.ndarray:
    """All s-clique ids incident to the peeled set (with duplicates)."""
    off = problem.mem_offsets
    counts = off[a_ids + 1] - off[a_ids]
    total = int(jnp.sum(counts))
    if total == 0:
        return jnp.zeros((0,), INT)
    starts = jnp.cumsum(counts) - counts
    rep = jnp.repeat(jnp.arange(a_ids.shape[0], dtype=INT), counts,
                     total_repeat_length=total)
    pos = jnp.arange(total, dtype=INT) - starts[rep]
    return problem.mem_sids[off[a_ids][rep] + pos]


def _peel_loop(problem: NucleusProblem, schedule: PeelSchedule) -> PeelResult:
    """Work-efficient gather backend: eager host loop, data-dependent shapes.

    The bucket sequence comes from the same ``PeelSchedule`` the compiled
    engine uses (level >= dmin every round, so each iteration peels at least
    the minimum-degree clique and the loop always terminates).
    """
    n_r = problem.n_r
    deg = problem.deg0
    core = jnp.full((n_r,), -1, INT)
    order_round = jnp.full((n_r,), -1, INT)
    peeled = jnp.zeros((n_r,), bool)
    s_alive = jnp.ones((problem.n_s,), bool)
    sched = schedule.init_carry()
    rounds = 0
    n_left = n_r
    while n_left > 0:
        live_deg = jnp.where(peeled, BIG, deg)
        sched, level = schedule.next_level(sched, jnp.min(live_deg))
        a_mask = (~peeled) & (deg <= level)
        core = jnp.where(a_mask, level, core)
        order_round = jnp.where(a_mask, rounds, order_round)
        peeled = peeled | a_mask
        n_left -= int(jnp.sum(a_mask))
        a_ids = jnp.nonzero(a_mask)[0].astype(INT)
        sids = _gather_incident_sids(problem, a_ids)
        if int(sids.shape[0]):
            sids_u = jnp.unique(sids)
            newly = sids_u[s_alive[sids_u]]
            if int(newly.shape[0]):
                s_alive = s_alive.at[newly].set(False)
                members = problem.inc_rid[newly].reshape(-1)
                deg = deg.at[members].add(-1)
        rounds += 1
    return PeelResult(core=core, rounds=rounds, order_round=order_round)


def _run(problem: NucleusProblem, schedule: PeelSchedule,
         backend: Literal["gather", "dense"],
         use_pallas: Optional[bool], hierarchy: bool = False,
         fast_lane: Optional[bool] = None) -> PeelResult:
    if backend == "dense":
        # the r1s2 degenerate case routes to the k-core fast lane (vertex
        # peel + one-shot edge-list fixpoint, ``core.kcore``) unless the
        # caller pins the Pallas megakernel — the lane the dense backend
        # declares as "kcore" and the planner records in Plan.reasons.
        # fast_lane=True/False forces the routing (tests compare lanes).
        if fast_lane is None:
            wants_pallas = use_pallas or (use_pallas is None
                                          and pallas_by_default())
            fast_lane = (problem.r, problem.s) == (1, 2) \
                and not wants_pallas
        if fast_lane:
            out = kcore_coreness(problem, schedule, hierarchy=hierarchy)
            if hierarchy:
                core, order, rounds, parent, L = out
                return PeelResult(core=core, rounds=int(rounds),
                                  order_round=order, uf_parent=parent,
                                  uf_L=L)
            core, order, rounds = out
            return PeelResult(core=core, rounds=int(rounds),
                              order_round=order)
        if hierarchy:
            core, order, rounds, parent, L = dense_coreness(
                problem, schedule, use_pallas=use_pallas, hierarchy=True)
            return PeelResult(core=core, rounds=int(rounds),
                              order_round=order, uf_parent=parent, uf_L=L)
        core, order, rounds = dense_coreness(problem, schedule,
                                             use_pallas=use_pallas)
        return PeelResult(core=core, rounds=int(rounds), order_round=order)
    res = _peel_loop(problem, schedule)
    if hierarchy:
        # eager backend: the forest comes from the host trace-replay oracle
        # (identical output by the DESIGN.md §4 contract); import is lazy to
        # avoid the peel <-> interleaved cycle
        from .interleaved import replay_trace, _resolve
        state = replay_trace(problem, res)
        parent = _resolve(state.parent, np.arange(problem.n_r,
                                                  dtype=np.int64))
        res = dataclasses.replace(res, uf_parent=jnp.asarray(parent, INT),
                                  uf_L=jnp.asarray(state.L, INT))
    return res


def exact_coreness(problem: NucleusProblem,
                   backend: Literal["gather", "dense"] = "gather",
                   use_pallas: Optional[bool] = None,
                   hierarchy: bool = False,
                   fast_lane: Optional[bool] = None) -> PeelResult:
    """Exact core numbers; hierarchy=True also returns the ANH-EL join
    forest (fused into the same jitted call on the dense backend).
    fast_lane forces the r1s2 k-core lane on/off (None = auto)."""
    return _run(problem, make_schedule(problem, "exact"), backend,
                use_pallas, hierarchy, fast_lane)


def approx_coreness(problem: NucleusProblem, delta: float = 0.1,
                    backend: Literal["gather", "dense"] = "gather",
                    use_pallas: Optional[bool] = None,
                    hierarchy: bool = False,
                    fast_lane: Optional[bool] = None) -> PeelResult:
    """(C(s,r)+eps)-approximate core numbers, eps = (C+delta)(1+delta)/C - C.

    Estimates are >= the true core and <= (C(s,r)+delta)(1+delta) * true core
    (Theorem 6.3).  Practical tightening: assigned value is clipped to the
    clique's original s-clique-degree (paper §6); ``peel_value`` keeps the
    unclipped bucket values because those drove LINK equality during the
    peel (the hierarchy replay must see them — and the fused forest is
    likewise built over the unclipped values).
    """
    res = _run(problem, make_schedule(problem, "approx", delta), backend,
               use_pallas, hierarchy, fast_lane)
    # practical improvement: estimate <= original degree
    core = jnp.minimum(res.core, problem.deg0)
    # still must be >= true core; deg0 >= true core always, so safe.
    return dataclasses.replace(res, core=core, peel_value=res.core)
