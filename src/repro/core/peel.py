"""Parallel peeling: exact (ARB-NUCLEUS analog) and approximate (Alg. 2).

Two backends share the bucketed peel loop:

  * ``gather``: each round touches only the s-cliques incident to the peeled
    set (CSR gather + unique + segment add) — the work-efficient formulation
    matching the paper's bounds; shapes are data-dependent per round (eager).
  * ``dense``: each round is a fixed-shape pass over the whole incidence
    structure — O(rounds * n_s * C) work but fully jit-able.  For the
    approximate algorithm rounds = O(log^2 n), so this is the TPU-preferred
    backend there (and a hillclimb lever recorded in EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses
from math import comb, log
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from ..graph import INT
from .incidence import NucleusProblem

BIG = np.iinfo(np.int32).max


@dataclasses.dataclass
class PeelResult:
    core: jnp.ndarray          # (n_r,) int32 — exact or estimated core numbers
    rounds: int                # number of peel rounds (peeling-complexity proxy)
    order_round: jnp.ndarray   # (n_r,) round index at which each clique peeled


def _gather_incident_sids(problem: NucleusProblem, a_ids: jnp.ndarray) -> jnp.ndarray:
    """All s-clique ids incident to the peeled set (with duplicates)."""
    off = problem.mem_offsets
    counts = off[a_ids + 1] - off[a_ids]
    total = int(jnp.sum(counts))
    if total == 0:
        return jnp.zeros((0,), INT)
    starts = jnp.cumsum(counts) - counts
    rep = jnp.repeat(jnp.arange(a_ids.shape[0], dtype=INT), counts,
                     total_repeat_length=total)
    pos = jnp.arange(total, dtype=INT) - starts[rep]
    return problem.mem_sids[off[a_ids][rep] + pos]


def _peel_loop(problem: NucleusProblem, thresholds, assign_value,
               backend: Literal["gather", "dense"] = "gather",
               collect_links=None) -> PeelResult:
    """Shared bucketed peel loop.

    thresholds: iterator protocol object with .current(dmin) -> (level used for
    the peel mask, value to assign); exact peeling sets both to the running
    max of dmin, approximate peeling uses geometric bucket upper bounds.
    """
    n_r, n_s = problem.n_r, problem.n_s
    deg = problem.deg0
    core = jnp.full((n_r,), -1, INT)
    order_round = jnp.full((n_r,), -1, INT)
    peeled = jnp.zeros((n_r,), bool)
    s_alive = jnp.ones((n_s,), bool)
    rounds = 0
    n_left = n_r
    while n_left > 0:
        live_deg = jnp.where(peeled, BIG, deg)
        dmin = int(jnp.min(live_deg))
        level, value = thresholds.step(dmin)
        if level is None:  # bucket advanced without peeling
            continue
        a_mask = (~peeled) & (deg <= level)
        n_a = int(jnp.sum(a_mask))
        if n_a == 0:
            thresholds.empty_bucket()
            continue
        value_arr = value if isinstance(value, jnp.ndarray) else jnp.full((n_r,), value, INT)
        core = jnp.where(a_mask, value_arr, core)
        order_round = jnp.where(a_mask, rounds, order_round)
        peeled = peeled | a_mask
        n_left -= n_a
        a_ids = jnp.nonzero(a_mask)[0].astype(INT)
        if collect_links is not None:
            collect_links(a_ids, core, peeled)
        if backend == "gather":
            sids = _gather_incident_sids(problem, a_ids)
            if int(sids.shape[0]):
                sids_u = jnp.unique(sids)
                newly = sids_u[s_alive[sids_u]]
                if int(newly.shape[0]):
                    s_alive = s_alive.at[newly].set(False)
                    members = problem.inc_rid[newly].reshape(-1)
                    deg = deg.at[members].add(-1)
        else:  # dense
            first_peel = peeled[problem.inc_rid]  # (n_s, C)
            dead_now = jnp.any(first_peel, axis=1) & s_alive
            s_alive = s_alive & ~dead_now
            members = problem.inc_rid.reshape(-1)
            dead_rep = jnp.repeat(dead_now, problem.n_sub,
                                  total_repeat_length=members.shape[0])
            deg = deg.at[members].add(-dead_rep.astype(INT))
        rounds += 1
    return PeelResult(core=core, rounds=rounds, order_round=order_round)


class _ExactThresholds:
    def __init__(self):
        self.cur = 0

    def step(self, dmin: int):
        self.cur = max(self.cur, dmin)
        return self.cur, self.cur

    def empty_bucket(self):  # cannot happen for exact (dmin always peelable)
        raise AssertionError("exact peel found empty minimum bucket")


class _ApproxThresholds:
    """Geometric buckets of Alg. 2: B_i = [.., (C+delta)(1+delta)^{i+1}]."""

    def __init__(self, n: int, s_choose_r: int, delta: float):
        self.delta = delta
        self.Cb = s_choose_r + delta
        self.i = 0
        self.rounds_in_bucket = 0
        # O(log_{1+delta/C(s,r)} n) per-bucket round cap (Alg. 2 line 17)
        self.cap = max(1, int(np.ceil(log(max(n, 2)) / log(1.0 + delta / s_choose_r))))

    def upper(self) -> int:
        return int(np.floor(self.Cb * (1.0 + self.delta) ** (self.i + 1)))

    def step(self, dmin: int):
        # advance buckets until dmin falls inside (skip empty buckets fast)
        while dmin > self.upper() or self.rounds_in_bucket >= self.cap:
            self.i += 1
            self.rounds_in_bucket = 0
        self.rounds_in_bucket += 1
        return self.upper(), self.upper()

    def empty_bucket(self):
        self.i += 1
        self.rounds_in_bucket = 0


def exact_coreness(problem: NucleusProblem,
                   backend: Literal["gather", "dense"] = "gather",
                   collect_links=None) -> PeelResult:
    return _peel_loop(problem, _ExactThresholds(), None, backend=backend,
                      collect_links=collect_links)


def approx_coreness(problem: NucleusProblem, delta: float = 0.1,
                    backend: Literal["gather", "dense"] = "gather",
                    collect_links=None) -> PeelResult:
    """(C(s,r)+eps)-approximate core numbers, eps = (C+delta)(1+delta)/C - C.

    Estimates are >= the true core and <= (C(s,r)+delta)(1+delta) * true core
    (Theorem 6.3).  Practical tightening: assigned value is clipped to the
    clique's original s-clique-degree (paper §6).
    """
    th = _ApproxThresholds(problem.g.n, comb(problem.s, problem.r), delta)
    res = _peel_loop(problem, th, None, backend=backend,
                     collect_links=collect_links)
    # practical improvement: estimate <= original degree
    core = jnp.minimum(res.core, problem.deg0)
    # still must be >= true core; deg0 >= true core always, so safe.
    return PeelResult(core=core, rounds=res.rounds, order_round=res.order_round)
