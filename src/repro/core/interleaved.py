"""Interleaved hierarchy construction — ANH-EL (paper Alg. 3 + Alg. 5).

The paper's LINK-EFFICIENT maintains, *while peeling*:
  * ``uf`` — one union-find connecting r-cliques with EQUAL core numbers that
    are s-clique-connected considering only cliques with core >= that number;
  * ``L``  — per uf-component root, the "nearest" enclosing lower core: an
    r-clique R' with maximal ND[R'] < ND[root] connected to the component
    through cliques with core >= ND[R'].

The sequential algorithm resolves conflicts with CAS loops and recursive
cascades.  On TPU-style dense arrays we replace the cascade with a *batched
fixpoint*: each peel round materializes its link multiset, then `uf`/`L`
converge by iterated grouped reductions (argmax-by-core per target component).
Each fixpoint iteration either merges components or strictly raises
core[L[root]] somewhere, so it terminates; the per-round worklists mirror the
sequential cascade one "generation" at a time.

LINK input comes from the **peel trace**, not an in-loop callback: every peel
backend records (order_round, peel_value) on device, and ``replay_trace``
reconstructs each round's peeled set A_t = {i : order_round[i] == t} post-hoc
— information-equivalent to the old per-round host callback stream (DESIGN.md
§"Engine"), so coreness stays one compiled call while the hierarchy output
(join levels) is unchanged.

The replay is now the *oracle* path: the same fixpoint also runs fused
inside the compiled peel loop (``engine.round_links`` +
``engine.link_fixpoint``, DESIGN.md §5), where one jitted call returns
coreness and the join forest together; ``link_state_from_forest`` adapts
that forest to the ``LinkState`` the tree post-pass consumes.

Link-generation work matches ANH-EL's bound: per round, per incident s-clique,
we emit O(|A ∩ S|) pairs — the chain reduction of DESIGN.md §3 — instead of
all O(C^2) member pairs (connectivity-equivalent at every level; proven by the
prefix argument in DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Literal

import numpy as np
import jax.numpy as jnp

from ..graph import INT
from .incidence import NucleusProblem
from .hierarchy import HierarchyTree
from .peel import PeelResult, exact_coreness, approx_coreness


def _resolve(parent: np.ndarray, x: np.ndarray) -> np.ndarray:
    """Vectorized find: chase parent pointers to roots (path-halving style)."""
    x = x.copy()
    while True:
        p = parent[x]
        if (p == x).all():
            return x
        x = p


@dataclasses.dataclass
class LinkState:
    """The two arrays of LINK-EFFICIENT: uf parents + nearest-core table L."""

    parent: np.ndarray  # (n_r,) int64 — same-core union-find
    L: np.ndarray       # (n_r,) int64 — nearest lower core per root, -1 empty
    core: np.ndarray    # (n_r,) int64 — final core numbers of peeled cliques
    stats_links: int = 0
    stats_unions: int = 0

    @classmethod
    def create(cls, n_r: int) -> "LinkState":
        return cls(parent=np.arange(n_r, dtype=np.int64),
                   L=np.full(n_r, -1, np.int64),
                   core=np.zeros(n_r, np.int64))

    # -- batched LINK-EFFICIENT -------------------------------------------
    def process_links(self, a: np.ndarray, b: np.ndarray,
                      max_gens: int = 10_000) -> None:
        """Fixpoint over the link worklist; (a, b) need no core ordering."""
        core, parent, L = self.core, self.parent, self.L
        gens = 0
        while a.shape[0]:
            gens += 1
            if gens > max_gens:  # pragma: no cover - termination guard
                raise RuntimeError("LINK fixpoint did not converge")
            self.stats_links += int(a.shape[0])
            a = _resolve(parent, a)
            b = _resolve(parent, b)
            # orient: core[a] <= core[b]
            swap = core[a] > core[b]
            a2 = np.where(swap, b, a)
            b2 = np.where(swap, a, b)
            a, b = a2, b2
            keep = a != b
            a, b = a[keep], b[keep]
            if a.shape[0] == 0:
                return
            eq = core[a] == core[b]
            next_a: list[np.ndarray] = []
            next_b: list[np.ndarray] = []
            if eq.any():
                ea, eb = a[eq], b[eq]
                # batched union by min-root hooking to a fixpoint
                old_roots = np.unique(np.concatenate([ea, eb]))
                while True:
                    ra, rb = _resolve(parent, ea), _resolve(parent, eb)
                    m = np.minimum(ra, rb)
                    if (ra == rb).all():
                        break
                    np.minimum.at(parent, ra, m)
                    np.minimum.at(parent, rb, m)
                self.stats_unions += int(ea.shape[0])
                new_roots = _resolve(parent, old_roots)
                changed = new_roots != old_roots
                # losers hand their L to the new root via a fresh link pair
                losers = old_roots[changed]
                lvals = L[losers]
                has = lvals >= 0
                next_a.append(lvals[has])
                next_b.append(new_roots[changed][has])
                L[losers] = -1
            lt = ~eq
            if lt.any():
                la, lb = a[lt], b[lt]
                lb = _resolve(parent, lb)  # roots may have moved in eq step
                la = _resolve(parent, la)
                # candidates for L[lb]: the incoming la's plus the current L
                tgt = np.unique(lb)
                cur = L[tgt]
                curhas = cur >= 0
                cand_t = np.concatenate([lb, tgt[curhas]])
                cand_v = np.concatenate([la, cur[curhas]])
                # winner per target = argmax core (ties -> min id)
                o = np.lexsort((cand_v, -core[cand_v], cand_t))
                ct, cv = cand_t[o], cand_v[o]
                first = np.concatenate([[True], ct[1:] != ct[:-1]])
                winners = cv[first]
                L[ct[first]] = winners
                # every non-winner candidate links against its target's winner
                lose = ~first
                if lose.any():
                    lt_t, lt_v = ct[lose], cv[lose]
                    slot = np.searchsorted(ct[first], lt_t)
                    wv = winners[slot]
                    k2 = lt_v != wv  # drop exact duplicates of the winner
                    next_a.append(lt_v[k2])
                    next_b.append(wv[k2])
            a = np.concatenate(next_a) if next_a else np.zeros(0, np.int64)
            b = np.concatenate(next_b) if next_b else np.zeros(0, np.int64)


def _round_links(problem: NucleusProblem, a_ids: np.ndarray,
                 last_peeled: np.ndarray, mem_off: np.ndarray,
                 mem_sid: np.ndarray, inc: np.ndarray, peeled: np.ndarray):
    """Chain-reduced link pairs for one peel round.

    Per incident s-clique S: connect A ∩ S as a chain and hook its head to the
    most recently peeled member of S (which has the max core among previously
    peeled members — peel values are monotone over rounds).
    """
    if a_ids.shape[0] == 0:
        return (np.zeros(0, np.int64),) * 2, last_peeled
    # all s-cliques incident to the peeled set (deduped)
    counts = mem_off[a_ids + 1] - mem_off[a_ids]
    sids = np.concatenate([mem_sid[mem_off[i]:mem_off[i + 1]] for i in a_ids]) \
        if counts.sum() else np.zeros(0, np.int64)
    sids = np.unique(sids)
    if sids.shape[0] == 0:
        return (np.zeros(0, np.int64),) * 2, last_peeled
    members = inc[sids]                      # (S, C)
    in_a = np.zeros(peeled.shape[0], bool)
    in_a[a_ids] = True
    am = in_a[members]                       # (S, C) members in this round's A
    # chain within A∩S: sort each row so A-members are leading, link consecutive
    order = np.argsort(~am, axis=1, kind="stable")
    mem_sorted = np.take_along_axis(members, order, axis=1)
    am_sorted = np.take_along_axis(am, order, axis=1)
    cnt = am_sorted.sum(axis=1)
    u_chain = mem_sorted[:, :-1][am_sorted[:, 1:]]
    v_chain = mem_sorted[:, 1:][am_sorted[:, 1:]]
    # head of each chain hooks to the previous representative of S (if any)
    head = mem_sorted[:, 0]
    prev = last_peeled[sids]
    hhas = (prev >= 0) & (cnt > 0)
    u_head, v_head = prev[hhas], head[hhas]
    # update last-peeled representative
    upd = cnt > 0
    last_peeled[sids[upd]] = head[upd]
    a = np.concatenate([u_chain.astype(np.int64), u_head.astype(np.int64)])
    b = np.concatenate([v_chain.astype(np.int64), v_head.astype(np.int64)])
    return (a, b), last_peeled


@dataclasses.dataclass
class InterleavedResult:
    core: jnp.ndarray
    tree: HierarchyTree
    rounds: int
    state: LinkState


def link_state_from_forest(peel_value, uf_parent, uf_L) -> LinkState:
    """Adapt the fused engine's on-device join forest to a ``LinkState``.

    The engine returns (parent resolved, L) plus the raw peel values — the
    exact arrays the host replay would have produced (engine.link_fixpoint
    is confluent with ``process_links``), so the same tree post-pass
    (``construct_tree_efficient``) applies unchanged.
    """
    return LinkState(parent=np.asarray(uf_parent).astype(np.int64),
                     L=np.asarray(uf_L).astype(np.int64),
                     core=np.asarray(peel_value).astype(np.int64))


def construct_tree_efficient(problem: NucleusProblem,
                             state: LinkState) -> HierarchyTree:
    """CONSTRUCT-TREE-EFFICIENT (Alg. 5, Lines 28–36), fully batched."""
    n_r = problem.n_r
    parent_uf = _resolve(state.parent, np.arange(n_r, dtype=np.int64))
    core = state.core
    cap = 2 * max(n_r, 1)
    parent = np.full(cap, -1, np.int64)
    level = np.zeros(cap, np.int64)
    level[:n_r] = core
    next_id = n_r
    # one internal node per multi-member uf component
    roots, counts = np.unique(parent_uf, return_counts=True)
    multi = counts >= 2
    node_of = np.arange(n_r, dtype=np.int64)  # root -> representing tree node
    n_new = int(multi.sum())
    ids = next_id + np.arange(n_new)
    node_of[roots[multi]] = ids
    level[ids] = core[roots[multi]]
    # leaves of multi components point at their component node
    comp_node = node_of[parent_uf]
    is_multi_leaf = comp_node != np.arange(n_r)
    parent[:n_r][is_multi_leaf] = comp_node[is_multi_leaf]
    next_id += n_new
    # hook each component to its nearest enclosing core via L
    lvals = state.L[roots]
    has = lvals >= 0
    tgt_roots = _resolve(state.parent, lvals[has])
    parent[node_of[roots[has]]] = node_of[tgt_roots]
    return HierarchyTree(n_leaves=n_r, parent=parent[:next_id].copy(),
                         level=level[:next_id].copy())


def replay_trace(problem: NucleusProblem, res: PeelResult) -> LinkState:
    """Run LINK-EFFICIENT over the recorded peel trace (DESIGN.md §"Engine").

    The trace (order_round, peel_value) determines every round's peeled set
    A_t = {i : order_round[i] == t} and the bucket value each clique was
    assigned, which is exactly what the old ``collect_links`` callback saw —
    so the per-round link stream, and therefore uf/L and the final tree, are
    identical.  One stable argsort groups cliques by round, then the replay
    feeds ``_round_links``/``process_links`` round by round.
    """
    n_r, n_s = problem.n_r, problem.n_s
    state = LinkState.create(n_r)
    mem_off = np.asarray(problem.mem_offsets).astype(np.int64)
    mem_sid = np.asarray(problem.mem_sids).astype(np.int64)
    inc = np.asarray(problem.inc_rid).astype(np.int64)
    last_peeled = np.full(n_s, -1, np.int64)
    peeled_np = np.zeros(n_r, bool)
    order = np.asarray(res.order_round).astype(np.int64)
    value = np.asarray(res.peel_value).astype(np.int64)
    ids = np.nonzero(order >= 0)[0]
    ids = ids[np.argsort(order[ids], kind="stable")].astype(np.int64)
    bounds = np.searchsorted(order[ids], np.arange(int(res.rounds) + 1))
    for t in range(int(res.rounds)):
        a_ids = ids[bounds[t]:bounds[t + 1]]
        if a_ids.shape[0] == 0:
            continue
        state.core[a_ids] = value[a_ids]
        peeled_np[a_ids] = True
        (a, b), last_peeled = _round_links(
            problem, a_ids, last_peeled, mem_off, mem_sid, inc, peeled_np)
        state.process_links(a, b)
    return state


def build_hierarchy_interleaved(
        problem: NucleusProblem,
        mode: Literal["exact", "approx"] = "exact",
        delta: float = 0.1,
        backend: Literal["gather", "dense"] = "gather",
        link: Literal["replay", "fused"] = "replay") -> InterleavedResult:
    """ANH-EL: one peel pass (trace recorded on device), LINK state, one
    tree post-pass.

    link="replay" rebuilds uf/L on the host from the recorded trace (the
    oracle path); link="fused" runs the LINK fixpoint *inside* the compiled
    peel (dense backend), so peel + hierarchy are one jitted call and only
    the O(n_r) tree post-pass touches the host.  Both produce identical
    forests (tests pin this); with backend="gather" the fused request falls
    back to the replay (there is no compiled loop to fuse into)."""
    peel = (exact_coreness if mode == "exact"
            else partial(approx_coreness, delta=delta))
    if link == "fused" and backend == "dense":
        # NOTE: the forest (like the replay) is built over the unclipped
        # bucket values; res.core carries the clipped estimates.
        res: PeelResult = peel(problem, backend=backend, hierarchy=True)
        state = link_state_from_forest(res.peel_value, res.uf_parent,
                                       res.uf_L)
    else:
        res = peel(problem, backend=backend)
        state = replay_trace(problem, res)
    tree = construct_tree_efficient(problem, state)
    return InterleavedResult(core=res.core, tree=tree, rounds=res.rounds,
                             state=state)
