"""Distributed (r, s) nucleus decomposition under `jax.shard_map`.

A thin wrapper over the unified peel engine (``repro.core.engine``): the
s-clique incidence structure is partitioned across devices (each device owns
a contiguous slab of s-cliques); r-clique degree/peeled state is replicated.
The shared ``peel_round`` body runs per shard with its ``reduce_delta`` hook
bound to one psum of the (n_r,) int32 decrement vector — the distributed
analogue of the paper's atomic decrements.  The whole loop is the engine's
`lax.while_loop` with fixed shapes, so it jits, lowers and compiles for any
mesh (this is what the multi-pod dry-run exercises).

Both exact and approximate (Alg. 2) bucket schedules are supported via the
same ``PeelSchedule`` every backend uses; the approximate schedule's
geometric thresholds make the trip count O(log^2 n), which is the paper's
span result translated to "number of all-reduces".
"""
from __future__ import annotations

import functools
from math import comb
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..graph import INT
from .engine import run_peel_engine
from .incidence import NucleusProblem
from .schedule import PeelSchedule

def _shard_map(f, mesh, in_specs, out_specs):
    """jax.shard_map moved out of experimental after 0.4.x; support both."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs)
    from jax.experimental.shard_map import shard_map
    # the engine's while_loop carries per-shard state (alive/residual), which
    # the legacy replication checker cannot type — the modern VMA tracker
    # handles it via pvary, so only disable checking on the legacy path
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def _pvary(x, axis_names):
    """Mark x device-varying for shard_map VMA tracking (no-op pre-VMA)."""
    fn = getattr(jax.lax, "pvary", None)
    return fn(x, axis_names) if fn is not None else x


def pad_incidence(inc_rid: jnp.ndarray, n_shards: int):
    """Pad the s-clique axis to a multiple of the shard count.

    Padded rows point at a ghost r-clique id (-1) whose updates are dropped.
    """
    n_s, C = inc_rid.shape
    pad = (-n_s) % n_shards
    if pad:
        ghost = jnp.full((pad, C), -1, INT)
        inc_rid = jnp.concatenate([inc_rid, ghost], axis=0)
    return inc_rid, n_s + pad


def make_sharded_decomposition(mesh: Mesh, n_r: int, n_s_padded: int, C: int,
                               schedule: PeelSchedule,
                               max_rounds: Optional[int] = None,
                               compress: bool = False,
                               hierarchy: bool = False,
                               padded: bool = False):
    """Build the jittable distributed decomposition for a mesh.

    Returns (fn, in_shardings, out_shardings); fn(inc_rid, deg0) -> (core,
    rounds) — or (core, rounds, parent, L) with hierarchy=True.  inc_rid is
    sharded over all mesh axes (s-clique partition), state is replicated.

    padded=True is the shape-bucketed variant (``core.session``): fn takes
    a third replicated ``peeled0`` bool mask marking ghost r-cliques of a
    padded shape class as pre-peeled (they never bucket, emit no links,
    keep core/order at -1).  The default 2-arg signature is unchanged —
    the multi-pod dry-run lowers it as-is.

    compress=True: the (n_r,) int32 delta all-reduce is sent as int16 with
    per-shard saturation + ERROR FEEDBACK — the saturated remainder stays in
    a local residual and is re-sent next round.  Degrees therefore lag by at
    most a round for pathological hubs but never undershoot, and every
    destroyed incidence is eventually counted exactly (peel levels are
    monotone, so late decrements only delay a peel, never mis-assign a
    core).  Halves the per-round collective bytes (the dominant term).

    hierarchy=True fuses the ANH-EL LINK state into the same loop: each
    round's links are generated from the device-local s-clique slab
    (``engine.round_links``; ghost rows emit nothing, last_peeled stays
    device-local), all-gathered so every device sees the round's global
    link multiset, and folded into the REPLICATED (parent, L) carry by the
    same ``engine.link_fixpoint`` the dense backend runs — value-identical
    on every device, so the emitted forest equals the single-device fused
    forest exactly.
    """
    n_dev = int(np.prod(mesh.devices.shape))
    if n_s_padded % n_dev:
        # pow2 bucketing alone is NOT shard-aware: a mesh whose device
        # count is not a power of two would slice the s-clique axis
        # raggedly and shard_map rejects (or worse, silently uneven-pads)
        # the operand.  Callers pad via pad_incidence or round the bucket
        # with session.shard_bucket_size.
        raise ValueError(
            f"n_s_padded={n_s_padded} is not a multiple of the mesh's "
            f"{n_dev} devices — the shard_map s-clique slices would be "
            f"ragged; pad with pad_incidence() or round the shape class "
            f"with session.shard_bucket_size()")
    axis_names = tuple(mesh.axis_names)
    shard_spec = P(axis_names)      # all axes partition the s-clique dim
    repl_spec = P()
    cap_rounds = max_rounds if max_rounds is not None else n_r + 2

    def reduce_delta(delta, resid):
        if compress:
            delta = delta + resid
            sent = jnp.minimum(delta, 32767).astype(jnp.int16)
            resid = delta - sent.astype(INT)
            red = sent
            for ax in axis_names:
                red = jax.lax.psum(red, ax)  # s16 on the wire: half the bytes
            return red.astype(INT), resid
        for ax in axis_names:
            delta = jax.lax.psum(delta, ax)
        return delta, resid

    def gather_links(la, lb, lv):
        for ax in axis_names:
            la = jax.lax.all_gather(la, ax, tiled=True)
            lb = jax.lax.all_gather(lb, ax, tiled=True)
            lv = jax.lax.all_gather(lv, ax, tiled=True)
        return la, lb, lv

    def replicate(x):
        # parent/L are value-identical across devices (every device folded
        # the same gathered multiset); pmax is an identity that re-types
        # them replicated so out_specs=P() checks under VMA tracking
        for ax in axis_names:
            x = jax.lax.pmax(x, ax)
        return x

    def local_fn(inc_local, deg0, peeled0=None):
        # alive/residual are per-shard state: mark them device-varying so
        # the engine's while_loop carry types match (shard_map VMA tracking)
        n_s_local = inc_local.shape[0]
        alive0 = _pvary(jnp.ones((n_s_local,), bool), axis_names)
        resid0 = _pvary(
            jnp.zeros((n_r,) if compress else (1,), INT), axis_names)
        if hierarchy:
            link0 = (_pvary(jnp.arange(n_r, dtype=INT), axis_names),
                     _pvary(jnp.full((n_r,), -1, INT), axis_names),
                     _pvary(jnp.full((n_s_local,), -1, INT), axis_names))
            core, _order, rounds, parent, L = run_peel_engine(
                inc_local, deg0, schedule, max_rounds=cap_rounds,
                reduce_delta=reduce_delta, resid0=resid0, alive0=alive0,
                hierarchy=True, link0=link0, gather_links=gather_links,
                peeled0=peeled0)
            return core, rounds, replicate(parent), replicate(L)
        core, _order, rounds = run_peel_engine(
            inc_local, deg0, schedule, max_rounds=cap_rounds,
            reduce_delta=reduce_delta, resid0=resid0, alive0=alive0,
            peeled0=peeled0)
        return core, rounds

    n_out = 4 if hierarchy else 2
    n_in = 3 if padded else 2
    if not padded:
        # keep the historical 2-arg signature: the dry-run lowers it
        body = lambda inc_local, deg0: local_fn(inc_local, deg0)
    else:
        body = local_fn
    fn = _shard_map(body, mesh=mesh,
                    in_specs=(shard_spec,) + (repl_spec,) * (n_in - 1),
                    out_specs=(repl_spec,) * n_out)
    in_sh = (NamedSharding(mesh, shard_spec),) + \
        (NamedSharding(mesh, repl_spec),) * (n_in - 1)
    out_sh = (NamedSharding(mesh, repl_spec),) * n_out
    return fn, in_sh, out_sh


@functools.lru_cache(maxsize=64)
def _jitted_decomposition(mesh: Mesh, n_r: int, n_s_padded: int, C: int,
                          schedule: PeelSchedule,
                          max_rounds: Optional[int], compress: bool,
                          hierarchy: bool, padded: bool = False):
    """Warm pool for the sharded fn: ``jax.jit`` caches executables per
    *callable object*, and ``make_sharded_decomposition`` used to return a
    fresh closure on every call — so every sharded run recompiled even for
    identical shapes.  Memoizing the jitted callable on the hashable key
    (Mesh compares by value) makes repeated same-shape sharded runs reuse
    the compiled executable — the warm-pool behaviour ``core.session``
    relies on."""
    fn, _, _ = make_sharded_decomposition(mesh, n_r, n_s_padded, C, schedule,
                                          max_rounds, compress=compress,
                                          hierarchy=hierarchy, padded=padded)
    return jax.jit(fn)


def sharded_decomposition_padded(inc: jnp.ndarray, deg0: jnp.ndarray,
                                 peeled0: jnp.ndarray, mesh: Mesh,
                                 schedule: PeelSchedule, *,
                                 max_rounds: Optional[int] = None,
                                 compress: bool = False,
                                 hierarchy: bool = False):
    """Run the sharded peel on an already shape-bucketed problem.

    ``core.session``'s sharded warm path: the caller has padded the
    s-clique axis to a shard-multiple shape class (``shard_bucket_size``)
    with ghost -1 rows, the r-clique axis to its bucket with ghost
    pre-peeled entries (``peeled0``), and canonicalized the schedule — so
    same-bucket problems key the same ``_jitted_decomposition`` entry and
    reuse one shard_map executable.  Returns the engine outputs unsliced
    (the caller trims the ghost tail)."""
    n_s_pad, C = int(inc.shape[0]), int(inc.shape[1])
    fn = _jitted_decomposition(mesh, int(deg0.shape[0]), n_s_pad, C,
                               schedule, max_rounds, compress, hierarchy,
                               padded=True)
    return fn(inc, deg0, peeled0)


def sharded_decomposition(problem: NucleusProblem, mesh: Mesh,
                          kind: str = "exact", delta: float = 0.1,
                          max_rounds: Optional[int] = None,
                          compress: bool = False, hierarchy: bool = False):
    """Run the distributed decomposition end-to-end on real data.

    Returns (core, rounds); with hierarchy=True, (core, rounds, parent, L,
    peel_value) — the fused ANH-EL join forest, identical to the
    single-device fused forest, plus the raw (unclipped) peel values it was
    built over: ``link_state_from_forest(peel_value, parent, L)`` is the
    tree-building input, NOT the clipped approx estimates in ``core``.
    """
    n_dev = int(np.prod(mesh.devices.shape))
    inc, n_s_pad = pad_incidence(problem.inc_rid, n_dev)
    schedule = PeelSchedule(kind=kind, s_choose_r=comb(problem.s, problem.r),
                            delta=delta, n=problem.g.n)
    fn = _jitted_decomposition(mesh, problem.n_r, n_s_pad, problem.n_sub,
                               schedule, max_rounds, compress, hierarchy)
    out = fn(inc, problem.deg0)
    core, rounds = out[0], out[1]
    raw = core
    if kind == "approx":  # practical tightening (paper §6)
        core = jnp.minimum(core, problem.deg0)
    if hierarchy:
        return core, int(rounds), out[2], out[3], raw
    return core, int(rounds)


def dryrun_specs(n_r: int, n_s: int, C: int):
    """ShapeDtypeStructs for lowering the decomposition without data."""
    return (jax.ShapeDtypeStruct((n_s, C), jnp.int32),
            jax.ShapeDtypeStruct((n_r,), jnp.int32))
