"""Distributed (r, s) nucleus decomposition under `jax.shard_map`.

The paper's shared-memory peel loop, recast for a TPU pod: the s-clique
incidence structure is partitioned across devices (each device owns a
contiguous slab of s-cliques); r-clique degree/peeled state is replicated.
One peel round is then:

    local:  dead = any(peeled[inc_local]) & alive_local        (gather)
    local:  delta = segment-add of dead rows' members          (scatter)
    comm:   delta = psum(delta)                                (all-reduce)
    local:  deg -= delta; peel mask from global min            (elementwise)

— i.e. exactly one all-reduce of an (n_r,) int32 vector per round, the
distributed analogue of the paper's atomic decrements.  The whole loop is a
`lax.while_loop` with fixed shapes, so it jits, lowers and compiles for any
mesh (this is what the multi-pod dry-run exercises).

Both exact and approximate (Alg. 2) bucket schedules are supported; the
approximate schedule's geometric thresholds make the trip count O(log^2 n),
which is the paper's span result translated to "number of all-reduces".
"""
from __future__ import annotations

import dataclasses
from functools import partial
from math import comb, log
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..graph import INT
from .incidence import NucleusProblem

BIG = np.iinfo(np.int32).max


def pad_incidence(inc_rid: jnp.ndarray, n_shards: int):
    """Pad the s-clique axis to a multiple of the shard count.

    Padded rows point at a ghost r-clique id (n_r) whose updates are dropped.
    """
    n_s, C = inc_rid.shape
    pad = (-n_s) % n_shards
    if pad:
        ghost = jnp.full((pad, C), -1, INT)
        inc_rid = jnp.concatenate([inc_rid, ghost], axis=0)
    return inc_rid, n_s + pad


@dataclasses.dataclass(frozen=True)
class PeelSchedule:
    """Static bucket schedule. exact: level tracks the running min.
    approx: geometric buckets (C(s,r)+delta)(1+delta)^i with a round cap."""

    kind: str  # "exact" | "approx"
    s_choose_r: int = 1
    delta: float = 0.1
    n: int = 1

    def init_carry(self):
        # (bucket index i, rounds_in_bucket, current level)
        return (jnp.zeros((), INT), jnp.zeros((), INT), jnp.zeros((), INT))

    def cap(self) -> int:
        return max(1, int(np.ceil(log(max(self.n, 2))
                                  / log(1.0 + self.delta / self.s_choose_r))))

    def next_level(self, sched, dmin):
        if self.kind == "exact":
            i, rib, level = sched
            level = jnp.maximum(level, dmin)
            return (i, rib, level), level
        Cb = self.s_choose_r + self.delta
        i, rib, _ = sched

        def upper(ix):
            return jnp.floor(Cb * (1.0 + self.delta) ** (ix + 1.0)).astype(INT)

        def advance(state):
            ix, r = state
            return jnp.where((dmin > upper(ix)) | (r >= self.cap()),
                             ix + 1, ix), jnp.where(
                                 (dmin > upper(ix)) | (r >= self.cap()), 0, r)

        # advance buckets until dmin fits and the round cap is not exceeded
        def cond(state):
            ix, r = state
            return (dmin > upper(ix)) | (r >= self.cap())

        i, rib = jax.lax.while_loop(cond, lambda s: advance(s), (i, rib))
        level = upper(i)
        return (i, rib + 1, level), level


def _peel_body(inc_local: jnp.ndarray, deg: jnp.ndarray, peeled: jnp.ndarray,
               alive_local: jnp.ndarray, core: jnp.ndarray,
               sched, schedule: PeelSchedule, axis_names,
               residual: Optional[jnp.ndarray] = None,
               compress: bool = False):
    """One peel round on one shard. inc_local: (n_s_local, C).

    compress=True: the (n_r,) int32 delta all-reduce is sent as int16 with
    per-shard saturation + ERROR FEEDBACK — the saturated remainder stays in
    a local residual and is re-sent next round.  Degrees therefore lag by at
    most a round for pathological hubs but never undershoot, and every
    destroyed incidence is eventually counted exactly (peel levels are
    monotone, so late decrements only delay a peel, never mis-assign a
    core).  Halves the per-round collective bytes (the dominant term).
    """
    n_r = deg.shape[0]
    live_deg = jnp.where(peeled, BIG, deg)
    dmin = jnp.min(live_deg)
    sched, level = schedule.next_level(sched, dmin)
    a_mask = (~peeled) & (deg <= level)
    core = jnp.where(a_mask, level, core)
    peeled_new = peeled | a_mask
    # which local s-cliques die this round
    member_peeled = peeled_new[jnp.clip(inc_local, 0, n_r - 1)]
    member_peeled = member_peeled | (inc_local < 0)  # ghost rows always "dead"
    dead_now = jnp.any(member_peeled, axis=1) & alive_local
    alive_local = alive_local & ~dead_now
    # local scatter of destroyed incidence, then one all-reduce
    members = jnp.clip(inc_local, 0, n_r - 1).reshape(-1)
    valid = ((inc_local >= 0) & dead_now[:, None]).reshape(-1)
    delta = jnp.zeros((n_r,), INT).at[members].add(valid.astype(INT))
    if compress:
        delta = delta + residual
        sent = jnp.minimum(delta, 32767).astype(jnp.int16)
        residual = delta - sent.astype(INT)
        red = sent
        for ax in axis_names:
            red = jax.lax.psum(red, ax)  # s16 on the wire: half the bytes
        delta = red.astype(INT)
    else:
        for ax in axis_names:
            delta = jax.lax.psum(delta, ax)
    # peeled cliques keep deg frozen (their core is already assigned)
    deg = jnp.where(peeled_new, deg, deg - delta)
    return deg, peeled_new, alive_local, core, sched, residual


def make_sharded_decomposition(mesh: Mesh, n_r: int, n_s_padded: int, C: int,
                               schedule: PeelSchedule,
                               max_rounds: Optional[int] = None,
                               compress: bool = False):
    """Build the jittable distributed decomposition for a mesh.

    Returns (fn, in_shardings, out_shardings); fn(inc_rid, deg0) -> (core,
    rounds).  inc_rid is sharded over all mesh axes (s-clique partition),
    state is replicated.
    """
    axis_names = tuple(mesh.axis_names)
    shard_spec = P(axis_names)      # all axes partition the s-clique dim
    repl_spec = P()
    cap_rounds = max_rounds if max_rounds is not None else n_r + 2

    def local_fn(inc_local, deg0):
        peeled0 = jnp.zeros((n_r,), bool)
        # alive is per-shard state: mark it device-varying so the while_loop
        # carry types match (shard_map VMA tracking)
        alive0 = jax.lax.pvary(jnp.ones((inc_local.shape[0],), bool),
                               axis_names)
        core0 = jnp.zeros((n_r,), INT)
        sched0 = schedule.init_carry()
        rounds0 = jnp.zeros((), INT)

        resid0 = jax.lax.pvary(
            jnp.zeros((n_r,) if compress else (1,), INT), axis_names)

        def cond(carry):
            _, peeled, _, _, _, rounds, _ = carry
            return (~jnp.all(peeled)) & (rounds < cap_rounds)

        def body(carry):
            deg, peeled, alive, core, sched, rounds, resid = carry
            deg, peeled, alive, core, sched, resid = _peel_body(
                inc_local, deg, peeled, alive, core, sched, schedule,
                axis_names, residual=resid if compress else resid,
                compress=compress)
            return deg, peeled, alive, core, sched, rounds + 1, resid

        carry = (deg0, peeled0, alive0, core0, sched0, rounds0, resid0)
        deg, peeled, alive, core, sched, rounds, _ = jax.lax.while_loop(
            cond, body, carry)
        return core, rounds

    fn = jax.shard_map(local_fn, mesh=mesh,
                       in_specs=(shard_spec, repl_spec),
                       out_specs=(repl_spec, repl_spec))
    in_sh = (NamedSharding(mesh, shard_spec), NamedSharding(mesh, repl_spec))
    out_sh = (NamedSharding(mesh, repl_spec), NamedSharding(mesh, repl_spec))
    return fn, in_sh, out_sh


def sharded_decomposition(problem: NucleusProblem, mesh: Mesh,
                          kind: str = "exact", delta: float = 0.1,
                          max_rounds: Optional[int] = None,
                          compress: bool = False):
    """Run the distributed decomposition end-to-end on real data."""
    n_dev = int(np.prod(mesh.devices.shape))
    inc, n_s_pad = pad_incidence(problem.inc_rid, n_dev)
    schedule = PeelSchedule(kind=kind, s_choose_r=comb(problem.s, problem.r),
                            delta=delta, n=problem.g.n)
    fn, _, _ = make_sharded_decomposition(mesh, problem.n_r, n_s_pad,
                                          problem.n_sub, schedule, max_rounds,
                                          compress=compress)
    core, rounds = jax.jit(fn)(inc, problem.deg0)
    if kind == "approx":  # practical tightening (paper §6)
        core = jnp.minimum(core, problem.deg0)
    return core, int(rounds)


def dryrun_specs(n_r: int, n_s: int, C: int):
    """ShapeDtypeStructs for lowering the decomposition without data."""
    return (jax.ShapeDtypeStruct((n_s, C), jnp.int32),
            jax.ShapeDtypeStruct((n_r,), jnp.int32))
