"""Nuclei extraction — the Fig. 10 "usefulness of the hierarchy" experiment.

`cut_hierarchy` extracts every c-(r,s) nucleus from a prebuilt hierarchy tree
by a single upward sweep (cheap).  `nuclei_without_hierarchy` answers the same
query from core numbers alone by running connectivity over qualifying
r-cliques (expensive) — the comparison baseline.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np
import jax.numpy as jnp

from ..graph import connected_components, INT
from .hierarchy import HierarchyTree, hierarchy_edges
from .incidence import NucleusProblem


def cut_hierarchy(tree: HierarchyTree, c: int) -> np.ndarray:
    """Label each leaf (r-clique) with its c-(r,s) nucleus id; -1 if core < c.

    Removing all internal nodes of level < c makes each surviving subtree one
    c-nucleus; the subtree root id is the label.
    """
    return tree.ancestor_at_level(c)


def nuclei_without_hierarchy(problem: NucleusProblem, core: jnp.ndarray,
                             c: int) -> np.ndarray:
    """The no-hierarchy baseline: connectivity over r-cliques with core >= c."""
    n_r = problem.n_r
    u, v, w = hierarchy_edges(problem, core, chain=True)
    sel = w >= c
    labels = connected_components(n_r, u[sel], v[sel])
    out = np.asarray(labels).astype(np.int64)
    out[np.asarray(core) < c] = -1
    return out


def _r_clique_table(problem_or_rcliques) -> np.ndarray:
    """Accept a NucleusProblem or a raw (n_r, r) table (serialized serving
    path has no problem object)."""
    if isinstance(problem_or_rcliques, NucleusProblem):
        return np.asarray(problem_or_rcliques.r_cliques)
    return np.asarray(problem_or_rcliques)


def nucleus_vertex_sets(problem_or_rcliques, labels: np.ndarray
                        ) -> Dict[int, np.ndarray]:
    """Expand nucleus labels over r-cliques into vertex sets per nucleus.

    Vectorized: one stable argsort over labels + ``np.split`` at label
    boundaries replaces the old per-r-clique Python append loop (which
    dominated Fig.-10 sweeps in interpreter time once graphs had >10^4
    r-cliques).  Output is identical: {label: sorted unique vertex ids}.
    """
    rc = _r_clique_table(problem_or_rcliques)
    labels = np.asarray(labels)
    rids = np.nonzero(labels >= 0)[0]
    if rids.shape[0] == 0:
        return {}
    order = np.argsort(labels[rids], kind="stable")
    rids = rids[order]
    labs = labels[rids]
    uniq, starts = np.unique(labs, return_index=True)
    groups = np.split(rids, starts[1:])
    return {int(lab): np.unique(rc[g].reshape(-1))
            for lab, g in zip(uniq, groups)}


def _nucleus_vertex_sets_loop(problem_or_rcliques, labels: np.ndarray
                              ) -> Dict[int, np.ndarray]:
    """The original per-r-clique loop — kept as the parity oracle for
    ``nucleus_vertex_sets`` (tests pin loop == vectorized on the golden
    fixtures)."""
    rc = _r_clique_table(problem_or_rcliques)
    out: Dict[int, List[int]] = {}
    for rid, lab in enumerate(labels):
        if lab < 0:
            continue
        out.setdefault(int(lab), []).append(rid)
    return {lab: np.unique(rc[rids].reshape(-1)) for lab, rids in out.items()}


def edge_density(g_edges: np.ndarray, vertices: np.ndarray) -> float:
    """|E(S)| / C(|S|, 2) — the paper's subgraph quality metric (Fig. 10).

    Vectorized: one ``np.isin`` membership test over the (m, 2) edge array
    instead of a per-edge Python set scan (the old path was O(|E|·|S|) in
    interpreter time, dominating Fig.-10-style sweeps on dense nuclei).
    """
    vertices = np.asarray(vertices)
    k = int(vertices.shape[0])
    if k < 2:
        return 0.0
    e = np.asarray(g_edges)
    if e.shape[0] == 0:
        return 0.0
    inside = int(np.isin(e, vertices).all(axis=1).sum())
    return inside / (k * (k - 1) / 2)


def canonicalize_labels(labels: np.ndarray) -> np.ndarray:
    """Canonical partition form: each label -> rank of its first occurrence.

    Negative labels (outside every nucleus) are preserved as -1.  Two
    labelings induce the same partition iff their canonical forms are
    equal — this is the form the golden fixtures store.
    """
    labels = np.asarray(labels)
    out = np.full(labels.shape[0], -1, np.int64)
    sel = labels >= 0
    x = labels[sel]
    if x.shape[0]:
        _, first, inv = np.unique(x, return_index=True, return_inverse=True)
        rank = np.argsort(np.argsort(first))  # unique-label -> occurrence rank
        out[sel] = rank[inv]
    return out


def same_partition(a: np.ndarray, b: np.ndarray) -> bool:
    """Do two labelings induce the same partition (ignoring label names)?"""
    a = np.asarray(a)
    b = np.asarray(b)
    if a.shape != b.shape:
        return False
    if ((a < 0) != (b < 0)).any():
        return False
    return bool((canonicalize_labels(a) == canonicalize_labels(b)).all())
