"""Nuclei extraction — the Fig. 10 "usefulness of the hierarchy" experiment.

`cut_hierarchy` extracts every c-(r,s) nucleus from a prebuilt hierarchy tree
by a single upward sweep (cheap).  `nuclei_without_hierarchy` answers the same
query from core numbers alone by running connectivity over qualifying
r-cliques (expensive) — the comparison baseline.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np
import jax.numpy as jnp

from ..graph import connected_components, INT
from .hierarchy import HierarchyTree, hierarchy_edges
from .incidence import NucleusProblem


def cut_hierarchy(tree: HierarchyTree, c: int) -> np.ndarray:
    """Label each leaf (r-clique) with its c-(r,s) nucleus id; -1 if core < c.

    Removing all internal nodes of level < c makes each surviving subtree one
    c-nucleus; the subtree root id is the label.
    """
    return tree.ancestor_at_level(c)


def nuclei_without_hierarchy(problem: NucleusProblem, core: jnp.ndarray,
                             c: int) -> np.ndarray:
    """The no-hierarchy baseline: connectivity over r-cliques with core >= c."""
    n_r = problem.n_r
    u, v, w = hierarchy_edges(problem, core, chain=True)
    sel = w >= c
    labels = connected_components(n_r, u[sel], v[sel])
    out = np.asarray(labels).astype(np.int64)
    out[np.asarray(core) < c] = -1
    return out


def nucleus_vertex_sets(problem: NucleusProblem, labels: np.ndarray
                        ) -> Dict[int, np.ndarray]:
    """Expand nucleus labels over r-cliques into vertex sets per nucleus."""
    rc = np.asarray(problem.r_cliques)
    out: Dict[int, List[int]] = {}
    for rid, lab in enumerate(labels):
        if lab < 0:
            continue
        out.setdefault(int(lab), []).append(rid)
    return {lab: np.unique(rc[rids].reshape(-1)) for lab, rids in out.items()}


def edge_density(g_edges: np.ndarray, vertices: np.ndarray) -> float:
    """|E(S)| / C(|S|, 2) — the paper's subgraph quality metric (Fig. 10)."""
    k = vertices.shape[0]
    if k < 2:
        return 0.0
    vs = set(int(x) for x in vertices)
    inside = sum(1 for u, v in g_edges if int(u) in vs and int(v) in vs)
    return inside / (k * (k - 1) / 2)


def same_partition(a: np.ndarray, b: np.ndarray) -> bool:
    """Do two labelings induce the same partition (ignoring label names)?"""
    a = np.asarray(a)
    b = np.asarray(b)
    if a.shape != b.shape:
        return False
    if ((a < 0) != (b < 0)).any():
        return False
    sel = a >= 0
    a, b = a[sel], b[sel]
    # canonical form: label -> first index at which it appears
    def canon(x):
        _, first = np.unique(x, return_index=True)
        remap = {int(x[i]): r for r, i in enumerate(np.sort(first))}
        return np.array([remap[int(v)] for v in x])
    return bool((canon(a) == canon(b)).all())
