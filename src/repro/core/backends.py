"""Capability-declared backend registry + the auto-planner.

Every peel backend is a registered ``Backend``: a name, a declarative
``BackendCapabilities`` record, and ``run(problem, config) ->
BackendResult``.  The registry is the single source of backend truth:

  * ``NucleusConfig.validate()`` derives the legality matrix from the
    capability declarations (``check_capabilities``) — there are no
    hand-coded per-backend branches anywhere; adding a backend is one
    ``register()`` call and the matrix, the error messages and
    ``legal_combinations()`` all follow.
  * ``decompose()`` dispatches by registry lookup (``get``), not if/elif.
  * ``resolve_plan`` is the ``backend="auto"`` / ``hierarchy="auto"``
    planner: it filters the registry down to capability-compatible
    candidates, then picks by device kind, mesh availability, problem
    size and ``memory_budget_bytes`` (decision rules in DESIGN.md §8).
    The resolved ``Plan`` (requested vs resolved + human-readable
    reasons) is recorded on every ``Decomposition`` and embedded in
    ``to_json()``.

Capability semantics (how legality is *derived*, DESIGN.md §8):

  * ``hierarchy='fused'`` is legal iff the backend has a compiled peel
    loop to fuse the LINK fixpoint into (``compiled_peel``).
  * ``hierarchy='replay'`` is legal iff the backend records the peel
    trace the host replay consumes (``records_trace``).
  * ``'none'``/``'two_phase'``/``'basic'`` need only core numbers, so
    every backend supports them.
  * the device knobs (``use_pallas``/``mesh``/``compress``) are legal
    iff the backend lists them in ``knobs``.

This module must stay import-light (``api`` imports it at module load):
backend implementations are imported lazily inside the ``run`` adapters.
"""
from __future__ import annotations

import dataclasses
from typing import (Any, Callable, Dict, List, Optional, Protocol, Tuple,
                    runtime_checkable)

import numpy as np

from . import planner_profile
from .incidence import NucleusProblem

METHODS = ("exact", "approx")
HIERARCHIES = ("none", "fused", "replay", "two_phase", "basic")
KNOBS = ("pallas", "mesh", "compress")
AUTO = "auto"


class ConfigError(ValueError):
    """An unsupported ``NucleusConfig`` combination (caught at validate())."""


@dataclasses.dataclass(frozen=True)
class BackendCapabilities:
    """What a backend declares it can do — legality is derived from this.

    ``methods``: peel schedules the backend runs ("exact"/"approx").
    ``compiled_peel``: the peel is one compiled loop, so the LINK fixpoint
        can fuse into it (``hierarchy='fused'`` legal).
    ``records_trace``: the backend returns the on-device peel trace
        (``order_round``), so host replay can rebuild the forest
        (``hierarchy='replay'`` legal).
    ``knobs``: device knobs the backend honours ("pallas"/"mesh"/
        "compress").
    ``fast_lanes``: special-case engine lanes the backend routes to by
        itself (e.g. "kcore": the r1s2 vertex-degree peel with the
        one-shot edge-list link fixpoint) — declared so the planner can
        *record* the routing in ``Plan.reasons``; legality is unaffected
        (a fast lane is an internal specialization, not a capability a
        config can request).
    ``summary``: one-line description, quoted in derived error messages
        and ``plan_report()``.
    """

    methods: Tuple[str, ...]
    compiled_peel: bool
    records_trace: bool
    knobs: frozenset
    summary: str
    fast_lanes: Tuple[str, ...] = ()

    @property
    def hierarchies(self) -> Tuple[str, ...]:
        """Supported hierarchy strategies, derived — not hand-listed."""
        return tuple(h for h in HIERARCHIES
                     if (h != "fused" or self.compiled_peel)
                     and (h != "replay" or self.records_trace))


@dataclasses.dataclass(frozen=True)
class BackendResult:
    """What ``Backend.run`` returns: host-side arrays + normalized scalars.

    ``rounds`` is always a python int (every adapter coerces — the old
    facade's sharded+fused branch forgot to); optional fields are None
    exactly when the capabilities say the backend does not produce them
    (``order_round``/``peel_value`` need ``records_trace``;
    ``uf_parent``/``uf_L`` need a fused hierarchy).
    """

    core: np.ndarray
    rounds: int
    order_round: Optional[np.ndarray] = None
    peel_value: Optional[np.ndarray] = None
    uf_parent: Optional[np.ndarray] = None
    uf_L: Optional[np.ndarray] = None


@runtime_checkable
class Backend(Protocol):
    """The registry entry contract (structural — see ``_Registered``)."""

    name: str
    capabilities: BackendCapabilities

    def run(self, problem: NucleusProblem, config) -> BackendResult:
        ...


@dataclasses.dataclass(frozen=True)
class _Registered:
    name: str
    capabilities: BackendCapabilities
    _run: Callable[[NucleusProblem, Any], BackendResult]

    def run(self, problem: NucleusProblem, config) -> BackendResult:
        return self._run(problem, config)


_REGISTRY: Dict[str, Backend] = {}


def register(backend: Backend) -> Backend:
    """Register a backend (insertion order defines enumeration order)."""
    if backend.name in _REGISTRY:
        raise ValueError(f"backend {backend.name!r} already registered")
    _REGISTRY[backend.name] = backend
    return backend


def names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def get(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ConfigError(
            f"backend={name!r}; expected one of {names()} (or 'auto')")


def all_backends() -> Tuple[Backend, ...]:
    return tuple(_REGISTRY.values())


# ---------------------------------------------------------------------------
# Capability-derived validation: the ONLY place config x backend legality
# lives.  Messages are rule templates formatted with registry-derived
# alternatives — never hand-coded per backend.
# ---------------------------------------------------------------------------

_HIERARCHY_RULES = {
    "fused": (
        "compiled_peel",
        "hierarchy='fused' runs the LINK fixpoint inside the compiled peel "
        "loop, but backend={backend!r} has no compiled loop to fuse into; "
        "use hierarchy='replay' (same forest, host fixpoint) or one of "
        "backend={alts}"),
    "replay": (
        "records_trace",
        "hierarchy='replay' rebuilds the forest from the recorded peel "
        "trace, which backend={backend!r} does not return; use "
        "hierarchy='fused' (forest computed in the same loop) or "
        "'two_phase', or one of backend={alts}"),
}

_KNOB_RULES = {
    "pallas": (
        lambda cfg: bool(cfg.use_pallas),
        "use_pallas=True selects the Pallas scatter-decrement of the "
        "compiled dense engine; backend={backend!r} never runs it — use "
        "one of backend={alts} or drop use_pallas"),
    "compress": (
        lambda cfg: bool(cfg.compress),
        "compress=True (int16 + error-feedback delta all-reduce) only "
        "applies to a sharded collective, which backend={backend!r} does "
        "not run; use one of backend={alts} or drop compress"),
    "mesh": (
        lambda cfg: cfg.mesh is not None,
        "a mesh only applies to one of backend={alts}, got "
        "backend={backend!r}"),
}


def _hierarchy_supported(caps: BackendCapabilities, hierarchy: str) -> bool:
    rule = _HIERARCHY_RULES.get(hierarchy)
    return rule is None or getattr(caps, rule[0])


def _method_alts(method: str) -> Tuple[str, ...]:
    return tuple(b.name for b in all_backends()
                 if method in b.capabilities.methods)


def check_capabilities(config) -> None:
    """Raise ConfigError iff ``config`` asks a backend for something its
    capability declaration rules out.  ``backend='auto'`` defers the
    per-backend checks to the planner but still requires at least one
    capability-compatible candidate to exist."""
    if config.backend == AUTO:
        if not candidate_backends(config):
            raise ConfigError(
                f"backend='auto': no registered backend supports "
                f"method={config.method!r} with "
                f"hierarchy={config.hierarchy!r} and the requested knobs "
                f"(use_pallas={config.use_pallas}, "
                f"mesh={'set' if config.mesh is not None else None}, "
                f"compress={config.compress}); registered: {names()}")
        return
    caps = get(config.backend).capabilities
    if config.method not in caps.methods:
        raise ConfigError(
            f"backend={config.backend!r} is {caps.summary} — "
            f"method={config.method!r} needs one of "
            f"backend={_method_alts(config.method)}")
    if config.hierarchy != AUTO and \
            not _hierarchy_supported(caps, config.hierarchy):
        attr, template = _HIERARCHY_RULES[config.hierarchy]
        alts = tuple(b.name for b in all_backends()
                     if getattr(b.capabilities, attr))
        raise ConfigError(template.format(backend=config.backend, alts=alts))
    for knob, (is_set, template) in _KNOB_RULES.items():
        if is_set(config) and knob not in caps.knobs:
            alts = tuple(b.name for b in all_backends()
                         if knob in b.capabilities.knobs)
            raise ConfigError(
                template.format(backend=config.backend, alts=alts))


# ---------------------------------------------------------------------------
# The auto-planner: backend="auto" / hierarchy="auto" resolution
# ---------------------------------------------------------------------------

# Decision thresholds (DESIGN.md §8).  TINY_NR: below this, an eager host
# loop beats paying an XLA compile for a one-shot decomposition on CPU.
# SHARD_MIN_INCIDENCE: minimum n_s * C incidence entries before slicing the
# s-clique axis across devices beats single-device overheadlessness.
# DENSE_ROUND_BYTES_PER_ENTRY: the dense engine touches the whole (n_s, C)
# incidence plus two boolean/int views of it every round (~3 int32 reads);
# if that working set exceeds memory_budget_bytes, the work-efficient
# gather backend (touches only incident s-cliques per round) is preferred.
#
# TINY_NR / SHARD_MIN_INCIDENCE are the *static fallback* values (re-
# exported from ``planner_profile``): ``resolve_plan`` prefers the
# measured per-device crossovers of ``planner_profile.json`` (written by
# ``tools/calibrate_planner.py``) and records which source fired in the
# Plan reasons.
TINY_NR = planner_profile.STATIC_TINY_NR
SHARD_MIN_INCIDENCE = planner_profile.STATIC_SHARD_MIN_INCIDENCE
DENSE_ROUND_BYTES_PER_ENTRY = 12


@dataclasses.dataclass(frozen=True)
class Plan:
    """The planner's decision record: requested vs resolved + why.

    Attached to every ``Decomposition`` (explicit configs get a trivial
    plan) and embedded in ``to_json()`` so a served artifact still says
    how it was computed."""

    backend: str
    hierarchy: str
    requested_backend: str
    requested_hierarchy: str
    reasons: Tuple[str, ...] = ()

    @property
    def was_auto(self) -> bool:
        return AUTO in (self.requested_backend, self.requested_hierarchy)

    def report(self) -> str:
        """Human-readable resolution report (examples print this)."""
        lines = [
            f"plan: backend={self.backend!r} hierarchy={self.hierarchy!r}"
            f" (requested backend={self.requested_backend!r}"
            f" hierarchy={self.requested_hierarchy!r})"]
        lines += [f"  - {r}" for r in self.reasons]
        return "\n".join(lines)

    def to_dict(self) -> Dict[str, Any]:
        return {"backend": self.backend, "hierarchy": self.hierarchy,
                "requested_backend": self.requested_backend,
                "requested_hierarchy": self.requested_hierarchy,
                "reasons": list(self.reasons)}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Plan":
        missing = [k for k in ("backend", "hierarchy", "requested_backend",
                               "requested_hierarchy") if k not in d]
        if missing:
            raise ValueError(
                f"malformed Decomposition plan: missing {missing} in {d!r} "
                f"— the artifact was truncated or hand-edited; regenerate "
                f"it with to_json()/save()")
        return cls(backend=d["backend"], hierarchy=d["hierarchy"],
                   requested_backend=d["requested_backend"],
                   requested_hierarchy=d["requested_hierarchy"],
                   reasons=tuple(d.get("reasons", ())))


def candidate_backends(config) -> List[Backend]:
    """Registry entries whose capabilities satisfy every explicit axis of
    ``config`` (the planner chooses among these; registry order is the
    tiebreak order)."""
    out = []
    for b in all_backends():
        caps = b.capabilities
        if config.method not in caps.methods:
            continue
        if config.hierarchy != AUTO and \
                not _hierarchy_supported(caps, config.hierarchy):
            continue
        if any(is_set(config) and knob not in caps.knobs
               for knob, (is_set, _t) in _KNOB_RULES.items()):
            continue
        out.append(b)
    return out


def resolve_plan(config, *, n_r: int, n_s: int, n_sub: int,
                 device_kind: Optional[str] = None,
                 n_devices: Optional[int] = None,
                 r: Optional[int] = None, s: Optional[int] = None,
                 profile_path: Optional[str] = None,
                 build: Optional[str] = None,
                 eager_build_bytes: Optional[int] = None) -> Plan:
    """Resolve ``backend='auto'`` / ``hierarchy='auto'`` to concrete axes.

    Problem facts come in as plain ints so the rules are unit-testable;
    ``decompose()``/``Session`` pass them from the built problem.  Device
    facts default to this process's jax runtime.  The rules (priority
    order, DESIGN.md §8):

      1. an explicit backend is kept as-is;
      2. knobs bind: ``mesh``/``compress`` force the sharded collective,
         ``use_pallas`` the dense engine;
      2b. build facts bind (DESIGN.md §13): a sharded incidence build
         (``build='sharded'``), or an estimated eager build working set
         (``eager_build_bytes``) exceeding ``memory_budget_bytes``, on a
         multi-device host -> sharded (the peel partitions the very
         s-clique slabs the build produced);
      3. multi-device + enough incidence work (>= the shard crossover
         entries) -> sharded;
      4. a ``memory_budget_bytes`` smaller than the dense engine's
         per-round working set -> gather (work-efficient);
      5. accelerator -> dense (compiled engine);
      6. CPU: tiny problems (below the compile-vs-eager crossover) ->
         gather (no compile), everything else -> dense.

    The crossover thresholds of rules 3 and 6 come from the loaded
    ``planner_profile.json`` entry for this device kind (measured by
    ``tools/calibrate_planner.py``), falling back to the static
    ``TINY_NR``/``SHARD_MIN_INCIDENCE`` constants; the Plan reasons
    record which source fired.  ``profile_path`` overrides the profile
    location (tests).

    ``hierarchy='auto'`` then picks the richest strategy the resolved
    backend supports: fused > replay > two_phase.  When (r, s) = (1, 2)
    and the resolved backend declares the "kcore" fast lane, the reasons
    additionally record that the degenerate k-core case routes to the
    dedicated vertex-peel engine lane.
    """
    reasons: List[str] = []
    cands = candidate_backends(config)
    if not cands:
        check_capabilities(config)          # raises with the derived message
        raise ConfigError("no capability-compatible backend")  # unreachable
    cand_names = [b.name for b in cands]

    if config.backend != AUTO:
        backend = config.backend
        reasons.append(f"backend {backend!r}: explicitly configured")
    else:
        if device_kind is None or n_devices is None:
            import jax
            device_kind = device_kind or jax.default_backend()
            n_devices = n_devices if n_devices is not None \
                else len(jax.devices())
        prof = planner_profile.thresholds(device_kind=device_kind,
                                          platform=device_kind,
                                          path=profile_path)
        tiny_nr = prof["tiny_nr"]
        shard_min = prof["shard_min_incidence"]
        prof_src = prof["source"]
        reasons.append(
            f"thresholds: tiny_nr={tiny_nr}, "
            f"shard_min_incidence={shard_min} ({prof_src})")
        budget = config.memory_budget_bytes
        dense_round_bytes = DENSE_ROUND_BYTES_PER_ENTRY * n_s * n_sub

        def pick(name, why):
            if name in cand_names:
                reasons.append(f"backend {name!r}: {why}")
                return name
            return None

        backend = None
        if config.mesh is not None:
            backend = pick("sharded", "a mesh was supplied")
        if backend is None and config.compress:
            backend = pick("sharded",
                           "compress=True implies the sharded collective")
        if backend is None and config.use_pallas:
            backend = pick("dense", "use_pallas=True selects the dense "
                                    "engine's Pallas round megakernel")
        if backend is None and n_devices > 1 and build == "sharded":
            backend = pick(
                "sharded",
                f"the incidence structure was built sharded over "
                f"{n_devices} devices; the peel partitions the same "
                f"s-clique slabs")
        if backend is None and n_devices > 1 and budget is not None and \
                eager_build_bytes is not None and eager_build_bytes > budget:
            backend = pick(
                "sharded",
                f"estimated eager build working set ~{eager_build_bytes} B "
                f"exceeds memory_budget_bytes={budget} on {n_devices} "
                f"devices: shard the build and the peel together")
        if backend is None and n_devices > 1 and \
                n_s * n_sub >= shard_min:
            backend = pick(
                "sharded",
                f"{n_devices} devices and {n_s * n_sub} incidence entries "
                f">= {shard_min} ({prof_src}): partition the s-clique axis")
        if backend is None and budget is not None and \
                dense_round_bytes > budget:
            backend = pick(
                "gather",
                f"dense per-round working set ~{dense_round_bytes} B "
                f"exceeds memory_budget_bytes={budget}; the gather "
                f"backend touches only incident s-cliques per round")
        if backend is None and device_kind != "cpu":
            backend = pick("dense", f"accelerator ({device_kind}): the "
                                    f"compiled engine is the fast path")
        if backend is None and n_r < tiny_nr:
            backend = pick(
                "gather",
                f"tiny problem (n_r={n_r} < {tiny_nr}, {prof_src}) on "
                f"cpu: the eager work-efficient loop beats paying an XLA "
                f"compile")
        if backend is None:
            backend = pick("dense", f"cpu default (n_r={n_r}): the "
                                    f"compiled engine amortizes its "
                                    f"compile over the peel rounds")
        if backend is None:             # preferred pick filtered by caps
            backend = cand_names[0]
            reasons.append(
                f"backend {backend!r}: first capability-compatible "
                f"candidate (preferred picks excluded by the requested "
                f"method/hierarchy/knobs)")

    caps = get(backend).capabilities
    if (r, s) == (1, 2) and "kcore" in caps.fast_lanes:
        reasons.append(
            f"fast lane 'kcore': (r, s) = (1, 2) on backend {backend!r} — "
            f"vertex-degree peel with the one-shot edge-list link "
            f"fixpoint, no incidence-table indirection")
    if config.hierarchy != AUTO:
        hierarchy = config.hierarchy
        reasons.append(f"hierarchy {hierarchy!r}: explicitly configured")
    elif caps.compiled_peel:
        hierarchy = "fused"
        reasons.append("hierarchy 'fused': the resolved backend has a "
                       "compiled peel loop to fuse the LINK fixpoint into")
    elif caps.records_trace:
        hierarchy = "replay"
        reasons.append("hierarchy 'replay': the resolved backend records "
                       "the peel trace the host LINK replay consumes")
    else:
        hierarchy = "two_phase"
        reasons.append("hierarchy 'two_phase': the resolved backend "
                       "returns only core numbers, so the tree is built "
                       "by the two-phase (ANH-TE) post-pass")
    return Plan(backend=backend, hierarchy=hierarchy,
                requested_backend=config.backend,
                requested_hierarchy=config.hierarchy,
                reasons=tuple(reasons))


# ---------------------------------------------------------------------------
# The four in-tree backends, ported from decompose()'s dispatch chain.
# Implementations are imported lazily so this module stays import-light.
# ---------------------------------------------------------------------------

def _run_local(problem: NucleusProblem, config, backend: str,
               **peel_kw) -> BackendResult:
    from .peel import approx_coreness, exact_coreness
    fused = config.hierarchy == "fused"
    if config.method == "exact":
        res = exact_coreness(problem, backend=backend, hierarchy=fused,
                             **peel_kw)
    else:
        res = approx_coreness(problem, delta=config.delta, backend=backend,
                              hierarchy=fused, **peel_kw)
    return BackendResult(
        core=np.asarray(res.core), rounds=int(res.rounds),
        order_round=np.asarray(res.order_round),
        peel_value=np.asarray(res.peel_value),
        uf_parent=np.asarray(res.uf_parent) if fused else None,
        uf_L=np.asarray(res.uf_L) if fused else None)


def _run_dense(problem: NucleusProblem, config) -> BackendResult:
    return _run_local(problem, config, "dense", use_pallas=config.use_pallas)


def _run_gather(problem: NucleusProblem, config) -> BackendResult:
    return _run_local(problem, config, "gather")


def _run_sharded(problem: NucleusProblem, config) -> BackendResult:
    from .distributed import sharded_decomposition
    mesh = config.mesh
    if mesh is None:
        from ..launch.mesh import make_host_mesh
        mesh = make_host_mesh()
    fused = config.hierarchy == "fused"
    out = sharded_decomposition(problem, mesh, kind=config.method,
                                delta=config.delta, compress=config.compress,
                                hierarchy=fused)
    if fused:
        core, rounds, parent, L, raw = out
        return BackendResult(core=np.asarray(core), rounds=int(rounds),
                             peel_value=np.asarray(raw),
                             uf_parent=np.asarray(parent),
                             uf_L=np.asarray(L))
    return BackendResult(core=np.asarray(out[0]), rounds=int(out[1]))


def _run_nh(problem: NucleusProblem, config) -> BackendResult:
    from .nh_baseline import nh_coreness
    core, rho = nh_coreness(problem)
    return BackendResult(core=np.asarray(core), rounds=int(rho))


register(_Registered(
    name="dense",
    capabilities=BackendCapabilities(
        methods=("exact", "approx"), compiled_peel=True, records_trace=True,
        knobs=frozenset({"pallas"}),
        summary="the compiled single-device lax.while_loop engine",
        fast_lanes=("kcore",)),
    _run=_run_dense))

register(_Registered(
    name="gather",
    capabilities=BackendCapabilities(
        methods=("exact", "approx"), compiled_peel=False, records_trace=True,
        knobs=frozenset(),
        summary="the eager work-efficient host loop"),
    _run=_run_gather))

register(_Registered(
    name="sharded",
    capabilities=BackendCapabilities(
        methods=("exact", "approx"), compiled_peel=True, records_trace=False,
        knobs=frozenset({"mesh", "compress"}),
        summary="the shard_map distributed engine"),
    _run=_run_sharded))

register(_Registered(
    name="nh",
    capabilities=BackendCapabilities(
        methods=("exact",), compiled_peel=False, records_trace=False,
        knobs=frozenset(),
        summary="the sequential exact baseline; it has no approximate "
                "bucket schedule"),
    _run=_run_nh))

BACKENDS = names()
