"""The (r, s) incidence structure: the paper's multi-level hash tables, dense.

Materialized ONCE per problem (the same O(m * alpha^{s-2}) space the paper's
L_i tables occupy), it drives every later stage with gathers/segment-sums:

  r_cliques   (n_r, r)  lexicographically sorted unique rows; id = row index
  inc_rid     (n_s, C)  the C = C(s, r) member r-clique ids of each s-clique
  mem CSR               r-clique id -> incident s-clique ids
  deg0        (n_r,)    initial s-clique-degree of each r-clique
"""
from __future__ import annotations

import dataclasses
from math import comb
from typing import Optional

import jax.numpy as jnp
import numpy as np

from ..graph import (Graph, INT, csr_from_pairs, list_cliques, sort_join,
                     subset_columns)
from ..graph.orientation import degree_rank, approx_degeneracy_rank
from ..graph.container import orient


@dataclasses.dataclass
class NucleusProblem:
    g: Graph
    r: int
    s: int
    r_cliques: jnp.ndarray      # (n_r, r) int32, lexsorted rows
    inc_rid: jnp.ndarray        # (n_s, C) int32
    mem_offsets: jnp.ndarray    # (n_r + 1,) int32
    mem_sids: jnp.ndarray       # (n_s * C,) int32
    deg0: jnp.ndarray           # (n_r,) int32

    @property
    def n_r(self) -> int:
        return int(self.r_cliques.shape[0])

    @property
    def n_s(self) -> int:
        return int(self.inc_rid.shape[0])

    @property
    def n_sub(self) -> int:
        return comb(self.s, self.r)


def pick_rank(g: Graph):
    """Pick the orientation with the smaller max out-degree (cheap to try both)."""
    cand = [degree_rank(g), approx_degeneracy_rank(g)]
    dgs = [orient(g, c) for c in cand]
    return min(dgs, key=lambda d: d.dmax)


def build_problem(g: Graph, r: int, s: int,
                  rank: Optional[jnp.ndarray] = None) -> NucleusProblem:
    assert 1 <= r < s, (r, s)
    dg = None
    if rank is None:
        dg = pick_rank(g)
    levels = list_cliques(g, [r, s], rank=rank, dg=dg)
    r_rows = levels.levels[r]
    s_rows = levels.levels[s]
    # r-clique table: rows are already unique; sort lexicographically for ids.
    from ..graph.cliques import lexsort_rows
    order = lexsort_rows(r_rows) if r_rows.shape[0] else jnp.arange(0, dtype=INT)
    r_table = r_rows[order]
    n_r = int(r_table.shape[0])
    n_s = int(s_rows.shape[0])
    C = comb(s, r)
    if n_s:
        subs = [s_rows[:, list(cols)] for cols in subset_columns(s, r)]
        queries = jnp.concatenate(subs, axis=0)  # (C * n_s, r), grouped by combo
        ids = sort_join(r_table, queries)
        inc_rid = jnp.stack(jnp.split(ids, C), axis=1).astype(INT)  # (n_s, C)
    else:
        inc_rid = jnp.zeros((0, C), INT)
    flat_rid = inc_rid.reshape(-1)
    flat_sid = jnp.repeat(jnp.arange(n_s, dtype=INT), C, total_repeat_length=n_s * C)
    mem_offsets, mem_sids = csr_from_pairs(flat_rid, flat_sid, n_r)
    deg0 = jnp.zeros((n_r,), INT)
    if n_s:
        deg0 = deg0.at[flat_rid].add(1)
    return NucleusProblem(g=g, r=r, s=s, r_cliques=r_table, inc_rid=inc_rid,
                          mem_offsets=mem_offsets, mem_sids=mem_sids, deg0=deg0)
