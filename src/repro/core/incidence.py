"""The (r, s) incidence structure: the paper's multi-level hash tables, dense.

Materialized ONCE per problem (the same O(m * alpha^{s-2}) space the paper's
L_i tables occupy), it drives every later stage with gathers/segment-sums:

  r_cliques   (n_r, r)  lexicographically sorted unique rows; id = row index
  inc_rid     (n_s, C)  the C = C(s, r) member r-clique ids of each s-clique
  mem CSR               r-clique id -> incident s-clique ids
  deg0        (n_r,)    initial s-clique-degree of each r-clique

Three builders produce bit-identical output (DESIGN.md §7, §13):

  * ``build="eager"``   — one level-synchronous expansion over all source
    vertices at once, one concatenated sort-join.  Fastest when the
    intermediate candidate arrays fit comfortably in memory.
  * ``build="chunked"`` — the memory-bounded pipeline: the level-1 frontier
    is split into source-vertex chunks (sized from ``memory_budget_bytes``),
    each chunk runs the same fixed-shape expansion independently (the DAG
    orientation makes chunks duplicate-free), and the final arrays are
    assembled with a two-pass count-then-fill build instead of one giant
    concatenate.  On the (2,3) hot path the count pass routes through the
    Pallas ``tricount_oriented`` boolean-tile kernel (jnp oracle fallback),
    so allocation sizes come off the MXU without materializing a candidate
    array.
  * ``build="sharded"`` — the distributed build (``repro.distbuild``,
    DESIGN.md §13): budget-sized chunks are assigned to shards by a work
    planner, each shard expands its own contiguous seed range, and the
    incidence arrays are assembled slab-by-slab with a two-pass
    count-then-fill exchange — no global concatenate, no single-host
    ``csr_from_pairs``.

Peak intermediate memory is tracked by both builders (``build_stats`` on the
returned problem) so the ``build`` benchmark lane can report the headroom.
"""
from __future__ import annotations

import dataclasses
from math import comb
from typing import Any, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..graph import (Graph, INT, csr_from_pairs, iter_clique_chunks,
                     sort_join, subset_columns)
from ..graph.cliques import expand_levels, lexsort_rows, sort_join_np
from ..graph.orientation import degree_rank, approx_degeneracy_rank
from ..graph.container import Digraph, orient

BUILDS = ("eager", "chunked", "sharded")
# default memory budget for build="chunked" when the caller names neither a
# budget nor a chunk size: enough for the dense (2,3) fast path at n ~ 4.5k
DEFAULT_BUILD_BUDGET = 256 << 20

# candidate orientations tried by pick_rank, in tie-break priority order
ORIENTATIONS = (("degree", degree_rank),
                ("approx_degeneracy", approx_degeneracy_rank))


@dataclasses.dataclass
class NucleusProblem:
    g: Graph
    r: int
    s: int
    r_cliques: jnp.ndarray      # (n_r, r) int32, lexsorted rows
    inc_rid: jnp.ndarray        # (n_s, C) int32
    mem_offsets: jnp.ndarray    # (n_r + 1,) int32
    mem_sids: jnp.ndarray       # (n_s * C,) int32
    deg0: jnp.ndarray           # (n_r,) int32
    # which orientation produced the DAG the cliques were listed from —
    # reproducibility metadata ("degree" | "approx_degeneracy" | "caller");
    # eager and chunked builders must agree (tests assert it)
    orientation: str = "degree"
    # builder telemetry: {"build", "chunk_size", "n_chunks",
    #  "peak_intermediate_bytes", "memory_budget_bytes", "fastpath"};
    # NOT part of the byte-identity contract
    build_stats: Optional[Dict[str, Any]] = None

    @property
    def n_r(self) -> int:
        return int(self.r_cliques.shape[0])

    @property
    def n_s(self) -> int:
        return int(self.inc_rid.shape[0])

    @property
    def n_sub(self) -> int:
        return comb(self.s, self.r)


def pick_rank(g: Graph) -> Tuple[Digraph, str]:
    """Pick the orientation with the smaller max out-degree (cheap to try
    both).  Returns (digraph, orientation_name); ties go to the first
    candidate in ORIENTATIONS order, so the winner is deterministic and can
    be recorded on the problem."""
    oriented = [(orient(g, fn(g)), name) for name, fn in ORIENTATIONS]
    return min(oriented, key=lambda t: t[0].dmax)


def _resolve_digraph(g: Graph,
                     rank: Optional[jnp.ndarray]) -> Tuple[Digraph, str]:
    if rank is None:
        return pick_rank(g)
    return orient(g, rank), "caller"


def build_problem(g: Graph, r: int, s: int,
                  rank: Optional[jnp.ndarray] = None, *,
                  build: str = "eager",
                  memory_budget_bytes: Optional[int] = None,
                  chunk_size: Optional[int] = None,
                  fastpath: Optional[bool] = None,
                  shards: Optional[int] = None) -> NucleusProblem:
    """Build the (r, s) incidence structure.

    build="eager" is the one-burst builder; build="chunked" bounds peak
    intermediate memory by ``memory_budget_bytes`` (or an explicit
    ``chunk_size`` in source vertices); build="sharded" distributes the
    chunks over ``shards`` workers (default: ``jax.device_count()``) and
    assembles per-shard slabs directly (``repro.distbuild``).  All three
    produce bit-identical arrays.  ``fastpath`` forces the dense Pallas
    (2,3) count pass on/off (None = auto: on when (r, s) == (2, 3) and
    the dense blocks fit the budget; chunked builder only).
    """
    assert 1 <= r < s, (r, s)
    if build not in BUILDS:
        raise ValueError(f"build={build!r}; expected one of {BUILDS}")
    if shards is not None and build != "sharded":
        raise ValueError(
            f"shards={shards} is the sharded builder's worker count; set "
            f"build='sharded' or drop it (got build={build!r})")
    if build == "sharded":
        if fastpath:
            raise ValueError(
                "fastpath=True is the chunked builder's dense (2,3) count "
                "pass; it does not apply to build='sharded'")
        from ..distbuild import build_problem_sharded
        return build_problem_sharded(
            g, r, s, rank, n_shards=shards,
            memory_budget_bytes=memory_budget_bytes, chunk_size=chunk_size)
    dg, orientation = _resolve_digraph(g, rank)
    if build == "eager":
        return _build_eager(g, r, s, dg, orientation)
    return _build_chunked(g, r, s, dg, orientation,
                          memory_budget_bytes=memory_budget_bytes,
                          chunk_size=chunk_size, fastpath=fastpath)


# ---------------------------------------------------------------------------
# Eager builder (the original one-burst pipeline)
# ---------------------------------------------------------------------------

def _build_eager(g: Graph, r: int, s: int, dg: Digraph,
                 orientation: str) -> NucleusProblem:
    levels, expand_peak = expand_levels(dg, jnp.arange(g.n, dtype=INT), [r, s])
    r_rows = levels[r]
    s_rows = levels[s]
    # r-clique table: rows are already unique; sort lexicographically for ids.
    order = lexsort_rows(r_rows) if r_rows.shape[0] else jnp.arange(0, dtype=INT)
    r_table = r_rows[order]
    n_r = int(r_table.shape[0])
    n_s = int(s_rows.shape[0])
    C = comb(s, r)
    join_bytes = 0
    if n_s:
        subs = [s_rows[:, list(cols)] for cols in subset_columns(s, r)]
        queries = jnp.concatenate(subs, axis=0)  # (C * n_s, r), grouped by combo
        join_bytes = 3 * int(queries.nbytes)  # queries + comb + sort perm
        ids = sort_join(r_table, queries)
        inc_rid = jnp.stack(jnp.split(ids, C), axis=1).astype(INT)  # (n_s, C)
    else:
        inc_rid = jnp.zeros((0, C), INT)
    flat_rid = inc_rid.reshape(-1)
    flat_sid = jnp.repeat(jnp.arange(n_s, dtype=INT), C, total_repeat_length=n_s * C)
    mem_offsets, mem_sids = csr_from_pairs(flat_rid, flat_sid, n_r)
    deg0 = jnp.zeros((n_r,), INT)
    if n_s:
        deg0 = deg0.at[flat_rid].add(1)
    stats = {"build": "eager", "chunk_size": g.n, "n_chunks": 1,
             "peak_intermediate_bytes": max(int(expand_peak), join_bytes),
             "memory_budget_bytes": None, "fastpath": False}
    return NucleusProblem(g=g, r=r, s=s, r_cliques=r_table, inc_rid=inc_rid,
                          mem_offsets=mem_offsets, mem_sids=mem_sids,
                          deg0=deg0, orientation=orientation,
                          build_stats=stats)


# ---------------------------------------------------------------------------
# Chunked builder (memory-bounded, two-pass count-then-fill)
# ---------------------------------------------------------------------------

def _derive_chunk_size(dg: Digraph, s: int, budget: int) -> int:
    """memory budget (bytes) -> source vertices per chunk (DESIGN.md §7).

    The deepest expansion level holds ~outdeg_avg * dmax^(s-2) partial rows
    per seed vertex; each row carries its (t,) vertex tuple plus a
    (dmax,)-wide candidate set at ~28 B per candidate element — the same
    constant the expansion's memory meter charges (int32 rows/gathers plus
    the int64 flat/query copies of the batched binary search).  The
    estimate is clamped to [1, n]: chunk_size=1 is the floor — one seed's
    expansion can exceed a pathological budget, which the builder reports
    in build_stats rather than failing.
    """
    dmax = max(dg.dmax, 1)
    n = max(dg.n, 1)
    outdeg = np.asarray(dg.outdeg)
    avg_out = max(float(outdeg.mean()), 1.0) if outdeg.size else 1.0
    rows_per_seed = avg_out * float(dmax) ** max(s - 2, 0)
    bytes_per_seed = 28.0 * (s + dmax) * rows_per_seed
    return int(np.clip(budget / max(bytes_per_seed, 1.0), 1, n))


def _fill_parts(parts: List[np.ndarray], width: int) -> np.ndarray:
    """Count-then-fill assembly: allocate the exact total once and copy each
    chunk in, releasing it — peak = total + one chunk, vs 2x total for a
    concatenate."""
    total = sum(int(p.shape[0]) for p in parts)
    out = np.empty((total, width), np.int32)
    at = 0
    for i, p in enumerate(parts):
        out[at:at + p.shape[0]] = p
        at += p.shape[0]
        parts[i] = None  # release as we go
    return out


def _assemble(g: Graph, r: int, s: int, r_rows: np.ndarray,
              s_rows: np.ndarray, orientation: str,
              budget: int, stats: Dict[str, Any]) -> NucleusProblem:
    """Shared incidence assembly from host-resident clique rows.

    The sort-join and CSR fill are blocked by ``budget``; every step is a
    per-row pure function of the eager path's, so output is bit-identical.
    """
    C = comb(s, r)
    n_s = int(s_rows.shape[0])
    if r_rows.shape[0]:
        order = np.lexsort(tuple(r_rows[:, c] for c in reversed(range(r))))
        r_table = r_rows[order]
    else:
        r_table = r_rows.reshape(0, r)
    n_r = int(r_table.shape[0])

    # blocked sort-join: ids are a per-query-row function of (table, row),
    # so block boundaries cannot change them
    q_block = max(1, int(budget // max(8 * 4 * C * max(r, 1), 1)))
    inc = np.empty((n_s, C), np.int32)
    join_bytes = 0
    for b0 in range(0, n_s, q_block):
        blk = s_rows[b0:b0 + q_block]
        qs = np.concatenate([blk[:, list(cols)]
                             for cols in subset_columns(s, r)], axis=0)
        join_bytes = max(join_bytes, 3 * int(qs.nbytes))
        ids = sort_join_np(r_table, qs)
        inc[b0:b0 + blk.shape[0]] = np.stack(np.split(ids, C), axis=1)

    # two-pass mem-CSR: counts (= deg0) first, then a cursor fill that
    # reproduces the stable argsort grouping of csr_from_pairs
    deg0 = np.bincount(inc.reshape(-1), minlength=n_r).astype(np.int32) \
        if n_s else np.zeros((n_r,), np.int32)
    mem_offsets = np.concatenate(
        [np.zeros((1,), np.int32),
         np.cumsum(deg0, dtype=np.int64).astype(np.int32)])
    mem_sids = np.empty((n_s * C,), np.int32)
    cursor = mem_offsets[:-1].astype(np.int64)
    for b0 in range(0, n_s, q_block):
        blk = inc[b0:b0 + q_block]
        rid = blk.reshape(-1)
        sid = np.repeat(np.arange(b0, b0 + blk.shape[0], dtype=np.int32), C)
        ordr = np.argsort(rid, kind="stable")
        rid_s, sid_s = rid[ordr], sid[ordr]
        uniq, counts = np.unique(rid_s, return_counts=True)
        run_starts = np.cumsum(counts) - counts
        occ = np.arange(rid_s.size, dtype=np.int64) - \
            np.repeat(run_starts, counts)
        mem_sids[cursor[rid_s] + occ] = sid_s
        cursor[uniq] += counts

    stats["peak_intermediate_bytes"] = max(
        stats.get("peak_intermediate_bytes", 0), join_bytes)
    return NucleusProblem(
        g=g, r=r, s=s, r_cliques=jnp.asarray(r_table),
        inc_rid=jnp.asarray(inc), mem_offsets=jnp.asarray(mem_offsets),
        mem_sids=jnp.asarray(mem_sids), deg0=jnp.asarray(deg0),
        orientation=orientation, build_stats=stats)


def _oriented_counts(dense: jnp.ndarray) -> jnp.ndarray:
    """(D @ Dᵀ) ⊙ D for the (2,3) count pass: the Pallas boolean-tile kernel
    on accelerators, the pure-jnp oracle on CPU (interpret-mode Pallas walks
    the tile grid in Python — one XLA matmul is the honest CPU fallback) or
    if the kernel launch fails."""
    import jax
    from ..kernels import ref
    if jax.default_backend() == "cpu":
        return ref.tricount_oriented_ref(dense)
    try:
        from ..kernels import ops
        return ops.tricount_oriented(dense)
    except Exception:
        return ref.tricount_oriented_ref(dense)


def _fastpath_ok(r: int, s: int, dg: Digraph, budget: int) -> bool:
    """Dense (2,3) count pass: the count stage holds ~4 (n, n) f32 blocks
    live (np dense, its jnp copy, the jnp counts, their np copy) plus one
    edge-block of membership rows — all must fit the budget."""
    return (r, s) == (2, 3) and 5 * dg.n * dg.n * 4 <= budget


def _build_chunked(g: Graph, r: int, s: int, dg: Digraph, orientation: str,
                   memory_budget_bytes: Optional[int],
                   chunk_size: Optional[int],
                   fastpath: Optional[bool]) -> NucleusProblem:
    budget = memory_budget_bytes if memory_budget_bytes is not None \
        else DEFAULT_BUILD_BUDGET
    if fastpath and (r, s) != (2, 3):
        raise ValueError(
            f"fastpath=True is the dense (2,3) count pass; it does not "
            f"apply to (r, s) = ({r}, {s})")
    # an explicit chunk_size pins the sparse seed-chunked path (the caller
    # is asking for a specific chunking, e.g. the equivalence tests)
    use_fast = (_fastpath_ok(r, s, dg, budget) and chunk_size is None) \
        if fastpath is None else bool(fastpath)
    if use_fast and (r, s) == (2, 3):
        return _build_chunked_23_dense(g, dg, orientation, budget)

    chunk = chunk_size if chunk_size is not None \
        else _derive_chunk_size(dg, s, budget)
    r_parts: List[np.ndarray] = []
    s_parts: List[np.ndarray] = []
    peak = 0
    n_chunks = 0
    for _start, levels, chunk_peak in iter_clique_chunks(dg, [r, s], chunk):
        n_chunks += 1
        peak = max(peak, int(chunk_peak))
        r_parts.append(np.asarray(levels[r]))
        s_parts.append(np.asarray(levels[s]))
    r_rows = _fill_parts(r_parts, r)
    s_rows = _fill_parts(s_parts, s)
    stats = {"build": "chunked", "chunk_size": chunk, "n_chunks": n_chunks,
             "peak_intermediate_bytes": peak,
             "memory_budget_bytes": memory_budget_bytes, "fastpath": False}
    return _assemble(g, r, s, r_rows, s_rows, orientation, budget, stats)


def _build_chunked_23_dense(g: Graph, dg: Digraph, orientation: str,
                            budget: int) -> NucleusProblem:
    """(2,3) fast path: Pallas boolean-tile count pass + dense-row fill.

    Pass 1 (count) runs ``tricount_oriented`` — (D @ Dᵀ) ⊙ D on the oriented
    0/1 block — so per-edge triangle-extension counts, and therefore every
    allocation size, come off the MXU without materializing a candidate
    array.  Pass 2 (fill) walks DAG edges in CSR order in budget-sized
    blocks; each block's candidate intersections are dense row products,
    and nonzero extraction emits triangles in exactly the expansion order
    of the sparse builder (u-major, then v, then w ascending), so output is
    bit-identical.  Falls back to the pure-jnp oracle when the Pallas call
    is unavailable.
    """
    n = dg.n
    outdeg = np.asarray(dg.outdeg)
    nbrs = np.asarray(dg.neighbors)
    src = np.repeat(np.arange(n, dtype=np.int32), outdeg)
    dense = np.zeros((n, n), np.float32)
    if src.size:
        dense[src, nbrs] = 1.0
    counts_nn = np.asarray(_oriented_counts(jnp.asarray(dense)))
    ext = counts_nn[src, nbrs].astype(np.int64) if src.size \
        else np.zeros((0,), np.int64)
    n_s = int(ext.sum())

    # r-cliques = DAG edges in CSR (expansion) order, rows ascending
    r_rows = np.sort(np.stack([src, nbrs], axis=1), axis=1).astype(np.int32) \
        if src.size else np.zeros((0, 2), np.int32)

    # fill pass: membership rows for a block of edges at a time
    e_block = max(1, int(budget // max(3 * 4 * n, 1)))
    s_rows = np.empty((n_s, 3), np.int32)
    at = 0
    n_blocks = 0
    for e0 in range(0, src.size, e_block):
        u = src[e0:e0 + e_block]
        v = nbrs[e0:e0 + e_block]
        members = dense[u] * dense[v]  # (block, n) common out-neighbors
        eidx, w = np.nonzero(members)  # row-major: edge order, w ascending
        tri = np.stack([u[eidx].astype(np.int32),
                        v[eidx].astype(np.int32),
                        w.astype(np.int32)], axis=1)
        tri.sort(axis=1)
        s_rows[at:at + tri.shape[0]] = tri
        at += tri.shape[0]
        n_blocks += 1
    assert at == n_s, (at, n_s)  # kernel counts must agree with the fill

    # the count stage held ~4 (n, n) f32 blocks live (np dense + jnp copy +
    # jnp counts + np counts); the fill holds 3 edge-blocks (u/v gathers +
    # their product) on top of dense
    peak = 4 * dense.nbytes + 3 * e_block * n * 4
    stats = {"build": "chunked", "chunk_size": e_block, "n_chunks": n_blocks,
             "peak_intermediate_bytes": int(peak),
             "memory_budget_bytes": budget, "fastpath": True}
    return _assemble(g, 2, 3, r_rows, s_rows, orientation, budget, stats)
