"""Sequential NH baseline (Sariyüce–Pinar [49]) + trusted pure-python oracles.

Two roles:
  * the paper's sequential state-of-the-art comparison point (Fig. 9): an
    honest, reasonably optimized sequential implementation of interleaved
    peeling + union-find hierarchy construction;
  * the correctness oracle for every parallel implementation in this repo
    (exact coreness, hierarchy join levels, approximation bounds).

Everything here is numpy/python on purpose — no JAX — so that agreement
between this module and the vectorized implementations is meaningful.

``nh_coreness`` also backs the registered ``nh`` backend
(``repro.core.backends``), whose capability declaration — exact-only, no
peel trace, no compiled loop — is what makes ``decompose(backend='nh')``
reject approx/fused/replay configs with derived errors.
"""
from __future__ import annotations

import heapq
from typing import Tuple

import numpy as np

from .incidence import NucleusProblem
from .hierarchy import HierarchyTree


def nh_coreness(problem: NucleusProblem) -> Tuple[np.ndarray, int]:
    """Sequential exact peeling: one r-clique (min s-degree) at a time.

    Returns (core numbers, number of *batched* peeling rounds that the
    parallel algorithm would need = the peeling complexity rho observed).
    """
    n_r = problem.n_r
    inc = np.asarray(problem.inc_rid)          # (n_s, C)
    mem_off = np.asarray(problem.mem_offsets)  # (n_r + 1,)
    mem_sid = np.asarray(problem.mem_sids)
    deg = np.asarray(problem.deg0).copy()
    core = np.zeros(n_r, np.int64)
    peeled = np.zeros(n_r, bool)
    s_alive = np.ones(inc.shape[0], bool)

    heap = [(int(deg[i]), i) for i in range(n_r)]
    heapq.heapify(heap)
    kmax = 0
    done = 0
    while done < n_r:
        d, i = heapq.heappop(heap)
        if peeled[i] or d != deg[i]:
            continue  # stale entry
        kmax = max(kmax, d)
        core[i] = kmax
        peeled[i] = True
        done += 1
        for sid in mem_sid[mem_off[i]:mem_off[i + 1]]:
            if not s_alive[sid]:
                continue
            s_alive[sid] = False
            for rid in inc[sid]:
                if not peeled[rid]:
                    deg[rid] -= 1
                    heapq.heappush(heap, (int(deg[rid]), int(rid)))
    # observed batched peeling complexity: rounds where all current-min
    # cliques are removed together.
    rho = len(np.unique(core)) if n_r else 0
    return core, rho


class _SeqUnionFind:
    __slots__ = ("parent",)

    def __init__(self, n: int):
        self.parent = list(range(n))

    def find(self, x: int) -> int:
        root = x
        while self.parent[root] != root:
            root = self.parent[root]
        while self.parent[x] != root:
            self.parent[x], x = root, self.parent[x]
        return root

    def union(self, a: int, b: int) -> int:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return ra
        if ra > rb:
            ra, rb = rb, ra
        self.parent[rb] = ra  # min-id root, matching the batched UF
        return ra


def nh_hierarchy(problem: NucleusProblem, core: np.ndarray) -> HierarchyTree:
    """Sequential bottom-up hierarchy via union-find (the NH strategy).

    Edges between s-clique-adjacent r-cliques carry weight
    min(core_u, core_v); sweeping levels descending and uniting edges of the
    current level reproduces Algorithm 1's per-level connectivity.
    """
    n_r = problem.n_r
    inc = np.asarray(problem.inc_rid)
    core = np.asarray(core)
    # All adjacent pairs (the paper's L_i lists), deduped.
    pairs = set()
    C = inc.shape[1] if inc.size else 0
    for row in inc:
        for a in range(C):
            for b in range(a + 1, C):
                u, v = int(row[a]), int(row[b])
                if u == v:
                    continue
                if u > v:
                    u, v = v, u
                pairs.add((u, v))
    by_level: dict[int, list[tuple[int, int]]] = {}
    for (u, v) in pairs:
        w = int(min(core[u], core[v]))
        by_level.setdefault(w, []).append((u, v))

    cap = 2 * max(n_r, 1)
    parent = np.full(cap, -1, np.int64)
    level = np.zeros(cap, np.int64)
    level[:n_r] = core
    node_of = np.arange(n_r, dtype=np.int64)  # uf root -> tree node carrying it
    uf = _SeqUnionFind(n_r)
    next_id = n_r
    for w in sorted(by_level, reverse=True):
        # group this level's edges into merged components
        touched_roots = set()
        for (u, v) in by_level[w]:
            touched_roots.add(uf.find(u))
            touched_roots.add(uf.find(v))
        for (u, v) in by_level[w]:
            uf.union(u, v)
        groups: dict[int, list[int]] = {}
        for old_root in touched_roots:
            groups.setdefault(uf.find(old_root), []).append(old_root)
        for new_root, olds in sorted(groups.items()):
            if len(olds) < 2:
                continue
            nid = next_id
            next_id += 1
            level[nid] = w
            for o in sorted(olds):
                parent[node_of[o]] = nid
            node_of[new_root] = nid
    return HierarchyTree(n_leaves=n_r, parent=parent[:next_id].copy(),
                         level=level[:next_id].copy())


def nh_full(problem: NucleusProblem):
    """End-to-end sequential NH: coreness + hierarchy (the Fig. 9 baseline)."""
    core, rho = nh_coreness(problem)
    tree = nh_hierarchy(problem, core)
    return core, tree, rho


def brute_force_coreness(problem: NucleusProblem) -> np.ndarray:
    """Definition-level oracle: iteratively delete r-cliques with s-degree < c.

    O(n_r^2 * n_s)-ish; only for tiny graphs in tests. Independent of the
    peeling implementations above (different algorithm entirely).
    """
    n_r = problem.n_r
    inc = np.asarray(problem.inc_rid)
    core = np.zeros(n_r, np.int64)
    c = 1
    alive = np.ones(n_r, bool)
    while alive.any():
        # prune to the c-(r,s) nucleus: every r-clique needs s-degree >= c
        changed = True
        cur = alive.copy()
        while changed:
            s_ok = cur[inc].all(axis=1) if inc.size else np.zeros(0, bool)
            deg = np.zeros(n_r, np.int64)
            if inc.size:
                np.add.at(deg, inc[s_ok].reshape(-1), 1)
            nxt = cur & (deg >= c)
            changed = bool((nxt != cur).any())
            cur = nxt
        core[cur] = c
        alive = cur
        c += 1
    return core
