"""The unified peel engine: ONE fixed-shape peel-round body for every backend.

``peel_round`` is the single implementation of a peel round (DESIGN.md
§"Engine"); ``run_peel_engine`` drives it with a ``lax.while_loop`` so the
whole peel compiles to one XLA computation — no per-round host sync, no
eager dispatch.  The same body serves:

  * the single-device dense backend (``peel.exact_coreness(backend="dense")``
    delegates here, one jitted call per problem shape), and
  * the ``shard_map`` distributed backend (``repro.core.distributed`` wraps
    the body with a psum ``reduce_delta`` hook; s-clique slabs are local,
    r-clique state replicated).

The loop carry records the **peel trace** on device: ``order_round[i]`` is
the round at which r-clique i peeled and ``core[i]`` the (raw, unclipped)
bucket value assigned to it.  The trace is information-equivalent to the old
per-round ``collect_links`` host callback (A_t = {i : order_round[i] == t},
peel values are the callback's core snapshot), so ANH-EL hierarchy
construction replays it post-hoc (``interleaved.replay_trace``) and coreness
stays a single compiled call.

The scatter-decrement hot path (count destroyed incidence per r-clique) has
two implementations: XLA ``.at[].add`` (the interpret/oracle fallback, and
the default off-TPU) and a Pallas sorted-segment-sum over the CSR edge array
(``kernels.segment_sum``), whose one-hot contraction runs on the MXU instead
of serialized scatter-adds.

With ``hierarchy=True`` the engine additionally threads the ANH-EL LINK
state (same-core union-find ``parent``, nearest-lower-core table ``L``,
per-s-clique ``last_peeled`` representative) through the while_loop carry:
each round materializes its chain-reduced link multiset (``round_links``)
and converges it with a batched fixpoint (``link_fixpoint``) — so ONE
compiled call returns coreness *and* the join forest, with the host trace
replay (``interleaved.replay_trace``) kept as the cross-check oracle.
DESIGN.md §5 has the carry layout and the termination argument.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from math import comb
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..graph import INT
from ..graph.unionfind import uf_union_edges
from ..kernels.peel_round import (chunk_windows, fused_peel_round,
                                  peel_round_plan)
from ..kernels.segment_sum import (DEFAULT_BLOCK_N, DEFAULT_CHUNK_E,
                                   segment_sum_sorted, sorted_ids_plan)
from .incidence import NucleusProblem
from .schedule import PeelSchedule

BIG = np.iinfo(np.int32).max


def make_schedule(problem: NucleusProblem, kind: str,
                  delta: float = 0.1) -> PeelSchedule:
    return PeelSchedule(kind=kind, s_choose_r=comb(problem.s, problem.r),
                        delta=delta, n=problem.g.n)


def pallas_by_default() -> bool:
    """THE default-kernel policy: what ``use_pallas=None`` resolves to.

    Consults the loaded planner profile (``core.planner_profile``, the
    telemetry written by ``tools/calibrate_planner.py``) for a measured
    ``pallas_default`` verdict on this device; when no profile entry
    covers the platform it warns once and falls back to the static oracle
    (Pallas on TPU, XLA scatter-add elsewhere — interpret-mode Pallas is a
    correctness oracle, not a fast path).  ``dense_coreness`` and
    ``core.session`` both resolve through here — one place to change if
    the policy ever widens."""
    from .planner_profile import pallas_default
    v = pallas_default(jax.default_backend())
    if v is not None:
        return v
    return jax.default_backend() == "tpu"


@dataclasses.dataclass(frozen=True)
class ScatterSpec:
    """Static (hashable) config of the Pallas scatter-decrement path."""

    block_n: int
    chunk_e: int
    max_chunks: int
    n_seg_pad: int
    interpret: bool


def scatter_decrement(inc_rid: jnp.ndarray, dead_now: jnp.ndarray,
                      n_r: int) -> jnp.ndarray:
    """delta[r] = # of s-cliques dying this round that contain r.

    XLA scatter-add formulation — the oracle the Pallas path is checked
    against, and the default backend off-TPU.  Rows with negative ids
    (distributed ghost padding) never contribute.
    """
    members = jnp.clip(inc_rid, 0, n_r - 1).reshape(-1)
    valid = ((inc_rid >= 0) & dead_now[:, None]).reshape(-1)
    return jnp.zeros((n_r,), INT).at[members].add(valid.astype(INT))


# ---------------------------------------------------------------------------
# Fused ANH-EL link state (DESIGN.md §5): fixed-shape link generation + the
# batched LINK-EFFICIENT fixpoint, pure jnp so it nests inside the peel loop.
# ---------------------------------------------------------------------------

def round_links(inc_rid, a_mask, last_peeled):
    """Chain-reduced ANH-EL link multiset for one peel round, fixed shape.

    Per s-clique row: members peeled this round (A ∩ S) are moved to the
    front by a stable sort and linked consecutively (the chain reduction of
    DESIGN.md §3); the chain head additionally hooks to the s-clique's
    previously peeled representative.  Matches ``interleaved._round_links``
    link-for-link, but emitted densely over all rows with a validity mask —
    untouched rows (no A-member) contribute nothing and keep last_peeled.

    Returns (la, lb, lvalid) of shape (n_s * C,) and the updated
    last_peeled.  Ghost rows (inc_rid < 0, distributed padding) never emit.
    """
    n_s, C = inc_rid.shape
    n_r = a_mask.shape[0]
    am = (inc_rid >= 0) & a_mask[jnp.clip(inc_rid, 0, n_r - 1)]
    order = jnp.argsort(~am, axis=1, stable=True)
    mem_s = jnp.take_along_axis(inc_rid, order, axis=1)
    am_s = jnp.take_along_axis(am, order, axis=1)
    cnt = am_s.sum(axis=1)
    # chain: A-members are a prefix after the sort, link consecutive pairs
    chain_valid = am_s[:, 1:]
    # head of each chain hooks to the previous representative of S (if any)
    head = mem_s[:, 0]
    prev = last_peeled
    head_valid = (prev >= 0) & (cnt > 0)
    last_peeled = jnp.where(cnt > 0, head, last_peeled)
    la = jnp.concatenate([mem_s[:, :-1].reshape(-1), prev])
    lb = jnp.concatenate([mem_s[:, 1:].reshape(-1), head])
    lvalid = jnp.concatenate([chain_valid.reshape(-1), head_valid])
    return la, lb, lvalid, last_peeled


def link_fixpoint(parent, L, core, la, lb, lvalid, *, max_gens: int):
    """Batched LINK-EFFICIENT fixpoint over one round's links, pure jnp.

    The numpy worklist (``interleaved.LinkState.process_links``) with fixed
    shapes: the worklist has K + n_r slots (K initial links; one handoff
    slot per r-clique).  Each generation

      1. resolves + orients every link so core[a] <= core[b];
      2. unions same-core links with min-hooking (``uf_union_edges``, dead
         slots masked to self-edges), keeping ``parent`` fully resolved;
      3. roots absorbed by the union hand their L off as a fresh link in
         their node's handoff slot (each node loses root status at most
         once ever, so the slot is collision-free);
      4. lower-core links compete for L[target] by (max core, min id) via a
         two-pass scatter; every losing candidate re-links against the
         winner *in place* (slot i's successor overwrites slot i), and the
         ousted previous L re-links from the winning slot.

    Final (parent, L) is the same resolved state the host replay computes:
    min-hooking and the (max core, min id) winner rule are confluent, so the
    result depends only on the link multiset, not on slot order.  Progress
    argument (DESIGN.md §5): unions are bounded by n_r - 1 and every
    surviving successor strictly lowers core[target], so max_gens = O(n_r)
    generations suffice; the cap is a lowering bound, never binding.
    """
    n_r = parent.shape[0]
    K = la.shape[0]
    W = K + n_r
    node = jnp.arange(n_r, dtype=INT)
    idx = jnp.arange(W, dtype=INT)
    zpad = jnp.zeros((n_r,), INT)
    wa = jnp.concatenate([la, zpad])
    wb = jnp.concatenate([lb, zpad])
    wv = jnp.concatenate([lvalid, jnp.zeros((n_r,), bool)])

    def cond(st):
        _, _, _, _, wv, gen = st
        return jnp.any(wv) & (gen < max_gens)

    def body(st):
        parent, L, wa, wb, wv, gen = st
        # resolve (parent is fully resolved: one gather) and orient
        a = parent[jnp.clip(wa, 0, n_r - 1)]
        b = parent[jnp.clip(wb, 0, n_r - 1)]
        swap = core[a] > core[b]
        a, b = jnp.where(swap, b, a), jnp.where(swap, a, b)
        wv = wv & (a != b)
        eq = wv & (core[a] == core[b])
        # -- same-core union: min-hooking seeded by the current forest
        parent = uf_union_edges(parent, jnp.where(eq, a, 0),
                                jnp.where(eq, b, 0))
        # -- losers (roots absorbed just now) hand their L to the new root
        lost = (L >= 0) & (parent != node)
        Lc = jnp.where(lost, -1, L)
        # -- lower-core links install into L by (max core, min id)
        lt = wv & ~eq
        a = parent[a]  # roots may have moved in the union step
        b = parent[b]
        tgt = jnp.where(lt, b, n_r)          # slot n_r = dummy row
        cv = jnp.where(lt, a, 0)
        Lval = jnp.clip(Lc, 0, n_r - 1)
        Lhas = Lc >= 0
        best_core = (jnp.full((n_r + 1,), -1, INT)
                     .at[tgt].max(jnp.where(lt, core[cv], -1))
                     .at[jnp.where(Lhas, node, n_r)]
                     .max(jnp.where(Lhas, core[Lval], -1)))
        is_best = lt & (core[cv] == best_core[tgt])
        old_best = Lhas & (core[Lval] == best_core[:n_r])
        best_id = (jnp.full((n_r + 1,), BIG, INT)
                   .at[jnp.where(is_best, tgt, n_r)]
                   .min(jnp.where(is_best, cv, BIG))
                   .at[jnp.where(old_best, node, n_r)]
                   .min(jnp.where(old_best, Lval, BIG)))
        newL = jnp.where(best_id[:n_r] < BIG, best_id[:n_r], Lc)
        # -- successors: losing candidates re-link against their winner
        w_t = best_id[tgt]
        is_win = lt & (cv == w_t)
        rep = (jnp.full((n_r + 1,), W, INT)
               .at[jnp.where(is_win, tgt, n_r)]
               .min(jnp.where(is_win, idx, W)))
        host = is_win & (idx == rep[tgt])
        succ_a = jnp.where(host, Lc[jnp.clip(tgt, 0, n_r - 1)], cv)
        succ_v = lt & (succ_a >= 0) & (succ_a != w_t)
        na = jnp.where(succ_v, succ_a, 0)
        nb = jnp.where(succ_v, w_t, 0)
        # -- handoff slots: node j's slot is K + j (free until j loses)
        wa = jnp.concatenate([na[:K], jnp.where(lost, L, na[K:])])
        wb = jnp.concatenate([nb[:K], jnp.where(lost, parent, nb[K:])])
        wv = jnp.concatenate([succ_v[:K], succ_v[K:] | lost])
        return parent, newL, wa, wb, wv, gen + 1

    parent, L, _, _, _, _ = jax.lax.while_loop(
        cond, body, (parent, L, wa, wb, wv, jnp.zeros((), INT)))
    return parent, L


# ---------------------------------------------------------------------------
# Restartable local convergence (DESIGN.md §10): the h-operator Jacobi sweep
# the streaming update path runs over an affected subproblem.  Unlike the
# peel (which starts from scratch), these entries start from a caller-
# provided value state and iterate DOWNWARD to the largest fixpoint below
# it — which equals the exact core values whenever the seed dominates them
# pointwise and the frozen boundary carries its true values (the local
# h-index characterization of Sarıyüce–Seshadhri–Pınar, arXiv 1704.00386).
# ---------------------------------------------------------------------------

def h_index_rows(vals: jnp.ndarray) -> jnp.ndarray:
    """Row-wise h-index: the largest h with >= h entries >= h.

    Negative entries are padding sentinels and never count (they cannot
    satisfy ``>= h`` for any h >= 1)."""
    d = vals.shape[1]
    if d == 0:
        return jnp.zeros((vals.shape[0],), INT)
    desc = -jnp.sort(-vals, axis=1)
    ks = jnp.arange(1, d + 1, dtype=INT)[None, :]
    return jnp.max(jnp.where(desc >= ks, ks, 0), axis=1)


@jax.jit
def local_converge(inc_sub, gather_idx, vals0, frozen, max_sweeps):
    """Restartable-from-state h-operator iteration over a padded subproblem.

    One Jacobi sweep computes, for every r-clique i of the subproblem,
    Theta(f)[i] = h-index over { min_{j in S, j != i} f[j] : S an incident
    s-clique }, then applies f <- min(f, Theta(f)) on the non-frozen
    entries; the loop runs until a sweep changes nothing.  Theta is
    monotone, so the iteration converges to the largest fixpoint below the
    seed (Tarski) — the exact core values when the seed dominates them and
    the frozen ring carries its true values (DESIGN.md §10).

    inc_sub:    (rows, C) member indices into the subproblem's r-clique
                space; fully -1 rows are padding.
    gather_idx: (m, d) flat indices into the (rows * C) incidence slots
                owned by each r-clique; ``rows * C`` is the sentinel slot
                (reads -1, which the h-index ignores).
    vals0:      (m,) seed values; frozen entries are boundary state.
    max_sweeps: traced scalar safety cap (each productive sweep lowers the
                integer total by >= 1, so sum(seed) + 2 always suffices).

    Shapes are the jit key: the streaming path pads (rows, m, d) to pow2
    buckets so a stream of updates reuses one executable per shape class.
    Returns (vals, sweeps).
    """
    m = vals0.shape[0]
    n_slots = inc_sub.shape[0] * inc_sub.shape[1]
    colv = jnp.arange(inc_sub.shape[1], dtype=INT)[None, :]

    def theta(vals):
        va = jnp.where(inc_sub >= 0, vals[jnp.clip(inc_sub, 0, m - 1)], BIG)
        m1 = jnp.min(va, axis=1)
        am = jnp.argmin(va, axis=1).astype(INT)
        m2 = jnp.min(jnp.where(colv == am[:, None], BIG, va), axis=1)
        # min over the OTHER members: the unique argmin column sees the
        # second-smallest, every other column sees the row minimum
        excl = jnp.where(colv == am[:, None], m2[:, None], m1[:, None])
        rv = jnp.where(inc_sub >= 0, excl, -1).reshape(-1)
        rv = jnp.concatenate([rv, jnp.full((1,), -1, INT)])
        cand = rv[jnp.clip(gather_idx, 0, n_slots)]
        return h_index_rows(cand)

    def cond(st):
        _, done, sweeps = st
        return (~done) & (sweeps < max_sweeps)

    def body(st):
        vals, _, sweeps = st
        new = jnp.where(frozen, vals, jnp.minimum(vals, theta(vals)))
        return new, jnp.all(new == vals), sweeps + 1

    vals, _, sweeps = jax.lax.while_loop(
        cond, body, (vals0, jnp.zeros((), bool), jnp.zeros((), INT)))
    return vals, sweeps


def peel_round(inc_rid, deg, peeled, s_alive, core, order_round, sched,
               rounds, schedule: PeelSchedule, *,
               reduce_delta: Optional[Callable] = None, resid=None,
               scatter: Optional[Callable] = None,
               fused_round: Optional[Callable] = None):
    """THE peel-round body — every backend runs exactly this.

    inc_rid: (n_s_local, C) member r-clique ids (-1 rows = ghost padding);
    deg/peeled/core/order_round: (n_r,) replicated r-clique state; s_alive:
    (n_s_local,) local s-clique liveness; sched: schedule carry; rounds: the
    round counter recorded into the trace.

    reduce_delta(delta, resid) -> (delta, resid) is the distributed
    all-reduce hook (identity when None); scatter(dead_now) -> (n_r,) delta
    overrides the decrement implementation (Pallas scatter path).  The
    round's peeled set a_mask is returned so the fused hierarchy path can
    generate its links without recomputing the bucket.

    fused_round(deg, peeled, core, order, level, rounds) -> (deg, peeled,
    core, order) replaces the ENTIRE select + gather + decrement chain
    (the Pallas round megakernel, or the r1s2 vertex-peel fast lane); the
    schedule advance and dmin reduction stay here, s_alive passes through
    untouched (the megakernel derives liveness from ``peeled``, DESIGN.md
    §9), and a_mask is recovered from the peeled delta.
    """
    n_r = deg.shape[0]
    live_deg = jnp.where(peeled, BIG, deg)
    dmin = jnp.min(live_deg)
    sched, level = schedule.next_level(sched, dmin)
    if fused_round is not None:
        deg, peeled_new, core, order_round = fused_round(
            deg, peeled, core, order_round, level, rounds)
        a_mask = peeled_new & ~peeled
        return (deg, peeled_new, s_alive, core, order_round, sched, resid,
                a_mask)
    a_mask = (~peeled) & (deg <= level)
    core = jnp.where(a_mask, level, core)
    order_round = jnp.where(a_mask, rounds, order_round)
    peeled = peeled | a_mask
    member_peeled = peeled[jnp.clip(inc_rid, 0, n_r - 1)] | (inc_rid < 0)
    dead_now = jnp.any(member_peeled, axis=1) & s_alive
    s_alive = s_alive & ~dead_now
    if scatter is None:
        delta = scatter_decrement(inc_rid, dead_now, n_r)
    else:
        delta = scatter(dead_now)
    if reduce_delta is not None:
        delta, resid = reduce_delta(delta, resid)
    # peeled cliques keep deg frozen (their core is already assigned)
    deg = jnp.where(peeled, deg, deg - delta)
    return deg, peeled, s_alive, core, order_round, sched, resid, a_mask


def run_peel_engine(inc_rid, deg0, schedule: PeelSchedule, *,
                    max_rounds: int,
                    reduce_delta: Optional[Callable] = None,
                    resid0=None, alive0=None,
                    scatter: Optional[Callable] = None,
                    fused_round: Optional[Callable] = None,
                    hierarchy: bool = False, link0=None,
                    gather_links: Optional[Callable] = None,
                    peeled0=None):
    """Drive ``peel_round`` to a fixpoint under one ``lax.while_loop``.

    Returns (core, order_round, rounds): raw bucket values per r-clique, the
    on-device peel trace, and the round count.  Every round peels at least
    one clique (the schedule guarantees level >= dmin), so the loop runs at
    most n_r rounds; max_rounds is a static safety cap for lowering.

    hierarchy=True additionally threads the fused ANH-EL state through the
    carry and appends (parent, L) — the resolved same-core join forest — to
    the return: one compiled call yields coreness AND hierarchy.  link0
    overrides the initial (parent, L, last_peeled) triple (the distributed
    backend passes device-varying-marked arrays); gather_links(la, lb,
    lvalid) all-gathers each round's locally generated links so the
    replicated link state sees the global multiset.

    peeled0 marks r-cliques as already peeled before round 0 — the ghost
    entries of a shape-bucketed padded problem (``core.session``).  They
    never enter a peel bucket, never drag the schedule minimum (live
    degree is masked to BIG), emit no links and keep core/order at -1, so
    the real prefix of every output is bit-identical to the unpadded run.
    """
    n_r = deg0.shape[0]
    core0 = jnp.full((n_r,), -1, INT)
    order0 = jnp.full((n_r,), -1, INT)
    if n_r == 0:
        if hierarchy:
            empty = jnp.zeros((0,), INT)
            return core0, order0, jnp.zeros((), INT), empty, empty
        return core0, order0, jnp.zeros((), INT)
    peeled0 = jnp.zeros((n_r,), bool) if peeled0 is None else peeled0
    if alive0 is None:
        alive0 = jnp.ones((inc_rid.shape[0],), bool)
    if resid0 is None:
        resid0 = jnp.zeros((1,), INT)
    if hierarchy and link0 is None:
        link0 = (jnp.arange(n_r, dtype=INT), jnp.full((n_r,), -1, INT),
                 jnp.full((inc_rid.shape[0],), -1, INT))
    if not hierarchy:
        link0 = ()
    sched0 = schedule.init_carry()
    rounds0 = jnp.zeros((), INT)
    # every generation consumes one of three finite budgets — a union
    # (≤ n_r - 1 total), a handoff re-entry (≤ 1 per node), or a relink
    # whose target core strictly drops (≤ n_r distinct values per chain) —
    # so 3·n_r generations always suffice; the cap is a static lowering
    # bound for the while_loop, never binding
    max_gens = 3 * n_r + 4

    def cond(carry):
        peeled, rounds = carry[1], carry[6]
        return (~jnp.all(peeled)) & (rounds < max_rounds)

    def body(carry):
        deg, peeled, alive, core, order, sched, rounds, resid = carry[:8]
        deg, peeled, alive, core, order, sched, resid, a_mask = peel_round(
            inc_rid, deg, peeled, alive, core, order, sched, rounds,
            schedule, reduce_delta=reduce_delta, resid=resid,
            scatter=scatter, fused_round=fused_round)
        link = carry[8:]
        # no s-cliques -> no links ever; also keeps all_gather away from
        # zero-size operands (XLA rejects an empty all_gather dim)
        if hierarchy and inc_rid.shape[0] > 0:
            parent, L, last = link
            la, lb, lv, last = round_links(inc_rid, a_mask, last)
            if gather_links is not None:
                la, lb, lv = gather_links(la, lb, lv)
            parent, L = link_fixpoint(parent, L, core, la, lb, lv,
                                      max_gens=max_gens)
            link = (parent, L, last)
        return (deg, peeled, alive, core, order, sched, rounds + 1,
                resid) + link

    carry = (deg0, peeled0, alive0, core0, order0, sched0, rounds0,
             resid0) + tuple(link0)
    out = jax.lax.while_loop(cond, body, carry)
    core, order, rounds = out[3], out[4], out[6]
    if hierarchy:
        return core, order, rounds, out[8], out[9]
    return core, order, rounds


# ---------------------------------------------------------------------------
# Single-device dense backend: jitted entry + Pallas scatter plan
# ---------------------------------------------------------------------------

# Plan-memory ceiling for the round megakernel: the per-edge member matrix
# is E * C int32 (each CSR edge carries its s-clique's full member row so
# the in-kernel dead test needs no second indirection).  Past this the
# scatter-only Pallas path (plan = 2 * E int32) takes over — fallback rule
# #2 of DESIGN.md §9.
MEGAKERNEL_PLAN_BUDGET_BYTES = 1 << 29


@partial(jax.jit, static_argnames=("schedule", "max_rounds", "spec",
                                   "hierarchy", "fused"))
def _dense_engine(inc_rid, deg0, plan_a, plan_b, peeled0, *,
                  schedule: PeelSchedule, max_rounds: int,
                  spec: Optional[ScatterSpec], hierarchy: bool = False,
                  fused: bool = False):
    """The jitted dense entry.  spec=None: pure-XLA round body.  spec set
    with fused=True: (plan_a, plan_b) = (ids, members) of the round
    megakernel — one Pallas launch replaces the whole select + gather +
    decrement chain.  spec set with fused=False: (plan_a, plan_b) =
    (rids, sids) of the scatter-only Pallas path (the decrement alone)."""
    n_r = deg0.shape[0]
    scatter = None
    fused_round = None
    if spec is not None and fused:
        ids, members = plan_a, plan_b
        # loop-invariant per-block chunk windows: computed once out here,
        # closed over by every round's kernel launch
        c0, nch = chunk_windows(ids, spec.n_seg_pad, spec.block_n,
                                spec.chunk_e, spec.max_chunks)
        pad = spec.n_seg_pad - n_r

        def fused_round(deg, peeled, core, order, level, rnd):
            degp = jnp.concatenate([deg, jnp.zeros((pad,), INT)])
            peeledp = jnp.concatenate(
                [peeled.astype(INT), jnp.ones((pad,), INT)])
            corep = jnp.concatenate([core, jnp.full((pad,), -1, INT)])
            orderp = jnp.concatenate([order, jnp.full((pad,), -1, INT)])
            d, p, c, o = fused_peel_round(
                ids, members, degp, peeledp, corep, orderp, level, rnd,
                c0, nch, block_n=spec.block_n, chunk_e=spec.chunk_e,
                max_chunks=spec.max_chunks, interpret=spec.interpret)
            return d[:n_r], p[:n_r] > 0, c[:n_r], o[:n_r]
    elif spec is not None:
        plan_rids, plan_sids = plan_a, plan_b

        def scatter(dead_now):
            data = dead_now[plan_sids].astype(INT)[:, None]
            out = segment_sum_sorted(data, plan_rids, spec.n_seg_pad,
                                     block_n=spec.block_n,
                                     chunk_e=spec.chunk_e,
                                     max_chunks=spec.max_chunks,
                                     interpret=spec.interpret)
            return out[:n_r, 0]
    return run_peel_engine(inc_rid, deg0, schedule, max_rounds=max_rounds,
                           scatter=scatter, fused_round=fused_round,
                           hierarchy=hierarchy, peeled0=peeled0)


def _scatter_plan(problem: NucleusProblem, block_n: int, chunk_e: int,
                  interpret: bool):
    """CSR edge arrays (rid-sorted) padded for the Pallas segment sum.

    Edge k of the flat CSR is (rid=plan_rids[k], sid=plan_sids[k]) with
    plan_rids ascending — exactly what ``segment_sum_sorted`` wants; the
    per-round data vector is just ``dead_now[plan_sids]``.  Built once per
    (problem, kernel tiling) and memoized on the problem: the O(E) host
    expansion + device upload must not recur on every coreness call.
    """
    key = (block_n, chunk_e, interpret)
    cache = getattr(problem, "_scatter_plans", None)
    if cache is None:
        cache = {}
        problem._scatter_plans = cache
    if key in cache:
        return cache[key]
    counts = np.diff(np.asarray(problem.mem_offsets))
    rids = np.repeat(np.arange(problem.n_r, dtype=np.int32), counts)
    rids_pad, n_seg_pad, max_chunks = sorted_ids_plan(
        rids, problem.n_r, block_n=block_n, chunk_e=chunk_e)
    sids_pad = np.zeros(rids_pad.shape[0], np.int32)
    sids_pad[:rids.shape[0]] = np.asarray(problem.mem_sids, np.int32)
    spec = ScatterSpec(block_n=block_n, chunk_e=chunk_e,
                       max_chunks=max_chunks, n_seg_pad=n_seg_pad,
                       interpret=interpret)
    cache[key] = (jnp.asarray(rids_pad), jnp.asarray(sids_pad), spec)
    return cache[key]


def _plan_arrays(problem: NucleusProblem):
    """(rids, members) of the rid-sorted CSR edge plan, eager numpy."""
    counts = np.diff(np.asarray(problem.mem_offsets))
    rids = np.repeat(np.arange(problem.n_r, dtype=np.int32), counts)
    members = np.asarray(problem.inc_rid)[np.asarray(problem.mem_sids,
                                                     np.int64)]
    return rids, members


def _round_plan(problem: NucleusProblem, block_n: int, chunk_e: int,
                interpret: bool, *, e_pad: Optional[int] = None,
                n_r_pad: Optional[int] = None,
                max_chunks: Optional[int] = None,
                pow2_chunks: bool = False):
    """Megakernel plan: (ids, members, spec), memoized on the problem.

    Edge k of the flat CSR is rid ``ids[k]`` inside the s-clique whose full
    member row is ``members[k]`` — everything the fused dead test needs,
    gathered once at plan-build time.  The optional pad overrides let
    ``core.session`` shape the plan to its pow2 buckets so same-bucket
    problems share one executable; ``pow2_chunks`` additionally rounds the
    (data-dependent) per-block chunk-span bound up to a power of two
    (floor 8, capped at the total chunk count) so it stops fragmenting the
    bucket's jit key.
    """
    key = ("round", block_n, chunk_e, interpret, e_pad, n_r_pad, max_chunks,
           pow2_chunks)
    cache = getattr(problem, "_scatter_plans", None)
    if cache is None:
        cache = {}
        problem._scatter_plans = cache
    if key in cache:
        return cache[key]
    rids, members = _plan_arrays(problem)
    ids_pad, members_pad, n_r_pad, max_chunks = peel_round_plan(
        rids, members, problem.n_r, block_n=block_n, chunk_e=chunk_e,
        e_pad=e_pad, n_r_pad=n_r_pad, max_chunks=max_chunks)
    if pow2_chunks:
        mc = max(max_chunks, 8)
        mc = 1 << (mc - 1).bit_length()
        max_chunks = min(mc, ids_pad.shape[0] // chunk_e)
        max_chunks = max(max_chunks, 1)
    spec = ScatterSpec(block_n=block_n, chunk_e=chunk_e,
                       max_chunks=max_chunks, n_seg_pad=n_r_pad,
                       interpret=interpret)
    cache[key] = (jnp.asarray(ids_pad), jnp.asarray(members_pad), spec)
    return cache[key]


def dense_coreness(problem: NucleusProblem, schedule: PeelSchedule, *,
                   use_pallas: Optional[bool] = None,
                   max_rounds: Optional[int] = None,
                   block_n: int = DEFAULT_BLOCK_N,
                   chunk_e: int = DEFAULT_CHUNK_E,
                   interpret: Optional[bool] = None,
                   hierarchy: bool = False,
                   peeled0=None,
                   plan=None,
                   fused_kernel: Optional[bool] = None):
    """One jitted call: (core_raw, order_round, rounds) for the whole peel.

    use_pallas=None resolves through ``pallas_by_default()`` — the planner
    profile's measured verdict when one covers this device, else Pallas on
    TPU (Pallas interpret mode is a correctness oracle, not a fast path).
    Raw bucket values are returned — approx clipping is the caller's job
    so the trace keeps the values that drove LINK equality.

    With Pallas on, the round MEGAKERNEL (``kernels.peel_round``: select +
    dead-s-clique gather + segment decrement in one launch) is the default
    round body; the scatter-only Pallas path remains as the fallback when
    the per-edge member plan would exceed MEGAKERNEL_PLAN_BUDGET_BYTES
    (fused_kernel=True/False forces the choice; DESIGN.md §9 has the full
    fallback ladder).  ``plan=(ids, members, spec)`` injects a prebuilt
    megakernel plan — ``core.session`` passes its pow2-bucketed plan so
    warm calls share one executable.

    hierarchy=True fuses the ANH-EL link fixpoint into the same compiled
    call and appends the join forest (parent, L) to the return tuple.

    peeled0 pre-peels ghost r-cliques of a shape-bucketed padded problem
    (``core.session``); it is always materialized to an array before the
    jit call so the executable cache keys only on shapes + statics.
    """
    if use_pallas is None:
        use_pallas = pallas_by_default()
    if max_rounds is None:
        max_rounds = problem.n_r + 2
    dummy = jnp.zeros((0,), INT)
    plan_a, plan_b, spec = dummy, dummy, None
    fused = False
    if use_pallas and problem.n_s > 0:
        if interpret is None:
            interpret = jax.default_backend() == "cpu"
        if plan is not None:
            plan_a, plan_b, spec = plan
            fused = True
        else:
            plan_bytes = 4 * problem.n_s * problem.n_sub ** 2
            if fused_kernel is None:
                fused_kernel = plan_bytes <= MEGAKERNEL_PLAN_BUDGET_BYTES
            if fused_kernel:
                plan_a, plan_b, spec = _round_plan(problem, block_n,
                                                   chunk_e, interpret)
                fused = True
            else:
                plan_a, plan_b, spec = _scatter_plan(problem, block_n,
                                                     chunk_e, interpret)
    if peeled0 is None:
        peeled0 = jnp.zeros((problem.deg0.shape[0],), bool)
    return _dense_engine(problem.inc_rid, problem.deg0, plan_a, plan_b,
                         peeled0, schedule=schedule, max_rounds=max_rounds,
                         spec=spec, hierarchy=hierarchy, fused=fused)
