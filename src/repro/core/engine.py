"""The unified peel engine: ONE fixed-shape peel-round body for every backend.

``peel_round`` is the single implementation of a peel round (DESIGN.md
§"Engine"); ``run_peel_engine`` drives it with a ``lax.while_loop`` so the
whole peel compiles to one XLA computation — no per-round host sync, no
eager dispatch.  The same body serves:

  * the single-device dense backend (``peel.exact_coreness(backend="dense")``
    delegates here, one jitted call per problem shape), and
  * the ``shard_map`` distributed backend (``repro.core.distributed`` wraps
    the body with a psum ``reduce_delta`` hook; s-clique slabs are local,
    r-clique state replicated).

The loop carry records the **peel trace** on device: ``order_round[i]`` is
the round at which r-clique i peeled and ``core[i]`` the (raw, unclipped)
bucket value assigned to it.  The trace is information-equivalent to the old
per-round ``collect_links`` host callback (A_t = {i : order_round[i] == t},
peel values are the callback's core snapshot), so ANH-EL hierarchy
construction replays it post-hoc (``interleaved.replay_trace``) and coreness
stays a single compiled call.

The scatter-decrement hot path (count destroyed incidence per r-clique) has
two implementations: XLA ``.at[].add`` (the interpret/oracle fallback, and
the default off-TPU) and a Pallas sorted-segment-sum over the CSR edge array
(``kernels.segment_sum``), whose one-hot contraction runs on the MXU instead
of serialized scatter-adds.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from math import comb
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..graph import INT
from ..kernels.segment_sum import (DEFAULT_BLOCK_N, DEFAULT_CHUNK_E,
                                   segment_sum_sorted, sorted_ids_plan)
from .incidence import NucleusProblem
from .schedule import PeelSchedule

BIG = np.iinfo(np.int32).max


def make_schedule(problem: NucleusProblem, kind: str,
                  delta: float = 0.1) -> PeelSchedule:
    return PeelSchedule(kind=kind, s_choose_r=comb(problem.s, problem.r),
                        delta=delta, n=problem.g.n)


@dataclasses.dataclass(frozen=True)
class ScatterSpec:
    """Static (hashable) config of the Pallas scatter-decrement path."""

    block_n: int
    chunk_e: int
    max_chunks: int
    n_seg_pad: int
    interpret: bool


def scatter_decrement(inc_rid: jnp.ndarray, dead_now: jnp.ndarray,
                      n_r: int) -> jnp.ndarray:
    """delta[r] = # of s-cliques dying this round that contain r.

    XLA scatter-add formulation — the oracle the Pallas path is checked
    against, and the default backend off-TPU.  Rows with negative ids
    (distributed ghost padding) never contribute.
    """
    members = jnp.clip(inc_rid, 0, n_r - 1).reshape(-1)
    valid = ((inc_rid >= 0) & dead_now[:, None]).reshape(-1)
    return jnp.zeros((n_r,), INT).at[members].add(valid.astype(INT))


def peel_round(inc_rid, deg, peeled, s_alive, core, order_round, sched,
               rounds, schedule: PeelSchedule, *,
               reduce_delta: Optional[Callable] = None, resid=None,
               scatter: Optional[Callable] = None):
    """THE peel-round body — every backend runs exactly this.

    inc_rid: (n_s_local, C) member r-clique ids (-1 rows = ghost padding);
    deg/peeled/core/order_round: (n_r,) replicated r-clique state; s_alive:
    (n_s_local,) local s-clique liveness; sched: schedule carry; rounds: the
    round counter recorded into the trace.

    reduce_delta(delta, resid) -> (delta, resid) is the distributed
    all-reduce hook (identity when None); scatter(dead_now) -> (n_r,) delta
    overrides the decrement implementation (Pallas path).
    """
    n_r = deg.shape[0]
    live_deg = jnp.where(peeled, BIG, deg)
    dmin = jnp.min(live_deg)
    sched, level = schedule.next_level(sched, dmin)
    a_mask = (~peeled) & (deg <= level)
    core = jnp.where(a_mask, level, core)
    order_round = jnp.where(a_mask, rounds, order_round)
    peeled = peeled | a_mask
    member_peeled = peeled[jnp.clip(inc_rid, 0, n_r - 1)] | (inc_rid < 0)
    dead_now = jnp.any(member_peeled, axis=1) & s_alive
    s_alive = s_alive & ~dead_now
    if scatter is None:
        delta = scatter_decrement(inc_rid, dead_now, n_r)
    else:
        delta = scatter(dead_now)
    if reduce_delta is not None:
        delta, resid = reduce_delta(delta, resid)
    # peeled cliques keep deg frozen (their core is already assigned)
    deg = jnp.where(peeled, deg, deg - delta)
    return deg, peeled, s_alive, core, order_round, sched, resid


def run_peel_engine(inc_rid, deg0, schedule: PeelSchedule, *,
                    max_rounds: int,
                    reduce_delta: Optional[Callable] = None,
                    resid0=None, alive0=None,
                    scatter: Optional[Callable] = None):
    """Drive ``peel_round`` to a fixpoint under one ``lax.while_loop``.

    Returns (core, order_round, rounds): raw bucket values per r-clique, the
    on-device peel trace, and the round count.  Every round peels at least
    one clique (the schedule guarantees level >= dmin), so the loop runs at
    most n_r rounds; max_rounds is a static safety cap for lowering.
    """
    n_r = deg0.shape[0]
    core0 = jnp.full((n_r,), -1, INT)
    order0 = jnp.full((n_r,), -1, INT)
    if n_r == 0:
        return core0, order0, jnp.zeros((), INT)
    peeled0 = jnp.zeros((n_r,), bool)
    if alive0 is None:
        alive0 = jnp.ones((inc_rid.shape[0],), bool)
    if resid0 is None:
        resid0 = jnp.zeros((1,), INT)
    sched0 = schedule.init_carry()
    rounds0 = jnp.zeros((), INT)

    def cond(carry):
        _, peeled, _, _, _, _, rounds, _ = carry
        return (~jnp.all(peeled)) & (rounds < max_rounds)

    def body(carry):
        deg, peeled, alive, core, order, sched, rounds, resid = carry
        deg, peeled, alive, core, order, sched, resid = peel_round(
            inc_rid, deg, peeled, alive, core, order, sched, rounds,
            schedule, reduce_delta=reduce_delta, resid=resid, scatter=scatter)
        return deg, peeled, alive, core, order, sched, rounds + 1, resid

    carry = (deg0, peeled0, alive0, core0, order0, sched0, rounds0, resid0)
    _, _, _, core, order, _, rounds, _ = jax.lax.while_loop(cond, body, carry)
    return core, order, rounds


# ---------------------------------------------------------------------------
# Single-device dense backend: jitted entry + Pallas scatter plan
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("schedule", "max_rounds", "spec"))
def _dense_engine(inc_rid, deg0, plan_rids, plan_sids, *,
                  schedule: PeelSchedule, max_rounds: int,
                  spec: Optional[ScatterSpec]):
    n_r = deg0.shape[0]
    scatter = None
    if spec is not None:
        def scatter(dead_now):
            data = dead_now[plan_sids].astype(INT)[:, None]
            out = segment_sum_sorted(data, plan_rids, spec.n_seg_pad,
                                     block_n=spec.block_n,
                                     chunk_e=spec.chunk_e,
                                     max_chunks=spec.max_chunks,
                                     interpret=spec.interpret)
            return out[:n_r, 0]
    return run_peel_engine(inc_rid, deg0, schedule, max_rounds=max_rounds,
                           scatter=scatter)


def _scatter_plan(problem: NucleusProblem, block_n: int, chunk_e: int,
                  interpret: bool):
    """CSR edge arrays (rid-sorted) padded for the Pallas segment sum.

    Edge k of the flat CSR is (rid=plan_rids[k], sid=plan_sids[k]) with
    plan_rids ascending — exactly what ``segment_sum_sorted`` wants; the
    per-round data vector is just ``dead_now[plan_sids]``.  Built once per
    (problem, kernel tiling) and memoized on the problem: the O(E) host
    expansion + device upload must not recur on every coreness call.
    """
    key = (block_n, chunk_e, interpret)
    cache = getattr(problem, "_scatter_plans", None)
    if cache is None:
        cache = {}
        problem._scatter_plans = cache
    if key in cache:
        return cache[key]
    counts = np.diff(np.asarray(problem.mem_offsets))
    rids = np.repeat(np.arange(problem.n_r, dtype=np.int32), counts)
    rids_pad, n_seg_pad, max_chunks = sorted_ids_plan(
        rids, problem.n_r, block_n=block_n, chunk_e=chunk_e)
    sids_pad = np.zeros(rids_pad.shape[0], np.int32)
    sids_pad[:rids.shape[0]] = np.asarray(problem.mem_sids, np.int32)
    spec = ScatterSpec(block_n=block_n, chunk_e=chunk_e,
                       max_chunks=max_chunks, n_seg_pad=n_seg_pad,
                       interpret=interpret)
    cache[key] = (jnp.asarray(rids_pad), jnp.asarray(sids_pad), spec)
    return cache[key]


def dense_coreness(problem: NucleusProblem, schedule: PeelSchedule, *,
                   use_pallas: Optional[bool] = None,
                   max_rounds: Optional[int] = None,
                   block_n: int = DEFAULT_BLOCK_N,
                   chunk_e: int = DEFAULT_CHUNK_E,
                   interpret: Optional[bool] = None):
    """One jitted call: (core_raw, order_round, rounds) for the whole peel.

    use_pallas=None picks the Pallas scatter on TPU and the XLA scatter-add
    elsewhere (Pallas interpret mode is a correctness oracle, not a fast
    path).  Raw bucket values are returned — approx clipping is the
    caller's job so the trace keeps the values that drove LINK equality.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if max_rounds is None:
        max_rounds = problem.n_r + 2
    dummy = jnp.zeros((0,), INT)
    if use_pallas and problem.n_s > 0:
        if interpret is None:
            interpret = jax.default_backend() == "cpu"
        rids, sids, spec = _scatter_plan(problem, block_n, chunk_e, interpret)
    else:
        rids, sids, spec = dummy, dummy, None
    core, order, rounds = _dense_engine(problem.inc_rid, problem.deg0,
                                        rids, sids, schedule=schedule,
                                        max_rounds=max_rounds, spec=spec)
    return core, order, rounds
