"""Warm ``Session``: decompose many graphs without recompiling per shape.

``decompose()`` is one-shot: every new problem shape keys a fresh XLA
compile of the dense engine (shapes + the static ``PeelSchedule`` make up
the executable cache key), so a serving process that decomposes a stream
of similar graphs pays the dominant cost — compilation — over and over.
``Session`` is the warm-pool front door:

  * **Shape buckets.**  Each problem is padded to a shape class
    (``n_r``/``n_s`` rounded up to the next power of two, floor
    ``bucket_floor``): ghost s-clique rows carry ``-1`` member ids (the
    engine's distributed padding convention — they die in round 0 and
    contribute no decrements, no links) and ghost r-cliques enter
    pre-peeled (``peeled0``), so they never join a bucket, never drag the
    schedule minimum, and keep core/order at -1.  The real prefix of every
    output is bit-identical to the unpadded run (tests pin this
    array-for-array against ``decompose()``).
  * **Schedule canonicalization.**  The static ``PeelSchedule`` carries
    the vertex count ``n``, which differs per graph and would defeat the
    bucket.  Exact schedules never read ``n`` (pinned to 1); approximate
    schedules read it only through ``cap()``, so ``n`` is replaced by the
    smallest vertex count with the same cap — same compiled behaviour,
    same results, one executable per (delta, C, cap) class.
  * **Warm executables.**  With shapes and statics canonicalized,
    same-bucket problems hit the engine's jitted-callable cache instead of
    recompiling; ``Session.stats`` records the bucket hit pattern, and the
    ``session`` bench lane + EXPERIMENTS.md record the cold/warm speedup.

Pallas configs ride the warm path too: the round-megakernel plan is
padded to the same pow2 buckets (edge axis bucketed at floor ``chunk_e``,
chunk-span bound pow2-rounded), so ``use_pallas=True`` — or a profile
that defaults it on — reuses one executable per shape class instead of
recompiling per problem.  Sharded configs ride it as well: the s-clique
axis is padded to SHARD-MULTIPLE shape classes (``shard_bucket_size`` —
pow2 alone slices raggedly when the mesh size is not a power of two;
DESIGN.md §13) with ghost -1 rows, ghost r-cliques enter pre-peeled, and
same-bucket problems reuse one ``shard_map`` executable through
``distributed._jitted_decomposition``.  Configs that resolve to any other
non-dense backend, or whose megakernel plan would exceed its memory
budget, fall back to the planned cold path (same ``Plan`` provenance,
counted in ``stats["fallback"]``): correct, just not bucket-warmed.
``launch.serve --arch nucleus --warm-pool`` drives this end-to-end.
"""
from __future__ import annotations

import dataclasses
import threading
from math import comb
from typing import Any, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..graph import INT
from ..kernels.segment_sum import DEFAULT_BLOCK_N, DEFAULT_CHUNK_E
from .api import (Decomposition, NucleusConfig, execute_plan, plan_config,
                  resolve_problem)
from .engine import (MEGAKERNEL_PLAN_BUDGET_BYTES, ScatterSpec, _round_plan,
                     dense_coreness, pallas_by_default)
from .incidence import NucleusProblem
from .schedule import PeelSchedule

DEFAULT_BUCKET_FLOOR = 64
# default LRU bound on stats["buckets"]: generous for real serving mixes
# (hundreds of shape classes) while keeping a long-lived process O(1)
DEFAULT_BUCKET_CAP = 256

# the session-manifest wire format (serve.cache persists it so a restarted
# server can pre-warm the same shape buckets before taking traffic)
MANIFEST_FORMAT = "repro.session-manifest"
MANIFEST_VERSION = 1


def bucket_size(n: int, floor: int = DEFAULT_BUCKET_FLOOR) -> int:
    """Next power of two >= max(n, floor): the shape-class boundary.

    Power-of-two classes bound the padding overhead at 2x work per axis
    while collapsing the long tail of near-miss shapes onto one compiled
    executable."""
    n = max(int(n), int(floor), 1)
    return 1 << (n - 1).bit_length()


def shard_bucket_size(n: int, n_shards: int,
                      floor: int = DEFAULT_BUCKET_FLOOR) -> int:
    """Shard-aware shape class: the pow2 bucket rounded UP to a multiple
    of ``n_shards``.

    ``shard_map`` slices the s-clique axis evenly across the mesh, so a
    sharded bucket must be a shard multiple — pow2 alone is ragged
    whenever the device count is not a power of two (the PR-5 leftover;
    ``make_sharded_decomposition`` rejects ragged shapes).  For pow2
    shard counts <= the bucket this is the identity, so near-miss shapes
    still collapse onto one shard_map executable."""
    b = bucket_size(n, floor)
    n_shards = max(int(n_shards), 1)
    return -(-b // n_shards) * n_shards


def canonical_schedule(method: str, s_choose_r: int, delta: float,
                       n: int) -> PeelSchedule:
    """The behaviour-preserving schedule representative of (method, C,
    delta, n)'s equivalence class.

    Exact schedules never read ``n`` or ``delta``; approximate schedules
    read ``n`` only through ``cap()`` (the per-bucket round cap), so the
    smallest ``n`` with the same cap is substituted (binary search — cap
    is nondecreasing in n).  Results are bit-identical to the
    uncanonicalized schedule; the static jit key stops varying per graph.
    """
    if method == "exact":
        return PeelSchedule(kind="exact", s_choose_r=s_choose_r)
    mk = lambda nn: PeelSchedule(kind="approx", s_choose_r=s_choose_r,
                                 delta=delta, n=nn)
    target = mk(n).cap()
    lo, hi = 2, max(int(n), 2)
    while lo < hi:
        mid = (lo + hi) // 2
        if mk(mid).cap() >= target:
            hi = mid
        else:
            lo = mid + 1
    return mk(lo)


@dataclasses.dataclass(frozen=True)
class _Bucket:
    """One shape class: the statics + padded shapes a compiled executable
    keys on.  ``astuple`` is the hashable stats key."""

    method: str
    r: int
    s: int
    fused: bool
    n_r_pad: int
    n_s_pad: int
    schedule: PeelSchedule
    # the Pallas megakernel tiling this bucket compiles with (None = pure
    # XLA round body); the plan arrays are padded to the same pow2 buckets
    # (edge axis included) so warm members reuse the executable
    pallas: Optional[ScatterSpec] = None
    # mesh device count of a sharded bucket (0 = single-device dense);
    # its n_s_pad is a shard multiple (``shard_bucket_size``).  NEW FIELDS
    # GO AFTER THIS ONE: positional consumers (router report, manifest
    # ``_Bucket(*key)``) index the prefix.
    shards: int = 0

    def astuple(self) -> Tuple:
        return (self.method, self.r, self.s, self.fused, self.n_r_pad,
                self.n_s_pad, self.schedule, self.pallas, self.shards)


@dataclasses.dataclass(frozen=True)
class _PaddedProblem:
    """The minimal view ``dense_coreness`` reads off a problem (the mem-CSR
    and r-clique table stay on the real problem — queries never see the
    padding)."""

    inc_rid: jnp.ndarray
    deg0: jnp.ndarray
    n_r: int
    n_s: int


class Session:
    """Warm decompose-many: ``Session(config).decompose(graph)``.

    The config is fixed at construction (keyword overrides apply on top,
    like ``decompose``); every ``decompose``/``decompose_many`` call runs
    the same pipeline as the module-level ``decompose()`` — same planner,
    same validation, same ``Decomposition`` artifact — but routes dense
    peels through the shape-bucketed padded engine so same-bucket problems
    reuse one compiled executable.  ``stats`` tallies warm vs cold engine
    calls and the per-bucket hit counts.
    """

    def __init__(self, config: Optional[NucleusConfig] = None, *,
                 bucket_floor: int = DEFAULT_BUCKET_FLOOR,
                 bucket_cap: int = DEFAULT_BUCKET_CAP, **overrides):
        if config is None:
            config = NucleusConfig()
        if overrides:
            config = dataclasses.replace(config, **overrides)
        config.validate()
        self.config = config
        self.bucket_floor = int(bucket_floor)
        # bound on tracked shape classes: a long-lived serving process
        # seeing adversarial shape streams must not grow bookkeeping
        # without limit (ROADMAP's PR-5 leftover).  0 disables the cap.
        self.bucket_cap = int(bucket_cap)
        self.stats: Dict[str, Any] = {
            "decompositions": 0,   # total artifacts produced
            "warm": 0,             # padded engine calls that hit a bucket
            "cold": 0,             # padded engine calls compiling a bucket
            "fallback": 0,         # routed to plain decompose()
            "updates": 0,          # incremental update() calls served
            "stream_warm": 0,      # update stages hitting a known bucket
            "stream_cold": 0,      # update stages opening a bucket
            "evictions": 0,        # bucket entries dropped by the LRU cap
            "prewarmed": 0,        # buckets compiled ahead of traffic
            "buckets": {},         # bucket key -> call count (LRU order)
        }
        # counter + bucket-table mutations take this lock so concurrent
        # readers (a status endpoint polling while the serving worker
        # decomposes) never see torn LRU state and no increment is lost;
        # the ENGINE path stays single-writer by frontend discipline —
        # the lock protects bookkeeping, not compiled-call ordering
        self._stats_lock = threading.Lock()
        # decompose-bucket extras the manifest needs but the hashable key
        # cannot carry (the padded plan-array length of Pallas buckets)
        self._bucket_meta: Dict[Tuple, Dict[str, Any]] = {}

    # -- front door --------------------------------------------------------
    def decompose(self, graph_or_problem) -> Decomposition:
        """Same contract (and bit-identical arrays) as
        ``api.decompose(graph_or_problem, self.config)``."""
        problem, config = resolve_problem(graph_or_problem, self.config)
        config, plan = plan_config(problem, config)
        self._count("decompositions")
        # the padded path covers the compiled dense engine, XLA round body
        # AND Pallas megakernel: the megakernel plan is padded to the same
        # pow2 buckets (edge axis included), so use_pallas rides the warm
        # path too.  Only a plan that would blow the megakernel's memory
        # budget still takes the cold path (scatter-only fallback there).
        wants_pallas = bool(config.use_pallas or (
            config.use_pallas is None and pallas_by_default()))
        # gate on what the padded plan actually allocates — the member
        # matrix is (e_pad, C) int32 with the edge axis pow2-bucketed, so
        # a problem just under budget unpadded can land over it padded
        e_pad = bucket_size(problem.n_s * problem.n_sub, DEFAULT_CHUNK_E)
        plan_bytes = 4 * e_pad * problem.n_sub
        if config.backend == "sharded" and problem.n_r > 0:
            return self._decompose_padded_sharded(problem, config, plan)
        if config.backend != "dense" or problem.n_r == 0 or (
                wants_pallas and plan_bytes > MEGAKERNEL_PLAN_BUDGET_BYTES):
            self._count("fallback")
            return execute_plan(problem, config, plan)
        return self._decompose_padded(problem, config, plan,
                                      wants_pallas=wants_pallas)

    def decompose_many(self, graphs) -> List[Decomposition]:
        """Decompose a stream; same-bucket members after the first are
        warm.  Order of results matches the input order."""
        return [self.decompose(g) for g in graphs]

    def update(self, dec: Decomposition, delta) -> Decomposition:
        """Incrementally patch ``dec`` (same parity contract as
        ``Decomposition.update``) while bucketing the streaming engine's
        compiled stages.

        The local converge / link-fixpoint stages are jitted on
        pow2-padded shapes, so repeat updates against a live graph land
        in the same shape classes; their keys join ``stats['buckets']``
        (and the LRU cap) alongside the decompose buckets, tallied as
        ``stream_warm`` / ``stream_cold``."""
        self._count("updates")

        def hook(key: Tuple) -> None:
            warm = self._bucket_hit(key)
            self._count("stream_warm" if warm else "stream_cold")

        return dec.update(delta, bucket_hook=hook)

    # -- the padded dense path ---------------------------------------------
    def _bucket(self, problem: NucleusProblem, config: NucleusConfig, *,
                wants_pallas: Optional[bool] = None) -> "_Bucket":
        """The shape class ``problem`` lands in under ``config``: the
        canonical schedule plus padded shapes (everything the compiled
        executable depends on), computed once and named."""
        if wants_pallas is None:
            wants_pallas = bool(config.use_pallas or (
                config.use_pallas is None and pallas_by_default()))
        n_r_pad = bucket_size(problem.n_r, self.bucket_floor)
        pallas_spec = None
        if wants_pallas and problem.n_s > 0:
            pallas_spec = self._pallas_spec(problem, n_r_pad)
        return _Bucket(
            method=config.method, r=config.r, s=config.s,
            fused=config.hierarchy == "fused",
            n_r_pad=n_r_pad,
            n_s_pad=bucket_size(problem.n_s, self.bucket_floor),
            schedule=canonical_schedule(config.method, problem.n_sub,
                                        config.delta, problem.g.n),
            pallas=pallas_spec)

    def _pallas_plan(self, problem: NucleusProblem, n_r_pad: int):
        """The bucketed megakernel plan: CSR edge arrays padded to pow2
        shape classes (edge count included, floor ``chunk_e``) with a
        pow2-rounded chunk-span bound, so the ScatterSpec — part of the
        executable's jit key — repeats across same-bucket problems."""
        block_n, chunk_e = DEFAULT_BLOCK_N, DEFAULT_CHUNK_E
        e_real = int(problem.mem_sids.shape[0])
        e_pad = bucket_size(e_real, chunk_e)
        n_seg_pad = max(n_r_pad, block_n)
        return _round_plan(problem, block_n, chunk_e,
                           jax.default_backend() == "cpu",
                           e_pad=e_pad, n_r_pad=n_seg_pad,
                           pow2_chunks=True)

    def _pallas_spec(self, problem: NucleusProblem,
                     n_r_pad: int) -> ScatterSpec:
        """``_pallas_plan``'s ScatterSpec without the plan arrays.

        Keys must be cheap: a bucket probe that materializes the full
        padded (e_pad, C) member matrix on device just to hash a tiling
        is most of a cold plan's cost.  Every spec field is derived here
        from the mem-CSR offsets alone.  The one data-dependent field,
        the chunk-span bound, only reads the padded rid stream at chunk
        boundaries: rid(k) for k < E is the CSR row containing slot k
        (``searchsorted(offsets[1:], k, 'right')``), and every padded
        slot holds the ``n_seg_pad`` sentinel.  The c0/c1 span count and
        pow2 rounding mirror ``peel_round_plan`` / ``_round_plan`` —
        ``_decompose_padded`` asserts the twin agrees with the real plan
        whenever one is built."""
        block_n, chunk_e = DEFAULT_BLOCK_N, DEFAULT_CHUNK_E
        e_real = int(problem.mem_sids.shape[0])
        e_pad = bucket_size(e_real, chunk_e)
        n_seg_pad = max(n_r_pad, block_n)
        n_chunks = e_pad // chunk_e
        off = np.asarray(problem.mem_offsets, dtype=np.int64)

        def ids_at(k: np.ndarray) -> np.ndarray:
            rid = np.searchsorted(off[1:], k, side="right")
            return np.where(k < e_real, rid, n_seg_pad)

        k_first = np.arange(n_chunks, dtype=np.int64) * chunk_e
        chunk_first = ids_at(k_first)
        chunk_last = ids_at(k_first + chunk_e - 1)
        bounds_lo = np.arange(n_seg_pad // block_n, dtype=np.int64) * block_n
        c0 = np.searchsorted(chunk_last, bounds_lo, side="left")
        c1 = np.searchsorted(chunk_first, bounds_lo + block_n, side="left")
        need = max(int(np.max(np.maximum(c1 - c0, 0), initial=0)), 1)
        mc = max(need, 8)
        mc = 1 << (mc - 1).bit_length()
        max_chunks = max(min(mc, n_chunks), 1)
        return ScatterSpec(block_n=block_n, chunk_e=chunk_e,
                           max_chunks=max_chunks, n_seg_pad=n_seg_pad,
                           interpret=jax.default_backend() == "cpu")

    def bucket_key(self, problem: NucleusProblem,
                   config: Optional[NucleusConfig] = None) -> Tuple:
        """The hashable shape-class key (``stats['buckets']`` is indexed
        by it).  Derived from shapes + the mem-CSR offsets only — probing
        a key never builds padded plan arrays."""
        return tuple(self._bucket(problem, config or self.config).astuple())

    # -- manifest export / prewarm (the persistent warm path) --------------
    def manifest(self) -> Dict[str, Any]:
        """Serializable record of every decompose shape bucket this
        session has seen: the statics + padded shapes a compiled
        executable keys on, nothing graph-specific.

        ``serve.cache`` persists it next to jax's persistent compilation
        cache; a restarted server feeds it to ``prewarm`` so the first
        post-restart same-bucket decompose is a warm hit instead of a
        multi-second compile.  Stream-stage buckets (from ``update``) are
        excluded — they re-warm on first use and their keys are not
        shape-class records."""
        with self._stats_lock:
            items = list(self.stats["buckets"].items())
            meta = {k: dict(v) for k, v in self._bucket_meta.items()}
        entries = []
        for key, count in items:
            m = meta.get(key)
            if m is None or m.get("kind") != "decompose":
                continue
            b = _Bucket(*key)
            entries.append({
                "method": b.method, "r": b.r, "s": b.s, "fused": b.fused,
                "n_r_pad": b.n_r_pad, "n_s_pad": b.n_s_pad,
                "schedule": dataclasses.asdict(b.schedule),
                "pallas": None if b.pallas is None
                else dataclasses.asdict(b.pallas),
                "e_pad": m.get("e_pad"),
                "count": int(count)})
        return {"format": MANIFEST_FORMAT, "version": MANIFEST_VERSION,
                "config": self.config.to_dict(),
                "bucket_floor": self.bucket_floor,
                "bucket_cap": self.bucket_cap,
                "buckets": entries}

    def prewarm(self, manifest_or_buckets) -> int:
        """Compile each manifest bucket's executable before traffic.

        For every bucket record (a ``manifest()`` dict or its
        ``"buckets"`` list) an all-ghost padded problem with the bucket's
        exact shapes + statics is run through the dense engine: ghost
        s-rows (-1 ids) and pre-peeled r-cliques make the run trivially
        cheap, but the jitted computation — keyed on shapes and statics
        only — is byte-identical to a real member's, so the call either
        loads the executable from jax's persistent compilation cache
        (``serve.cache.init_persistent_cache``) or compiles and caches
        it.  The bucket is then registered warm: the first real
        same-bucket decompose counts as a warm hit and pays no compile.
        Returns the number of buckets prewarmed."""
        buckets = manifest_or_buckets
        if isinstance(buckets, dict):
            if buckets.get("format") != MANIFEST_FORMAT:
                raise ValueError(
                    f"not a session manifest: format="
                    f"{buckets.get('format')!r} (expected "
                    f"{MANIFEST_FORMAT!r}) — regenerate it with "
                    f"Session.manifest()")
            buckets = buckets["buckets"]
        done = 0
        for e in buckets:
            sched = PeelSchedule(**e["schedule"])
            spec = None if e.get("pallas") is None \
                else ScatterSpec(**e["pallas"])
            n_r_pad, n_s_pad = int(e["n_r_pad"]), int(e["n_s_pad"])
            C = comb(int(e["s"]), int(e["r"]))
            ghost = _PaddedProblem(
                inc_rid=jnp.full((n_s_pad, C), -1, INT),
                deg0=jnp.zeros((n_r_pad,), INT),
                n_r=n_r_pad, n_s=n_s_pad)
            plan = None
            meta: Dict[str, Any] = {"kind": "decompose"}
            if spec is not None:
                e_pad = int(e["e_pad"])
                meta["e_pad"] = e_pad
                # ghost plan arrays: every slot the padding sentinel —
                # the VALUES never enter the jit key, only the shapes do
                plan = (jnp.full((e_pad,), spec.n_seg_pad, INT),
                        jnp.full((e_pad, C), -1, INT), spec)
            out = dense_coreness(ghost, sched, use_pallas=spec is not None,
                                 max_rounds=n_r_pad + 2,
                                 hierarchy=bool(e["fused"]),
                                 peeled0=jnp.ones((n_r_pad,), bool),
                                 plan=plan)
            jax.block_until_ready(out)
            key = _Bucket(method=e["method"], r=int(e["r"]), s=int(e["s"]),
                          fused=bool(e["fused"]), n_r_pad=n_r_pad,
                          n_s_pad=n_s_pad, schedule=sched,
                          pallas=spec).astuple()
            with self._stats_lock:
                if key not in self.stats["buckets"]:
                    self.stats["buckets"][key] = 1
                    self._bucket_meta[key] = meta
                    self.stats["prewarmed"] += 1
            done += 1
        return done

    def _count(self, name: str, by: int = 1) -> None:
        """Lock-guarded counter bump (no lost updates under threads)."""
        with self._stats_lock:
            self.stats[name] += by

    def _bucket_hit(self, key: Tuple,
                    meta: Optional[Dict[str, Any]] = None) -> bool:
        """Count one engine call against ``key``'s bucket, LRU-style.

        ``stats['buckets']`` is insertion-ordered; a hit reinserts the
        key at the back, and opening a new bucket past ``bucket_cap``
        evicts the stalest entry (only the bookkeeping is bounded — the
        evicted executable may still sit in jax's compile cache, and a
        re-seen key simply counts cold again).  ``meta`` attaches the
        manifest extras of a decompose bucket (stream-stage keys carry
        none and stay out of the manifest).  Returns True when the
        bucket was already warm."""
        with self._stats_lock:
            buckets = self.stats["buckets"]
            seen = buckets.pop(key, 0)
            buckets[key] = seen + 1
            if meta is not None:
                self._bucket_meta[key] = meta
            if seen == 0 and self.bucket_cap \
                    and len(buckets) > self.bucket_cap:
                stale = next(iter(buckets))
                del buckets[stale]
                self._bucket_meta.pop(stale, None)
                self.stats["evictions"] += 1
            return seen > 0

    def _decompose_padded(self, problem: NucleusProblem,
                          config: NucleusConfig, plan, *,
                          wants_pallas: bool = False) -> Decomposition:
        fused = config.hierarchy == "fused"
        n_r, n_s, C = problem.n_r, problem.n_s, problem.n_sub
        bucket = self._bucket(problem, config, wants_pallas=wants_pallas)
        key = tuple(bucket.astuple())
        sched = bucket.schedule
        n_r_pad, n_s_pad = bucket.n_r_pad, bucket.n_s_pad
        meta: Dict[str, Any] = {"kind": "decompose"}
        if bucket.pallas is not None:
            # the plan-array length is part of the executable's jit key
            # but not of the hashable bucket key — record it so a
            # manifest prewarm can rebuild identically-shaped plan arrays
            meta["e_pad"] = bucket_size(int(problem.mem_sids.shape[0]),
                                        DEFAULT_CHUNK_E)
        warm = self._bucket_hit(key, meta=meta)
        self._count("warm" if warm else "cold")

        inc = jnp.concatenate(
            [problem.inc_rid, jnp.full((n_s_pad - n_s, C), -1, INT)], axis=0)
        deg0 = jnp.concatenate(
            [problem.deg0, jnp.zeros((n_r_pad - n_r,), INT)])
        peeled0 = jnp.concatenate(
            [jnp.zeros((n_r,), bool), jnp.ones((n_r_pad - n_r,), bool)])
        padded = _PaddedProblem(inc_rid=inc, deg0=deg0, n_r=n_r_pad,
                                n_s=n_s_pad)
        kernel_plan = None
        if bucket.pallas is not None:
            # plan arrays materialize only here, on the execute path; the
            # bucket key came from the shape-derived spec twin, which must
            # agree with the real plan or warm members would miss the
            # executable the bucket promised
            kernel_plan = self._pallas_plan(problem, n_r_pad)
            assert kernel_plan[2] == bucket.pallas, (
                "shape-derived ScatterSpec diverged from the real plan: "
                f"{bucket.pallas} vs {kernel_plan[2]}")
        out = dense_coreness(padded, sched,
                             use_pallas=kernel_plan is not None,
                             max_rounds=n_r_pad + 2, hierarchy=fused,
                             peeled0=peeled0, plan=kernel_plan)
        core_raw = np.asarray(out[0])[:n_r]
        order_round = np.asarray(out[1])[:n_r]
        rounds = int(out[2])
        uf_parent = uf_L = None
        if fused:
            uf_parent = np.asarray(out[3])[:n_r]
            uf_L = np.asarray(out[4])[:n_r]
        if config.method == "approx":
            # same practical tightening as peel.approx_coreness: the
            # estimate never exceeds the original s-clique-degree, while
            # peel_value keeps the raw bucket values LINK equality saw
            core = np.minimum(core_raw, np.asarray(problem.deg0))
            peel_value = core_raw
        else:
            core, peel_value = core_raw, core_raw
        return Decomposition(config, problem=problem, core=core,
                             rounds=rounds, order_round=order_round,
                             peel_value=peel_value, uf_parent=uf_parent,
                             uf_L=uf_L, plan=plan)

    # -- the padded sharded path -------------------------------------------
    def _decompose_padded_sharded(self, problem: NucleusProblem,
                                  config: NucleusConfig,
                                  plan) -> Decomposition:
        """Shape-bucketed ``shard_map`` peel: same artifact contract as the
        sharded backend's cold path, but the s-clique axis is padded to a
        SHARD-MULTIPLE shape class (``shard_bucket_size``) and ghost
        r-cliques enter pre-peeled, so near-miss shapes share one compiled
        ``shard_map`` executable (``distributed._jitted_decomposition``
        keys on the padded shapes + canonical schedule)."""
        from .distributed import sharded_decomposition_padded
        mesh = config.mesh
        if mesh is None:
            from ..launch.mesh import make_host_mesh
            mesh = make_host_mesh()
        n_dev = int(np.prod(mesh.devices.shape))
        fused = config.hierarchy == "fused"
        n_r, n_s, C = problem.n_r, problem.n_s, problem.n_sub
        bucket = _Bucket(
            method=config.method, r=config.r, s=config.s, fused=fused,
            n_r_pad=bucket_size(n_r, self.bucket_floor),
            n_s_pad=shard_bucket_size(n_s, n_dev, self.bucket_floor),
            schedule=canonical_schedule(config.method, C, config.delta,
                                        problem.g.n),
            shards=n_dev)
        n_r_pad, n_s_pad = bucket.n_r_pad, bucket.n_s_pad
        assert n_s_pad % n_dev == 0, (n_s_pad, n_dev)
        key = tuple(bucket.astuple())
        # kind "sharded" keeps these out of the manifest: prewarm rebuilds
        # dense executables only (a restarted server re-warms shard_map
        # buckets on first traffic)
        warm = self._bucket_hit(key, meta={"kind": "sharded"})
        self._count("warm" if warm else "cold")

        inc = jnp.concatenate(
            [problem.inc_rid, jnp.full((n_s_pad - n_s, C), -1, INT)], axis=0)
        deg0 = jnp.concatenate(
            [problem.deg0, jnp.zeros((n_r_pad - n_r,), INT)])
        peeled0 = jnp.concatenate(
            [jnp.zeros((n_r,), bool), jnp.ones((n_r_pad - n_r,), bool)])
        out = sharded_decomposition_padded(
            inc, deg0, peeled0, mesh, bucket.schedule,
            max_rounds=n_r_pad + 2, compress=config.compress,
            hierarchy=fused)
        core_raw = np.asarray(out[0])[:n_r]
        rounds = int(out[1])
        uf_parent = uf_L = None
        if fused:
            uf_parent = np.asarray(out[2])[:n_r]
            uf_L = np.asarray(out[3])[:n_r]
        if config.method == "approx":
            core = np.minimum(core_raw, np.asarray(problem.deg0))
            peel_value = core_raw
        else:
            core, peel_value = core_raw, core_raw
        # no order_round: the sharded engine records no trace
        # (records_trace=False), matching the cold sharded backend
        return Decomposition(config, problem=problem, core=core,
                             rounds=rounds, order_round=None,
                             peel_value=peel_value, uf_parent=uf_parent,
                             uf_L=uf_L, plan=plan)
