"""Hierarchy construction: ANH-TE / ANH-BL analogs (paper §5, §7.4).

Tree representation: node ids 0..n_r-1 are leaves (one per r-clique), internal
nodes are appended.  `parent[i] == -1` marks roots; `level[i]` is the merge
level (for leaves: the clique's core number).  A forest with n_r leaves where
every internal node has >= 2 children has < 2 * n_r nodes, so arrays are
preallocated.

TPU adaptation notes (see DESIGN.md §3):
  * Algorithm 1's per-level linked lists + list ranking become flat edge
    arrays grouped by level with one sort.
  * Chain reduction: per s-clique, members sorted by core descending and
    linked consecutively give identical per-level connectivity as all
    O(C^2) pairs with only C-1 edges (beyond-paper optimization; the
    all-pairs mode is kept for cross-validation).
  * The connectivity substrate (``graph.connectivity``) is a fixed-carry
    ``lax.while_loop`` (DESIGN.md §5): each per-level union here is one
    device-resident dispatch with no per-round host sync, and the same
    primitive runs *inside* the fused engine's peel loop.  These two-phase
    builders stay host-driven over levels — they are the cross-check and
    the Fig. 6 comparison baseline; the fused ANH-EL path
    (``interleaved.build_hierarchy_interleaved(link="fused")``) is the
    production one-call route.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from ..graph import INT, connected_components, pointer_jump
from .incidence import NucleusProblem


@dataclasses.dataclass
class HierarchyTree:
    n_leaves: int
    parent: np.ndarray  # (n_nodes,) int64, -1 for roots
    level: np.ndarray   # (n_nodes,) int64

    @property
    def n_nodes(self) -> int:
        return int(self.parent.shape[0])

    @property
    def n_internal(self) -> int:
        return self.n_nodes - self.n_leaves

    def ancestor_at_level(self, c: int) -> np.ndarray:
        """For each leaf: highest ancestor with level >= c (-1 if core < c).

        This is the "cut the hierarchy" query behind Fig. 10: the returned
        node ids label the c-(r,s) nuclei.
        """
        node = np.arange(self.n_leaves, dtype=np.int64)
        cur = np.where(self.level[: self.n_leaves] >= c, node, -1)
        while True:
            valid = cur >= 0
            p = np.where(valid, self.parent[np.maximum(cur, 0)], -1)
            ok = (p >= 0) & (self.level[np.maximum(p, 0)] >= c) & valid
            if not ok.any():
                return cur
            cur = np.where(ok, p, cur)

    def join_levels(self, pairs) -> np.ndarray:
        """Merge level of each (leaf_a, leaf_b) pair; -1 if never joined.

        Canonical comparison metric between hierarchy implementations (the
        trees may differ by unary-node collapsing, but join levels agree).
        """
        pairs = np.asarray(pairs)
        out = np.full(pairs.shape[0], -1, np.int64)
        for idx in range(pairs.shape[0]):
            a, b = int(pairs[idx, 0]), int(pairs[idx, 1])
            if a == b:
                out[idx] = self.level[a]
                continue
            anc = set()
            x = a
            while x != -1:
                anc.add(x)
                x = int(self.parent[x])
            x = b
            while x != -1:
                if x in anc:
                    out[idx] = self.level[x]
                    break
                x = int(self.parent[x])
        return out


def new_tree_buffers(n_r: int, core_np: np.ndarray):
    cap = 2 * max(n_r, 1)
    parent = np.full(cap, -1, np.int64)
    level = np.zeros(cap, np.int64)
    level[:n_r] = core_np
    node_of = np.arange(n_r, dtype=np.int64)
    return parent, level, node_of


def finish_tree(n_r: int, parent: np.ndarray, level: np.ndarray,
                next_id: int) -> HierarchyTree:
    return HierarchyTree(n_leaves=n_r, parent=parent[:next_id].copy(),
                         level=level[:next_id].copy())


# ---------------------------------------------------------------------------
# Hierarchy edge construction (the L_i tables of Algorithm 1, flattened)
# ---------------------------------------------------------------------------

def hierarchy_edges(problem: NucleusProblem, core: jnp.ndarray,
                    chain: bool = True):
    """(u, v, w) r-clique adjacency edges with w = min(core_u, core_v).

    chain=True emits C-1 consecutive edges per s-clique after an in-row sort
    by core descending (connectivity-equivalent to all pairs at every level);
    chain=False emits all C(C,2) pairs (Algorithm 1 verbatim, for tests).
    Result is deduped and sorted by weight descending.
    """
    inc = problem.inc_rid
    n_s, C = inc.shape
    if n_s == 0 or C < 2:
        z = jnp.zeros((0,), INT)
        return z, z, z
    cores = core[inc]  # (n_s, C)
    if chain:
        order = jnp.argsort(-cores, axis=1, stable=True)
        rid_s = jnp.take_along_axis(inc, order, axis=1)
        c_s = jnp.take_along_axis(cores, order, axis=1)
        u = rid_s[:, :-1].reshape(-1)
        v = rid_s[:, 1:].reshape(-1)
        w = c_s[:, 1:].reshape(-1)
    else:
        us, vs, ws = [], [], []
        for i in range(C):
            for j in range(i + 1, C):
                us.append(inc[:, i])
                vs.append(inc[:, j])
                ws.append(jnp.minimum(cores[:, i], cores[:, j]))
        u, v, w = jnp.concatenate(us), jnp.concatenate(vs), jnp.concatenate(ws)
    lo = jnp.minimum(u, v)
    hi = jnp.maximum(u, v)
    order = jnp.lexsort((hi, lo, -w))
    lo, hi, w = lo[order], hi[order], w[order]
    dup = jnp.concatenate([jnp.zeros((1,), bool),
                           (lo[1:] == lo[:-1]) & (hi[1:] == hi[:-1]) & (w[1:] == w[:-1])])
    keep = ~dup
    return lo[keep], hi[keep], w[keep]


def emit_merges(t_old: np.ndarray, t_new: np.ndarray, wv: int,
                parent: np.ndarray, level: np.ndarray, node_of: np.ndarray,
                next_id: int) -> int:
    """Group old roots by new root; every group of >= 2 gets a new parent."""
    if t_old.shape[0] == 0:
        return next_id
    order = np.argsort(t_new, kind="stable")
    tn, to = t_new[order], t_old[order]
    uniq, counts = np.unique(tn, return_counts=True)
    merged = counts >= 2
    if not merged.any():
        return next_id
    n_new = int(merged.sum())
    ids = np.full(uniq.shape[0], -1, np.int64)
    ids[merged] = next_id + np.arange(n_new)
    inv = np.repeat(np.arange(uniq.shape[0]), counts)
    child_mask = merged[inv]
    children_nodes = node_of[to[child_mask]]
    parent[children_nodes] = ids[inv][child_mask]
    level[next_id:next_id + n_new] = int(wv)
    node_of[uniq[merged]] = ids[merged]
    return next_id + n_new


def build_hierarchy_levels(problem: NucleusProblem, core: jnp.ndarray,
                           chain: bool = True) -> HierarchyTree:
    """ANH-TE analog: one union-find forest swept over levels descending."""
    n_r = problem.n_r
    core_np = np.asarray(core)
    u, v, w = hierarchy_edges(problem, core, chain=chain)
    w_np = np.asarray(w)
    parent, level, node_of = new_tree_buffers(n_r, core_np)
    next_id = n_r
    comp = jnp.arange(n_r, dtype=INT)
    neg, starts = np.unique(-w_np, return_index=True)
    distinct = -neg  # descending levels; starts index the sorted edge array
    bounds = list(starts) + [w_np.shape[0]]
    for gi, wv in enumerate(distinct):
        sl = slice(int(bounds[gi]), int(bounds[gi + 1]))
        uu, vv = u[sl], v[sl]
        old = pointer_jump(comp)
        new = connected_components(n_r, uu, vv, init=old)
        touched = np.unique(np.asarray(old[jnp.concatenate([uu, vv])]))
        t_new = np.asarray(new)[touched]
        next_id = emit_merges(touched, t_new, int(wv), parent, level, node_of,
                              next_id)
        comp = new
    return finish_tree(n_r, parent, level, next_id)


def build_hierarchy_basic(problem: NucleusProblem, core: jnp.ndarray,
                          chain: bool = True) -> HierarchyTree:
    """ANH-BL analog: connectivity re-run from scratch per level (k passes).

    Deliberately work-inefficient (the paper's LINK-BASIC baseline): level i
    re-unions every edge of weight >= i instead of reusing the forest.
    """
    n_r = problem.n_r
    core_np = np.asarray(core)
    u, v, w = hierarchy_edges(problem, core, chain=chain)
    w_np = np.asarray(w)
    parent, level, node_of = new_tree_buffers(n_r, core_np)
    next_id = n_r
    prev = np.arange(n_r, dtype=np.int64)
    for wv in np.unique(w_np)[::-1]:
        sel = jnp.asarray(w_np >= wv)  # every qualifying edge, from scratch
        new = connected_components(n_r, u[sel], v[sel])
        new_np = np.asarray(new)
        prev_roots = np.unique(prev)
        next_id = emit_merges(prev_roots, new_np[prev_roots], int(wv), parent,
                              level, node_of, next_id)
        prev = new_np
    return finish_tree(n_r, parent, level, next_id)
