"""Telemetry-driven planner thresholds: the measured profile + loader.

``resolve_plan``'s decision constants (``TINY_NR``, the compile-vs-eager
crossover; ``SHARD_MIN_INCIDENCE``, the shard-vs-single-device crossover)
and the ``use_pallas=None`` default were hand-set in PR 1–5.  This module
replaces them with *measured* crossovers, per device kind:

  * ``tools/calibrate_planner.py`` times the real engines on a problem-size
    ladder and writes ``planner_profile.json`` next to this file (or any
    path via ``--out``).  The committed file is a CPU profile measured on
    the reference container — the shipped default.
  * ``resolve_plan`` and ``engine.pallas_by_default()`` read the profile
    through the loaders here; every consumer records which profile entry
    fired (or that it fell back) so ``plan_report()`` says why a lane was
    chosen.
  * The static constants remain the documented, tested fallback: a missing
    file, malformed JSON, or an uncovered device kind degrades to exactly
    the PR-5 behaviour — with a warning the first time, not silently.

Lookup is by device kind first (``jax.devices()[0].device_kind``, e.g.
"TPU v4"), then platform (``jax.default_backend()``, e.g. "cpu"): a
calibration run records both keys, so a profile measured on one TPU
generation does not silently govern another.

Import-light on purpose (json/os only — no jax): ``backends`` imports this
at module load.
"""
from __future__ import annotations

import json
import os
import warnings
from typing import Any, Dict, Optional, Tuple

FORMAT = "repro.planner-profile"
VERSION = 1
PROFILE_PATH = os.path.join(os.path.dirname(__file__),
                            "planner_profile.json")

# The PR-5 hand-set constants — the verified fallback when no profile
# entry covers the device (backends.py re-exports them under their
# historical names).
STATIC_TINY_NR = 64
STATIC_SHARD_MIN_INCIDENCE = 1 << 20

_CACHE: Dict[str, Optional[Dict[str, Any]]] = {}
_WARNED: set = set()


def _warn_once(key: str, message: str) -> None:
    if key not in _WARNED:
        _WARNED.add(key)
        warnings.warn(message, stacklevel=3)


def load_profile(path: Optional[str] = None) -> Optional[Dict[str, Any]]:
    """The parsed profile dict, or None (missing/malformed file — each
    malformed file warns once and then degrades to the static constants).
    Cached per path; ``reset_cache()`` drops the cache (tests)."""
    path = path or PROFILE_PATH
    if path in _CACHE:
        return _CACHE[path]
    prof: Optional[Dict[str, Any]] = None
    if os.path.exists(path):
        try:
            with open(path) as f:
                blob = json.load(f)
            if blob.get("format") != FORMAT or "profiles" not in blob:
                raise ValueError(
                    f"expected format={FORMAT!r} with a 'profiles' map, "
                    f"got keys {sorted(blob)}")
            prof = blob
        except (ValueError, OSError) as e:
            _warn_once(f"malformed:{path}",
                       f"planner profile {path} is unreadable ({e}); "
                       f"falling back to the static planner constants")
    return _CACHE.setdefault(path, prof)


def reset_cache() -> None:
    """Drop the load cache and warn-once state (test isolation)."""
    _CACHE.clear()
    _WARNED.clear()


def profile_entry(device_kind: Optional[str] = None,
                  platform: Optional[str] = None,
                  path: Optional[str] = None
                  ) -> Tuple[Optional[Dict[str, Any]], str]:
    """(entry, source_tag) for this device: the most specific profile
    entry (device kind beats platform), or (None, "static defaults")."""
    prof = load_profile(path)
    if prof is not None:
        profiles = prof["profiles"]
        for key in (device_kind, platform):
            if key and key in profiles:
                return profiles[key], f"planner_profile[{key!r}]"
    return None, "static defaults"


def thresholds(device_kind: Optional[str] = None,
               platform: Optional[str] = None,
               path: Optional[str] = None) -> Dict[str, Any]:
    """The planner's decision thresholds for this device + provenance.

    Returns {"tiny_nr", "shard_min_incidence", "source"}; each threshold
    falls back to its static constant individually (a profile entry may
    have measured only one crossover)."""
    entry, source = profile_entry(device_kind, platform, path)
    entry = entry or {}
    return {
        "tiny_nr": int(entry.get("tiny_nr", STATIC_TINY_NR)),
        "shard_min_incidence": int(entry.get("shard_min_incidence",
                                             STATIC_SHARD_MIN_INCIDENCE)),
        "source": source,
    }


def pallas_default(platform: Optional[str] = None,
                   device_kind: Optional[str] = None,
                   path: Optional[str] = None) -> Optional[bool]:
    """The profile's measured ``use_pallas=None`` verdict, or None.

    None means no profile entry covers this device (or the entry never
    measured the kernel race): the caller falls back to its static oracle
    — and we warn once per platform, so a fleet running uncalibrated is
    visible without spamming every decompose call."""
    entry, _source = profile_entry(device_kind, platform, path)
    if entry is not None and entry.get("pallas_default") is not None:
        return bool(entry["pallas_default"])
    _warn_once(
        f"pallas_default:{device_kind}:{platform}",
        f"no planner profile entry covers device_kind={device_kind!r} / "
        f"platform={platform!r}; use_pallas=None falls back to the static "
        f"platform oracle (Pallas on TPU).  Run tools/calibrate_planner.py "
        f"(or `make calibrate`) to measure this device.")
    return None
