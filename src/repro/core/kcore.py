"""The r1s2 (k-core) fast lane: vertex-degree peel, no incidence table.

For (r, s) = (1, 2) the nucleus decomposition degenerates to the classic
k-core: r-cliques are vertices, s-cliques are edges, and the s-clique
degree is just the vertex degree.  The generic engine still pays the full
incidence machinery there — a (m, 2) member gather + any-reduce + scatter
per round, plus (fused hierarchy) a chain-reduction sort over the (m, 2)
rows and a LINK fixpoint invocation EVERY round.  That per-round fixpoint
is what inverted the paper's headline result on r1s2 (EXPERIMENTS.md
hierarchy lane: fused 0.40x vs two-phase before this lane).

This lane exploits two degeneracies:

  * **Peel**: the per-round decrement is a plain adjacency reduction —
    ``delta[v] = #{u in N(v) : u peeled this round}`` over the vertex CSR.
    Each edge {u, v} decrements v exactly once across the whole peel (at
    u's peel round); the generic engine's edge-death bookkeeping reaches
    the same ``deg`` trajectory because decrements against already-peeled
    (frozen) vertices are no-ops in both formulations, so core/order/
    rounds are bit-identical to the generic engine (tests pin this).
  * **Hierarchy**: with C = 2 the chain reduction degenerates — every
    edge emits EXACTLY ONE link {u, v} over the whole peel (the chain link
    when both endpoints peel together, the head-to-representative link
    otherwise).  The total link multiset is therefore the edge list
    itself, and since ``engine.link_fixpoint`` is confluent (the result
    depends only on the link multiset, not on arrival order — DESIGN.md
    §5), ONE post-peel fixpoint over the edge list with the final raw
    core values replaces rounds-many in-loop invocations.  This is the
    whole speedup: O(rounds · fixpoint) becomes O(1 · fixpoint).

The lane reuses ``run_peel_engine`` via its ``fused_round`` hook (same
schedule, same trace semantics, same while_loop) and is declared as the
``"kcore"`` fast lane on the dense backend's capabilities so the planner
records the routing in ``Plan.reasons``.  ``peel._run`` routes
(r, s) = (1, 2) dense peels here unless the caller pins the Pallas
megakernel path (``use_pallas=True`` keeps the generic engine so the
megakernel stays exercised on r1s2 fixtures too).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..graph import INT
from .engine import h_index_rows, link_fixpoint, run_peel_engine
from .incidence import NucleusProblem
from .schedule import PeelSchedule


@jax.jit
def kcore_local_converge(nbr_idx, vals0, frozen, max_sweeps):
    """Restartable-from-state local k-core iteration (the r1s2 degeneracy
    of ``engine.local_converge``): with C = 2 the per-s-clique "min of the
    other members" is just the neighbor's value, so one Jacobi sweep is a
    direct adjacency gather + h-index — no incidence-slot indirection.

    nbr_idx: (m, d) neighbor indices into the subproblem's vertex space
    (sentinel ``m`` reads -1, which the h-index ignores); vals0/frozen/
    max_sweeps as in ``engine.local_converge``.  Shapes key the jit cache:
    the streaming path pads to pow2 buckets so updates stay warm.
    Returns (vals, sweeps).
    """
    m = vals0.shape[0]

    def cond(st):
        _, done, sweeps = st
        return (~done) & (sweeps < max_sweeps)

    def body(st):
        vals, _, sweeps = st
        flat = jnp.concatenate([vals, jnp.full((1,), -1, INT)])
        theta = h_index_rows(flat[jnp.clip(nbr_idx, 0, m)])
        new = jnp.where(frozen, vals, jnp.minimum(vals, theta))
        return new, jnp.all(new == vals), sweeps + 1

    vals, _, sweeps = jax.lax.while_loop(
        cond, body, (vals0, jnp.zeros((), bool), jnp.zeros((), INT)))
    return vals, sweeps


def kcore_plan(problem: NucleusProblem):
    """Vertex-adjacency CSR slots: (vids, nbrs), both (2m,), vids sorted.

    Slot k says: vertex ``vids[k]`` has neighbor ``nbrs[k]``.  Built once
    per problem (memoized on it) — the per-round decrement is then
    ``segment_add(a_mask[nbrs] by vids)``.
    """
    cached = getattr(problem, "_kcore_plan", None)
    if cached is not None:
        return cached
    e = np.asarray(problem.g.edges)
    src = np.concatenate([e[:, 0], e[:, 1]])
    dst = np.concatenate([e[:, 1], e[:, 0]])
    order = np.argsort(src, kind="stable")
    plan = (jnp.asarray(src[order], INT), jnp.asarray(dst[order], INT))
    problem._kcore_plan = plan
    return plan


@partial(jax.jit, static_argnames=("schedule", "max_rounds", "hierarchy"))
def _kcore_engine(vids, nbrs, edges, deg0, *, schedule: PeelSchedule,
                  max_rounds: int, hierarchy: bool):
    n = deg0.shape[0]
    def fused_round(deg, peeled, core, order, level, rnd):
        a = (~peeled) & (deg <= level)
        newp = peeled | a
        core = jnp.where(a, level, core)
        order = jnp.where(a, rnd, order)
        # delta[v] = # newly peeled neighbors; decrements against frozen
        # (already peeled) vertices are masked below, matching the generic
        # engine's edge-death accounting exactly
        delta = jnp.zeros((n,), INT).at[vids].add(a[nbrs].astype(INT))
        deg = jnp.where(newp, deg, deg - delta)
        return deg, newp, core, order

    dummy_inc = jnp.zeros((0, 2), INT)
    core, order, rounds = run_peel_engine(
        dummy_inc, deg0, schedule, max_rounds=max_rounds,
        fused_round=fused_round)
    if not hierarchy:
        return core, order, rounds
    # ONE fixpoint over the whole edge-list link multiset (see module
    # docstring): same (parent, L) as the per-round fused engine by the
    # confluence of link_fixpoint, at a single invocation's cost.
    parent0 = jnp.arange(n, dtype=INT)
    L0 = jnp.full((n,), -1, INT)
    lvalid = jnp.ones((edges.shape[0],), bool)
    parent, L = link_fixpoint(parent0, L0, core, edges[:, 0], edges[:, 1],
                              lvalid, max_gens=3 * n + 4)
    return core, order, rounds, parent, L


def kcore_coreness(problem: NucleusProblem, schedule: PeelSchedule, *,
                   max_rounds: Optional[int] = None,
                   hierarchy: bool = False):
    """Drop-in for ``dense_coreness`` on an (r, s) = (1, 2) problem.

    Same return contract: (core_raw, order_round, rounds[, parent, L]),
    bit-identical to the generic dense engine (and, for the hierarchy,
    to the host replay oracle) — the golden tests pin both.
    """
    assert (problem.r, problem.s) == (1, 2), \
        f"kcore lane needs (r, s) = (1, 2), got {(problem.r, problem.s)}"
    n = problem.n_r
    if max_rounds is None:
        max_rounds = n + 2
    if n == 0:
        empty = jnp.zeros((0,), INT)
        out = (empty, empty, jnp.zeros((), INT))
        return out + (empty, empty) if hierarchy else out
    vids, nbrs = kcore_plan(problem)
    edges = jnp.asarray(problem.g.edges, INT).reshape(-1, 2)
    return _kcore_engine(vids, nbrs, edges, problem.deg0,
                         schedule=schedule, max_rounds=max_rounds,
                         hierarchy=hierarchy)
