"""The front door: ``decompose(graph, config) -> Decomposition``.

The paper's value proposition is a *single* artifact — coreness plus the
join-forest hierarchy — built once and queried at many resolutions (Fig. 10).
This module is the one entry point that owns that artifact:

  * ``NucleusConfig`` captures every axis of the decomposition in one frozen,
    validated record: (r, s), exact vs approximate peeling, which backend
    executes the peel, which hierarchy strategy (if any) is attached, and the
    device knobs (Pallas scatter, mesh, collective compression).
    ``validate()`` rejects unsupported combinations with actionable errors
    instead of deep tracebacks (the legality matrix is DESIGN.md §6).
  * ``decompose`` builds the incidence structure if needed, runs the peel on
    the configured backend (the fused hierarchy rides inside the same jitted
    call), and returns a ``Decomposition``.
  * ``Decomposition`` owns the results *lazily with caching*: ``.core`` /
    ``.rounds`` are materialized by the peel; ``.tree`` materializes the
    ``HierarchyTree`` from the fused ``(uf_parent, uf_L)`` forest (or the
    configured builder) on first access; ``.cut(c)`` / ``.nuclei(c)`` answer
    Fig.-10 queries from the cached tree.  ``to_json()`` / ``from_json()``
    round-trip the whole artifact so a decomposition computed offline
    (sharded, multi-host) can be loaded and queried in a serving process
    (``python -m repro.launch.serve --arch nucleus``).

Everything below composes the existing building blocks (``peel``,
``interleaved``, ``hierarchy``, ``nuclei``, ``distributed``); the legacy
per-function surface survives as deprecated wrappers in ``repro.core``.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from . import backends as backend_registry
from .backends import (AUTO, BACKENDS, HIERARCHIES, METHODS, ConfigError,
                       Plan)
from .hierarchy import (HierarchyTree, build_hierarchy_basic,
                        build_hierarchy_levels)
from .incidence import BUILDS, NucleusProblem, build_problem
from .interleaved import (construct_tree_efficient, link_state_from_forest,
                          replay_trace)
from .nuclei import edge_density, nucleus_vertex_sets
from .peel import PeelResult

JSON_FORMAT = "repro.nucleus-decomposition"
JSON_VERSION = 2
# version 1 artifacts (pre-Plan) load fine: "plan" is simply absent.
SUPPORTED_JSON_VERSIONS = (1, 2)


@dataclasses.dataclass(frozen=True)
class NucleusConfig:
    """Every axis of a nucleus decomposition, in one validated record.

    Axes (legality matrix in DESIGN.md §6):
      r, s        — the (r, s) of the decomposition, 1 <= r < s.
      method      — "exact" (ARB-NUCLEUS) or "approx" (Alg. 2, geometric
                    buckets); ``delta`` sets the approximation knob.
      backend     — a registered backend name ("dense": compiled
                    single-device engine, "gather": eager work-efficient
                    host loop, "sharded": shard_map over ``mesh``, "nh":
                    sequential baseline/oracle) or "auto" (the registry
                    planner picks from device kind, mesh availability,
                    problem size and memory budget; DESIGN.md §8).
      hierarchy   — "none", "fused" (LINK fixpoint inside the compiled
                    peel), "replay" (host trace replay), "two_phase"
                    (ANH-TE), "basic" (ANH-BL), or "auto" (richest
                    strategy the resolved backend supports).
      use_pallas  — force the Pallas scatter-decrement on/off (None =
                    backend default; dense backend only).
      mesh        — jax Mesh for the sharded backend (None = whatever this
                    host has, resolved at decompose() time).
      compress    — int16 + error-feedback delta all-reduce (sharded only).
      build       — incidence builder: "eager" (one-burst expansion),
                    "chunked" (memory-bounded source-vertex chunks +
                    two-pass count-then-fill assembly; DESIGN.md §7), or
                    "sharded" (chunks planned onto shards, per-shard slab
                    assembly + count-then-fill exchange;
                    ``repro.distbuild``, DESIGN.md §13).  All three are
                    bit-identical; chunked/sharded bound peak memory.
      memory_budget_bytes — chunked/sharded-build intermediate-memory
                    budget (None = a 256 MiB default); sets the chunk
                    size.  With backend='auto' the planner additionally
                    reads it as the machine's memory ceiling: if the dense
                    engine's per-round working set would exceed it, the
                    work-efficient gather backend is preferred — and if
                    the ESTIMATED EAGER BUILD working set exceeds it, the
                    build itself is upgraded to 'sharded' (multi-device)
                    or 'chunked' before the incidence structure is
                    materialized (the resolved plan's reasons name the
                    rule when it fires; DESIGN.md §8, §13).
      build_chunk_size — explicit source vertices per chunk (overrides the
                    budget-derived size; pins the sparse chunked path).
      build_shards — sharded-build worker count (None = this process's
                    ``jax.device_count()``, so build slabs line up with
                    the peel mesh; build='sharded' only).
    """

    r: int = 2
    s: int = 3
    method: str = "exact"
    delta: float = 0.1
    backend: str = "dense"
    hierarchy: str = "fused"
    use_pallas: Optional[bool] = None
    mesh: Optional[Any] = None
    compress: bool = False
    build: str = "eager"
    memory_budget_bytes: Optional[int] = None
    build_chunk_size: Optional[int] = None
    build_shards: Optional[int] = None

    def validate(self) -> "NucleusConfig":
        """Reject unsupported combinations with actionable errors.

        Backend x (method, hierarchy, knob) legality is DERIVED from the
        registry's capability declarations
        (``backends.check_capabilities``) — this method holds only the
        backend-independent axis checks.  ``backend='auto'`` /
        ``hierarchy='auto'`` are accepted here; the planner resolves them
        at decompose() time and the resolved config re-validates.
        """
        if not (1 <= self.r < self.s):
            raise ConfigError(
                f"need 1 <= r < s, got (r, s) = ({self.r}, {self.s})")
        if self.method not in METHODS:
            raise ConfigError(
                f"method={self.method!r}; expected one of {METHODS}")
        # membership is checked against the LIVE registry, so a backend
        # registered at runtime is immediately legal (BACKENDS is the
        # import-time snapshot kept for display/tests)
        if self.backend != AUTO and \
                self.backend not in backend_registry.names():
            raise ConfigError(
                f"backend={self.backend!r}; expected one of "
                f"{backend_registry.names() + (AUTO,)}")
        if self.hierarchy != AUTO and self.hierarchy not in HIERARCHIES:
            raise ConfigError(
                f"hierarchy={self.hierarchy!r}; expected one of "
                f"{HIERARCHIES + (AUTO,)}")
        if self.method == "approx" and not self.delta > 0:
            raise ConfigError(
                f"method='approx' needs delta > 0, got {self.delta}")
        backend_registry.check_capabilities(self)
        if self.build not in BUILDS:
            raise ConfigError(
                f"build={self.build!r}; expected one of {BUILDS}")
        if self.memory_budget_bytes is not None:
            # the budget sizes the chunked/sharded builders; with
            # backend='auto' it is ALSO the planner's memory ceiling (and
            # can upgrade the build itself), so it stays legal there even
            # with build='eager'
            if self.build not in ("chunked", "sharded") and \
                    self.backend != AUTO:
                raise ConfigError(
                    "memory_budget_bytes sizes the chunked/sharded "
                    "incidence builders (or guides backend='auto'); set "
                    "build='chunked'/'sharded', backend='auto', or drop "
                    "the budget")
            if self.memory_budget_bytes <= 0:
                raise ConfigError(
                    f"memory_budget_bytes must be positive, got "
                    f"{self.memory_budget_bytes}")
        if self.build_chunk_size is not None:
            if self.build not in ("chunked", "sharded"):
                raise ConfigError(
                    "build_chunk_size is the chunked/sharded builders' "
                    "chunk; set build='chunked'/'sharded' or drop it")
            if self.build_chunk_size <= 0:
                raise ConfigError(
                    f"build_chunk_size must be positive, got "
                    f"{self.build_chunk_size}")
        if self.build_shards is not None:
            if self.build != "sharded":
                raise ConfigError(
                    "build_shards is the sharded builder's worker count; "
                    "set build='sharded' or drop it")
            if self.build_shards <= 0:
                raise ConfigError(
                    f"build_shards must be positive, got "
                    f"{self.build_shards}")
        return self

    @classmethod
    def legal_combinations(cls) -> List[Tuple[str, str, str]]:
        """Every (method, backend, hierarchy) triple ``validate()`` accepts.

        The single source of the legality matrix — the facade parity suite
        iterates it and DESIGN.md §6 documents it.
        """
        out = []
        for method in METHODS:
            for backend in backend_registry.names():  # live registry
                for hierarchy in HIERARCHIES:
                    cfg = cls(method=method, backend=backend,
                              hierarchy=hierarchy)
                    try:
                        cfg.validate()
                    except ConfigError:
                        continue
                    out.append((method, backend, hierarchy))
        return out

    def to_dict(self) -> Dict[str, Any]:
        """JSON-safe view (the mesh is a process-local handle, not state)."""
        d = dataclasses.asdict(self)
        d.pop("mesh")
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "NucleusConfig":
        return cls(**{k: v for k, v in d.items() if k != "mesh"})


@dataclasses.dataclass(frozen=True)
class Nucleus:
    """One c-(r, s) nucleus: its vertex set + the Fig. 10 quality metric."""

    label: int
    vertices: np.ndarray   # sorted unique vertex ids
    n_r_cliques: int       # r-cliques carrying the nucleus
    density: float         # |E(S)| / C(|S|, 2); nan if edges unavailable


def _ints(x) -> List[int]:
    return [int(v) for v in np.asarray(x).reshape(-1)]


def _opt_ints(x) -> Optional[List[int]]:
    return None if x is None else _ints(x)


class Decomposition:
    """The build-once/query-many artifact: coreness + hierarchy + queries.

    Materialization contract (DESIGN.md §6): the peel (``core``, ``rounds``,
    the trace, and — for hierarchy='fused' — the join forest) is computed by
    ``decompose()``; everything downstream is lazy and cached:

      .tree      — first access builds the ``HierarchyTree`` from the fused
                   forest / trace replay / configured two-phase builder.
      .cut(c)    — first call per level walks the tree; repeats are O(1).
      .nuclei(c) — vertex sets + densities, derived from the cached cut.

    ``to_json()`` pins the artifact (tree materialized, inputs the queries
    need embedded), so ``from_json()`` serves queries with no
    ``NucleusProblem`` and no recomputation.
    """

    def __init__(self, config: NucleusConfig, *,
                 problem: Optional[NucleusProblem] = None,
                 core: np.ndarray, rounds: int,
                 order_round: Optional[np.ndarray] = None,
                 peel_value: Optional[np.ndarray] = None,
                 uf_parent: Optional[np.ndarray] = None,
                 uf_L: Optional[np.ndarray] = None,
                 tree: Optional[HierarchyTree] = None,
                 r_cliques: Optional[np.ndarray] = None,
                 edges: Optional[np.ndarray] = None,
                 n_vertices: Optional[int] = None,
                 n_s: Optional[int] = None,
                 plan: Optional[Plan] = None,
                 name: Optional[str] = None,
                 version: int = 0):
        self.config = config
        self._name = name
        self._version = int(version)
        self._plan = plan
        self.problem = problem
        self._core = np.asarray(core)
        self._rounds = int(rounds)
        self._order_round = None if order_round is None \
            else np.asarray(order_round)
        self._peel_value = self._core if peel_value is None \
            else np.asarray(peel_value)
        self._uf_parent = None if uf_parent is None else np.asarray(uf_parent)
        self._uf_L = None if uf_L is None else np.asarray(uf_L)
        self._tree = tree
        self._r_cliques = None if r_cliques is None else np.asarray(r_cliques)
        self._edges = None if edges is None else np.asarray(edges)
        self._n_vertices = n_vertices
        self._n_s = n_s
        self._cuts: Dict[int, np.ndarray] = {}
        self._nuclei: Dict[int, Dict[int, "Nucleus"]] = {}
        self._link_stats: Optional[Tuple[int, int]] = None

    # -- materialized by decompose() --------------------------------------
    @property
    def core(self) -> np.ndarray:
        """(n_r,) core numbers (approx: clipped practical estimates)."""
        return self._core

    @property
    def rounds(self) -> int:
        """Peel rounds (the span / all-reduce count proxy)."""
        return self._rounds

    @property
    def order_round(self) -> Optional[np.ndarray]:
        """(n_r,) round each r-clique peeled — the on-device trace (None on
        backends that do not record it: sharded, nh)."""
        return self._order_round

    @property
    def peel_value(self) -> np.ndarray:
        """(n_r,) raw bucket values (unclipped) — what LINK equality saw."""
        return self._peel_value

    @property
    def n_r(self) -> int:
        return int(self._core.shape[0])

    # -- live-artifact identity --------------------------------------------
    @property
    def name(self) -> Optional[str]:
        """The serving-side artifact name (None until published).  A
        router publishing this decomposition under a tenant-visible name
        sets it; ``update()`` carries it to the successor artifact."""
        return self._name

    @name.setter
    def name(self, value: Optional[str]) -> None:
        self._name = value

    @property
    def version(self) -> int:
        """Monotone live-artifact version: 0 at decompose() time, +1 per
        ``update(delta)`` — what a status endpoint reports so clients can
        tell which edit generation answered their query."""
        return self._version

    @property
    def has_hierarchy(self) -> bool:
        return self.config.hierarchy != "none"

    @property
    def link_stats(self) -> Optional[Tuple[int, int]]:
        """(links processed, unions) of the host LINK replay — populated
        only after hierarchy='replay' materializes the tree (the fused
        fixpoint runs on device and does not count)."""
        return self._link_stats

    @property
    def uf_parent(self) -> Optional[np.ndarray]:
        """(n_r,) resolved ANH-EL union-find — the join forest (fused:
        computed by decompose(); replay: after .tree materializes)."""
        return self._uf_parent

    @property
    def uf_L(self) -> Optional[np.ndarray]:
        """(n_r,) nearest-lower-core table of the join forest."""
        return self._uf_L

    # -- the planner's decision record -------------------------------------
    @property
    def plan(self) -> Optional[Plan]:
        """How backend/hierarchy were resolved (requested vs resolved +
        reasons).  None only on artifacts serialized before plans existed
        (JSON version 1)."""
        return self._plan

    def plan_report(self) -> str:
        """Human-readable resolution report (what quickstart prints)."""
        if self._plan is None:
            return "plan: not recorded (artifact predates plan embedding)"
        return self._plan.report()

    # -- lazy hierarchy ----------------------------------------------------
    @property
    def tree(self) -> HierarchyTree:
        """The hierarchy tree, materialized on first access and cached."""
        if self._tree is not None:
            return self._tree
        h = self.config.hierarchy
        if h == "none":
            raise ValueError(
                "this Decomposition was built with hierarchy='none'; "
                "re-run decompose() with hierarchy='fused' (or 'replay'/"
                "'two_phase'/'basic') to get a tree")
        if h in ("fused", "replay") and self._uf_parent is None:
            # replay defers the host LINK fixpoint until the tree is needed
            if self.problem is None or self._order_round is None:
                raise ValueError(
                    "cannot materialize the hierarchy: the join forest was "
                    "not computed and the peel trace / problem is not "
                    "available (serialize with to_json() *after* the tree "
                    "exists, or keep the NucleusProblem attached)")
            res = PeelResult(core=self._core, rounds=self._rounds,
                             order_round=self._order_round,
                             peel_value=self._peel_value)
            state = replay_trace(self.problem, res)
            from .interleaved import _resolve
            self._link_stats = (state.stats_links, state.stats_unions)
            self._uf_parent = _resolve(state.parent,
                                       np.arange(self.n_r, dtype=np.int64))
            self._uf_L = state.L.copy()
        if h in ("fused", "replay"):
            state = link_state_from_forest(self._peel_value, self._uf_parent,
                                           self._uf_L)
            self._tree = construct_tree_efficient(self._problem_view(), state)
        elif h == "two_phase":
            self._tree = build_hierarchy_levels(self._require_problem(),
                                                self._core)
        elif h == "basic":
            self._tree = build_hierarchy_basic(self._require_problem(),
                                               self._core)
        return self._tree

    def _require_problem(self) -> NucleusProblem:
        if self.problem is None:
            raise ValueError(
                f"hierarchy={self.config.hierarchy!r} rebuilds the tree "
                "from the incidence structure, which a deserialized "
                "Decomposition does not carry; serialize with to_json() "
                "after the tree is materialized (to_json() does this) or "
                "keep the NucleusProblem attached")
        return self.problem

    class _TreeProblemView:
        """The construct-tree post-pass only reads ``n_r``."""

        def __init__(self, n_r: int):
            self.n_r = n_r

    def _problem_view(self):
        return self.problem if self.problem is not None \
            else self._TreeProblemView(self.n_r)

    # -- queries -----------------------------------------------------------
    def cut(self, c: int) -> np.ndarray:
        """Label each r-clique with its c-(r, s) nucleus id (-1: core < c).

        First call per level walks the cached tree; repeats return the
        cached labels (the serving hot path).
        """
        c = int(c)
        if c not in self._cuts:
            self._cuts[c] = self.tree.ancestor_at_level(c)
        return self._cuts[c]

    def nuclei(self, c: int) -> Dict[int, Nucleus]:
        """The c-(r, s) nuclei as vertex sets + densities (Fig. 10).

        Cached per level, like ``cut`` — repeats are dict hits (the
        serving hot path)."""
        c = int(c)
        if c in self._nuclei:
            return self._nuclei[c]
        labels = self.cut(c)
        rc = self._r_cliques if self._r_cliques is not None else (
            None if self.problem is None
            else np.asarray(self.problem.r_cliques))
        if rc is None:
            raise ValueError(
                "nucleus vertex sets need the r-clique table; serialize "
                "with to_json(include_inputs=True) or keep the "
                "NucleusProblem attached")
        edges = self._edges if self._edges is not None else (
            None if self.problem is None
            else np.asarray(self.problem.g.edges))
        out = {}
        sets = nucleus_vertex_sets(rc, labels)
        pos = np.asarray(labels)
        labs, cnts = np.unique(pos[pos >= 0], return_counts=True)
        counts = dict(zip(labs.tolist(), cnts.tolist()))
        for lab, verts in sets.items():
            dens = edge_density(edges, verts) if edges is not None \
                else float("nan")
            out[int(lab)] = Nucleus(label=int(lab), vertices=verts,
                                    n_r_cliques=int(counts[lab]),
                                    density=dens)
        self._nuclei[c] = out
        return out

    # -- incremental maintenance -------------------------------------------
    def update(self, delta, *, bucket_hook=None) -> "Decomposition":
        """Apply a ``GraphDelta`` (edge inserts/deletes) incrementally.

        Returns a NEW ``Decomposition`` for the edited graph — core
        values, peel values, the fused join forest, and every downstream
        query (``tree``/``cut``/``nuclei``) are array-for-array identical
        to a fresh ``decompose()`` of the edited graph (the parity tests
        pin this), but only the affected neighborhood is recomputed
        (``repro.core.streaming``; DESIGN.md §10).  ``self`` is left
        untouched and remains valid for the OLD graph.

        Caveats: exact method only, (r, s) in ``streaming.SUPPORTED_RS``,
        hierarchy 'fused' or 'none', and the ``NucleusProblem`` must
        still be attached.  The returned artifact has no peel trace
        (``order_round=None``, ``rounds == -1``) and carries an
        ``update_stats`` telemetry record.  ``bucket_hook`` (internal)
        lets ``Session.update`` count the padded-shape buckets the
        compiled local stages hit.
        """
        from .streaming import update_decomposition
        new_dec, _stats = update_decomposition(self, delta,
                                               bucket_hook=bucket_hook)
        return new_dec

    # -- serialization -----------------------------------------------------
    def to_json(self, include_inputs: bool = True) -> str:
        """Serialize the full artifact (deterministic, round-trip exact).

        The tree is materialized first so a loaded Decomposition answers
        ``cut``/``nuclei`` without the incidence structure;
        ``include_inputs`` embeds the r-clique table + graph edges the
        nucleus/density queries need (skip it to ship core + tree only).
        """
        tree = self.tree if self.has_hierarchy else None
        d: Dict[str, Any] = {
            "format": JSON_FORMAT,
            "version": JSON_VERSION,
            "config": self.config.to_dict(),
            "n_r": self.n_r,
            "n_s": self._n_s if self._n_s is not None else (
                None if self.problem is None else self.problem.n_s),
            "n_vertices": self._n_vertices if self._n_vertices is not None
            else (None if self.problem is None else int(self.problem.g.n)),
            "rounds": self._rounds,
            "name": self._name,
            "live_version": self._version,
            "core": _ints(self._core),
            "order_round": _opt_ints(self._order_round),
            "peel_value": _ints(self._peel_value),
            "uf_parent": _opt_ints(self._uf_parent),
            "uf_L": _opt_ints(self._uf_L),
            "plan": None if self._plan is None else self._plan.to_dict(),
            "tree": None if tree is None else {
                "n_leaves": tree.n_leaves,
                "parent": _ints(tree.parent),
                "level": _ints(tree.level),
            },
        }
        if include_inputs:
            rc = self._r_cliques if self._r_cliques is not None else (
                None if self.problem is None
                else np.asarray(self.problem.r_cliques))
            ed = self._edges if self._edges is not None else (
                None if self.problem is None
                else np.asarray(self.problem.g.edges))
            d["r_cliques"] = None if rc is None else \
                [_ints(row) for row in np.asarray(rc)]
            d["edges"] = None if ed is None else \
                [_ints(row) for row in np.asarray(ed)]
        else:
            d["r_cliques"] = None
            d["edges"] = None
        return json.dumps(d, sort_keys=True, separators=(",", ":"))

    @classmethod
    def from_json(cls, blob: str) -> "Decomposition":
        """Load a serialized decomposition for query serving.

        The result has no ``NucleusProblem``; ``cut``/``nuclei`` answer from
        the embedded tree + inputs, and ``to_json()`` round-trips exactly.
        """
        d = json.loads(blob)
        if d.get("format") != JSON_FORMAT:
            raise ValueError(
                f"not a serialized Decomposition: format={d.get('format')!r}"
                f" (expected {JSON_FORMAT!r}) — this file was not written "
                f"by Decomposition.to_json(); regenerate the artifact with "
                f"decompose(...).save(path)")
        if d.get("version") not in SUPPORTED_JSON_VERSIONS:
            raise ValueError(
                f"unsupported Decomposition version {d.get('version')!r}: "
                f"this build reads versions {SUPPORTED_JSON_VERSIONS} and "
                f"writes {JSON_VERSION} — the artifact was written by a "
                f"different repro version; regenerate it with to_json()/"
                f"save() or upgrade the serving process")
        config = NucleusConfig.from_dict(d["config"])
        plan_d = d.get("plan")
        arr = lambda x: None if x is None else np.asarray(x, np.int64)
        t = d.get("tree")
        tree = None if t is None else HierarchyTree(
            n_leaves=int(t["n_leaves"]),
            parent=np.asarray(t["parent"], np.int64),
            level=np.asarray(t["level"], np.int64))
        rc = d.get("r_cliques")
        ed = d.get("edges")
        return cls(config,
                   core=np.asarray(d["core"], np.int64),
                   rounds=int(d["rounds"]),
                   order_round=arr(d.get("order_round")),
                   peel_value=np.asarray(d["peel_value"], np.int64),
                   uf_parent=arr(d.get("uf_parent")),
                   uf_L=arr(d.get("uf_L")),
                   tree=tree,
                   r_cliques=None if rc is None
                   else np.asarray(rc, np.int64).reshape(-1, config.r),
                   edges=None if ed is None
                   else np.asarray(ed, np.int64).reshape(-1, 2),
                   n_vertices=d.get("n_vertices"),
                   n_s=d.get("n_s"),
                   plan=None if plan_d is None else Plan.from_dict(plan_d),
                   name=d.get("name"),
                   version=int(d.get("live_version", 0)))

    def save(self, path: str, include_inputs: bool = True) -> None:
        with open(path, "w") as f:
            f.write(self.to_json(include_inputs=include_inputs))
            f.write("\n")

    @classmethod
    def load(cls, path: str) -> "Decomposition":
        with open(path) as f:
            return cls.from_json(f.read())

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return (f"Decomposition(r={self.config.r}, s={self.config.s}, "
                f"method={self.config.method!r}, "
                f"backend={self.config.backend!r}, "
                f"hierarchy={self.config.hierarchy!r}, n_r={self.n_r}, "
                f"rounds={self._rounds}, "
                f"tree={'materialized' if self._tree is not None else 'lazy'})")


def resolve_problem(graph_or_problem,
                    config: NucleusConfig
                    ) -> Tuple[NucleusProblem, NucleusConfig]:
    """The front doors' shared input prologue: validate the config, build
    the incidence structure from a ``Graph`` (threading every build knob),
    or adopt a prebuilt ``NucleusProblem`` (its (r, s) wins).  Shared by
    ``decompose()`` and ``Session`` so the build stage cannot drift.

    Build auto-upgrade (DESIGN.md §13): with ``backend='auto'``, a
    ``memory_budget_bytes``, and the default eager build, the estimated
    eager expansion working set is compared against the budget BEFORE the
    build runs; if it does not fit, the build is upgraded to 'sharded'
    (multi-device — slabs line up with the peel mesh) or 'chunked'
    (single device).  Output is bit-identical either way, so the upgrade
    changes peak memory, never results."""
    if isinstance(graph_or_problem, NucleusProblem):
        problem = graph_or_problem
        if (problem.r, problem.s) != (config.r, config.s):
            config = dataclasses.replace(config, r=problem.r, s=problem.s)
        config.validate()
    else:
        config.validate()
        if config.backend == AUTO and config.build == "eager" and \
                config.memory_budget_bytes is not None:
            import jax
            from ..distbuild import estimate_eager_build_bytes
            from .incidence import pick_rank
            dg, _ = pick_rank(graph_or_problem)
            if estimate_eager_build_bytes(dg, config.s) > \
                    config.memory_budget_bytes:
                upgraded = "sharded" if len(jax.devices()) > 1 else "chunked"
                config = dataclasses.replace(config, build=upgraded)
        problem = build_problem(
            graph_or_problem, config.r, config.s, build=config.build,
            memory_budget_bytes=config.memory_budget_bytes,
            chunk_size=config.build_chunk_size,
            shards=config.build_shards)
    return problem, config


def plan_config(problem: NucleusProblem,
                config: NucleusConfig) -> Tuple[NucleusConfig, Plan]:
    """Resolve ``backend='auto'``/``hierarchy='auto'`` against ``problem``.

    Returns the concrete, re-validated config plus the ``Plan`` decision
    record (explicit configs get a trivial plan).  Shared by
    ``decompose()`` and ``Session`` so the two front doors cannot drift.
    """
    stats = problem.build_stats or {}
    plan = backend_registry.resolve_plan(
        config, n_r=problem.n_r, n_s=problem.n_s, n_sub=problem.n_sub,
        r=problem.r, s=problem.s, build=stats.get("build", config.build),
        eager_build_bytes=stats.get("eager_estimate_bytes"))
    if stats.get("build") == "sharded":
        # build telemetry rides the plan reasons so plan_report() (and the
        # serve report) shows HOW the incidence structure was distributed
        plan = dataclasses.replace(plan, reasons=plan.reasons + (
            f"build 'sharded': {stats.get('n_shards')} shards x "
            f"{stats.get('n_chunks')} chunks "
            f"(chunks/shard={stats.get('chunks_per_shard')}), "
            f"work skew {stats.get('skew'):.3f}, "
            f"exchange {stats.get('exchange_bytes')} B",))
    if (plan.backend, plan.hierarchy) != (config.backend, config.hierarchy):
        config = dataclasses.replace(config, backend=plan.backend,
                                     hierarchy=plan.hierarchy)
    config.validate()
    return config, plan


def decompose(graph_or_problem, config: Optional[NucleusConfig] = None,
              **overrides) -> Decomposition:
    """THE entry point: run an (r, s) nucleus decomposition per ``config``.

    ``graph_or_problem`` is a ``Graph`` (the incidence structure is built
    here from ``config.r/s``) or a prebuilt ``NucleusProblem`` (its (r, s)
    wins).  ``config`` defaults to ``NucleusConfig()``; keyword overrides
    are applied on top, e.g. ``decompose(g, method="approx", delta=0.5)``.
    ``backend='auto'``/``hierarchy='auto'`` are resolved here by the
    registry planner (``backends.resolve_plan``); the decision is recorded
    on the result (``.plan`` / ``plan_report()``) and serialized with it.

    The peel runs now (fused hierarchy included — one jitted call on the
    dense backend) on the registered backend the config names; tree
    materialization and cut/nuclei queries are lazy on the returned
    ``Decomposition``.
    """
    if config is None:
        config = NucleusConfig()
    if overrides:
        config = dataclasses.replace(config, **overrides)
    problem, config = resolve_problem(graph_or_problem, config)
    config, plan = plan_config(problem, config)
    return execute_plan(problem, config, plan)


def execute_plan(problem: NucleusProblem, config: NucleusConfig,
                 plan: Plan) -> Decomposition:
    """Run an already-planned decomposition: registry lookup + dispatch.

    ``config`` must be concrete (post-``plan_config``); ``plan`` is the
    decision record to attach.  ``Session`` calls this directly on its
    fallback path so the planner's provenance (requested='auto' + reasons)
    survives instead of being re-derived from the resolved config.
    """
    res = backend_registry.get(config.backend).run(problem, config)
    return Decomposition(config, problem=problem, core=res.core,
                         rounds=res.rounds, order_round=res.order_round,
                         peel_value=res.peel_value, uf_parent=res.uf_parent,
                         uf_L=res.uf_L, plan=plan)
