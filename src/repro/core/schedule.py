"""The one bucket schedule driving every peel loop (exact + Alg. 2 approx).

``PeelSchedule`` is a static (hashable) description of the threshold
sequence; its carry is a triple of int32 scalars that rides inside the peel
engine's ``lax.while_loop`` carry.  The same object drives

  * the eager ``gather`` backend (concrete scalars, Python loop),
  * the jitted single-device dense engine (``repro.core.engine``), and
  * the ``shard_map`` distributed loop (``repro.core.distributed``),

so exact/approx bucket semantics exist in exactly one place.

exact:  the level is the running max of the current minimum degree — the
        classic bucketed peel (ARB-NUCLEUS analog).
approx: geometric buckets B_i with upper bound (C(s,r)+delta)(1+delta)^{i+1}
        and a per-bucket round cap of O(log_{1+delta/C(s,r)} n) rounds
        (Alg. 2 line 17), which bounds total rounds at O(log^2 n).
"""
from __future__ import annotations

import dataclasses
from math import log

import jax
import jax.numpy as jnp
import numpy as np

from ..graph import INT


@dataclasses.dataclass(frozen=True)
class PeelSchedule:
    """Static bucket schedule. exact: level tracks the running min.
    approx: geometric buckets (C(s,r)+delta)(1+delta)^i with a round cap."""

    kind: str  # "exact" | "approx"
    s_choose_r: int = 1
    delta: float = 0.1
    n: int = 1

    def init_carry(self):
        # (bucket index i, rounds_in_bucket, current level)
        return (jnp.zeros((), INT), jnp.zeros((), INT), jnp.zeros((), INT))

    def cap(self) -> int:
        return max(1, int(np.ceil(log(max(self.n, 2))
                                  / log(1.0 + self.delta / self.s_choose_r))))

    def next_level(self, sched, dmin):
        """Advance the carry for one round; returns (carry, peel level).

        The returned level always satisfies level >= dmin, so the clique
        attaining the minimum degree is peelable every round — peel loops
        never need an empty-bucket path.
        """
        if self.kind == "exact":
            i, rib, level = sched
            level = jnp.maximum(level, dmin)
            return (i, rib, level), level
        Cb = self.s_choose_r + self.delta
        i, rib, _ = sched

        def upper(ix):
            return jnp.floor(Cb * (1.0 + self.delta) ** (ix + 1.0)).astype(INT)

        def advance(state):
            ix, r = state
            return jnp.where((dmin > upper(ix)) | (r >= self.cap()),
                             ix + 1, ix), jnp.where(
                                 (dmin > upper(ix)) | (r >= self.cap()), 0, r)

        # advance buckets until dmin fits and the round cap is not exceeded
        def cond(state):
            ix, r = state
            return (dmin > upper(ix)) | (r >= self.cap())

        i, rib = jax.lax.while_loop(cond, lambda s: advance(s), (i, rib))
        level = upper(i)
        return (i, rib + 1, level), level
