"""Incremental nucleus maintenance: ``Decomposition.update(GraphDelta)``.

The serving lane (DESIGN.md §8/§9) froze the artifact: any edge change
forced a full rebuild + re-peel, and — because the problem shapes change
with every edge — a fresh XLA compile on top.  This module maintains the
decomposition under edge inserts/deletes by *local* work (DESIGN.md §10):

  1. **Problem surgery.**  The canonical tables are edited directly: for
     (2, 3) the r-clique table IS the lexsorted edge list, so an edge
     toggle is one ``searchsorted`` row insert/delete plus a vectorized
     rid remap of the incidence rows; new triangles come from the common
     neighborhood of the toggled edge, dead ones straight off the edge's
     mem-CSR row.  No clique re-enumeration, no orientation, no expansion.
  2. **Affected region.**  Only r-cliques connected to the touched
     s-cliques through a path of s-cliques whose old-core bottleneck
     reaches their own old core can change (insert: the single-edge rise
     bound caps the change at +1; delete: old values are upper bounds).
     The region comes from a vectorized max-min label propagation seeded
     at the touched s-cliques.
  3. **Local convergence.**  Values converge downward from an upper-bound
     seed by the h-operator Jacobi sweep (``engine.local_converge``; the
     r1s2 degeneracy rides ``kcore.kcore_local_converge`` — the PR-6 fast
     lane's adjacency layout), run over the extracted subproblem padded
     to pow2 shape buckets, so a stream of updates reuses ONE compiled
     executable per shape class instead of cold-compiling per edge.
  4. **Forest patch.**  The join forest is a pure function of (core
     values, link multiset) — ``link_fixpoint`` is confluent over
     peel-order link streams (DESIGN.md §5) — so an insert that creates
     no s-clique and moves no value is a pure rid relabeling of the
     resolved forest, and every other op re-presents the canonical chain
     multiset (members of each s-clique sorted by core, consecutive
     pairs linked) in ONE fixpoint call: linear work, no peel rounds,
     same padded warm buckets.  (Continuing the fixpoint from the
     resolved state with only the new chains is tempting but unsound:
     L ties break by arrival history, and a late low-core link can merge
     components whose subsumed L candidates are never re-presented.)

``decompose()`` stays the parity oracle: tests pin every update
array-for-array (core, peel values, forest, tree, cuts) against a fresh
decompose of the edited graph under randomized insert/delete sequences.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..graph import INT
from ..graph.container import Graph
from .engine import BIG, link_fixpoint, local_converge
from .incidence import NucleusProblem
from .kcore import kcore_local_converge

# (r, s) pairs with a problem-surgery implementation.  The local theory
# (region bound + h-operator) is generic; what is specialized here is the
# incremental table edit: the r-clique table must be a cheap function of
# the edge list (r=1: the vertices; r=2: the edge list itself).
SUPPORTED_RS = ((1, 2), (2, 3))

# pow2 pad floors for the compiled local stages — small enough that tiny
# fixtures stay tiny, large enough that a real stream collapses onto a
# handful of shape classes (same rationale as session.DEFAULT_BUCKET_FLOOR)
SUB_FLOOR = 64
DEG_FLOOR = 8

Hook = Optional[Callable[[Tuple], None]]


def _pow2(n: int, floor: int) -> int:
    n = max(int(n), int(floor), 1)
    return 1 << (n - 1).bit_length()


# ---------------------------------------------------------------------------
# The delta type
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GraphDelta:
    """An edge-set change: ``delete`` rows are removed first, then
    ``insert`` rows are added, each applied ONE EDGE AT A TIME (the
    single-edge rise/fall bounds that seed the affected region are
    per-edge facts; batching would need the weaker multi-edge bounds).

    Rows are (u, v) vertex pairs in either order; self-loops are
    rejected, as are inserts of present edges / deletes of absent ones
    (strict by design — a no-op delta usually means the caller's view of
    the graph has drifted).  The vertex set is fixed: deltas change
    edges, not ``n``.
    """

    insert: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((0, 2), np.int64))
    delete: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((0, 2), np.int64))

    def __post_init__(self):
        for name in ("insert", "delete"):
            e = np.asarray(getattr(self, name), np.int64).reshape(-1, 2)
            if e.size and (e[:, 0] == e[:, 1]).any():
                raise ValueError(f"GraphDelta.{name} contains a self-loop")
            lo = np.minimum(e[:, 0], e[:, 1])
            hi = np.maximum(e[:, 0], e[:, 1])
            object.__setattr__(self, name, np.stack([lo, hi], axis=1))

    @property
    def n_ops(self) -> int:
        return int(self.insert.shape[0]) + int(self.delete.shape[0])

    def ops(self) -> Iterator[Tuple[str, int, int]]:
        for u, v in self.delete:
            yield ("delete", int(u), int(v))
        for u, v in self.insert:
            yield ("insert", int(u), int(v))


# ---------------------------------------------------------------------------
# Canonical table surgery
# ---------------------------------------------------------------------------

def _edge_keys(edges: np.ndarray) -> np.ndarray:
    e = np.asarray(edges, np.int64)
    return (e[:, 0] << 32) | e[:, 1]


def _apply_edge(g: Graph, u: int, v: int, op: str) -> Tuple[Graph, int]:
    """Toggle one canonical edge; returns (new graph, touched row)."""
    if not (0 <= u < v < g.n):
        raise ValueError(f"edge ({u}, {v}) out of range for n={g.n}")
    e = np.asarray(g.edges, np.int64).reshape(-1, 2)
    keys = _edge_keys(e)
    pos = int(np.searchsorted(keys, (u << 32) | v))
    present = pos < keys.shape[0] and keys[pos] == ((u << 32) | v)
    if op == "insert":
        if present:
            raise ValueError(f"insert of present edge ({u}, {v})")
        new = np.insert(e, pos, (u, v), axis=0)
    else:
        if not present:
            raise ValueError(f"delete of absent edge ({u}, {v})")
        new = np.delete(e, pos, axis=0)
    return Graph(n=g.n, edges=jnp.asarray(new, INT)), pos


def _mem_csr(inc: np.ndarray, n_r: int):
    """(mem_offsets, mem_sids, deg0) from 2D incidence rows — the same
    stable (rid, then sid-ascending) grouping the builders produce."""
    flat = inc.reshape(-1)
    deg0 = np.bincount(flat, minlength=n_r).astype(np.int32) if flat.size \
        else np.zeros((n_r,), np.int32)
    off = np.zeros((n_r + 1,), np.int64)
    np.cumsum(deg0, out=off[1:])
    order = np.argsort(flat, kind="stable")
    sids = (order // max(inc.shape[1], 1)).astype(np.int32)
    return off, sids, deg0


def _pack_problem(old: NucleusProblem, g: Graph, r_table: np.ndarray,
                  inc: np.ndarray) -> NucleusProblem:
    n_r = int(r_table.shape[0])
    off, sids, deg0 = _mem_csr(inc, n_r)
    return NucleusProblem(
        g=g, r=old.r, s=old.s,
        r_cliques=jnp.asarray(r_table, INT).reshape(n_r, old.r),
        inc_rid=jnp.asarray(inc, INT).reshape(inc.shape[0], old.n_sub),
        mem_offsets=jnp.asarray(off, INT), mem_sids=jnp.asarray(sids, INT),
        deg0=jnp.asarray(deg0, INT), orientation=old.orientation,
        build_stats={"build": "streaming"})


@dataclasses.dataclass
class _OpEdit:
    """Everything one edge toggle did to the problem tables."""

    problem: NucleusProblem
    rid_map: Optional[np.ndarray]   # old rid -> new rid; None = identity
    new_rids: np.ndarray            # new-space ids of created r-cliques
    new_sids: np.ndarray            # new-space ids of created s-cliques
    seed_best: np.ndarray           # (n_r_new,) initial bottleneck labels


def _edit_12(problem: NucleusProblem, g_new: Graph, u: int, v: int,
             op: str, core_old: np.ndarray) -> _OpEdit:
    """(1, 2): r-cliques are the vertices (rid space fixed), s-cliques
    the edges — one incidence row toggles.  The builder's s-row order is
    DAG-expansion order, NOT the lexsorted edge order, so rows are
    located by content; new rows append (s-order is free: every output
    is rid-indexed and the forest is confluent over the link multiset).
    """
    inc_old = np.asarray(problem.inc_rid, np.int64).reshape(-1, 2)
    seed_best = np.full((problem.n_r,), -1, np.int64)
    if op == "insert":
        inc = np.concatenate([inc_old, np.array([[u, v]], np.int64)])
        new_sids = np.array([inc.shape[0] - 1], np.int64)
    else:
        row = int(np.flatnonzero((inc_old[:, 0] == u)
                                 & (inc_old[:, 1] == v))[0])
        # seeds: the dead edge's surviving endpoints, at the dead
        # s-clique's bottleneck under the OLD core values
        seed_best[inc_old[row]] = core_old[inc_old[row]].min()
        inc = np.delete(inc_old, row, axis=0)
        new_sids = np.zeros((0,), np.int64)
    new = _pack_problem(problem, g_new,
                        np.asarray(problem.r_cliques, np.int64), inc)
    return _OpEdit(problem=new, rid_map=None,
                   new_rids=np.zeros((0,), np.int64), new_sids=new_sids,
                   seed_best=seed_best)


def _neighbors(e: np.ndarray, x: int) -> np.ndarray:
    return np.concatenate([e[e[:, 0] == x, 1], e[e[:, 1] == x, 0]])


def _edit_23(problem: NucleusProblem, g_new: Graph, pos: int, op: str,
             u: int, v: int, core_old: np.ndarray) -> _OpEdit:
    """(2, 3): the r-clique table IS the lexsorted edge list — one row
    shifts the rid space by one; triangles toggle with the edge."""
    inc_old = np.asarray(problem.inc_rid, np.int64).reshape(-1, 3)
    n_r_old = problem.n_r
    e_new = np.asarray(g_new.edges, np.int64).reshape(-1, 2)
    if op == "insert":
        rid_map = np.arange(n_r_old, dtype=np.int64)
        rid_map[pos:] += 1
        inc = rid_map[inc_old]
        # every new triangle contains the new edge: enumerate the common
        # neighborhood of its endpoints in the NEW graph
        ws = np.intersect1d(_neighbors(e_new, u), _neighbors(e_new, v))
        if ws.size:
            tris = np.sort(np.stack(
                [np.full(ws.shape, u), np.full(ws.shape, v), ws],
                axis=1), axis=1)
            pairs = np.stack([tris[:, [0, 1]], tris[:, [0, 2]],
                              tris[:, [1, 2]]], axis=1)      # (t, 3, 2)
            rids = np.searchsorted(_edge_keys(e_new), _edge_keys(
                pairs.reshape(-1, 2))).reshape(-1, 3)
            inc = np.concatenate([inc, rids], axis=0)
            new_sids = np.arange(inc.shape[0] - rids.shape[0],
                                 inc.shape[0], dtype=np.int64)
        else:
            new_sids = np.zeros((0,), np.int64)
        new_rids = np.array([pos], np.int64)
        # the fresh rid is unconditionally a candidate; its (new)
        # incident s-cliques seed their other members via the generic
        # new-sid fold in _apply_op
        seed_best = np.full((n_r_old + 1,), -1, np.int64)
        seed_best[pos] = BIG
    else:
        off = np.asarray(problem.mem_offsets, np.int64)
        msids = np.asarray(problem.mem_sids, np.int64)
        dead = msids[off[pos]:off[pos + 1]]
        rid_map = np.arange(n_r_old, dtype=np.int64)
        rid_map[pos] = -1
        rid_map[pos + 1:] -= 1
        seed_best = np.full((n_r_old - 1,), -1, np.int64)
        if dead.size:
            dead_rows = inc_old[dead]                    # old rid space
            # bottleneck of a dead triangle = min OLD core over ALL its
            # members (the deleted edge included: the triangle only
            # supported a member at level c if every member sat at >= c)
            w = core_old[dead_rows].min(axis=1)          # (t,)
            live = rid_map[dead_rows]                    # (t, 3); -1 = e0
            np.maximum.at(seed_best, np.clip(live, 0, None).reshape(-1),
                          np.where(live >= 0, w[:, None], -1).reshape(-1))
        keep = np.ones((inc_old.shape[0],), bool)
        keep[dead] = False
        inc = rid_map[inc_old[keep]]
        new_rids = np.zeros((0,), np.int64)
        new_sids = np.zeros((0,), np.int64)
    new = _pack_problem(problem, g_new, e_new, inc)
    return _OpEdit(problem=new, rid_map=rid_map, new_rids=new_rids,
                   new_sids=new_sids, seed_best=seed_best)


# ---------------------------------------------------------------------------
# Affected region: vectorized max-min (bottleneck) label propagation
# ---------------------------------------------------------------------------

def _region(inc: np.ndarray, off: np.ndarray, msids: np.ndarray,
            core_u: np.ndarray, best0: np.ndarray) -> np.ndarray:
    """Largest bottleneck label reachable from the seeds, per r-clique.

    A label b entering s-clique S leaves as min(b, min over S's members
    of ``core_u``); candidates for change are exactly the r-cliques whose
    final label reaches their own ``core_u`` (the witness-subgraph /
    cascade arguments of DESIGN.md §10).  Labels only grow, each step is
    a vectorized scatter-max over the frontier's incidence — a max-min
    Bellman–Ford that settles in at most #distinct-label rounds.
    """
    best = best0.copy()
    if not inc.size:
        return best
    swt = core_u[inc].min(axis=1)          # (n_s,) s-clique bottleneck
    frontier = np.flatnonzero(best >= 0)
    while frontier.size:
        cnt = (off[frontier + 1] - off[frontier]).astype(np.int64)
        total = int(cnt.sum())
        if total == 0:
            break
        starts = np.cumsum(cnt) - cnt
        idx = np.arange(total, dtype=np.int64) \
            - np.repeat(starts, cnt) + np.repeat(off[frontier], cnt)
        sids = msids[idx]
        w = np.minimum(np.repeat(best[frontier], cnt), swt[sids])
        mem = inc[sids]                                  # (k, C)
        new = best.copy()
        np.maximum.at(new, mem.reshape(-1),
                      np.broadcast_to(w[:, None], mem.shape).reshape(-1))
        frontier = np.flatnonzero(new > best)
        best = new
    return best


def _prune_rise(inc: np.ndarray, core_u: np.ndarray, cand: np.ndarray,
                f0: np.ndarray, protect: np.ndarray):
    """Shrink the candidate set before the compiled converge — INSERT
    ops only.

    A single insert only ever RAISES cores, and ``f0`` is a valid upper
    bound on every final value; theta is monotone in its inputs, so a
    candidate R whose support count even under these upper bounds cannot
    reach ``core_u[R] + 1`` (fewer than k+1 incident s-cliques whose
    other members all bound >= k+1) provably keeps its old core.
    Freezing it lowers the bound its neighbors see — iterate the
    (monotone) screen to a fixpoint.  Pure screening: any candidate it
    cannot disprove goes to the compiled converge unchanged, so parity
    is untouched.  Without it, uniform-core graphs (a BA 8-core) flood
    the region bound and the "local" converge is the whole graph.

    ``protect`` marks rids that must stay candidates regardless (fresh
    rids whose ``core_u`` is the BIG sentinel, not a real old value).
    """
    if not cand.any() or not inc.size:
        return cand, f0
    if inc.shape[1] == 2:
        return _prune_rise_pairs(inc, core_u, cand, f0, protect)
    cand = cand.copy()
    f0 = f0.copy()
    n_r = core_u.shape[0]
    thr = core_u + 1                       # the level a riser must reach
    # Only rows touching a live candidate can change a verdict, and the
    # set shrinks monotonically as rids freeze — subset per sweep so the
    # cascade tail costs |frontier|, not |incidence|.
    live = np.flatnonzero(cand[inc].any(axis=1))
    for _ in range(64):
        sub = inc[live]
        row_vals = f0[sub]                               # (rows, C)
        part = np.partition(row_vals, 1, axis=1)         # C >= 2 (r < s)
        m1, m2 = part[:, 0], part[:, 1]
        is_min = row_vals == m1[:, None]
        unique_min = is_min.sum(axis=1) == 1
        # min over the OTHER members, per member slot
        others = np.where(is_min & unique_min[:, None],
                          m2[:, None], m1[:, None])
        support = others >= thr[sub]
        cnt = np.zeros((n_r,), np.int64)
        np.add.at(cnt, sub[support], 1)
        newly = cand & ~protect & (cnt < thr)
        if not newly.any():
            break
        cand[newly] = False
        f0[newly] = core_u[newly]
        live = live[cand[sub].any(axis=1)]
    return cand, f0


def _delete_keeps_cores(core_u: np.ndarray, perturbed: np.ndarray,
                        inc: np.ndarray, off: np.ndarray,
                        msids: np.ndarray) -> bool:
    """Exact early-out for DELETE ops: do the old cores survive as-is?

    Deletion only ever lowers values, and the cores are the greatest
    assignment c with c <= theta(c).  The old assignment stays feasible
    in the edited problem unless some rid lost support — and only
    members of the removed s-cliques changed incidence at all.  So if
    every perturbed rid still counts >= c(x) incident s-cliques whose
    other members all sit at >= c(x) (under the OLD values), the old
    assignment is still a fixpoint, hence still greatest: nothing moves
    and the compiled converge can be skipped entirely.
    """
    for x in perturbed:
        k = int(core_u[x])
        if k <= 0:
            continue
        sids = msids[off[x]:off[x + 1]]
        if sids.size < k:
            return False
        rows = inc[sids]                                 # (d, C)
        others = np.where(rows == x, BIG, core_u[rows]).min(axis=1)
        if int((others >= k).sum()) < k:
            return False
    return True


def _prune_rise_pairs(inc: np.ndarray, core_u: np.ndarray, cand: np.ndarray,
                      f0: np.ndarray, protect: np.ndarray):
    """The C == 2 (r1s2) case of the rise screen as a worklist.

    Same fixpoint as the sweep loop above, but freezes propagate through
    an incidence CSR so a row is only revisited when one of its members
    actually drops — O(m) amortized instead of O(m * cascade depth),
    which is what a uniform-core flood (the whole graph as candidates)
    would otherwise cost.  Support only ever flips True -> False (f0 is
    nonincreasing, thr fixed), so decrement-on-flip is exact.
    """
    cand = cand.copy()
    f0 = f0.copy()
    n_r = core_u.shape[0]
    thr = core_u + 1
    a = inc[:, 0].astype(np.int64)
    b = inc[:, 1].astype(np.int64)
    sup_a = f0[b] >= thr[a]                # row's support for member a
    sup_b = f0[a] >= thr[b]
    cnt = np.zeros((n_r,), np.int64)
    np.add.at(cnt, a[sup_a], 1)
    np.add.at(cnt, b[sup_b], 1)
    # rows incident to each rid, CSR over both endpoint columns
    ends = np.concatenate([a, b])
    row_of = np.concatenate([np.arange(a.size), np.arange(b.size)])
    order = np.argsort(ends, kind="stable")
    rows_s = row_of[order]
    starts = np.searchsorted(ends[order], np.arange(n_r + 1))
    kill = np.flatnonzero(cand & ~protect & (cnt < thr))
    while kill.size:
        cand[kill] = False
        f0[kill] = core_u[kill]
        deg = starts[kill + 1] - starts[kill]
        idx = np.repeat(starts[kill], deg) \
            + np.arange(int(deg.sum())) - np.repeat(np.cumsum(deg) - deg, deg)
        tr = np.unique(rows_s[idx])
        new_sa = f0[b[tr]] >= thr[a[tr]]
        new_sb = f0[a[tr]] >= thr[b[tr]]
        drop_a = a[tr][sup_a[tr] & ~new_sa]
        drop_b = b[tr][sup_b[tr] & ~new_sb]
        np.subtract.at(cnt, drop_a, 1)
        np.subtract.at(cnt, drop_b, 1)
        sup_a[tr] = new_sa
        sup_b[tr] = new_sb
        hit = np.unique(np.concatenate([drop_a, drop_b]))
        hit = hit[cand[hit] & ~protect[hit]]
        kill = hit[cnt[hit] < thr[hit]]
    return cand, f0


# ---------------------------------------------------------------------------
# Local convergence over the extracted subproblem (padded, compiled)
# ---------------------------------------------------------------------------

def _csr_fill(keys: np.ndarray, vals: np.ndarray, rows: int, d_pad: int,
              sentinel: int) -> np.ndarray:
    """Grouped fill: row ``keys[k]`` gets ``vals[k]`` in its next free
    column (stable in k); unused cells hold ``sentinel``."""
    out = np.full((rows, d_pad), sentinel, np.int64)
    if keys.size:
        order = np.argsort(keys, kind="stable")
        degs = np.bincount(keys, minlength=rows)
        starts = np.cumsum(degs) - degs
        occ = np.arange(keys.size, dtype=np.int64) \
            - np.repeat(starts, degs)
        out[keys[order], occ] = vals[order]
    return out


def _converge(problem: NucleusProblem, f0: np.ndarray, cand: np.ndarray,
              hook: Hook) -> Tuple[np.ndarray, int]:
    """Run the padded compiled local iteration; returns (values, sweeps).

    ``f0`` must dominate the true new core values pointwise on the
    candidate set and carry the exact values elsewhere (frozen ring).
    """
    n_r = f0.shape[0]
    cand_idx = np.flatnonzero(cand)
    if cand_idx.size == 0:
        return f0, 0
    inc = np.asarray(problem.inc_rid, np.int64).reshape(problem.n_s,
                                                        problem.n_sub)
    off = np.asarray(problem.mem_offsets, np.int64)
    msids = np.asarray(problem.mem_sids, np.int64)
    cnt = (off[cand_idx + 1] - off[cand_idx]).astype(np.int64)
    total = int(cnt.sum())
    if total == 0:
        # isolated candidates: the h-operator over no s-cliques is 0
        out = f0.copy()
        out[cand_idx] = 0
        return out, 0
    starts = np.cumsum(cnt) - cnt
    idx = np.arange(total, dtype=np.int64) \
        - np.repeat(starts, cnt) + np.repeat(off[cand_idx], cnt)
    sids = np.unique(msids[idx])
    sub_r = np.unique(np.concatenate([cand_idx, inc[sids].reshape(-1)]))
    inv = np.full((n_r,), -1, np.int64)
    inv[sub_r] = np.arange(sub_r.size)
    inc_sub = inv[inc[sids]]                         # (k, C), all >= 0
    k, C = inc_sub.shape
    m_pad = _pow2(sub_r.size, SUB_FLOOR)
    vals = np.zeros((m_pad,), np.int32)
    vals[:sub_r.size] = f0[sub_r]
    frozen = np.ones((m_pad,), bool)
    frozen[:sub_r.size] = ~cand[sub_r]
    # every sweep but the last strictly lowers some candidate and values
    # are bounded below by 0 — the seed sum caps the loop
    cap = int(vals[:sub_r.size][~frozen[:sub_r.size]].sum()) + 2
    if (problem.r, problem.s) == (1, 2):
        # k-core fast lane: C = 2 rows ARE edges — direct adjacency
        src = np.concatenate([inc_sub[:, 0], inc_sub[:, 1]])
        dst = np.concatenate([inc_sub[:, 1], inc_sub[:, 0]])
        d_pad = _pow2(int(np.bincount(src, minlength=1).max()), DEG_FLOOR)
        nbr = _csr_fill(src, dst, m_pad, d_pad, sentinel=m_pad)
        if hook is not None:
            hook(("stream-converge", 1, 2, m_pad, d_pad))
        out, sweeps = kcore_local_converge(
            jnp.asarray(nbr, INT), jnp.asarray(vals),
            jnp.asarray(frozen), jnp.asarray(cap, INT))
    else:
        rows_pad = _pow2(k, SUB_FLOOR)
        inc_pad = np.full((rows_pad, C), -1, np.int32)
        inc_pad[:k] = inc_sub
        # flat slot index row * C + col is invariant under row padding
        # (rows append at the end), so the gather table stays valid
        flat = inc_sub.reshape(-1)
        slots = np.arange(flat.size, dtype=np.int64)
        d_pad = _pow2(int(np.bincount(flat, minlength=1).max()), DEG_FLOOR)
        gather = _csr_fill(flat, slots, m_pad, d_pad,
                           sentinel=rows_pad * C)
        if hook is not None:
            hook(("stream-converge", problem.r, problem.s, rows_pad,
                  m_pad, d_pad))
        out, sweeps = local_converge(
            jnp.asarray(inc_pad, INT), jnp.asarray(gather, INT),
            jnp.asarray(vals), jnp.asarray(frozen),
            jnp.asarray(cap, INT))
    f = f0.copy()
    sel = ~frozen[:sub_r.size]
    f[sub_r[sel]] = np.asarray(out)[:sub_r.size][sel]
    return f, int(sweeps)


# ---------------------------------------------------------------------------
# Forest patch: confluent link fixpoint over canonical chains
# ---------------------------------------------------------------------------

def _chains(inc: np.ndarray, core: np.ndarray):
    """Canonical per-s-clique chains: members sorted by core (ascending,
    stable), consecutive pairs linked.  The chain multiset over ALL
    s-cliques with the final core values resolves to exactly the fused
    engine's (parent, L) — confluence of ``link_fixpoint`` (DESIGN.md
    §5/§10; the golden parity tests pin it)."""
    if not inc.size:
        z = np.zeros((0,), np.int64)
        return z, z
    order = np.argsort(core[inc], axis=1, kind="stable")
    mem = np.take_along_axis(inc, order, axis=1)
    return mem[:, :-1].reshape(-1), mem[:, 1:].reshape(-1)


@jax.jit
def _fixpoint_padded(parent0, L0, core, la, lb, lv):
    n = parent0.shape[0]
    return link_fixpoint(parent0, L0, core, la, lb, lv,
                        max_gens=3 * n + 4)


def _run_fixpoint(parent0: np.ndarray, L0: np.ndarray, core: np.ndarray,
                  la: np.ndarray, lb: np.ndarray,
                  hook: Hook) -> Tuple[np.ndarray, np.ndarray]:
    n_r = parent0.shape[0]
    if la.size == 0:
        return parent0, L0
    # pad to pow2 buckets: padded rids are isolated self-roots with core
    # -1 and no links, so they never interact with the real slots
    n_pad = _pow2(n_r, SUB_FLOOR)
    k_pad = _pow2(la.size, SUB_FLOOR)
    pp = np.concatenate([parent0, np.arange(n_r, n_pad, dtype=np.int64)])
    Lp = np.concatenate([L0, np.full((n_pad - n_r,), -1, np.int64)])
    cp = np.concatenate([core, np.full((n_pad - n_r,), -1, np.int64)])
    lap = np.zeros((k_pad,), np.int64)
    lbp = np.zeros((k_pad,), np.int64)
    lvp = np.zeros((k_pad,), bool)
    lap[:la.size], lbp[:la.size], lvp[:la.size] = la, lb, True
    if hook is not None:
        hook(("stream-link", n_pad, k_pad))
    p, L = _fixpoint_padded(jnp.asarray(pp, INT), jnp.asarray(Lp, INT),
                            jnp.asarray(cp, INT), jnp.asarray(lap, INT),
                            jnp.asarray(lbp, INT), jnp.asarray(lvp))
    return (np.asarray(p, np.int64)[:n_r], np.asarray(L, np.int64)[:n_r])


# ---------------------------------------------------------------------------
# The per-op driver + public entry
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class UpdateStats:
    """Telemetry of one ``update()`` call (summed over its ops)."""

    ops: int = 0
    candidates: int = 0           # r-cliques seeded as possible changers
    changed: int = 0              # r-cliques whose core actually moved
    sweeps: int = 0               # compiled Jacobi sweeps run
    incremental_relinks: int = 0  # forest kept: pure rid relabeling
    full_relinks: int = 0         # forest re-resolved from full multiset


def _remap_forest(parent: np.ndarray, L: np.ndarray,
                  edit: _OpEdit) -> Tuple[np.ndarray, np.ndarray]:
    """Carry the resolved forest into the new rid space (insert only:
    positions shift by one; the fresh rid starts as its own root)."""
    if edit.rid_map is None:
        return parent, L
    p = edit.rid_map[parent]
    Lr = np.where(L >= 0, edit.rid_map[np.clip(L, 0, None)], -1)
    for rid in edit.new_rids:
        p = np.insert(p, rid, rid)
        Lr = np.insert(Lr, rid, -1)
    return p, Lr


def _apply_op(problem: NucleusProblem, core: np.ndarray,
              parent: Optional[np.ndarray], L: Optional[np.ndarray],
              op: str, u: int, v: int, stats: UpdateStats, hook: Hook):
    g_new, pos = _apply_edge(problem.g, u, v, op)
    rs = (problem.r, problem.s)
    core_old = core.astype(np.int64)
    if rs == (1, 2):
        edit = _edit_12(problem, g_new, u, v, op, core_old)
        core_u = core_old                         # rid space unchanged
    else:
        edit = _edit_23(problem, g_new, pos, op, u, v, core_old)
        # old values carried into the NEW rid space; BIG marks the fresh
        # rid so min(core_u + 1, deg0) seeds it at its degree bound
        core_u = (np.insert(core_old, pos, BIG) if op == "insert"
                  else np.delete(core_old, pos))
    new_p = edit.problem
    n_r = new_p.n_r
    deg0 = np.asarray(new_p.deg0, np.int64)
    inc = np.asarray(new_p.inc_rid, np.int64).reshape(new_p.n_s,
                                                      new_p.n_sub)
    off = np.asarray(new_p.mem_offsets, np.int64)
    msids = np.asarray(new_p.mem_sids, np.int64)
    # fold inserted s-cliques into the seeds: each new s-clique S pushes
    # its bottleneck w(S) (under the carried upper labels) to its members
    best0 = edit.seed_best
    is_new = np.zeros((n_r,), bool)
    is_new[edit.new_rids] = True
    if op == "delete" and _delete_keeps_cores(
            core_u, np.flatnonzero(best0 >= 0), inc, off, msids):
        # feasibility held at every perturbed rid — skip region/converge
        f = core_u.astype(np.int64)
    else:
        if edit.new_sids.size:
            new_rows = inc[edit.new_sids]
            swt = core_u[new_rows].min(axis=1)
            np.maximum.at(best0, new_rows.reshape(-1),
                          np.broadcast_to(swt[:, None],
                                          new_rows.shape).reshape(-1))
        best = _region(inc, off, msids, core_u, best0)
        cand = (best >= 0) & (best >= core_u)
        bump = 1 if op == "insert" else 0
        f0 = np.where(cand, np.minimum(core_u + bump, deg0), core_u)
        if op == "insert":
            cand, f0 = _prune_rise(inc, core_u, cand, f0, is_new)
        # counted AFTER the rise screen: what the compiled converge pays
        stats.candidates += int(cand.sum())
        if cand.any():
            f, sweeps = _converge(new_p, f0.astype(np.int64), cand, hook)
            stats.sweeps += sweeps
        else:
            # the screen disproved every rise: f0 has already been frozen
            # back to core_u everywhere, so skip the compiled dispatch
            f = f0.astype(np.int64)
    changed_existing = (f != core_u) & ~is_new
    stats.changed += int(changed_existing.sum()) + int(is_new.sum())
    core_new = f.astype(np.int64)
    if parent is None:
        return new_p, core_new, None, None
    if op == "insert" and not changed_existing.any() \
            and edit.new_sids.size == 0:
        # insert that creates no s-clique and moves no value: the link
        # multiset and cores are untouched, so the resolved forest just
        # relabels into the new rid space (the fresh rid, in no link, is
        # its own root) — no fixpoint call at all
        parent_new, L_new = _remap_forest(parent, L, edit)
        stats.incremental_relinks += 1
    else:
        # one-shot canonical refixpoint over the FULL chain multiset.
        # NOTE: continuing the fixpoint from the resolved state with only
        # the new chains is NOT sound — L ties break by arrival history,
        # and confluence is only pinned for peel-order link streams (a
        # late low-core link can re-merge components whose subsumed L
        # candidates are no longer re-presented); bowtie_plus randomized
        # sequences catch the discrepancy
        p0 = np.arange(n_r, dtype=np.int64)
        L0 = np.full((n_r,), -1, np.int64)
        la, lb = _chains(inc, core_new)
        stats.full_relinks += 1
        parent_new, L_new = _run_fixpoint(p0, L0, core_new, la, lb, hook)
    return new_p, core_new, parent_new, L_new


def update_decomposition(dec, delta: GraphDelta, *,
                         bucket_hook: Hook = None):
    """Apply ``delta`` to a live ``Decomposition``; returns
    ``(new_decomposition, UpdateStats)``.

    Requirements (actionable errors otherwise): ``method='exact'``,
    ``hierarchy`` in {'fused', 'none'}, (r, s) in ``SUPPORTED_RS``, and
    the ``NucleusProblem`` still attached.  ``order_round``/``rounds``
    are global-peel trace artifacts a local update cannot reproduce; the
    returned artifact carries ``order_round=None`` (like the sharded
    backend) and the ``rounds=-1`` sentinel.
    """
    from .api import Decomposition

    config = dec.config
    if config.method != "exact":
        raise ValueError(
            "update() maintains exact decompositions only (approximate "
            "peel values are trace artifacts, not a local fixpoint); "
            "re-run decompose() for approx artifacts")
    if (config.r, config.s) not in SUPPORTED_RS:
        raise ValueError(
            f"update() supports (r, s) in {SUPPORTED_RS}; got "
            f"({config.r}, {config.s}) — run a full decompose() instead")
    if config.hierarchy not in ("fused", "none"):
        raise ValueError(
            "update() patches the fused join forest (or none); "
            f"hierarchy={config.hierarchy!r} artifacts must re-decompose")
    if dec.problem is None:
        raise ValueError(
            "update() needs the NucleusProblem attached; a deserialized "
            "Decomposition has no incidence structure to maintain — "
            "re-decompose the edited graph instead")
    problem = dec.problem
    core = np.asarray(dec.core, np.int64).copy()
    parent = L = None
    if config.hierarchy == "fused":
        parent = np.asarray(dec.uf_parent, np.int64).copy()
        L = np.asarray(dec.uf_L, np.int64).copy()
    stats = UpdateStats()
    for op, u, v in delta.ops():
        stats.ops += 1
        problem, core, parent, L = _apply_op(problem, core, parent, L,
                                             op, u, v, stats, bucket_hook)
    core32 = jnp.asarray(core.astype(np.int32))
    out = Decomposition(
        config, problem=problem, core=core32, rounds=-1,
        order_round=None, peel_value=core32,
        uf_parent=None if parent is None
        else jnp.asarray(parent.astype(np.int32)),
        uf_L=None if L is None else jnp.asarray(L.astype(np.int32)),
        plan=dec.plan,
        # live-artifact identity: the successor keeps the published name
        # and advances one edit generation (what a routed status endpoint
        # reports as the artifact's version)
        name=dec.name, version=dec.version + 1)
    out.update_stats = stats
    return out, stats
