"""The paper's contribution: parallel (r, s) nucleus decomposition + hierarchy.

Public surface:
  build_problem            — (r, s) incidence structure over a Graph
  exact_coreness           — ARB-NUCLEUS analog (bucketed parallel peeling)
  approx_coreness          — APPROX-ARB-NUCLEUS (Alg. 2, geometric buckets)
  build_hierarchy_levels   — ANH-TE (two-phase, level-descending connectivity)
  build_hierarchy_basic    — ANH-BL (per-level from-scratch baseline)
  build_hierarchy_interleaved — ANH-EL (Alg. 3+5, uf + L, single pass)
  nh_full / nh_coreness / nh_hierarchy — sequential NH baseline + oracle
  cut_hierarchy / nuclei_without_hierarchy — Fig. 10 queries
  sharded_decomposition    — shard_map-distributed peeling (multi-pod ready)
  PeelSchedule / peel_round / run_peel_engine — the ONE bucket schedule and
                             the ONE compiled peel-round body every backend
                             (dense, distributed) shares; gather drives the
                             same schedule eagerly
  replay_trace             — LINK-EFFICIENT over the on-device peel trace
                             (the host oracle for the fused fixpoint)
  round_links / link_fixpoint — the fused on-device ANH-EL LINK state
                             (hierarchy=True: coreness + join forest in one
                             jitted call; DESIGN.md §5)
"""
from .incidence import NucleusProblem, build_problem
from .schedule import PeelSchedule
from .engine import (peel_round, run_peel_engine, dense_coreness,
                     make_schedule, scatter_decrement, round_links,
                     link_fixpoint)
from .peel import PeelResult, exact_coreness, approx_coreness
from .hierarchy import (HierarchyTree, build_hierarchy_levels,
                        build_hierarchy_basic, hierarchy_edges)
from .interleaved import (LinkState, InterleavedResult,
                          build_hierarchy_interleaved,
                          construct_tree_efficient, replay_trace,
                          link_state_from_forest)
from .nh_baseline import (nh_coreness, nh_hierarchy, nh_full,
                          brute_force_coreness)
from .nuclei import (cut_hierarchy, nuclei_without_hierarchy,
                     nucleus_vertex_sets, edge_density, same_partition,
                     canonicalize_labels)
from .distributed import (sharded_decomposition,
                          make_sharded_decomposition, pad_incidence)
