"""The paper's contribution: parallel (r, s) nucleus decomposition + hierarchy.

One front door (DESIGN.md §6; backend registry + planner + warm Session
in §8):

  decompose(graph, config) -> Decomposition
      Runs the whole pipeline — incidence structure, exact/approx peeling on
      the chosen backend (compiled dense engine, eager gather, shard_map,
      sequential NH), with the ANH-EL join forest optionally fused into the
      same jitted call — and returns the build-once/query-many artifact.
  NucleusConfig
      Every axis in one frozen, validated record: (r, s), method, backend,
      hierarchy strategy, Pallas/mesh knobs.  ``validate()`` rejects illegal
      combinations with actionable errors.
  Decomposition
      Lazy + cached results: ``.core`` / ``.rounds`` / ``.tree`` /
      ``.cut(c)`` / ``.nuclei(c)``, plus ``to_json()`` / ``from_json()`` so
      a decomposition computed offline is served by
      ``python -m repro.launch.serve --arch nucleus``.

Building blocks (stable, used by the facade and by tests/oracles):

  build_problem / NucleusProblem — the (r, s) incidence structure
  PeelSchedule / peel_round / run_peel_engine — the ONE bucket schedule and
      ONE compiled peel-round body every backend shares
  round_links / link_fixpoint — the fused on-device ANH-EL LINK state
  replay_trace / construct_tree_efficient / link_state_from_forest — host
      LINK oracle + the tree post-pass
  HierarchyTree / hierarchy_edges — tree container + the L_i edge tables
  nucleus_vertex_sets / edge_density / canonicalize_labels / same_partition
  make_sharded_decomposition / pad_incidence — mesh-lowerable distributed
      pieces; brute_force_coreness — the definition-level oracle
  Backend / BackendCapabilities / BackendResult / register_backend /
      resolve_plan / Plan — the capability-declared backend registry +
      the backend='auto' planner (repro.core.backends)
  Session — warm decompose-many: shape-bucketed padded problems reuse one
      compiled peel executable (repro.core.session)

Legacy per-function entry points (exact_coreness, approx_coreness,
dense_coreness, build_hierarchy_*, nh_*, cut_hierarchy,
nuclei_without_hierarchy, sharded_decomposition) remain importable from this
package but are deprecated: they emit a ``DeprecationWarning`` on first use
and delegate unchanged.  New code goes through ``decompose()``.
"""
import functools as _functools
import warnings as _warnings

from .incidence import NucleusProblem, build_problem
from .schedule import PeelSchedule
from .engine import (peel_round, run_peel_engine, make_schedule,
                     scatter_decrement, round_links, link_fixpoint)
from .engine import dense_coreness as _dense_coreness
from .peel import PeelResult
from .peel import exact_coreness as _exact_coreness
from .peel import approx_coreness as _approx_coreness
from .hierarchy import HierarchyTree, hierarchy_edges
from .hierarchy import build_hierarchy_levels as _build_hierarchy_levels
from .hierarchy import build_hierarchy_basic as _build_hierarchy_basic
from .interleaved import (LinkState, InterleavedResult,
                          construct_tree_efficient, replay_trace,
                          link_state_from_forest)
from .interleaved import build_hierarchy_interleaved as \
    _build_hierarchy_interleaved
from .nh_baseline import brute_force_coreness
from .nh_baseline import nh_coreness as _nh_coreness
from .nh_baseline import nh_hierarchy as _nh_hierarchy
from .nh_baseline import nh_full as _nh_full
from .nuclei import (nucleus_vertex_sets, edge_density, same_partition,
                     canonicalize_labels)
from .nuclei import cut_hierarchy as _cut_hierarchy
from .nuclei import nuclei_without_hierarchy as _nuclei_without_hierarchy
from .distributed import make_sharded_decomposition, pad_incidence
from .distributed import sharded_decomposition as _sharded_decomposition
from .backends import (Backend, BackendCapabilities, BackendResult, Plan,
                       resolve_plan)
from .backends import register as register_backend
from .api import (NucleusConfig, Decomposition, Nucleus, ConfigError,
                  decompose, plan_config)
from .streaming import GraphDelta, UpdateStats, update_decomposition
from .session import Session

# ---------------------------------------------------------------------------
# Deprecated legacy surface: thin wrappers that warn once, then delegate.
# In-repo code imports the implementations from their submodules (or uses
# decompose()); only the historical package-level names pay the warning.
# ---------------------------------------------------------------------------

_warned_deprecations = set()


def _reset_deprecation_warnings() -> None:
    """Testing hook: make every deprecated wrapper warn again."""
    _warned_deprecations.clear()


def _deprecated(name, impl, hint):
    @_functools.wraps(impl)
    def wrapper(*args, **kwargs):
        if name not in _warned_deprecations:
            _warned_deprecations.add(name)
            _warnings.warn(
                f"repro.core.{name} is deprecated; {hint}",
                DeprecationWarning, stacklevel=2)
        return impl(*args, **kwargs)
    wrapper.__deprecated__ = (
        f"repro.core.{name} is deprecated; {hint}")
    return wrapper


_HINT = "use repro.core.decompose(graph, NucleusConfig(...))"
DEPRECATED_NAMES = {
    "exact_coreness": (_exact_coreness, f"{_HINT} with method='exact'"),
    "approx_coreness": (_approx_coreness, f"{_HINT} with method='approx'"),
    "dense_coreness": (_dense_coreness, f"{_HINT} with backend='dense'"),
    "sharded_decomposition": (
        _sharded_decomposition, f"{_HINT} with backend='sharded'"),
    "build_hierarchy_levels": (
        _build_hierarchy_levels, f"{_HINT} with hierarchy='two_phase'"),
    "build_hierarchy_basic": (
        _build_hierarchy_basic, f"{_HINT} with hierarchy='basic'"),
    "build_hierarchy_interleaved": (
        _build_hierarchy_interleaved,
        f"{_HINT} with hierarchy='fused' (or 'replay')"),
    "nh_coreness": (_nh_coreness, f"{_HINT} with backend='nh'"),
    "nh_hierarchy": (
        _nh_hierarchy,
        "use repro.core.nh_baseline.nh_hierarchy (oracle) or decompose() "
        "with backend='nh', hierarchy='two_phase'"),
    "nh_full": (
        _nh_full,
        "use repro.core.nh_baseline.nh_full (oracle) or decompose() with "
        "backend='nh'"),
    "cut_hierarchy": (
        _cut_hierarchy, "use Decomposition.cut(c) from decompose()"),
    "nuclei_without_hierarchy": (
        _nuclei_without_hierarchy,
        "use Decomposition.cut(c)/.nuclei(c); the from-scratch baseline "
        "lives at repro.core.nuclei.nuclei_without_hierarchy"),
}

exact_coreness = _deprecated("exact_coreness", *DEPRECATED_NAMES["exact_coreness"])
approx_coreness = _deprecated("approx_coreness", *DEPRECATED_NAMES["approx_coreness"])
dense_coreness = _deprecated("dense_coreness", *DEPRECATED_NAMES["dense_coreness"])
sharded_decomposition = _deprecated(
    "sharded_decomposition", *DEPRECATED_NAMES["sharded_decomposition"])
build_hierarchy_levels = _deprecated(
    "build_hierarchy_levels", *DEPRECATED_NAMES["build_hierarchy_levels"])
build_hierarchy_basic = _deprecated(
    "build_hierarchy_basic", *DEPRECATED_NAMES["build_hierarchy_basic"])
build_hierarchy_interleaved = _deprecated(
    "build_hierarchy_interleaved",
    *DEPRECATED_NAMES["build_hierarchy_interleaved"])
nh_coreness = _deprecated("nh_coreness", *DEPRECATED_NAMES["nh_coreness"])
nh_hierarchy = _deprecated("nh_hierarchy", *DEPRECATED_NAMES["nh_hierarchy"])
nh_full = _deprecated("nh_full", *DEPRECATED_NAMES["nh_full"])
cut_hierarchy = _deprecated("cut_hierarchy", *DEPRECATED_NAMES["cut_hierarchy"])
nuclei_without_hierarchy = _deprecated(
    "nuclei_without_hierarchy", *DEPRECATED_NAMES["nuclei_without_hierarchy"])
