"""Data pipelines: deterministic, resumable, host-side synthetic sources.

Every source is (a) seeded + step-indexed so a restore at step N reproduces
batch N exactly (checkpoint stores only the step), and (b) shaped exactly
like `input_specs()` of the corresponding arch so the trained step and the
dry-run lower identically.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Sequence, Tuple

import numpy as np

from ..graph import Graph, NeighborSampler
from ..models.gnn_common import build_triplets


@dataclasses.dataclass(frozen=True)
class TokenStreamConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


class TokenStream:
    """Synthetic LM token batches with a Zipfian unigram + ngram structure
    (so losses actually decrease during example training runs)."""

    def __init__(self, cfg: TokenStreamConfig):
        self.cfg = cfg
        probs = 1.0 / np.arange(1, cfg.vocab + 1) ** 1.1
        self._probs = probs / probs.sum()

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.cfg.seed, step))
        B, S = self.cfg.global_batch, self.cfg.seq_len
        toks = rng.choice(self.cfg.vocab, size=(B, S + 1), p=self._probs)
        # inject learnable bigram structure: x[t+1] = f(x[t]) half the time
        nxt = (toks[:, :-1] * 31 + 7) % self.cfg.vocab
        mask = rng.random((B, S)) < 0.5
        toks[:, 1:][mask] = nxt[mask]
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


@dataclasses.dataclass(frozen=True)
class RecsysStreamConfig:
    n_items: int
    n_cates: int
    n_users: int
    seq_len: int
    batch: int
    seed: int = 0


class RecsysStream:
    """DIN batches: user history (items+cates), candidate, CTR label with a
    planted preference signal (users favour items in their own cluster)."""

    def __init__(self, cfg: RecsysStreamConfig):
        self.cfg = cfg

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng((c.seed, step))
        user = rng.integers(0, c.n_users, size=c.batch)
        cluster = user % 64
        hist = (rng.integers(0, c.n_items // 64, size=(c.batch, c.seq_len))
                * 64 + cluster[:, None]) % c.n_items
        # random padding tail
        lens = rng.integers(c.seq_len // 2, c.seq_len + 1, size=c.batch)
        pad = np.arange(c.seq_len)[None, :] >= lens[:, None]
        hist[pad] = -1
        cand_pos = rng.random(c.batch) < 0.5
        cand = np.where(
            cand_pos,
            (rng.integers(0, c.n_items // 64, size=c.batch) * 64 + cluster)
            % c.n_items,
            rng.integers(0, c.n_items, size=c.batch))
        return {
            "hist_items": hist.astype(np.int32),
            "hist_cates": np.where(hist >= 0, hist % c.n_cates, -1).astype(np.int32),
            "cand_item": cand.astype(np.int32),
            "cand_cate": (cand % c.n_cates).astype(np.int32),
            "user_id": user.astype(np.int32),
            "label": cand_pos.astype(np.float32),
        }


class GraphMinibatchStream:
    """Fanout-sampled GNN blocks over a base graph (minibatch_lg shape)."""

    def __init__(self, g: Graph, fanouts: Sequence[int], batch_nodes: int,
                 d_feat: int, n_classes: int, seed: int = 0,
                 with_pos: bool = False, triplet_cap: Optional[int] = None):
        self.sampler = NeighborSampler(g, fanouts, seed=seed)
        self.g = g
        self.batch_nodes = batch_nodes
        self.d_feat = d_feat
        self.n_classes = n_classes
        self.seed = seed
        self.with_pos = with_pos
        self.triplet_cap = triplet_cap
        self.cap_nodes, self.cap_edges = NeighborSampler.capacities(
            batch_nodes, fanouts)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        seeds = rng.integers(0, self.g.n, size=self.batch_nodes)
        blk = self.sampler.sample(seeds)
        feat_rng = np.random.default_rng(self.seed + 1)
        feats = feat_rng.standard_normal(
            (self.cap_nodes, self.d_feat)).astype(np.float32)
        out = {
            "nodes": feats,
            "edge_src": blk.edge_src,
            "edge_dst": blk.edge_dst,
            "node_mask": (np.arange(self.cap_nodes) < blk.n_nodes),
            "edge_mask": (np.arange(self.cap_edges) < blk.n_edges),
            "labels": rng.integers(0, self.n_classes,
                                   size=self.cap_nodes).astype(np.int32),
            "label_mask": (np.arange(self.cap_nodes)
                           < blk.seed_count).astype(np.float32),
        }
        if self.with_pos:
            out["pos"] = feat_rng.standard_normal(
                (self.cap_nodes, 3)).astype(np.float32)
        return out


def synthetic_molecules(n_graphs: int, n_nodes: int, n_edges: int,
                        d_feat: int, seed: int = 0,
                        triplet_cap: Optional[int] = None):
    """A batch of random molecular graphs (positions in a box, kNN edges).

    Returns flat padded arrays for a GraphBatch + per-graph energy targets
    with a learnable structure (sum of pairwise LJ-ish terms).
    """
    rng = np.random.default_rng(seed)
    N, E = n_graphs * n_nodes, n_graphs * n_edges
    pos = rng.uniform(0, 2.5, size=(N, 3)).astype(np.float32)
    feats = rng.standard_normal((N, d_feat)).astype(np.float32)
    srcs, dsts = [], []
    for gi in range(n_graphs):
        base = gi * n_nodes
        p = pos[base:base + n_nodes]
        d2 = np.sum((p[:, None] - p[None, :]) ** 2, -1)
        np.fill_diagonal(d2, np.inf)
        k = max(1, n_edges // n_nodes)
        nbr = np.argsort(d2, axis=1)[:, :k]
        s = np.repeat(np.arange(n_nodes), k) + base
        t = nbr.reshape(-1) + base
        srcs.append(s[:n_edges])
        dsts.append(t[:n_edges])
    src = np.concatenate(srcs).astype(np.int32)
    dst = np.concatenate(dsts).astype(np.int32)
    graph_id = np.repeat(np.arange(n_graphs), n_nodes).astype(np.int32)
    # synthetic energies: smooth function of geometry
    d = np.linalg.norm(pos[src] - pos[dst], axis=1)
    e_per_edge = 4.0 * ((0.8 / d) ** 12 - (0.8 / d) ** 6)
    energy = np.zeros(n_graphs, np.float32)
    np.add.at(energy, graph_id[src], e_per_edge.astype(np.float32) / 2)
    trip = build_triplets(src, dst, N, cap_per_edge=triplet_cap)
    return {
        "nodes": feats, "pos": pos, "edge_src": src, "edge_dst": dst,
        "graph_id": graph_id, "n_graphs": n_graphs,
        "triplets": trip, "energy": np.tanh(energy).astype(np.float32),
    }
