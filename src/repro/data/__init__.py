from .pipeline import (TokenStream, TokenStreamConfig, RecsysStream,
                       RecsysStreamConfig, GraphMinibatchStream,
                       synthetic_molecules)
