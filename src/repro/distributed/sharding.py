"""Sharding rules: logical-axis PartitionSpecs per architecture family.

Conventions (MaxText-style, adapted):
  mesh axes: single pod  -> ("data", "model")
             multi-pod   -> ("pod", "data", "model")
  * batch/tokens shard over the data axes (("pod","data") when present) — DP.
  * weight matrices shard their contraction dim over "data" (FSDP/ZeRO-3;
    GSPMD inserts the all-gathers) and their output/head/expert/vocab dim
    over "model" (TP/EP) — 2D sharding, so per-device optimizer state is
    params/|mesh|.
  * axes that do not divide evenly stay unsharded (checked at build time).

`tree_specs` resolves a rule table (path-substring -> spec template) against
a param pytree; `shard_tree` produces NamedShardings for jit in_shardings.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def fsdp_axis(mesh: Mesh) -> Optional[str]:
    return "data" if "data" in mesh.axis_names else None


def model_axis(mesh: Mesh) -> Optional[str]:
    return "model" if "model" in mesh.axis_names else None


def _axis_size(mesh: Mesh, name: Optional[str]) -> int:
    if name is None:
        return 1
    return mesh.shape[name]


def _fits(dim: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return True
    if isinstance(axis, tuple):
        size = int(np.prod([_axis_size(mesh, a) for a in axis]))
    else:
        size = _axis_size(mesh, axis)
    return dim % size == 0


def safe_spec(shape: Tuple[int, ...], template: Sequence, mesh: Mesh) -> P:
    """Drop sharding on axes that don't divide; keep the rest."""
    spec = []
    for dim, ax in zip(shape, template):
        spec.append(ax if (ax is not None and _fits(dim, mesh, ax)) else None)
    return P(*spec)


def tree_specs(params: Any, rules: Dict[str, Sequence], mesh: Mesh,
               default=()) -> Any:
    """Map each leaf to a PartitionSpec via the first matching path rule."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        template = None
        for pat, tmpl in rules.items():
            if re.search(pat, key):
                template = tmpl
                break
        if template is None:
            template = list(default) + [None] * (leaf.ndim - len(default))
        template = list(template)[: leaf.ndim] + [None] * (
            leaf.ndim - len(template))
        out.append(safe_spec(leaf.shape, template, mesh))
    return jax.tree_util.tree_unflatten(treedef, out)


def shard_tree(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def constrain(x, spec: P):
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# per-family rule tables
# ---------------------------------------------------------------------------

def lm_param_rules(mesh: Mesh, moe: bool,
                   moe_ep_data: bool = False) -> Dict[str, Sequence]:
    """Transformer params (stacked layers: leading axis = L, never sharded)."""
    f, m = fsdp_axis(mesh), model_axis(mesh)
    rules = {
        r"embed": [m, None],
        r"unembed": [f, m],
        r"layers/w_dkv": [None, f, m],
        r"layers/w_kr": [None, f, None],
        r"layers/w_uk": [None, None, m],
        r"layers/w_uv": [None, None, m],
        r"layers/wq": [None, f, m],
        r"layers/wk": [None, f, m],
        r"layers/wv": [None, f, m],
        r"layers/wo": [None, m, f],
        r"layers/ln1": [None, None],
        r"layers/ln2": [None, None],
        r"ln_f": [None],
    }
    if moe:
        if moe_ep_data:
            # EP over the token-sharding axis (all-to-all dispatch) with TP
            # inside each expert over "model"
            expert_rules = {
                r"layers/w1": [None, f, None, m],
                r"layers/w2": [None, f, m, None],
                r"layers/w3": [None, f, None, m],
            }
        else:
            # experts over model (EP), d over data (FSDP)
            expert_rules = {
                r"layers/w1": [None, m, f, None],
                r"layers/w2": [None, m, None, f],
                r"layers/w3": [None, m, f, None],
            }
        rules.update({
            r"layers/router": [None, f, None],
            **expert_rules,
            r"layers/sw1": [None, f, m],
            r"layers/sw2": [None, m, f],
            r"layers/sw3": [None, f, m],
        })
    else:
        rules.update({
            r"layers/w1": [None, f, m],
            r"layers/w2": [None, m, f],
            r"layers/w3": [None, f, m],
        })
    return rules


def lm_batch_spec(mesh: Mesh) -> P:
    return P(data_axes(mesh), None)


def lm_cache_spec(mesh: Mesh, kv_heads: int, mla: bool) -> Any:
    """KV cache specs: batch over data axes; sequence over "model" (decode
    attention becomes a distributed flash-decode — GSPMD inserts the
    softmax-stat all-reduces). Stacked layer axis leads."""
    d = data_axes(mesh)
    m = model_axis(mesh)
    if mla:  # (L, B, S, r), (L, B, S, 1, pr)
        return (P(None, d, m, None), P(None, d, m, None, None))
    return (P(None, d, m, None, None), P(None, d, m, None, None))


def gnn_rules(mesh: Mesh) -> Dict[str, Sequence]:
    """GNN params are small: replicate everything (edges carry the scale)."""
    return {r".*": []}


def gnn_batch_specs(mesh: Mesh, shard_nodes: bool) -> Dict[str, P]:
    """Edges shard over the full mesh (flattened); nodes replicated unless
    the graph is huge (ogb_products) in which case features shard too."""
    all_axes = tuple(mesh.axis_names)
    node_spec = P(all_axes, None) if shard_nodes else P(None, None)
    return {
        "nodes": node_spec,
        "edge_src": P(all_axes),
        "edge_dst": P(all_axes),
        "node_mask": P(all_axes) if shard_nodes else P(None),
        "edge_mask": P(all_axes),
        "pos": P(None, None),
        "graph_id": P(all_axes) if shard_nodes else P(None),
        "triplet_kj": P(all_axes),
        "triplet_ji": P(all_axes),
        "triplet_mask": P(all_axes),
        "labels": P(all_axes) if shard_nodes else P(None),
        "label_mask": P(all_axes) if shard_nodes else P(None),
    }


def din_rules(mesh: Mesh) -> Dict[str, Sequence]:
    m = model_axis(mesh)
    return {
        r"item_table": [m, None],   # the classic vocab-sharded embedding
        r"cate_table": [m, None],
        r"user_table": [m, None],
        r".*": [],
    }


def din_batch_spec(mesh: Mesh) -> P:
    return P(data_axes(mesh))
