"""Distributed runtime services: fault tolerance around the train loop.

  * `StragglerMonitor` — per-step wall-clock watchdog.  On a real pod, a
    straggling host shows up as step-time inflation; the monitor keeps a
    robust running median and flags steps slower than `slack` x median so the
    launcher can trigger hot-spare replacement / re-mesh.  (On this CPU
    container it is exercised by tests with synthetic delays.)
  * `PreemptionGuard` — SIGTERM/SIGINT hook that flips a flag the train loop
    polls; the loop then checkpoints and exits cleanly (standard behaviour
    for TPU maintenance events).
  * `ElasticPlan` — given a changed device count, recompute per-device batch
    and return the new mesh shape; used with CheckpointManager's re-mesh
    restore to resume after losing a pod/slice.
  * `HeartbeatLog` — lightweight JSONL step-event log for postmortems.
"""
from __future__ import annotations

import dataclasses
import json
import os
import signal
import time
from typing import Callable, List, Optional, Tuple

import numpy as np


class StragglerMonitor:
    def __init__(self, slack: float = 2.0, warmup: int = 5,
                 window: int = 50):
        self.slack = slack
        self.warmup = warmup
        self.window = window
        self.durations: List[float] = []
        self.flagged: List[Tuple[int, float, float]] = []
        self._t0: Optional[float] = None
        self._step = 0

    def start_step(self) -> None:
        self._t0 = time.monotonic()

    def end_step(self) -> Optional[Tuple[int, float, float]]:
        """Returns (step, duration, median) when the step is a straggler."""
        assert self._t0 is not None, "start_step() not called"
        dur = time.monotonic() - self._t0
        self._t0 = None
        self._step += 1
        hist = self.durations[-self.window:]
        self.durations.append(dur)
        if len(hist) >= self.warmup:
            med = float(np.median(hist))
            if dur > self.slack * med:
                event = (self._step - 1, dur, med)
                self.flagged.append(event)
                return event
        return None


class PreemptionGuard:
    """Installs handlers; `should_stop` flips on SIGTERM/SIGINT."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self.should_stop = False
        self._prev = {}
        for sig in signals:
            self._prev[sig] = signal.signal(sig, self._handler)

    def _handler(self, signum, frame):
        self.should_stop = True

    def request_stop(self) -> None:  # for tests / manual drain
        self.should_stop = True

    def restore(self) -> None:
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """Re-mesh plan after a device-count change."""

    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    per_device_batch: int
    global_batch: int

    @staticmethod
    def plan(n_devices: int, global_batch: int, model_parallel: int,
             multi_pod: bool = False) -> "ElasticPlan":
        """Keep `model_parallel` fixed (weights must still fit); resize the
        data axis; adjust per-device batch so global batch is preserved
        (rounding up to keep it divisible)."""
        if n_devices % model_parallel:
            raise ValueError(
                f"devices ({n_devices}) not divisible by model parallelism "
                f"({model_parallel})")
        data = n_devices // model_parallel
        if multi_pod:
            # factor a pod axis of 2 when possible
            pod = 2 if data % 2 == 0 else 1
            shape = (pod, data // pod, model_parallel)
            names = ("pod", "data", "model")
        else:
            shape = (data, model_parallel)
            names = ("data", "model")
        per_dev = -(-global_batch // data)
        return ElasticPlan(mesh_shape=shape, axis_names=names,
                           per_device_batch=per_dev,
                           global_batch=per_dev * data)


class HeartbeatLog:
    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._f = open(path, "a", buffering=1)

    def event(self, kind: str, **fields) -> None:
        rec = {"t": time.time(), "kind": kind, **fields}
        self._f.write(json.dumps(rec) + "\n")

    def close(self) -> None:
        self._f.close()
