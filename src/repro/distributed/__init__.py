from . import sharding
from .runtime import (StragglerMonitor, PreemptionGuard, ElasticPlan,
                      HeartbeatLog)
