"""Shared AST machinery: traced-context discovery + value taint.

The trace-hygiene and recompile rules both need the same two facts about
a module:

  * **Which function bodies trace.**  A function is a *traced context*
    when jax re-executes it symbolically: decorated with ``jax.jit`` (or
    ``partial(jax.jit, ...)``), passed as the body/cond of
    ``lax.while_loop`` / ``lax.scan`` / ``lax.fori_loop`` / ``lax.cond``
    / ``lax.switch`` / ``lax.map`` / ``shard_map`` / ``pallas_call`` /
    ``jax.jit(f)``'s call form, or lexically nested inside one of those
    (a closure the traced body calls).  Discovery is name-based and
    module-local — names passed at a traced-body argument position mark
    the same-module ``def`` of that name.
  * **Which values are traced.**  Inside a traced context the parameters
    (minus the decorator's ``static_argnames``) seed a forward taint;
    assignment propagates it, and the static accessors ``.shape`` /
    ``.ndim`` / ``.dtype`` block it (shape arithmetic is Python-static
    under tracing — ``n = x.shape[0]`` is a plain int).  Nested contexts
    inherit the enclosing taint through their closure.

The taint is deliberately additive (a rebound name stays tainted): the
rules it feeds flag *operations* on tainted values, so the cost of the
imprecision is a stray finding — silenced with an inline suppression —
never a missed host sync.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Set, Tuple, Union

FuncNode = Union[ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda]
FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)

# callee (matched on the trailing dotted segments) -> positions of the
# arguments that are traced callables
TRACED_ARG_POSITIONS: Dict[str, Tuple[int, ...]] = {
    "while_loop": (0, 1),
    "scan": (0,),
    "fori_loop": (2,),
    "cond": (1, 2),
    "switch": (),          # branches arg handled specially (list at [1])
    "map": (0,),
    "shard_map": (0,),
    "pallas_call": (0,),
    "jit": (0,),
    "checkpoint": (0,),
    "remat": (0,),
    "vmap": (0,),
    "pmap": (0,),
    "custom_jvp": (0,),
    "custom_vjp": (0,),
}
# the bare names above are jax-ambiguous (``map`` is a builtin, ``cond``
# a common variable); require a dotted qualifier for these
REQUIRE_QUALIFIER = {"cond", "map", "switch", "scan", "jit", "checkpoint",
                     "remat", "vmap", "pmap", "custom_jvp", "custom_vjp"}
JAX_QUALIFIERS = {"jax", "lax", "pl", "pallas", "experimental", "linen",
                  "nn", "checkpoint"}

# attribute accesses that launder a traced value into a Python-static one
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "aval", "sharding"}


def dotted_name(node: ast.AST) -> Optional[str]:
    """'jax.lax.while_loop' for the matching Attribute/Name chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def is_jit_name(name: Optional[str]) -> bool:
    """Does ``name`` denote jax.jit (jit / jax.jit / eqx.filter_jit)?"""
    return bool(name) and (name == "jit" or name.endswith(".jit")
                           or name.endswith("filter_jit"))


def _string_names(node: ast.AST) -> Set[str]:
    """Literal string / tuple-or-list-of-strings -> the set of names."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return {node.value}
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        out: Set[str] = set()
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                out.add(e.value)
        return out
    return set()


def jit_decorator_statics(dec: ast.AST) -> Optional[Set[str]]:
    """If ``dec`` is a jit decorator, the declared static_argnames
    (possibly empty); None when it is not a jit decorator.

    Recognized forms: ``@jax.jit``, ``@jit``, ``@partial(jax.jit, ...)``,
    ``@functools.partial(jax.jit, static_argnames=(...))``,
    ``@jax.jit`` is never called with arguments directly, but
    ``@jax.jit(fn)``-style factories are matched defensively.
    """
    name = dotted_name(dec)
    if is_jit_name(name):
        return set()
    if isinstance(dec, ast.Call):
        fn = dotted_name(dec.func)
        if fn and fn.split(".")[-1] == "partial" and dec.args \
                and is_jit_name(dotted_name(dec.args[0])):
            statics: Set[str] = set()
            for kw in dec.keywords:
                if kw.arg in ("static_argnames", "static_argnums"):
                    statics |= _string_names(kw.value)
            return statics
        if is_jit_name(fn):
            statics = set()
            for kw in dec.keywords:
                if kw.arg in ("static_argnames", "static_argnums"):
                    statics |= _string_names(kw.value)
            return statics
    return None


def traced_callee_positions(call: ast.Call) -> Tuple[int, ...]:
    """Argument positions of ``call`` that receive traced callables
    (empty when the callee is not a known tracing combinator)."""
    name = dotted_name(call.func)
    if not name:
        return ()
    parts = name.split(".")
    last = parts[-1]
    if last not in TRACED_ARG_POSITIONS:
        return ()
    if last in REQUIRE_QUALIFIER and len(parts) == 1:
        return ()
    if len(parts) > 1 and last in REQUIRE_QUALIFIER \
            and parts[-2] not in JAX_QUALIFIERS:
        return ()
    return TRACED_ARG_POSITIONS[last]


@dataclasses.dataclass
class TracedContext:
    """One function body jax traces, with its taint environment."""

    node: FuncNode
    name: str                   # display name ("_dense_engine", "<lambda>")
    reason: str                 # "decorated jax.jit" / "lax.while_loop body"
    statics: Set[str]           # param names excluded from taint seeding
    tainted: Set[str] = dataclasses.field(default_factory=set)
    parent: Optional["TracedContext"] = None


def _param_names(node: FuncNode) -> List[str]:
    a = node.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


class _ContextFinder(ast.NodeVisitor):
    """Collect traced roots: decorated defs, loop-body callables (by name
    or inline lambda), and the names referenced at traced positions."""

    def __init__(self):
        self.decorated: Dict[FuncNode, Tuple[str, Set[str]]] = {}
        self.body_nodes: Dict[FuncNode, str] = {}   # lambdas passed inline
        self.body_names: Dict[str, str] = {}        # name -> reason
        self.defs: Dict[str, List[FuncNode]] = {}

    def visit_FunctionDef(self, node):
        self._def(node)

    def visit_AsyncFunctionDef(self, node):
        self._def(node)

    def _def(self, node):
        self.defs.setdefault(node.name, []).append(node)
        for dec in node.decorator_list:
            statics = jit_decorator_statics(dec)
            if statics is not None:
                self.decorated[node] = (
                    f"decorated {dotted_name(dec) or 'jax.jit'}", statics)
                break
        self.generic_visit(node)

    def visit_Call(self, node):
        positions = traced_callee_positions(node)
        callee = dotted_name(node.func) or "?"
        for pos in positions:
            if pos >= len(node.args):
                continue
            arg = node.args[pos]
            self._mark(arg, f"{callee} body")
        # lax.switch takes a *list* of branch callables at position 1
        if callee.split(".")[-1] == "switch" and len(node.args) > 1 \
                and isinstance(node.args[1], (ast.List, ast.Tuple)):
            for e in node.args[1].elts:
                self._mark(e, f"{callee} branch")
        self.generic_visit(node)

    def _mark(self, arg: ast.AST, reason: str) -> None:
        if isinstance(arg, ast.Lambda):
            self.body_nodes[arg] = reason
        else:
            name = dotted_name(arg)
            if name and "." not in name:
                self.body_names.setdefault(name, reason)


def find_traced_contexts(tree: ast.Module) -> List[TracedContext]:
    """All traced contexts of a module, nested contexts included.

    Each root context is returned with taint seeded from its non-static
    parameters; nested defs/lambdas inside a root become child contexts
    inheriting the enclosing taint (their own parameters seed too — a
    closure the traced body calls receives traced values).
    """
    finder = _ContextFinder()
    finder.visit(tree)
    roots: List[Tuple[FuncNode, str, Set[str]]] = []
    for node, (reason, statics) in finder.decorated.items():
        roots.append((node, reason, statics))
    for node, reason in finder.body_nodes.items():
        roots.append((node, reason, set()))
    claimed = {id(n) for n, _, _ in roots}
    for name, reason in finder.body_names.items():
        for node in finder.defs.get(name, []):
            if id(node) not in claimed:
                roots.append((node, reason, set()))
                claimed.add(id(node))
    out: List[TracedContext] = []
    for node, reason, statics in roots:
        ctx = TracedContext(
            node=node, reason=reason, statics=statics,
            name=getattr(node, "name", "<lambda>"))
        ctx.tainted = {p for p in _param_names(node) if p not in statics}
        out.append(ctx)
    return out


class TaintEnv:
    """Forward taint over one traced context's body (additive)."""

    def __init__(self, ctx: TracedContext):
        self.ctx = ctx
        self.tainted: Set[str] = set(ctx.tainted)
        if ctx.parent is not None:
            self.tainted |= ctx.parent.tainted

    # -- expression query ---------------------------------------------------
    def expr_tainted(self, node: ast.AST) -> bool:
        """Does evaluating ``node`` read a traced value (modulo the
        static accessors)?"""
        for sub in self._walk(node):
            if isinstance(sub, ast.Name) and sub.id in self.tainted:
                return True
        return False

    def _walk(self, node: ast.AST):
        """ast.walk that does not descend past static accessors or into
        nested function bodies (children are analyzed as their own
        contexts)."""
        stack = [node]
        while stack:
            n = stack.pop()
            if isinstance(n, ast.Attribute) and n.attr in STATIC_ATTRS:
                continue
            yield n
            if isinstance(n, FUNC_NODES) and n is not node:
                continue
            stack.extend(ast.iter_child_nodes(n))

    # -- statement-level propagation ---------------------------------------
    def _target_names(self, target: ast.AST) -> Set[str]:
        out: Set[str] = set()
        for sub in ast.walk(target):
            if isinstance(sub, ast.Name):
                out.add(sub.id)
        return out

    def propagate(self) -> None:
        """Run assignment propagation over the context body to fixpoint
        (bounded — the tainted set only grows)."""
        body = self.ctx.node.body
        stmts = body if isinstance(body, list) else [ast.Expr(body)]
        for _ in range(8):
            before = len(self.tainted)
            for stmt in stmts:
                self._visit_stmts(stmt)
            if len(self.tainted) == before:
                break

    def _visit_stmts(self, stmt: ast.AST) -> None:
        for node in ast.walk(stmt):
            if isinstance(node, FUNC_NODES):
                continue
            if isinstance(node, ast.Assign):
                if self.expr_tainted(node.value):
                    for t in node.targets:
                        self.tainted |= self._target_names(t)
            elif isinstance(node, ast.AugAssign):
                if self.expr_tainted(node.value) \
                        or self.expr_tainted(node.target):
                    self.tainted |= self._target_names(node.target)
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                if self.expr_tainted(node.value):
                    self.tainted |= self._target_names(node.target)
            elif isinstance(node, ast.For):
                if self.expr_tainted(node.iter):
                    self.tainted |= self._target_names(node.target)
            elif isinstance(node, (ast.withitem,)):
                if node.optional_vars is not None \
                        and self.expr_tainted(node.context_expr):
                    self.tainted |= self._target_names(node.optional_vars)
            elif isinstance(node, ast.NamedExpr):
                if self.expr_tainted(node.value):
                    self.tainted |= self._target_names(node.target)
            elif isinstance(node, (ast.comprehension,)):
                if self.expr_tainted(node.iter):
                    self.tainted |= self._target_names(node.target)


def expand_contexts(roots: List[TracedContext]) -> List[TracedContext]:
    """Roots + their nested function contexts, each with propagated
    taint (parents before children, so closures inherit)."""
    out: List[TracedContext] = []
    work = list(roots)
    seen = {id(c.node) for c in roots}
    while work:
        ctx = work.pop(0)
        env = TaintEnv(ctx)
        env.propagate()
        ctx.tainted = env.tainted
        out.append(ctx)
        for node in ast.walk(ctx.node):
            if node is ctx.node or not isinstance(node, FUNC_NODES):
                continue
            if id(node) in seen:
                continue
            # direct child only (grandchildren queue via their parent)
            if _enclosing_func(ctx.node, node) is ctx.node:
                seen.add(id(node))
                child = TracedContext(
                    node=node, name=getattr(node, "name", "<lambda>"),
                    reason=f"nested in {ctx.name} ({ctx.reason})",
                    statics=set(), parent=ctx)
                child.tainted = set(_param_names(node))
                work.append(child)
    return out


def _enclosing_func(root: FuncNode, target: ast.AST) -> Optional[ast.AST]:
    """The innermost function node of ``root``'s tree that strictly
    contains ``target``."""
    result: List[ast.AST] = [root]

    def descend(node: ast.AST, owner: ast.AST) -> bool:
        for child in ast.iter_child_nodes(node):
            if child is target:
                result[0] = owner
                return True
            next_owner = child if isinstance(child, FUNC_NODES) else owner
            if descend(child, next_owner):
                return True
        return False

    descend(root, root)
    return result[0]
