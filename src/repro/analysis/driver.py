"""The checker driver: load sources once, run every rule, merge output.

A rule is a callable ``(module: Module, project: Project) -> List[Finding]``
registered in ``RULES``.  The driver parses each file once into a
``Module`` (source text, AST, suppression table), bundles them into a
``Project`` (rules that need cross-file context — NL401 reads the
registry module regardless of which files were requested — get the whole
picture), then applies inline suppressions and the committed baseline.

Adding a rule (DESIGN.md §12): write the checker in the matching
``rules_*`` module, give it a docstring (it becomes ``--list-rules``
output), and append it to ``RULES`` here.  Rules must be pure functions
of the ASTs — no imports of the analyzed code, so linting never executes
jax.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .findings import Finding, apply_suppressions, parse_suppressions


@dataclasses.dataclass
class Module:
    """One parsed source file."""

    path: str                      # repo-relative, '/'-separated
    source: str
    tree: ast.Module
    suppressions: Dict[int, frozenset]

    @property
    def lines(self) -> List[str]:
        return self.source.splitlines()


@dataclasses.dataclass
class Project:
    """Every module under analysis, keyed by repo-relative path."""

    root: str
    modules: Dict[str, Module]

    def module(self, path: str) -> Optional[Module]:
        return self.modules.get(path)

    def match(self, suffix: str) -> Optional[Module]:
        """The unique module whose path ends with ``suffix`` (for rules
        pinned to well-known files like ``serve/frontend.py``)."""
        hits = [m for p, m in self.modules.items() if p.endswith(suffix)]
        return hits[0] if len(hits) == 1 else None


def _rel(path: str, root: str) -> str:
    rel = os.path.relpath(os.path.abspath(path), os.path.abspath(root))
    return rel.replace(os.sep, "/")


def load_project(paths: Sequence[str], root: str = ".") -> Project:
    """Parse every ``.py`` file under ``paths`` (files or directories).

    Files that fail to parse yield a synthetic NL000 finding instead of
    aborting the run — the driver attaches those via ``Project`` so the
    gate still fails loudly on a broken file.
    """
    files: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = sorted(
                    d for d in dirnames
                    if d not in ("__pycache__", ".git", ".venv"))
                files.extend(os.path.join(dirpath, f)
                             for f in sorted(filenames) if f.endswith(".py"))
        elif p.endswith(".py"):
            files.append(p)
    modules: Dict[str, Module] = {}
    for f in files:
        rel = _rel(f, root)
        with open(f, encoding="utf-8") as fh:
            source = fh.read()
        try:
            tree = ast.parse(source, filename=rel)
        except SyntaxError as e:
            tree = ast.Module(body=[], type_ignores=[])
            mod = Module(path=rel, source=source, tree=tree, suppressions={})
            mod.parse_error = Finding(           # type: ignore[attr-defined]
                path=rel, line=e.lineno or 1, col=(e.offset or 1) - 1,
                rule="NL000", message=f"syntax error: {e.msg}",
                hint="nucleuslint cannot analyze this file until it parses")
            modules[rel] = mod
            continue
        modules[rel] = Module(
            path=rel, source=source, tree=tree,
            suppressions=parse_suppressions(source.splitlines()))
    return Project(root=os.path.abspath(root), modules=modules)


Rule = Callable[[Module, Project], List[Finding]]


def _rules() -> List[Tuple[str, Rule]]:
    # imported lazily so `findings`/`baseline` stay importable standalone
    from . import rules_concurrency, rules_recompile, rules_registry, \
        rules_trace
    return [
        ("NL1xx trace hygiene", rules_trace.check),
        ("NL2xx recompile hazards", rules_recompile.check),
        ("NL3xx concurrency", rules_concurrency.check),
        ("NL4xx registry conformance", rules_registry.check),
    ]


def rule_catalog() -> List[Tuple[str, str]]:
    """(rule id, one-line description) for ``--list-rules``."""
    from . import rules_concurrency, rules_recompile, rules_registry, \
        rules_trace
    out: List[Tuple[str, str]] = []
    for mod in (rules_trace, rules_recompile, rules_concurrency,
                rules_registry):
        out.extend(mod.CATALOG)
    return sorted(out)


def run_analysis(project: Project,
                 only: Optional[Sequence[str]] = None) -> List[Finding]:
    """All non-suppressed findings over ``project``, sorted.

    ``only`` restricts to rule-id prefixes (e.g. ``["NL3"]``) for
    focused runs; suppressions always apply, the baseline is the
    caller's job (the CLI layers it so tests can see raw findings).
    """
    findings: List[Finding] = []
    for mod in project.modules.values():
        err = getattr(mod, "parse_error", None)
        if err is not None:
            findings.append(err)
            continue
        raw: List[Finding] = []
        for _family, rule in _rules():
            raw.extend(rule(mod, project))
        findings.extend(apply_suppressions(raw, mod.suppressions))
    if only:
        findings = [f for f in findings
                    if any(f.rule.startswith(p) for p in only)]
    return sorted(findings)
