"""NL3xx concurrency: the PR 8 lock convention + single-writer engine.

The threaded server's safety argument (DESIGN.md §10) has two legs, and
each leg is a checkable AST property:

  NL301  lock-convention violation.  The convention is seeded per class:
         any ``self.<attr>`` a class EVER mutates inside a
         ``with self.<...lock...>:`` block (direct assignment, augmented
         assignment, subscript store, or a mutating method call like
         ``.pop`` / ``.append``) is a *guarded attribute* — e.g.
         ``Frontend.stats`` via ``_count``, the Router pool tables.
         Every other mutation of a guarded attribute must also hold the
         lock; ``__init__`` is exempt (no concurrent readers exist
         before construction completes).
  NL302  single-writer violation.  ``serve/frontend.py``'s correctness
         claim is that exactly one thread drives the engine: calls that
         enter it (``route_many`` / ``router.update`` /
         ``decompose``\\*) may appear only in the worker methods
         ``_run`` / ``_serve_batch``.  ``submit()`` may resolve, pool
         and bucket (lock-guarded reads) but never run a decomposition.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from .driver import Module, Project
from .findings import Finding
from .jaxast import dotted_name

CATALOG = [
    ("NL301", "write to a lock-guarded attribute outside `with "
              "self.<lock>` (the PR 8 _count convention)"),
    ("NL302", "engine-entry call outside the frontend worker thread "
              "(single-writer invariant)"),
]

_MUTATORS = {"append", "extend", "pop", "popitem", "clear", "update",
             "setdefault", "add", "remove", "discard", "insert",
             "appendleft", "__setitem__"}
_ENGINE_ENTRIES = {"route_many", "update", "decompose", "decompose_many"}
_WORKER_METHODS = {"_run", "_serve_batch"}


def _is_lock_name(attr: str) -> bool:
    return "lock" in attr.lower()


def _self_attr_mutations(node: ast.AST) -> List[Tuple[str, ast.AST]]:
    """(attr name, site) for every mutation of ``self.<attr>`` performed
    by ``node`` itself (not a full walk)."""
    out: List[Tuple[str, ast.AST]] = []

    def self_attr(target: ast.AST) -> Optional[str]:
        # self.x  |  self.x[...]  (store through subscript)
        if isinstance(target, ast.Subscript):
            target = target.value
        if isinstance(target, ast.Attribute) \
                and isinstance(target.value, ast.Name) \
                and target.value.id == "self":
            return target.attr
        return None

    if isinstance(node, ast.Assign):
        for t in node.targets:
            attr = self_attr(t)
            if attr:
                out.append((attr, node))
    elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
        attr = self_attr(node.target)
        if attr:
            out.append((attr, node))
    elif isinstance(node, ast.Delete):
        for t in node.targets:
            attr = self_attr(t)
            if attr:
                out.append((attr, node))
    elif isinstance(node, ast.Call) \
            and isinstance(node.func, ast.Attribute) \
            and node.func.attr in _MUTATORS:
        attr = self_attr(node.func.value)
        if attr:
            out.append((attr, node))
    return out


def _with_holds_self_lock(node: ast.With) -> bool:
    for item in node.items:
        name = dotted_name(item.context_expr)
        if name and name.startswith("self.") \
                and _is_lock_name(name.split(".")[-1]):
            return True
    return False


def _scan_class(cls: ast.ClassDef
                ) -> List[Tuple[str, str, ast.AST, bool]]:
    """(method, attr, site, under_lock) for every self-attr mutation in
    ``cls``, with lock context tracked lexically."""
    sites: List[Tuple[str, str, ast.AST, bool]] = []

    def walk(node: ast.AST, method: str, locked: bool) -> None:
        for child in ast.iter_child_nodes(node):
            child_method, child_locked = method, locked
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                child_method, child_locked = child.name, False
            elif isinstance(child, ast.With) \
                    and _with_holds_self_lock(child):
                child_locked = True
            for attr, site in _self_attr_mutations(child):
                sites.append((child_method, attr, site, child_locked))
            walk(child, child_method, child_locked)

    walk(cls, "<class body>", False)
    return sites


def check(module: Module, project: Project) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(module.tree):
        if isinstance(node, ast.ClassDef):
            findings.extend(_check_class(module, node))
    if module.path.endswith("serve/frontend.py"):
        findings.extend(_check_single_writer(module))
    return findings


def _check_class(module: Module, cls: ast.ClassDef) -> List[Finding]:
    sites = _scan_class(cls)
    guarded: Set[str] = {attr for _m, attr, _s, locked in sites if locked}
    if not guarded:
        return []
    out: List[Finding] = []
    for method, attr, site, locked in sites:
        if locked or attr not in guarded or method == "__init__":
            continue
        if _is_lock_name(attr):
            continue
        out.append(Finding(
            path=module.path, line=site.lineno, col=site.col_offset,
            rule="NL301",
            message=f"{cls.name}.{attr} mutated in {method}() without "
                    f"holding the lock that guards it elsewhere",
            hint="this attribute is written under `with self.<lock>` in "
                 "another method — wrap this write too (the PR 8 _count "
                 "convention), or move it to __init__"))
    return out


def _check_single_writer(module: Module) -> List[Finding]:
    out: List[Finding] = []
    for cls in ast.walk(module.tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        for method in cls.body:
            if not isinstance(method, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                continue
            if method.name in _WORKER_METHODS:
                continue
            for node in ast.walk(method):
                if not isinstance(node, ast.Call):
                    continue
                name = dotted_name(node.func)
                if not name:
                    continue
                parts = name.split(".")
                if parts[-1] not in _ENGINE_ENTRIES:
                    continue
                # only receiver chains through the router / a session —
                # `job.future.update(...)`-style lookalikes stay clean
                if not any(p in ("router", "sess", "session")
                           for p in parts[:-1]):
                    continue
                out.append(Finding(
                    path=module.path, line=node.lineno,
                    col=node.col_offset, rule="NL302",
                    message=f"engine entry {name}() called from "
                            f"{cls.name}.{method.name}() — only the "
                            f"worker ({'/'.join(sorted(_WORKER_METHODS))}"
                            f") may drive the engine",
                    hint="route the work through the queue; the "
                         "single-writer invariant is what makes the "
                         "engine lock-free"))
    return out
