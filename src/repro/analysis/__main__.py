"""``python -m repro.analysis`` — the nucleuslint CLI (the CI gate).

Exit status is the contract: 0 = clean modulo the committed baseline,
1 = new findings (or stale baseline entries under ``--strict-stale``),
2 = usage error.  ``make lint-nucleus`` wraps the default invocation.

    python -m repro.analysis                     # lint src/repro
    python -m repro.analysis src/repro/serve     # subset
    python -m repro.analysis --json out.json     # machine-readable
    python -m repro.analysis --regen-baseline    # re-accept current state
    python -m repro.analysis --dead --dead-json dead.json
    python -m repro.analysis --list-rules
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List

from .baseline import (DEFAULT_BASELINE, apply_baseline, load_baseline,
                       write_baseline)
from .deadmod import dead_module_report
from .driver import load_project, rule_catalog, run_analysis


def main(argv: List[str] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="nucleuslint: jit/trace/concurrency lint for the "
                    "nucleus-decomposition reproduction")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/directories to lint (default: src/repro)")
    ap.add_argument("--json", metavar="FILE",
                    help="also write findings + summary as JSON "
                         "('-' for stdout)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help=f"baseline file (default {DEFAULT_BASELINE})")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline: report everything")
    ap.add_argument("--regen-baseline", action="store_true",
                    help="rewrite the baseline from current findings "
                         "(the diff is the review artifact) and exit 0")
    ap.add_argument("--only", action="append", default=None,
                    metavar="PREFIX",
                    help="restrict to rule-id prefixes (repeatable), "
                         "e.g. --only NL3")
    ap.add_argument("--dead", action="store_true",
                    help="also run the dead-module report")
    ap.add_argument("--dead-json", metavar="FILE",
                    help="write the dead-module report as JSON "
                         "(implies --dead)")
    ap.add_argument("--strict-stale", action="store_true",
                    help="fail when baseline entries no longer fire")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, desc in rule_catalog():
            print(f"{rule}  {desc}")
        return 0

    paths = args.paths or ["src/repro"]
    project = load_project(paths)
    findings = run_analysis(project, only=args.only)

    if args.regen_baseline:
        path = write_baseline(findings, args.baseline)
        print(f"nucleuslint: baseline regenerated -> {path} "
              f"({len(findings)} findings accepted)")
        return 0

    baseline = (load_baseline(args.baseline)
                if not args.no_baseline else None)
    if baseline is not None:
        new, stale = apply_baseline(findings, baseline)
    else:
        new, stale = findings, []

    for f in new:
        print(f.render())
    n_baselined = len(findings) - len(new)
    status = (f"nucleuslint: {len(new)} finding(s)"
              f" ({len(findings)} total, {n_baselined} baselined)")
    if stale:
        status += f"; {len(stale)} stale baseline entr(y/ies)"
        for path, rule, message in stale:
            print(f"stale baseline: {path}: {rule}: {message}")
    print(status)

    dead = None
    if args.dead or args.dead_json:
        dead = dead_module_report()
        print(f"dead modules: {len(dead['dead'])} of "
              f"{dead['n_modules']} unreachable from "
              f"core/serve/launch/benchmarks/tests")
        for line in dead["dead_summary"]:
            print(f"  {line}")
        print(f"nucleus-only view (core/serve roots): "
              f"{len(dead['nucleus_unreachable'])} modules outside the "
              f"nucleus product")
        for line in dead["nucleus_unreachable_summary"]:
            print(f"  {line}")
        if args.dead_json:
            with open(args.dead_json, "w") as f:
                json.dump(dead, f, indent=1, sort_keys=True)
                f.write("\n")

    if args.json:
        blob = {
            "tool": "nucleuslint",
            "paths": paths,
            "n_total": len(findings),
            "n_new": len(new),
            "n_baselined": n_baselined,
            "stale_baseline": [list(k) for k in stale],
            "findings": [f.to_dict() for f in new],
            "all_findings": [f.to_dict() for f in findings],
        }
        if dead is not None:
            blob["dead_modules"] = dead
        if args.json == "-":
            json.dump(blob, sys.stdout, indent=1, sort_keys=True)
            sys.stdout.write("\n")
        else:
            with open(args.json, "w") as f:
                json.dump(blob, f, indent=1, sort_keys=True)
                f.write("\n")

    if new:
        return 1
    if stale and args.strict_stale:
        return 1
    return 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except BrokenPipeError:
        # `... | head` closed our stdout; exit quietly like other CLIs
        sys.stderr.close()
        sys.exit(0)
