"""The ``Finding`` record + inline suppression parsing.

A finding is one rule violation at one source location; its identity for
baseline matching is ``(path, rule, message)`` — the line number is for
humans and editors, so unrelated edits that shift lines never invalidate
a committed baseline (``baseline.py`` has the count semantics).

Suppressions are inline comments, pylint-style but namespaced so the two
tools never fight over a line::

    x = bool(flag)          # nucleuslint: disable=NL101
    # nucleuslint: disable=NL102,NL103   (suppresses the NEXT line too)
    # nucleuslint: disable=all

A suppression on the finding's own line or the line directly above it
applies; ``all`` suppresses every rule.  Suppressions are deliberate,
reviewable markers — prefer them over baselining for code that is
*correct* but outside a rule's precision (the baseline is for accepted
legacy findings).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Sequence, Tuple

_SUPPRESS_RE = re.compile(
    r"#\s*nucleuslint:\s*disable=([A-Za-z0-9_,\s]+|all)")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation: file:line + rule id + message + fix hint."""

    path: str          # repo-relative, '/'-separated
    line: int          # 1-indexed
    col: int           # 0-indexed (ast convention)
    rule: str          # e.g. "NL101"
    message: str       # what is wrong, with the offending names inlined
    hint: str = ""     # how to fix it

    @property
    def key(self) -> Tuple[str, str, str]:
        """Baseline identity: line numbers drift, (path, rule, message)
        does not (messages inline the offending names, so two distinct
        violations in one file rarely collide; colliding ones share a
        baseline budget — see ``baseline.apply_baseline``)."""
        return (self.path, self.rule, self.message)

    def render(self) -> str:
        loc = f"{self.path}:{self.line}:{self.col + 1}"
        out = f"{loc}: {self.rule}: {self.message}"
        if self.hint:
            out += f"\n    hint: {self.hint}"
        return out

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


def parse_suppressions(lines: Sequence[str]) -> Dict[int, frozenset]:
    """Map 1-indexed line number -> rules suppressed AT that line.

    A ``# nucleuslint: disable=...`` comment covers its own line and the
    following line (the comment-above idiom); ``all`` becomes the
    sentinel ``{"all"}``.
    """
    out: Dict[int, frozenset] = {}
    for i, text in enumerate(lines, start=1):
        m = _SUPPRESS_RE.search(text)
        if not m:
            continue
        spec = m.group(1).strip()
        rules = (frozenset({"all"}) if spec == "all" else
                 frozenset(r.strip().upper()
                           for r in spec.split(",") if r.strip()))
        for line in (i, i + 1):
            out[line] = out.get(line, frozenset()) | rules
    return out


def is_suppressed(finding: Finding,
                  suppressions: Dict[int, frozenset]) -> bool:
    rules = suppressions.get(finding.line)
    return bool(rules) and ("all" in rules or finding.rule in rules)


def apply_suppressions(findings: List[Finding],
                       suppressions: Dict[int, frozenset]) -> List[Finding]:
    return [f for f in findings if not is_suppressed(f, suppressions)]
