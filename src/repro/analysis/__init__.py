"""nucleuslint: the repo's jit/trace/concurrency static-analysis engine.

Every correctness regression this reproduction has shipped and then fixed
by hand belongs to a statically detectable class: host-sync ``bool()``
calls inside compiled loops (the PR 2 ``connectivity.py`` rewrite),
Session bucket keys that silently materialized incidence (PR 7), and
unguarded mutation of shared ``Session`` counters under the threaded
server (PR 8).  The paper's contribution is making the hierarchy
computation *safely* parallel; this package enforces the reproduction's
analogous invariants mechanically, on every PR (DESIGN.md §12):

  * **NL1xx trace hygiene** — no host syncs or Python control flow on
    traced values inside ``jax.jit`` / ``lax.while_loop`` / ``lax.scan``
    / ``shard_map`` bodies.
  * **NL2xx recompile hazards** — jit keys must be shapes + declared
    statics: no per-call ``jax.jit`` closures, no value-varying
    captures, no unhashable static arguments.
  * **NL3xx concurrency** — attributes a class ever guards with its lock
    must be guarded at every write; engine access stays single-writer.
  * **NL4xx registry conformance** — a registered ``Backend`` may only
    touch the config knobs its ``BackendCapabilities`` declaration
    claims, so the derived legality matrix is verifiable, not trusted.

Pure stdlib (``ast`` + ``pathlib``): importable and runnable without jax,
so the CI lint lane needs no accelerator deps.  Entry points:

  ``python -m repro.analysis src/repro``          lint (text output)
  ``python -m repro.analysis --json out.json``    machine-readable
  ``python -m repro.analysis --regen-baseline``   re-accept current state
  ``python -m repro.analysis --dead``             dead-module report
  ``make lint-nucleus``                           the CI gate
"""
from .findings import Finding
from .driver import Project, run_analysis, load_project
from .baseline import load_baseline, write_baseline, apply_baseline
from .deadmod import dead_module_report

__all__ = [
    "Finding", "Project", "run_analysis", "load_project",
    "load_baseline", "write_baseline", "apply_baseline",
    "dead_module_report",
]
