"""``--dead``: modules unreachable from the product surface.

The growth seed carried whole LLM-era subtrees (``kernels/
flash_attention.py``, ``configs/minicpm_2b.py``, ...) that nothing in
the nucleus-decomposition product imports.  This report makes that
inventory explicit and keeps it in the bench artifact so reviewers see
the dead set shrink (or grow) per PR — it never deletes anything.

Reachability is an import-graph BFS over ``src/repro``:

  * **Roots** — every module under the product packages ``repro.core``,
    ``repro.serve``, ``repro.launch``, plus every ``repro.*`` module
    imported (textually, via AST) by files under ``benchmarks/`` and
    ``tests/``.
  * **Edges** — ``import x`` / ``from x import y`` statements, with
    relative imports resolved against the importing module's package;
    ``from pkg import name`` also targets ``pkg.name`` when that is a
    module (the lazy-import idiom inside function bodies counts — the
    walk covers the whole AST, not just top level).
  * **Dead** — modules never reached.  Packages whose every module is
    dead are summarized as ``pkg/*``.

Because ``repro.launch`` still drives the LLM-era train/serve/dryrun
lanes, most legacy modules are *reachable* under that definition; the
report therefore also carries a secondary ``nucleus_unreachable`` view —
modules unreachable from ``repro.core`` + ``repro.serve`` alone — which
is the actual LLM-era inventory a future removal PR would work from.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Sequence, Set

TOP = "repro"


def _discover(src_root: str) -> Dict[str, str]:
    """dotted module name -> file path for every .py under src_root
    (src_root is the directory CONTAINING the ``repro`` package)."""
    out: Dict[str, str] = {}
    pkg_root = os.path.join(src_root, TOP)
    for dirpath, dirnames, filenames in os.walk(pkg_root):
        dirnames[:] = sorted(d for d in dirnames if d != "__pycache__")
        for f in sorted(filenames):
            if not f.endswith(".py"):
                continue
            path = os.path.join(dirpath, f)
            rel = os.path.relpath(path, src_root)
            parts = rel[:-3].split(os.sep)
            if parts[-1] == "__init__":
                parts = parts[:-1]
            out[".".join(parts)] = path
    return out


def _imports_of(path: str, module: str) -> Set[str]:
    """Dotted names this file imports (absolute, ``repro.*`` only)."""
    with open(path, encoding="utf-8") as f:
        try:
            tree = ast.parse(f.read(), filename=path)
        except SyntaxError:
            return set()
    pkg_parts = module.split(".")
    is_pkg = os.path.basename(path) == "__init__.py"
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                out.add(alias.name)
        elif isinstance(node, ast.ImportFrom):
            if node.level:
                # relative: `from ..core import engine` in repro.serve.x
                # resolves against the containing package
                base_parts = pkg_parts if is_pkg else pkg_parts[:-1]
                cut = node.level - 1
                base = base_parts[:len(base_parts) - cut] if cut else \
                    base_parts
                prefix = ".".join(base)
                stem = f"{prefix}.{node.module}" if node.module else prefix
            else:
                stem = node.module or ""
            if stem:
                out.add(stem)
                for alias in node.names:
                    out.add(f"{stem}.{alias.name}")
    return {n for n in out if n == TOP or n.startswith(TOP + ".")}


def _external_roots(dirs: Sequence[str]) -> Set[str]:
    """``repro.*`` modules imported by .py files under ``dirs``
    (benchmarks/, tests/ — anything there keeps its imports alive)."""
    out: Set[str] = set()
    for d in dirs:
        if not os.path.isdir(d):
            continue
        for dirpath, dirnames, filenames in os.walk(d):
            dirnames[:] = sorted(x for x in dirnames
                                 if x not in ("__pycache__", ".git"))
            for f in sorted(filenames):
                if f.endswith(".py"):
                    path = os.path.join(dirpath, f)
                    out |= _imports_of(path, "external")
    return out


ROOT_PACKAGES = ("repro.core", "repro.serve", "repro.launch")
NUCLEUS_PACKAGES = ("repro.core", "repro.serve")


def dead_module_report(src_root: str = "src",
                       extra_root_dirs: Sequence[str] = ("benchmarks",
                                                         "tests"),
                       ) -> Dict[str, object]:
    """The dead-module inventory (JSON-ready; see module docstring)."""
    modules = _discover(src_root)
    imports = {m: _imports_of(p, m) for m, p in modules.items()}

    def resolve(name: str) -> List[str]:
        """Importing ``a.b.c`` reaches a.b.c AND executes a, a.b."""
        hits = []
        parts = name.split(".")
        for i in range(1, len(parts) + 1):
            prefix = ".".join(parts[:i])
            if prefix in modules:
                hits.append(prefix)
        return hits

    def bfs(roots: Set[str]) -> Set[str]:
        reachable: Set[str] = set()
        frontier = sorted(roots)
        while frontier:
            m = frontier.pop()
            if m in reachable:
                continue
            reachable.add(m)
            for name in imports.get(m, ()):
                for hit in resolve(name):
                    if hit not in reachable:
                        frontier.append(hit)
        return reachable

    def pkg_roots(prefixes: Sequence[str]) -> Set[str]:
        return {m for m in modules
                if m in prefixes or any(m.startswith(r + ".")
                                        for r in prefixes)}

    def summarize(dead: List[str], reachable: Set[str]) -> List[str]:
        # collapse fully-dead packages for the human summary
        by_pkg: Dict[str, List[str]] = {}
        for m in dead:
            pkg = m.rsplit(".", 1)[0] if "." in m else m
            by_pkg.setdefault(pkg, []).append(m)
        summary: List[str] = []
        for pkg, members in sorted(by_pkg.items()):
            live_in_pkg = any(x == pkg or x.startswith(pkg + ".")
                              for x in reachable)
            if not live_in_pkg and len(members) > 1:
                summary.append(f"{pkg}.* ({len(members)} modules)")
            else:
                summary.extend(members)
        return summary

    roots = pkg_roots(ROOT_PACKAGES)
    for name in _external_roots(extra_root_dirs):
        roots.update(resolve(name))
    reachable = bfs(roots)
    dead = sorted(m for m in modules if m not in reachable)

    nucleus_reachable = bfs(pkg_roots(NUCLEUS_PACKAGES))
    nucleus_dead = sorted(m for m in modules if m not in nucleus_reachable)

    return {
        "src_root": src_root,
        "roots": sorted(roots),
        "n_modules": len(modules),
        "n_reachable": len(reachable),
        "dead": dead,
        "dead_summary": summarize(dead, reachable),
        "dead_paths": [os.path.relpath(modules[m]).replace(os.sep, "/")
                       for m in dead],
        "nucleus_unreachable": nucleus_dead,
        "nucleus_unreachable_summary": summarize(nucleus_dead,
                                                 nucleus_reachable),
    }
