"""NL4xx registry conformance: backends only touch declared knobs.

``NucleusConfig.validate()`` derives the whole config x backend legality
matrix from each backend's ``BackendCapabilities.knobs`` declaration
(DESIGN.md §8) — the declaration is load-bearing, so it must be TRUE.
This rule makes it verifiable instead of trusted:

  NL401  a registered backend's ``run`` adapter (or a module-local
         helper it forwards ``config`` to) reads a knob the
         declaration does not claim.  Knob evidence is an attribute
         read on the config parameter: ``config.use_pallas`` ->
         ``pallas``, ``config.mesh`` -> ``mesh``, ``config.compress``
         -> ``compress``.  Reading an *undeclared* knob means the
         derived error messages lie ("backend X never runs it" while
         X's AST dispatches on it) and the planner's knob-binding rules
         route around a capability that actually exists.

The analysis is module-local and one-level transitive: it parses every
``register(_Registered(name=..., capabilities=BackendCapabilities(...,
knobs=frozenset({...})), _run=<adapter>))`` call, then scans the adapter
plus any same-module function the adapter calls with the config argument
(the ``_run_local`` pattern).  Over-declaring (a declared knob the AST
never reads) is NOT flagged — capabilities may legitimately precede the
wiring within a PR stack.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from .driver import Module, Project
from .findings import Finding
from .jaxast import dotted_name

CATALOG = [
    ("NL401", "registered Backend adapter reads a config knob its "
              "BackendCapabilities declaration does not claim"),
]

# config attribute -> declared knob name
KNOB_ATTRS = {"use_pallas": "pallas", "mesh": "mesh", "compress": "compress"}


def _knob_strings(node: ast.AST) -> Set[str]:
    """String constants anywhere under a knobs=... expression
    (handles ``frozenset({"a", "b"})``, ``frozenset()``, bare sets)."""
    out: Set[str] = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
            out.add(sub.value)
    return out


def _registered_backends(tree: ast.Module
                         ) -> List[Tuple[str, Set[str], str, ast.Call]]:
    """(backend name, declared knobs, adapter function name, call site)
    for each ``register(...)`` in the module."""
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        callee = dotted_name(node.func)
        if not callee or callee.split(".")[-1] != "register":
            continue
        if not node.args or not isinstance(node.args[0], ast.Call):
            continue
        entry = node.args[0]
        name = adapter = None
        knobs: Set[str] = set()
        for kw in entry.keywords:
            if kw.arg == "name" and isinstance(kw.value, ast.Constant):
                name = kw.value.value
            elif kw.arg == "_run":
                adapter = dotted_name(kw.value)
            elif kw.arg == "capabilities" \
                    and isinstance(kw.value, ast.Call):
                for ckw in kw.value.keywords:
                    if ckw.arg == "knobs":
                        knobs = _knob_strings(ckw.value)
        if name and adapter and "." not in adapter:
            out.append((name, knobs, adapter, node))
    return out


def _config_param(func: ast.AST) -> Optional[str]:
    """The name of the config-carrying parameter (by convention the one
    named ``config`` / ``cfg``)."""
    args = func.args
    for p in args.posonlyargs + args.args + args.kwonlyargs:
        if p.arg in ("config", "cfg"):
            return p.arg
    return None


def _knob_reads(func: ast.AST, param: str
                ) -> List[Tuple[str, ast.Attribute]]:
    out = []
    for node in ast.walk(func):
        if isinstance(node, ast.Attribute) \
                and isinstance(node.value, ast.Name) \
                and node.value.id == param and node.attr in KNOB_ATTRS:
            out.append((KNOB_ATTRS[node.attr], node))
    return out


def _forwarded_helpers(func: ast.AST, param: str,
                       defs: Dict[str, ast.AST]) -> List[ast.AST]:
    """Same-module functions ``func`` calls with the config argument."""
    out = []
    for node in ast.walk(func):
        if not isinstance(node, ast.Call):
            continue
        callee = dotted_name(node.func)
        if not callee or "." in callee or callee not in defs:
            continue
        passes_config = any(
            isinstance(a, ast.Name) and a.id == param for a in node.args
        ) or any(
            isinstance(kw.value, ast.Name) and kw.value.id == param
            for kw in node.keywords)
        if passes_config:
            out.append(defs[callee])
    return out


def check(module: Module, project: Project) -> List[Finding]:
    backends = _registered_backends(module.tree)
    if not backends:
        return []
    defs: Dict[str, ast.AST] = {
        n.name: n for n in module.tree.body
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
    findings: List[Finding] = []
    for name, knobs, adapter, _site in backends:
        func = defs.get(adapter)
        if func is None:
            continue
        param = _config_param(func)
        if param is None:
            continue
        scan: List[Tuple[ast.AST, str]] = [(func, param)]
        for helper in _forwarded_helpers(func, param, defs):
            hp = _config_param(helper)
            if hp is not None:
                scan.append((helper, hp))
        for target, p in scan:
            for knob, site in _knob_reads(target, p):
                if knob in knobs:
                    continue
                where = getattr(target, "name", adapter)
                findings.append(Finding(
                    path=module.path, line=site.lineno,
                    col=site.col_offset, rule="NL401",
                    message=f"backend {name!r} reads config knob "
                            f"{site.attr!r} in {where}() but its "
                            f"BackendCapabilities declares "
                            f"knobs={sorted(knobs)}",
                    hint="add the knob to the declaration (legality is "
                         "derived from it) or stop dispatching on it — "
                         "the matrix must match the AST"))
    return findings
