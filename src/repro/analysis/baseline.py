"""The committed findings baseline: legacy findings don't block CI.

The baseline is a JSON file (``.nucleuslint-baseline.json`` at the repo
root) listing findings that predate a rule (or are accepted legacy —
e.g. the LLM-era ``launch/dryrun.py`` jit-per-call sites) so the CI gate
fails only on NEW findings.  Matching ignores line numbers: an entry is
``(path, rule, message)`` and the file stores a *count* per key, so two
identical violations in one file consume two baseline slots — fixing one
of them shrinks the next ``--regen-baseline`` diff instead of hiding the
survivor.

``--regen-baseline`` rewrites the file from the current findings (the
review artifact for intentionally accepting a finding is the JSON diff,
same contract as ``tools/regen_golden.py``).  Stale entries — baselined
findings that no longer fire — are reported by ``apply_baseline`` so the
file shrinks monotonically instead of fossilizing.
"""
from __future__ import annotations

import json
import os
from collections import Counter
from typing import Dict, List, Tuple

from .findings import Finding

BASELINE_FORMAT = "repro.nucleuslint-baseline"
BASELINE_VERSION = 1
DEFAULT_BASELINE = ".nucleuslint-baseline.json"

Key = Tuple[str, str, str]


def load_baseline(path: str) -> Counter:
    """Key -> allowed count.  A missing file is an empty baseline (first
    run of a fresh checkout must still gate on everything)."""
    if not os.path.exists(path):
        return Counter()
    with open(path) as f:
        blob = json.load(f)
    if blob.get("format") != BASELINE_FORMAT:
        raise ValueError(
            f"{path}: not a nucleuslint baseline (format="
            f"{blob.get('format')!r}); regenerate it with "
            f"python -m repro.analysis --regen-baseline")
    out: Counter = Counter()
    for e in blob.get("findings", []):
        out[(e["path"], e["rule"], e["message"])] += int(e.get("count", 1))
    return out


def write_baseline(findings: List[Finding], path: str) -> str:
    """Serialize current findings as the new baseline (sorted, counted —
    the diff IS the review artifact)."""
    counts: Counter = Counter(f.key for f in findings)
    lines: Dict[Key, int] = {}
    for f in sorted(findings):
        lines.setdefault(f.key, f.line)
    entries = [
        {"path": p, "rule": r, "message": m, "count": c,
         "line": lines[(p, r, m)]}   # informational only, not matched
        for (p, r, m), c in sorted(counts.items())]
    blob = {"format": BASELINE_FORMAT, "version": BASELINE_VERSION,
            "findings": entries}
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(blob, f, indent=1, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)
    return path


def apply_baseline(findings: List[Finding], baseline: Counter
                   ) -> Tuple[List[Finding], List[Key]]:
    """Split findings into (new, stale-baseline-keys).

    Each finding consumes one slot of its baseline key's count; findings
    past the count (or unknown keys) are NEW and gate CI.  Keys with
    unconsumed slots are STALE — the violation was fixed, so the entry
    should leave the baseline at the next ``--regen-baseline``.
    """
    budget = Counter(baseline)
    new: List[Finding] = []
    for f in sorted(findings):
        if budget.get(f.key, 0) > 0:
            budget[f.key] -= 1
        else:
            new.append(f)
    stale = sorted(k for k, c in budget.items() if c > 0)
    return new, stale
