"""NL1xx trace hygiene: no host syncs inside traced bodies.

The PR 1–2 bug class: ``bool(mask.any())`` inside what became the
compiled peel loop forced a device sync per round and, worse, silently
baked the *first call's* value into the trace.  Inside a traced context
(see ``jaxast``) a value reachable from a traced parameter must never
flow into a Python-level consumer:

  NL101  host-sync call — ``bool()`` / ``int()`` / ``float()`` /
         ``complex()`` / ``.item()`` / ``.tolist()`` / ``np.asarray()``
         / ``np.array()`` on a traced value.  Under ``jax.jit`` these
         raise ``TracerConversionError`` at trace time; inside a
         ``lax.while_loop`` body reached through other jit code they can
         instead silently constant-fold.  Either way the code is wrong.
  NL102  Python control flow — ``if`` / ``while`` / ``assert`` (or a
         ternary) testing a traced value; the branch is resolved once at
         trace time, not per element.  Use ``jnp.where`` / ``lax.cond``.
  NL103  ``len()`` on a traced value.  Legal (returns the static leading
         dim) but misleading next to NL101's genuine syncs — prefer the
         explicitly-static ``x.shape[0]``.

``.shape`` / ``.ndim`` / ``.dtype`` accesses launder taint (static under
tracing), and ``static_argnames`` parameters never seed it, so the
engine's ``if spec is not None and fused:`` idiom stays clean.
"""
from __future__ import annotations

import ast
from typing import List

from .driver import Module, Project
from .findings import Finding
from .jaxast import (FUNC_NODES, TaintEnv, dotted_name, expand_contexts,
                     find_traced_contexts)

CATALOG = [
    ("NL101", "host-sync call (bool/int/float/.item/np.asarray) on a "
              "traced value inside a traced context"),
    ("NL102", "Python if/while/assert on a traced value inside a traced "
              "context"),
    ("NL103", "len() on a traced value (static but misleading; use "
              ".shape[0])"),
]

_SYNC_BUILTINS = {"bool", "int", "float", "complex"}
_SYNC_METHODS = {"item", "tolist", "__bool__", "__index__"}
_SYNC_NP = {"asarray", "array", "asanyarray"}
_NP_MODULES = {"np", "numpy", "onp"}


def _own_nodes(func_node):
    """Walk a context body without descending into nested functions
    (those are separate contexts with their own taint)."""
    stack = list(ast.iter_child_nodes(func_node))
    while stack:
        n = stack.pop()
        yield n
        if isinstance(n, FUNC_NODES):
            continue
        stack.extend(ast.iter_child_nodes(n))


def check(module: Module, project: Project) -> List[Finding]:
    findings: List[Finding] = []
    contexts = expand_contexts(find_traced_contexts(module.tree))
    for ctx in contexts:
        env = TaintEnv(ctx)        # taint already propagated by expand
        where = f"in {ctx.name} ({ctx.reason})"
        for node in _own_nodes(ctx.node):
            if isinstance(node, ast.Call):
                f = _check_call(module, env, node, where)
                if f:
                    findings.append(f)
            elif isinstance(node, (ast.If, ast.While, ast.Assert,
                                   ast.IfExp)):
                test = node.test
                if env.expr_tainted(test):
                    kind = type(node).__name__.lower().replace("exp", "-expr")
                    findings.append(Finding(
                        path=module.path, line=test.lineno,
                        col=test.col_offset, rule="NL102",
                        message=f"Python {kind} on traced value {where}",
                        hint="branch resolves once at trace time; use "
                             "jnp.where / lax.cond / lax.while_loop"))
    return findings


def _check_call(module: Module, env: TaintEnv, node: ast.Call,
                where: str) -> Finding | None:
    fn = node.func
    name = dotted_name(fn)
    # x.item() / x.tolist() — sync iff the receiver is traced
    if isinstance(fn, ast.Attribute) and fn.attr in _SYNC_METHODS:
        if env.expr_tainted(fn.value):
            return Finding(
                path=module.path, line=node.lineno, col=node.col_offset,
                rule="NL101",
                message=f".{fn.attr}() on traced value {where}",
                hint="device sync under trace; keep the value on device "
                     "or hoist it out of the traced region")
    if not node.args:
        return None
    arg = node.args[0]
    if name in _SYNC_BUILTINS and env.expr_tainted(arg):
        return Finding(
            path=module.path, line=node.lineno, col=node.col_offset,
            rule="NL101",
            message=f"{name}() on traced value {where}",
            hint="raises TracerConversionError under jit; use jnp ops "
                 "(jnp.where, .astype) instead of host conversion")
    if name and "." in name:
        head, _, last = name.rpartition(".")
        if head in _NP_MODULES and last in _SYNC_NP \
                and env.expr_tainted(arg):
            return Finding(
                path=module.path, line=node.lineno, col=node.col_offset,
                rule="NL101",
                message=f"{name}() on traced value {where}",
                hint="materializes the array on host; use jnp.asarray or "
                     "move the conversion outside the traced region")
    if name == "len" and env.expr_tainted(arg):
        return Finding(
            path=module.path, line=node.lineno, col=node.col_offset,
            rule="NL103",
            message=f"len() on traced value {where}",
            hint="static but reads like a sync; prefer x.shape[0]")
    return None
