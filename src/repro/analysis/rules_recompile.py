"""NL2xx recompile hazards: jit keys must be shapes + declared statics.

The PR 7/8 warm path (Session pow2 buckets, the persistent compile
cache, Router prewarm) is only warm if calling the same logical plan
twice hits the same executable.  Three ways the codebase has broken (or
nearly broken) that:

  NL201  ``jax.jit(...)`` called inside a function body with no
         memoization: a fresh ``jit`` wrapper per call means a fresh
         trace per call (the ``distributed._jitted_decomposition``
         docstring records fixing exactly this; its
         ``functools.lru_cache`` wrapper is the sanctioned pattern and
         is exempt).  Module-level ``_fn = jax.jit(f)`` is fine.
  NL202  value-varying capture inside a traced body or a warm-path key
         function: ``time.*()``, ``random.*``, ``np.random.*``,
         ``os.environ`` / ``os.getenv``, ``datetime.now`` — the value is
         baked at trace time (trace body) or varies the cache key per
         call (key function).  Warm-path key functions are the
         ``key`` / ``bucket`` / ``canonical`` / ``plan`` -named
         functions of ``core/session.py`` and ``serve/cache.py``.
  NL203  unhashable literal (list / dict / set display) passed for a
         parameter that some same-module jit declares in
         ``static_argnames`` — statics are hashed into the jit key, so
         this raises at call time (or, with a mutable default on the
         decorated def itself, whenever the default is used).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set

from .driver import Module, Project
from .findings import Finding
from .jaxast import (FUNC_NODES, dotted_name, expand_contexts,
                     find_traced_contexts, is_jit_name,
                     jit_decorator_statics)

CATALOG = [
    ("NL201", "jax.jit called per-invocation inside a function body "
              "without memoization (fresh trace every call)"),
    ("NL202", "value-varying capture (time/random/os.environ) inside a "
              "traced body or warm-path key function"),
    ("NL203", "unhashable literal bound to a declared static_argnames "
              "parameter"),
]

_MEMO_DECORATORS = {"lru_cache", "cache", "cached_property"}
_VARYING_CALLS = {
    "time.time", "time.monotonic", "time.perf_counter", "time.time_ns",
    "os.getenv", "os.urandom", "uuid.uuid4", "id",
}
_VARYING_PREFIXES = ("random.", "np.random.", "numpy.random.",
                     "secrets.", "datetime.datetime.now",
                     "datetime.date.today")
_WARM_FILES = ("core/session.py", "serve/cache.py")
_KEY_NAME_PARTS = ("key", "bucket", "canonical", "plan")
_UNHASHABLE = (ast.List, ast.Dict, ast.Set, ast.DictComp, ast.SetComp,
               ast.ListComp)


def _is_memoized(func: ast.AST) -> bool:
    for dec in getattr(func, "decorator_list", []):
        name = dotted_name(dec if not isinstance(dec, ast.Call)
                           else dec.func)
        if name and name.split(".")[-1] in _MEMO_DECORATORS:
            return True
    return False


def _enclosing_functions(tree: ast.Module):
    """Yield (func_node, node) for every node with its innermost
    enclosing function (module-level nodes are skipped)."""
    def walk(node, owner):
        for child in ast.iter_child_nodes(node):
            next_owner = owner
            if isinstance(child, FUNC_NODES):
                next_owner = child
            elif owner is not None:
                yield owner, child
            yield from walk(child, next_owner)
    yield from walk(tree, None)


def _varying_reason(node: ast.AST) -> str:
    """Non-empty description when ``node`` reads a value-varying
    source."""
    if isinstance(node, ast.Call):
        name = dotted_name(node.func)
        if name:
            if name in _VARYING_CALLS:
                return f"{name}()"
            if any(name.startswith(p) for p in _VARYING_PREFIXES):
                return f"{name}()"
    name = dotted_name(node)
    if name and (name == "os.environ" or name.startswith("os.environ.")):
        return "os.environ"
    return ""


def check(module: Module, project: Project) -> List[Finding]:
    findings: List[Finding] = []
    findings.extend(_check_jit_per_call(module))
    findings.extend(_check_varying(module))
    findings.extend(_check_statics(module))
    return findings


def _check_jit_per_call(module: Module) -> List[Finding]:
    out: List[Finding] = []
    for owner, node in _enclosing_functions(module.tree):
        if not isinstance(node, ast.Call):
            continue
        if not is_jit_name(dotted_name(node.func)):
            continue
        if _is_memoized(owner):
            continue
        fn_name = getattr(owner, "name", "<lambda>")
        target = dotted_name(node.args[0]) if node.args else None
        what = f"jax.jit({target})" if target else "jax.jit(...)"
        out.append(Finding(
            path=module.path, line=node.lineno, col=node.col_offset,
            rule="NL201",
            message=f"{what} constructed inside {fn_name}() — fresh "
                    f"trace on every call",
            hint="hoist to module level, or memoize the wrapper with "
                 "functools.lru_cache (see "
                 "core/distributed._jitted_decomposition)"))
    return out


def _check_varying(module: Module) -> List[Finding]:
    out: List[Finding] = []
    # (a) inside traced bodies: the value is frozen at trace time
    contexts = expand_contexts(find_traced_contexts(module.tree))
    seen: Set[int] = set()
    for ctx in contexts:
        for node in ast.walk(ctx.node):
            if id(node) in seen:
                continue
            reason = _varying_reason(node)
            if reason:
                seen.add(id(node))
                out.append(Finding(
                    path=module.path, line=node.lineno,
                    col=node.col_offset, rule="NL202",
                    message=f"value-varying capture {reason} inside "
                            f"traced {ctx.name} ({ctx.reason})",
                    hint="the value is baked into the trace at compile "
                         "time; pass it as an argument instead"))
    # (b) warm-path key functions: the key must be a pure function of
    # shapes + declared statics
    if module.path.endswith(_WARM_FILES):
        for func in ast.walk(module.tree):
            if not isinstance(func, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            if not any(p in func.name.lower() for p in _KEY_NAME_PARTS):
                continue
            for node in ast.walk(func):
                if id(node) in seen:
                    continue
                reason = _varying_reason(node)
                if reason:
                    seen.add(id(node))
                    out.append(Finding(
                        path=module.path, line=node.lineno,
                        col=node.col_offset, rule="NL202",
                        message=f"value-varying {reason} in warm-path "
                                f"key function {func.name}()",
                        hint="jit/cache keys must depend only on shapes "
                             "and declared statics or the warm pool "
                             "never hits"))
    return out


def _declared_statics(module: Module) -> Dict[str, Set[str]]:
    """function name -> its jit-declared static parameter names."""
    out: Dict[str, Set[str]] = {}
    for node in ast.walk(module.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for dec in node.decorator_list:
            statics = jit_decorator_statics(dec)
            if statics:
                out[node.name] = statics
    return out


def _check_statics(module: Module) -> List[Finding]:
    out: List[Finding] = []
    statics_by_fn = _declared_statics(module)
    # mutable default on a static parameter of the decorated def itself
    for node in ast.walk(module.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        statics = statics_by_fn.get(node.name)
        if not statics:
            continue
        args = node.args
        pos = args.posonlyargs + args.args
        for arg, default in zip(pos[len(pos) - len(args.defaults):],
                                args.defaults):
            if arg.arg in statics and isinstance(default, _UNHASHABLE):
                out.append(Finding(
                    path=module.path, line=default.lineno,
                    col=default.col_offset, rule="NL203",
                    message=f"unhashable default for static parameter "
                            f"{arg.arg!r} of {node.name}()",
                    hint="statics are hashed into the jit key; use a "
                         "tuple / frozenset / None sentinel"))
        for arg, default in zip(args.kwonlyargs, args.kw_defaults):
            if default is not None and arg.arg in statics \
                    and isinstance(default, _UNHASHABLE):
                out.append(Finding(
                    path=module.path, line=default.lineno,
                    col=default.col_offset, rule="NL203",
                    message=f"unhashable default for static parameter "
                            f"{arg.arg!r} of {node.name}()",
                    hint="statics are hashed into the jit key; use a "
                         "tuple / frozenset / None sentinel"))
    if not statics_by_fn:
        return out
    # unhashable literal at a call site, bound by keyword to a static
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.Call):
            continue
        callee = dotted_name(node.func)
        if not callee:
            continue
        statics = statics_by_fn.get(callee.split(".")[-1])
        if not statics:
            continue
        for kw in node.keywords:
            if kw.arg in statics and isinstance(kw.value, _UNHASHABLE):
                out.append(Finding(
                    path=module.path, line=kw.value.lineno,
                    col=kw.value.col_offset, rule="NL203",
                    message=f"unhashable literal for static parameter "
                            f"{kw.arg!r} in call to {callee}()",
                    hint="statics are hashed into the jit key; pass a "
                         "tuple / frozenset instead"))
    return out
