import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST precede every other import: jax fixes the device
# count at first initialization, and the production meshes below need 512
# placeholder host devices (2 pods x 16 x 16).

import argparse
import dataclasses
import json
import re
import time
from functools import partial
from math import comb
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_arch, all_archs, ArchSpec, ShapeCell
from repro.core.distributed import make_sharded_decomposition
from repro.distributed import sharding as shard_rules
from repro.launch import steps as S
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.optim import adamw

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "benchmarks", "artifacts")

COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes of every collective in post-SPMD HLO text."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m or "-done(" in line:
            continue  # -done carries no new payload
        op = m.group(1)
        shapes = SHAPE_RE.findall(line)
        if not shapes:
            continue
        # first match = result; remaining inside parens = operands.  Sum the
        # operands (the data actually put on the wire); if operand shapes are
        # not printed fall back to the result shape.
        paren = line[m.end() - 1:]
        operands = SHAPE_RE.findall(paren)
        use = operands if operands else shapes[:1]
        out[op] = out.get(op, 0) + sum(_shape_bytes(d, s) for d, s in use)
    return out


# ---------------------------------------------------------------------------
# per-family lowering
# ---------------------------------------------------------------------------

def _abstract(fn, *args, **kwargs):
    return jax.eval_shape(fn, *args, **kwargs)


def _gnn_cfg_for_cell(spec: ArchSpec, cell: ShapeCell):
    cfg = spec.make_config()
    d = cell.dims
    node_level = cell.name != "molecule"
    n_out = d.get("n_classes", 1) if node_level else 1
    kw = dict(cfg.__dict__)
    kw["d_in"] = d["d_feat"]
    if "n_classes" in kw:
        kw["n_classes"] = n_out
        if "graph_level" in kw:
            kw["graph_level"] = not node_level
    if "n_out" in kw:
        kw["n_out"] = n_out
    return cfg.__class__(**kw)


def lower_cell(spec: ArchSpec, cell: ShapeCell, mesh: Mesh,
               opt_overrides: Optional[Dict[str, Any]] = None):
    """Lower + compile one (arch x shape x mesh) cell; returns artifacts."""
    opt_cfg = adamw.AdamWConfig()
    key = jax.random.PRNGKey(0)
    dp = shard_rules.data_axes(mesh)

    if spec.family == "lm":
        cfg = spec.make_config()
        if opt_overrides:
            cfg = dataclasses.replace(cfg, **opt_overrides)
        params_a = _abstract(lambda k: T.init_params(k, cfg), key)
        rules = shard_rules.lm_param_rules(mesh, moe=cfg.moe is not None,
                                           moe_ep_data=cfg.moe_ep_data)
        p_spec = shard_rules.tree_specs(params_a, rules, mesh)
        p_sh = shard_rules.shard_tree(p_spec, mesh)
        specs = spec.input_specs(cfg, cell)
        if cell.kind == "train":
            opt_a = _abstract(adamw.init_state, params_a)
            o_sh = jax.tree.map(
                lambda l: NamedSharding(mesh, P()) if l.ndim == 0 else None,
                opt_a)
            # moments shard exactly like params
            o_sh = adamw.AdamWState(
                step=NamedSharding(mesh, P()),
                mu=jax.tree.map(lambda s: s, p_sh), nu=jax.tree.map(lambda s: s, p_sh))
            b_sh = {k: NamedSharding(mesh, P(dp, None))
                    for k in specs["batch"]}
            fn = partial(S.lm_train_step, cfg=cfg, opt_cfg=opt_cfg)
            jfn = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh),
                          out_shardings=(p_sh, o_sh, None))
            lowered = jfn.lower(params_a, opt_a, specs["batch"])
        elif cell.kind == "prefill":
            b_sh = {"tokens": NamedSharding(mesh, P(dp, None))}
            fn = partial(S.lm_prefill_step, cfg=cfg)
            jfn = jax.jit(fn, in_shardings=(p_sh, b_sh))
            lowered = jfn.lower(params_a, specs["batch"])
        else:  # decode
            c_spec = shard_rules.lm_cache_spec(mesh, cfg.n_kv_heads,
                                               cfg.mla is not None)
            cache_a = specs["cache"]
            c_sh = tuple(
                NamedSharding(mesh, shard_rules.safe_spec(
                    a.shape, list(sp), mesh))
                for a, sp in zip(cache_a, c_spec))
            tok_sh = NamedSharding(mesh, P(dp, None))
            fn = partial(S.lm_decode_step, cfg=cfg)
            jfn = jax.jit(fn, in_shardings=(p_sh, tok_sh, c_sh,
                                            NamedSharding(mesh, P())),
                          out_shardings=(None, c_sh, None))
            lowered = jfn.lower(params_a, specs["tokens"], cache_a,
                                specs["cache_len"])

    elif spec.family == "gnn":
        cfg = _gnn_cfg_for_cell(spec, cell)
        arch = spec.arch_id
        mod = S._GNN[arch]
        params_a = _abstract(lambda k: mod.init_params(k, cfg), key)
        p_sh = shard_rules.shard_tree(
            shard_rules.tree_specs(params_a, shard_rules.gnn_rules(mesh),
                                   mesh), mesh)
        specs = spec.input_specs(cfg, cell)
        shard_nodes = cell.dims.get("n_nodes", 0) >= 1_000_000
        bspecs = shard_rules.gnn_batch_specs(mesh, shard_nodes)
        b_sh = {k: NamedSharding(
            mesh, shard_rules.safe_spec(v.shape, list(bspecs.get(
                k, P())) if bspecs.get(k) else [], mesh))
            for k, v in specs["batch"].items()}
        opt_a = _abstract(adamw.init_state, params_a)
        o_sh = adamw.AdamWState(step=NamedSharding(mesh, P()),
                                mu=jax.tree.map(lambda s: s, p_sh),
                                nu=jax.tree.map(lambda s: s, p_sh))
        fn = partial(S.gnn_train_step, cfg=cfg, arch=arch,
                     n_graphs=specs["n_graphs"],
                     node_level=specs["node_level"], opt_cfg=opt_cfg)
        jfn = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh),
                      out_shardings=(p_sh, o_sh, None))
        lowered = jfn.lower(params_a, opt_a, specs["batch"])

    elif spec.family == "recsys":
        cfg = spec.make_config()
        from repro.models import din as DINM
        params_a = _abstract(lambda k: DINM.init_params(k, cfg), key)
        p_sh = shard_rules.shard_tree(
            shard_rules.tree_specs(params_a, shard_rules.din_rules(mesh),
                                   mesh), mesh)
        specs = spec.input_specs(cfg, cell)
        if cell.kind == "retrieval":
            all_ax = tuple(mesh.axis_names)
            b_sh = {k: NamedSharding(
                mesh, P(all_ax) if k.startswith("cand") else P())
                for k in specs["batch"]}
            fn = partial(S.din_retrieval_step, cfg=cfg)
            jfn = jax.jit(fn, in_shardings=(p_sh, b_sh))
            lowered = jfn.lower(params_a, specs["batch"])
        elif cell.kind == "serve":
            b_sh = {k: NamedSharding(
                mesh, shard_rules.safe_spec(
                    v.shape, [shard_rules.data_axes(mesh)], mesh))
                for k, v in specs["batch"].items()}
            fn = partial(S.din_serve_step, cfg=cfg)
            jfn = jax.jit(fn, in_shardings=(p_sh, b_sh))
            lowered = jfn.lower(params_a, specs["batch"])
        else:
            b_sh = {k: NamedSharding(
                mesh, shard_rules.safe_spec(
                    v.shape, [shard_rules.data_axes(mesh)], mesh))
                for k, v in specs["batch"].items()}
            opt_a = _abstract(adamw.init_state, params_a)
            o_sh = adamw.AdamWState(step=NamedSharding(mesh, P()),
                                    mu=jax.tree.map(lambda s: s, p_sh),
                                    nu=jax.tree.map(lambda s: s, p_sh))
            fn = partial(S.din_train_step, cfg=cfg, opt_cfg=opt_cfg)
            jfn = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh),
                          out_shardings=(p_sh, o_sh, None))
            lowered = jfn.lower(params_a, opt_a, specs["batch"])

    elif spec.family == "core":
        from repro.configs.nucleus import make_peel_schedule, max_rounds_bound
        d = cell.dims
        n_dev = int(np.prod(mesh.devices.shape))
        n_s_pad = -(-d["n_s"] // n_dev) * n_dev
        cfg = spec.make_config()
        cfg.update(opt_overrides or {})
        sched = make_peel_schedule(cfg, cell)
        fn, in_sh, out_sh = make_sharded_decomposition(
            mesh, d["n_r"], n_s_pad, d["C"], sched,
            max_rounds=max_rounds_bound(cfg, cell),
            compress=bool(cfg.get("compress", False)))
        jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jfn.lower(
            jax.ShapeDtypeStruct((n_s_pad, d["C"]), jnp.int32),
            jax.ShapeDtypeStruct((d["n_r"],), jnp.int32))
    else:
        raise ValueError(spec.family)

    return lowered


def _extrapolate_lm_cost(spec: ArchSpec, cell: ShapeCell, mesh: Mesh,
                         opt_overrides: Optional[Dict[str, Any]] = None):
    """True LM cost totals via layer extrapolation.

    XLA's HloCostAnalysis counts scan bodies once, so the scanned production
    program under-reports per-layer work by ~n_layers.  Lowering the SAME
    model at n_layers=1 and n_layers=2 with cost_unroll=True (cheap: tiny
    HLO) gives exact per-layer deltas:  cost(L) = c1 + (L-1) * (c2 - c1).
    Exact for layer-uniform programs (all archs here); collectives dicts are
    extrapolated the same way per op type.
    """
    cfg_full = spec.make_config()
    if opt_overrides:
        cfg_full = dataclasses.replace(cfg_full, **opt_overrides)
    L = cfg_full.n_layers
    outs = []
    for nl in (1, 2):
        cfg_n = dataclasses.replace(cfg_full, n_layers=nl, cost_unroll=True)
        spec_n = dataclasses.replace(spec, make_config=lambda c=cfg_n: c)
        lowered = lower_cell(spec_n, cell, mesh, None)
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        coll = collective_bytes(compiled.as_text())
        outs.append((cost, coll))
    (c1, k1), (c2, k2) = outs

    def extr(a, b):
        return a + (L - 1) * (b - a)

    cost_x = {key: extr(c1.get(key, 0.0) or 0.0, c2.get(key, 0.0) or 0.0)
              for key in ("flops", "bytes accessed", "transcendentals")}
    ops = set(k1) | set(k2)
    coll_x = {op: int(extr(k1.get(op, 0), k2.get(op, 0))) for op in ops}
    return cost_x, coll_x


def _mesh_context(mesh: Mesh):
    """Mesh context manager across jax versions: jax.set_mesh exists from
    0.6 on; in 0.4.x the Mesh object itself is the context manager."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             opt_overrides: Optional[Dict[str, Any]] = None,
             tag: str = "") -> Dict[str, Any]:
    spec = get_arch(arch_id)
    cell = spec.shape(shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    result: Dict[str, Any] = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_name,
        "kind": cell.kind, "tag": tag,
    }
    if cell.skip_reason:
        result["status"] = "skipped"
        result["skip_reason"] = cell.skip_reason
        return result
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    # mesh context: required for PartitionSpec-based sharding constraints
    # inside the models (jax.lax.with_sharding_constraint)
    with _mesh_context(mesh):
        lowered = lower_cell(spec, cell, mesh, opt_overrides)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    cost_x = coll_x = None
    if spec.family == "lm":
        try:
            with _mesh_context(mesh):
                cost_x, coll_x = _extrapolate_lm_cost(spec, cell, mesh,
                                                      opt_overrides)
        except Exception as e:
            result["extrapolation_error"] = repr(e)[:500]
    result.update({
        "status": "ok",
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "cost": {k: cost.get(k) for k in
                 ("flops", "bytes accessed", "transcendentals")
                 if k in cost},
        "collectives": coll,
        "collective_bytes_total": int(sum(coll.values())),
        "n_devices": int(np.prod(mesh.devices.shape)),
    })
    if cost_x is not None:
        result["cost_extrapolated"] = cost_x
        result["collectives_extrapolated"] = coll_x
        result["collective_bytes_total_extrapolated"] = int(
            sum(coll_x.values()))
    return result


def artifact_path(arch_id: str, shape_name: str, multi_pod: bool,
                  tag: str = "") -> str:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    suffix = f"-{tag}" if tag else ""
    return os.path.join(ARTIFACT_DIR,
                        f"{arch_id}--{shape_name}--{mesh_name}{suffix}.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=False)
    ap.add_argument("--shape", required=False)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--tag", default="")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    if args.list:
        for aid, spec in sorted(all_archs().items()):
            for c in spec.shapes:
                skip = f"  [skip: {bool(c.skip_reason)}]" if c.skip_reason else ""
                print(f"{aid:24s} {c.name:16s} {c.kind}{skip}")
        return

    meshes = {"single": [False], "multi": [True], "both": [False, True]}
    archs = [args.arch] if args.arch else sorted(all_archs())
    for aid in archs:
        spec = get_arch(aid)
        shapes = ([args.shape] if args.shape
                  else [c.name for c in spec.shapes])
        for sname in shapes:
            for mp in meshes[args.mesh]:
                path = artifact_path(aid, sname, mp, args.tag)
                if os.path.exists(path) and not args.force:
                    # error artifacts are retried, not treated as cached:
                    # an unreadable/failed record must never mask a cell.
                    try:
                        with open(path) as f:
                            prev_status = json.load(f).get("status")
                    except (OSError, ValueError):
                        prev_status = None
                    if prev_status in ("ok", "skipped"):
                        print(f"SKIP (cached) {path}")
                        continue
                    print(f"RERUN (cached status={prev_status}) {path}")
                print(f"== {aid} x {sname} x "
                      f"{'multi' if mp else 'single'} ==", flush=True)
                try:
                    res = run_cell(aid, sname, mp, tag=args.tag)
                except Exception as e:  # record failures as artifacts too
                    res = {"arch": aid, "shape": sname,
                           "mesh": "pod2x16x16" if mp else "pod16x16",
                           "status": "error", "error": repr(e)[:2000],
                           "tag": args.tag}
                with open(path, "w") as f:
                    json.dump(res, f, indent=2)
                print(json.dumps(
                    {k: res.get(k) for k in
                     ("status", "compile_s", "collective_bytes_total",
                      "error")}, indent=None), flush=True)


if __name__ == "__main__":
    main()
