"""Process-level platform/device setup for server + bench entrypoints.

Long-lived processes (the nucleus server, the bench driver) need their
device decisions made ONCE, at process start, before the first jax
operation initializes a backend: the platform pick, the host-device
count, and the XLA flag set a GPU serving lane should run with (async
collectives + latency-hiding scheduler — the set the olmax/bayespec
slices ship; see SNIPPETS.md).  ``setup_platform`` is that one call —
``serve`` and ``benchmarks.run`` invoke it from ``main()`` ahead of any
device use.

Unlike the snippet it is modeled on, flag application *merges* into an
existing ``XLA_FLAGS`` (a flag already set by the operator wins), so a
container-level tuning baseline survives the entrypoint.
"""
from __future__ import annotations

import os
import warnings
from typing import Any, Dict, Iterable, Optional

# the GPU serving flag set (applied only when platform == "gpu"):
# overlap collectives with compute and let the scheduler hide launch
# latency — the knobs that matter for a request-batched serving loop
GPU_XLA_FLAGS = (
    "--xla_gpu_enable_async_collectives=true",
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
)


def _merge_xla_flags(new_flags: Iterable[str]) -> str:
    """Append ``new_flags`` to ``XLA_FLAGS``, existing settings winning:
    a flag whose ``--name=`` already appears is left untouched."""
    current = os.environ.get("XLA_FLAGS", "")
    have = {f.split("=", 1)[0] for f in current.split() if f}
    added = [f for f in new_flags if f.split("=", 1)[0] not in have]
    merged = " ".join(filter(None, [current, *added]))
    os.environ["XLA_FLAGS"] = merged
    return merged


def setup_platform(platform: Optional[str] = None, *,
                   cpu_devices: Optional[int] = None,
                   enable_x64: Optional[bool] = None,
                   extra_xla_flags: Iterable[str] = ()) -> Dict[str, Any]:
    """Configure the process's jax platform and XLA flags, once.

    Call from ``main()`` before any jax computation (config updates are
    ignored or rejected after a backend initializes).  All arguments are
    optional; None leaves the corresponding knob at its environment
    default (``JAX_PLATFORMS`` etc. keep working).

      platform        — "cpu" | "gpu" | "tpu"; also applies the GPU
                        serving flag set when "gpu".
      cpu_devices     — host platform device count (the
                        ``--xla_force_host_platform_device_count`` idiom
                        sharded CPU tests/meshes use), clamped to the
                        machine's core count with a warning.
      enable_x64      — flip jax's 64-bit mode.
      extra_xla_flags — additional ``--flag=value`` strings, merged
                        (operator-set flags win).

    Returns a record of what was applied (logged by the entrypoints,
    asserted by tests).
    """
    import jax

    applied: Dict[str, Any] = {"platform": None, "cpu_devices": None,
                               "enable_x64": None, "xla_flags": None}
    flags = list(extra_xla_flags)
    if platform is not None:
        jax.config.update("jax_platform_name", platform)
        applied["platform"] = platform
        if platform == "gpu":
            flags = list(GPU_XLA_FLAGS) + flags
    if cpu_devices is not None:
        n = int(cpu_devices)
        total = os.cpu_count() or 1
        if n > total:
            warnings.warn(
                f"requested {n} host devices but only {total} cores are "
                f"available; using {total}", RuntimeWarning)
            n = total
        flags.append(f"--xla_force_host_platform_device_count={n}")
        applied["cpu_devices"] = n
    if enable_x64 is not None:
        jax.config.update("jax_enable_x64", bool(enable_x64))
        applied["enable_x64"] = bool(enable_x64)
    if flags:
        applied["xla_flags"] = _merge_xla_flags(flags)
    return applied
