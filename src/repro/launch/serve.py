"""Serving driver: batched LM decode + DIN CTR scoring + nucleus queries.

`python -m repro.launch.serve --arch minicpm-2b` prefills a batch of prompts
and decodes tokens with the KV cache; `--arch din` scores batched CTR
requests.  Request batching is continuous-style: a fixed-slot batch where
finished sequences are replaced by queued prompts every step (the static
shape keeps the step jit-stable).

`--arch nucleus` is the paper's build-once/query-many lane: it loads a
serialized ``Decomposition`` (``--decomposition path.json``, e.g. computed
offline by the sharded backend; without a path a small graph is decomposed,
serialized, and reloaded to prove the loop) and answers batched
``cut``/``nuclei`` queries with latency stats — the heavy-traffic story of
Fig. 10 end-to-end.  ``--warm-pool`` instead drives a stream of graphs
through one ``repro.core.Session`` so same-bucket graphs reuse the compiled
peel executable (the offline stage at traffic, not just the query stage).
"""
from __future__ import annotations

import argparse
import time
from functools import partial
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_arch
from ..models import transformer as T
from ..models import din as DIN
from . import steps as S


def serve_lm(arch_id: str, n_requests: int = 16, batch_slots: int = 4,
             prompt_len: int = 16, gen_len: int = 24, smoke: bool = True,
             quiet: bool = False):
    spec = get_arch(arch_id)
    cfg = spec.make_smoke_config() if smoke else spec.make_config()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    max_len = prompt_len + gen_len
    rng = np.random.default_rng(0)
    queue: List[np.ndarray] = [
        rng.integers(0, cfg.vocab, prompt_len).astype(np.int32)
        for _ in range(n_requests)]
    decode = jax.jit(partial(S.lm_decode_step, cfg=cfg))

    cache = T.init_cache(cfg, batch_slots, max_len)
    # slot state (host): current length per slot, tokens emitted
    slot_req = [-1] * batch_slots
    produced = {}
    done = 0
    # simple continuous batching loop: one token per step for all slots
    lens = jnp.zeros((), jnp.int32)
    # per-slot caches must share cache_len in this compact driver, so slots
    # are refilled in waves (wave = batch_slots requests)
    t0 = time.time()
    wave = 0
    while done < n_requests:
        take = queue[wave * batch_slots:(wave + 1) * batch_slots]
        if not take:
            break
        bs = len(take)
        toks = np.stack([np.pad(t, (0, prompt_len - len(t))) for t in take])
        cache = T.init_cache(cfg, bs, max_len)
        # prefill via decode steps over the prompt (simple + exact)
        cache_len = jnp.zeros((), jnp.int32)
        last = None
        for i in range(prompt_len):
            last, cache, cache_len = decode(params,
                                            jnp.asarray(toks[:, i:i + 1]),
                                            cache, cache_len)
        outs = [last]
        for _ in range(gen_len - 1):
            nxt, cache, cache_len = decode(params, outs[-1][:, None], cache,
                                           cache_len)
            outs.append(nxt)
        for bi, req in enumerate(take):
            produced[wave * batch_slots + bi] = np.stack(
                [np.asarray(o[bi]) for o in outs])
        done += bs
        wave += 1
    dt = time.time() - t0
    if not quiet:
        tput = done * gen_len / dt
        print(f"served {done} requests, {gen_len} tokens each, "
              f"{tput:.1f} tok/s")
    return produced


def serve_din(n_batches: int = 8, batch: int = 512, smoke: bool = True,
              quiet: bool = False):
    spec = get_arch("din")
    cfg = spec.make_smoke_config() if smoke else spec.make_config()
    params = DIN.init_params(jax.random.PRNGKey(0), cfg)
    from ..data import RecsysStream, RecsysStreamConfig
    stream = RecsysStream(RecsysStreamConfig(
        n_items=cfg.n_items, n_cates=cfg.n_cates, n_users=cfg.n_user_feats,
        seq_len=cfg.seq_len, batch=batch))
    step = jax.jit(partial(S.din_serve_step, cfg=cfg))
    t0 = time.time()
    scores = []
    for i in range(n_batches):
        b = jax.tree.map(jnp.asarray, stream.batch(i))
        b.pop("label")
        scores.append(np.asarray(step(params, b)))
    dt = time.time() - t0
    if not quiet:
        print(f"scored {n_batches * batch} requests in {dt:.2f}s "
              f"({n_batches * batch / dt:.0f} req/s)")
    return np.concatenate(scores)


def serve_nucleus_warm_pool(n_graphs: int = 5, n_queries: int = 32,
                            seed: int = 0, bucket_cap: int = 0,
                            quiet: bool = False):
    """Warm-pool serving: one ``Session``, a stream of same-bucket graphs.

    The heavy-traffic shape of the decompose-once/query-many story: many
    tenants submit similar-sized graphs, the offline stage runs them
    through a shared ``Session`` so every graph after the first reuses the
    bucket's compiled peel executable, and each resulting artifact then
    answers cut/nuclei queries.  Prints per-graph decompose latency (the
    cold-vs-warm split), the session's bucket stats, and aggregate query
    latency.  Returns a stats dict.
    """
    from ..core import NucleusConfig, Session
    from ..graph import generators

    from ..core.incidence import build_problem

    if n_graphs < 1:
        raise SystemExit("--pool-graphs must be >= 1")
    sess_kw = {"bucket_cap": bucket_cap} if bucket_cap else {}
    sess = Session(NucleusConfig(r=2, s=3, backend="dense",
                                 hierarchy="fused"), **sess_kw)
    rng = np.random.default_rng(seed)
    dec_s: List[float] = []
    lat_us: List[float] = []
    queries = 0
    # the incidence structures are built up front (the build stage has its
    # own lane/chunked story, DESIGN.md §7); the timer below isolates what
    # the Session warms — the compiled peel + hierarchy
    problems = []
    for gi in range(n_graphs):
        # sizes drift but stay inside one power-of-two shape class, so the
        # pool demonstrates the warm path rather than bucket churn
        g = generators.planted_cliques(118 + 2 * gi, [10, 8, 6], 0.03,
                                       seed=seed + gi)
        problems.append(build_problem(g, 2, 3))
    for problem in problems:
        t0 = time.perf_counter()
        dec = sess.decompose(problem)
        dec_s.append(time.perf_counter() - t0)
        kmax = int(dec.core.max()) if dec.n_r else 0
        for c in rng.integers(1, max(kmax, 1) + 1, size=n_queries):
            t0 = time.perf_counter()
            dec.nuclei(int(c)) if queries % 2 else dec.cut(int(c))
            lat_us.append((time.perf_counter() - t0) * 1e6)
            queries += 1
    lat = np.asarray(lat_us) if lat_us else np.zeros((1,))
    # None (JSON-safe), not NaN, when a 1-graph pool has no warm calls
    warm = float(np.median(dec_s[1:])) if dec_s[1:] else None
    stats = {"graphs": n_graphs, "queries": queries,
             "decompose_cold_s": dec_s[0],
             "decompose_warm_s": warm,
             "p50_us": float(np.percentile(lat, 50)),
             "p95_us": float(np.percentile(lat, 95)),
             "session": {k: v for k, v in sess.stats.items()
                         if k != "buckets"},
             "n_buckets": len(sess.stats["buckets"])}
    if not quiet:
        warm_txt = "no warm calls (pool of 1)" if warm is None else (
            f"warm median {warm * 1e3:.0f}ms "
            f"({dec_s[0] / max(warm, 1e-9):.1f}x)")
        print(f"warm pool: {n_graphs} graphs through 1 Session "
              f"({stats['n_buckets']} shape bucket(s), "
              f"{stats['session']['warm']} warm hits): "
              f"cold {dec_s[0] * 1e3:.0f}ms, {warm_txt}; "
              f"{queries} queries p50={stats['p50_us']:.0f}us "
              f"p95={stats['p95_us']:.0f}us")
    return stats


def serve_nucleus(path: str = "", n_queries: int = 64, batch: int = 8,
                  seed: int = 0, quiet: bool = False):
    """Nucleus-query serving: decompose once (offline), query many (here).

    Loads a serialized ``Decomposition`` and answers ``n_queries`` queries
    in fixed-size batches — alternating ``cut(c)`` (nucleus labels) and
    ``nuclei(c)`` (vertex sets + densities) over random cut levels c.  The
    first query per level pays lazy tree/cut materialization; repeats hit
    the cache, which is exactly the decompose-once/query-many claim.
    Returns a stats dict (also printed unless quiet).
    """
    from ..core.api import Decomposition, NucleusConfig, decompose

    if path:
        dec = Decomposition.load(path)
    else:
        # no artifact supplied: build the offline stage inline on a small
        # planted graph, round-trip through JSON, and serve the reload —
        # the same code path a real offline artifact takes
        from ..graph import generators
        g = generators.planted_cliques(120, [10, 8, 6], 0.03, seed=3)
        offline = decompose(g, NucleusConfig(r=2, s=3, backend="dense",
                                             hierarchy="fused"))
        dec = Decomposition.from_json(offline.to_json())
    kmax = int(dec.core.max()) if dec.n_r else 0
    rng = np.random.default_rng(seed)
    lat_us: List[float] = []
    n_cut = n_nuc = 0
    t_all = time.perf_counter()
    for start in range(0, n_queries, batch):
        cs = rng.integers(1, max(kmax, 1) + 1, size=min(batch,
                                                        n_queries - start))
        for qi, c in enumerate(cs):
            t0 = time.perf_counter()
            if (start + qi) % 2 == 0:
                dec.cut(int(c))
                n_cut += 1
            else:
                dec.nuclei(int(c))
                n_nuc += 1
            lat_us.append((time.perf_counter() - t0) * 1e6)
    dt = time.perf_counter() - t_all
    lat = np.asarray(lat_us) if lat_us else np.zeros((1,))
    stats = {"queries": len(lat_us), "cut": n_cut, "nuclei": n_nuc,
             "qps": len(lat_us) / max(dt, 1e-9),
             "p50_us": float(np.percentile(lat, 50)),
             "p95_us": float(np.percentile(lat, 95)),
             "max_us": float(lat.max()), "n_r": dec.n_r, "kmax": kmax}
    if not quiet:
        print(f"served {stats['queries']} nucleus queries "
              f"({n_cut} cut, {n_nuc} nuclei) from a serialized "
              f"decomposition (n_r={dec.n_r}, kmax={kmax}): "
              f"{stats['qps']:.0f} q/s, p50={stats['p50_us']:.0f}us "
              f"p95={stats['p95_us']:.0f}us max={stats['max_us']:.0f}us")
    return stats


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--decomposition", default="",
                    help="path to a serialized Decomposition JSON "
                         "(--arch nucleus); omitted = inline offline stage")
    ap.add_argument("--queries", type=int, default=64,
                    help="number of nucleus queries (--arch nucleus)")
    ap.add_argument("--warm-pool", action="store_true",
                    help="--arch nucleus: decompose a stream of graphs "
                         "through one warm Session (shape-bucketed compile "
                         "cache) instead of serving a single artifact")
    ap.add_argument("--pool-graphs", type=int, default=5,
                    help="graphs in the warm pool (--warm-pool)")
    ap.add_argument("--bucket-cap", type=int, default=0,
                    help="LRU cap on the Session's tracked shape buckets "
                         "(--warm-pool); 0 = the Session default")
    args = ap.parse_args()
    if args.arch == "nucleus":
        if args.warm_pool:
            serve_nucleus_warm_pool(n_graphs=args.pool_graphs,
                                    n_queries=max(args.queries // max(
                                        args.pool_graphs, 1), 1),
                                    bucket_cap=args.bucket_cap)
        else:
            serve_nucleus(path=args.decomposition, n_queries=args.queries)
    elif args.arch == "din":
        serve_din(n_batches=4)
    else:
        serve_lm(args.arch, n_requests=args.requests)


if __name__ == "__main__":
    main()
