"""Serving driver: batched LM decode + DIN CTR scoring + nucleus queries.

`python -m repro.launch.serve --arch minicpm-2b` prefills a batch of prompts
and decodes tokens with the KV cache; `--arch din` scores batched CTR
requests.  Request batching is continuous-style: a fixed-slot batch where
finished sequences are replaced by queued prompts every step (the static
shape keeps the step jit-stable).

`--arch nucleus` is the paper's build-once/query-many lane: it loads a
serialized ``Decomposition`` (``--decomposition path.json``, e.g. computed
offline by the sharded backend; without a path a small graph is decomposed,
serialized, and reloaded to prove the loop) and answers batched
``cut``/``nuclei`` queries with latency stats — the heavy-traffic story of
Fig. 10 end-to-end.  ``--warm-pool`` drives a stream of graphs through the
plan-aware ``repro.serve.Router`` (``--r/--s/--method`` accept comma lists,
so the pool exercises mixed tenant configs across per-config Sessions).
``--server`` starts the real multi-tenant front end (DESIGN.md §11): the
bounded-queue ``Frontend`` + stdlib HTTP surface, with ``--cache-dir``
wiring the persistent compilation cache + session manifest so a restarted
server pre-warms its pools; ``--selftest`` drives a short mixed workload
over HTTP (decompose + query + update + status) and exits — the CI smoke.
"""
from __future__ import annotations

import argparse
import json
import time
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from functools import lru_cache

from ..configs import get_arch
from ..models import transformer as T
from ..models import din as DIN
from . import steps as S


# Step factories are memoized at module level (nucleuslint NL201): building
# `jax.jit(partial(step, cfg=...))` inside the driver body made every driver
# invocation re-trace the step — the same hazard class
# core/distributed._jitted_decomposition fixed for the sharded callable.
# The configs are frozen dataclasses, so they key an lru_cache directly.

@lru_cache(maxsize=16)
def _decode_step_fn(cfg):
    return jax.jit(partial(S.lm_decode_step, cfg=cfg))


@lru_cache(maxsize=16)
def _din_serve_step_fn(cfg):
    return jax.jit(partial(S.din_serve_step, cfg=cfg))


def serve_lm(arch_id: str, n_requests: int = 16, batch_slots: int = 4,
             prompt_len: int = 16, gen_len: int = 24, smoke: bool = True,
             quiet: bool = False):
    spec = get_arch(arch_id)
    cfg = spec.make_smoke_config() if smoke else spec.make_config()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    max_len = prompt_len + gen_len
    rng = np.random.default_rng(0)
    queue: List[np.ndarray] = [
        rng.integers(0, cfg.vocab, prompt_len).astype(np.int32)
        for _ in range(n_requests)]
    decode = _decode_step_fn(cfg)

    cache = T.init_cache(cfg, batch_slots, max_len)
    # slot state (host): current length per slot, tokens emitted
    slot_req = [-1] * batch_slots
    produced = {}
    done = 0
    # simple continuous batching loop: one token per step for all slots
    lens = jnp.zeros((), jnp.int32)
    # per-slot caches must share cache_len in this compact driver, so slots
    # are refilled in waves (wave = batch_slots requests)
    t0 = time.time()
    wave = 0
    while done < n_requests:
        take = queue[wave * batch_slots:(wave + 1) * batch_slots]
        if not take:
            break
        bs = len(take)
        toks = np.stack([np.pad(t, (0, prompt_len - len(t))) for t in take])
        cache = T.init_cache(cfg, bs, max_len)
        # prefill via decode steps over the prompt (simple + exact)
        cache_len = jnp.zeros((), jnp.int32)
        last = None
        for i in range(prompt_len):
            last, cache, cache_len = decode(params,
                                            jnp.asarray(toks[:, i:i + 1]),
                                            cache, cache_len)
        outs = [last]
        for _ in range(gen_len - 1):
            nxt, cache, cache_len = decode(params, outs[-1][:, None], cache,
                                           cache_len)
            outs.append(nxt)
        for bi, req in enumerate(take):
            produced[wave * batch_slots + bi] = np.stack(
                [np.asarray(o[bi]) for o in outs])
        done += bs
        wave += 1
    dt = time.time() - t0
    if not quiet:
        tput = done * gen_len / dt
        print(f"served {done} requests, {gen_len} tokens each, "
              f"{tput:.1f} tok/s")
    return produced


def serve_din(n_batches: int = 8, batch: int = 512, smoke: bool = True,
              quiet: bool = False):
    spec = get_arch("din")
    cfg = spec.make_smoke_config() if smoke else spec.make_config()
    params = DIN.init_params(jax.random.PRNGKey(0), cfg)
    from ..data import RecsysStream, RecsysStreamConfig
    stream = RecsysStream(RecsysStreamConfig(
        n_items=cfg.n_items, n_cates=cfg.n_cates, n_users=cfg.n_user_feats,
        seq_len=cfg.seq_len, batch=batch))
    step = _din_serve_step_fn(cfg)
    t0 = time.time()
    scores = []
    for i in range(n_batches):
        b = jax.tree.map(jnp.asarray, stream.batch(i))
        b.pop("label")
        scores.append(np.asarray(step(params, b)))
    dt = time.time() - t0
    if not quiet:
        print(f"scored {n_batches * batch} requests in {dt:.2f}s "
              f"({n_batches * batch / dt:.0f} req/s)")
    return np.concatenate(scores)


def _parse_pool_configs(r: str, s: str, method: str
                        ) -> List[Tuple[int, int, str]]:
    """Comma-list flag values -> positional (r, s, method) tuples (length-1
    lists broadcast), validated so a bad pair fails at the CLI, not three
    layers down."""
    rs = [int(x) for x in str(r).split(",")]
    ss = [int(x) for x in str(s).split(",")]
    ms = [m.strip() for m in str(method).split(",")]
    width = max(len(rs), len(ss), len(ms))
    bcast = lambda xs: xs * width if len(xs) == 1 else xs
    rs, ss, ms = bcast(rs), bcast(ss), bcast(ms)
    if not len(rs) == len(ss) == len(ms):
        raise SystemExit(
            f"--r/--s/--method comma lists must broadcast to one length; "
            f"got {len(rs)}/{len(ss)}/{len(ms)}")
    for rr, sv in zip(rs, ss):
        if not 1 <= rr < sv:
            raise SystemExit(f"need 1 <= r < s, got ({rr}, {sv})")
    return list(zip(rs, ss, ms))


def serve_nucleus_warm_pool(n_graphs: int = 5, n_queries: int = 32,
                            seed: int = 0, bucket_cap: int = 0,
                            r: str = "2", s: str = "3",
                            method: str = "exact",
                            quiet: bool = False):
    """Warm-pool serving through the plan-aware router.

    The heavy-traffic shape of the decompose-once/query-many story: many
    tenants submit similar-sized graphs under (possibly mixed) configs;
    the ``repro.serve.Router`` keys a ``Session`` pool per canonical
    config, so same-config same-bucket graphs reuse one compiled peel
    executable, and each resulting artifact then answers cut/nuclei
    queries.  ``--r/--s/--method`` accept comma lists — graphs
    round-robin over the config tuples, exercising multi-pool routing.
    Prints per-graph decompose latency (the cold-vs-warm split), the
    per-pool hit rates, and aggregate query latency.  Returns a stats
    dict (query percentiles are None when ``n_queries == 0`` — zero
    served queries have no latency distribution).
    """
    from ..core.incidence import build_problem
    from ..graph import generators
    from ..serve import Request, Router

    if n_graphs < 1:
        raise SystemExit("--pool-graphs must be >= 1")
    configs = _parse_pool_configs(r, s, method)
    router = Router(**({"bucket_cap": bucket_cap} if bucket_cap else {}))
    rng = np.random.default_rng(seed)
    dec_s: List[float] = []
    lat_us: List[float] = []
    queries = 0
    # the incidence structures are built up front (the build stage has its
    # own lane/chunked story, DESIGN.md §7); the timer below isolates what
    # the Sessions warm — the compiled peel + hierarchy
    requests = []
    for gi in range(n_graphs):
        # sizes drift but stay inside one power-of-two shape class, so the
        # pool demonstrates the warm path rather than bucket churn
        g = generators.planted_cliques(118 + 2 * gi, [10, 8, 6], 0.03,
                                       seed=seed + gi)
        rr, sv, mm = configs[gi % len(configs)]
        requests.append(Request(graph=build_problem(g, rr, sv),
                                r=rr, s=sv, method=mm))
    for req in requests:
        t0 = time.perf_counter()
        dec = router.route(req)
        dec_s.append(time.perf_counter() - t0)
        kmax = int(dec.core.max()) if dec.n_r else 0
        for c in rng.integers(1, max(kmax, 1) + 1, size=n_queries):
            t0 = time.perf_counter()
            dec.nuclei(int(c)) if queries % 2 else dec.cut(int(c))
            lat_us.append((time.perf_counter() - t0) * 1e6)
            queries += 1
    report = router.report()
    pools = report["pools"]
    warm_hits = sum(p["stats"]["warm"] for p in pools)
    n_buckets = sum(len(p["buckets"]) for p in pools)
    lat = np.asarray(lat_us)
    # None (JSON-safe), not NaN/zeros, when nothing was measured: a pool
    # of 1 has no warm calls, zero queries have no percentiles
    warm = float(np.median(dec_s[1:])) if dec_s[1:] else None
    stats = {"graphs": n_graphs, "queries": queries,
             "configs": [f"{m}-r{rr}s{sv}" for rr, sv, m in configs],
             "decompose_cold_s": dec_s[0],
             "decompose_warm_s": warm,
             "p50_us": float(np.percentile(lat, 50)) if queries else None,
             "p95_us": float(np.percentile(lat, 95)) if queries else None,
             "pools": [{"config": p["config"], "stats": p["stats"],
                        "hit_rate": p["hit_rate"]} for p in pools],
             "warm_hits": warm_hits,
             "n_buckets": n_buckets}
    if not quiet:
        warm_txt = "no warm calls (pool of 1)" if warm is None else (
            f"warm median {warm * 1e3:.0f}ms "
            f"({dec_s[0] / max(warm, 1e-9):.1f}x)")
        q_txt = "0 queries" if not queries else (
            f"{queries} queries p50={stats['p50_us']:.0f}us "
            f"p95={stats['p95_us']:.0f}us")
        print(f"warm pool: {n_graphs} graphs through {len(pools)} "
              f"router pool(s) ({n_buckets} shape bucket(s), "
              f"{warm_hits} warm hits): "
              f"cold {dec_s[0] * 1e3:.0f}ms, {warm_txt}; {q_txt}")
    return stats


def serve_nucleus(path: str = "", n_queries: int = 64, batch: int = 8,
                  seed: int = 0, quiet: bool = False):
    """Nucleus-query serving: decompose once (offline), query many (here).

    Loads a serialized ``Decomposition`` and answers ``n_queries`` queries
    in fixed-size batches — alternating ``cut(c)`` (nucleus labels) and
    ``nuclei(c)`` (vertex sets + densities) over random cut levels c.  The
    first query per level pays lazy tree/cut materialization; repeats hit
    the cache, which is exactly the decompose-once/query-many claim.
    Returns a stats dict (also printed unless quiet).
    """
    from ..core.api import Decomposition, NucleusConfig, decompose

    if path:
        dec = Decomposition.load(path)
    else:
        # no artifact supplied: build the offline stage inline on a small
        # planted graph, round-trip through JSON, and serve the reload —
        # the same code path a real offline artifact takes
        from ..graph import generators
        g = generators.planted_cliques(120, [10, 8, 6], 0.03, seed=3)
        offline = decompose(g, NucleusConfig(r=2, s=3, backend="dense",
                                             hierarchy="fused"))
        dec = Decomposition.from_json(offline.to_json())
    kmax = int(dec.core.max()) if dec.n_r else 0
    rng = np.random.default_rng(seed)
    lat_us: List[float] = []
    n_cut = n_nuc = 0
    t_all = time.perf_counter()
    for start in range(0, n_queries, batch):
        cs = rng.integers(1, max(kmax, 1) + 1, size=min(batch,
                                                        n_queries - start))
        for qi, c in enumerate(cs):
            t0 = time.perf_counter()
            if (start + qi) % 2 == 0:
                dec.cut(int(c))
                n_cut += 1
            else:
                dec.nuclei(int(c))
                n_nuc += 1
            lat_us.append((time.perf_counter() - t0) * 1e6)
    dt = time.perf_counter() - t_all
    lat = np.asarray(lat_us)
    # None (JSON-safe), not fake zeros, when no queries were served
    served = len(lat_us)
    stats = {"queries": served, "cut": n_cut, "nuclei": n_nuc,
             "qps": served / max(dt, 1e-9),
             "p50_us": float(np.percentile(lat, 50)) if served else None,
             "p95_us": float(np.percentile(lat, 95)) if served else None,
             "max_us": float(lat.max()) if served else None,
             "n_r": dec.n_r, "kmax": kmax}
    if not quiet:
        q_txt = "0 queries" if not served else (
            f"{stats['qps']:.0f} q/s, p50={stats['p50_us']:.0f}us "
            f"p95={stats['p95_us']:.0f}us max={stats['max_us']:.0f}us")
        print(f"served {served} nucleus queries "
              f"({n_cut} cut, {n_nuc} nuclei) from a serialized "
              f"decomposition (n_r={dec.n_r}, kmax={kmax}): {q_txt}")
    return stats


def _selftest_workload(host: str, port: int, quiet: bool = False
                       ) -> Dict[str, int]:
    """Drive the mixed CI-smoke workload over real HTTP and assert on it.

    Two same-bucket decomposes (the second MUST be a warm hit), a
    different-config decompose (second pool), cut + nuclei queries, one
    update delta (live version bump), and a status fetch validated
    against the schema.  Raises ``SystemExit`` on any violated
    invariant so the CI job fails loudly."""
    import urllib.request

    from ..graph import generators
    from ..serve import STATUS_FORMAT, validate_status

    def call(route: str, payload: Optional[Dict] = None) -> Dict:
        url = f"http://{host}:{port}{route}"
        if payload is None:
            req = urllib.request.Request(url)
        else:
            req = urllib.request.Request(
                url, data=json.dumps(payload).encode(),
                headers={"Content-Type": "application/json"})
        with urllib.request.urlopen(req, timeout=300) as resp:
            return json.loads(resp.read())

    def edges_of(g) -> List[List[int]]:
        return np.asarray(g.edges).tolist()

    def check(cond: bool, what: str) -> None:
        if not cond:
            raise SystemExit(f"server selftest failed: {what}")

    # sizes drift but stay inside one power-of-two shape class (the
    # warm-pool convention), so the second decompose MUST hit warm
    g0 = generators.planted_cliques(120, [10, 8, 6], 0.03, seed=3)
    g1 = generators.planted_cliques(122, [10, 8, 6], 0.03, seed=4)
    # same shape bucket + config as g0/g1 -> the warm path; distinct
    # artifact names so all three stay queryable
    a0 = call("/decompose", {"n": g0.n, "edges": edges_of(g0),
                             "r": 2, "s": 3, "artifact": "alpha"})
    a1 = call("/decompose", {"n": g1.n, "edges": edges_of(g1),
                             "r": 2, "s": 3, "artifact": "beta"})
    # a second tenant config -> a second router pool
    a2 = call("/decompose", {"n": g0.n, "edges": edges_of(g0),
                             "r": 1, "s": 2, "artifact": "gamma"})
    for name, art in (("alpha", a0), ("beta", a1), ("gamma", a2)):
        check(art["artifact"] == name and art["version"] == 0,
              f"decompose reply for {name!r}: {art}")
        check(art["plan"] and "backend" in art["plan"],
              f"decompose reply for {name!r} lacks an embedded plan")
    cut = call("/query", {"artifact": "alpha", "kind": "cut", "c": 1})
    check(len(cut["cut"]) == a0["n_r"], "cut length != n_r")
    nuc = call("/query", {"artifact": "beta", "kind": "nuclei", "c": 1})
    check(len(nuc["nuclei"]) >= 1, "no nuclei at c=1")
    upd = call("/update", {"artifact": "alpha",
                           "insert": [[0, int(g0.n - 1)]]})
    check(upd["version"] == 1, f"update did not bump version: {upd}")
    status = validate_status(call("/status"))
    check(status["format"] == STATUS_FORMAT, "bad status format")
    warm = sum(p["stats"]["warm"] for p in status["pools"])
    check(warm >= 1, f"expected >=1 warm hit after same-bucket pair, "
                     f"got {warm}")
    check(len(status["pools"]) == 2,
          f"expected 2 pools (two tenant configs), "
          f"got {len(status['pools'])}")
    check(status["artifacts"]["alpha"]["version"] == 1,
          "status does not show the updated live version")
    check(status["frontend"]["served"] >= 4, "served counter too low")
    out = {"decomposes": 3, "queries": 2, "updates": 1,
           "warm_hits": warm, "pools": len(status["pools"])}
    if not quiet:
        print(f"selftest ok: {out}")
    return out


def serve_nucleus_server(port: int = 0, cache_dir: str = "",
                         selftest: bool = False, max_queue: int = 64,
                         quiet: bool = False):
    """The real multi-tenant server (DESIGN.md §11).

    Builds the Router -> Frontend -> HTTP stack; with ``--cache-dir`` it
    first wires jax's persistent compilation cache and, if a session
    manifest from a previous run exists there, pre-warms the pools so the
    first same-bucket decompose after restart is a compile-cache hit.  On
    shutdown the manifest is (re)saved.  ``--selftest`` drives the mixed
    CI-smoke workload over HTTP and exits; without it the server blocks
    until SIGINT.
    """
    from ..serve import (Frontend, NucleusHTTPServer, Router,
                         init_persistent_cache, load_manifest,
                         prewarm_router, save_manifest)

    router = Router()
    prewarmed = 0
    if cache_dir:
        init_persistent_cache(cache_dir)
        manifest = load_manifest(cache_dir)
        if manifest is not None:
            prewarmed = prewarm_router(router, manifest)
    frontend = Frontend(router, max_queue=max_queue)
    server = NucleusHTTPServer(frontend, port=port)
    host, bound = server.start()
    if not quiet:
        print(f"nucleus server on http://{host}:{bound} "
              f"({prewarmed} bucket(s) pre-warmed"
              f"{' from ' + cache_dir if cache_dir else ''})")
    try:
        if selftest:
            out = _selftest_workload(host, bound, quiet=quiet)
            out["prewarmed"] = prewarmed
            return out
        while True:  # pragma: no cover - interactive serving loop
            time.sleep(1.0)
    except KeyboardInterrupt:  # pragma: no cover
        pass
    finally:
        server.stop()
        if cache_dir:
            save_manifest(router, cache_dir)
            if not quiet:
                print(f"session manifest saved to {cache_dir}")


def main() -> None:
    from .platform import setup_platform

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--platform", default="",
                    help="jax platform override (cpu|gpu|tpu); applied "
                         "before any device use, GPU adds the serving "
                         "XLA flag set")
    ap.add_argument("--cpu-devices", type=int, default=0,
                    help="host platform device count (0 = leave alone)")
    ap.add_argument("--decomposition", default="",
                    help="path to a serialized Decomposition JSON "
                         "(--arch nucleus); omitted = inline offline stage")
    ap.add_argument("--queries", type=int, default=64,
                    help="number of nucleus queries (--arch nucleus); "
                         "0 is honored (no query stage, percentiles None)")
    ap.add_argument("--warm-pool", action="store_true",
                    help="--arch nucleus: decompose a stream of graphs "
                         "through the plan-aware router (per-config "
                         "Session pools) instead of serving one artifact")
    ap.add_argument("--pool-graphs", type=int, default=5,
                    help="graphs in the warm pool (--warm-pool)")
    ap.add_argument("--bucket-cap", type=int, default=0,
                    help="LRU cap on each Session's tracked shape buckets "
                         "(--warm-pool); 0 = the Session default")
    ap.add_argument("--r", default="2",
                    help="nucleus r; comma list for mixed tenant configs "
                         "(--warm-pool)")
    ap.add_argument("--s", default="3",
                    help="nucleus s; comma list for mixed tenant configs "
                         "(--warm-pool)")
    ap.add_argument("--method", default="exact",
                    help="exact|approx; comma list for mixed tenant "
                         "configs (--warm-pool)")
    ap.add_argument("--server", action="store_true",
                    help="--arch nucleus: start the multi-tenant HTTP "
                         "server (Frontend + admission control)")
    ap.add_argument("--port", type=int, default=0,
                    help="--server port (0 = ephemeral)")
    ap.add_argument("--cache-dir", default="",
                    help="--server: persistent compilation cache + "
                         "session manifest directory (restart warm path)")
    ap.add_argument("--selftest", action="store_true",
                    help="--server: drive the mixed smoke workload over "
                         "HTTP, assert the status schema, and exit")
    args = ap.parse_args()
    setup_platform(platform=args.platform or None,
                   cpu_devices=args.cpu_devices or None)
    if args.arch == "nucleus":
        if args.server:
            serve_nucleus_server(port=args.port, cache_dir=args.cache_dir,
                                 selftest=args.selftest)
        elif args.warm_pool:
            serve_nucleus_warm_pool(n_graphs=args.pool_graphs,
                                    n_queries=args.queries // max(
                                        args.pool_graphs, 1),
                                    bucket_cap=args.bucket_cap,
                                    r=args.r, s=args.s, method=args.method)
        else:
            serve_nucleus(path=args.decomposition, n_queries=args.queries)
    elif args.arch == "din":
        serve_din(n_batches=4)
    else:
        serve_lm(args.arch, n_requests=args.requests)


if __name__ == "__main__":
    main()
