"""Serving driver: batched LM decode + DIN CTR scoring.

`python -m repro.launch.serve --arch minicpm-2b` prefills a batch of prompts
and decodes tokens with the KV cache; `--arch din` scores batched CTR
requests.  Request batching is continuous-style: a fixed-slot batch where
finished sequences are replaced by queued prompts every step (the static
shape keeps the step jit-stable).
"""
from __future__ import annotations

import argparse
import time
from functools import partial
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_arch
from ..models import transformer as T
from ..models import din as DIN
from . import steps as S


def serve_lm(arch_id: str, n_requests: int = 16, batch_slots: int = 4,
             prompt_len: int = 16, gen_len: int = 24, smoke: bool = True,
             quiet: bool = False):
    spec = get_arch(arch_id)
    cfg = spec.make_smoke_config() if smoke else spec.make_config()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    max_len = prompt_len + gen_len
    rng = np.random.default_rng(0)
    queue: List[np.ndarray] = [
        rng.integers(0, cfg.vocab, prompt_len).astype(np.int32)
        for _ in range(n_requests)]
    decode = jax.jit(partial(S.lm_decode_step, cfg=cfg))

    cache = T.init_cache(cfg, batch_slots, max_len)
    # slot state (host): current length per slot, tokens emitted
    slot_req = [-1] * batch_slots
    produced = {}
    done = 0
    # simple continuous batching loop: one token per step for all slots
    lens = jnp.zeros((), jnp.int32)
    # per-slot caches must share cache_len in this compact driver, so slots
    # are refilled in waves (wave = batch_slots requests)
    t0 = time.time()
    wave = 0
    while done < n_requests:
        take = queue[wave * batch_slots:(wave + 1) * batch_slots]
        if not take:
            break
        bs = len(take)
        toks = np.stack([np.pad(t, (0, prompt_len - len(t))) for t in take])
        cache = T.init_cache(cfg, bs, max_len)
        # prefill via decode steps over the prompt (simple + exact)
        cache_len = jnp.zeros((), jnp.int32)
        last = None
        for i in range(prompt_len):
            last, cache, cache_len = decode(params,
                                            jnp.asarray(toks[:, i:i + 1]),
                                            cache, cache_len)
        outs = [last]
        for _ in range(gen_len - 1):
            nxt, cache, cache_len = decode(params, outs[-1][:, None], cache,
                                           cache_len)
            outs.append(nxt)
        for bi, req in enumerate(take):
            produced[wave * batch_slots + bi] = np.stack(
                [np.asarray(o[bi]) for o in outs])
        done += bs
        wave += 1
    dt = time.time() - t0
    if not quiet:
        tput = done * gen_len / dt
        print(f"served {done} requests, {gen_len} tokens each, "
              f"{tput:.1f} tok/s")
    return produced


def serve_din(n_batches: int = 8, batch: int = 512, smoke: bool = True,
              quiet: bool = False):
    spec = get_arch("din")
    cfg = spec.make_smoke_config() if smoke else spec.make_config()
    params = DIN.init_params(jax.random.PRNGKey(0), cfg)
    from ..data import RecsysStream, RecsysStreamConfig
    stream = RecsysStream(RecsysStreamConfig(
        n_items=cfg.n_items, n_cates=cfg.n_cates, n_users=cfg.n_user_feats,
        seq_len=cfg.seq_len, batch=batch))
    step = jax.jit(partial(S.din_serve_step, cfg=cfg))
    t0 = time.time()
    scores = []
    for i in range(n_batches):
        b = jax.tree.map(jnp.asarray, stream.batch(i))
        b.pop("label")
        scores.append(np.asarray(step(params, b)))
    dt = time.time() - t0
    if not quiet:
        print(f"scored {n_batches * batch} requests in {dt:.2f}s "
              f"({n_batches * batch / dt:.0f} req/s)")
    return np.concatenate(scores)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()
    if args.arch == "din":
        serve_din(n_batches=4)
    else:
        serve_lm(args.arch, n_requests=args.requests)


if __name__ == "__main__":
    main()
