"""Production meshes.  Defined as functions so importing never touches jax
device state (the dry-run sets XLA_FLAGS before any jax initialization)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Whatever this host actually has (tests / examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))
