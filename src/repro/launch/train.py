"""End-to-end training driver (the runnable example backend).

Runs REAL training on whatever devices exist (CPU here, a pod in prod):
data pipeline -> jit-sharded train step -> checkpoint/restore -> straggler &
preemption handling.  `python -m repro.launch.train --arch minicpm-2b
--smoke` trains the reduced config for a few hundred steps on CPU.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from functools import lru_cache

from ..configs import get_arch
from ..data import TokenStream, TokenStreamConfig, RecsysStream, RecsysStreamConfig
from ..checkpoint import CheckpointManager
from ..distributed import StragglerMonitor, PreemptionGuard, HeartbeatLog
from ..distributed import sharding as shard_rules
from ..models import transformer as T
from ..optim import adamw
from . import steps as S
from .mesh import make_host_mesh


@dataclasses.dataclass
class TrainRun:
    losses: list
    steps_done: int
    restored_from: Optional[int]


# Train-step factories are memoized at module level (nucleuslint NL201):
# building `jax.jit(partial(step, cfg=...))` inside the driver body made
# every driver invocation — e.g. the restore-resume test's three train_lm
# calls — re-trace the step.  Same fix class as
# core/distributed._jitted_decomposition; the configs are frozen
# dataclasses, so they key an lru_cache directly.

@lru_cache(maxsize=16)
def _lm_train_step_fn(cfg, opt_cfg, n_micro):
    if n_micro > 1:
        return jax.jit(partial(S.lm_train_step_microbatched, cfg=cfg,
                               opt_cfg=opt_cfg, n_micro=n_micro))
    return jax.jit(partial(S.lm_train_step, cfg=cfg, opt_cfg=opt_cfg))


@lru_cache(maxsize=16)
def _din_train_step_fn(cfg, opt_cfg):
    return jax.jit(partial(S.din_train_step, cfg=cfg, opt_cfg=opt_cfg))


def train_lm(arch_id: str, steps: int = 200, smoke: bool = True,
             ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
             batch_override: Optional[int] = None,
             seq_override: Optional[int] = None,
             schedule: str = "cosine",
             resume: bool = False, log_every: int = 20,
             microbatches: int = 1, quiet: bool = False) -> TrainRun:
    spec = get_arch(arch_id)
    cfg = spec.make_smoke_config() if smoke else spec.make_config()
    mesh = make_host_mesh()
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=steps,
                                schedule=schedule)
    B = batch_override or 8
    Sq = seq_override or 64
    stream = TokenStream(TokenStreamConfig(vocab=cfg.vocab, seq_len=Sq,
                                           global_batch=B))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = adamw.init_state(params)
    rules = shard_rules.lm_param_rules(mesh, moe=cfg.moe is not None)
    p_sh = shard_rules.shard_tree(
        shard_rules.tree_specs(params, rules, mesh), mesh)
    params = jax.device_put(params, p_sh)

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start_step = 0
    restored_from = None
    if mgr and resume and mgr.latest_step() is not None:
        (params, opt_state), start_step, _ = mgr.restore((params, opt_state))
        restored_from = start_step

    step_fn = _lm_train_step_fn(cfg, opt_cfg, microbatches)
    monitor = StragglerMonitor()
    guard = PreemptionGuard()
    log = HeartbeatLog(f"{ckpt_dir}/heartbeat.jsonl") if ckpt_dir else None
    losses = []
    step = start_step
    try:
        for step in range(start_step, steps):
            batch = jax.tree.map(jnp.asarray, stream.batch(step))
            monitor.start_step()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            straggle = monitor.end_step()
            loss = float(metrics["loss"])
            losses.append(loss)
            if log:
                log.event("step", step=step, loss=loss)
                if straggle:
                    log.event("straggler", step=straggle[0],
                              duration=straggle[1], median=straggle[2])
            if not quiet and step % log_every == 0:
                print(f"step {step:5d}  loss {loss:.4f}  "
                      f"lr {float(metrics['lr']):.2e}", flush=True)
            if mgr and (step + 1) % ckpt_every == 0:
                mgr.save(step + 1, (params, opt_state))
            if guard.should_stop:
                if mgr:
                    mgr.save(step + 1, (params, opt_state), blocking=True)
                break
    finally:
        if mgr:
            mgr.wait()
        guard.restore()
        if log:
            log.close()
    return TrainRun(losses=losses, steps_done=step + 1 - start_step,
                    restored_from=restored_from)


def train_din(steps: int = 100, smoke: bool = True, batch: int = 256,
              quiet: bool = False) -> TrainRun:
    spec = get_arch("din")
    cfg = spec.make_smoke_config() if smoke else spec.make_config()
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=steps,
                                schedule="cosine", weight_decay=0.0)
    stream = RecsysStream(RecsysStreamConfig(
        n_items=cfg.n_items, n_cates=cfg.n_cates, n_users=cfg.n_user_feats,
        seq_len=cfg.seq_len, batch=batch))
    from ..models import din as DIN
    params = DIN.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = adamw.init_state(params)
    step_fn = _din_train_step_fn(cfg, opt_cfg)
    losses = []
    for step in range(steps):
        b = jax.tree.map(jnp.asarray, stream.batch(step))
        params, opt_state, metrics = step_fn(params, opt_state, b)
        losses.append(float(metrics["loss"]))
        if not quiet and step % 20 == 0:
            print(f"step {step:4d}  loss {losses[-1]:.4f}", flush=True)
    return TrainRun(losses=losses, steps_done=steps, restored_from=None)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--schedule", default="cosine",
                    choices=["constant", "cosine", "wsd"])
    args = ap.parse_args()
    spec = get_arch(args.arch)
    if spec.family == "recsys":
        run = train_din(steps=args.steps, smoke=args.smoke)
    else:
        run = train_lm(args.arch, steps=args.steps, smoke=args.smoke,
                       ckpt_dir=args.ckpt_dir, resume=args.resume,
                       schedule=args.schedule,
                       microbatches=args.microbatches)
    print(f"done: {run.steps_done} steps, "
          f"loss {run.losses[0]:.4f} -> {run.losses[-1]:.4f}")


if __name__ == "__main__":
    main()
