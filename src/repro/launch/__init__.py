from . import mesh
from . import steps
