"""Step functions per architecture family — the units the launcher jits.

Each step is a pure function suitable for jit/lower on any mesh; shardings
are supplied by the launcher from `repro.distributed.sharding` rules.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..models import transformer as T
from ..models import gin as GIN
from ..models import egnn as EGNN
from ..models import dimenet as DIME
from ..models import mace as MACE
from ..models import din as DIN
from ..models.gnn_common import GraphBatch
from ..optim import adamw


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

def lm_train_step(params, opt_state, batch, cfg: T.TransformerConfig,
                  opt_cfg: adamw.AdamWConfig):
    loss, grads = jax.value_and_grad(T.loss_fn)(
        params, batch["tokens"], batch["labels"], cfg)
    params, opt_state, metrics = adamw.apply_updates(params, grads,
                                                     opt_state, opt_cfg)
    return params, opt_state, {"loss": loss, **metrics}


def lm_train_step_microbatched(params, opt_state, batch,
                               cfg: T.TransformerConfig,
                               opt_cfg: adamw.AdamWConfig, n_micro: int):
    """Gradient accumulation over n_micro microbatches via lax.scan."""
    B = batch["tokens"].shape[0]
    mb = B // n_micro
    toks = batch["tokens"].reshape(n_micro, mb, -1)
    labs = batch["labels"].reshape(n_micro, mb, -1)

    def one(carry, xs):
        acc, = carry
        tk, lb = xs
        loss, grads = jax.value_and_grad(T.loss_fn)(params, tk, lb, cfg)
        acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
        return (acc,), loss

    zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (acc,), losses = jax.lax.scan(one, (zero,), (toks, labs))
    grads = jax.tree.map(lambda g: g / n_micro, acc)
    params, opt_state, metrics = adamw.apply_updates(params, grads,
                                                     opt_state, opt_cfg)
    return params, opt_state, {"loss": jnp.mean(losses), **metrics}


def lm_prefill_step(params, batch, cfg: T.TransformerConfig):
    """Inference prefill: forward over the full prompt, loss-free."""
    logits = T.forward(params, batch["tokens"], cfg)
    return jnp.argmax(logits[:, -1], axis=-1)


def lm_decode_step(params, tokens, cache, cache_len,
                   cfg: T.TransformerConfig):
    """One token for every sequence in the batch against a full KV cache."""
    logits, cache, new_len = T.decode_step(params, tokens, cache, cache_len,
                                           cfg)
    return jnp.argmax(logits[:, -1], axis=-1), cache, new_len


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------

_GNN = {"gin-tu": GIN, "egnn": EGNN, "dimenet": DIME, "mace": MACE}


def _rebatch(batch: Dict[str, jnp.ndarray], n_graphs: int) -> GraphBatch:
    return GraphBatch(
        nodes=batch["nodes"], edge_src=batch["edge_src"],
        edge_dst=batch["edge_dst"], node_mask=batch["node_mask"],
        edge_mask=batch["edge_mask"], graph_id=batch["graph_id"],
        n_graphs=n_graphs, pos=batch.get("pos"),
        triplet_kj=batch.get("triplet_kj"),
        triplet_ji=batch.get("triplet_ji"),
        triplet_mask=batch.get("triplet_mask"))


def gnn_loss(params, batch, cfg, arch: str, n_graphs: int, node_level: bool):
    gb = _rebatch(batch, n_graphs)
    mod = _GNN[arch]
    if arch == "gin-tu":
        if node_level:
            cfg2 = cfg.__class__(**{**cfg.__dict__, "graph_level": False})
            return GIN.loss_fn(params, gb, batch["labels"], cfg2,
                               batch.get("label_mask"))
        # graph-level regression (molecule shape): MSE on pooled readout
        out = GIN.forward(params, gb, cfg).astype(jnp.float32)
        return jnp.mean(jnp.square(out - batch["energy"].astype(jnp.float32)))
    if node_level:
        # equivariant models emit graph outputs; for node tasks we read out
        # per-node class scores from the last invariant features
        if arch == "egnn":
            out, _ = EGNN.forward(params, gb, cfg)
        elif arch == "mace":
            out, _ = MACE.forward(params, gb, cfg)
        else:
            out = DIME.forward(params, gb, cfg)
        # node-level: n_graphs == n_nodes with graph_id = node index
        logits = out.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["labels"][:, None],
                                   axis=-1)[:, 0]
        nll = logz - gold
        m = batch["label_mask"]
        return jnp.sum(nll * m) / jnp.maximum(m.sum(), 1.0)
    return mod.loss_fn(params, gb, batch["energy"], cfg)


def gnn_train_step(params, opt_state, batch, cfg, arch: str, n_graphs: int,
                   node_level: bool, opt_cfg: adamw.AdamWConfig):
    loss, grads = jax.value_and_grad(gnn_loss)(params, batch, cfg, arch,
                                               n_graphs, node_level)
    params, opt_state, metrics = adamw.apply_updates(params, grads,
                                                     opt_state, opt_cfg)
    return params, opt_state, {"loss": loss, **metrics}


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------

def din_train_step(params, opt_state, batch, cfg: DIN.DINConfig,
                   opt_cfg: adamw.AdamWConfig):
    loss, grads = jax.value_and_grad(DIN.loss_fn)(params, batch,
                                                  batch["label"], cfg)
    params, opt_state, metrics = adamw.apply_updates(params, grads,
                                                     opt_state, opt_cfg)
    return params, opt_state, {"loss": loss, **metrics}


def din_serve_step(params, batch, cfg: DIN.DINConfig):
    return jax.nn.sigmoid(DIN.forward(params, batch, cfg))


def din_retrieval_step(params, batch, cfg: DIN.DINConfig):
    return DIN.score_candidates(params, batch, cfg)
