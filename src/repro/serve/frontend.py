"""Concurrent request intake: bounded queue, coalescing, admission.

Concurrent clients and a single-writer engine meet here.  The
``Frontend`` owns a bounded queue and ONE worker thread; clients call
``submit()`` (any thread) and get a ``concurrent.futures.Future``, the
worker drains the queue and is the only thread that touches the
``Router``'s Sessions — so concurrent submissions produce artifacts
bit-identical to serial execution (the parity tests pin this), with no
engine-level locking at all.

  * **Admission control.**  ``submit()`` resolves the request's problem
    and computes the *padded* plan-budget estimate — the same
    ``4 * bucket_size(n_s * C, CHUNK_E) * C`` bytes the Session's
    megakernel gate uses, i.e. what the bucketed engine would actually
    allocate — and rejects over-budget graphs up front with a typed
    ``AdmissionError`` carrying the computed bytes.  A full queue is a
    typed ``QueueFullError`` (backpressure, not silent buffering).
  * **Coalescing.**  The worker drains whatever is queued, groups
    decompose jobs by (pool, shape bucket), and runs each group through
    ``Session.decompose_many`` — same-bucket tenants submitted together
    ride one warm executable back-to-back instead of interleaving pool
    switches.  Updates to named artifacts keep FIFO order (per-artifact
    generations must apply in submission order).
  * **Queries stay lock-free.**  ``query()`` reads the named artifact's
    cached cut/nuclei tables directly — the high-qps path never enters
    the queue.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future
from typing import Any, Dict, List, Optional, Tuple

from ..core.engine import MEGAKERNEL_PLAN_BUDGET_BYTES
from ..core.incidence import NucleusProblem
from ..core.session import bucket_size
from ..kernels.segment_sum import DEFAULT_CHUNK_E
from .router import Request, Router, canonical_config, pool_key


class AdmissionError(RuntimeError):
    """Request rejected up front: the padded engine plan for this graph
    would exceed the server's admission budget."""

    def __init__(self, plan_bytes: int, budget_bytes: int):
        self.plan_bytes = int(plan_bytes)
        self.budget_bytes = int(budget_bytes)
        super().__init__(
            f"admission rejected: padded plan needs {self.plan_bytes} "
            f"bytes > budget {self.budget_bytes} bytes — decompose this "
            f"graph offline (sharded/chunked) and serve the artifact, or "
            f"raise admission_budget_bytes")


class QueueFullError(RuntimeError):
    """Request rejected: the bounded intake queue is full (backpressure —
    retry after the pool drains)."""


def padded_plan_bytes(problem: NucleusProblem) -> int:
    """What the bucketed engine would allocate for ``problem``: the
    (e_pad, C) int32 member matrix with the edge axis pow2-bucketed —
    the same estimate ``Session.decompose`` gates the megakernel on
    (DESIGN.md §8/§9), reused here as the admission formula."""
    e_pad = bucket_size(problem.n_s * problem.n_sub, DEFAULT_CHUNK_E)
    return 4 * e_pad * problem.n_sub


@dataclasses.dataclass
class _Job:
    request: Request
    future: Future
    problem: Optional[NucleusProblem]   # resolved at admission time
    pool: Optional[Tuple]               # pool key (decompose jobs)
    bucket: Optional[Tuple]             # shape-bucket key (decompose jobs)


class Frontend:
    """The server's intake: ``submit() -> Future`` + a worker loop.

    ``max_queue`` bounds in-flight work (admission is per-graph, the
    queue bound is per-server); ``admission_budget_bytes`` defaults to
    the engine's megakernel plan budget.  ``start()``/``stop()`` manage
    the worker thread; ``stop()`` drains nothing — queued futures are
    cancelled so shutdown is prompt and explicit.
    """

    def __init__(self, router: Optional[Router] = None, *,
                 max_queue: int = 64,
                 admission_budget_bytes: int = MEGAKERNEL_PLAN_BUDGET_BYTES,
                 batch_wait_s: float = 0.002):
        self.router = router if router is not None else Router()
        self.admission_budget_bytes = int(admission_budget_bytes)
        self.batch_wait_s = float(batch_wait_s)
        self._queue: "queue.Queue[_Job]" = queue.Queue(maxsize=max_queue)
        self._stats_lock = threading.Lock()
        self.stats: Dict[str, int] = {
            "submitted": 0,           # accepted into the queue
            "served": 0,              # futures resolved successfully
            "failed": 0,              # futures resolved with an exception
            "rejected_admission": 0,  # AdmissionError at submit()
            "rejected_queue": 0,      # QueueFullError at submit()
            "batches": 0,             # worker drain cycles that did work
            "coalesced": 0,           # decompose jobs served in a shared
                                      # decompose_many batch (size >= 2)
        }
        self._worker: Optional[threading.Thread] = None
        self._running = threading.Event()

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "Frontend":
        if self._worker is not None:
            return self
        self._running.set()
        self._worker = threading.Thread(target=self._run, daemon=True,
                                        name="nucleus-frontend")
        self._worker.start()
        return self

    def stop(self, timeout: float = 10.0) -> None:
        if self._worker is None:
            return
        self._running.clear()
        self._worker.join(timeout)
        self._worker = None
        # cancel anything still queued: shutdown must be explicit, not
        # silently half-served
        while True:
            try:
                job = self._queue.get_nowait()
            except queue.Empty:
                break
            job.future.cancel()

    @property
    def queue_depth(self) -> int:
        return self._queue.qsize()

    def _count(self, name: str, by: int = 1) -> None:
        with self._stats_lock:
            self.stats[name] += by

    # -- intake ------------------------------------------------------------
    def submit(self, request: Request) -> "Future":
        """Admit + enqueue one request; returns a Future resolving to its
        ``Decomposition``.  Raises ``AdmissionError`` (over-budget graph)
        or ``QueueFullError`` (backpressure) instead of queueing doomed
        or unbounded work."""
        if self._worker is None:
            raise RuntimeError("Frontend not started — call start() first")
        problem = pool = bucket = None
        if request.kind == "decompose":
            problem, config = self.router.resolve(request)
            need = padded_plan_bytes(problem)
            if need > self.admission_budget_bytes:
                self._count("rejected_admission")
                raise AdmissionError(need, self.admission_budget_bytes)
            pool = pool_key(config)
            sess = self.router.pool(config)
            bucket = sess.bucket_key(problem, config)
        fut: Future = Future()
        job = _Job(request=request, future=fut, problem=problem,
                   pool=pool, bucket=bucket)
        try:
            self._queue.put_nowait(job)
        except queue.Full:
            self._count("rejected_queue")
            raise QueueFullError(
                f"intake queue full ({self._queue.maxsize} jobs) — "
                f"retry after the pool drains") from None
        self._count("submitted")
        return fut

    def submit_wait(self, request: Request, timeout: float = 300.0):
        """``submit`` + block for the artifact (small-scale callers)."""
        return self.submit(request).result(timeout=timeout)

    # -- reads (never queued) ----------------------------------------------
    def query(self, name: str, kind: str, c: int):
        """Answer a cut/nuclei query from the named live artifact's
        cached tables — the decompose-once/query-many hot path."""
        dec = self.router.artifact(name)
        if kind == "cut":
            return dec.cut(int(c))
        if kind == "nuclei":
            return dec.nuclei(int(c))
        raise ValueError(f"unknown query kind {kind!r}; expected "
                         f"'cut' or 'nuclei'")

    # -- the worker --------------------------------------------------------
    def _run(self) -> None:
        while self._running.is_set():
            try:
                first = self._queue.get(timeout=0.05)
            except queue.Empty:
                continue
            batch = [first]
            # drain whatever arrived with it (plus a short window so a
            # burst of concurrent submits lands in one coalesced batch)
            deadline_waited = False
            while True:
                try:
                    batch.append(self._queue.get_nowait())
                except queue.Empty:
                    if deadline_waited or not self.batch_wait_s:
                        break
                    time.sleep(self.batch_wait_s)
                    deadline_waited = True
            self._serve_batch(batch)
            self._count("batches")

    def _serve_batch(self, batch: List[_Job]) -> None:
        # decompose jobs grouped by (pool, shape bucket): each group is
        # one decompose_many call on one warm Session — the coalescing
        # claim.  Updates run afterwards in FIFO order (publication
        # precedes update within one drain; per-artifact generations
        # stay ordered).
        groups: Dict[Tuple, List[_Job]] = {}
        updates: List[_Job] = []
        for job in batch:
            if job.request.kind == "update":
                updates.append(job)
            else:
                groups.setdefault((job.pool, job.bucket), []).append(job)
        for (_pool, _bucket), jobs in groups.items():
            try:
                decs = self.router.route_many(
                    [j.request for j in jobs],
                    problems=[j.problem for j in jobs])
            except Exception as e:
                for j in jobs:
                    j.future.set_exception(e)
                self._count("failed", len(jobs))
                continue
            for j, dec in zip(jobs, decs):
                j.future.set_result(dec)
            self._count("served", len(jobs))
            if len(jobs) >= 2:
                self._count("coalesced", len(jobs))
        for job in updates:
            try:
                dec = self.router.update(job.request.artifact,
                                         job.request.update)
            except Exception as e:
                job.future.set_exception(e)
                self._count("failed")
                continue
            job.future.set_result(dec)
            self._count("served")
