"""The multi-tenant nucleus server (DESIGN.md §11).

Four layers over the ``repro.core`` Session/planner stack, turning the
decompose-once/query-many claim into a running service:

  * ``router``   — plan-aware routing: per-canonical-config ``Session``
                   pools, named live artifacts, per-pool Plan + hit-rate
                   introspection.
  * ``cache``    — the persistent warm path: jax's on-disk compilation
                   cache + the session manifest, so a restarted server
                   pre-warms its pools before taking traffic.
  * ``frontend`` — bounded intake queue, one single-writer worker,
                   same-bucket coalescing into ``decompose_many``, typed
                   admission control (``AdmissionError``).
  * ``status``   — the JSON status schema + validator; ``httpd`` serves
                   it (and decompose/query/update) over stdlib HTTP.

Entry point: ``python -m repro.launch.serve --arch nucleus --server``.
"""
from .cache import (init_persistent_cache, load_manifest, prewarm_router,
                    router_manifest, save_manifest)
from .frontend import (AdmissionError, Frontend, QueueFullError,
                       padded_plan_bytes)
from .httpd import NucleusHTTPServer
from .router import Request, Router, canonical_config, pool_key
from .status import (STATUS_FORMAT, STATUS_VERSION, status_report,
                     validate_status)

__all__ = [
    "AdmissionError", "Frontend", "NucleusHTTPServer", "QueueFullError",
    "Request", "Router", "STATUS_FORMAT", "STATUS_VERSION",
    "canonical_config", "init_persistent_cache", "load_manifest",
    "padded_plan_bytes", "pool_key", "prewarm_router", "router_manifest",
    "save_manifest", "status_report", "validate_status",
]
