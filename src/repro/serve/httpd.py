"""A thin stdlib HTTP surface over the ``Frontend``.

The server the CLI starts (``serve --arch nucleus --server``): four JSON
routes, no dependencies beyond ``http.server``.  Query and status
traffic is answered directly in the handler threads (they are pure
reads); decompose/update traffic goes through ``Frontend.submit`` so the
single-writer worker — not the HTTP threads — touches the Sessions.

  POST /decompose  {"n", "edges", "r", "s", "method", "hierarchy",
                    "artifact"?}        -> artifact summary + plan
  POST /query      {"artifact", "kind": "cut"|"nuclei", "c"}
  POST /update     {"artifact", "insert"?: [[u,v]..], "delete"?: ..}
  GET  /status                          -> serve.status schema

Typed rejections map to HTTP codes: over-budget admission is 413
(payload too large), queue backpressure is 429 (too many requests),
unknown artifacts are 404, malformed bodies 400.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, Optional, Tuple

import numpy as np

from ..core.streaming import GraphDelta
from ..graph.container import make_graph
from .frontend import AdmissionError, Frontend, QueueFullError
from .router import Request
from .status import status_report, validate_status


def _decompose_summary(dec) -> Dict[str, Any]:
    kmax = int(dec.core.max()) if dec.n_r else 0
    return {"artifact": dec.name, "version": dec.version,
            "n_r": dec.n_r, "kmax": kmax, "rounds": dec.rounds,
            "plan": None if dec.plan is None else dec.plan.to_dict()}


class _Handler(BaseHTTPRequestHandler):
    frontend: Frontend  # injected by NucleusHTTPServer
    request_timeout_s: float

    # silence the default per-request stderr log (the status endpoint is
    # the observability surface)
    def log_message(self, fmt, *args):  # noqa: A002
        pass

    def _send(self, code: int, payload: Dict[str, Any]) -> None:
        blob = json.dumps(payload).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(blob)))
        self.end_headers()
        self.wfile.write(blob)

    def _body(self) -> Dict[str, Any]:
        length = int(self.headers.get("Content-Length", 0))
        if length == 0:
            return {}
        return json.loads(self.rfile.read(length))

    def do_GET(self) -> None:  # noqa: N802
        if self.path.rstrip("/") in ("", "/status"):
            self._send(200, validate_status(status_report(self.frontend)))
        else:
            self._send(404, {"error": f"unknown route {self.path!r}"})

    def do_POST(self) -> None:  # noqa: N802
        try:
            body = self._body()
        except (ValueError, json.JSONDecodeError) as e:
            self._send(400, {"error": f"malformed JSON body: {e}"})
            return
        try:
            if self.path == "/decompose":
                self._decompose(body)
            elif self.path == "/query":
                self._query(body)
            elif self.path == "/update":
                self._update(body)
            else:
                self._send(404, {"error": f"unknown route {self.path!r}"})
        except AdmissionError as e:
            self._send(413, {"error": str(e), "plan_bytes": e.plan_bytes,
                             "budget_bytes": e.budget_bytes})
        except QueueFullError as e:
            self._send(429, {"error": str(e)})
        except KeyError as e:
            self._send(404, {"error": str(e.args[0]) if e.args else str(e)})
        except (ValueError, TypeError) as e:
            self._send(400, {"error": str(e)})

    def _decompose(self, body: Dict[str, Any]) -> None:
        # missing fields are a malformed body (400), not a missing
        # resource — only unknown-artifact KeyErrors mean 404
        for field in ("n", "edges"):
            if field not in body:
                raise ValueError(f"decompose body requires {field!r}")
        graph = make_graph(int(body["n"]),
                           np.asarray(body["edges"], np.int64))
        req = Request(graph=graph,
                      r=int(body.get("r", 2)), s=int(body.get("s", 3)),
                      method=str(body.get("method", "exact")),
                      hierarchy=str(body.get("hierarchy", "fused")),
                      backend=str(body.get("backend", "dense")),
                      delta=float(body.get("delta", 0.1)),
                      artifact=str(body.get("artifact", "")))
        dec = self.frontend.submit(req).result(self.request_timeout_s)
        self._send(200, _decompose_summary(dec))

    def _query(self, body: Dict[str, Any]) -> None:
        for field in ("artifact", "c"):
            if field not in body:
                raise ValueError(f"query body requires {field!r}")
        name, kind = str(body["artifact"]), str(body.get("kind", "cut"))
        c = int(body["c"])
        out = self.frontend.query(name, kind, c)
        dec = self.frontend.router.artifact(name)
        if kind == "cut":
            payload: Dict[str, Any] = {"cut": np.asarray(out).tolist()}
        else:
            payload = {"nuclei": {
                str(lab): {"vertices": nuc.vertices.tolist(),
                           "n_r_cliques": nuc.n_r_cliques,
                           "density": None if np.isnan(nuc.density)
                           else float(nuc.density)}
                for lab, nuc in out.items()}}
        payload.update({"artifact": name, "version": dec.version, "c": c})
        self._send(200, payload)

    def _update(self, body: Dict[str, Any]) -> None:
        if "artifact" not in body:
            raise ValueError("update body requires 'artifact'")
        delta = GraphDelta(
            insert=np.asarray(body.get("insert", []),
                              np.int64).reshape(-1, 2),
            delete=np.asarray(body.get("delete", []),
                              np.int64).reshape(-1, 2))
        req = Request(artifact=str(body["artifact"]), update=delta)
        dec = self.frontend.submit(req).result(self.request_timeout_s)
        self._send(200, _decompose_summary(dec))


class NucleusHTTPServer:
    """Own a ``ThreadingHTTPServer`` bound to a ``Frontend``.

    ``start()`` binds (port 0 = ephemeral) and serves in a daemon
    thread; ``stop()`` shuts both the HTTP loop and the frontend worker
    down.  The handler class is built per-instance so two servers in one
    process (tests) never share a frontend."""

    def __init__(self, frontend: Frontend, host: str = "127.0.0.1",
                 port: int = 0, request_timeout_s: float = 300.0):
        self.frontend = frontend
        self._handler = type("BoundHandler", (_Handler,),
                             {"frontend": frontend,
                              "request_timeout_s": request_timeout_s})
        self._httpd = ThreadingHTTPServer((host, port), self._handler)
        self._thread: Optional[threading.Thread] = None

    @property
    def address(self) -> Tuple[str, int]:
        return self._httpd.server_address[:2]

    def start(self) -> Tuple[str, int]:
        self.frontend.start()
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        daemon=True, name="nucleus-httpd")
        self._thread.start()
        return self.address

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(5.0)
            self._thread = None
        self.frontend.stop()
